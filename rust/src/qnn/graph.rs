//! Static description of QNN architectures as a DAG (the SparqCNN
//! chain from `python/compile/model.py` plus residual, depthwise-
//! separable and dense-headed variants), and the mixed-precision
//! legality rules the dataflow compiler enforces.
//!
//! ## Graph shape
//!
//! A [`QnnGraph`] is a list of [`LayerDesc`] nodes plus an explicit
//! edge list: `preds[i]` names the producer node(s) layer `i`
//! consumes.  Exactly one node has no predecessors — it consumes the
//! graph input.  [`LayerDesc::Add`] (the residual join) takes exactly
//! two predecessors; every other kind takes one.  Compilation and the
//! golden network walk the graph in the deterministic Kahn topological
//! order of [`QnnGraph::topo_order`] (lowest index first, so linear
//! chains keep their declaration order and stay bit-identical with the
//! pre-DAG compiler).
//!
//! ## Per-layer precision
//!
//! A quantized conv-like layer ([`LayerDesc::Conv`],
//! [`LayerDesc::DepthwiseConv`], [`LayerDesc::Dense`]) may carry an
//! optional `(w_bits, a_bits)` override (`precision`); layers without
//! one inherit the network default
//! ([`crate::qnn::schedule::QnnPrecision`]).  Legality is checked at
//! two levels:
//!
//! * [`QnnGraph::validate`] — graph-intrinsic rules (DAG shape
//!   chaining, cycle rejection, fan-in arity, override ranges,
//!   overrides only on quantized layers), no processor needed.
//! * [`QnnGraph::validate_for`] — the full mixed-precision rules for a
//!   concrete processor: every resolved precision must map to a legal
//!   kernel variant (vmacsr-only precisions are rejected on Ara-like
//!   configs with no `vmacsr`; `Dense` is vmacsr-only), every requant
//!   boundary must narrow to the next layer's activation element width
//!   in at most one `vnsrl` step, and the two branches of an `Add`
//!   join must live in the same activation level domain
//!   ([`GraphError::JoinPrecision`] — a W2-quantized branch cannot be
//!   summed with a W4-quantized branch without an explicit requant,
//!   which no join stage emits).  Boundary widths are derived from the
//!   *canonical* variant assignment (the same region-calculus plan the
//!   compiler and the golden network resolve through); the autotuner
//!   may only substitute variants that keep the chain legal.

use crate::arch::ProcessorConfig;
use crate::isa::Sew;
use crate::qnn::schedule::QnnPrecision;
use crate::ulppack::region::{self, Container, RegionMode};

/// One layer of the network.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerDesc {
    /// 'same' conv: C_in x H x W -> C_out x H x W with an FxF kernel.
    /// `precision` is the optional per-layer `(w_bits, a_bits)`
    /// override; `None` inherits the network default.  A pointwise
    /// (1x1) conv is this kind with `f: 1`.
    Conv {
        c_in: u32,
        c_out: u32,
        h: u32,
        w: u32,
        f: u32,
        quantized: bool,
        precision: Option<(u32, u32)>,
    },
    /// 2x2 max pool (halves H and W).
    MaxPool { c: u32, h: u32, w: u32 },
    /// Global average pool + linear head.
    GapFc { c: u32, classes: u32 },
    /// Residual join: element-wise add of two equal-shape branches,
    /// each requantized into the common activation level domain first
    /// (kernels/eltwise.rs).  Always takes exactly two predecessors.
    Add { c: u32, h: u32, w: u32 },
    /// Depthwise 'same' conv: one FxF filter per channel (C x H x W ->
    /// C x H x W), always quantized, lowered as C per-channel packed
    /// sub-convs sharing one autotune entry.
    DepthwiseConv { c: u32, h: u32, w: u32, f: u32, precision: Option<(u32, u32)> },
    /// Dense / GEMM layer over the flattened C_in x H x W input
    /// (kernels/im2col_gemm.rs as a full-extent 'valid' conv with
    /// Ho = Wo = 1), always quantized, vmacsr-only.
    Dense { c_in: u32, h: u32, w: u32, c_out: u32, precision: Option<(u32, u32)> },
}

impl LayerDesc {
    /// Multiply-accumulates of this layer (per image).
    pub fn macs(&self) -> u64 {
        match *self {
            LayerDesc::Conv { c_in, c_out, h, w, f, .. } => {
                c_in as u64 * c_out as u64 * h as u64 * w as u64 * (f * f) as u64
            }
            LayerDesc::MaxPool { .. } => 0,
            LayerDesc::GapFc { c, classes } => (c * classes) as u64,
            // the join is adds only, no multiplies
            LayerDesc::Add { .. } => 0,
            LayerDesc::DepthwiseConv { c, h, w, f, .. } => {
                c as u64 * h as u64 * w as u64 * (f * f) as u64
            }
            LayerDesc::Dense { c_in, h, w, c_out, .. } => {
                c_in as u64 * h as u64 * w as u64 * c_out as u64
            }
        }
    }

    pub fn name(&self) -> String {
        match *self {
            LayerDesc::Conv { c_in, c_out, f, quantized, .. } => format!(
                "conv {c_in}->{c_out} {f}x{f}{}",
                if quantized { " [sub-byte]" } else { " [stem]" }
            ),
            LayerDesc::MaxPool { .. } => "maxpool2".into(),
            LayerDesc::GapFc { .. } => "gap+fc".into(),
            LayerDesc::Add { .. } => "add [join]".into(),
            LayerDesc::DepthwiseConv { c, f, .. } => format!("dwconv {c} {f}x{f} [sub-byte]"),
            LayerDesc::Dense { c_in, h, w, c_out, .. } => {
                format!("dense {}->{c_out} [sub-byte]", c_in * h * w)
            }
        }
    }

    /// (c, h, w) this layer consumes.
    pub fn in_dims(&self) -> (u32, u32, u32) {
        match *self {
            LayerDesc::Conv { c_in, h, w, .. } => (c_in, h, w),
            LayerDesc::MaxPool { c, h, w } => (c, h, w),
            // GAP+FC consumes whatever spatial extent it is handed;
            // validate() checks the channel count only
            LayerDesc::GapFc { c, .. } => (c, 0, 0),
            LayerDesc::Add { c, h, w } => (c, h, w),
            LayerDesc::DepthwiseConv { c, h, w, .. } => (c, h, w),
            LayerDesc::Dense { c_in, h, w, .. } => (c_in, h, w),
        }
    }

    /// (c, h, w) this layer produces ('same' convs preserve h x w;
    /// GAP+FC and Dense produce flat vectors).
    pub fn out_dims(&self) -> (u32, u32, u32) {
        match *self {
            LayerDesc::Conv { c_out, h, w, .. } => (c_out, h, w),
            LayerDesc::MaxPool { c, h, w } => (c, h / 2, w / 2),
            LayerDesc::GapFc { classes, .. } => (classes, 1, 1),
            LayerDesc::Add { c, h, w } => (c, h, w),
            LayerDesc::DepthwiseConv { c, h, w, .. } => (c, h, w),
            LayerDesc::Dense { c_out, .. } => (c_out, 1, 1),
        }
    }

    /// How many input edges this kind requires (the residual join
    /// takes two; everything else one).
    pub fn fan_in(&self) -> usize {
        match *self {
            LayerDesc::Add { .. } => 2,
            _ => 1,
        }
    }
}

/// Why a [`QnnGraph`] failed shape-chaining or mixed-precision
/// validation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GraphError {
    Empty,
    /// Layer `layer`'s declared input dims do not equal its
    /// producer's output dims (for `Add`, either producer's).
    ShapeMismatch { layer: usize, expected: (u32, u32, u32), got: (u32, u32, u32) },
    /// 2x2 pooling needs even spatial dims.
    OddPool { layer: usize, h: u32, w: u32 },
    /// 'same' convs need an odd kernel (symmetric border).
    EvenKernel { layer: usize, f: u32 },
    /// GAP+FC must be the final layer.
    HeadNotLast { layer: usize },
    /// The head's class count disagrees with the graph's.
    ClassMismatch { head: u32, graph: u32 },
    /// A resolved quantized-layer precision is outside the sub-byte
    /// range the packed kernels support (W and A in 1..=4).
    BadPrecision { layer: usize, w_bits: u32, a_bits: u32 },
    /// A per-layer precision override on a non-quantized (int16 stem)
    /// conv — the stem always runs 8-bit weights.
    OverrideOnStem { layer: usize },
    /// No kernel variant on this processor can run the layer's
    /// resolved precision (e.g. W4A4 on an Ara-like config: vmacsr is
    /// absent and the native ULPPACK scheme cannot admit the pair;
    /// `Dense` always needs vmacsr).
    VariantUnsupported { layer: usize, w_bits: u32, a_bits: u32, processor: String },
    /// A requant boundary would have to narrow by more than one
    /// element-width step (the producer's wide output element vs the
    /// consumer's container width under the canonical variant
    /// assignment) — `vnsrl` narrows one step per boundary.
    BoundaryWidth { layer: usize, from_bits: u32, to_bits: u32 },
    /// Layer `layer` is part of a dependency cycle (or names an
    /// unresolvable input edge — a self-loop or an out-of-range
    /// predecessor index): no topological order exists.
    Cycle { layer: usize },
    /// Layer `layer` has the wrong number of input edges for its kind
    /// (`Add` joins take exactly two; every other layer one; only the
    /// single graph-input node may have zero).
    FanInMismatch { layer: usize, expected: usize, got: usize },
    /// The two branches of an `Add` join resolved to different
    /// activation level domains (`a` vs `b` activation bits): summing
    /// W2-quantized levels with W4-quantized levels without an
    /// explicit requant would mix scales, and no join stage emits one.
    JoinPrecision { layer: usize, a: u32, b: u32 },
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            GraphError::Empty => write!(f, "graph has no layers"),
            GraphError::ShapeMismatch { layer, expected, got } => write!(
                f,
                "layer {layer}: input dims {got:?} != producer's output {expected:?}"
            ),
            GraphError::OddPool { layer, h, w } => {
                write!(f, "layer {layer}: 2x2 maxpool over odd dims {h}x{w}")
            }
            GraphError::EvenKernel { layer, f: k } => {
                write!(f, "layer {layer}: 'same' conv needs an odd kernel, got {k}x{k}")
            }
            GraphError::HeadNotLast { layer } => {
                write!(f, "layer {layer}: gap+fc must be the final layer")
            }
            GraphError::ClassMismatch { head, graph } => {
                write!(f, "head produces {head} classes but the graph declares {graph}")
            }
            GraphError::BadPrecision { layer, w_bits, a_bits } => write!(
                f,
                "layer {layer}: resolved precision W{w_bits}A{a_bits} outside the sub-byte range 1..=4"
            ),
            GraphError::OverrideOnStem { layer } => write!(
                f,
                "layer {layer}: precision override on a non-quantized stem conv (the stem runs int16)"
            ),
            GraphError::VariantUnsupported { layer, w_bits, a_bits, ref processor } => write!(
                f,
                "layer {layer}: no kernel variant runs W{w_bits}A{a_bits} on '{processor}' \
                 (vmacsr absent and the precision is outside the native ULPPACK region)"
            ),
            GraphError::BoundaryWidth { layer, from_bits, to_bits } => write!(
                f,
                "layer {layer}: requant boundary narrows {from_bits}-bit producer elements to \
                 {to_bits}-bit consumer elements (more than one vnsrl step)"
            ),
            GraphError::Cycle { layer } => write!(
                f,
                "layer {layer}: dependency cycle (no topological order resolves its input edges)"
            ),
            GraphError::FanInMismatch { layer, expected, got } => write!(
                f,
                "layer {layer}: expects {expected} input edge(s), got {got}"
            ),
            GraphError::JoinPrecision { layer, a, b } => write!(
                f,
                "layer {layer}: add join over branches in different activation level domains \
                 (A{a} vs A{b}) — joining W2/W4-style mixed branches needs a requant no join emits"
            ),
        }
    }
}

impl std::error::Error for GraphError {}

/// In-channel count the packed kernels actually run with: odd counts
/// get one explicit always-zero channel (the stem's 1 -> 2).
pub fn padded_c(c: u32) -> u32 {
    if c % 2 == 1 {
        c + 1
    } else {
        c
    }
}

/// The whole network: nodes plus explicit input edges.  `preds[i]`
/// are the producer indices of layer `i`; the single node with no
/// predecessors consumes the graph input.  Linear networks are built
/// with [`QnnGraph::chain`]; the fields stay public so tests and
/// callers can reshape graphs, with [`QnnGraph::validate`] as the
/// gatekeeper (`preds.len()` must equal `layers.len()`).
#[derive(Debug, Clone)]
pub struct QnnGraph {
    pub layers: Vec<LayerDesc>,
    /// Input edges: `preds[i]` = indices of the layer(s) feeding layer
    /// `i`.  Empty = consumes the graph input.
    pub preds: Vec<Vec<usize>>,
    pub input: (u32, u32, u32),
    pub classes: u32,
}

impl QnnGraph {
    /// A straight-line chain: layer `i` consumes layer `i-1`, layer 0
    /// the graph input — the pre-DAG implicit topology, made explicit.
    pub fn chain(layers: Vec<LayerDesc>, input: (u32, u32, u32), classes: u32) -> QnnGraph {
        let preds = (0..layers.len())
            .map(|i| if i == 0 { Vec::new() } else { vec![i - 1] })
            .collect();
        QnnGraph { layers, preds, input, classes }
    }

    /// The SparqCNN from `python/compile/model.py`: 16x16 single-channel
    /// inputs, 4 classes; conv2/conv3 carry the sub-byte precision.
    pub fn sparq_cnn() -> QnnGraph {
        QnnGraph::chain(
            vec![
                LayerDesc::Conv {
                    c_in: 1,
                    c_out: 16,
                    h: 16,
                    w: 16,
                    f: 3,
                    quantized: false,
                    precision: None,
                },
                LayerDesc::Conv {
                    c_in: 16,
                    c_out: 32,
                    h: 16,
                    w: 16,
                    f: 3,
                    quantized: true,
                    precision: None,
                },
                LayerDesc::MaxPool { c: 32, h: 16, w: 16 },
                LayerDesc::Conv {
                    c_in: 32,
                    c_out: 32,
                    h: 8,
                    w: 8,
                    f: 3,
                    quantized: true,
                    precision: None,
                },
                LayerDesc::MaxPool { c: 32, h: 8, w: 8 },
                LayerDesc::GapFc { c: 32, classes: 4 },
            ],
            (1, 16, 16),
            4,
        )
    }

    /// The SparqCNN with per-layer precision overrides on the two
    /// quantized convs: `stem_adj` on the stem-adjacent conv (layer 1)
    /// and `deep` on the deeper conv (layer 3).  The paper's precision
    /// ladder in mixed form — e.g. a W4A4 stem-adjacent conv keeping
    /// early-layer fidelity over a W2A2 deep conv taking the 3.2x
    /// throughput.
    pub fn sparq_cnn_mixed(stem_adj: (u32, u32), deep: (u32, u32)) -> QnnGraph {
        let mut g = QnnGraph::sparq_cnn();
        let set = |l: &mut LayerDesc, p: (u32, u32)| {
            if let LayerDesc::Conv { precision, .. } = l {
                *precision = Some(p);
            }
        };
        set(&mut g.layers[1], stem_adj);
        set(&mut g.layers[3], deep);
        g
    }

    /// A ResNet-style residual block on the SparqCNN scaffold: two
    /// quantized convs whose output rejoins the block input through an
    /// `Add` (layer 3 consumes layers 1 AND 2), then the usual
    /// pool/conv/pool/head tail.
    pub fn sparq_resnetlike() -> QnnGraph {
        let conv = |c_in, c_out, h, w| LayerDesc::Conv {
            c_in,
            c_out,
            h,
            w,
            f: 3,
            quantized: true,
            precision: None,
        };
        let mut g = QnnGraph::chain(
            vec![
                LayerDesc::Conv {
                    c_in: 1,
                    c_out: 16,
                    h: 16,
                    w: 16,
                    f: 3,
                    quantized: false,
                    precision: None,
                },
                conv(16, 16, 16, 16),
                conv(16, 16, 16, 16),
                LayerDesc::Add { c: 16, h: 16, w: 16 },
                LayerDesc::MaxPool { c: 16, h: 16, w: 16 },
                conv(16, 32, 8, 8),
                LayerDesc::MaxPool { c: 32, h: 8, w: 8 },
                LayerDesc::GapFc { c: 32, classes: 4 },
            ],
            (1, 16, 16),
            4,
        );
        // the residual edge: the join reads both the block input
        // (layer 1) and the block body (layer 2)
        g.preds[3] = vec![1, 2];
        g
    }

    /// A MobileNet-style depthwise-separable network: two
    /// depthwise-conv + pointwise-conv (1x1) blocks between the stem
    /// and the head.
    pub fn sparq_mobilenetlike() -> QnnGraph {
        let pw = |c_in, c_out, h, w| LayerDesc::Conv {
            c_in,
            c_out,
            h,
            w,
            f: 1,
            quantized: true,
            precision: None,
        };
        QnnGraph::chain(
            vec![
                LayerDesc::Conv {
                    c_in: 1,
                    c_out: 8,
                    h: 16,
                    w: 16,
                    f: 3,
                    quantized: false,
                    precision: None,
                },
                LayerDesc::DepthwiseConv { c: 8, h: 16, w: 16, f: 3, precision: None },
                pw(8, 16, 16, 16),
                LayerDesc::MaxPool { c: 16, h: 16, w: 16 },
                LayerDesc::DepthwiseConv { c: 16, h: 8, w: 8, f: 3, precision: None },
                pw(16, 32, 8, 8),
                LayerDesc::MaxPool { c: 32, h: 8, w: 8 },
                LayerDesc::GapFc { c: 32, classes: 4 },
            ],
            (1, 16, 16),
            4,
        )
    }

    /// A dense/GEMM-headed network: a small conv trunk flattened into
    /// a `Dense` layer (im2col GEMM) before the GAP+FC head.
    pub fn sparq_denselike() -> QnnGraph {
        QnnGraph::chain(
            vec![
                LayerDesc::Conv {
                    c_in: 1,
                    c_out: 8,
                    h: 8,
                    w: 8,
                    f: 3,
                    quantized: false,
                    precision: None,
                },
                LayerDesc::Conv {
                    c_in: 8,
                    c_out: 16,
                    h: 8,
                    w: 8,
                    f: 3,
                    quantized: true,
                    precision: None,
                },
                LayerDesc::MaxPool { c: 16, h: 8, w: 8 },
                LayerDesc::Dense { c_in: 16, h: 4, w: 4, c_out: 16, precision: None },
                LayerDesc::GapFc { c: 16, classes: 4 },
            ],
            (1, 8, 8),
            4,
        )
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(LayerDesc::macs).sum()
    }

    /// Layer `i`'s input edges (empty slice when `preds` is shorter
    /// than `layers` — validate() then treats the node as a second
    /// graph input and rejects it).
    pub fn preds_of(&self, i: usize) -> &[usize] {
        self.preds.get(i).map(Vec::as_slice).unwrap_or(&[])
    }

    /// The deterministic topological order compilation and the golden
    /// network walk in: Kahn's algorithm with a lowest-index-first
    /// ready queue, so a linear chain keeps its declaration order.
    /// [`GraphError::Cycle`] when no order exists (a cycle, a
    /// self-loop, or an out-of-range predecessor index).
    pub fn topo_order(&self) -> Result<Vec<usize>, GraphError> {
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;
        let n = self.layers.len();
        let mut indeg = vec![0usize; n];
        let mut succ: Vec<Vec<usize>> = vec![Vec::new(); n];
        for i in 0..n {
            for &p in self.preds_of(i) {
                if p >= n || p == i {
                    return Err(GraphError::Cycle { layer: i });
                }
                indeg[i] += 1;
                succ[p].push(i);
            }
        }
        let mut ready: BinaryHeap<Reverse<usize>> =
            (0..n).filter(|&i| indeg[i] == 0).map(Reverse).collect();
        let mut order = Vec::with_capacity(n);
        let mut placed = vec![false; n];
        while let Some(Reverse(i)) = ready.pop() {
            order.push(i);
            placed[i] = true;
            for &s in &succ[i] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    ready.push(Reverse(s));
                }
            }
        }
        if order.len() != n {
            let layer = (0..n).find(|&i| !placed[i]).unwrap();
            return Err(GraphError::Cycle { layer });
        }
        Ok(order)
    }

    /// Shape-chaining validation over the DAG: a topological order
    /// must exist ([`GraphError::Cycle`]), every node must have the
    /// fan-in its kind requires with exactly one graph-input node
    /// ([`GraphError::FanInMismatch`]), and every node's declared
    /// input dims must equal its producer's output dims (both
    /// producers for `Add`).  Pools need even spatial dims, 'same'
    /// convs odd kernels, and the GAP+FC head must be last and agree
    /// on the class count.
    ///
    /// Also enforces the graph-intrinsic precision rules: an explicit
    /// per-layer override must target a quantized layer and stay
    /// inside the sub-byte range 1..=4.  The processor-dependent rules
    /// (variant availability, boundary widths, join domains) live in
    /// [`Self::validate_for`].
    pub fn validate(&self) -> Result<(), GraphError> {
        if self.layers.is_empty() {
            return Err(GraphError::Empty);
        }
        let order = self.topo_order()?;
        let n = self.layers.len();
        // fan-in arity: exactly one input node, everyone else exactly
        // what their kind requires
        let mut input_node: Option<usize> = None;
        for (li, layer) in self.layers.iter().enumerate() {
            let got = self.preds_of(li).len();
            if got == 0 {
                if input_node.is_some() || layer.fan_in() != 1 {
                    return Err(GraphError::FanInMismatch {
                        layer: li,
                        expected: layer.fan_in(),
                        got: 0,
                    });
                }
                input_node = Some(li);
            } else if got != layer.fan_in() {
                return Err(GraphError::FanInMismatch {
                    layer: li,
                    expected: layer.fan_in(),
                    got,
                });
            }
        }
        // shape chaining in topo order
        let mut outs = vec![(0u32, 0u32, 0u32); n];
        for &li in &order {
            let layer = &self.layers[li];
            let ps = self.preds_of(li);
            let cur = if ps.is_empty() { self.input } else { outs[ps[0]] };
            let (ic, ih, iw) = layer.in_dims();
            let expected_spatial = !matches!(layer, LayerDesc::GapFc { .. });
            let got = if expected_spatial { (ic, ih, iw) } else { (ic, cur.1, cur.2) };
            if got != cur {
                return Err(GraphError::ShapeMismatch { layer: li, expected: cur, got });
            }
            if matches!(layer, LayerDesc::Add { .. }) {
                let other = outs[ps[1]];
                if other != got {
                    return Err(GraphError::ShapeMismatch { layer: li, expected: other, got });
                }
            }
            match *layer {
                LayerDesc::Conv { f, .. } | LayerDesc::DepthwiseConv { f, .. } if f % 2 == 0 => {
                    return Err(GraphError::EvenKernel { layer: li, f });
                }
                LayerDesc::Conv { quantized, precision: Some((w, a)), .. } => {
                    if !quantized {
                        return Err(GraphError::OverrideOnStem { layer: li });
                    }
                    check_subbyte_range(li, w, a)?;
                }
                LayerDesc::DepthwiseConv { precision: Some((w, a)), .. }
                | LayerDesc::Dense { precision: Some((w, a)), .. } => {
                    check_subbyte_range(li, w, a)?;
                }
                LayerDesc::MaxPool { h, w, .. } if h % 2 != 0 || w % 2 != 0 => {
                    return Err(GraphError::OddPool { layer: li, h, w });
                }
                LayerDesc::GapFc { classes, .. } => {
                    if li != n - 1 || order.last() != Some(&li) {
                        return Err(GraphError::HeadNotLast { layer: li });
                    }
                    if classes != self.classes {
                        return Err(GraphError::ClassMismatch {
                            head: classes,
                            graph: self.classes,
                        });
                    }
                }
                _ => {}
            }
            outs[li] = layer.out_dims();
        }
        Ok(())
    }

    /// Per conv-like layer ([`LayerDesc::Conv`],
    /// [`LayerDesc::DepthwiseConv`], [`LayerDesc::Dense`]) resolved
    /// `(w_bits, a_bits, quantized)` under `default`, in graph order,
    /// with range checking of the *resolved* values (an out-of-range
    /// network default is rejected exactly like an out-of-range
    /// override).  The int16 stem resolves to 8-bit weights and the
    /// network's activation width.  Under [`QnnPrecision::Fp32`] the
    /// overrides are ignored (the fp32 baseline has no level domain —
    /// see `qnn::schedule`'s documented fallback) and every layer
    /// resolves to (8, 8).
    pub fn conv_precisions(&self, default: QnnPrecision) -> Result<Vec<ConvPrec>, GraphError> {
        let mut out = Vec::new();
        for (li, layer) in self.layers.iter().enumerate() {
            let (quantized, precision) = match *layer {
                LayerDesc::Conv { quantized, precision, .. } => (quantized, precision),
                LayerDesc::DepthwiseConv { precision, .. } => (true, precision),
                LayerDesc::Dense { precision, .. } => (true, precision),
                _ => continue,
            };
            let (w, a) = match default {
                QnnPrecision::Fp32 => (8, 8),
                QnnPrecision::SubByte { w_bits, a_bits } => {
                    if !quantized {
                        if precision.is_some() {
                            return Err(GraphError::OverrideOnStem { layer: li });
                        }
                        (8, a_bits)
                    } else {
                        let (w, a) = precision.unwrap_or((w_bits, a_bits));
                        check_subbyte_range(li, w, a)?;
                        (w, a)
                    }
                }
            };
            out.push(ConvPrec { layer: li, w_bits: w, a_bits: a, quantized });
        }
        Ok(out)
    }

    /// The full mixed-precision legality check for a concrete
    /// processor (on top of [`Self::validate`]):
    ///
    /// 1. every resolved quantized precision must map to a legal
    ///    canonical kernel variant on `cfg` — `vmacsr` where the
    ///    processor has it, the native ULPPACK scheme otherwise;
    ///    precisions only `vmacsr` can run (e.g. W4A4, or any `Dense`
    ///    layer) are rejected on Ara-like configs with
    ///    [`GraphError::VariantUnsupported`];
    /// 2. every requant boundary must narrow to the consumer's element
    ///    width in at most one `vnsrl` step
    ///    ([`GraphError::BoundaryWidth`]), with producer/consumer
    ///    widths derived from the same region-calculus plans the
    ///    compiler and the golden network resolve through;
    /// 3. the two branches of every `Add` join must carry the same
    ///    resolved activation bit-width
    ///    ([`GraphError::JoinPrecision`]).
    pub fn validate_for(&self, cfg: &ProcessorConfig, default: QnnPrecision) -> Result<(), GraphError> {
        self.validate()?;
        if matches!(default, QnnPrecision::Fp32) {
            // the fp32 baseline never chains boundaries (legacy
            // per-layer estimate); nothing processor-specific to check
            return Ok(());
        }
        let precs = self.conv_precisions(default)?;
        let prec_of = |li: usize| precs.iter().find(|p| p.layer == li);
        let default_a = match default {
            QnnPrecision::SubByte { a_bits, .. } => a_bits,
            QnnPrecision::Fp32 => unreachable!(),
        };
        let order = self.topo_order()?;
        // per-node (output element bits, activation level domain) —
        // a conv's output width under the canonical variant, and the
        // a_bits its activations were quantized at (joins must agree)
        let n = self.layers.len();
        let mut flows: Vec<Option<(u32, u32)>> = vec![None; n];
        for &li in &order {
            let ps = self.preds_of(li);
            let inflow = ps.first().and_then(|&p| flows[p]);
            let boundary = |in_bits: u32| -> Result<(), GraphError> {
                if let Some((from, _)) = inflow {
                    // equal widths or one narrowing step (vnsrl halves)
                    if !(in_bits == from || 2 * in_bits == from) {
                        return Err(GraphError::BoundaryWidth {
                            layer: li,
                            from_bits: from,
                            to_bits: in_bits,
                        });
                    }
                }
                Ok(())
            };
            match self.layers[li] {
                LayerDesc::Conv { c_in, f, quantized, .. } => {
                    let p = prec_of(li).expect("conv_precisions covers every conv");
                    let issues = (padded_c(c_in) as u64 / 2) * (f * f) as u64;
                    let (in_bits, out_bits) = if !quantized {
                        (16, 16) // int16 stem: E16 levels in, wrapping u16 sums out
                    } else {
                        canonical_widths(cfg, p.w_bits, p.a_bits, issues).ok_or(
                            GraphError::VariantUnsupported {
                                layer: li,
                                w_bits: p.w_bits,
                                a_bits: p.a_bits,
                                processor: cfg.name.clone(),
                            },
                        )?
                    };
                    boundary(in_bits)?;
                    flows[li] = Some((out_bits, p.a_bits));
                }
                LayerDesc::DepthwiseConv { f, .. } => {
                    // per-channel sub-conv: one padded channel pair
                    let p = prec_of(li).expect("conv_precisions covers every dwconv");
                    let issues = (f * f) as u64;
                    let (in_bits, out_bits) = canonical_widths(cfg, p.w_bits, p.a_bits, issues)
                        .ok_or(GraphError::VariantUnsupported {
                            layer: li,
                            w_bits: p.w_bits,
                            a_bits: p.a_bits,
                            processor: cfg.name.clone(),
                        })?;
                    boundary(in_bits)?;
                    flows[li] = Some((out_bits, p.a_bits));
                }
                LayerDesc::Dense { c_in, h, w, .. } => {
                    // vmacsr-only (im2col GEMM); always a u32 output
                    let p = prec_of(li).expect("conv_precisions covers every dense");
                    let issues = (padded_c(c_in) as u64 / 2) * (h * w) as u64;
                    let unsupported = GraphError::VariantUnsupported {
                        layer: li,
                        w_bits: p.w_bits,
                        a_bits: p.a_bits,
                        processor: cfg.name.clone(),
                    };
                    if !cfg.vmacsr {
                        return Err(unsupported);
                    }
                    let plan = region::plan_vmacsr(p.w_bits, p.a_bits, issues, RegionMode::Paper)
                        .ok_or(unsupported)?;
                    boundary(container_sew(plan.container).bits())?;
                    flows[li] = Some((32, p.a_bits));
                }
                LayerDesc::Add { .. } => {
                    // branches feeding a join are always compiled
                    // producers in practice; a raw-input branch
                    // defaults to the network activation domain
                    let a = flows[ps[0]].unwrap_or((16, default_a));
                    let b = flows[ps[1]].unwrap_or((16, default_a));
                    if a.1 != b.1 {
                        return Err(GraphError::JoinPrecision { layer: li, a: a.1, b: b.1 });
                    }
                    // the join stage requants each branch (one vnsrl
                    // step max — producers are 16- or 32-bit) and adds
                    // at E16
                    flows[li] = Some((16, a.1));
                }
                LayerDesc::MaxPool { .. } => {
                    flows[li] = inflow;
                }
                // the head requants to E16 levels: 16- and 32-bit
                // producers both narrow legally
                LayerDesc::GapFc { .. } => {}
            }
        }
        Ok(())
    }
}

/// The one definition of the legal sub-byte range: a quantized layer's
/// resolved (W, A) — explicit override or network default — must land
/// in 1..=4.  Shared by [`QnnGraph::validate`] (override checking) and
/// [`QnnGraph::conv_precisions`] (resolved checking) so the two entry
/// points cannot drift.
fn check_subbyte_range(layer: usize, w_bits: u32, a_bits: u32) -> Result<(), GraphError> {
    if !(1..=4).contains(&w_bits) || !(1..=4).contains(&a_bits) {
        return Err(GraphError::BadPrecision { layer, w_bits, a_bits });
    }
    Ok(())
}

/// One conv-like layer's resolved precision (see
/// [`QnnGraph::conv_precisions`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvPrec {
    /// Graph layer index.
    pub layer: usize,
    pub w_bits: u32,
    pub a_bits: u32,
    pub quantized: bool,
}

/// (input element bits, output element bits) of the canonical variant
/// for a quantized conv at (W, A) on `cfg`: the vmacsr plan where the
/// processor implements `vmacsr`, the native ULPPACK plan otherwise;
/// `None` when neither scheme admits the pair.  Kept in lock-step with
/// the conv engine's element choices by `conv_engine::vmacsr_out_elem`
/// / `packed_out_elem` (the compiler asserts agreement).
pub(crate) fn canonical_widths(
    cfg: &ProcessorConfig,
    w_bits: u32,
    a_bits: u32,
    issues: u64,
) -> Option<(u32, u32)> {
    let (container, out_elem) = if cfg.vmacsr {
        let plan = region::plan_vmacsr(w_bits, a_bits, issues, RegionMode::Paper)?;
        (
            plan.container,
            crate::kernels::conv_engine::vmacsr_out_elem(plan.container, plan.spill_every, issues),
        )
    } else {
        let plan = region::plan_native(w_bits, a_bits)?;
        // the native scheme always keeps a wide accumulator
        (plan.container, crate::kernels::conv_engine::packed_out_elem(plan.container, true))
    };
    let in_bits = container_sew(container).bits();
    let out_bits = match out_elem {
        crate::kernels::workload::OutElem::U16 => 16,
        _ => 32,
    };
    Some((in_bits, out_bits))
}

/// The element width packed levels load at for a container.
pub(crate) fn container_sew(c: Container) -> Sew {
    match c {
        Container::Lp => Sew::E16,
        Container::Ulp => Sew::E8,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparq_cnn_shapes() {
        let g = QnnGraph::sparq_cnn();
        assert_eq!(g.layers.len(), 6);
        assert_eq!(g.input, (1, 16, 16));
        // conv2: 16*32*16*16*9
        assert_eq!(g.layers[1].macs(), 16 * 32 * 16 * 16 * 9);
        assert!(g.total_macs() > 1_000_000);
        // the chain edges are explicit now
        assert_eq!(g.preds, vec![vec![], vec![0], vec![1], vec![2], vec![3], vec![4]]);
    }

    #[test]
    fn names_tag_quantized_layers() {
        let g = QnnGraph::sparq_cnn();
        assert!(g.layers[0].name().contains("[stem]"));
        assert!(g.layers[1].name().contains("[sub-byte]"));
        assert!(QnnGraph::sparq_resnetlike().layers[3].name().contains("[join]"));
        assert!(QnnGraph::sparq_mobilenetlike().layers[1].name().contains("dwconv"));
        assert!(QnnGraph::sparq_denselike().layers[3].name().contains("dense 256->16"));
    }

    #[test]
    fn sparq_cnn_validates() {
        QnnGraph::sparq_cnn().validate().unwrap();
    }

    #[test]
    fn dag_builders_validate_on_sparq_at_every_uniform_precision() {
        for g in [
            QnnGraph::sparq_resnetlike(),
            QnnGraph::sparq_mobilenetlike(),
            QnnGraph::sparq_denselike(),
        ] {
            g.validate().unwrap();
            for bits in 1..=4 {
                g.validate_for(&ProcessorConfig::sparq(), w(bits)).unwrap();
            }
        }
    }

    #[test]
    fn topo_order_keeps_chains_in_declaration_order() {
        let g = QnnGraph::sparq_cnn();
        assert_eq!(g.topo_order().unwrap(), vec![0, 1, 2, 3, 4, 5]);
        // the residual graph is already declared in a valid order,
        // and the lowest-index-first queue preserves it
        let r = QnnGraph::sparq_resnetlike();
        assert_eq!(r.topo_order().unwrap(), (0..r.layers.len()).collect::<Vec<_>>());
    }

    #[test]
    fn self_loop_and_cycle_rejected() {
        let mut g = QnnGraph::sparq_cnn();
        g.preds[2] = vec![2]; // self-loop
        assert_eq!(g.validate(), Err(GraphError::Cycle { layer: 2 }));
        let mut g = QnnGraph::sparq_cnn();
        g.preds[1] = vec![3]; // 1 <- 3 while 3 <- 2 <- 1: a real cycle
        assert_eq!(g.validate(), Err(GraphError::Cycle { layer: 1 }));
        // out-of-range predecessor: no order can resolve it
        let mut g = QnnGraph::sparq_cnn();
        g.preds[4] = vec![99];
        assert_eq!(g.validate(), Err(GraphError::Cycle { layer: 4 }));
    }

    #[test]
    fn fan_in_arity_enforced() {
        // an Add with one input edge
        let mut g = QnnGraph::sparq_resnetlike();
        g.preds[3] = vec![2];
        assert_eq!(
            g.validate(),
            Err(GraphError::FanInMismatch { layer: 3, expected: 2, got: 1 })
        );
        // a conv with two
        let mut g = QnnGraph::sparq_cnn();
        g.preds[3] = vec![2, 1];
        assert_eq!(
            g.validate(),
            Err(GraphError::FanInMismatch { layer: 3, expected: 1, got: 2 })
        );
        // two graph-input nodes
        let mut g = QnnGraph::sparq_cnn();
        g.preds[1] = vec![];
        assert!(matches!(g.validate(), Err(GraphError::FanInMismatch { got: 0, .. })));
    }

    #[test]
    fn residual_shape_mismatch_rejected_at_the_join() {
        let mut g = QnnGraph::sparq_resnetlike();
        // the body branch now widens to 32 channels: still a valid
        // conv chain, but the join's two producers no longer agree
        g.layers[2] = LayerDesc::Conv {
            c_in: 16,
            c_out: 32,
            h: 16,
            w: 16,
            f: 3,
            quantized: true,
            precision: None,
        };
        assert_eq!(
            g.validate(),
            Err(GraphError::ShapeMismatch {
                layer: 3,
                expected: (32, 16, 16),
                got: (16, 16, 16)
            })
        );
    }

    #[test]
    fn join_of_mismatched_precisions_rejected() {
        let mut g = QnnGraph::sparq_resnetlike();
        if let LayerDesc::Conv { precision, .. } = &mut g.layers[1] {
            *precision = Some((4, 4));
        }
        if let LayerDesc::Conv { precision, .. } = &mut g.layers[2] {
            *precision = Some((2, 2));
        }
        g.validate().unwrap(); // intrinsically fine...
        assert_eq!(
            // ...but W4-joins-W2 without a requant is not
            g.validate_for(&ProcessorConfig::sparq(), w(2)),
            Err(GraphError::JoinPrecision { layer: 3, a: 4, b: 2 })
        );
        // equal overrides on both branches are legal
        if let LayerDesc::Conv { precision, .. } = &mut g.layers[2] {
            *precision = Some((4, 4));
        }
        g.validate_for(&ProcessorConfig::sparq(), w(2)).unwrap();
    }

    #[test]
    fn dense_is_vmacsr_only() {
        let g = QnnGraph::sparq_denselike();
        assert!(matches!(
            g.validate_for(&ProcessorConfig::ara(), w(2)),
            Err(GraphError::VariantUnsupported { layer: 3, .. })
        ));
        g.validate_for(&ProcessorConfig::sparq(), w(2)).unwrap();
    }

    #[test]
    fn mismatched_channels_rejected() {
        let mut g = QnnGraph::sparq_cnn();
        // conv2 claims 8 input channels; conv1 produces 16
        g.layers[1] = LayerDesc::Conv {
            c_in: 8,
            c_out: 32,
            h: 16,
            w: 16,
            f: 3,
            quantized: true,
            precision: None,
        };
        assert!(matches!(g.validate(), Err(GraphError::ShapeMismatch { layer: 1, .. })));
    }

    #[test]
    fn mismatched_spatial_dims_rejected() {
        let mut g = QnnGraph::sparq_cnn();
        // conv3 claims the pre-pool 16x16 extent
        g.layers[3] = LayerDesc::Conv {
            c_in: 32,
            c_out: 32,
            h: 16,
            w: 16,
            f: 3,
            quantized: true,
            precision: None,
        };
        assert!(matches!(g.validate(), Err(GraphError::ShapeMismatch { layer: 3, .. })));
    }

    #[test]
    fn input_mismatch_rejected_at_layer_zero() {
        let mut g = QnnGraph::sparq_cnn();
        g.input = (3, 16, 16);
        assert!(matches!(g.validate(), Err(GraphError::ShapeMismatch { layer: 0, .. })));
    }

    #[test]
    fn odd_pool_and_even_kernel_rejected() {
        let g = QnnGraph::chain(vec![LayerDesc::MaxPool { c: 2, h: 5, w: 4 }], (2, 5, 4), 4);
        assert!(matches!(g.validate(), Err(GraphError::OddPool { layer: 0, .. })));
        let g = QnnGraph::chain(
            vec![LayerDesc::Conv {
                c_in: 2,
                c_out: 4,
                h: 8,
                w: 8,
                f: 2,
                quantized: true,
                precision: None,
            }],
            (2, 8, 8),
            4,
        );
        assert!(matches!(g.validate(), Err(GraphError::EvenKernel { layer: 0, f: 2 })));
        let g = QnnGraph::chain(
            vec![LayerDesc::DepthwiseConv { c: 2, h: 8, w: 8, f: 4, precision: None }],
            (2, 8, 8),
            4,
        );
        assert!(matches!(g.validate(), Err(GraphError::EvenKernel { layer: 0, f: 4 })));
    }

    #[test]
    fn head_position_and_classes_checked() {
        let mut g = QnnGraph::sparq_cnn();
        g.classes = 10;
        assert_eq!(g.validate(), Err(GraphError::ClassMismatch { head: 4, graph: 10 }));
        let g = QnnGraph::chain(
            vec![
                LayerDesc::GapFc { c: 2, classes: 4 },
                LayerDesc::MaxPool { c: 4, h: 1, w: 1 },
            ],
            (2, 4, 4),
            4,
        );
        assert!(matches!(g.validate(), Err(GraphError::HeadNotLast { layer: 0 })));
    }

    #[test]
    fn empty_graph_rejected_and_odd_cin_padding_is_explicit() {
        let g = QnnGraph::chain(vec![], (1, 1, 1), 0);
        assert_eq!(g.validate(), Err(GraphError::Empty));
        assert_eq!(padded_c(1), 2);
        assert_eq!(padded_c(16), 16);
    }

    fn w(bits: u32) -> QnnPrecision {
        QnnPrecision::SubByte { w_bits: bits, a_bits: bits }
    }

    #[test]
    fn override_out_of_range_rejected() {
        let g = QnnGraph::sparq_cnn_mixed((5, 2), (2, 2));
        assert_eq!(
            g.validate(),
            Err(GraphError::BadPrecision { layer: 1, w_bits: 5, a_bits: 2 })
        );
        let g = QnnGraph::sparq_cnn_mixed((2, 2), (2, 0));
        assert_eq!(
            g.validate(),
            Err(GraphError::BadPrecision { layer: 3, w_bits: 2, a_bits: 0 })
        );
        // dense/depthwise overrides are range-checked the same way
        let mut g = QnnGraph::sparq_denselike();
        if let LayerDesc::Dense { precision, .. } = &mut g.layers[3] {
            *precision = Some((7, 2));
        }
        assert_eq!(
            g.validate(),
            Err(GraphError::BadPrecision { layer: 3, w_bits: 7, a_bits: 2 })
        );
    }

    #[test]
    fn override_on_the_stem_rejected() {
        let mut g = QnnGraph::sparq_cnn();
        if let LayerDesc::Conv { precision, .. } = &mut g.layers[0] {
            *precision = Some((2, 2));
        }
        assert_eq!(g.validate(), Err(GraphError::OverrideOnStem { layer: 0 }));
    }

    #[test]
    fn resolved_default_out_of_range_rejected() {
        let g = QnnGraph::sparq_cnn();
        assert_eq!(
            g.conv_precisions(w(5)),
            Err(GraphError::BadPrecision { layer: 1, w_bits: 5, a_bits: 5 })
        );
        // overrides take precedence over the default in resolution
        let m = QnnGraph::sparq_cnn_mixed((4, 4), (2, 2));
        let ps = m.conv_precisions(w(3)).unwrap();
        assert_eq!(ps.len(), 3);
        assert_eq!((ps[0].w_bits, ps[0].a_bits, ps[0].quantized), (8, 3, false));
        assert_eq!((ps[1].w_bits, ps[1].a_bits), (4, 4));
        assert_eq!((ps[2].w_bits, ps[2].a_bits), (2, 2));
        // fp32 ignores the overrides entirely (documented fallback)
        let fp = m.conv_precisions(QnnPrecision::Fp32).unwrap();
        assert!(fp.iter().all(|p| (p.w_bits, p.a_bits) == (8, 8)));
        // depthwise and dense layers are covered in graph order
        let ps = QnnGraph::sparq_mobilenetlike().conv_precisions(w(2)).unwrap();
        assert_eq!(ps.iter().map(|p| p.layer).collect::<Vec<_>>(), vec![0, 1, 2, 4, 5]);
        assert!(ps.iter().skip(1).all(|p| p.quantized));
    }

    #[test]
    fn mixed_sparq_cnn_passes_the_full_check() {
        let g = QnnGraph::sparq_cnn_mixed((4, 4), (2, 2));
        g.validate_for(&ProcessorConfig::sparq(), w(2)).unwrap();
        let g = QnnGraph::sparq_cnn_mixed((2, 2), (4, 4));
        g.validate_for(&ProcessorConfig::sparq(), w(2)).unwrap();
    }

    #[test]
    fn vmacsr_only_precision_rejected_on_ara() {
        // W4A4 is outside the native ULPPACK region: on a config with
        // no vmacsr there is no variant left
        let g = QnnGraph::sparq_cnn();
        assert_eq!(
            g.validate_for(&ProcessorConfig::ara(), w(4)),
            Err(GraphError::VariantUnsupported {
                layer: 1,
                w_bits: 4,
                a_bits: 4,
                processor: "ara".into()
            })
        );
        // W2A2 still runs on Ara via the native scheme
        g.validate_for(&ProcessorConfig::ara(), w(2)).unwrap();
        // and on Sparq vmacsr admits W4A4
        g.validate_for(&ProcessorConfig::sparq(), w(4)).unwrap();
        // a depthwise layer is rejected identically when only vmacsr
        // admits its precision
        let g = QnnGraph::sparq_mobilenetlike();
        assert!(matches!(
            g.validate_for(&ProcessorConfig::ara(), w(4)),
            Err(GraphError::VariantUnsupported { layer: 1, .. })
        ));
    }

    #[test]
    fn boundary_narrowing_two_steps_rejected() {
        // a W4A4 producer with enough issues to need the wide u32
        // accumulator (spill cadence 156 < 18*9 = 162 issues) feeding a
        // W2A2 consumer whose ULP container loads 8-bit elements:
        // 32 -> 8 is two vnsrl steps, which no boundary stream can emit
        let g = QnnGraph::chain(
            vec![
                LayerDesc::Conv {
                    c_in: 36,
                    c_out: 8,
                    h: 8,
                    w: 8,
                    f: 3,
                    quantized: true,
                    precision: Some((4, 4)),
                },
                LayerDesc::Conv {
                    c_in: 8,
                    c_out: 4,
                    h: 8,
                    w: 8,
                    f: 3,
                    quantized: true,
                    precision: Some((2, 2)),
                },
                LayerDesc::GapFc { c: 4, classes: 4 },
            ],
            (36, 8, 8),
            4,
        );
        assert_eq!(
            g.validate_for(&ProcessorConfig::sparq(), w(2)),
            Err(GraphError::BoundaryWidth { layer: 1, from_bits: 32, to_bits: 8 })
        );
        // the same chain with a 16-bit-container consumer is legal
        let mut ok = g.clone();
        if let LayerDesc::Conv { precision, .. } = &mut ok.layers[1] {
            *precision = Some((4, 4)); // LP: E16 in, one narrowing step
        }
        ok.validate_for(&ProcessorConfig::sparq(), w(2)).unwrap();
    }
}
