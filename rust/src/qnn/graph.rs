//! Static description of the SparqCNN architecture (kept in lock-step
//! with `python/compile/model.py` — the artifact manifest carries the
//! same shapes and the integration tests cross-check them), plus the
//! mixed-precision legality rules the dataflow compiler enforces.
//!
//! ## Per-layer precision
//!
//! A quantized conv may carry an optional `(w_bits, a_bits)` override
//! (`precision`); layers without one inherit the network default
//! ([`crate::qnn::schedule::QnnPrecision`]).  Legality is checked at
//! two levels:
//!
//! * [`QnnGraph::validate`] — graph-intrinsic rules (shape chaining,
//!   override ranges, overrides only on quantized layers), no
//!   processor needed.
//! * [`QnnGraph::validate_for`] — the full mixed-precision rules for a
//!   concrete processor: every resolved precision must map to a legal
//!   kernel variant (vmacsr-only precisions are rejected on Ara-like
//!   configs with no `vmacsr`), and every requant boundary must narrow
//!   to the next layer's activation element width in at most one
//!   `vnsrl` step (a wide u32 producer cannot feed an 8-bit-container
//!   consumer directly).  Boundary widths are derived from the
//!   *canonical* variant assignment (the same region-calculus plan the
//!   compiler and the golden network resolve through); the autotuner
//!   may only substitute variants that keep the chain legal.

use crate::arch::ProcessorConfig;
use crate::isa::Sew;
use crate::qnn::schedule::QnnPrecision;
use crate::ulppack::region::{self, Container, RegionMode};

/// One layer of the network.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerDesc {
    /// 'same' conv: C_in x H x W -> C_out x H x W with an FxF kernel.
    /// `precision` is the optional per-layer `(w_bits, a_bits)`
    /// override; `None` inherits the network default.
    Conv {
        c_in: u32,
        c_out: u32,
        h: u32,
        w: u32,
        f: u32,
        quantized: bool,
        precision: Option<(u32, u32)>,
    },
    /// 2x2 max pool (halves H and W).
    MaxPool { c: u32, h: u32, w: u32 },
    /// Global average pool + linear head.
    GapFc { c: u32, classes: u32 },
}

impl LayerDesc {
    /// Multiply-accumulates of this layer (per image).
    pub fn macs(&self) -> u64 {
        match *self {
            LayerDesc::Conv { c_in, c_out, h, w, f, .. } => {
                c_in as u64 * c_out as u64 * h as u64 * w as u64 * (f * f) as u64
            }
            LayerDesc::MaxPool { .. } => 0,
            LayerDesc::GapFc { c, classes } => (c * classes) as u64,
        }
    }

    pub fn name(&self) -> String {
        match *self {
            LayerDesc::Conv { c_in, c_out, f, quantized, .. } => format!(
                "conv {c_in}->{c_out} {f}x{f}{}",
                if quantized { " [sub-byte]" } else { " [stem]" }
            ),
            LayerDesc::MaxPool { .. } => "maxpool2".into(),
            LayerDesc::GapFc { .. } => "gap+fc".into(),
        }
    }

    /// (c, h, w) this layer consumes.
    pub fn in_dims(&self) -> (u32, u32, u32) {
        match *self {
            LayerDesc::Conv { c_in, h, w, .. } => (c_in, h, w),
            LayerDesc::MaxPool { c, h, w } => (c, h, w),
            // GAP+FC consumes whatever spatial extent it is handed;
            // validate() checks the channel count only
            LayerDesc::GapFc { c, .. } => (c, 0, 0),
        }
    }

    /// (c, h, w) this layer produces ('same' convs preserve h x w;
    /// GAP+FC produces the logits vector).
    pub fn out_dims(&self) -> (u32, u32, u32) {
        match *self {
            LayerDesc::Conv { c_out, h, w, .. } => (c_out, h, w),
            LayerDesc::MaxPool { c, h, w } => (c, h / 2, w / 2),
            LayerDesc::GapFc { classes, .. } => (classes, 1, 1),
        }
    }
}

/// Why a [`QnnGraph`] failed shape-chaining or mixed-precision
/// validation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GraphError {
    Empty,
    /// Layer `layer`'s declared input dims do not equal the previous
    /// layer's output dims.
    ShapeMismatch { layer: usize, expected: (u32, u32, u32), got: (u32, u32, u32) },
    /// 2x2 pooling needs even spatial dims.
    OddPool { layer: usize, h: u32, w: u32 },
    /// 'same' convs need an odd kernel (symmetric border).
    EvenKernel { layer: usize, f: u32 },
    /// GAP+FC must be the final layer.
    HeadNotLast { layer: usize },
    /// The head's class count disagrees with the graph's.
    ClassMismatch { head: u32, graph: u32 },
    /// A resolved quantized-layer precision is outside the sub-byte
    /// range the packed kernels support (W and A in 1..=4).
    BadPrecision { layer: usize, w_bits: u32, a_bits: u32 },
    /// A per-layer precision override on a non-quantized (int16 stem)
    /// conv — the stem always runs 8-bit weights.
    OverrideOnStem { layer: usize },
    /// No kernel variant on this processor can run the layer's
    /// resolved precision (e.g. W4A4 on an Ara-like config: vmacsr is
    /// absent and the native ULPPACK scheme cannot admit the pair).
    VariantUnsupported { layer: usize, w_bits: u32, a_bits: u32, processor: String },
    /// A requant boundary would have to narrow by more than one
    /// element-width step (the producer's wide output element vs the
    /// consumer's container width under the canonical variant
    /// assignment) — `vnsrl` narrows one step per boundary.
    BoundaryWidth { layer: usize, from_bits: u32, to_bits: u32 },
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            GraphError::Empty => write!(f, "graph has no layers"),
            GraphError::ShapeMismatch { layer, expected, got } => write!(
                f,
                "layer {layer}: input dims {got:?} != previous layer's output {expected:?}"
            ),
            GraphError::OddPool { layer, h, w } => {
                write!(f, "layer {layer}: 2x2 maxpool over odd dims {h}x{w}")
            }
            GraphError::EvenKernel { layer, f: k } => {
                write!(f, "layer {layer}: 'same' conv needs an odd kernel, got {k}x{k}")
            }
            GraphError::HeadNotLast { layer } => {
                write!(f, "layer {layer}: gap+fc must be the final layer")
            }
            GraphError::ClassMismatch { head, graph } => {
                write!(f, "head produces {head} classes but the graph declares {graph}")
            }
            GraphError::BadPrecision { layer, w_bits, a_bits } => write!(
                f,
                "layer {layer}: resolved precision W{w_bits}A{a_bits} outside the sub-byte range 1..=4"
            ),
            GraphError::OverrideOnStem { layer } => write!(
                f,
                "layer {layer}: precision override on a non-quantized stem conv (the stem runs int16)"
            ),
            GraphError::VariantUnsupported { layer, w_bits, a_bits, ref processor } => write!(
                f,
                "layer {layer}: no kernel variant runs W{w_bits}A{a_bits} on '{processor}' \
                 (vmacsr absent and the precision is outside the native ULPPACK region)"
            ),
            GraphError::BoundaryWidth { layer, from_bits, to_bits } => write!(
                f,
                "layer {layer}: requant boundary narrows {from_bits}-bit producer elements to \
                 {to_bits}-bit consumer elements (more than one vnsrl step)"
            ),
        }
    }
}

impl std::error::Error for GraphError {}

/// In-channel count the packed kernels actually run with: odd counts
/// get one explicit always-zero channel (the stem's 1 -> 2).
pub fn padded_c(c: u32) -> u32 {
    if c % 2 == 1 {
        c + 1
    } else {
        c
    }
}

/// The whole network.
#[derive(Debug, Clone)]
pub struct QnnGraph {
    pub layers: Vec<LayerDesc>,
    pub input: (u32, u32, u32),
    pub classes: u32,
}

impl QnnGraph {
    /// The SparqCNN from `python/compile/model.py`: 16x16 single-channel
    /// inputs, 4 classes; conv2/conv3 carry the sub-byte precision.
    pub fn sparq_cnn() -> QnnGraph {
        QnnGraph {
            layers: vec![
                LayerDesc::Conv {
                    c_in: 1,
                    c_out: 16,
                    h: 16,
                    w: 16,
                    f: 3,
                    quantized: false,
                    precision: None,
                },
                LayerDesc::Conv {
                    c_in: 16,
                    c_out: 32,
                    h: 16,
                    w: 16,
                    f: 3,
                    quantized: true,
                    precision: None,
                },
                LayerDesc::MaxPool { c: 32, h: 16, w: 16 },
                LayerDesc::Conv {
                    c_in: 32,
                    c_out: 32,
                    h: 8,
                    w: 8,
                    f: 3,
                    quantized: true,
                    precision: None,
                },
                LayerDesc::MaxPool { c: 32, h: 8, w: 8 },
                LayerDesc::GapFc { c: 32, classes: 4 },
            ],
            input: (1, 16, 16),
            classes: 4,
        }
    }

    /// The SparqCNN with per-layer precision overrides on the two
    /// quantized convs: `stem_adj` on the stem-adjacent conv (layer 1)
    /// and `deep` on the deeper conv (layer 3).  The paper's precision
    /// ladder in mixed form — e.g. a W4A4 stem-adjacent conv keeping
    /// early-layer fidelity over a W2A2 deep conv taking the 3.2x
    /// throughput.
    pub fn sparq_cnn_mixed(stem_adj: (u32, u32), deep: (u32, u32)) -> QnnGraph {
        let mut g = QnnGraph::sparq_cnn();
        let set = |l: &mut LayerDesc, p: (u32, u32)| {
            if let LayerDesc::Conv { precision, .. } = l {
                *precision = Some(p);
            }
        };
        set(&mut g.layers[1], stem_adj);
        set(&mut g.layers[3], deep);
        g
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(LayerDesc::macs).sum()
    }

    /// Shape-chaining validation: every layer's declared input dims
    /// must equal the previous layer's output dims (the graph input for
    /// layer 0), pools need even spatial dims, 'same' convs odd
    /// kernels, and the GAP+FC head must be last and agree on the
    /// class count.  Before this check existed, mismatched graphs
    /// scheduled silently against per-layer random tensors; the
    /// dataflow compiler refuses them instead.
    ///
    /// Also enforces the graph-intrinsic precision rules: an explicit
    /// per-layer override must target a quantized conv and stay inside
    /// the sub-byte range 1..=4.  The processor-dependent rules
    /// (variant availability, boundary widths) live in
    /// [`Self::validate_for`].
    pub fn validate(&self) -> Result<(), GraphError> {
        if self.layers.is_empty() {
            return Err(GraphError::Empty);
        }
        let mut cur = self.input;
        for (li, layer) in self.layers.iter().enumerate() {
            let (ic, ih, iw) = layer.in_dims();
            let expected_spatial = !matches!(layer, LayerDesc::GapFc { .. });
            let got = if expected_spatial { (ic, ih, iw) } else { (ic, cur.1, cur.2) };
            if got != cur {
                return Err(GraphError::ShapeMismatch { layer: li, expected: cur, got });
            }
            match *layer {
                LayerDesc::Conv { f, .. } if f % 2 == 0 => {
                    return Err(GraphError::EvenKernel { layer: li, f });
                }
                LayerDesc::Conv { quantized, precision: Some((w, a)), .. } => {
                    if !quantized {
                        return Err(GraphError::OverrideOnStem { layer: li });
                    }
                    check_subbyte_range(li, w, a)?;
                }
                LayerDesc::MaxPool { h, w, .. } if h % 2 != 0 || w % 2 != 0 => {
                    return Err(GraphError::OddPool { layer: li, h, w });
                }
                LayerDesc::GapFc { classes, .. } => {
                    if li != self.layers.len() - 1 {
                        return Err(GraphError::HeadNotLast { layer: li });
                    }
                    if classes != self.classes {
                        return Err(GraphError::ClassMismatch {
                            head: classes,
                            graph: self.classes,
                        });
                    }
                }
                _ => {}
            }
            cur = layer.out_dims();
        }
        Ok(())
    }

    /// Per-conv resolved `(w_bits, a_bits, quantized)` under `default`,
    /// in graph order, with range checking of the *resolved* values
    /// (an out-of-range network default is rejected exactly like an
    /// out-of-range override).  The int16 stem resolves to 8-bit
    /// weights and the network's activation width.  Under
    /// [`QnnPrecision::Fp32`] the overrides are ignored (the fp32
    /// baseline has no level domain — see `qnn::schedule`'s documented
    /// fallback) and every conv resolves to (8, 8).
    pub fn conv_precisions(&self, default: QnnPrecision) -> Result<Vec<ConvPrec>, GraphError> {
        let mut out = Vec::new();
        for (li, layer) in self.layers.iter().enumerate() {
            let LayerDesc::Conv { quantized, precision, .. } = *layer else { continue };
            let (w, a) = match default {
                QnnPrecision::Fp32 => (8, 8),
                QnnPrecision::SubByte { w_bits, a_bits } => {
                    if !quantized {
                        if precision.is_some() {
                            return Err(GraphError::OverrideOnStem { layer: li });
                        }
                        (8, a_bits)
                    } else {
                        let (w, a) = precision.unwrap_or((w_bits, a_bits));
                        check_subbyte_range(li, w, a)?;
                        (w, a)
                    }
                }
            };
            out.push(ConvPrec { layer: li, w_bits: w, a_bits: a, quantized });
        }
        Ok(out)
    }

    /// The full mixed-precision legality check for a concrete
    /// processor (on top of [`Self::validate`]):
    ///
    /// 1. every resolved quantized precision must map to a legal
    ///    canonical kernel variant on `cfg` — `vmacsr` where the
    ///    processor has it, the native ULPPACK scheme otherwise;
    ///    precisions only `vmacsr` can run (e.g. W4A4) are rejected on
    ///    Ara-like configs with [`GraphError::VariantUnsupported`];
    /// 2. every requant boundary must narrow to the consumer's element
    ///    width in at most one `vnsrl` step
    ///    ([`GraphError::BoundaryWidth`]), with producer/consumer
    ///    widths derived from the same region-calculus plans the
    ///    compiler and the golden network resolve through.
    pub fn validate_for(&self, cfg: &ProcessorConfig, default: QnnPrecision) -> Result<(), GraphError> {
        self.validate()?;
        if matches!(default, QnnPrecision::Fp32) {
            // the fp32 baseline never chains boundaries (legacy
            // per-layer estimate); nothing processor-specific to check
            return Ok(());
        }
        let precs = self.conv_precisions(default)?;
        let mut precs = precs.iter();
        // element width flowing between layers: a conv sets its output
        // width, pools preserve it, the head always narrows legally
        let mut flow: Option<u32> = None;
        for (li, layer) in self.layers.iter().enumerate() {
            let LayerDesc::Conv { c_in, f, quantized, .. } = *layer else { continue };
            let p = precs.next().expect("conv_precisions covers every conv");
            debug_assert_eq!(p.layer, li);
            let issues = (padded_c(c_in) as u64 / 2) * (f * f) as u64;
            let (in_bits, out_bits) = if !quantized {
                (16, 16) // int16 stem: E16 levels in, wrapping u16 sums out
            } else {
                canonical_widths(cfg, p.w_bits, p.a_bits, issues).ok_or(
                    GraphError::VariantUnsupported {
                        layer: li,
                        w_bits: p.w_bits,
                        a_bits: p.a_bits,
                        processor: cfg.name.clone(),
                    },
                )?
            };
            if let Some(from) = flow {
                // equal widths or one narrowing step (vnsrl halves)
                if !(in_bits == from || 2 * in_bits == from) {
                    return Err(GraphError::BoundaryWidth { layer: li, from_bits: from, to_bits: in_bits });
                }
            }
            flow = Some(out_bits);
        }
        Ok(())
    }
}

/// The one definition of the legal sub-byte range: a quantized conv's
/// resolved (W, A) — explicit override or network default — must land
/// in 1..=4.  Shared by [`QnnGraph::validate`] (override checking) and
/// [`QnnGraph::conv_precisions`] (resolved checking) so the two entry
/// points cannot drift.
fn check_subbyte_range(layer: usize, w_bits: u32, a_bits: u32) -> Result<(), GraphError> {
    if !(1..=4).contains(&w_bits) || !(1..=4).contains(&a_bits) {
        return Err(GraphError::BadPrecision { layer, w_bits, a_bits });
    }
    Ok(())
}

/// One conv layer's resolved precision (see
/// [`QnnGraph::conv_precisions`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvPrec {
    /// Graph layer index.
    pub layer: usize,
    pub w_bits: u32,
    pub a_bits: u32,
    pub quantized: bool,
}

/// (input element bits, output element bits) of the canonical variant
/// for a quantized conv at (W, A) on `cfg`: the vmacsr plan where the
/// processor implements `vmacsr`, the native ULPPACK plan otherwise;
/// `None` when neither scheme admits the pair.  Kept in lock-step with
/// the conv engine's element choices by `conv_engine::vmacsr_out_elem`
/// / `packed_out_elem` (the compiler asserts agreement).
pub(crate) fn canonical_widths(
    cfg: &ProcessorConfig,
    w_bits: u32,
    a_bits: u32,
    issues: u64,
) -> Option<(u32, u32)> {
    let (container, out_elem) = if cfg.vmacsr {
        let plan = region::plan_vmacsr(w_bits, a_bits, issues, RegionMode::Paper)?;
        (
            plan.container,
            crate::kernels::conv_engine::vmacsr_out_elem(plan.container, plan.spill_every, issues),
        )
    } else {
        let plan = region::plan_native(w_bits, a_bits)?;
        // the native scheme always keeps a wide accumulator
        (plan.container, crate::kernels::conv_engine::packed_out_elem(plan.container, true))
    };
    let in_bits = container_sew(container).bits();
    let out_bits = match out_elem {
        crate::kernels::workload::OutElem::U16 => 16,
        _ => 32,
    };
    Some((in_bits, out_bits))
}

/// The element width packed levels load at for a container.
pub(crate) fn container_sew(c: Container) -> Sew {
    match c {
        Container::Lp => Sew::E16,
        Container::Ulp => Sew::E8,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparq_cnn_shapes() {
        let g = QnnGraph::sparq_cnn();
        assert_eq!(g.layers.len(), 6);
        assert_eq!(g.input, (1, 16, 16));
        // conv2: 16*32*16*16*9
        assert_eq!(g.layers[1].macs(), 16 * 32 * 16 * 16 * 9);
        assert!(g.total_macs() > 1_000_000);
    }

    #[test]
    fn names_tag_quantized_layers() {
        let g = QnnGraph::sparq_cnn();
        assert!(g.layers[0].name().contains("[stem]"));
        assert!(g.layers[1].name().contains("[sub-byte]"));
    }

    #[test]
    fn sparq_cnn_validates() {
        QnnGraph::sparq_cnn().validate().unwrap();
    }

    #[test]
    fn mismatched_channels_rejected() {
        let mut g = QnnGraph::sparq_cnn();
        // conv2 claims 8 input channels; conv1 produces 16
        g.layers[1] = LayerDesc::Conv {
            c_in: 8,
            c_out: 32,
            h: 16,
            w: 16,
            f: 3,
            quantized: true,
            precision: None,
        };
        assert!(matches!(g.validate(), Err(GraphError::ShapeMismatch { layer: 1, .. })));
    }

    #[test]
    fn mismatched_spatial_dims_rejected() {
        let mut g = QnnGraph::sparq_cnn();
        // conv3 claims the pre-pool 16x16 extent
        g.layers[3] = LayerDesc::Conv {
            c_in: 32,
            c_out: 32,
            h: 16,
            w: 16,
            f: 3,
            quantized: true,
            precision: None,
        };
        assert!(matches!(g.validate(), Err(GraphError::ShapeMismatch { layer: 3, .. })));
    }

    #[test]
    fn input_mismatch_rejected_at_layer_zero() {
        let mut g = QnnGraph::sparq_cnn();
        g.input = (3, 16, 16);
        assert!(matches!(g.validate(), Err(GraphError::ShapeMismatch { layer: 0, .. })));
    }

    #[test]
    fn odd_pool_and_even_kernel_rejected() {
        let g = QnnGraph {
            layers: vec![LayerDesc::MaxPool { c: 2, h: 5, w: 4 }],
            input: (2, 5, 4),
            classes: 4,
        };
        assert!(matches!(g.validate(), Err(GraphError::OddPool { layer: 0, .. })));
        let g = QnnGraph {
            layers: vec![LayerDesc::Conv {
                c_in: 2,
                c_out: 4,
                h: 8,
                w: 8,
                f: 2,
                quantized: true,
                precision: None,
            }],
            input: (2, 8, 8),
            classes: 4,
        };
        assert!(matches!(g.validate(), Err(GraphError::EvenKernel { layer: 0, f: 2 })));
    }

    #[test]
    fn head_position_and_classes_checked() {
        let mut g = QnnGraph::sparq_cnn();
        g.classes = 10;
        assert_eq!(g.validate(), Err(GraphError::ClassMismatch { head: 4, graph: 10 }));
        let g = QnnGraph {
            layers: vec![
                LayerDesc::GapFc { c: 2, classes: 4 },
                LayerDesc::MaxPool { c: 4, h: 1, w: 1 },
            ],
            input: (2, 4, 4),
            classes: 4,
        };
        assert!(matches!(g.validate(), Err(GraphError::HeadNotLast { layer: 0 })));
    }

    #[test]
    fn empty_graph_rejected_and_odd_cin_padding_is_explicit() {
        let g = QnnGraph { layers: vec![], input: (1, 1, 1), classes: 0 };
        assert_eq!(g.validate(), Err(GraphError::Empty));
        assert_eq!(padded_c(1), 2);
        assert_eq!(padded_c(16), 16);
    }

    fn w(bits: u32) -> QnnPrecision {
        QnnPrecision::SubByte { w_bits: bits, a_bits: bits }
    }

    #[test]
    fn override_out_of_range_rejected() {
        let g = QnnGraph::sparq_cnn_mixed((5, 2), (2, 2));
        assert_eq!(
            g.validate(),
            Err(GraphError::BadPrecision { layer: 1, w_bits: 5, a_bits: 2 })
        );
        let g = QnnGraph::sparq_cnn_mixed((2, 2), (2, 0));
        assert_eq!(
            g.validate(),
            Err(GraphError::BadPrecision { layer: 3, w_bits: 2, a_bits: 0 })
        );
    }

    #[test]
    fn override_on_the_stem_rejected() {
        let mut g = QnnGraph::sparq_cnn();
        if let LayerDesc::Conv { precision, .. } = &mut g.layers[0] {
            *precision = Some((2, 2));
        }
        assert_eq!(g.validate(), Err(GraphError::OverrideOnStem { layer: 0 }));
    }

    #[test]
    fn resolved_default_out_of_range_rejected() {
        let g = QnnGraph::sparq_cnn();
        assert_eq!(
            g.conv_precisions(w(5)),
            Err(GraphError::BadPrecision { layer: 1, w_bits: 5, a_bits: 5 })
        );
        // overrides take precedence over the default in resolution
        let m = QnnGraph::sparq_cnn_mixed((4, 4), (2, 2));
        let ps = m.conv_precisions(w(3)).unwrap();
        assert_eq!(ps.len(), 3);
        assert_eq!((ps[0].w_bits, ps[0].a_bits, ps[0].quantized), (8, 3, false));
        assert_eq!((ps[1].w_bits, ps[1].a_bits), (4, 4));
        assert_eq!((ps[2].w_bits, ps[2].a_bits), (2, 2));
        // fp32 ignores the overrides entirely (documented fallback)
        let fp = m.conv_precisions(QnnPrecision::Fp32).unwrap();
        assert!(fp.iter().all(|p| (p.w_bits, p.a_bits) == (8, 8)));
    }

    #[test]
    fn mixed_sparq_cnn_passes_the_full_check() {
        let g = QnnGraph::sparq_cnn_mixed((4, 4), (2, 2));
        g.validate_for(&ProcessorConfig::sparq(), w(2)).unwrap();
        let g = QnnGraph::sparq_cnn_mixed((2, 2), (4, 4));
        g.validate_for(&ProcessorConfig::sparq(), w(2)).unwrap();
    }

    #[test]
    fn vmacsr_only_precision_rejected_on_ara() {
        // W4A4 is outside the native ULPPACK region: on a config with
        // no vmacsr there is no variant left
        let g = QnnGraph::sparq_cnn();
        assert_eq!(
            g.validate_for(&ProcessorConfig::ara(), w(4)),
            Err(GraphError::VariantUnsupported {
                layer: 1,
                w_bits: 4,
                a_bits: 4,
                processor: "ara".into()
            })
        );
        // W2A2 still runs on Ara via the native scheme
        g.validate_for(&ProcessorConfig::ara(), w(2)).unwrap();
        // and on Sparq vmacsr admits W4A4
        g.validate_for(&ProcessorConfig::sparq(), w(4)).unwrap();
    }

    #[test]
    fn boundary_narrowing_two_steps_rejected() {
        // a W4A4 producer with enough issues to need the wide u32
        // accumulator (spill cadence 156 < 18*9 = 162 issues) feeding a
        // W2A2 consumer whose ULP container loads 8-bit elements:
        // 32 -> 8 is two vnsrl steps, which no boundary stream can emit
        let g = QnnGraph {
            layers: vec![
                LayerDesc::Conv {
                    c_in: 36,
                    c_out: 8,
                    h: 8,
                    w: 8,
                    f: 3,
                    quantized: true,
                    precision: Some((4, 4)),
                },
                LayerDesc::Conv {
                    c_in: 8,
                    c_out: 4,
                    h: 8,
                    w: 8,
                    f: 3,
                    quantized: true,
                    precision: Some((2, 2)),
                },
                LayerDesc::GapFc { c: 4, classes: 4 },
            ],
            input: (36, 8, 8),
            classes: 4,
        };
        assert_eq!(
            g.validate_for(&ProcessorConfig::sparq(), w(2)),
            Err(GraphError::BoundaryWidth { layer: 1, from_bits: 32, to_bits: 8 })
        );
        // the same chain with a 16-bit-container consumer is legal
        let mut ok = g.clone();
        if let LayerDesc::Conv { precision, .. } = &mut ok.layers[1] {
            *precision = Some((4, 4)); // LP: E16 in, one narrowing step
        }
        ok.validate_for(&ProcessorConfig::sparq(), w(2)).unwrap();
    }
}
