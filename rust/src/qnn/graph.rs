//! Static description of the SparqCNN architecture (kept in lock-step
//! with `python/compile/model.py` — the artifact manifest carries the
//! same shapes and the integration tests cross-check them).

/// One layer of the network.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerDesc {
    /// 'same' conv: C_in x H x W -> C_out x H x W with an FxF kernel.
    Conv { c_in: u32, c_out: u32, h: u32, w: u32, f: u32, quantized: bool },
    /// 2x2 max pool (halves H and W).
    MaxPool { c: u32, h: u32, w: u32 },
    /// Global average pool + linear head.
    GapFc { c: u32, classes: u32 },
}

impl LayerDesc {
    /// Multiply-accumulates of this layer (per image).
    pub fn macs(&self) -> u64 {
        match *self {
            LayerDesc::Conv { c_in, c_out, h, w, f, .. } => {
                c_in as u64 * c_out as u64 * h as u64 * w as u64 * (f * f) as u64
            }
            LayerDesc::MaxPool { .. } => 0,
            LayerDesc::GapFc { c, classes } => (c * classes) as u64,
        }
    }

    pub fn name(&self) -> String {
        match *self {
            LayerDesc::Conv { c_in, c_out, f, quantized, .. } => format!(
                "conv {c_in}->{c_out} {f}x{f}{}",
                if quantized { " [sub-byte]" } else { " [stem]" }
            ),
            LayerDesc::MaxPool { .. } => "maxpool2".into(),
            LayerDesc::GapFc { .. } => "gap+fc".into(),
        }
    }
}

/// The whole network.
#[derive(Debug, Clone)]
pub struct QnnGraph {
    pub layers: Vec<LayerDesc>,
    pub input: (u32, u32, u32),
    pub classes: u32,
}

impl QnnGraph {
    /// The SparqCNN from `python/compile/model.py`: 16x16 single-channel
    /// inputs, 4 classes; conv2/conv3 carry the sub-byte precision.
    pub fn sparq_cnn() -> QnnGraph {
        QnnGraph {
            layers: vec![
                LayerDesc::Conv { c_in: 1, c_out: 16, h: 16, w: 16, f: 3, quantized: false },
                LayerDesc::Conv { c_in: 16, c_out: 32, h: 16, w: 16, f: 3, quantized: true },
                LayerDesc::MaxPool { c: 32, h: 16, w: 16 },
                LayerDesc::Conv { c_in: 32, c_out: 32, h: 8, w: 8, f: 3, quantized: true },
                LayerDesc::MaxPool { c: 32, h: 8, w: 8 },
                LayerDesc::GapFc { c: 32, classes: 4 },
            ],
            input: (1, 16, 16),
            classes: 4,
        }
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(LayerDesc::macs).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparq_cnn_shapes() {
        let g = QnnGraph::sparq_cnn();
        assert_eq!(g.layers.len(), 6);
        assert_eq!(g.input, (1, 16, 16));
        // conv2: 16*32*16*16*9
        assert_eq!(g.layers[1].macs(), 16 * 32 * 16 * 16 * 9);
        assert!(g.total_macs() > 1_000_000);
    }

    #[test]
    fn names_tag_quantized_layers() {
        let g = QnnGraph::sparq_cnn();
        assert!(g.layers[0].name().contains("[stem]"));
        assert!(g.layers[1].name().contains("[sub-byte]"));
    }
}
