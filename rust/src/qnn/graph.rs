//! Static description of the SparqCNN architecture (kept in lock-step
//! with `python/compile/model.py` — the artifact manifest carries the
//! same shapes and the integration tests cross-check them).

/// One layer of the network.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerDesc {
    /// 'same' conv: C_in x H x W -> C_out x H x W with an FxF kernel.
    Conv { c_in: u32, c_out: u32, h: u32, w: u32, f: u32, quantized: bool },
    /// 2x2 max pool (halves H and W).
    MaxPool { c: u32, h: u32, w: u32 },
    /// Global average pool + linear head.
    GapFc { c: u32, classes: u32 },
}

impl LayerDesc {
    /// Multiply-accumulates of this layer (per image).
    pub fn macs(&self) -> u64 {
        match *self {
            LayerDesc::Conv { c_in, c_out, h, w, f, .. } => {
                c_in as u64 * c_out as u64 * h as u64 * w as u64 * (f * f) as u64
            }
            LayerDesc::MaxPool { .. } => 0,
            LayerDesc::GapFc { c, classes } => (c * classes) as u64,
        }
    }

    pub fn name(&self) -> String {
        match *self {
            LayerDesc::Conv { c_in, c_out, f, quantized, .. } => format!(
                "conv {c_in}->{c_out} {f}x{f}{}",
                if quantized { " [sub-byte]" } else { " [stem]" }
            ),
            LayerDesc::MaxPool { .. } => "maxpool2".into(),
            LayerDesc::GapFc { .. } => "gap+fc".into(),
        }
    }

    /// (c, h, w) this layer consumes.
    pub fn in_dims(&self) -> (u32, u32, u32) {
        match *self {
            LayerDesc::Conv { c_in, h, w, .. } => (c_in, h, w),
            LayerDesc::MaxPool { c, h, w } => (c, h, w),
            // GAP+FC consumes whatever spatial extent it is handed;
            // validate() checks the channel count only
            LayerDesc::GapFc { c, .. } => (c, 0, 0),
        }
    }

    /// (c, h, w) this layer produces ('same' convs preserve h x w;
    /// GAP+FC produces the logits vector).
    pub fn out_dims(&self) -> (u32, u32, u32) {
        match *self {
            LayerDesc::Conv { c_out, h, w, .. } => (c_out, h, w),
            LayerDesc::MaxPool { c, h, w } => (c, h / 2, w / 2),
            LayerDesc::GapFc { classes, .. } => (classes, 1, 1),
        }
    }
}

/// Why a [`QnnGraph`] failed shape-chaining validation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GraphError {
    Empty,
    /// Layer `layer`'s declared input dims do not equal the previous
    /// layer's output dims.
    ShapeMismatch { layer: usize, expected: (u32, u32, u32), got: (u32, u32, u32) },
    /// 2x2 pooling needs even spatial dims.
    OddPool { layer: usize, h: u32, w: u32 },
    /// 'same' convs need an odd kernel (symmetric border).
    EvenKernel { layer: usize, f: u32 },
    /// GAP+FC must be the final layer.
    HeadNotLast { layer: usize },
    /// The head's class count disagrees with the graph's.
    ClassMismatch { head: u32, graph: u32 },
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            GraphError::Empty => write!(f, "graph has no layers"),
            GraphError::ShapeMismatch { layer, expected, got } => write!(
                f,
                "layer {layer}: input dims {got:?} != previous layer's output {expected:?}"
            ),
            GraphError::OddPool { layer, h, w } => {
                write!(f, "layer {layer}: 2x2 maxpool over odd dims {h}x{w}")
            }
            GraphError::EvenKernel { layer, f: k } => {
                write!(f, "layer {layer}: 'same' conv needs an odd kernel, got {k}x{k}")
            }
            GraphError::HeadNotLast { layer } => {
                write!(f, "layer {layer}: gap+fc must be the final layer")
            }
            GraphError::ClassMismatch { head, graph } => {
                write!(f, "head produces {head} classes but the graph declares {graph}")
            }
        }
    }
}

impl std::error::Error for GraphError {}

/// In-channel count the packed kernels actually run with: odd counts
/// get one explicit always-zero channel (the stem's 1 -> 2).
pub fn padded_c(c: u32) -> u32 {
    if c % 2 == 1 {
        c + 1
    } else {
        c
    }
}

/// The whole network.
#[derive(Debug, Clone)]
pub struct QnnGraph {
    pub layers: Vec<LayerDesc>,
    pub input: (u32, u32, u32),
    pub classes: u32,
}

impl QnnGraph {
    /// The SparqCNN from `python/compile/model.py`: 16x16 single-channel
    /// inputs, 4 classes; conv2/conv3 carry the sub-byte precision.
    pub fn sparq_cnn() -> QnnGraph {
        QnnGraph {
            layers: vec![
                LayerDesc::Conv { c_in: 1, c_out: 16, h: 16, w: 16, f: 3, quantized: false },
                LayerDesc::Conv { c_in: 16, c_out: 32, h: 16, w: 16, f: 3, quantized: true },
                LayerDesc::MaxPool { c: 32, h: 16, w: 16 },
                LayerDesc::Conv { c_in: 32, c_out: 32, h: 8, w: 8, f: 3, quantized: true },
                LayerDesc::MaxPool { c: 32, h: 8, w: 8 },
                LayerDesc::GapFc { c: 32, classes: 4 },
            ],
            input: (1, 16, 16),
            classes: 4,
        }
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(LayerDesc::macs).sum()
    }

    /// Shape-chaining validation: every layer's declared input dims
    /// must equal the previous layer's output dims (the graph input for
    /// layer 0), pools need even spatial dims, 'same' convs odd
    /// kernels, and the GAP+FC head must be last and agree on the
    /// class count.  Before this check existed, mismatched graphs
    /// scheduled silently against per-layer random tensors; the
    /// dataflow compiler refuses them instead.
    pub fn validate(&self) -> Result<(), GraphError> {
        if self.layers.is_empty() {
            return Err(GraphError::Empty);
        }
        let mut cur = self.input;
        for (li, layer) in self.layers.iter().enumerate() {
            let (ic, ih, iw) = layer.in_dims();
            let expected_spatial = !matches!(layer, LayerDesc::GapFc { .. });
            let got = if expected_spatial { (ic, ih, iw) } else { (ic, cur.1, cur.2) };
            if got != cur {
                return Err(GraphError::ShapeMismatch { layer: li, expected: cur, got });
            }
            match *layer {
                LayerDesc::Conv { f, .. } if f % 2 == 0 => {
                    return Err(GraphError::EvenKernel { layer: li, f });
                }
                LayerDesc::MaxPool { h, w, .. } if h % 2 != 0 || w % 2 != 0 => {
                    return Err(GraphError::OddPool { layer: li, h, w });
                }
                LayerDesc::GapFc { classes, .. } => {
                    if li != self.layers.len() - 1 {
                        return Err(GraphError::HeadNotLast { layer: li });
                    }
                    if classes != self.classes {
                        return Err(GraphError::ClassMismatch {
                            head: classes,
                            graph: self.classes,
                        });
                    }
                }
                _ => {}
            }
            cur = layer.out_dims();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparq_cnn_shapes() {
        let g = QnnGraph::sparq_cnn();
        assert_eq!(g.layers.len(), 6);
        assert_eq!(g.input, (1, 16, 16));
        // conv2: 16*32*16*16*9
        assert_eq!(g.layers[1].macs(), 16 * 32 * 16 * 16 * 9);
        assert!(g.total_macs() > 1_000_000);
    }

    #[test]
    fn names_tag_quantized_layers() {
        let g = QnnGraph::sparq_cnn();
        assert!(g.layers[0].name().contains("[stem]"));
        assert!(g.layers[1].name().contains("[sub-byte]"));
    }

    #[test]
    fn sparq_cnn_validates() {
        QnnGraph::sparq_cnn().validate().unwrap();
    }

    #[test]
    fn mismatched_channels_rejected() {
        let mut g = QnnGraph::sparq_cnn();
        // conv2 claims 8 input channels; conv1 produces 16
        g.layers[1] = LayerDesc::Conv { c_in: 8, c_out: 32, h: 16, w: 16, f: 3, quantized: true };
        assert!(matches!(g.validate(), Err(GraphError::ShapeMismatch { layer: 1, .. })));
    }

    #[test]
    fn mismatched_spatial_dims_rejected() {
        let mut g = QnnGraph::sparq_cnn();
        // conv3 claims the pre-pool 16x16 extent
        g.layers[3] = LayerDesc::Conv { c_in: 32, c_out: 32, h: 16, w: 16, f: 3, quantized: true };
        assert!(matches!(g.validate(), Err(GraphError::ShapeMismatch { layer: 3, .. })));
    }

    #[test]
    fn input_mismatch_rejected_at_layer_zero() {
        let mut g = QnnGraph::sparq_cnn();
        g.input = (3, 16, 16);
        assert!(matches!(g.validate(), Err(GraphError::ShapeMismatch { layer: 0, .. })));
    }

    #[test]
    fn odd_pool_and_even_kernel_rejected() {
        let g = QnnGraph {
            layers: vec![LayerDesc::MaxPool { c: 2, h: 5, w: 4 }],
            input: (2, 5, 4),
            classes: 4,
        };
        assert!(matches!(g.validate(), Err(GraphError::OddPool { layer: 0, .. })));
        let g = QnnGraph {
            layers: vec![LayerDesc::Conv { c_in: 2, c_out: 4, h: 8, w: 8, f: 2, quantized: true }],
            input: (2, 8, 8),
            classes: 4,
        };
        assert!(matches!(g.validate(), Err(GraphError::EvenKernel { layer: 0, f: 2 })));
    }

    #[test]
    fn head_position_and_classes_checked() {
        let mut g = QnnGraph::sparq_cnn();
        g.classes = 10;
        assert_eq!(g.validate(), Err(GraphError::ClassMismatch { head: 4, graph: 10 }));
        let g = QnnGraph {
            layers: vec![
                LayerDesc::GapFc { c: 2, classes: 4 },
                LayerDesc::MaxPool { c: 4, h: 1, w: 1 },
            ],
            input: (2, 4, 4),
            classes: 4,
        };
        assert!(matches!(g.validate(), Err(GraphError::HeadNotLast { layer: 0 })));
    }

    #[test]
    fn empty_graph_rejected_and_odd_cin_padding_is_explicit() {
        let g = QnnGraph { layers: vec![], input: (1, 1, 1), classes: 0 };
        assert_eq!(g.validate(), Err(GraphError::Empty));
        assert_eq!(padded_c(1), 2);
        assert_eq!(padded_c(16), 16);
    }
}
