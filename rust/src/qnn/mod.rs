//! The QNN graph (mirroring `python/compile/model.py`'s SparqCNN) and
//! its layer-by-layer scheduling onto the Sparq simulator.
//!
//! The serving stack uses this to attach *hardware* cost to every
//! request: PJRT executes the numerics (the AOT artifact), while this
//! module answers "how many Sparq cycles would this inference take",
//! layer by layer, using the same kernel builders the benchmarks use.

pub mod graph;
pub mod schedule;

pub use graph::{LayerDesc, QnnGraph};
pub use schedule::{schedule, LayerCycles, QnnSchedule};
