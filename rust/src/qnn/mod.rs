//! The QNN graph (mirroring `python/compile/model.py`'s SparqCNN), the
//! dataflow compiler that turns it into one chained multi-layer
//! program ([`compiled`]), and the per-layer schedule readout.
//!
//! Since the end-to-end dataflow refactor, `schedule` is no longer a
//! cost model stitched from independent random tensors: for sub-byte
//! precisions it compiles the whole network once
//! ([`compiled::CompiledQnn`], cached in the shared
//! [`crate::kernels::ProgramCache`] under a graph-level key), runs ONE
//! real inference — activations flowing layer to layer through a
//! planned activation arena, maxpool and GAP+FC executed as
//! instruction streams — and reads the per-layer cycles off that run.
//! The serving stack classifies through the same compiled network
//! ([`crate::runtime::SimQnnModel`]).
//!
//! Precision and kernel variant are per-layer properties: quantized
//! convs may carry `(w_bits, a_bits)` overrides
//! ([`graph::LayerDesc::Conv`]), legality is validated with typed
//! errors ([`graph::QnnGraph::validate_for`]), and the compiler picks
//! each layer's kernel from the cached autotune ranking
//! ([`crate::kernels::autotune`]).
//!
//! Batched serving compiles the same graph under a batch-B arena
//! ([`compiled::CompiledQnn::compile_batched`], DESIGN.md §Serving):
//! one program, B per-image activation slots, per-slot execution via
//! address rebasing, and the runtime weight-pack pass hoisted into a
//! per-batch preamble.

pub mod compiled;
pub mod graph;
pub mod schedule;

pub use compiled::{
    CompiledQnn, GoldenTrace, QnnBatchRun, QnnNet, QnnRun, VariantPolicy, MAX_BATCH,
};
pub use graph::{ConvPrec, GraphError, LayerDesc, QnnGraph};
pub use schedule::{schedule, LayerCycles, QnnSchedule};
