//! Layer-by-layer scheduling of a QNN onto the simulated processor:
//! every conv layer is built with the same kernel builders the
//! benchmarks use and run through the cycle model.
//!
//! Padding note: the network uses 'same' convs; the kernel library
//! computes 'valid' convs, so each layer is scheduled over its padded
//! input (H+f-1), exactly what an im2row-free implementation does with
//! a zero-padded buffer.

use crate::arch::ProcessorConfig;
use crate::kernels::{run_conv_cached, ConvDims, ConvVariant, EngineOpts, ProgramCache, Workload};
use crate::qnn::graph::{LayerDesc, QnnGraph};
use crate::sim::{MachinePool, SimError};
use crate::ulppack::RegionMode;

/// Precision configuration for a scheduled network.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QnnPrecision {
    Fp32,
    /// Sub-byte (W, A) on the quantized layers; the stem stays int16.
    SubByte { w_bits: u32, a_bits: u32 },
}

impl QnnPrecision {
    pub fn label(&self) -> String {
        match *self {
            QnnPrecision::Fp32 => "fp32".into(),
            QnnPrecision::SubByte { w_bits, a_bits } => format!("w{w_bits}a{a_bits}"),
        }
    }
}

/// Cycle cost of one scheduled layer.
#[derive(Debug, Clone)]
pub struct LayerCycles {
    pub name: String,
    pub cycles: u64,
    pub macs: u64,
    pub variant: String,
}

/// A full per-image schedule.
#[derive(Debug, Clone)]
pub struct QnnSchedule {
    pub precision: QnnPrecision,
    pub layers: Vec<LayerCycles>,
    pub processor: String,
}

impl QnnSchedule {
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.cycles).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    /// Images/second at the lane fmax from the power model.
    pub fn throughput_at(&self, fmax_ghz: f64) -> f64 {
        fmax_ghz * 1e9 / self.total_cycles() as f64
    }
}

/// Pick the conv variant a layer runs with under `precision`.
fn variant_for(layer: &LayerDesc, precision: QnnPrecision) -> Option<ConvVariant> {
    match *layer {
        LayerDesc::Conv { quantized, .. } => Some(match precision {
            QnnPrecision::Fp32 => ConvVariant::Fp32,
            QnnPrecision::SubByte { w_bits, a_bits } => {
                if quantized {
                    ConvVariant::Vmacsr { w_bits, a_bits, mode: RegionMode::Paper }
                } else {
                    ConvVariant::Int16 // the stem
                }
            }
        }),
        _ => None,
    }
}

/// Schedule one inference of `graph` at `precision` on `cfg`.
///
/// Non-conv layers (pool, GAP+FC) are costed as a single memory-bound
/// vector pass over their activations (they are <2% of the MACs).
///
/// One-shot convenience over [`schedule_cached`] with a transient cache
/// and pool; callers that re-schedule (serving, sweeps) should hold a
/// shared [`ProgramCache`]/[`MachinePool`] and call the cached form so
/// every layer's instruction stream is emitted exactly once.
pub fn schedule(
    cfg: &ProcessorConfig,
    graph: &QnnGraph,
    precision: QnnPrecision,
) -> Result<QnnSchedule, SimError> {
    schedule_cached(cfg, graph, precision, &ProgramCache::new(), &MachinePool::new())
}

/// [`schedule`] through a shared compiled-program cache and machine
/// pool: layer programs compile once per (dims, variant, processor,
/// weights) and re-execute on reset pooled machines with identical
/// cycle counts.
pub fn schedule_cached(
    cfg: &ProcessorConfig,
    graph: &QnnGraph,
    precision: QnnPrecision,
    cache: &ProgramCache,
    pool: &MachinePool,
) -> Result<QnnSchedule, SimError> {
    let mut layers = Vec::new();
    for (li, layer) in graph.layers.iter().enumerate() {
        match variant_for(layer, precision) {
            Some(variant) => {
                let LayerDesc::Conv { c_in, c_out, h, w, f, .. } = *layer else { unreachable!() };
                // 'same' padding -> schedule the padded 'valid' problem.
                // in-channels are padded to even for the packed kernels
                // (the python model's channel counts are already even
                // except the 1-channel stem, which runs int16 anyway).
                let c = if c_in % 2 == 1 { c_in + 1 } else { c_in };
                let dims =
                    ConvDims { c, h: h + f - 1, w: w + f - 1, co: c_out, fh: f, fw: f };
                let (wb, ab) = variant.bits();
                let wl = Workload::random(dims, wb, ab, 0x5EED + li as u64);
                let report =
                    run_conv_cached(cache, pool, cfg, &wl, variant, EngineOpts::default())?;
                layers.push(LayerCycles {
                    name: layer.name(),
                    cycles: report.stats.cycles,
                    macs: layer.macs(),
                    variant: variant.label(),
                });
            }
            None => {
                // one streaming pass over the activations at the vector
                // engine's memory bandwidth
                let bytes = match *layer {
                    LayerDesc::MaxPool { c, h, w } => (c * h * w * 2) as u64,
                    LayerDesc::GapFc { c, .. } => (c * 64) as u64,
                    _ => unreachable!(),
                };
                let cycles = bytes.div_ceil(cfg.mem_bytes_per_cycle as u64)
                    + cfg.mem_latency as u64;
                layers.push(LayerCycles {
                    name: layer.name(),
                    cycles,
                    macs: layer.macs(),
                    variant: "streaming".into(),
                });
            }
        }
    }
    Ok(QnnSchedule { precision, layers, processor: cfg.name.clone() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules_all_layers() {
        let g = QnnGraph::sparq_cnn();
        let s = schedule(
            &ProcessorConfig::sparq(),
            &g,
            QnnPrecision::SubByte { w_bits: 2, a_bits: 2 },
        )
        .unwrap();
        assert_eq!(s.layers.len(), g.layers.len());
        assert!(s.total_cycles() > 0);
        assert_eq!(s.total_macs(), g.total_macs());
    }

    #[test]
    fn subbyte_faster_than_fp32() {
        let g = QnnGraph::sparq_cnn();
        let fp = schedule(&ProcessorConfig::ara(), &g, QnnPrecision::Fp32).unwrap();
        let q2 = schedule(
            &ProcessorConfig::sparq(),
            &g,
            QnnPrecision::SubByte { w_bits: 2, a_bits: 2 },
        )
        .unwrap();
        assert!(
            q2.total_cycles() < fp.total_cycles(),
            "w2a2 {} !< fp32 {}",
            q2.total_cycles(),
            fp.total_cycles()
        );
    }

    #[test]
    fn fp32_rejected_on_sparq() {
        let g = QnnGraph::sparq_cnn();
        assert!(schedule(&ProcessorConfig::sparq(), &g, QnnPrecision::Fp32).is_err());
    }

    #[test]
    fn cached_reschedule_is_identical_and_hits() {
        let g = QnnGraph::sparq_cnn();
        let cfg = ProcessorConfig::sparq();
        let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
        let cache = ProgramCache::new();
        let pool = MachinePool::new();
        let a = schedule_cached(&cfg, &g, prec, &cache, &pool).unwrap();
        let misses_after_first = cache.stats().misses;
        let b = schedule_cached(&cfg, &g, prec, &cache, &pool).unwrap();
        assert_eq!(a.total_cycles(), b.total_cycles());
        let s = cache.stats();
        assert_eq!(s.misses, misses_after_first, "second schedule must be all hits");
        assert!(s.hits >= misses_after_first);
        // and the cached path agrees with the one-shot path
        let cold = schedule(&cfg, &g, prec).unwrap();
        assert_eq!(a.total_cycles(), cold.total_cycles());
        assert!(pool.stats().reused > 0);
    }

    #[test]
    fn throughput_positive() {
        let g = QnnGraph::sparq_cnn();
        let s = schedule(
            &ProcessorConfig::sparq(),
            &g,
            QnnPrecision::SubByte { w_bits: 4, a_bits: 4 },
        )
        .unwrap();
        assert!(s.throughput_at(1.464) > 0.0);
    }
}
