//! Per-layer schedule readout of the QNN.
//!
//! For sub-byte precisions this is no longer a cost model: the whole
//! network compiles once into a chained multi-layer program
//! ([`crate::qnn::compiled::CompiledQnn`], cached in the shared
//! [`ProgramCache`] under a graph-level key) and `schedule` runs ONE
//! real end-to-end inference — activations flow layer to layer through
//! the planned activation arena, zero-padding/requantize/maxpool/
//! GAP+FC execute as instruction streams — then reads the per-layer
//! cycles off that run.  Cycle counts are data-independent (the
//! timing model sees the instruction stream and `vl`, not the
//! values), so one inference IS the schedule.
//!
//! The fp32 baseline keeps the legacy per-layer estimate (Ara has no
//! integer requantize path to chain through): conv layers run as
//! independent workloads, pool/head cost one streaming pass.  Its
//! per-layer workloads now derive from the same single graph-level
//! seed as the dataflow path (no more `0x5EED + li` per-layer
//! scatter).
//!
//! ## Per-layer precision resolution
//!
//! Every path that needs a layer's kernel resolves through
//! [`variant_for`]: a quantized conv's `(w_bits, a_bits)` is its
//! per-layer override when present, the network default otherwise
//! ([`crate::qnn::graph::QnnGraph::conv_precisions`] is the shared
//! resolution).  The fp32 legacy estimate routes through the same
//! resolution with a **documented fallback**: under
//! [`QnnPrecision::Fp32`] there is no level domain, so per-layer
//! sub-byte overrides are ignored and every conv is costed as the
//! uniform fp32 baseline — a mixed graph scheduled at fp32 reports
//! exactly the same cycles as its override-free twin rather than
//! mis-reporting a precision it cannot honour.

use crate::arch::ProcessorConfig;
use crate::kernels::{run_conv_cached, ConvDims, ConvVariant, EngineOpts, ProgramCache, Workload};
use crate::qnn::graph::{LayerDesc, QnnGraph};
use crate::sim::{MachinePool, SimError};
use crate::testutil::Gen;
use crate::ulppack::RegionMode;

/// The default graph-level weight seed (one seed derives every weight
/// in the network; recorded in [`QnnSchedule::seed`]).
pub const DEFAULT_QNN_SEED: u64 = 0x5EED_C0DE;

/// Precision configuration for a scheduled network.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QnnPrecision {
    Fp32,
    /// Sub-byte (W, A) on the quantized layers; the stem stays int16.
    SubByte { w_bits: u32, a_bits: u32 },
}

impl QnnPrecision {
    pub fn label(&self) -> String {
        match *self {
            QnnPrecision::Fp32 => "fp32".into(),
            QnnPrecision::SubByte { w_bits, a_bits } => format!("w{w_bits}a{a_bits}"),
        }
    }
}

/// Cycle cost of one scheduled layer.
#[derive(Debug, Clone)]
pub struct LayerCycles {
    pub name: String,
    pub cycles: u64,
    pub macs: u64,
    pub variant: String,
}

/// A full per-image schedule.
#[derive(Debug, Clone)]
pub struct QnnSchedule {
    pub precision: QnnPrecision,
    pub layers: Vec<LayerCycles>,
    pub processor: String,
    /// The graph-level weight seed the scheduled network was built
    /// from — reproducibility: `QnnNet::from_seed(graph, precision,
    /// seed)` reconstructs the exact same network.
    pub seed: u64,
}

impl QnnSchedule {
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.cycles).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    /// Images/second at the lane fmax from the power model.
    pub fn throughput_at(&self, fmax_ghz: f64) -> f64 {
        fmax_ghz * 1e9 / self.total_cycles() as f64
    }
}

/// Pick the *canonical* conv variant a layer runs with under
/// `precision`, honouring the layer's `(w_bits, a_bits)` override.
/// This is the non-tuned assignment the golden network and the graph
/// validator share; `kernels::autotune` may substitute a measured
/// faster variant at compile time (boundary legality preserved).
/// Under fp32 the overrides are ignored (see the module docs).
pub(crate) fn variant_for(layer: &LayerDesc, precision: QnnPrecision) -> Option<ConvVariant> {
    match *layer {
        LayerDesc::Conv { quantized, precision: ovr, .. } => Some(match precision {
            QnnPrecision::Fp32 => ConvVariant::Fp32,
            QnnPrecision::SubByte { w_bits, a_bits } => {
                if quantized {
                    let (w_bits, a_bits) = ovr.unwrap_or((w_bits, a_bits));
                    ConvVariant::Vmacsr { w_bits, a_bits, mode: RegionMode::Paper }
                } else {
                    ConvVariant::Int16 // the stem
                }
            }
        }),
        LayerDesc::DepthwiseConv { precision: ovr, .. } => Some(match precision {
            QnnPrecision::Fp32 => ConvVariant::Fp32,
            QnnPrecision::SubByte { w_bits, a_bits } => {
                let (w_bits, a_bits) = ovr.unwrap_or((w_bits, a_bits));
                ConvVariant::Vmacsr { w_bits, a_bits, mode: RegionMode::Paper }
            }
        }),
        LayerDesc::Dense { precision: ovr, .. } => match precision {
            // vmacsr-only (validate_for rejects it on Ara-likes); the
            // fp32 legacy estimate has no kernel for it either
            QnnPrecision::Fp32 => None,
            QnnPrecision::SubByte { w_bits, a_bits } => {
                let (w_bits, a_bits) = ovr.unwrap_or((w_bits, a_bits));
                Some(ConvVariant::Vmacsr { w_bits, a_bits, mode: RegionMode::Paper })
            }
        },
        _ => None,
    }
}

/// Schedule one inference of `graph` at `precision` on `cfg` with the
/// default graph-level weight seed.
///
/// One-shot convenience over [`schedule_cached`] with a transient cache
/// and pool; callers that re-schedule (serving, sweeps) should hold a
/// shared [`ProgramCache`]/[`MachinePool`] so the network compiles
/// exactly once.
pub fn schedule(
    cfg: &ProcessorConfig,
    graph: &QnnGraph,
    precision: QnnPrecision,
) -> Result<QnnSchedule, SimError> {
    schedule_cached(cfg, graph, precision, &ProgramCache::new(), &MachinePool::new())
}

/// [`schedule`] through a shared compiled-program cache and machine
/// pool, at the default seed.
pub fn schedule_cached(
    cfg: &ProcessorConfig,
    graph: &QnnGraph,
    precision: QnnPrecision,
    cache: &ProgramCache,
    pool: &MachinePool,
) -> Result<QnnSchedule, SimError> {
    schedule_seeded(cfg, graph, precision, DEFAULT_QNN_SEED, cache, pool)
}

/// The full form: schedule `graph` with the network weights derived
/// from `seed`.  Sub-byte precisions run the real end-to-end dataflow
/// program; fp32 keeps the legacy per-layer estimate.
pub fn schedule_seeded(
    cfg: &ProcessorConfig,
    graph: &QnnGraph,
    precision: QnnPrecision,
    seed: u64,
    cache: &ProgramCache,
    pool: &MachinePool,
) -> Result<QnnSchedule, SimError> {
    graph.validate().map_err(|e| SimError::Graph(e.to_string()))?;
    match precision {
        QnnPrecision::SubByte { .. } => {
            let cq = cache.get_or_compile_qnn(cfg, graph, precision, seed)?;
            let image = cq.net.test_image(seed ^ 0x1AA6E);
            let mut m = pool.acquire(cfg, cq.mem_bytes);
            let run = cq.execute_fresh(&mut m, &image);
            pool.release(m);
            let run = run?;
            Ok(QnnSchedule {
                precision,
                layers: cq.layer_cycles(&run),
                processor: cfg.name.clone(),
                seed,
            })
        }
        QnnPrecision::Fp32 => schedule_fp32_legacy(cfg, graph, seed, cache, pool),
    }
}

/// The pre-dataflow cost model, kept for the fp32 baseline only: conv
/// layers as independent workloads (weights from the one graph seed),
/// pool/head as a single memory-bound streaming pass.
fn schedule_fp32_legacy(
    cfg: &ProcessorConfig,
    graph: &QnnGraph,
    seed: u64,
    cache: &ProgramCache,
    pool: &MachinePool,
) -> Result<QnnSchedule, SimError> {
    let mut layers = Vec::new();
    let mut seeds = Gen::new(seed);
    for layer in graph.layers.iter() {
        match variant_for(layer, QnnPrecision::Fp32) {
            Some(variant) => {
                // 'same' padding -> schedule the padded 'valid' problem;
                // odd in-channel counts get the explicit zero channel
                let (dims, repeat) = match *layer {
                    LayerDesc::Conv { c_in, c_out, h, w, f, .. } => {
                        let c = super::graph::padded_c(c_in);
                        (ConvDims { c, h: h + f - 1, w: w + f - 1, co: c_out, fh: f, fw: f }, 1)
                    }
                    // depthwise: one (real + zero channel) group costed
                    // once, multiplied by the channel count — timing is
                    // data-independent, so the groups are identical
                    LayerDesc::DepthwiseConv { c, h, w, f, .. } => (
                        ConvDims { c: 2, h: h + f - 1, w: w + f - 1, co: 1, fh: f, fw: f },
                        c as u64,
                    ),
                    _ => unreachable!(),
                };
                let (wb, ab) = variant.bits();
                let wl = Workload::random(dims, wb, ab, seeds.next_u64());
                let report =
                    run_conv_cached(cache, pool, cfg, &wl, variant, EngineOpts::default())?;
                layers.push(LayerCycles {
                    name: layer.name(),
                    cycles: report.stats.cycles * repeat,
                    macs: layer.macs(),
                    variant: variant.label(),
                });
            }
            None => {
                // one streaming pass over the activations at the vector
                // engine's memory bandwidth (4 B/element: this estimate
                // is fp32-only now, so the former int16-flavoured 2 B
                // per pooled element was off by half)
                let bytes = match *layer {
                    LayerDesc::MaxPool { c, h, w } => (c * h * w * 4) as u64,
                    LayerDesc::GapFc { c, .. } => (c * 64) as u64,
                    // residual join: two branch loads + one store
                    LayerDesc::Add { c, h, w } => (c * h * w * 4 * 3) as u64,
                    LayerDesc::Dense { .. } => {
                        return Err(SimError::Unsupported(
                            "dense head is vmacsr-only; the fp32 legacy estimate has no kernel for it",
                        ))
                    }
                    _ => unreachable!(),
                };
                let cycles = bytes.div_ceil(cfg.mem_bytes_per_cycle as u64)
                    + cfg.mem_latency as u64;
                layers.push(LayerCycles {
                    name: layer.name(),
                    cycles,
                    macs: layer.macs(),
                    variant: "streaming".into(),
                });
            }
        }
    }
    Ok(QnnSchedule { precision: QnnPrecision::Fp32, layers, processor: cfg.name.clone(), seed })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules_all_layers() {
        let g = QnnGraph::sparq_cnn();
        let s = schedule(
            &ProcessorConfig::sparq(),
            &g,
            QnnPrecision::SubByte { w_bits: 2, a_bits: 2 },
        )
        .unwrap();
        assert_eq!(s.layers.len(), g.layers.len());
        assert!(s.total_cycles() > 0);
        assert_eq!(s.total_macs(), g.total_macs());
        assert_eq!(s.seed, DEFAULT_QNN_SEED);
        // dataflow, not estimate: the pool and head layers carry real
        // executed vector streams now
        let pool_row = s.layers.iter().find(|l| l.name == "maxpool2").unwrap();
        assert_eq!(pool_row.variant, "maxpool2-vec");
        let head = s.layers.iter().find(|l| l.name == "gap+fc").unwrap();
        assert_eq!(head.variant, "gap+fc-vec");
        assert!(pool_row.cycles > 0 && head.cycles > 0);
    }

    #[test]
    fn subbyte_faster_than_fp32() {
        let g = QnnGraph::sparq_cnn();
        let fp = schedule(&ProcessorConfig::ara(), &g, QnnPrecision::Fp32).unwrap();
        let q2 = schedule(
            &ProcessorConfig::sparq(),
            &g,
            QnnPrecision::SubByte { w_bits: 2, a_bits: 2 },
        )
        .unwrap();
        assert!(
            q2.total_cycles() < fp.total_cycles(),
            "w2a2 {} !< fp32 {}",
            q2.total_cycles(),
            fp.total_cycles()
        );
    }

    #[test]
    fn fp32_rejected_on_sparq() {
        let g = QnnGraph::sparq_cnn();
        assert!(schedule(&ProcessorConfig::sparq(), &g, QnnPrecision::Fp32).is_err());
    }

    #[test]
    fn invalid_graph_rejected_before_scheduling() {
        let mut g = QnnGraph::sparq_cnn();
        g.layers[1] = crate::qnn::LayerDesc::Conv {
            c_in: 8,
            c_out: 32,
            h: 16,
            w: 16,
            f: 3,
            quantized: true,
            precision: None,
        };
        let r = schedule(
            &ProcessorConfig::sparq(),
            &g,
            QnnPrecision::SubByte { w_bits: 2, a_bits: 2 },
        );
        assert!(matches!(r, Err(SimError::Graph(_))), "mismatched graphs must not schedule");
    }

    #[test]
    fn cached_reschedule_is_identical_and_hits() {
        let g = QnnGraph::sparq_cnn();
        let cfg = ProcessorConfig::sparq();
        let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
        let cache = ProgramCache::new();
        let pool = MachinePool::new();
        let a = schedule_cached(&cfg, &g, prec, &cache, &pool).unwrap();
        let misses_after_first = cache.stats().misses;
        let b = schedule_cached(&cfg, &g, prec, &cache, &pool).unwrap();
        assert_eq!(a.total_cycles(), b.total_cycles());
        let s = cache.stats();
        assert_eq!(s.misses, misses_after_first, "second schedule must be all hits");
        assert!(s.hits >= misses_after_first);
        // and the cached path agrees with the one-shot path
        let cold = schedule(&cfg, &g, prec).unwrap();
        assert_eq!(a.total_cycles(), cold.total_cycles());
        assert!(pool.stats().reused > 0);
    }

    #[test]
    fn seed_changes_weights_but_schedule_shape_survives() {
        let g = QnnGraph::sparq_cnn();
        let cfg = ProcessorConfig::sparq();
        let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
        let cache = ProgramCache::new();
        let pool = MachinePool::new();
        let a = schedule_seeded(&cfg, &g, prec, 1, &cache, &pool).unwrap();
        let b = schedule_seeded(&cfg, &g, prec, 2, &cache, &pool).unwrap();
        assert_eq!(a.seed, 1);
        assert_eq!(b.seed, 2);
        assert_eq!(a.layers.len(), b.layers.len());
        // same graph, same instruction shapes -> identical cycles even
        // though the weights differ (timing is data-independent)
        assert_eq!(a.total_cycles(), b.total_cycles());
        // two seeds = two distinct cached networks
        assert_eq!(cache.stats().misses, 2);
    }

    #[test]
    fn mixed_graph_schedules_between_its_uniform_endpoints() {
        let cfg = ProcessorConfig::sparq();
        let cache = ProgramCache::new();
        let pool = MachinePool::new();
        let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
        let lo = schedule_cached(&cfg, &QnnGraph::sparq_cnn(), prec, &cache, &pool).unwrap();
        let hi = schedule_cached(
            &cfg,
            &QnnGraph::sparq_cnn(),
            QnnPrecision::SubByte { w_bits: 4, a_bits: 4 },
            &cache,
            &pool,
        )
        .unwrap();
        let mixed = schedule_cached(
            &cfg,
            &QnnGraph::sparq_cnn_mixed((4, 4), (2, 2)),
            prec,
            &cache,
            &pool,
        )
        .unwrap();
        assert!(
            lo.total_cycles() < mixed.total_cycles() && mixed.total_cycles() < hi.total_cycles(),
            "w2a2 {} !< mixed {} !< w4a4 {}",
            lo.total_cycles(),
            mixed.total_cycles(),
            hi.total_cycles()
        );
        // the W4A4 stem-adjacent conv runs in the LP container, the
        // W2A2 deep conv in ULP — visible in the variant labels
        let row = |s: &QnnSchedule, i: usize| s.layers[i].variant.clone();
        assert!(row(&mixed, 1).contains("W4A4"), "{}", row(&mixed, 1));
        assert!(row(&mixed, 3).contains("W2A2"), "{}", row(&mixed, 3));
    }

    #[test]
    fn fp32_ignores_overrides_and_does_not_misreport() {
        // documented fallback: under fp32 the per-layer sub-byte
        // overrides have no level domain to apply to, so a mixed graph
        // costs exactly like its override-free twin
        let cfg = ProcessorConfig::ara();
        let plain = schedule(&cfg, &QnnGraph::sparq_cnn(), QnnPrecision::Fp32).unwrap();
        let mixed =
            schedule(&cfg, &QnnGraph::sparq_cnn_mixed((4, 4), (2, 2)), QnnPrecision::Fp32).unwrap();
        assert_eq!(plain.total_cycles(), mixed.total_cycles());
        for (p, m) in plain.layers.iter().zip(&mixed.layers) {
            assert_eq!(p.cycles, m.cycles);
            assert_eq!(p.variant, m.variant);
        }
    }

    #[test]
    fn dag_topologies_schedule_end_to_end() {
        let cfg = ProcessorConfig::sparq();
        for g in [
            QnnGraph::sparq_resnetlike(),
            QnnGraph::sparq_mobilenetlike(),
            QnnGraph::sparq_denselike(),
        ] {
            let s =
                schedule(&cfg, &g, QnnPrecision::SubByte { w_bits: 2, a_bits: 2 }).unwrap();
            assert_eq!(s.layers.len(), g.layers.len());
            assert!(s.total_cycles() > 0);
            assert_eq!(s.total_macs(), g.total_macs());
        }
    }

    #[test]
    fn fp32_legacy_costs_residual_and_depthwise_graphs() {
        let cfg = ProcessorConfig::ara();
        let r = schedule(&cfg, &QnnGraph::sparq_resnetlike(), QnnPrecision::Fp32).unwrap();
        assert_eq!(r.layers.len(), QnnGraph::sparq_resnetlike().layers.len());
        let join = r.layers.iter().find(|l| l.name.contains("add")).unwrap();
        assert_eq!(join.variant, "streaming");
        assert!(join.cycles > 0);
        let m = schedule(&cfg, &QnnGraph::sparq_mobilenetlike(), QnnPrecision::Fp32).unwrap();
        assert!(m.total_cycles() > 0);
        // the dense head has no fp32 kernel — typed error, no estimate
        assert!(matches!(
            schedule(&cfg, &QnnGraph::sparq_denselike(), QnnPrecision::Fp32),
            Err(SimError::Unsupported(_))
        ));
    }

    #[test]
    fn throughput_positive() {
        let g = QnnGraph::sparq_cnn();
        let s = schedule(
            &ProcessorConfig::sparq(),
            &g,
            QnnPrecision::SubByte { w_bits: 4, a_bits: 4 },
        )
        .unwrap();
        assert!(s.throughput_at(1.464) > 0.0);
    }
}
