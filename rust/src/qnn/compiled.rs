//! `CompiledQnn` — the whole network compiled once into a chained
//! multi-layer program over a single liveness-planned activation arena
//! (DESIGN.md §Graph programs).
//!
//! Before this refactor, `qnn::schedule` was only a cost model: every
//! conv layer ran on an independent random tensor, activations never
//! flowed layer to layer, and maxpool/GAP+FC cycles were a fabricated
//! bytes/cycle formula.  Now:
//!
//! * The compiler walks the graph in its deterministic topological
//!   order ([`QnnGraph::topo_order`]) — residual `Add` joins,
//!   depthwise convs and `Dense` GEMM layers included, not just
//!   straight chains — and allocates one arena through the
//!   free-list-backed [`LayoutAlloc`]: buffers that are provably dead
//!   (a conv's padded input and packed copy once its stage is planned,
//!   a GEMM's column matrix, the head's level buffer) are returned to
//!   the allocator and reused by later stages, while every layer's
//!   output stays live to the end (the taps contract).  Straight-line
//!   chains keep bit-identical outputs and cycles — timing is
//!   address-independent — and every topology's high-water mark is
//!   never larger than the append-only placement
//!   ([`CompiledQnn::compile_append_only`] keeps that baseline
//!   compilable for the regression tests).
//! * Each conv layer is a [`CompiledConv`] compiled *in the arena*
//!   (`conv_engine::compile_in_arena`) whose input region is exactly
//!   where the previous layer's requantize stream writes — inputs
//!   rebind to the previous layer's output region, not to host-staged
//!   tensors.
//! * Layer boundaries are real instruction streams: zero-padding and
//!   requantize+narrow via [`crate::kernels::requant`], the
//!   requantizing `vadd.vv` residual join via
//!   [`crate::kernels::eltwise`], maxpool and GAP+FC via
//!   [`crate::kernels::pool_fc`], depthwise convs as C per-channel
//!   packed sub-convs sharing one autotune entry, and `Dense` layers
//!   as an im2col + packed GEMM ([`crate::kernels::im2col_gemm`]).
//!   Nothing is estimated.
//! * The compiled network is cached whole in
//!   [`crate::kernels::ProgramCache`] under a graph-level key
//!   (processor + layers + precision + weight seed).
//!
//! Exactness contract: [`QnnNet::golden_forward`] is the host-side
//! golden network; every layer boundary of an executed inference
//! matches it bit-for-bit (`rust/tests/qnn_dataflow.rs`), and repeated
//! executions produce identical outputs *and* cycle counts.
//!
//! ## Mixed precision + autotuning
//!
//! Precision and kernel variant are per-layer properties now: each
//! quantized conv resolves its `(w_bits, a_bits)` (layer override or
//! network default, [`crate::qnn::graph::QnnGraph::conv_precisions`]),
//! the compiler consults [`crate::kernels::autotune`] for the fastest
//! measured variant on the target processor (memoized in the shared
//! [`crate::kernels::ProgramCache`] under `TuneKey`s), and every
//! requant boundary is re-derived from the *adjacent pair* of
//! precisions — the producer's output element and worst-case value
//! against the consumer's activation width.  The autotuner only
//! substitutes variants that keep the boundary chain legal (at most
//! one `vnsrl` narrowing step); the canonical chain was already
//! validated by `QnnGraph::validate_for`.  The golden network
//! dispatches per *chosen* variant ([`QnnNet::golden_forward_with`]),
//! so mixed autotuned networks pin bit-for-bit exactly like uniform
//! ones.

use crate::arch::ProcessorConfig;
use crate::kernels::autotune::{self, TuneOutcome};
use crate::kernels::conv_engine::{self, LayoutAlloc};
use crate::kernels::eltwise;
use crate::kernels::im2col_gemm;
use crate::kernels::pool_fc::{self, gap_fc_host, maxpool2_host};
use crate::kernels::requant::{self, requant_host, RequantSpec};
use crate::kernels::workload::{golden_mod, golden_packed_vmacsr, ConvDims, OutElem, OutputRef, Workload};
use crate::kernels::{asm::Asm, CompiledConv, ConvVariant, EngineOpts, ProgramCache};
use crate::qnn::graph::{padded_c, ConvPrec, LayerDesc, QnnGraph};
use crate::qnn::schedule::{variant_for, QnnPrecision};
use crate::sim::{CompiledProgram, Machine, Program, RunReport, SimError};
use crate::testutil::Gen;
use crate::ulppack::{act_level_max, region, weight_level_max, Container, RegionMode};
use std::sync::Arc;

/// Host-side network: the graph plus every weight tensor, all derived
/// from ONE graph-level seed (recorded in `QnnSchedule` for
/// reproducibility — no more per-layer `0x5EED + li` scatter).
#[derive(Debug, Clone)]
pub struct QnnNet {
    pub graph: QnnGraph,
    pub precision: QnnPrecision,
    pub seed: u64,
    /// Per-conv resolved precisions (graph order): the layer override
    /// or the network default; the stem resolves to 8-bit weights.
    pub precs: Vec<ConvPrec>,
    /// Weight levels per *conv-like* layer (graph order, one entry per
    /// `precs` row), drawn in the layer's *resolved* weight range.
    /// Shapes: `Conv` is `[co][padded_c][f*f]`, `DepthwiseConv` is
    /// `[c][2][f*f]` (each channel's filter plus its padded zero
    /// channel's), `Dense` is `[c_out][padded_c][h*w]`.  Padded
    /// channels' weights are drawn like any other but always multiply
    /// explicit zero activations.
    pub conv_wgt: Vec<Vec<Vec<Vec<u64>>>>,
    /// FC head weight levels, `[classes][c]` (network-default W bits).
    pub fc_wgt: Vec<Vec<u64>>,
}

/// What one layer boundary of the golden network holds.
#[derive(Debug, Clone)]
pub struct GoldenTrace {
    /// Per graph layer: the layer's output values (wide conv sums,
    /// pooled sums, or the logits for the head).
    pub layer_outs: Vec<Vec<i64>>,
    pub logits: Vec<i64>,
    pub argmax: usize,
}

impl QnnNet {
    /// Derive every weight in the network from one seed (one `Gen`
    /// stream, layers in graph order).  Each conv's weights are drawn
    /// in its *resolved* precision's range (layer override or network
    /// default); out-of-range resolved precisions and overrides on the
    /// stem are rejected with the typed [`crate::qnn::GraphError`]
    /// (via `SimError::Graph`).
    pub fn from_seed(
        graph: &QnnGraph,
        precision: QnnPrecision,
        seed: u64,
    ) -> Result<QnnNet, SimError> {
        graph.validate().map_err(|e| SimError::Graph(e.to_string()))?;
        let QnnPrecision::SubByte { w_bits, .. } = precision else {
            return Err(SimError::Unsupported(
                "the dataflow executor serves sub-byte precisions (fp32 keeps the legacy cost model)",
            ));
        };
        let precs =
            graph.conv_precisions(precision).map_err(|e| SimError::Graph(e.to_string()))?;
        let mut g = Gen::new(seed);
        let mut conv_wgt = Vec::new();
        let mut fc_wgt = Vec::new();
        let mut pi = 0usize;
        for layer in &graph.layers {
            match *layer {
                LayerDesc::Conv { c_in, c_out, f, .. } => {
                    let wmax = weight_level_max(precs[pi].w_bits);
                    pi += 1;
                    let cp = padded_c(c_in);
                    conv_wgt.push(
                        (0..c_out)
                            .map(|_| {
                                (0..cp)
                                    .map(|_| g.vec_below((f * f) as usize, wmax + 1))
                                    .collect()
                            })
                            .collect(),
                    );
                }
                LayerDesc::DepthwiseConv { c, f, .. } => {
                    let wmax = weight_level_max(precs[pi].w_bits);
                    pi += 1;
                    conv_wgt.push(
                        (0..c)
                            .map(|_| {
                                (0..2)
                                    .map(|_| g.vec_below((f * f) as usize, wmax + 1))
                                    .collect()
                            })
                            .collect(),
                    );
                }
                LayerDesc::Dense { c_in, h, w, c_out, .. } => {
                    let wmax = weight_level_max(precs[pi].w_bits);
                    pi += 1;
                    let cp = padded_c(c_in);
                    conv_wgt.push(
                        (0..c_out)
                            .map(|_| {
                                (0..cp)
                                    .map(|_| g.vec_below((h * w) as usize, wmax + 1))
                                    .collect()
                            })
                            .collect(),
                    );
                }
                LayerDesc::GapFc { c, classes } => {
                    let wmax = weight_level_max(w_bits);
                    fc_wgt = (0..classes).map(|_| g.vec_below(c as usize, wmax + 1)).collect();
                }
                LayerDesc::MaxPool { .. } | LayerDesc::Add { .. } => {}
            }
        }
        Ok(QnnNet { graph: graph.clone(), precision, seed, precs, conv_wgt, fc_wgt })
    }

    /// The network-default activation level bits: the input image's
    /// range and the GAP+FC head's level domain.  Layer boundaries
    /// requantize to each *consumer's* resolved width, which may
    /// differ per layer in a mixed graph.
    pub fn a_bits(&self) -> u32 {
        match self.precision {
            QnnPrecision::SubByte { a_bits, .. } => a_bits,
            QnnPrecision::Fp32 => unreachable!("from_seed rejects fp32"),
        }
    }

    /// The canonical (non-tuned) variant assignment, one per conv-like
    /// layer: vmacsr-paper for quantized convs, int16 for the stem —
    /// what [`Self::golden_forward`] pins and what the autotuner's
    /// winner equals on Sparq.
    pub fn canonical_variants(&self) -> Vec<ConvVariant> {
        self.graph.layers.iter().filter_map(|l| variant_for(l, self.precision)).collect()
    }

    /// Input image length in levels (c * h * w).
    pub fn input_len(&self) -> usize {
        let (c, h, w) = self.graph.input;
        (c * h * w) as usize
    }

    /// A deterministic test image (levels in the A-bit range).
    pub fn test_image(&self, image_seed: u64) -> Vec<u64> {
        let amax = act_level_max(self.a_bits());
        let mut g = Gen::new(image_seed);
        g.vec_below(self.input_len(), amax + 1)
    }

    /// The exact host-side forward pass the simulated program must
    /// reproduce bit-for-bit at every layer boundary, under the
    /// *canonical* variant assignment (vmacsr-paper quantized layers,
    /// mod-2^16 int16 stem): hardware-accurate conv models, maxpool on
    /// sums, `min(amax, v >> rshift)` requantization at every boundary
    /// (each boundary at its consumer's resolved activation width),
    /// integer GAP+FC.  For a compiled network's possibly-autotuned
    /// assignment use [`Self::golden_forward_with`] /
    /// [`CompiledQnn::golden`].
    pub fn golden_forward(&self, image: &[u64]) -> Result<GoldenTrace, SimError> {
        self.golden_forward_with(image, &self.canonical_variants())
    }

    /// [`Self::golden_forward`] under an explicit per-layer variant
    /// assignment (one entry per conv-like layer, graph order): the
    /// conv model dispatches per variant — packed-vmacsr dataflow,
    /// strict-exact native ULPPACK, or wrapping int16 — through the
    /// same region plans and output-element rules the compiler bakes
    /// streams with, so the boundary requant shifts cannot diverge.
    /// The walk follows the graph's deterministic topological order,
    /// keeping one flowing state per *node* (a DAG has live branches,
    /// not one running value): the node's dense output, its worst-case
    /// value, and the activation level domain its branch carries (what
    /// a downstream residual join requantizes into).
    pub fn golden_forward_with(
        &self,
        image: &[u64],
        variants: &[ConvVariant],
    ) -> Result<GoldenTrace, SimError> {
        assert_eq!(image.len(), self.input_len(), "image length != c*h*w");
        assert_eq!(variants.len(), self.precs.len(), "one variant per conv-like layer");
        let QnnPrecision::SubByte { a_bits: a_default, .. } = self.precision else {
            return Err(SimError::Unsupported("fp32 has no integer golden network"));
        };
        let amax_default = act_level_max(a_default);

        /// One node's flowing output (mirrors the compiler's `Flow`).
        #[derive(Clone)]
        struct GNode {
            vals: Vec<u64>,
            dims: (u32, u32, u32),
            max_val: u64,
            is_levels: bool,
            /// Activation level domain of this branch (a conv's own
            /// resolved `a_bits`; joins preserve it) — what `Add`
            /// requantizes both branches into.
            domain: u32,
        }
        // out-of-range input levels clamp exactly like `execute` does
        let input_node = GNode {
            vals: image.iter().map(|&v| v.min(amax_default)).collect(),
            dims: self.graph.input,
            max_val: amax_default,
            is_levels: true,
            domain: a_default,
        };
        let order = self.graph.topo_order().map_err(|e| SimError::Graph(e.to_string()))?;
        let n = self.graph.layers.len();
        let mut nodes: Vec<Option<GNode>> = vec![None; n];
        let mut layer_outs: Vec<Vec<i64>> = vec![Vec::new(); n];
        let mut logits: Vec<i64> = Vec::new();
        let prec_ix_of =
            |li: usize| self.precs.iter().position(|p| p.layer == li).expect("precs cover layer");
        // requantize a producer's output into `a_bits` levels on entry
        // to a consumer (identity when it is already levels — the raw
        // input image, which `execute` stages pre-clamped)
        let entry_levels = |s: &GNode, a_bits: u32| -> Vec<u64> {
            if s.is_levels {
                s.vals.clone()
            } else {
                let rs = requant::rshift_for(s.max_val, a_bits);
                let amax_l = act_level_max(a_bits);
                s.vals.iter().map(|&v| requant_host(v, rs, amax_l)).collect()
            }
        };

        for &li in &order {
            let layer = &self.graph.layers[li];
            let ps = self.graph.preds_of(li);
            // clone the (first) producer's state — the graph input for
            // the root node
            let src = match ps.first() {
                Some(&p) => nodes[p].clone().expect("topo order visits producers first"),
                None => input_node.clone(),
            };
            match *layer {
                LayerDesc::Conv { c_in, c_out, h, w, f, .. } => {
                    let cix = prec_ix_of(li);
                    let p = self.precs[cix];
                    let variant = variants[cix];
                    // the boundary requantizes to THIS consumer's
                    // resolved activation width — re-derived per
                    // adjacent precision pair in a mixed graph
                    let amax_l = act_level_max(p.a_bits);
                    let levels = entry_levels(&src, p.a_bits);
                    let cp = padded_c(c_in);
                    let pad = (f - 1) / 2;
                    let (hp, wp) = (h + f - 1, w + f - 1);
                    // zero-padded act tensor, explicit zero channel(s)
                    let mut act = vec![vec![0u64; (hp * wp) as usize]; cp as usize];
                    for ch in 0..c_in as usize {
                        for r in 0..h as usize {
                            for q in 0..w as usize {
                                act[ch][(r + pad as usize) * wp as usize + q + pad as usize] =
                                    levels[(ch * h as usize + r) * w as usize + q];
                            }
                        }
                    }
                    let d = ConvDims { c: cp, h: hp, w: wp, co: c_out, fh: f, fw: f };
                    let (wb, ab) = variant.bits();
                    let wl = Workload {
                        dims: d,
                        w_bits: wb,
                        a_bits: ab,
                        act,
                        wgt: self.conv_wgt[cix].clone(),
                        act_f32: vec![],
                        wgt_f32: vec![],
                    };
                    // the hardware-accurate conv model for the chosen
                    // variant + the element the machine stores it in
                    // (from the same conv_engine rules `compile`
                    // resolves through, so the boundary rshift cannot
                    // diverge)
                    let (out, out_el) = golden_conv(&wl, variant)?;
                    let vals = out.iter().map(|&v| v as u64).collect();
                    layer_outs[li] = out;
                    nodes[li] = Some(GNode {
                        vals,
                        dims: (c_out, h, w),
                        max_val: conv_out_max(c_in, f, amax_l, weight_level_max(p.w_bits), out_el),
                        is_levels: false,
                        domain: p.a_bits,
                    });
                }
                LayerDesc::DepthwiseConv { c, h, w, f, .. } => {
                    let cix = prec_ix_of(li);
                    let p = self.precs[cix];
                    let variant = variants[cix];
                    let amax_l = act_level_max(p.a_bits);
                    let levels = entry_levels(&src, p.a_bits);
                    let pad = (f - 1) / 2;
                    let (hp, wp) = (h + f - 1, w + f - 1);
                    let d = ConvDims { c: 2, h: hp, w: wp, co: 1, fh: f, fw: f };
                    let (wb, ab) = variant.bits();
                    // C per-channel sub-convs: the channel's padded
                    // plane paired with an explicit zero channel,
                    // exactly the group the compiler lowers to
                    let mut out: Vec<i64> = Vec::with_capacity((c * h * w) as usize);
                    let mut out_el = OutElem::U16;
                    for ch in 0..c as usize {
                        let mut act = vec![vec![0u64; (hp * wp) as usize]; 2];
                        for r in 0..h as usize {
                            for q in 0..w as usize {
                                act[0][(r + pad as usize) * wp as usize + q + pad as usize] =
                                    levels[(ch * h as usize + r) * w as usize + q];
                            }
                        }
                        let wl = Workload {
                            dims: d,
                            w_bits: wb,
                            a_bits: ab,
                            act,
                            wgt: vec![self.conv_wgt[cix][ch].clone()],
                            act_f32: vec![],
                            wgt_f32: vec![],
                        };
                        let (o, el) = golden_conv(&wl, variant)?;
                        out_el = el;
                        out.extend(o);
                    }
                    let vals = out.iter().map(|&v| v as u64).collect();
                    layer_outs[li] = out;
                    nodes[li] = Some(GNode {
                        vals,
                        dims: (c, h, w),
                        // one real channel contributes per output
                        max_val: conv_out_max(1, f, amax_l, weight_level_max(p.w_bits), out_el),
                        is_levels: false,
                        domain: p.a_bits,
                    });
                }
                LayerDesc::Dense { c_in, h, w, c_out, .. } => {
                    let cix = prec_ix_of(li);
                    let p = self.precs[cix];
                    let variant = variants[cix];
                    let ConvVariant::Vmacsr { mode, .. } = variant else {
                        return Err(SimError::Unsupported(
                            "dense layers are vmacsr-only (im2col GEMM)",
                        ));
                    };
                    let amax_l = act_level_max(p.a_bits);
                    let levels = entry_levels(&src, p.a_bits);
                    let cp = padded_c(c_in);
                    let plane = (h * w) as usize;
                    // full-extent 'valid' conv: no spatial padding,
                    // explicit zero channel when c_in is odd
                    let mut act = vec![vec![0u64; plane]; cp as usize];
                    for ch in 0..c_in as usize {
                        act[ch].copy_from_slice(&levels[ch * plane..(ch + 1) * plane]);
                    }
                    let d = ConvDims { c: cp, h, w, co: c_out, fh: h, fw: w };
                    let (wb, ab) = variant.bits();
                    let wl = Workload {
                        dims: d,
                        w_bits: wb,
                        a_bits: ab,
                        act,
                        wgt: self.conv_wgt[cix].clone(),
                        act_f32: vec![],
                        wgt_f32: vec![],
                    };
                    // the GEMM's own accumulation-order mirror, NOT the
                    // direct kernel's (they wrap differently outside
                    // the overflow-free region)
                    let vals = im2col_gemm::golden_packed_gemm(&wl, wb, ab, mode).ok_or(
                        SimError::Unsupported("precision pair outside every container's region"),
                    )?;
                    layer_outs[li] = vals.iter().map(|&v| v as i64).collect();
                    nodes[li] = Some(GNode {
                        vals,
                        dims: (c_out, 1, 1),
                        max_val: dense_out_max(c_in, h * w, amax_l, weight_level_max(p.w_bits)),
                        is_levels: false,
                        domain: p.a_bits,
                    });
                }
                LayerDesc::Add { c, h, w } => {
                    let b_src =
                        nodes[ps[1]].clone().expect("topo order visits producers first");
                    // validate_for checked the branch domains agree
                    let dom = src.domain;
                    let amax_j = act_level_max(dom);
                    let ra = requant_host_shift(src.is_levels, src.max_val, dom);
                    let rb = requant_host_shift(b_src.is_levels, b_src.max_val, dom);
                    let vals: Vec<u64> = src
                        .vals
                        .iter()
                        .zip(&b_src.vals)
                        .map(|(&va, &vb)| eltwise::add_requant_host(va, ra, vb, rb, amax_j))
                        .collect();
                    layer_outs[li] = vals.iter().map(|&v| v as i64).collect();
                    nodes[li] = Some(GNode {
                        vals,
                        dims: (c, h, w),
                        max_val: 2 * amax_j,
                        is_levels: false,
                        domain: dom,
                    });
                }
                LayerDesc::MaxPool { c, h, w } => {
                    let vals: Vec<i64> = src.vals.iter().map(|&v| v as i64).collect();
                    let out = maxpool2_host(&vals, c, h, w);
                    let vals = out.iter().map(|&v| v as u64).collect();
                    layer_outs[li] = out;
                    nodes[li] = Some(GNode { vals, dims: (c, h / 2, w / 2), ..src });
                }
                LayerDesc::GapFc { c, .. } => {
                    // the head's level domain is the network default
                    let rshift = requant_host_shift(src.is_levels, src.max_val, a_default);
                    let lv: Vec<i64> = src
                        .vals
                        .iter()
                        .map(|&v| requant_host(v, rshift, amax_default) as i64)
                        .collect();
                    let hw = src.dims.1 * src.dims.2;
                    logits = gap_fc_host(&lv, c, hw, &self.fc_wgt);
                    layer_outs[li] = logits.clone();
                }
            }
        }
        let argmax = argmax_i64(&logits);
        Ok(GoldenTrace { layer_outs, logits, argmax })
    }
}

/// The host golden model of one conv layer under a concrete variant,
/// plus the output element the machine stores it in — the single
/// dispatch both [`QnnNet::golden_forward_with`] and the compiler's
/// value-range bookkeeping share.
fn golden_conv(wl: &Workload, variant: ConvVariant) -> Result<(Vec<i64>, OutElem), SimError> {
    match variant {
        // int16 wraps mod 2^16 (the stem, and the unpacked fallback)
        ConvVariant::Int16 => Ok((golden_mod(wl, 16), OutElem::U16)),
        ConvVariant::Vmacsr { w_bits, a_bits, mode } => {
            let issues = wl.dims.issues_per_output();
            let plan = region::plan_vmacsr(w_bits, a_bits, issues, mode)
                .ok_or(SimError::Unsupported("precision outside every container's region"))?;
            Ok((
                golden_packed_vmacsr(wl, plan.container, plan.spill_every),
                conv_engine::vmacsr_out_elem(plan.container, plan.spill_every, issues),
            ))
        }
        ConvVariant::Native { w_bits, a_bits } => {
            // native ULPPACK is strict-exact; the engine's
            // wide-accumulator guard forbids reductions that could wrap
            let plan = region::plan_native(w_bits, a_bits)
                .ok_or(SimError::Unsupported("precision pair not natively packable"))?;
            let out_el = conv_engine::packed_out_elem(plan.container, true);
            let bits = match out_el {
                OutElem::U16 => 16,
                _ => 32,
            };
            Ok((golden_mod(wl, bits), out_el))
        }
        ConvVariant::Fp32 => Err(SimError::Unsupported("fp32 has no integer golden network")),
    }
}

/// Element width in bits of a conv output element (the unit the graph
/// validator's boundary chain is expressed in).
fn out_bits(e: OutElem) -> u32 {
    match e {
        OutElem::U16 => 16,
        OutElem::U32 | OutElem::F32 => 32,
    }
}

/// Worst-case output value of a conv layer, capped at what its output
/// element can physically hold — the bound both the compiler's
/// `Flow::max_val` and the golden network share, so the boundary
/// requant shift is identical by construction.
fn conv_out_max(c_in: u32, f: u32, amax_in: u64, wmax: u64, out_el: OutElem) -> u64 {
    (c_in as u64 * (f * f) as u64 * amax_in * wmax).min(elem_cap(out_el))
}

/// [`conv_out_max`] for a `Dense` layer: a full-extent reduction over
/// `c_in` planes of `hw` levels each, always into u32 output elements.
fn dense_out_max(c_in: u32, hw: u32, amax_in: u64, wmax: u64) -> u64 {
    (c_in as u64 * hw as u64 * amax_in * wmax).min(elem_cap(OutElem::U32))
}

/// The autotuner pick for one conv-like layer: the fastest measured
/// candidate that (a) preserves the layer's canonical OUTPUT element
/// width — so the chain the validator checked is exactly the chain
/// that compiles, layer by layer — and (b) loads its input at a width
/// the producer's (canonical-width) output can narrow to in one
/// `vnsrl` step.  The canonical candidate itself always satisfies
/// both, so whenever it compiles (it is in every ranking) a pick
/// exists.
fn pick_chained(
    outcome: &TuneOutcome,
    d: ConvDims,
    canon_out: u32,
    prev: Option<crate::isa::Sew>,
) -> Result<ConvVariant, SimError> {
    outcome
        .ranked
        .iter()
        .find(|c| match autotune::variant_io(c.variant, d) {
            Some((in_sew, out_el)) => {
                // (plain match, not Option::is_none_or: that API needs
                // Rust 1.82 and the MSRV gate builds at 1.75)
                out_bits(out_el) == canon_out
                    && match prev {
                        None => true,
                        Some(pv) => in_sew == pv || in_sew.widened() == Some(pv),
                    }
            }
            None => false,
        })
        .map(|c| c.variant)
        .ok_or(SimError::Unsupported("no tuned conv variant chains at this layer boundary"))
}

/// Requant shift on entry to a consumer: identity for values that are
/// already levels, `rshift_for` on wide sums.
fn requant_host_shift(is_levels: bool, max_val: u64, a_bits: u32) -> u32 {
    if is_levels {
        0
    } else {
        requant::rshift_for(max_val, a_bits)
    }
}

/// One stage of the chained program.  A graph layer maps to one or two
/// stages: an optional boundary stream (zero-pad + requantize into the
/// consumer's input region) and the layer's own stream.
#[derive(Debug)]
pub struct QnnStage {
    /// Graph layer this stage's cycles are attributed to.
    pub layer: usize,
    pub kind: StageKind,
}

#[derive(Debug)]
pub enum StageKind {
    /// Inter-layer boundary: zero-fill + requantize + place.
    Boundary(StageProg),
    /// The conv layer proper (arena-compiled; its input region is the
    /// previous boundary stream's destination — the rebind).  A
    /// depthwise layer contributes C of these, one per channel.
    Conv(Box<CompiledConv>),
    Pool(StageProg),
    /// The requantizing `vadd.vv` residual join
    /// ([`crate::kernels::eltwise`]).
    Eltwise(StageProg),
    /// A `Dense` layer's im2col + packed GEMM stream
    /// ([`crate::kernels::im2col_gemm`], arena-compiled).
    Gemm(StageProg),
    GapFc(StageProg),
}

/// An emitted stream plus its pre-compiled micro-op form — carrying
/// its fused execution plan, DESIGN.md §Perf — (present whenever the
/// stream is legal for the processor — always on Sparq).
#[derive(Debug)]
pub struct StageProg {
    pub prog: Program,
    pub compiled: Option<CompiledProgram>,
}

impl QnnStage {
    /// The stage's stream + its micro-op form, whichever kind it is.
    fn parts(&self) -> (&Program, Option<&CompiledProgram>) {
        match &self.kind {
            StageKind::Conv(cc) => (&cc.prog, cc.compiled.as_ref()),
            StageKind::Boundary(p)
            | StageKind::Pool(p)
            | StageKind::Eltwise(p)
            | StageKind::Gemm(p)
            | StageKind::GapFc(p) => (&p.prog, p.compiled.as_ref()),
        }
    }

    pub fn label(&self) -> &str {
        &self.parts().0.label
    }

    pub fn is_boundary(&self) -> bool {
        matches!(self.kind, StageKind::Boundary(_))
    }

    fn run(&self, m: &mut Machine) -> Result<RunReport, SimError> {
        self.run_rebased(m, 0)
    }

    /// Run the stage against the activation slot at arena offset
    /// `base` (the batched-execution rebind; 0 = the canonical slot).
    fn run_rebased(&self, m: &mut Machine, base: u64) -> Result<RunReport, SimError> {
        match self.parts() {
            (_, Some(cp)) => m.run_compiled_rebased(cp, base),
            (prog, None) => m.run_rebased(prog, base),
        }
    }

    /// Micro-op pre-compilation happened for this stage.
    pub fn has_uops(&self) -> bool {
        self.parts().1.is_some()
    }
}

/// Where a graph layer's output lives in the arena (for the
/// bit-for-bit boundary tests).
#[derive(Debug, Clone, Copy)]
pub struct LayerTap {
    pub out: OutputRef,
}

/// Where the input image is staged.
#[derive(Debug, Clone, Copy)]
struct InputDesc {
    x_addr: u64,
    ew: u64,
    c_real: u32,
    h: u32,
    w: u32,
    hp: u32,
    wp: u32,
    pad: u32,
}

/// How the compiler assigns kernel variants to conv layers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VariantPolicy {
    /// Per-layer autotuning: the fastest measured chain-legal variant
    /// from the [`crate::kernels::autotune`] ranking (the default).
    Autotuned,
    /// Every conv runs the unpacked int16 kernel — the paper's speedup
    /// denominator as a whole network (benches only; boundaries are
    /// trivially legal at uniform E16).
    AllInt16,
}

/// The whole QNN compiled once: chained per-layer programs over one
/// planned activation arena.  Execute any number of times on pooled
/// machines; outputs and cycle counts are bit-identical per execution.
///
/// ## Batched layout (DESIGN.md §Serving)
///
/// [`Self::compile_batched`] plans the same arena but sizes the
/// machine for `batch` disjoint per-image activation *slots*: slot 0
/// is the canonical layout the streams were compiled against, slots
/// 1..B are rebased copies at multiples of [`Self::slot_stride`].  One
/// program serves all slots — [`Self::execute_batch`] stages up to B
/// images and replays every stage per slot with the addresses rebased
/// (`Machine::run_compiled_rebased`, which walks the stage's fused
/// execution plan applying the slot offset once per fused block), so
/// per-image outputs and cycles are bit-identical to a one-image
/// execution.  The per-model runtime
/// *weight*-packing scalar pass is hoisted into `preamble`, executed
/// once per batch — the amortization that makes img/s grow with B.
#[derive(Debug)]
pub struct CompiledQnn {
    pub net: QnnNet,
    pub cfg: ProcessorConfig,
    pub stages: Vec<QnnStage>,
    /// One tap per graph layer (the executed layer boundaries).
    pub taps: Vec<LayerTap>,
    pub logits: OutputRef,
    /// Simulated-DRAM bytes a machine needs for the arena (covers all
    /// `batch` slots).
    pub mem_bytes: usize,
    /// The chosen kernel variant per conv layer (graph order) — what
    /// [`Self::golden`] pins the execution against.
    pub variants: Vec<ConvVariant>,
    /// The autotune ranking each conv choice came from (`None` under
    /// a fixed [`VariantPolicy`]), for reports and bench JSON.
    pub tuned: Vec<Option<Arc<TuneOutcome>>>,
    /// Activation slots this compilation's machine holds (1 for the
    /// unbatched layout).
    pub batch: u32,
    /// Byte stride between consecutive activation slots (the aligned
    /// single-image arena footprint).
    pub slot_stride: u64,
    /// Per-batch preamble (the hoisted weight-packing scalar pass) —
    /// present only on batched compilations of packed networks.
    pub preamble: Option<StageProg>,
    input: InputDesc,
}

/// One inference through the compiled network.
pub struct QnnRun {
    pub logits: Vec<i64>,
    pub argmax: usize,
    /// Per-stage reports (boundary streams included), stage order.
    pub stage_reports: Vec<RunReport>,
}

impl QnnRun {
    pub fn total_cycles(&self) -> u64 {
        self.stage_reports.iter().map(|r| r.stats.cycles).sum()
    }
}

/// One batched execution: up to `batch` images through one program on
/// one machine.  `runs[i]` is image `i`'s per-slot result, bit-identical
/// (logits *and* cycles) to a one-image execution of the same program;
/// the preamble is the per-batch weight-pack overhead shared by all of
/// them.
pub struct QnnBatchRun {
    /// The per-batch preamble report (`None` when the compilation has
    /// no hoisted pass — e.g. an all-int16 network).
    pub preamble: Option<RunReport>,
    /// One per staged image, submission order.
    pub runs: Vec<QnnRun>,
}

impl QnnBatchRun {
    /// Cycles of the shared per-batch preamble (0 when absent).
    pub fn preamble_cycles(&self) -> u64 {
        self.preamble.as_ref().map(|r| r.stats.cycles).unwrap_or(0)
    }

    /// Total simulated cycles of the whole batch: preamble + every
    /// slot's chained stages.
    pub fn total_cycles(&self) -> u64 {
        self.preamble_cycles() + self.runs.iter().map(|r| r.total_cycles()).sum::<u64>()
    }

    /// Amortized cycles per image — strictly decreasing in the batch
    /// fill whenever a preamble exists, since per-slot cycles are
    /// batch-invariant.
    pub fn cycles_per_image(&self) -> f64 {
        self.total_cycles() as f64 / self.runs.len().max(1) as f64
    }
}

/// Largest batch the batched arena layout will plan (bounds machine
/// memory growth; serving configs validate against it).
pub const MAX_BATCH: u32 = 64;

/// One node's flowing output during compilation: its dense wide sums
/// in the arena.  The DAG walk keeps one per *layer* (branches feeding
/// a residual join stay live side by side), indexed by graph layer.
#[derive(Clone, Copy)]
struct Flow {
    addr: u64,
    sew: crate::isa::Sew,
    c: u32,
    h: u32,
    w: u32,
    max_val: u64,
    /// Activation level domain of this branch (the producing conv's
    /// resolved `a_bits`; pools inherit it, joins preserve it) — what
    /// an `Add` consumer requantizes both branches into.
    domain: u32,
}

impl CompiledQnn {
    /// Compile `net`'s graph for `cfg` with per-layer autotuning
    /// against a transient tune memo: plan the arena, compile every
    /// conv in it, and emit the boundary/pool/head streams.  Callers
    /// that compile repeatedly (serving, sweeps) should go through
    /// [`ProgramCache::get_or_compile_qnn`] /
    /// [`Self::compile_tuned`] so rankings memoize.
    pub fn compile(cfg: &ProcessorConfig, net: QnnNet) -> Result<CompiledQnn, SimError> {
        Self::compile_tuned(cfg, net, &ProgramCache::new())
    }

    /// [`Self::compile`] with autotune rankings memoized in (and
    /// served from) `cache` under their `TuneKey`s.
    pub fn compile_tuned(
        cfg: &ProcessorConfig,
        net: QnnNet,
        cache: &ProgramCache,
    ) -> Result<CompiledQnn, SimError> {
        Self::compile_policy(cfg, net, cache, VariantPolicy::Autotuned)
    }

    /// The full form: compile under an explicit [`VariantPolicy`]
    /// (unbatched layout).
    pub fn compile_policy(
        cfg: &ProcessorConfig,
        net: QnnNet,
        cache: &ProgramCache,
        policy: VariantPolicy,
    ) -> Result<CompiledQnn, SimError> {
        Self::compile_full(cfg, net, cache, policy, None, true)
    }

    /// [`Self::compile_tuned`] with the pre-liveness append-only arena
    /// placement: every buffer at a fresh offset, no dead-range reuse.
    /// Streams execute with bit-identical outputs and cycle counts to
    /// the liveness layout (timing is address-independent) — only the
    /// addresses and the arena high-water mark ([`Self::slot_stride`])
    /// differ.  Kept compilable as the baseline the arena-liveness
    /// regression tests compare against.
    pub fn compile_append_only(
        cfg: &ProcessorConfig,
        net: QnnNet,
        cache: &ProgramCache,
    ) -> Result<CompiledQnn, SimError> {
        Self::compile_full(cfg, net, cache, VariantPolicy::Autotuned, None, false)
    }

    /// Compile the network with a batch-`batch` arena: one shared
    /// program (weights baked into the streams, the weight-pack scalar
    /// pass hoisted into a per-batch preamble) over `batch` per-image
    /// activation slots.  Drive it with [`Self::execute_batch`].
    /// `batch` must be in `1..=`[`MAX_BATCH`].
    pub fn compile_batched(
        cfg: &ProcessorConfig,
        net: QnnNet,
        cache: &ProgramCache,
        batch: u32,
    ) -> Result<CompiledQnn, SimError> {
        Self::compile_full(cfg, net, cache, VariantPolicy::Autotuned, Some(batch), true)
    }

    fn compile_full(
        cfg: &ProcessorConfig,
        net: QnnNet,
        cache: &ProgramCache,
        policy: VariantPolicy,
        batch: Option<u32>,
        liveness: bool,
    ) -> Result<CompiledQnn, SimError> {
        use crate::isa::Sew;
        if let Some(b) = batch {
            if b == 0 || b > MAX_BATCH {
                return Err(SimError::Unsupported(
                    "batch size must be between 1 and MAX_BATCH (64)",
                ));
            }
        }
        net.graph
            .validate_for(cfg, net.precision)
            .map_err(|e| SimError::Graph(e.to_string()))?;
        let QnnPrecision::SubByte { w_bits, a_bits } = net.precision else {
            return Err(SimError::Unsupported("fp32 is served by the legacy cost model"));
        };
        // the network-default activation width: the image range and
        // the head's level domain (boundaries use per-layer widths)
        let amax = act_level_max(a_bits);
        let opts = EngineOpts::default();
        // Batched layouts hoist the runtime weight-pack pass out of the
        // per-slot streams, so candidates must be RANKED on slot-only
        // cycles: probing with the pack disabled measures exactly the
        // hoisted stream (the emitted instructions are identical), and
        // the distinct EngineOpts keys the memo apart from unbatched
        // rankings.  Unbatched compiles keep ranking with the pack
        // in-stream, which is what they execute.
        let tune_opts = match batch {
            Some(_) => EngineOpts { runtime_weight_pack: false, ..opts },
            None => opts,
        };
        // liveness planning: dead buffers return to the free list and
        // later stages reuse them; the append-only baseline never does
        let mut la = if liveness { LayoutAlloc::new() } else { LayoutAlloc::append_only() };
        let order =
            net.graph.topo_order().map_err(|e| SimError::Graph(e.to_string()))?;
        let n = net.graph.layers.len();
        let mut stages: Vec<QnnStage> = Vec::new();
        // per-layer results, indexed by graph position (the walk runs
        // in topo order, which may differ on a DAG)
        let mut taps: Vec<Option<LayerTap>> = vec![None; n];
        let mut variants: Vec<Option<ConvVariant>> = vec![None; net.precs.len()];
        let mut tuned: Vec<Option<Arc<TuneOutcome>>> = vec![None; net.precs.len()];
        // one flowing output per *node*: residual branches stay live
        // side by side until their join consumes them
        let mut flows: Vec<Option<Flow>> = vec![None; n];
        let mut input: Option<InputDesc> = None;
        let mut logits: Option<OutputRef> = None;
        // batched layout: weight-pack scalar slots hoisted out of the
        // conv streams into one per-batch preamble
        let mut hoisted = 0u64;
        let prec_ix_of = |li: usize| {
            net.precs
                .iter()
                .position(|p| p.layer == li)
                .expect("conv_precisions covers every conv-like layer")
        };

        for &li in &order {
            let layer = &net.graph.layers[li];
            let ps = net.graph.preds_of(li);
            let inflow: Option<Flow> =
                ps.first().map(|&p| flows[p].expect("topo order visits producers first"));
            match *layer {
                LayerDesc::Conv { c_in, c_out, h, w, f, .. } => {
                    let cix = prec_ix_of(li);
                    let p = net.precs[cix];
                    let cp = padded_c(c_in);
                    let pad = (f - 1) / 2;
                    let d = ConvDims { c: cp, h: h + f - 1, w: w + f - 1, co: c_out, fh: f, fw: f };
                    // pick the layer's kernel: the fastest measured
                    // chain-legal variant (see `pick_chained`)
                    let (variant, outcome) = match policy {
                        VariantPolicy::AllInt16 => (ConvVariant::Int16, None),
                        VariantPolicy::Autotuned => {
                            let outcome = autotune::autotune_conv(
                                cache, cfg, d, p.w_bits, p.a_bits, p.quantized, tune_opts,
                            )?;
                            let canon_out = if p.quantized {
                                crate::qnn::graph::canonical_widths(
                                    cfg,
                                    p.w_bits,
                                    p.a_bits,
                                    d.issues_per_output(),
                                )
                                .expect("validate_for admitted this layer's precision")
                                .1
                            } else {
                                16 // int16 stem: wrapping u16 sums
                            };
                            let pick =
                                pick_chained(&outcome, d, canon_out, inflow.map(|fl| fl.sew))?;
                            (pick, Some(Arc::clone(&outcome)))
                        }
                    };
                    let (wb, ab) = variant.bits();
                    let wl = Workload {
                        dims: d,
                        w_bits: wb,
                        a_bits: ab,
                        act: vec![vec![0; (d.h * d.w) as usize]; cp as usize],
                        wgt: net.conv_wgt[cix].clone(),
                        act_f32: vec![],
                        wgt_f32: vec![],
                    };
                    let (inner, label) = variant.planned_inner(&wl)?;
                    let cc = match batch {
                        Some(_) => conv_engine::compile_in_arena_hoisted(
                            cfg, &wl, inner, opts, label, &mut la, &mut hoisted,
                        )?,
                        None => {
                            conv_engine::compile_in_arena(cfg, &wl, inner, opts, label, &mut la)?
                        }
                    };
                    let (x_addr, _) = cc.input_region();
                    let ew = cc.input_elem_bytes();
                    let in_sew = match ew {
                        1 => Sew::E8,
                        2 => Sew::E16,
                        _ => Sew::E32,
                    };
                    // the analytic widths the variant was picked by must
                    // equal what the engine actually compiled
                    if let Some((vio_sew, vio_elem)) = autotune::variant_io(variant, d) {
                        debug_assert_eq!(vio_sew, in_sew, "variant_io input width diverged");
                        debug_assert_eq!(vio_elem, cc.out.elem, "variant_io output elem diverged");
                    }
                    // this consumer's resolved activation width: the
                    // boundary requant is re-derived per adjacent pair
                    let amax_l = act_level_max(p.a_bits);
                    match inflow {
                        None => {
                            // layer 0: the host stages the image here
                            input = Some(InputDesc {
                                x_addr,
                                ew,
                                c_real: c_in,
                                h,
                                w,
                                hp: d.h,
                                wp: d.w,
                                pad,
                            });
                        }
                        Some(fl) => {
                            let spec = RequantSpec {
                                src: fl.addr,
                                src_sew: fl.sew,
                                c: fl.c,
                                h: fl.h,
                                w: fl.w,
                                dst: x_addr,
                                dst_sew: in_sew,
                                c_pad: cp,
                                pad,
                                rshift: requant::rshift_for(fl.max_val, p.a_bits),
                                amax: amax_l,
                            };
                            if !(fl.sew == in_sew || in_sew.widened() == Some(fl.sew)) {
                                return Err(SimError::Unsupported(
                                    "layer boundary narrows by more than one element width",
                                ));
                            }
                            let mut a = Asm::new(format!("boundary->{}", layer.name()), cfg.vlen_bits);
                            requant::emit_requant(&mut a, &spec);
                            stages.push(boundary_stage(li, a.finish(0), cfg));
                        }
                    }
                    let out = cc.out;
                    // worst-case output value, capped at what the output
                    // element can physically hold (a wrapping int16 stem
                    // never exceeds u16::MAX, whatever the exact bound
                    // says) — this also keeps the boundary's requant
                    // shift below the wide element width for any graph
                    let max_val =
                        conv_out_max(c_in, f, amax_l, weight_level_max(p.w_bits), out.elem);
                    flows[li] = Some(Flow {
                        addr: out.addr,
                        sew: out_sew(out.elem),
                        c: c_out,
                        h,
                        w,
                        max_val,
                        domain: p.a_bits,
                    });
                    taps[li] = Some(LayerTap { out });
                    // the padded input and packed copy die once this
                    // stage has run; the output (a tap) stays live
                    let scratch = cc.scratch_regions();
                    stages.push(QnnStage { layer: li, kind: StageKind::Conv(Box::new(cc)) });
                    for (base, bytes) in scratch {
                        la.free(base, bytes);
                    }
                    variants[cix] = Some(variant);
                    tuned[cix] = outcome;
                }
                LayerDesc::MaxPool { c, h, w } => {
                    let fl = inflow.ok_or(SimError::Unsupported(
                        "the dataflow executor needs a conv before the first pool",
                    ))?;
                    let eb = fl.sew.bytes() as u64;
                    if w as u64 * eb > (cfg.vlen_bits / 8) as u64 {
                        return Err(SimError::Unsupported(
                            "pool row does not fit one vector register at M1",
                        ));
                    }
                    let out_len = (c * (h / 2) * (w / 2)) as u64;
                    let dst = la.alloc(out_len * eb, 64);
                    let mut a = Asm::new("maxpool2-vec", cfg.vlen_bits);
                    pool_fc::emit_maxpool2(&mut a, c, h, w, fl.sew, fl.addr, dst);
                    let p = stage_prog(a.finish(0), cfg);
                    stages.push(QnnStage { layer: li, kind: StageKind::Pool(p) });
                    let out = OutputRef { addr: dst, elem: out_elem(fl.sew), len: out_len as usize };
                    taps[li] = Some(LayerTap { out });
                    flows[li] = Some(Flow { addr: dst, sew: fl.sew, c, h: h / 2, w: w / 2, ..fl });
                }
                LayerDesc::Add { c, h, w } => {
                    let fa = flows[ps[0]].expect("topo order visits producers first");
                    let fb = flows[ps[1]].expect("topo order visits producers first");
                    // the common level domain both branches requantize
                    // into (validate_for checked they agree)
                    let dom = fa.domain;
                    let amax_j = act_level_max(dom);
                    let len = c as u64 * h as u64 * w as u64;
                    let dst = la.alloc(len * 2, 64);
                    let spec = eltwise::AddSpec {
                        a_src: fa.addr,
                        a_sew: fa.sew,
                        a_rshift: requant::rshift_for(fa.max_val, dom),
                        b_src: fb.addr,
                        b_sew: fb.sew,
                        b_rshift: requant::rshift_for(fb.max_val, dom),
                        amax: amax_j,
                        dst,
                        len: len as u32,
                    };
                    let mut a = Asm::new("add-join-vec", cfg.vlen_bits);
                    eltwise::emit_add_requant(&mut a, &spec);
                    stages.push(QnnStage {
                        layer: li,
                        kind: StageKind::Eltwise(stage_prog(a.finish(0), cfg)),
                    });
                    let out = OutputRef { addr: dst, elem: OutElem::U16, len: len as usize };
                    taps[li] = Some(LayerTap { out });
                    // joined levels are bounded by 2*amax; the consumer's
                    // ordinary boundary requant renormalizes them
                    flows[li] = Some(Flow {
                        addr: dst,
                        sew: Sew::E16,
                        c,
                        h,
                        w,
                        max_val: 2 * amax_j,
                        domain: dom,
                    });
                }
                LayerDesc::DepthwiseConv { c, h, w, f, .. } => {
                    let cix = prec_ix_of(li);
                    let p = net.precs[cix];
                    let fl = inflow.ok_or(SimError::Unsupported(
                        "the dataflow executor needs a conv as the first layer",
                    ))?;
                    let pad = (f - 1) / 2;
                    // C per-channel sub-convs over one padded channel
                    // pair (the real plane + an explicit zero channel),
                    // all sharing ONE autotune entry for these dims
                    let d = ConvDims { c: 2, h: h + f - 1, w: w + f - 1, co: 1, fh: f, fw: f };
                    let (variant, outcome) = match policy {
                        VariantPolicy::AllInt16 => (ConvVariant::Int16, None),
                        VariantPolicy::Autotuned => {
                            let outcome = autotune::autotune_conv(
                                cache, cfg, d, p.w_bits, p.a_bits, true, tune_opts,
                            )?;
                            let canon_out = crate::qnn::graph::canonical_widths(
                                cfg,
                                p.w_bits,
                                p.a_bits,
                                d.issues_per_output(),
                            )
                            .expect("validate_for admitted this layer's precision")
                            .1;
                            let pick = pick_chained(&outcome, d, canon_out, Some(fl.sew))?;
                            (pick, Some(Arc::clone(&outcome)))
                        }
                    };
                    let (wb, ab) = variant.bits();
                    let (in_sew, out_el) = autotune::variant_io(variant, d)
                        .ok_or(SimError::Unsupported("precision pair outside every container's region"))?;
                    if !(fl.sew == in_sew || in_sew.widened() == Some(fl.sew)) {
                        return Err(SimError::Unsupported(
                            "layer boundary narrows by more than one element width",
                        ));
                    }
                    let amax_l = act_level_max(p.a_bits);
                    let rshift = requant::rshift_for(fl.max_val, p.a_bits);
                    let plane = h as u64 * w as u64;
                    let outb = out_sew(out_el).bytes() as u64;
                    let src_eb = fl.sew.bytes() as u64;
                    // one contiguous per-channel output block: channel
                    // ch's sub-conv output is placed at its dense slot,
                    // so the layer's tap reads like any conv's
                    let out_base = la.alloc(c as u64 * plane * outb, 64);
                    let mut bnd =
                        Asm::new(format!("boundary->{}", layer.name()), cfg.vlen_bits);
                    let mut ccs: Vec<CompiledConv> = Vec::with_capacity(c as usize);
                    let mut scratch: Vec<(u64, u64)> = Vec::new();
                    for ch in 0..c {
                        let wl = Workload {
                            dims: d,
                            w_bits: wb,
                            a_bits: ab,
                            act: vec![vec![0; (d.h * d.w) as usize]; 2],
                            wgt: vec![net.conv_wgt[cix][ch as usize].clone()],
                            act_f32: vec![],
                            wgt_f32: vec![],
                        };
                        let (inner, label) = variant.planned_inner(&wl)?;
                        let cc = conv_engine::compile_in_arena_placed(
                            cfg,
                            &wl,
                            inner,
                            opts,
                            label,
                            &mut la,
                            out_base + ch as u64 * plane * outb,
                            match batch {
                                Some(_) => Some(&mut hoisted),
                                None => None,
                            },
                        )?;
                        debug_assert_eq!(cc.input_elem_bytes(), in_sew.bytes() as u64);
                        let (x_addr, _) = cc.input_region();
                        // this channel's slice of the producer plane,
                        // requantized into the sub-conv's 2-channel
                        // padded input (channel 1 stays zero-filled)
                        let spec = RequantSpec {
                            src: fl.addr + ch as u64 * plane * src_eb,
                            src_sew: fl.sew,
                            c: 1,
                            h,
                            w,
                            dst: x_addr,
                            dst_sew: in_sew,
                            c_pad: 2,
                            pad,
                            rshift,
                            amax: amax_l,
                        };
                        requant::emit_requant(&mut bnd, &spec);
                        scratch.extend(cc.scratch_regions());
                        ccs.push(cc);
                    }
                    stages.push(boundary_stage(li, bnd.finish(0), cfg));
                    for cc in ccs {
                        stages.push(QnnStage { layer: li, kind: StageKind::Conv(Box::new(cc)) });
                    }
                    for (base, bytes) in scratch {
                        la.free(base, bytes);
                    }
                    let out = OutputRef {
                        addr: out_base,
                        elem: out_el,
                        len: (c as u64 * plane) as usize,
                    };
                    taps[li] = Some(LayerTap { out });
                    flows[li] = Some(Flow {
                        addr: out_base,
                        sew: out_sew(out_el),
                        c,
                        h,
                        w,
                        // one real channel contributes per output
                        max_val: conv_out_max(1, f, amax_l, weight_level_max(p.w_bits), out_el),
                        domain: p.a_bits,
                    });
                    variants[cix] = Some(variant);
                    tuned[cix] = outcome;
                }
                LayerDesc::Dense { c_in, h, w, c_out, .. } => {
                    let cix = prec_ix_of(li);
                    let p = net.precs[cix];
                    let fl = inflow.ok_or(SimError::Unsupported(
                        "the dataflow executor needs a conv as the first layer",
                    ))?;
                    if policy == VariantPolicy::AllInt16 {
                        return Err(SimError::Unsupported(
                            "dense layers are vmacsr-only (im2col GEMM has no int16 form)",
                        ));
                    }
                    let cp = padded_c(c_in);
                    let d = ConvDims { c: cp, h, w, co: c_out, fh: h, fw: w };
                    let wl = Workload {
                        dims: d,
                        w_bits: p.w_bits,
                        a_bits: p.a_bits,
                        act: vec![vec![0; (h * w) as usize]; cp as usize],
                        wgt: net.conv_wgt[cix].clone(),
                        act_f32: vec![],
                        wgt_f32: vec![],
                    };
                    let cg = im2col_gemm::compile_in_arena(
                        cfg,
                        &wl,
                        p.w_bits,
                        p.a_bits,
                        RegionMode::Paper,
                        &opts,
                        &mut la,
                        match batch {
                            Some(_) => Some(&mut hoisted),
                            None => None,
                        },
                    )?;
                    let in_sew = cg.x_sew;
                    if !(fl.sew == in_sew || in_sew.widened() == Some(fl.sew)) {
                        return Err(SimError::Unsupported(
                            "layer boundary narrows by more than one element width",
                        ));
                    }
                    let amax_l = act_level_max(p.a_bits);
                    // boundary requant into the GEMM's unpacked landing
                    // zone: dense planes, no spatial padding, explicit
                    // zero channel when c_in is odd
                    let spec = RequantSpec {
                        src: fl.addr,
                        src_sew: fl.sew,
                        c: fl.c,
                        h: fl.h,
                        w: fl.w,
                        dst: cg.x.0,
                        dst_sew: in_sew,
                        c_pad: cp,
                        pad: 0,
                        rshift: requant::rshift_for(fl.max_val, p.a_bits),
                        amax: amax_l,
                    };
                    let mut a =
                        Asm::new(format!("boundary->{}", layer.name()), cfg.vlen_bits);
                    requant::emit_requant(&mut a, &spec);
                    stages.push(boundary_stage(li, a.finish(0), cfg));
                    let out = cg.out;
                    stages.push(QnnStage {
                        layer: li,
                        kind: StageKind::Gemm(stage_prog(cg.prog, cfg)),
                    });
                    // the landing zone, packed planes and column matrix
                    // all die with this stage; the output is a tap
                    la.free(cg.x.0, cg.x.1);
                    for (base, bytes) in cg.scratch {
                        la.free(base, bytes);
                    }
                    taps[li] = Some(LayerTap { out });
                    flows[li] = Some(Flow {
                        addr: out.addr,
                        sew: Sew::E32,
                        c: c_out,
                        h: 1,
                        w: 1,
                        max_val: dense_out_max(c_in, h * w, amax_l, weight_level_max(p.w_bits)),
                        domain: p.a_bits,
                    });
                    variants[cix] = Some(ConvVariant::Vmacsr {
                        w_bits: p.w_bits,
                        a_bits: p.a_bits,
                        mode: RegionMode::Paper,
                    });
                    tuned[cix] = None;
                }
                LayerDesc::GapFc { c, classes } => {
                    use crate::isa::Sew;
                    let fl = inflow.ok_or(SimError::Unsupported(
                        "the dataflow executor needs a conv before the head",
                    ))?;
                    if classes > 4 {
                        return Err(SimError::Unsupported(
                            "the GAP+FC head holds at most 4 logit accumulators",
                        ));
                    }
                    // boundary requant into a dense E16 level buffer
                    let hw = fl.h * fl.w;
                    if !hw.is_power_of_two() || !pool_fc::gap_fits(hw, Sew::E16, cfg.vlen_bits) {
                        return Err(SimError::Unsupported(
                            "GAP spatial extent must be a power of two fitting one register",
                        ));
                    }
                    // value-range guards: the channel sums reduce in
                    // 16-bit lanes and the logits accumulate in u32 —
                    // the golden network is exact i64, so a graph that
                    // could wrap either must not compile
                    let gap_max = hw as u64 * amax;
                    if gap_max > u16::MAX as u64 {
                        return Err(SimError::Unsupported(
                            "GAP channel sum would overflow its 16-bit lanes",
                        ));
                    }
                    if c as u64 * gap_max * weight_level_max(w_bits) > u32::MAX as u64 {
                        return Err(SimError::Unsupported(
                            "FC logits would overflow their 32-bit accumulators",
                        ));
                    }
                    let lv_addr = la.alloc(c as u64 * hw as u64 * 2, 64);
                    let spec = RequantSpec {
                        src: fl.addr,
                        src_sew: fl.sew,
                        c,
                        h: fl.h,
                        w: fl.w,
                        dst: lv_addr,
                        dst_sew: Sew::E16,
                        c_pad: c,
                        pad: 0,
                        rshift: requant::rshift_for(fl.max_val, a_bits),
                        amax,
                    };
                    let mut a = Asm::new("boundary->gap+fc", cfg.vlen_bits);
                    requant::emit_requant(&mut a, &spec);
                    stages.push(boundary_stage(li, a.finish(0), cfg));

                    let lg_addr = la.alloc(classes as u64 * 4, 64);
                    let mut a = Asm::new("gap+fc-vec", cfg.vlen_bits);
                    pool_fc::emit_gap_fc(&mut a, c, hw, Sew::E16, lv_addr, &net.fc_wgt, lg_addr);
                    let p = stage_prog(a.finish(layer.macs()), cfg);
                    stages.push(QnnStage { layer: li, kind: StageKind::GapFc(p) });
                    // the head consumed its level buffer (the logits
                    // stay live — they are the result)
                    la.free(lv_addr, c as u64 * hw as u64 * 2);
                    let out = OutputRef { addr: lg_addr, elem: OutElem::U32, len: classes as usize };
                    taps[li] = Some(LayerTap { out });
                    logits = Some(out);
                }
            }
        }

        let input = input.ok_or(SimError::Unsupported(
            "the dataflow executor needs a conv as the first layer",
        ))?;
        let logits = logits.ok_or(SimError::Unsupported(
            "the dataflow executor needs a gap+fc head as the last layer",
        ))?;
        let taps: Vec<LayerTap> =
            taps.into_iter().map(|t| t.expect("every layer leaves a tap")).collect();
        let variants: Vec<ConvVariant> = variants
            .into_iter()
            .map(|v| v.expect("every conv-like layer picked a variant"))
            .collect();
        // slot stride at the arena's strongest alignment (64), so every
        // rebased access keeps the alignment the streams were checked at
        let slot_stride = (la.brk() + 63) & !63;
        let b = batch.unwrap_or(1);
        let mem_bytes = ((la.brk() + (b as u64 - 1) * slot_stride) as usize)
            .next_power_of_two()
            .max(1 << 16);
        let preamble = match batch {
            Some(_) if hoisted > 0 => {
                debug_assert!(hoisted <= u32::MAX as u64, "weight-pack slot count overflow");
                let mut a = Asm::new("batch-preamble(weight-pack)", cfg.vlen_bits);
                a.scalar(crate::isa::ScalarKind::AddrCalc, hoisted as u32);
                Some(stage_prog(a.finish(0), cfg))
            }
            _ => None,
        };
        Ok(CompiledQnn {
            net,
            cfg: cfg.clone(),
            stages,
            taps,
            logits,
            mem_bytes,
            variants,
            tuned,
            batch: b,
            slot_stride,
            preamble,
            input,
        })
    }

    /// The golden forward pass under THIS compilation's per-layer
    /// variant choices — what the executed arena is pinned against.
    pub fn golden(&self, image: &[u64]) -> Result<GoldenTrace, SimError> {
        self.net.golden_forward_with(image, &self.variants)
    }

    /// Execute one inference: reset the machine, stage the image into
    /// layer 0's padded input region, run every chained stage, read
    /// the logits back from the arena.
    pub fn execute(&self, m: &mut Machine, image: &[u64]) -> Result<QnnRun, SimError> {
        m.reset_for(self.mem_bytes);
        self.execute_fresh(m, image)
    }

    /// [`Self::execute`] for a machine known to be freshly reset (the
    /// pooled-serving path: `MachinePool::acquire` already reset it).
    ///
    /// Runs the canonical slot only (no batch preamble); batched
    /// compilations are driven through [`Self::execute_batch`], which
    /// also accounts the shared per-batch weight-pack pass.
    pub fn execute_fresh(&self, m: &mut Machine, image: &[u64]) -> Result<QnnRun, SimError> {
        if m.cfg != self.cfg {
            return Err(SimError::Unsupported(
                "machine configuration differs from the compiled network's",
            ));
        }
        self.stage_image(m, image, 0)?;
        let mut stage_reports = Vec::with_capacity(self.stages.len());
        for st in &self.stages {
            stage_reports.push(st.run(m)?);
        }
        let logits = self.logits.read_ints(&m.mem)?;
        let argmax = argmax_i64(&logits);
        Ok(QnnRun { logits, argmax, stage_reports })
    }

    /// Stage one image into the padded layer-0 input region of the
    /// activation slot at arena offset `base`.
    fn stage_image(&self, m: &mut Machine, image: &[u64], base: u64) -> Result<(), SimError> {
        if image.len() != self.net.input_len() {
            return Err(SimError::Unsupported("image length != c*h*w"));
        }
        let d = &self.input;
        let amax = act_level_max(self.net.a_bits());
        for ch in 0..d.c_real {
            for r in 0..d.h {
                for q in 0..d.w {
                    let lv = image[((ch * d.h + r) * d.w + q) as usize].min(amax);
                    let at = base
                        + d.x_addr
                        + ((ch as u64 * d.hp as u64 + (r + d.pad) as u64) * d.wp as u64
                            + (q + d.pad) as u64)
                            * d.ew;
                    m.mem.store_uint(at, d.ew as u32, lv)?;
                }
            }
        }
        Ok(())
    }

    /// Execute one *batch*: reset the machine, stage up to
    /// [`Self::batch`] images into their activation slots, run the
    /// per-batch preamble once, then replay every chained stage per
    /// slot with rebased addresses (stage-major order, so each stage's
    /// fused execution plan stays hot across the whole batch).  Per-image
    /// logits and per-slot cycles are bit-identical to a one-image
    /// execution of the same program; the preamble cycles are paid once
    /// however full the batch is — that amortization is the batched
    /// serving throughput gain (DESIGN.md §Serving).
    pub fn execute_batch(
        &self,
        m: &mut Machine,
        images: &[Vec<u64>],
    ) -> Result<QnnBatchRun, SimError> {
        m.reset_for(self.mem_bytes);
        self.execute_batch_fresh(m, images)
    }

    /// [`Self::execute_batch`] for a machine known to be freshly reset
    /// (the pooled-serving path: `MachinePool::acquire` already reset
    /// it).
    pub fn execute_batch_fresh(
        &self,
        m: &mut Machine,
        images: &[Vec<u64>],
    ) -> Result<QnnBatchRun, SimError> {
        if m.cfg != self.cfg {
            return Err(SimError::Unsupported(
                "machine configuration differs from the compiled network's",
            ));
        }
        if images.is_empty() || images.len() > self.batch as usize {
            return Err(SimError::Unsupported(
                "batch must stage between 1 and the compiled batch size images",
            ));
        }
        for (slot, image) in images.iter().enumerate() {
            self.stage_image(m, image, slot as u64 * self.slot_stride)?;
        }
        let preamble = match &self.preamble {
            Some(p) => Some(match &p.compiled {
                Some(cp) => m.run_compiled(cp)?,
                None => m.run(&p.prog)?,
            }),
            None => None,
        };
        let mut reports: Vec<Vec<RunReport>> =
            images.iter().map(|_| Vec::with_capacity(self.stages.len())).collect();
        for st in &self.stages {
            for (slot, per_slot) in reports.iter_mut().enumerate() {
                per_slot.push(st.run_rebased(m, slot as u64 * self.slot_stride)?);
            }
        }
        let mut runs = Vec::with_capacity(images.len());
        for (slot, stage_reports) in reports.into_iter().enumerate() {
            let out = OutputRef {
                addr: self.logits.addr + slot as u64 * self.slot_stride,
                ..self.logits
            };
            let logits = out.read_ints(&m.mem)?;
            let argmax = argmax_i64(&logits);
            runs.push(QnnRun { logits, argmax, stage_reports });
        }
        Ok(QnnBatchRun { preamble, runs })
    }

    /// Read graph layer `li`'s executed output back from the arena
    /// (after an `execute` on `m`) — the boundary the golden network
    /// pins bit-for-bit.
    pub fn read_tap(&self, m: &Machine, li: usize) -> Result<Vec<i64>, SimError> {
        self.read_tap_slot(m, li, 0)
    }

    /// [`Self::read_tap`] against activation slot `slot` of a batched
    /// execution.
    pub fn read_tap_slot(&self, m: &Machine, li: usize, slot: u32) -> Result<Vec<i64>, SimError> {
        let t = self.taps[li].out;
        let out = OutputRef { addr: t.addr + slot as u64 * self.slot_stride, ..t };
        out.read_ints(&m.mem)
    }

    /// Aggregate a run's stage reports into per-graph-layer cycles
    /// (boundary streams count toward their consumer layer, exactly
    /// like the runtime packing passes count toward their conv).
    pub fn layer_cycles(&self, run: &QnnRun) -> Vec<super::schedule::LayerCycles> {
        let mut rows: Vec<super::schedule::LayerCycles> = self
            .net
            .graph
            .layers
            .iter()
            .map(|l| super::schedule::LayerCycles {
                name: l.name(),
                cycles: 0,
                macs: l.macs(),
                variant: String::new(),
            })
            .collect();
        for (st, rep) in self.stages.iter().zip(&run.stage_reports) {
            rows[st.layer].cycles += rep.stats.cycles;
            if !st.is_boundary() {
                rows[st.layer].variant = rep.label.clone();
            }
        }
        rows
    }
}

/// Tie-breaking matches `coordinator::argmax` (last maximum wins), so
/// a served classification and the golden argmax can never disagree on
/// equal logits.
pub fn argmax_i64(xs: &[i64]) -> usize {
    xs.iter().enumerate().max_by(|a, b| a.1.cmp(b.1)).map(|(i, _)| i).unwrap_or(0)
}

fn stage_prog(prog: Program, cfg: &ProcessorConfig) -> StageProg {
    let compiled = CompiledProgram::compile(&prog, cfg).ok();
    StageProg { prog, compiled }
}

fn boundary_stage(layer: usize, prog: Program, cfg: &ProcessorConfig) -> QnnStage {
    QnnStage { layer, kind: StageKind::Boundary(stage_prog(prog, cfg)) }
}

/// Largest value an output element can hold — the cap both the
/// compiler's `Flow::max_val` and the golden network's bound share.
fn elem_cap(e: OutElem) -> u64 {
    match e {
        OutElem::U16 => u16::MAX as u64,
        OutElem::U32 | OutElem::F32 => u32::MAX as u64,
    }
}

fn out_sew(e: OutElem) -> crate::isa::Sew {
    match e {
        OutElem::U16 => crate::isa::Sew::E16,
        OutElem::U32 | OutElem::F32 => crate::isa::Sew::E32,
    }
}

fn out_elem(s: crate::isa::Sew) -> OutElem {
    match s {
        crate::isa::Sew::E16 => OutElem::U16,
        _ => OutElem::U32,
    }
}

/// Which container a quantized layer of this net runs in (diagnostic,
/// used by the benches' labels).
pub fn container_for(precision: QnnPrecision, issues: u64) -> Option<Container> {
    match precision {
        QnnPrecision::SubByte { w_bits, a_bits } => {
            region::plan_vmacsr(w_bits, a_bits, issues, crate::ulppack::RegionMode::Paper)
                .map(|p| p.container)
        }
        QnnPrecision::Fp32 => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::MachinePool;

    fn w2a2() -> QnnPrecision {
        QnnPrecision::SubByte { w_bits: 2, a_bits: 2 }
    }

    #[test]
    fn compiles_and_runs_the_sparq_cnn() {
        let net = QnnNet::from_seed(&QnnGraph::sparq_cnn(), w2a2(), 0xABCD).unwrap();
        let cq = CompiledQnn::compile(&ProcessorConfig::sparq(), net).unwrap();
        assert_eq!(cq.taps.len(), cq.net.graph.layers.len());
        let image = cq.net.test_image(7);
        let mut m = Machine::new(cq.cfg.clone(), cq.mem_bytes);
        let run = cq.execute(&mut m, &image).unwrap();
        assert_eq!(run.logits.len(), 4);
        assert!(run.total_cycles() > 0);
        // every stage stream pre-compiled to micro-ops on Sparq
        assert!(cq.stages.iter().all(|s| s.has_uops()));
    }

    #[test]
    fn executed_boundaries_match_the_golden_network() {
        let net = QnnNet::from_seed(&QnnGraph::sparq_cnn(), w2a2(), 0x5EED_CAFE).unwrap();
        let cq = CompiledQnn::compile(&ProcessorConfig::sparq(), net).unwrap();
        let image = cq.net.test_image(42);
        let golden = cq.net.golden_forward(&image).unwrap();
        let mut m = Machine::new(cq.cfg.clone(), cq.mem_bytes);
        let run = cq.execute(&mut m, &image).unwrap();
        for li in 0..cq.net.graph.layers.len() {
            assert_eq!(
                cq.read_tap(&m, li).unwrap(),
                golden.layer_outs[li],
                "layer {li} ({}) diverged",
                cq.net.graph.layers[li].name()
            );
        }
        assert_eq!(run.logits, golden.logits);
        assert_eq!(run.argmax, golden.argmax);
    }

    #[test]
    fn repeated_execution_is_bit_identical_on_pooled_machines() {
        let net = QnnNet::from_seed(&QnnGraph::sparq_cnn(), w2a2(), 1).unwrap();
        let cq = CompiledQnn::compile(&ProcessorConfig::sparq(), net).unwrap();
        let pool = MachinePool::new();
        let image = cq.net.test_image(3);
        let mut m = pool.acquire(&cq.cfg, cq.mem_bytes);
        let a = cq.execute_fresh(&mut m, &image).unwrap();
        pool.release(m);
        let mut m = pool.acquire(&cq.cfg, cq.mem_bytes);
        let b = cq.execute_fresh(&mut m, &image).unwrap();
        pool.release(m);
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.total_cycles(), b.total_cycles());
        assert_eq!(pool.stats().reused, 1);
    }

    #[test]
    fn fp32_and_invalid_graphs_are_rejected() {
        let g = QnnGraph::sparq_cnn();
        assert!(matches!(
            QnnNet::from_seed(&g, QnnPrecision::Fp32, 1),
            Err(SimError::Unsupported(_))
        ));
        let mut bad = g.clone();
        bad.input = (3, 16, 16);
        assert!(matches!(QnnNet::from_seed(&bad, w2a2(), 1), Err(SimError::Graph(_))));
    }

    #[test]
    fn different_seeds_give_different_weights_same_seed_identical() {
        let g = QnnGraph::sparq_cnn();
        let a = QnnNet::from_seed(&g, w2a2(), 10).unwrap();
        let b = QnnNet::from_seed(&g, w2a2(), 10).unwrap();
        let c = QnnNet::from_seed(&g, w2a2(), 11).unwrap();
        assert_eq!(a.conv_wgt, b.conv_wgt);
        assert_eq!(a.fc_wgt, b.fc_wgt);
        assert_ne!(a.conv_wgt, c.conv_wgt);
    }

    #[test]
    fn autotuned_choice_is_the_canonical_vmacsr_on_sparq() {
        // on Sparq the measured winner per layer must be the canonical
        // vmacsr-paper assignment (the golden_forward default), so the
        // plain golden network keeps pinning autotuned compilations
        let net = QnnNet::from_seed(&QnnGraph::sparq_cnn(), w2a2(), 3).unwrap();
        let canonical = net.canonical_variants();
        let cq = CompiledQnn::compile(&ProcessorConfig::sparq(), net).unwrap();
        assert_eq!(cq.variants, canonical);
        // the quantized layers carry full rankings (4 candidates
        // measured or rejected), the stem a single-int16 one
        assert_eq!(cq.tuned.len(), 3);
        let stem = cq.tuned[0].as_ref().unwrap();
        assert_eq!(stem.ranked.len(), 1);
        for t in &cq.tuned[1..] {
            let t = t.as_ref().unwrap();
            assert_eq!(t.ranked.len() + t.rejected.len(), 4);
            assert!(t.ranked.len() >= 2, "vmacsr + at least one fallback must run");
        }
    }

    #[test]
    fn mixed_precision_weights_follow_the_per_layer_resolution() {
        let g = QnnGraph::sparq_cnn_mixed((4, 4), (2, 2));
        let net = QnnNet::from_seed(&g, w2a2(), 5).unwrap();
        // stem at 8-bit weights, conv2 at W4 levels (<= 14), conv3 at
        // W2 levels (<= 2)
        assert_eq!(net.precs.len(), 3);
        let max_of = |t: &[Vec<Vec<u64>>]| {
            t.iter().flatten().flatten().copied().max().unwrap()
        };
        assert!(max_of(&net.conv_wgt[1]) > 2, "W4 weights must use the wider range");
        assert!(max_of(&net.conv_wgt[1]) <= 14);
        assert!(max_of(&net.conv_wgt[2]) <= 2);
    }

    #[test]
    fn mixed_network_executes_and_matches_its_golden() {
        let g = QnnGraph::sparq_cnn_mixed((4, 4), (2, 2));
        let net = QnnNet::from_seed(&g, w2a2(), 0x31BED).unwrap();
        let cq = CompiledQnn::compile(&ProcessorConfig::sparq(), net).unwrap();
        let image = cq.net.test_image(11);
        let golden = cq.golden(&image).unwrap();
        let mut m = Machine::new(cq.cfg.clone(), cq.mem_bytes);
        let run = cq.execute(&mut m, &image).unwrap();
        for li in 0..cq.net.graph.layers.len() {
            assert_eq!(cq.read_tap(&m, li).unwrap(), golden.layer_outs[li], "layer {li}");
        }
        assert_eq!(run.logits, golden.logits);
    }

    #[test]
    fn batched_compile_lays_out_aligned_slots_and_hoists_weight_packing() {
        let cache = ProgramCache::new();
        let net = QnnNet::from_seed(&QnnGraph::sparq_cnn(), w2a2(), 2).unwrap();
        let cq = CompiledQnn::compile_batched(&ProcessorConfig::sparq(), net, &cache, 4).unwrap();
        assert_eq!(cq.batch, 4);
        assert_eq!(cq.slot_stride % 64, 0, "slots must keep the arena alignment");
        assert!(cq.mem_bytes as u64 >= 3 * cq.slot_stride, "memory must cover every slot");
        // the quantized convs carry runtime weight packing, so the
        // batched layout must have hoisted it into a preamble
        let p = cq.preamble.as_ref().expect("packed network must hoist a preamble");
        assert!(p.compiled.is_some());
        // per-slot conv streams no longer bill the pack pass, the
        // preamble does: a batch of 4 pays it once
        let images: Vec<Vec<u64>> = (0..4).map(|i| cq.net.test_image(i)).collect();
        let mut m = Machine::new(cq.cfg.clone(), cq.mem_bytes);
        let run = cq.execute_batch(&mut m, &images).unwrap();
        assert!(run.preamble_cycles() > 0);
        assert_eq!(run.runs.len(), 4);
        // batch-size bounds are typed errors
        assert!(cq.execute_batch(&mut m, &[]).is_err());
        let five: Vec<Vec<u64>> = (0..5).map(|i| cq.net.test_image(i)).collect();
        assert!(cq.execute_batch(&mut m, &five).is_err());
        assert!(matches!(
            CompiledQnn::compile_batched(
                &ProcessorConfig::sparq(),
                QnnNet::from_seed(&QnnGraph::sparq_cnn(), w2a2(), 2).unwrap(),
                &cache,
                0,
            ),
            Err(SimError::Unsupported(_))
        ));
    }

    #[test]
    fn batched_slots_match_the_golden_network_and_one_image_runs() {
        // every slot of a full batch pins bit-for-bit against the
        // golden network AND against a singleton batch of the same
        // image — outputs and per-slot cycles
        let cache = ProgramCache::new();
        let net = QnnNet::from_seed(&QnnGraph::sparq_cnn(), w2a2(), 0xBA7C).unwrap();
        let cq = CompiledQnn::compile_batched(&ProcessorConfig::sparq(), net, &cache, 4).unwrap();
        let images: Vec<Vec<u64>> = (0..4).map(|i| cq.net.test_image(100 + i)).collect();
        let mut m = Machine::new(cq.cfg.clone(), cq.mem_bytes);
        let batch = cq.execute_batch(&mut m, &images).unwrap();
        for (slot, img) in images.iter().enumerate() {
            let golden = cq.golden(img).unwrap();
            assert_eq!(batch.runs[slot].logits, golden.logits, "slot {slot} logits");
            // slots are disjoint arena regions, so every slot's layer
            // taps coexist after the batch run
            for li in 0..cq.net.graph.layers.len() {
                assert_eq!(
                    cq.read_tap_slot(&m, li, slot as u32).unwrap(),
                    golden.layer_outs[li],
                    "slot {slot} layer {li}"
                );
            }
        }
        // singleton batches: identical per-slot cycles and logits
        let mut total_single = 0u64;
        for (slot, img) in images.iter().enumerate() {
            let mut m1 = Machine::new(cq.cfg.clone(), cq.mem_bytes);
            let one = cq.execute_batch(&mut m1, std::slice::from_ref(img)).unwrap();
            assert_eq!(one.runs[0].logits, batch.runs[slot].logits);
            assert_eq!(
                one.runs[0].total_cycles(),
                batch.runs[slot].total_cycles(),
                "slot {slot} cycles diverged from the singleton run"
            );
            total_single += one.total_cycles();
        }
        // exact amortization: the batch saves (B-1) preambles
        assert_eq!(batch.total_cycles() + 3 * batch.preamble_cycles(), total_single);
    }

    #[test]
    fn dag_networks_execute_and_pin_their_golden_boundaries() {
        // the three DAG topologies — residual join, depthwise +
        // pointwise, dense head — compile to one chained program and
        // pin bit-for-bit at EVERY node boundary, exactly like the
        // straight chain does
        for graph in
            [QnnGraph::sparq_resnetlike(), QnnGraph::sparq_mobilenetlike(), QnnGraph::sparq_denselike()]
        {
            let net = QnnNet::from_seed(&graph, w2a2(), 0xDA6).unwrap();
            let cq = CompiledQnn::compile(&ProcessorConfig::sparq(), net).unwrap();
            assert_eq!(cq.taps.len(), cq.net.graph.layers.len());
            assert!(cq.stages.iter().all(|s| s.has_uops()));
            let image = cq.net.test_image(17);
            let golden = cq.golden(&image).unwrap();
            let mut m = Machine::new(cq.cfg.clone(), cq.mem_bytes);
            let run = cq.execute(&mut m, &image).unwrap();
            for li in 0..cq.net.graph.layers.len() {
                assert_eq!(
                    cq.read_tap(&m, li).unwrap(),
                    golden.layer_outs[li],
                    "layer {li} ({}) diverged",
                    cq.net.graph.layers[li].name()
                );
            }
            assert_eq!(run.logits, golden.logits);
            assert_eq!(run.argmax, golden.argmax);
            assert!(run.total_cycles() > 0);
        }
    }

    #[test]
    fn liveness_reuses_dead_ranges_without_changing_outputs_or_cycles() {
        // the arena-liveness regression: against the append-only
        // baseline placement, the free-list layout (a) never needs MORE
        // arena bytes, (b) strictly shrinks the residual net (its
        // freed conv scratch is big enough for later stages to land
        // in), and (c) leaves outputs AND per-stage cycles bit-identical
        // everywhere — timing is address-independent
        let cache = ProgramCache::new();
        let cfg = ProcessorConfig::sparq();
        let nets = [
            ("chain", QnnGraph::sparq_cnn()),
            ("resnetlike", QnnGraph::sparq_resnetlike()),
            ("mobilenetlike", QnnGraph::sparq_mobilenetlike()),
            ("denselike", QnnGraph::sparq_denselike()),
        ];
        for (name, graph) in nets {
            let live = CompiledQnn::compile_tuned(
                &cfg,
                QnnNet::from_seed(&graph, w2a2(), 0x11FE).unwrap(),
                &cache,
            )
            .unwrap();
            let ao = CompiledQnn::compile_append_only(
                &cfg,
                QnnNet::from_seed(&graph, w2a2(), 0x11FE).unwrap(),
                &cache,
            )
            .unwrap();
            assert!(
                live.slot_stride <= ao.slot_stride,
                "{name}: liveness grew the arena ({} > {})",
                live.slot_stride,
                ao.slot_stride
            );
            if name == "resnetlike" {
                assert!(
                    live.slot_stride < ao.slot_stride,
                    "resnetlike must strictly shrink under liveness ({} vs {})",
                    live.slot_stride,
                    ao.slot_stride
                );
            }
            let image = live.net.test_image(23);
            let mut m = Machine::new(cfg.clone(), live.mem_bytes);
            let lr = live.execute(&mut m, &image).unwrap();
            let mut m = Machine::new(cfg.clone(), ao.mem_bytes);
            let ar = ao.execute(&mut m, &image).unwrap();
            assert_eq!(lr.logits, ar.logits, "{name}: logits diverged");
            let lc: Vec<u64> = lr.stage_reports.iter().map(|r| r.stats.cycles).collect();
            let ac: Vec<u64> = ar.stage_reports.iter().map(|r| r.stats.cycles).collect();
            assert_eq!(lc, ac, "{name}: per-stage cycles diverged under address reuse");
        }
    }

    #[test]
    fn all_int16_policy_compiles_and_is_slower() {
        let cache = ProgramCache::new();
        let cfg = ProcessorConfig::sparq();
        let tuned = CompiledQnn::compile_tuned(
            &cfg,
            QnnNet::from_seed(&QnnGraph::sparq_cnn(), w2a2(), 9).unwrap(),
            &cache,
        )
        .unwrap();
        let int16 = CompiledQnn::compile_policy(
            &cfg,
            QnnNet::from_seed(&QnnGraph::sparq_cnn(), w2a2(), 9).unwrap(),
            &cache,
            VariantPolicy::AllInt16,
        )
        .unwrap();
        assert!(int16.variants.iter().all(|v| matches!(v, ConvVariant::Int16)));
        let image = tuned.net.test_image(2);
        let mut m = Machine::new(cfg.clone(), tuned.mem_bytes);
        let fast = tuned.execute(&mut m, &image).unwrap();
        let mut m = Machine::new(cfg.clone(), int16.mem_bytes);
        let slow = int16.execute(&mut m, &image).unwrap();
        // both pin against their own golden, and the autotuned network
        // is strictly faster than the all-int16 denominator
        assert_eq!(slow.logits, int16.golden(&image).unwrap().logits);
        assert!(fast.total_cycles() < slow.total_cycles());
    }
}
