//! `CompiledQnn` — the whole network compiled once into a chained
//! multi-layer program over a single planned activation arena
//! (DESIGN.md §Dataflow).
//!
//! Before this refactor, `qnn::schedule` was only a cost model: every
//! conv layer ran on an independent random tensor, activations never
//! flowed layer to layer, and maxpool/GAP+FC cycles were a fabricated
//! bytes/cycle formula.  Now:
//!
//! * A layout planner walks the shape-chained graph and allocates one
//!   arena with the same bump-allocator discipline the conv engine
//!   uses — each conv's padded input buffer, packed-copy buffer and
//!   wide output buffer, plus the pool/requant/logits buffers, are
//!   fixed addresses baked into every stream.
//! * Each conv layer is a [`CompiledConv`] compiled *in the arena*
//!   (`conv_engine::compile_in_arena`) whose input region is exactly
//!   where the previous layer's requantize stream writes — inputs
//!   rebind to the previous layer's output region, not to host-staged
//!   tensors.
//! * Layer boundaries are real instruction streams: zero-padding and
//!   requantize+narrow via [`crate::kernels::requant`], maxpool and
//!   GAP+FC via [`crate::kernels::pool_fc`].  Nothing is estimated.
//! * The compiled network is cached whole in
//!   [`crate::kernels::ProgramCache`] under a graph-level key
//!   (processor + layers + precision + weight seed).
//!
//! Exactness contract: [`QnnNet::golden_forward`] is the host-side
//! golden network; every layer boundary of an executed inference
//! matches it bit-for-bit (`rust/tests/qnn_dataflow.rs`), and repeated
//! executions produce identical outputs *and* cycle counts.

use crate::arch::ProcessorConfig;
use crate::kernels::conv_engine::{self, LayoutAlloc};
use crate::kernels::pool_fc::{self, gap_fc_host, maxpool2_host};
use crate::kernels::requant::{self, requant_host, RequantSpec};
use crate::kernels::workload::{golden_mod, golden_packed_vmacsr, ConvDims, OutElem, OutputRef, Workload};
use crate::kernels::{asm::Asm, CompiledConv, EngineOpts};
use crate::qnn::graph::{padded_c, LayerDesc, QnnGraph};
use crate::qnn::schedule::{variant_for, QnnPrecision};
use crate::sim::{CompiledProgram, Machine, Program, RunReport, SimError};
use crate::testutil::Gen;
use crate::ulppack::{act_level_max, region, weight_level_max, Container};

/// Host-side network: the graph plus every weight tensor, all derived
/// from ONE graph-level seed (recorded in `QnnSchedule` for
/// reproducibility — no more per-layer `0x5EED + li` scatter).
#[derive(Debug, Clone)]
pub struct QnnNet {
    pub graph: QnnGraph,
    pub precision: QnnPrecision,
    pub seed: u64,
    /// Conv weight levels per *conv* layer (graph order), shaped
    /// `[co][padded_c][f*f]`; the padded channel's weights are drawn
    /// like any other but always multiply explicit zero activations.
    pub conv_wgt: Vec<Vec<Vec<Vec<u64>>>>,
    /// FC head weight levels, `[classes][c]`.
    pub fc_wgt: Vec<Vec<u64>>,
}

/// What one layer boundary of the golden network holds.
#[derive(Debug, Clone)]
pub struct GoldenTrace {
    /// Per graph layer: the layer's output values (wide conv sums,
    /// pooled sums, or the logits for the head).
    pub layer_outs: Vec<Vec<i64>>,
    pub logits: Vec<i64>,
    pub argmax: usize,
}

impl QnnNet {
    /// Derive every weight in the network from one seed (one `Gen`
    /// stream, layers in graph order).
    pub fn from_seed(
        graph: &QnnGraph,
        precision: QnnPrecision,
        seed: u64,
    ) -> Result<QnnNet, SimError> {
        graph.validate().map_err(|e| SimError::Graph(e.to_string()))?;
        let QnnPrecision::SubByte { w_bits, .. } = precision else {
            return Err(SimError::Unsupported(
                "the dataflow executor serves sub-byte precisions (fp32 keeps the legacy cost model)",
            ));
        };
        let mut g = Gen::new(seed);
        let mut conv_wgt = Vec::new();
        let mut fc_wgt = Vec::new();
        for layer in &graph.layers {
            match *layer {
                LayerDesc::Conv { c_in, c_out, f, quantized, .. } => {
                    let wmax = if quantized { weight_level_max(w_bits) } else { weight_level_max(8) };
                    let cp = padded_c(c_in);
                    conv_wgt.push(
                        (0..c_out)
                            .map(|_| {
                                (0..cp)
                                    .map(|_| g.vec_below((f * f) as usize, wmax + 1))
                                    .collect()
                            })
                            .collect(),
                    );
                }
                LayerDesc::GapFc { c, classes } => {
                    let wmax = weight_level_max(w_bits);
                    fc_wgt = (0..classes).map(|_| g.vec_below(c as usize, wmax + 1)).collect();
                }
                LayerDesc::MaxPool { .. } => {}
            }
        }
        Ok(QnnNet { graph: graph.clone(), precision, seed, conv_wgt, fc_wgt })
    }

    /// Activation level bits (uniform across layer boundaries).
    pub fn a_bits(&self) -> u32 {
        match self.precision {
            QnnPrecision::SubByte { a_bits, .. } => a_bits,
            QnnPrecision::Fp32 => unreachable!("from_seed rejects fp32"),
        }
    }

    /// Input image length in levels (c * h * w).
    pub fn input_len(&self) -> usize {
        let (c, h, w) = self.graph.input;
        (c * h * w) as usize
    }

    /// A deterministic test image (levels in the A-bit range).
    pub fn test_image(&self, image_seed: u64) -> Vec<u64> {
        let amax = act_level_max(self.a_bits());
        let mut g = Gen::new(image_seed);
        g.vec_below(self.input_len(), amax + 1)
    }

    /// The exact host-side forward pass the simulated program must
    /// reproduce bit-for-bit at every layer boundary: hardware-accurate
    /// conv models (mod-2^16 int16 stem, packed-vmacsr dataflow for
    /// quantized layers), maxpool on sums, `min(amax, v >> rshift)`
    /// requantization at every boundary, integer GAP+FC.
    pub fn golden_forward(&self, image: &[u64]) -> Result<GoldenTrace, SimError> {
        assert_eq!(image.len(), self.input_len(), "image length != c*h*w");
        let QnnPrecision::SubByte { w_bits, a_bits } = self.precision else {
            return Err(SimError::Unsupported("fp32 has no integer golden network"));
        };
        let amax = act_level_max(a_bits);
        let (c0, h0, w0) = self.graph.input;

        // the flowing value: dense levels (conv inputs are re-padded
        // per layer) or dense sums; bookkeeping mirrors the compiler.
        // Out-of-range input levels clamp exactly like `execute` does.
        let mut levels: Vec<u64> = image.iter().map(|&v| v.min(amax)).collect();
        let mut dims = (c0, h0, w0);
        let mut max_val = amax;
        let mut is_levels = true;
        let mut conv_ix = 0usize;
        let mut layer_outs = Vec::new();
        let mut logits: Vec<i64> = Vec::new();

        for layer in &self.graph.layers {
            match *layer {
                LayerDesc::Conv { c_in, c_out, h, w, f, quantized } => {
                    if !is_levels {
                        // boundary requant happens on entry to a conv
                        levels = levels.iter().map(|&v| requant_host(v, requant::rshift_for(max_val, a_bits), amax)).collect();
                        is_levels = true;
                    }
                    let cp = padded_c(c_in);
                    let pad = (f - 1) / 2;
                    let (hp, wp) = (h + f - 1, w + f - 1);
                    // zero-padded act tensor, explicit zero channel(s)
                    let mut act = vec![vec![0u64; (hp * wp) as usize]; cp as usize];
                    for ch in 0..c_in as usize {
                        for r in 0..h as usize {
                            for q in 0..w as usize {
                                act[ch][(r + pad as usize) * wp as usize + q + pad as usize] =
                                    levels[(ch * h as usize + r) * w as usize + q];
                            }
                        }
                    }
                    let d = ConvDims { c: cp, h: hp, w: wp, co: c_out, fh: f, fw: f };
                    let (wb, ab) = if quantized { (w_bits, a_bits) } else { (8, a_bits) };
                    let wl = Workload {
                        dims: d,
                        w_bits: wb,
                        a_bits: ab,
                        act,
                        wgt: self.conv_wgt[conv_ix].clone(),
                        act_f32: vec![],
                        wgt_f32: vec![],
                    };
                    // the hardware-accurate conv model + the element the
                    // machine stores it in (the latter from the same
                    // conv_engine helper `compile` resolves through, so
                    // the boundary rshift cannot diverge)
                    let (out, out_el) = if quantized {
                        let plan = region::plan_vmacsr(
                            w_bits,
                            a_bits,
                            d.issues_per_output(),
                            crate::ulppack::RegionMode::Paper,
                        )
                        .ok_or(SimError::Unsupported("precision outside every container's region"))?;
                        (
                            golden_packed_vmacsr(&wl, plan.container, plan.spill_every),
                            conv_engine::vmacsr_out_elem(
                                plan.container,
                                plan.spill_every,
                                d.issues_per_output(),
                            ),
                        )
                    } else {
                        // the int16 stem wraps mod 2^16
                        (golden_mod(&wl, 16), OutElem::U16)
                    };
                    layer_outs.push(out.clone());
                    levels = out.iter().map(|&v| v as u64).collect();
                    dims = (c_out, h, w);
                    max_val = (c_in as u64
                        * (f * f) as u64
                        * amax
                        * if quantized { weight_level_max(w_bits) } else { weight_level_max(8) })
                    .min(elem_cap(out_el));
                    is_levels = false;
                    conv_ix += 1;
                }
                LayerDesc::MaxPool { c, h, w } => {
                    let vals: Vec<i64> = levels.iter().map(|&v| v as i64).collect();
                    let out = maxpool2_host(&vals, c, h, w);
                    layer_outs.push(out.clone());
                    levels = out.iter().map(|&v| v as u64).collect();
                    dims = (c, h / 2, w / 2);
                }
                LayerDesc::GapFc { c, .. } => {
                    let rshift = requant_host_shift(is_levels, max_val, a_bits);
                    let lv: Vec<i64> = levels
                        .iter()
                        .map(|&v| requant_host(v, rshift, amax) as i64)
                        .collect();
                    let hw = dims.1 * dims.2;
                    logits = gap_fc_host(&lv, c, hw, &self.fc_wgt);
                    layer_outs.push(logits.clone());
                }
            }
        }
        let argmax = argmax_i64(&logits);
        Ok(GoldenTrace { layer_outs, logits, argmax })
    }
}

/// Requant shift on entry to a consumer: identity for values that are
/// already levels, `rshift_for` on wide sums.
fn requant_host_shift(is_levels: bool, max_val: u64, a_bits: u32) -> u32 {
    if is_levels {
        0
    } else {
        requant::rshift_for(max_val, a_bits)
    }
}

/// One stage of the chained program.  A graph layer maps to one or two
/// stages: an optional boundary stream (zero-pad + requantize into the
/// consumer's input region) and the layer's own stream.
#[derive(Debug)]
pub struct QnnStage {
    /// Graph layer this stage's cycles are attributed to.
    pub layer: usize,
    pub kind: StageKind,
}

#[derive(Debug)]
pub enum StageKind {
    /// Inter-layer boundary: zero-fill + requantize + place.
    Boundary(StageProg),
    /// The conv layer proper (arena-compiled; its input region is the
    /// previous boundary stream's destination — the rebind).
    Conv(Box<CompiledConv>),
    Pool(StageProg),
    GapFc(StageProg),
}

/// An emitted stream plus its pre-compiled micro-op form (present
/// whenever the stream is legal for the processor — always on Sparq).
#[derive(Debug)]
pub struct StageProg {
    pub prog: Program,
    pub compiled: Option<CompiledProgram>,
}

impl QnnStage {
    /// The stage's stream + its micro-op form, whichever kind it is.
    fn parts(&self) -> (&Program, Option<&CompiledProgram>) {
        match &self.kind {
            StageKind::Conv(cc) => (&cc.prog, cc.compiled.as_ref()),
            StageKind::Boundary(p) | StageKind::Pool(p) | StageKind::GapFc(p) => {
                (&p.prog, p.compiled.as_ref())
            }
        }
    }

    pub fn label(&self) -> &str {
        &self.parts().0.label
    }

    pub fn is_boundary(&self) -> bool {
        matches!(self.kind, StageKind::Boundary(_))
    }

    fn run(&self, m: &mut Machine) -> Result<RunReport, SimError> {
        match self.parts() {
            (_, Some(cp)) => m.run_compiled(cp),
            (prog, None) => m.run(prog),
        }
    }

    /// Micro-op pre-compilation happened for this stage.
    pub fn has_uops(&self) -> bool {
        self.parts().1.is_some()
    }
}

/// Where a graph layer's output lives in the arena (for the
/// bit-for-bit boundary tests).
#[derive(Debug, Clone, Copy)]
pub struct LayerTap {
    pub out: OutputRef,
}

/// Where the input image is staged.
#[derive(Debug, Clone, Copy)]
struct InputDesc {
    x_addr: u64,
    ew: u64,
    c_real: u32,
    h: u32,
    w: u32,
    hp: u32,
    wp: u32,
    pad: u32,
}

/// The whole QNN compiled once: chained per-layer programs over one
/// planned activation arena.  Execute any number of times on pooled
/// machines; outputs and cycle counts are bit-identical per execution.
#[derive(Debug)]
pub struct CompiledQnn {
    pub net: QnnNet,
    pub cfg: ProcessorConfig,
    pub stages: Vec<QnnStage>,
    /// One tap per graph layer (the executed layer boundaries).
    pub taps: Vec<LayerTap>,
    pub logits: OutputRef,
    /// Simulated-DRAM bytes a machine needs for the arena.
    pub mem_bytes: usize,
    input: InputDesc,
}

/// One inference through the compiled network.
pub struct QnnRun {
    pub logits: Vec<i64>,
    pub argmax: usize,
    /// Per-stage reports (boundary streams included), stage order.
    pub stage_reports: Vec<RunReport>,
}

impl QnnRun {
    pub fn total_cycles(&self) -> u64 {
        self.stage_reports.iter().map(|r| r.stats.cycles).sum()
    }
}

/// The flowing inter-layer value during compilation: dense wide sums.
#[derive(Clone, Copy)]
struct Flow {
    addr: u64,
    sew: crate::isa::Sew,
    c: u32,
    h: u32,
    w: u32,
    max_val: u64,
}

impl CompiledQnn {
    /// Compile `net`'s graph for `cfg`: plan the arena, compile every
    /// conv in it, and emit the boundary/pool/head streams.
    pub fn compile(cfg: &ProcessorConfig, net: QnnNet) -> Result<CompiledQnn, SimError> {
        use crate::isa::Sew;
        net.graph.validate().map_err(|e| SimError::Graph(e.to_string()))?;
        let QnnPrecision::SubByte { w_bits, a_bits } = net.precision else {
            return Err(SimError::Unsupported("fp32 is served by the legacy cost model"));
        };
        let amax = act_level_max(a_bits);
        let opts = EngineOpts::default();
        let mut la = LayoutAlloc::new();
        let mut stages: Vec<QnnStage> = Vec::new();
        let mut taps: Vec<LayerTap> = Vec::new();
        let mut flow: Option<Flow> = None;
        let mut input: Option<InputDesc> = None;
        let mut logits: Option<OutputRef> = None;
        let mut conv_ix = 0usize;

        for (li, layer) in net.graph.layers.iter().enumerate() {
            match *layer {
                LayerDesc::Conv { c_in, c_out, h, w, f, quantized } => {
                    let cp = padded_c(c_in);
                    let pad = (f - 1) / 2;
                    let d = ConvDims { c: cp, h: h + f - 1, w: w + f - 1, co: c_out, fh: f, fw: f };
                    let variant = variant_for(layer, net.precision)
                        .expect("conv layers always map to a variant");
                    let (wb, ab) = variant.bits();
                    let wl = Workload {
                        dims: d,
                        w_bits: wb,
                        a_bits: ab,
                        act: vec![vec![0; (d.h * d.w) as usize]; cp as usize],
                        wgt: net.conv_wgt[conv_ix].clone(),
                        act_f32: vec![],
                        wgt_f32: vec![],
                    };
                    let (inner, label) = variant.planned_inner(&wl)?;
                    let cc = conv_engine::compile_in_arena(cfg, &wl, inner, opts, label, &mut la)?;
                    let (x_addr, _) = cc.input_region();
                    let ew = cc.input_elem_bytes();
                    let in_sew = match ew {
                        1 => Sew::E8,
                        2 => Sew::E16,
                        _ => Sew::E32,
                    };
                    match flow {
                        None => {
                            // layer 0: the host stages the image here
                            input = Some(InputDesc {
                                x_addr,
                                ew,
                                c_real: c_in,
                                h,
                                w,
                                hp: d.h,
                                wp: d.w,
                                pad,
                            });
                        }
                        Some(fl) => {
                            let spec = RequantSpec {
                                src: fl.addr,
                                src_sew: fl.sew,
                                c: fl.c,
                                h: fl.h,
                                w: fl.w,
                                dst: x_addr,
                                dst_sew: in_sew,
                                c_pad: cp,
                                pad,
                                rshift: requant::rshift_for(fl.max_val, a_bits),
                                amax,
                            };
                            if !(fl.sew == in_sew || in_sew.widened() == Some(fl.sew)) {
                                return Err(SimError::Unsupported(
                                    "layer boundary narrows by more than one element width",
                                ));
                            }
                            let mut a = Asm::new(format!("boundary->{}", layer.name()), cfg.vlen_bits);
                            requant::emit_requant(&mut a, &spec);
                            stages.push(boundary_stage(li, a.finish(0), cfg));
                        }
                    }
                    let out = cc.out;
                    // worst-case output value, capped at what the output
                    // element can physically hold (a wrapping int16 stem
                    // never exceeds u16::MAX, whatever the exact bound
                    // says) — this also keeps the boundary's requant
                    // shift below the wide element width for any graph
                    let max_val = (c_in as u64 * (f * f) as u64 * amax * weight_level_max(wb))
                        .min(elem_cap(out.elem));
                    flow = Some(Flow {
                        addr: out.addr,
                        sew: out_sew(out.elem),
                        c: c_out,
                        h,
                        w,
                        max_val,
                    });
                    taps.push(LayerTap { out });
                    stages.push(QnnStage { layer: li, kind: StageKind::Conv(Box::new(cc)) });
                    conv_ix += 1;
                }
                LayerDesc::MaxPool { c, h, w } => {
                    let fl = flow.ok_or(SimError::Unsupported(
                        "the dataflow executor needs a conv before the first pool",
                    ))?;
                    let eb = fl.sew.bytes() as u64;
                    if w as u64 * eb > (cfg.vlen_bits / 8) as u64 {
                        return Err(SimError::Unsupported(
                            "pool row does not fit one vector register at M1",
                        ));
                    }
                    let out_len = (c * (h / 2) * (w / 2)) as u64;
                    let dst = la.alloc(out_len * eb, 64);
                    let mut a = Asm::new("maxpool2-vec", cfg.vlen_bits);
                    pool_fc::emit_maxpool2(&mut a, c, h, w, fl.sew, fl.addr, dst);
                    let p = stage_prog(a.finish(0), cfg);
                    stages.push(QnnStage { layer: li, kind: StageKind::Pool(p) });
                    let out = OutputRef { addr: dst, elem: out_elem(fl.sew), len: out_len as usize };
                    taps.push(LayerTap { out });
                    flow = Some(Flow { addr: dst, sew: fl.sew, c, h: h / 2, w: w / 2, ..fl });
                }
                LayerDesc::GapFc { c, classes } => {
                    use crate::isa::Sew;
                    let fl = flow.ok_or(SimError::Unsupported(
                        "the dataflow executor needs a conv before the head",
                    ))?;
                    if classes > 4 {
                        return Err(SimError::Unsupported(
                            "the GAP+FC head holds at most 4 logit accumulators",
                        ));
                    }
                    // boundary requant into a dense E16 level buffer
                    let hw = fl.h * fl.w;
                    if !hw.is_power_of_two() || !pool_fc::gap_fits(hw, Sew::E16, cfg.vlen_bits) {
                        return Err(SimError::Unsupported(
                            "GAP spatial extent must be a power of two fitting one register",
                        ));
                    }
                    // value-range guards: the channel sums reduce in
                    // 16-bit lanes and the logits accumulate in u32 —
                    // the golden network is exact i64, so a graph that
                    // could wrap either must not compile
                    let gap_max = hw as u64 * amax;
                    if gap_max > u16::MAX as u64 {
                        return Err(SimError::Unsupported(
                            "GAP channel sum would overflow its 16-bit lanes",
                        ));
                    }
                    if c as u64 * gap_max * weight_level_max(w_bits) > u32::MAX as u64 {
                        return Err(SimError::Unsupported(
                            "FC logits would overflow their 32-bit accumulators",
                        ));
                    }
                    let lv_addr = la.alloc(c as u64 * hw as u64 * 2, 64);
                    let spec = RequantSpec {
                        src: fl.addr,
                        src_sew: fl.sew,
                        c,
                        h: fl.h,
                        w: fl.w,
                        dst: lv_addr,
                        dst_sew: Sew::E16,
                        c_pad: c,
                        pad: 0,
                        rshift: requant::rshift_for(fl.max_val, a_bits),
                        amax,
                    };
                    let mut a = Asm::new("boundary->gap+fc", cfg.vlen_bits);
                    requant::emit_requant(&mut a, &spec);
                    stages.push(boundary_stage(li, a.finish(0), cfg));

                    let lg_addr = la.alloc(classes as u64 * 4, 64);
                    let mut a = Asm::new("gap+fc-vec", cfg.vlen_bits);
                    pool_fc::emit_gap_fc(&mut a, c, hw, Sew::E16, lv_addr, &net.fc_wgt, lg_addr);
                    let p = stage_prog(a.finish(layer.macs()), cfg);
                    stages.push(QnnStage { layer: li, kind: StageKind::GapFc(p) });
                    let out = OutputRef { addr: lg_addr, elem: OutElem::U32, len: classes as usize };
                    taps.push(LayerTap { out });
                    logits = Some(out);
                }
            }
        }

        let input = input.ok_or(SimError::Unsupported(
            "the dataflow executor needs a conv as the first layer",
        ))?;
        let logits = logits.ok_or(SimError::Unsupported(
            "the dataflow executor needs a gap+fc head as the last layer",
        ))?;
        let mem_bytes = (la.brk() as usize).next_power_of_two().max(1 << 16);
        Ok(CompiledQnn {
            net,
            cfg: cfg.clone(),
            stages,
            taps,
            logits,
            mem_bytes,
            input,
        })
    }

    /// Execute one inference: reset the machine, stage the image into
    /// layer 0's padded input region, run every chained stage, read
    /// the logits back from the arena.
    pub fn execute(&self, m: &mut Machine, image: &[u64]) -> Result<QnnRun, SimError> {
        m.reset_for(self.mem_bytes);
        self.execute_fresh(m, image)
    }

    /// [`Self::execute`] for a machine known to be freshly reset (the
    /// pooled-serving path: `MachinePool::acquire` already reset it).
    pub fn execute_fresh(&self, m: &mut Machine, image: &[u64]) -> Result<QnnRun, SimError> {
        if m.cfg != self.cfg {
            return Err(SimError::Unsupported(
                "machine configuration differs from the compiled network's",
            ));
        }
        if image.len() != self.net.input_len() {
            return Err(SimError::Unsupported("image length != c*h*w"));
        }
        let d = &self.input;
        let amax = act_level_max(self.net.a_bits());
        for ch in 0..d.c_real {
            for r in 0..d.h {
                for q in 0..d.w {
                    let lv = image[((ch * d.h + r) * d.w + q) as usize].min(amax);
                    let at = d.x_addr
                        + ((ch as u64 * d.hp as u64 + (r + d.pad) as u64) * d.wp as u64
                            + (q + d.pad) as u64)
                            * d.ew;
                    m.mem.store_uint(at, d.ew as u32, lv)?;
                }
            }
        }
        let mut stage_reports = Vec::with_capacity(self.stages.len());
        for st in &self.stages {
            stage_reports.push(st.run(m)?);
        }
        let logits = self.logits.read_ints(&m.mem)?;
        let argmax = argmax_i64(&logits);
        Ok(QnnRun { logits, argmax, stage_reports })
    }

    /// Read graph layer `li`'s executed output back from the arena
    /// (after an `execute` on `m`) — the boundary the golden network
    /// pins bit-for-bit.
    pub fn read_tap(&self, m: &Machine, li: usize) -> Result<Vec<i64>, SimError> {
        self.taps[li].out.read_ints(&m.mem)
    }

    /// Aggregate a run's stage reports into per-graph-layer cycles
    /// (boundary streams count toward their consumer layer, exactly
    /// like the runtime packing passes count toward their conv).
    pub fn layer_cycles(&self, run: &QnnRun) -> Vec<super::schedule::LayerCycles> {
        let mut rows: Vec<super::schedule::LayerCycles> = self
            .net
            .graph
            .layers
            .iter()
            .map(|l| super::schedule::LayerCycles {
                name: l.name(),
                cycles: 0,
                macs: l.macs(),
                variant: String::new(),
            })
            .collect();
        for (st, rep) in self.stages.iter().zip(&run.stage_reports) {
            rows[st.layer].cycles += rep.stats.cycles;
            if !st.is_boundary() {
                rows[st.layer].variant = rep.label.clone();
            }
        }
        rows
    }
}

/// Tie-breaking matches `coordinator::argmax` (last maximum wins), so
/// a served classification and the golden argmax can never disagree on
/// equal logits.
pub fn argmax_i64(xs: &[i64]) -> usize {
    xs.iter().enumerate().max_by(|a, b| a.1.cmp(b.1)).map(|(i, _)| i).unwrap_or(0)
}

fn stage_prog(prog: Program, cfg: &ProcessorConfig) -> StageProg {
    let compiled = CompiledProgram::compile(&prog, cfg).ok();
    StageProg { prog, compiled }
}

fn boundary_stage(layer: usize, prog: Program, cfg: &ProcessorConfig) -> QnnStage {
    QnnStage { layer, kind: StageKind::Boundary(stage_prog(prog, cfg)) }
}

/// Largest value an output element can hold — the cap both the
/// compiler's `Flow::max_val` and the golden network's bound share.
fn elem_cap(e: OutElem) -> u64 {
    match e {
        OutElem::U16 => u16::MAX as u64,
        OutElem::U32 | OutElem::F32 => u32::MAX as u64,
    }
}

fn out_sew(e: OutElem) -> crate::isa::Sew {
    match e {
        OutElem::U16 => crate::isa::Sew::E16,
        OutElem::U32 | OutElem::F32 => crate::isa::Sew::E32,
    }
}

fn out_elem(s: crate::isa::Sew) -> OutElem {
    match s {
        crate::isa::Sew::E16 => OutElem::U16,
        _ => OutElem::U32,
    }
}

/// Which container a quantized layer of this net runs in (diagnostic,
/// used by the benches' labels).
pub fn container_for(precision: QnnPrecision, issues: u64) -> Option<Container> {
    match precision {
        QnnPrecision::SubByte { w_bits, a_bits } => {
            region::plan_vmacsr(w_bits, a_bits, issues, crate::ulppack::RegionMode::Paper)
                .map(|p| p.container)
        }
        QnnPrecision::Fp32 => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::MachinePool;

    fn w2a2() -> QnnPrecision {
        QnnPrecision::SubByte { w_bits: 2, a_bits: 2 }
    }

    #[test]
    fn compiles_and_runs_the_sparq_cnn() {
        let net = QnnNet::from_seed(&QnnGraph::sparq_cnn(), w2a2(), 0xABCD).unwrap();
        let cq = CompiledQnn::compile(&ProcessorConfig::sparq(), net).unwrap();
        assert_eq!(cq.taps.len(), cq.net.graph.layers.len());
        let image = cq.net.test_image(7);
        let mut m = Machine::new(cq.cfg.clone(), cq.mem_bytes);
        let run = cq.execute(&mut m, &image).unwrap();
        assert_eq!(run.logits.len(), 4);
        assert!(run.total_cycles() > 0);
        // every stage stream pre-compiled to micro-ops on Sparq
        assert!(cq.stages.iter().all(|s| s.has_uops()));
    }

    #[test]
    fn executed_boundaries_match_the_golden_network() {
        let net = QnnNet::from_seed(&QnnGraph::sparq_cnn(), w2a2(), 0x5EED_CAFE).unwrap();
        let cq = CompiledQnn::compile(&ProcessorConfig::sparq(), net).unwrap();
        let image = cq.net.test_image(42);
        let golden = cq.net.golden_forward(&image).unwrap();
        let mut m = Machine::new(cq.cfg.clone(), cq.mem_bytes);
        let run = cq.execute(&mut m, &image).unwrap();
        for li in 0..cq.net.graph.layers.len() {
            assert_eq!(
                cq.read_tap(&m, li).unwrap(),
                golden.layer_outs[li],
                "layer {li} ({}) diverged",
                cq.net.graph.layers[li].name()
            );
        }
        assert_eq!(run.logits, golden.logits);
        assert_eq!(run.argmax, golden.argmax);
    }

    #[test]
    fn repeated_execution_is_bit_identical_on_pooled_machines() {
        let net = QnnNet::from_seed(&QnnGraph::sparq_cnn(), w2a2(), 1).unwrap();
        let cq = CompiledQnn::compile(&ProcessorConfig::sparq(), net).unwrap();
        let pool = MachinePool::new();
        let image = cq.net.test_image(3);
        let mut m = pool.acquire(&cq.cfg, cq.mem_bytes);
        let a = cq.execute_fresh(&mut m, &image).unwrap();
        pool.release(m);
        let mut m = pool.acquire(&cq.cfg, cq.mem_bytes);
        let b = cq.execute_fresh(&mut m, &image).unwrap();
        pool.release(m);
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.total_cycles(), b.total_cycles());
        assert_eq!(pool.stats().reused, 1);
    }

    #[test]
    fn fp32_and_invalid_graphs_are_rejected() {
        let g = QnnGraph::sparq_cnn();
        assert!(matches!(
            QnnNet::from_seed(&g, QnnPrecision::Fp32, 1),
            Err(SimError::Unsupported(_))
        ));
        let mut bad = g.clone();
        bad.input = (3, 16, 16);
        assert!(matches!(QnnNet::from_seed(&bad, w2a2(), 1), Err(SimError::Graph(_))));
    }

    #[test]
    fn different_seeds_give_different_weights_same_seed_identical() {
        let g = QnnGraph::sparq_cnn();
        let a = QnnNet::from_seed(&g, w2a2(), 10).unwrap();
        let b = QnnNet::from_seed(&g, w2a2(), 10).unwrap();
        let c = QnnNet::from_seed(&g, w2a2(), 11).unwrap();
        assert_eq!(a.conv_wgt, b.conv_wgt);
        assert_eq!(a.fc_wgt, b.fc_wgt);
        assert_ne!(a.conv_wgt, c.conv_wgt);
    }
}
