//! Flat byte-addressed memory with a bump allocator — the simulated
//! system's DRAM.  Kernel builders allocate tensors here and bake the
//! resolved addresses into their instruction traces.

use std::fmt;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemError {
    OutOfBounds { addr: u64, len: usize, size: usize },
    OutOfMemory(u64),
}

impl fmt::Display for MemError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            MemError::OutOfBounds { addr, len, size } => {
                write!(f, "access at {addr:#x}+{len} out of bounds (size {size:#x})")
            }
            MemError::OutOfMemory(bytes) => {
                write!(f, "allocation of {bytes} bytes exceeds memory")
            }
        }
    }
}

impl std::error::Error for MemError {}

/// Simulated main memory.
#[derive(Debug, Clone)]
pub struct Mem {
    data: Vec<u8>,
    brk: u64,
}

impl Mem {
    /// A memory of `size` bytes, zero-initialised.
    pub fn new(size: usize) -> Mem {
        Mem { data: vec![0; size], brk: 64 } // keep null page tiny but nonzero
    }

    pub fn size(&self) -> usize {
        self.data.len()
    }

    /// Reset to the freshly-constructed state (all zeroes, allocator
    /// rewound) — the machine-pool reuse path, cheaper than a realloc.
    pub fn reset(&mut self) {
        self.data.fill(0);
        self.brk = 64;
    }

    /// Bump-allocate `bytes` with `align` (power of two) alignment.
    pub fn alloc(&mut self, bytes: u64, align: u64) -> Result<u64, MemError> {
        debug_assert!(align.is_power_of_two());
        let base = (self.brk + align - 1) & !(align - 1);
        if base + bytes > self.data.len() as u64 {
            return Err(MemError::OutOfMemory(bytes));
        }
        self.brk = base + bytes;
        Ok(base)
    }

    fn check(&self, addr: u64, len: usize) -> Result<usize, MemError> {
        let a = addr as usize;
        if a + len > self.data.len() {
            return Err(MemError::OutOfBounds { addr, len, size: self.data.len() });
        }
        Ok(a)
    }

    pub fn read(&self, addr: u64, len: usize) -> Result<&[u8], MemError> {
        let a = self.check(addr, len)?;
        Ok(&self.data[a..a + len])
    }

    pub fn write(&mut self, addr: u64, bytes: &[u8]) -> Result<(), MemError> {
        let a = self.check(addr, bytes.len())?;
        self.data[a..a + bytes.len()].copy_from_slice(bytes);
        Ok(())
    }

    /// The whole backing store, mutably — the fused store-run fast
    /// path (`sim::uop`): one merged bounds check for the run's span,
    /// then raw per-member copies.  `write` has no side effect beyond
    /// the byte copy, so bypassing it is behaviour-preserving.
    pub(crate) fn bytes_mut(&mut self) -> &mut [u8] {
        &mut self.data
    }

    /// Unsigned element load of `bytes` in {1,2,4,8}.
    pub fn load_uint(&self, addr: u64, bytes: u32) -> Result<u64, MemError> {
        let s = self.read(addr, bytes as usize)?;
        let mut v = [0u8; 8];
        v[..bytes as usize].copy_from_slice(s);
        Ok(u64::from_le_bytes(v))
    }

    pub fn store_uint(&mut self, addr: u64, bytes: u32, val: u64) -> Result<(), MemError> {
        let le = val.to_le_bytes();
        self.write(addr, &le[..bytes as usize])
    }

    /// Typed helpers for the host side of tests / drivers.
    pub fn write_u16s(&mut self, addr: u64, xs: &[u16]) -> Result<(), MemError> {
        for (i, &x) in xs.iter().enumerate() {
            self.store_uint(addr + 2 * i as u64, 2, x as u64)?;
        }
        Ok(())
    }

    pub fn write_u8s(&mut self, addr: u64, xs: &[u8]) -> Result<(), MemError> {
        self.write(addr, xs)
    }

    pub fn write_f32s(&mut self, addr: u64, xs: &[f32]) -> Result<(), MemError> {
        for (i, &x) in xs.iter().enumerate() {
            self.store_uint(addr + 4 * i as u64, 4, x.to_bits() as u64)?;
        }
        Ok(())
    }

    pub fn read_u16s(&self, addr: u64, n: usize) -> Result<Vec<u16>, MemError> {
        (0..n).map(|i| self.load_uint(addr + 2 * i as u64, 2).map(|v| v as u16)).collect()
    }

    pub fn read_u8s(&self, addr: u64, n: usize) -> Result<Vec<u8>, MemError> {
        Ok(self.read(addr, n)?.to_vec())
    }

    pub fn read_i32s(&self, addr: u64, n: usize) -> Result<Vec<i32>, MemError> {
        (0..n).map(|i| self.load_uint(addr + 4 * i as u64, 4).map(|v| v as u32 as i32)).collect()
    }

    pub fn read_f32s(&self, addr: u64, n: usize) -> Result<Vec<f32>, MemError> {
        (0..n)
            .map(|i| self.load_uint(addr + 4 * i as u64, 4).map(|v| f32::from_bits(v as u32)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_respects_alignment_and_bounds() {
        let mut m = Mem::new(1024);
        let a = m.alloc(10, 64).unwrap();
        assert_eq!(a % 64, 0);
        let b = m.alloc(10, 64).unwrap();
        assert!(b >= a + 10 && b % 64 == 0);
        assert_eq!(m.alloc(10_000, 8), Err(MemError::OutOfMemory(10_000)));
    }

    #[test]
    fn uint_roundtrip_all_widths() {
        let mut m = Mem::new(256);
        for (bytes, val) in [(1u32, 0xAB), (2, 0xABCD), (4, 0xABCD_1234), (8, 0xABCD_1234_5678u64)] {
            m.store_uint(128, bytes, val).unwrap();
            assert_eq!(m.load_uint(128, bytes).unwrap(), val);
        }
    }

    #[test]
    fn oob_rejected() {
        let m = Mem::new(64);
        assert!(m.load_uint(63, 4).is_err());
        assert!(m.read(64, 1).is_err());
    }

    #[test]
    fn f32_roundtrip() {
        let mut m = Mem::new(64);
        m.write_f32s(0, &[1.5, -2.25]).unwrap();
        assert_eq!(m.read_f32s(0, 2).unwrap(), vec![1.5, -2.25]);
    }
}
