//! The machine pool — reset-in-place reuse of simulated machines for
//! compile-once / execute-many serving and sweeps.
//!
//! `Machine::new` allocates the simulated DRAM and VRF on every call;
//! on repeated workloads (a serving worker, a bench sweep) that
//! allocation plus the instruction-stream rebuild dominates the
//! non-simulation cost.  The pool keeps finished machines, bucketed by
//! processor configuration (compared by value — a bucket can never
//! hand out a wrong-config machine), and hands them back after a
//! `Machine::reset_for` — architecturally indistinguishable from a
//! fresh machine.
//!
//! Sharing model: the pool is `Sync` (internally locked), but the
//! serving coordinator deliberately gives each worker its *own* pool
//! (one machine per worker in steady state, no cross-worker lock
//! traffic) while sharing one `ProgramCache` via `Arc` — see
//! DESIGN.md §"Compile once, execute many".

use super::Machine;
use crate::arch::ProcessorConfig;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Pool counters (diagnostics; `reused / (created + reused)` is the
/// hit rate).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PoolStats {
    pub created: u64,
    pub reused: u64,
    /// Machines currently parked in the pool.
    pub idle: u64,
}

/// A pool of reusable simulated machines, bucketed by configuration.
#[derive(Debug, Default)]
pub struct MachinePool {
    buckets: Mutex<HashMap<ProcessorConfig, Vec<Machine>>>,
    created: AtomicU64,
    reused: AtomicU64,
}

/// Per-bucket cap: a serving worker needs one machine; sweeps over a
/// few sizes need a handful.  Beyond this, released machines are
/// dropped instead of parked.
const MAX_IDLE_PER_BUCKET: usize = 8;

impl MachinePool {
    pub fn new() -> MachinePool {
        MachinePool::default()
    }

    /// Take a machine for `cfg` with at least `mem_bytes` of simulated
    /// DRAM, reset and ready to run — reusing a parked machine when one
    /// exists, allocating otherwise.
    pub fn acquire(&self, cfg: &ProcessorConfig, mem_bytes: usize) -> Machine {
        let reusable = {
            let mut buckets = self.buckets.lock().unwrap();
            match buckets.get_mut(cfg) {
                Some(v) if !v.is_empty() => {
                    // prefer one whose DRAM already fits (avoids a grow)
                    let i = v
                        .iter()
                        .position(|m| m.mem.size() >= mem_bytes)
                        .unwrap_or(v.len() - 1);
                    Some(v.swap_remove(i))
                }
                _ => None,
            }
        };
        match reusable {
            Some(mut m) => {
                m.reset_for(mem_bytes);
                self.reused.fetch_add(1, Ordering::Relaxed);
                m
            }
            None => {
                self.created.fetch_add(1, Ordering::Relaxed);
                Machine::new(cfg.clone(), mem_bytes)
            }
        }
    }

    /// Return a machine to the pool for later reuse.
    pub fn release(&self, m: Machine) {
        let mut buckets = self.buckets.lock().unwrap();
        let v = buckets.entry(m.cfg.clone()).or_default();
        if v.len() < MAX_IDLE_PER_BUCKET {
            v.push(m);
        }
    }

    pub fn stats(&self) -> PoolStats {
        let idle = self.buckets.lock().unwrap().values().map(|v| v.len() as u64).sum();
        PoolStats {
            created: self.created.load(Ordering::Relaxed),
            reused: self.reused.load(Ordering::Relaxed),
            idle,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuses_released_machines() {
        let pool = MachinePool::new();
        let cfg = ProcessorConfig::sparq();
        for _ in 0..4 {
            let m = pool.acquire(&cfg, 1 << 16);
            pool.release(m);
        }
        let s = pool.stats();
        assert_eq!(s.created, 1);
        assert_eq!(s.reused, 3);
        assert_eq!(s.idle, 1);
    }

    #[test]
    fn different_configs_use_different_buckets() {
        let pool = MachinePool::new();
        let a = pool.acquire(&ProcessorConfig::sparq(), 1 << 16);
        pool.release(a);
        // ara must not receive the parked sparq machine
        let b = pool.acquire(&ProcessorConfig::ara(), 1 << 16);
        assert!(b.cfg.fpu);
        assert_eq!(pool.stats().created, 2);
    }

    #[test]
    fn grows_memory_on_demand() {
        let pool = MachinePool::new();
        let cfg = ProcessorConfig::sparq();
        let m = pool.acquire(&cfg, 1 << 12);
        pool.release(m);
        let m = pool.acquire(&cfg, 1 << 20);
        assert!(m.mem.size() >= 1 << 20);
        assert_eq!(pool.stats().reused, 1);
    }

    #[test]
    fn bucket_capped() {
        let pool = MachinePool::new();
        let cfg = ProcessorConfig::sparq();
        let machines: Vec<_> = (0..12).map(|_| pool.acquire(&cfg, 1 << 10)).collect();
        for m in machines {
            pool.release(m);
        }
        assert!(pool.stats().idle as usize <= super::MAX_IDLE_PER_BUCKET);
    }
}
