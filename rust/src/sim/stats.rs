//! Cycle/utilization accounting — the numbers behind Fig. 4, Fig. 5
//! and the §III-A utilization claims.

use crate::arch::Unit;
use std::collections::BTreeMap;

fn uix(u: Unit) -> usize {
    match u {
        Unit::Mfpu => 0,
        Unit::Valu => 1,
        Unit::Vlsu => 2,
        Unit::Sldu => 3,
        Unit::Dispatch => 4,
    }
}

/// Counters accumulated over one program run.  Per-unit counters are
/// flat arrays indexed by unit (§Perf iteration 3 replaced the former
/// string-keyed maps that were walked once per instruction).
#[derive(Debug, Clone, Default)]
pub struct Stats {
    /// Total cycles (time the last instruction retires).
    pub cycles: u64,
    /// Cycles each unit spent busy (indexed by [`Unit`]).
    busy: [u64; 5],
    /// Dynamic instruction counts per unit (scalar slots under DISP).
    insts: [u64; 5],
    /// Vector element operations executed (functional count).
    pub element_ops: u64,
    /// Bytes moved by the VLSU.
    pub bytes_loaded: u64,
    pub bytes_stored: u64,
    /// Stall cycles attributable to operand (RAW) dependencies.
    pub raw_stall_cycles: u64,
}

impl Stats {
    pub fn busy(&self, u: Unit) -> u64 {
        self.busy[uix(u)]
    }

    pub fn insts(&self, u: Unit) -> u64 {
        self.insts[uix(u)]
    }

    #[inline]
    pub fn add_busy(&mut self, u: Unit, cycles: u64) {
        self.busy[uix(u)] += cycles;
        self.insts[uix(u)] += 1;
    }

    #[inline]
    pub fn add_scalar_slots(&mut self, n: u64) {
        self.busy[uix(Unit::Dispatch)] += n;
        self.insts[uix(Unit::Dispatch)] += n;
    }

    /// Named view of the per-unit counters (reports).
    pub fn unit_table(&self) -> BTreeMap<&'static str, (u64, u64)> {
        Unit::ALL.iter().map(|&u| (u.name(), (self.busy(u), self.insts(u)))).collect()
    }

    /// Utilization of a unit over the whole run, in [0, 1].
    pub fn utilization(&self, u: Unit) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.busy(u) as f64 / self.cycles as f64
    }
}

/// Superinstruction-fusion counters from the plan engine
/// (`Machine::run_compiled`): how much of the stream executed as fused
/// multi-uop blocks instead of per-uop dispatch.  Zero/`Default` for
/// the interpreting engines and the retained per-uop engine
/// (`Machine::run_compiled_unfused`), which never fuse.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FusedCounts {
    /// Multi-member fused blocks executed.
    pub blocks: u64,
    /// Bulk micro-ops those blocks absorbed.
    pub uops: u64,
}

/// A finished run plus the kernel-declared work, ready for reporting.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub stats: Stats,
    /// Effective multiply-accumulates the kernel computed (declared by
    /// the builder: packed kernels do 2 MACs per container product).
    pub macs: u64,
    /// Human label ("int16-conv2d", "ULP-conv2d", ...).
    pub label: String,
    /// Fused-block execution counters (diagnostics; never part of the
    /// bit-identity contract between engines).
    pub fused: FusedCounts,
}

impl RunReport {
    /// Operations per cycle, counting 1 MAC = 2 ops (mul + add), the
    /// convention of the paper's Fig. 4.
    pub fn ops_per_cycle(&self) -> f64 {
        if self.stats.cycles == 0 {
            return 0.0;
        }
        (2 * self.macs) as f64 / self.stats.cycles as f64
    }

    /// Speedup of this run over a baseline run of the same workload.
    pub fn speedup_over(&self, base: &RunReport) -> f64 {
        debug_assert_eq!(self.macs, base.macs, "speedup needs identical workloads");
        base.stats.cycles as f64 / self.stats.cycles as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_math() {
        let mut s = Stats::default();
        s.cycles = 200;
        s.add_busy(Unit::Mfpu, 150);
        assert!((s.utilization(Unit::Mfpu) - 0.75).abs() < 1e-12);
        assert_eq!(s.insts(Unit::Mfpu), 1);
        assert_eq!(s.utilization(Unit::Valu), 0.0);
    }

    #[test]
    fn ops_per_cycle_counts_mac_as_two() {
        let mut s = Stats::default();
        s.cycles = 100;
        let r = RunReport { stats: s, macs: 400, label: "x".into(), fused: FusedCounts::default() };
        assert!((r.ops_per_cycle() - 8.0).abs() < 1e-12);
    }

    #[test]
    fn speedup_is_cycle_ratio() {
        let mk = |cycles| RunReport {
            stats: Stats { cycles, ..Default::default() },
            macs: 10,
            label: String::new(),
            fused: FusedCounts::default(),
        };
        assert!((mk(50).speedup_over(&mk(100)) - 2.0).abs() < 1e-12);
    }
}
