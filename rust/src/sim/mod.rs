//! The Ara/Sparq vector-machine simulator: functionally exact execution
//! (see [`exec`]) married to a cycle-approximate timing model
//! ([`timing`]) with per-unit utilization accounting ([`stats`]).
//!
//! Several execution engines share those semantics (DESIGN.md §Perf):
//! [`Machine::run`] interprets the trace instruction by instruction,
//! while [`Machine::run_compiled`] walks a [`uop::CompiledProgram`]'s
//! fused execution plan — legality/alignment checked once at compile
//! time, elements processed many-per-`u64`-word (SWAR), recurring bulk
//! runs fused into one sweep per run, and the whole-run [`Stats`]
//! precomputed at compile time — with bit-identical outputs and cycle
//! counts.  [`Machine::run_compiled_unfused`] is the retained per-uop
//! engine (the fused plan's bench baseline), and
//! [`Machine::run_reference`] is the pure per-element oracle all of
//! them are differentially fuzzed against (`rust/tests/exec_diff.rs`).

pub mod exec;
pub mod mem;
pub mod pool;
pub mod stats;
pub mod timing;
pub mod uop;
pub mod vrf;

use crate::arch::{ProcessorConfig, Unit};
use crate::isa::{EncodeError, Sew, VInst, VOp};
use exec::ExecState;
use mem::{Mem, MemError};
use stats::Stats;
pub use pool::MachinePool;
pub use stats::{FusedCounts, RunReport};
pub use uop::{CompiledProgram, StrategyCounts};
use std::fmt;
use timing::Timing;
use vrf::Vrf;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimError {
    Mem(MemError),
    NoFpu(&'static str),
    NoVmacsr,
    NoCfgShifter,
    Misaligned { reg: u8, lmul: u32 },
    GroupPastV31 { reg: u8, lmul: u32 },
    Unsupported(&'static str),
    /// A kernel builder constructed an instruction with no machine
    /// encoding (surfaced as a `Result` instead of a builder panic).
    Encode(EncodeError),
    /// A QNN graph failed shape-chaining validation (`QnnGraph::
    /// validate`): the dataflow compiler refuses to schedule it.
    Graph(String),
}

impl fmt::Display for SimError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            SimError::Mem(ref e) => write!(f, "memory fault: {e}"),
            SimError::NoFpu(op) => {
                write!(f, "illegal instruction: {op} needs the FPU (removed on Sparq)")
            }
            SimError::NoVmacsr => {
                write!(f, "illegal instruction: vmacsr is not implemented on this core")
            }
            SimError::NoCfgShifter => write!(
                f,
                "illegal instruction: vmacsr.cfg needs the configurable-shifter extension"
            ),
            SimError::Misaligned { reg, lmul } => {
                write!(f, "illegal instruction: v{reg} not aligned to LMUL={lmul} group")
            }
            SimError::GroupPastV31 { reg, lmul } => {
                write!(f, "illegal instruction: v{reg} group of {lmul} extends past v31")
            }
            SimError::Unsupported(what) => write!(f, "unsupported by this model: {what}"),
            SimError::Encode(ref e) => write!(f, "unencodable instruction: {e}"),
            SimError::Graph(ref m) => write!(f, "invalid qnn graph: {m}"),
        }
    }
}

impl std::error::Error for SimError {}

impl From<MemError> for SimError {
    fn from(e: MemError) -> SimError {
        SimError::Mem(e)
    }
}

impl From<EncodeError> for SimError {
    fn from(e: EncodeError) -> SimError {
        SimError::Encode(e)
    }
}

/// A dynamic instruction trace plus the work it claims to perform.
#[derive(Debug, Clone, Default)]
pub struct Program {
    pub insts: Vec<VInst>,
    /// Effective MACs the kernel computes (declared by the builder;
    /// packed kernels count 2 MACs per container multiply).
    pub macs: u64,
    /// Label for reports.
    pub label: String,
}

impl Program {
    pub fn new(label: impl Into<String>) -> Program {
        Program { insts: Vec::new(), macs: 0, label: label.into() }
    }

    pub fn push(&mut self, i: VInst) {
        self.insts.push(i);
    }

    pub fn len(&self) -> usize {
        self.insts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.insts.is_empty()
    }

    /// Encode the whole trace to its 32-bit machine words — the
    /// architectural view of the stream (what an AOT emitter would
    /// write to an ELF).  A builder that constructed an unencodable
    /// instruction surfaces here as [`SimError::Encode`] instead of a
    /// panic.
    pub fn machine_code(&self) -> Result<Vec<u32>, SimError> {
        self.insts
            .iter()
            .map(|i| crate::isa::encode(i).map_err(SimError::from))
            .collect()
    }
}

/// The simulated machine: configuration + architectural state + memory.
pub struct Machine {
    pub cfg: ProcessorConfig,
    pub mem: Mem,
    vrf: Vrf,
    state: ExecState,
}

impl Machine {
    /// A machine with `mem_bytes` of simulated DRAM.
    pub fn new(cfg: ProcessorConfig, mem_bytes: usize) -> Machine {
        let vrf = Vrf::new(cfg.vlen_bits);
        Machine { cfg, mem: Mem::new(mem_bytes), vrf, state: ExecState::default() }
    }

    /// Reset architectural state in place (memory zeroed + allocator
    /// rewound, VRF zeroed, vtype/vl/CSRs cleared) — equivalent to a
    /// fresh `Machine::new` with the same configuration, without the
    /// reallocation.  The machine pool calls this between executions.
    pub fn reset(&mut self) {
        self.mem.reset();
        self.vrf.clear();
        self.state = ExecState::default();
    }

    /// Reset, growing the simulated DRAM to at least `mem_bytes` if the
    /// current allocation is too small (pool reuse across workloads).
    pub fn reset_for(&mut self, mem_bytes: usize) {
        if self.mem.size() < mem_bytes {
            self.mem = Mem::new(mem_bytes);
        } else {
            self.mem.reset();
        }
        self.vrf.clear();
        self.state = ExecState::default();
    }

    /// Set the configurable-shifter CSR (vmacsr.cfg extension).
    pub fn set_shift_csr(&mut self, shift: u32) {
        self.state.csr_shift = shift;
    }

    /// Current vl (after the last vsetvli).
    pub fn vl(&self) -> u32 {
        self.state.vl
    }

    /// Direct VRF access for tests.
    pub fn vrf(&mut self) -> &mut Vrf {
        &mut self.vrf
    }

    /// Run a program to completion: functional execution + timing.
    ///
    /// This is the interpreting engine (per-instruction validation, VX
    /// fast paths).  The serving hot path pre-compiles the trace with
    /// [`uop::CompiledProgram::compile`] and uses
    /// [`Machine::run_compiled`] instead — same results, far less host
    /// work per execution.
    pub fn run(&mut self, prog: &Program) -> Result<RunReport, SimError> {
        self.run_interp(prog, true, 0)
    }

    /// [`Machine::run`] with every memory address offset by `base` —
    /// the interpreter-side dual of
    /// [`uop::CompiledProgram`]-based rebasing
    /// (`Machine::run_compiled_rebased`), used by the batched QNN
    /// executor when a stage stream has no micro-op form.  `base` must
    /// be a multiple of the arena allocation alignment (64); the
    /// timing model never reads addresses, so the report is
    /// bit-identical to the `base = 0` run.
    pub fn run_rebased(&mut self, prog: &Program, base: u64) -> Result<RunReport, SimError> {
        self.run_interp(prog, true, base)
    }

    /// [`Machine::run`] with every fast path disabled: the retained
    /// per-element reference interpreter.  The differential fuzz test
    /// pins both `run` and `run_compiled` to this oracle bit-for-bit
    /// (VRF, memory, and cycle counts).
    pub fn run_reference(&mut self, prog: &Program) -> Result<RunReport, SimError> {
        self.run_interp(prog, false, 0)
    }

    fn run_interp(&mut self, prog: &Program, fast: bool, base: u64) -> Result<RunReport, SimError> {
        let mut timing = Timing::new(&self.cfg);
        let mut st = Stats::default();

        for inst in &prog.insts {
            // rebase memory operands only (registers and scalar
            // operands are arena-independent)
            let inst = &match *inst {
                VInst::Load { eew, vd, addr } if base != 0 => {
                    VInst::Load { eew, vd, addr: addr + base }
                }
                VInst::Store { eew, vs3, addr } if base != 0 => {
                    VInst::Store { eew, vs3, addr: addr + base }
                }
                other => other,
            };
            let ops = if fast {
                exec::execute(inst, &self.cfg, &mut self.state, &mut self.vrf, &mut self.mem)?
            } else {
                exec::execute_reference(
                    inst,
                    &self.cfg,
                    &mut self.state,
                    &mut self.vrf,
                    &mut self.mem,
                )?
            };
            st.element_ops += ops;
            self.account(inst, &mut timing, &mut st);
        }
        st.cycles = timing.cycles();
        st.raw_stall_cycles = timing.raw_stalls;
        Ok(RunReport {
            stats: st,
            macs: prog.macs,
            label: prog.label.clone(),
            fused: stats::FusedCounts::default(),
        })
    }

    /// Timing-side accounting for one instruction.
    fn account(&self, inst: &VInst, timing: &mut Timing, st: &mut Stats) {
        let lmul = self.state.vtype.lmul.factor();
        let sew = self.state.vtype.sew;
        let vl = self.state.vl as u64;
        match *inst {
            VInst::Scalar { n, .. } => {
                timing.scalar(n);
                st.add_scalar_slots(n as u64);
            }
            VInst::SetVl { .. } => {
                timing.scalar(1);
                st.add_scalar_slots(1);
            }
            VInst::Load { eew, vd, .. } => {
                let bytes = vl * eew.bytes() as u64;
                let (s, e) = timing.vector(Unit::Vlsu, bytes, bytes, Some((vd, lmul)), &[]);
                st.add_busy(Unit::Vlsu, e - s);
                st.bytes_loaded += bytes;
            }
            VInst::Store { eew, vs3, .. } => {
                let bytes = vl * eew.bytes() as u64;
                let (s, e) = timing.vector(Unit::Vlsu, bytes, bytes, None, &[(vs3, lmul)]);
                st.add_busy(Unit::Vlsu, e - s);
                st.bytes_stored += bytes;
            }
            VInst::OpVV { .. } | VInst::OpVX { .. } | VInst::OpVI { .. } => {
                let op = inst.vop().unwrap();
                let unit = if op.is_fp() || op.is_mul() {
                    Unit::Mfpu
                } else if op.is_slide() {
                    Unit::Sldu
                } else {
                    Unit::Valu
                };
                // widening/narrowing ops move wide-width data
                let ebytes = if op == VOp::WAdduWv || op == VOp::NSrl {
                    sew.widened().map(Sew::bytes).unwrap_or(8) as u64
                } else {
                    sew.bytes() as u64
                };
                let dst_regs = if op == VOp::WAdduWv { lmul * 2 } else { lmul };
                // narrowing ops read vs2 as a 2*LMUL group
                let src_regs = if op == VOp::NSrl { lmul * 2 } else { lmul };
                let mut buf = [0u8; 3];
                let n = inst.srcs_into(&mut buf);
                let mut srcs = [(0u8, 0u32); 3];
                for (i, &r) in buf[..n].iter().enumerate() {
                    srcs[i] = (r, src_regs);
                }
                let dst = inst.vd().map(|d| (d, dst_regs));
                let busy = vl * ebytes;
                let (_, _) = timing.vector(unit, busy, 0, dst, &srcs[..n]);
                // a unit is "busy" for its occupancy, not its latency
                st.add_busy(unit, busy.div_ceil(self.cfg.bytes_per_cycle() as u64).max(1));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Lmul, ScalarKind};

    fn machine() -> Machine {
        Machine::new(ProcessorConfig::sparq(), 1 << 20)
    }

    #[test]
    fn runs_a_tiny_program_and_counts_cycles() {
        let mut m = machine();
        m.mem.write_u16s(0x100, &[1, 2, 3, 4]).unwrap();
        let mut p = Program::new("tiny");
        p.push(VInst::SetVl { avl: 4, sew: Sew::E16, lmul: Lmul::M1 });
        p.push(VInst::Load { eew: Sew::E16, vd: 1, addr: 0x100 });
        p.push(VInst::OpVX { op: VOp::Add, vd: 2, vs2: 1, rs1: 10 });
        p.push(VInst::Store { eew: Sew::E16, vs3: 2, addr: 0x200 });
        p.macs = 0;
        let r = m.run(&p).unwrap();
        assert_eq!(m.mem.read_u16s(0x200, 4).unwrap(), vec![11, 12, 13, 14]);
        assert!(r.stats.cycles > 0);
        assert_eq!(r.stats.bytes_loaded, 8);
        assert_eq!(r.stats.bytes_stored, 8);
    }

    #[test]
    fn short_consumer_cannot_retire_before_long_producer() {
        // Chaining lets dependents start early, but a short dependent op
        // must still retire after its producer's last element.
        let build = |dep: bool| {
            let mut p = Program::new("x");
            p.push(VInst::SetVl { avl: 512, sew: Sew::E16, lmul: Lmul::M2 });
            p.push(VInst::OpVX { op: VOp::Mul, vd: 2, vs2: 2, rs1: 3 }); // 32-cycle producer
            p.push(VInst::SetVl { avl: 16, sew: Sew::E16, lmul: Lmul::M2 });
            let vs2 = if dep { 2 } else { 4 };
            p.push(VInst::OpVX { op: VOp::Add, vd: 6, vs2, rs1: 1 }); // 1-cycle consumer
            p
        };
        let c_dep = machine().run(&build(true)).unwrap().stats.cycles;
        let c_ind = machine().run(&build(false)).unwrap().stats.cycles;
        assert!(c_dep > c_ind, "dep {c_dep} <= ind {c_ind}");
    }

    #[test]
    fn mfpu_utilization_high_for_back_to_back_maccs() {
        let mut m = machine();
        let mut p = Program::new("macc-stream");
        p.push(VInst::SetVl { avl: 512, sew: Sew::E16, lmul: Lmul::M2 });
        for k in 0..64 {
            // independent accumulators round-robin over 8 groups
            let vd = ((k % 8) * 2) as u8;
            p.push(VInst::OpVX { op: VOp::Macc, vd, vs2: 16, rs1: 7 });
        }
        let r = m.run(&p).unwrap();
        let util = r.stats.utilization(Unit::Mfpu);
        assert!(util > 0.9, "MFPU utilization {util}");
    }

    #[test]
    fn scalar_slots_serialize_dispatch() {
        let mut m = machine();
        let mut p = Program::new("scalar-heavy");
        p.push(VInst::SetVl { avl: 16, sew: Sew::E16, lmul: Lmul::M1 });
        for _ in 0..100 {
            p.push(VInst::Scalar { kind: ScalarKind::AddrCalc, n: 4 });
            p.push(VInst::OpVX { op: VOp::Macc, vd: 2, vs2: 4, rs1: 7 });
        }
        let r = m.run(&p).unwrap();
        // 400 scalar slots dominate: MFPU can't be >50% utilized
        assert!(r.stats.utilization(Unit::Mfpu) < 0.5);
        assert!(r.stats.cycles >= 500);
    }

    #[test]
    fn errors_propagate_from_exec() {
        let mut m = Machine::new(ProcessorConfig::ara(), 1 << 16);
        let mut p = Program::new("bad");
        p.push(VInst::SetVl { avl: 4, sew: Sew::E16, lmul: Lmul::M1 });
        p.push(VInst::OpVX { op: VOp::Macsr, vd: 1, vs2: 2, rs1: 0 });
        assert_eq!(m.run(&p).unwrap_err(), SimError::NoVmacsr);
    }

    #[test]
    fn program_machine_code_encodes_or_errors_typed() {
        let mut p = Program::new("enc");
        p.push(VInst::SetVl { avl: 4, sew: Sew::E16, lmul: Lmul::M1 });
        p.push(VInst::OpVX { op: VOp::Macsr, vd: 1, vs2: 2, rs1: 7 });
        let words = p.machine_code().unwrap();
        assert_eq!(words.len(), 2);
        // an unencodable instruction is a typed error, not a panic
        p.push(VInst::OpVI { op: VOp::Macc, vd: 1, vs2: 2, imm: 0 });
        assert!(matches!(p.machine_code(), Err(SimError::Encode(_))));
    }

    #[test]
    fn reset_machine_reruns_bit_identically() {
        let mut m = machine();
        let mut p = Program::new("rr");
        p.push(VInst::SetVl { avl: 8, sew: Sew::E16, lmul: Lmul::M1 });
        p.push(VInst::OpVX { op: VOp::Macc, vd: 2, vs2: 4, rs1: 3 });
        p.push(VInst::Store { eew: Sew::E16, vs3: 2, addr: 0x100 });
        let r1 = m.run(&p).unwrap();
        let o1 = m.mem.read_u16s(0x100, 8).unwrap();
        m.reset();
        let r2 = m.run(&p).unwrap();
        assert_eq!(o1, m.mem.read_u16s(0x100, 8).unwrap());
        assert_eq!(r1.stats.cycles, r2.stats.cycles);
        m.reset_for(1 << 22); // grow
        assert!(m.mem.size() >= 1 << 22);
        let r3 = m.run(&p).unwrap();
        assert_eq!(r1.stats.cycles, r3.stats.cycles);
    }

    #[test]
    fn rebased_runs_are_bit_identical_at_an_offset() {
        // same program, interpreter and compiled engine, at base 0 and
        // at a 64-aligned rebase: identical values land at the shifted
        // addresses with identical cycle counts
        let mut p = Program::new("rebase");
        p.push(VInst::SetVl { avl: 8, sew: Sew::E16, lmul: Lmul::M1 });
        p.push(VInst::Load { eew: Sew::E16, vd: 1, addr: 0x100 });
        p.push(VInst::OpVX { op: VOp::Macc, vd: 2, vs2: 1, rs1: 3 });
        p.push(VInst::Store { eew: Sew::E16, vs3: 2, addr: 0x200 });
        const BASE: u64 = 0x4_0000; // 64-aligned slot offset
        let data: Vec<u16> = (0..8).map(|i| i * 11 + 1).collect();

        let mut m0 = machine();
        m0.mem.write_u16s(0x100, &data).unwrap();
        let r0 = m0.run(&p).unwrap();
        let out0 = m0.mem.read_u16s(0x200, 8).unwrap();

        let mut m1 = machine();
        m1.mem.write_u16s(BASE + 0x100, &data).unwrap();
        let r1 = m1.run_rebased(&p, BASE).unwrap();
        assert_eq!(m1.mem.read_u16s(BASE + 0x200, 8).unwrap(), out0);
        assert_eq!(r0.stats.cycles, r1.stats.cycles);

        let cp = CompiledProgram::compile(&p, &ProcessorConfig::sparq()).unwrap();
        let mut m2 = machine();
        m2.mem.write_u16s(BASE + 0x100, &data).unwrap();
        let r2 = m2.run_compiled_rebased(&cp, BASE).unwrap();
        assert_eq!(m2.mem.read_u16s(BASE + 0x200, 8).unwrap(), out0);
        assert_eq!(r0.stats.cycles, r2.stats.cycles);
        assert_eq!(r0.stats.bytes_loaded, r2.stats.bytes_loaded);
    }

    #[test]
    fn oob_load_faults() {
        let mut m = machine();
        let mut p = Program::new("oob");
        p.push(VInst::SetVl { avl: 64, sew: Sew::E64, lmul: Lmul::M1 });
        p.push(VInst::Load { eew: Sew::E64, vd: 0, addr: (1 << 20) - 8 });
        assert!(matches!(m.run(&p), Err(SimError::Mem(_))));
    }
}
