//! Cycle-approximate timing model of the Ara/Sparq vector engine.
//!
//! Model (matching Ara's published microarchitecture at the level the
//! paper's numbers depend on):
//!
//! * Single-issue front end: every instruction (vector or scalar slot)
//!   consumes one dispatch cycle; vector instructions then sit in a
//!   per-unit queue, so dispatch runs ahead of execution.
//! * Each functional unit (MFPU, VALU, SLDU, VLSU) processes one
//!   lane-word per lane per cycle: an instruction over `bytes` of data
//!   occupies its unit for `ceil(bytes / (lanes*8))` cycles after a
//!   unit-specific startup latency.
//! * Chaining: a consumer may start once its producer has emitted its
//!   first result word (`producer.start + producer.latency + 1`), but
//!   can never finish before the producer does (`end >= producer.end+1`)
//!   — this is the slack-based approximation of Ara's element-granular
//!   chaining.
//! * The VLSU is additionally bounded by the memory port bandwidth and
//!   pays an AXI round-trip latency on loads.
//!
//! The model is *not* RTL-cycle-exact; it reproduces the throughput
//! ratios and utilization numbers the paper reports (§V-A), which is
//! what the evaluation needs.  See DESIGN.md §2 for the argument.
//!
//! ## Data independence (the execution-plan contract)
//!
//! Every input to this model — unit, byte counts, register-group ids —
//! is known at *compile* time; [`Timing`] never reads run-time data,
//! addresses, or the architectural state.  Given the same accounting
//! call sequence it produces the same cycle numbers, deterministically.
//! The fused execution plan (`sim::uop`) depends on this: it replays
//! the accounting stream once at compile time, stores per-block cycle
//! advances and the whole-run totals, and `Machine::run_compiled`
//! returns those precomputed numbers without touching a [`Timing`] at
//! all.  Any future input to this model that depends on run-time data
//! would break that contract and must move the plan engine back to
//! live accounting.

use crate::arch::{ProcessorConfig, Unit};

/// Per-register-group production record, for chaining decisions.
#[derive(Debug, Clone, Copy, Default)]
struct RegTime {
    /// When the producing instruction started (0 = never written).
    start: u64,
    /// When the producer's last element is architecturally visible.
    end: u64,
    /// Producer's startup latency (first element at start+latency+1).
    latency: u64,
}

/// The evolving timing state of one program run.
#[derive(Debug, Clone)]
pub struct Timing {
    cfg: ProcessorConfig,
    /// Front-end cursor: cycle at which the next instruction dispatches.
    dispatch: u64,
    /// Per-unit "busy until" cycle.
    unit_free: [u64; 4],
    /// Per-architectural-register production records.
    reg: [RegTime; 32],
    /// Latest retire time seen (the run's cycle count).
    pub horizon: u64,
    /// Cycles lost to RAW waits (diagnostic).
    pub raw_stalls: u64,
}

fn unit_ix(u: Unit) -> usize {
    match u {
        Unit::Mfpu => 0,
        Unit::Valu => 1,
        Unit::Vlsu => 2,
        Unit::Sldu => 3,
        Unit::Dispatch => unreachable!("dispatch is not a backend unit"),
    }
}

/// Startup latency (pipeline depth) of each unit, in cycles.
fn unit_latency(u: Unit, cfg: &ProcessorConfig) -> u64 {
    match u {
        Unit::Mfpu => 3,
        Unit::Valu => 1,
        Unit::Sldu => 2,
        Unit::Vlsu => cfg.mem_latency as u64,
        Unit::Dispatch => 0,
    }
}

impl Timing {
    pub fn new(cfg: &ProcessorConfig) -> Timing {
        Timing {
            cfg: cfg.clone(),
            dispatch: 0,
            unit_free: [0; 4],
            reg: [RegTime::default(); 32],
            horizon: 0,
            raw_stalls: 0,
        }
    }

    /// Account a scalar-core slot of `n` instructions.
    pub fn scalar(&mut self, n: u32) {
        self.dispatch += n as u64;
        self.horizon = self.horizon.max(self.dispatch);
    }

    /// Account one vector instruction.
    ///
    /// * `unit` — which backend unit executes it;
    /// * `bytes` — datapath bytes it must move (vl * max(src,dst) width);
    /// * `mem_bytes` — bytes on the memory port (loads/stores, else 0);
    /// * `dst` — destination register group (first reg, count);
    /// * `srcs` — source register groups.
    ///
    /// Returns (start, end) of the instruction's occupancy.
    pub fn vector(
        &mut self,
        unit: Unit,
        bytes: u64,
        mem_bytes: u64,
        dst: Option<(u8, u32)>,
        srcs: &[(u8, u32)],
    ) -> (u64, u64) {
        // front end: one dispatch slot
        self.dispatch += 1;
        let ui = unit_ix(unit);
        let lat = unit_latency(unit, &self.cfg);
        let bpc = self.cfg.bytes_per_cycle() as u64;
        let mut duration = bytes.div_ceil(bpc).max(1);
        if mem_bytes > 0 {
            duration = duration.max(mem_bytes.div_ceil(self.cfg.mem_bytes_per_cycle as u64));
        }

        let issue_ready = self.dispatch + self.cfg.issue_latency as u64;
        let structural = self.unit_free[ui];
        let mut start = issue_ready.max(structural);
        let mut min_end = 0u64;

        // RAW (and RMW-on-dst) chaining
        let consider = |rt: &RegTime, start: &mut u64, min_end: &mut u64| {
            if rt.end == 0 {
                return; // never written — no dependency
            }
            *start = (*start).max(rt.start + rt.latency + 1);
            *min_end = (*min_end).max(rt.end + 1);
        };
        for &(r, n) in srcs {
            for k in 0..n {
                consider(&self.reg[(r as u32 + k) as usize % 32], &mut start, &mut min_end);
            }
        }
        // WAW: a second write to the same group must not complete first
        if let Some((r, n)) = dst {
            for k in 0..n {
                let rt = &self.reg[(r as u32 + k) as usize % 32];
                if rt.end > 0 {
                    min_end = min_end.max(rt.end + 1);
                    start = start.max(rt.start + 1);
                }
            }
        }

        let hazard_wait = start.saturating_sub(issue_ready.max(structural));
        self.raw_stalls += hazard_wait;

        let mut end = start + lat + duration;
        if end < min_end {
            // chained consumer throttled by its producer's completion
            end = min_end;
        }
        // unit pipelines: occupied for `duration` plus the turnaround
        // bubble before the next instruction can enter
        self.unit_free[ui] = start + duration + self.cfg.issue_bubble as u64;
        if let Some((r, n)) = dst {
            for k in 0..n {
                self.reg[(r as u32 + k) as usize % 32] =
                    RegTime { start, end, latency: lat };
            }
        }
        self.horizon = self.horizon.max(end);
        (start, end)
    }

    /// Total cycles of the run so far.
    pub fn cycles(&self) -> u64 {
        self.horizon
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> Timing {
        Timing::new(&ProcessorConfig::sparq())
    }

    #[test]
    fn duration_is_bytes_over_datapath() {
        let mut tm = t();
        // 512 e16 elements = 1024 B over 32 B/cycle = 32 cycles
        let (s, e) = tm.vector(Unit::Mfpu, 1024, 0, Some((1, 1)), &[(2, 1)]);
        assert_eq!(e - s, 3 + 32);
    }

    #[test]
    fn independent_ops_pipeline_on_one_unit() {
        let mut tm = t();
        let (s1, _) = tm.vector(Unit::Mfpu, 1024, 0, Some((1, 1)), &[(2, 1)]);
        let (s2, _) = tm.vector(Unit::Mfpu, 1024, 0, Some((3, 1)), &[(4, 1)]);
        // second op starts when the unit frees (32 cycles of occupancy
        // plus the turnaround bubble), not after latency+duration
        assert_eq!(s2, s1 + 32 + 1);
    }

    #[test]
    fn different_units_overlap() {
        let mut tm = t();
        let (s1, _) = tm.vector(Unit::Mfpu, 1024, 0, Some((1, 1)), &[(2, 1)]);
        let (s2, _) = tm.vector(Unit::Sldu, 1024, 0, Some((3, 1)), &[(4, 1)]);
        // only the extra dispatch slot separates them
        assert_eq!(s2, s1 + 1);
    }

    #[test]
    fn chaining_starts_consumer_early_but_not_before_producer_ends() {
        let mut tm = t();
        let (ps, pe) = tm.vector(Unit::Mfpu, 1024, 0, Some((1, 1)), &[(2, 1)]);
        // consumer on another unit reading v1
        let (cs, ce) = tm.vector(Unit::Valu, 1024, 0, Some((3, 1)), &[(1, 1)]);
        assert!(cs > ps && cs < pe, "chained start inside producer window");
        assert!(ce > pe, "consumer cannot retire before producer");
    }

    #[test]
    fn raw_stall_counted() {
        let mut tm = t();
        tm.vector(Unit::Mfpu, 4096, 0, Some((1, 1)), &[(2, 1)]);
        let before = tm.raw_stalls;
        tm.vector(Unit::Mfpu, 64, 0, Some((3, 1)), &[(1, 1)]);
        assert!(tm.raw_stalls >= before);
    }

    #[test]
    fn memory_bandwidth_bounds_loads() {
        let mut cfg = ProcessorConfig::sparq();
        cfg.mem_bytes_per_cycle = 8; // throttle the AXI port
        let mut tm = Timing::new(&cfg);
        let (s, e) = tm.vector(Unit::Vlsu, 1024, 1024, Some((1, 1)), &[]);
        // 1024/8 = 128 cycles, not 1024/32 = 32
        assert_eq!(e - s, cfg.mem_latency as u64 + 128);
    }

    #[test]
    fn scalar_slots_advance_dispatch() {
        let mut tm = t();
        tm.scalar(5);
        let (s, _) = tm.vector(Unit::Valu, 64, 0, Some((1, 1)), &[]);
        assert!(s >= 5);
    }

    /// The data-independence contract the fused execution plan rests
    /// on: the same accounting call sequence yields the same numbers,
    /// every time (see the module docs).
    #[test]
    fn identical_call_sequences_time_identically() {
        let run = || {
            let mut tm = t();
            tm.scalar(3);
            tm.vector(Unit::Vlsu, 256, 256, Some((1, 1)), &[]);
            tm.vector(Unit::Mfpu, 1024, 0, Some((2, 2)), &[(1, 1)]);
            tm.scalar(1);
            tm.vector(Unit::Valu, 64, 0, Some((4, 1)), &[(2, 2)]);
            (tm.cycles(), tm.raw_stalls)
        };
        assert_eq!(run(), run());
    }
}
