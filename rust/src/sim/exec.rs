//! Functional execution of one trace instruction — bit-exact integer
//! semantics (modular at SEW), IEEE f32 for the FPU ops, RVV slide
//! semantics.  The timing model lives in `sim::timing`; this file only
//! answers "what values" — and is itself the subject of the
//! SIMD-vs-scalar property tests.
//!
//! This is the *interpreting* engine: [`execute`] re-validates legality
//! and alignment per instruction and (outside the `exec_vx_fast` VX
//! paths) walks elements one at a time.  The serving hot path instead
//! pre-compiles a trace into micro-ops and executes them word-parallel
//! — see [`super::uop`] and DESIGN.md §Perf.  [`execute_reference`]
//! pins the semantics: it forces the per-element loop everywhere and is
//! the oracle the differential fuzz test (`rust/tests/exec_diff.rs`)
//! compares both fast engines against.

use super::mem::Mem;
use super::vrf::Vrf;
use super::SimError;
use crate::arch::ProcessorConfig;
use crate::isa::{Lmul, Sew, VInst, VOp, VType};

/// Architectural state carried between instructions.
#[derive(Debug, Clone)]
pub struct ExecState {
    pub vl: u32,
    pub vtype: VType,
    /// The configurable-shifter CSR (vmacsr.cfg extension).
    pub csr_shift: u32,
}

impl Default for ExecState {
    fn default() -> Self {
        ExecState { vl: 0, vtype: VType::new(Sew::E8, Lmul::M1), csr_shift: 0 }
    }
}

#[inline]
pub(crate) fn sext(v: u64, sew: Sew) -> i64 {
    let sh = 64 - sew.bits();
    ((v << sh) as i64) >> sh
}

#[inline]
pub(crate) fn trunc(v: u64, sew: Sew) -> u64 {
    if sew.bits() == 64 {
        v
    } else {
        v & ((1u64 << sew.bits()) - 1)
    }
}

#[inline]
fn mulhu(a: u64, b: u64, sew: Sew) -> u64 {
    match sew {
        Sew::E64 => ((a as u128 * b as u128) >> 64) as u64,
        _ => trunc((a.wrapping_mul(b)) >> sew.bits(), sew),
    }
}

#[inline]
fn mulh(a: u64, b: u64, sew: Sew) -> u64 {
    match sew {
        Sew::E64 => (((sext(a, sew) as i128) * (sext(b, sew) as i128)) >> 64) as u64,
        _ => trunc(((sext(a, sew) as i64).wrapping_mul(sext(b, sew) as i64) >> sew.bits()) as u64, sew),
    }
}

/// ALU/MUL op at one element; `x` is the vs1/rs1/imm operand, `a` is
/// vs2, `d` the old vd (for ternary ops).
#[inline]
pub(crate) fn scalar_op(op: VOp, a: u64, x: u64, d: u64, sew: Sew, shift: u32) -> u64 {
    let m = |v| trunc(v, sew);
    match op {
        VOp::Add => m(a.wrapping_add(x)),
        VOp::Sub => m(a.wrapping_sub(x)),
        VOp::And => a & x,
        VOp::Or => a | x,
        VOp::Xor => a ^ x,
        VOp::Min => a.min(x),
        VOp::Max => a.max(x),
        VOp::Sll => m(a << (x & (sew.bits() as u64 - 1))),
        VOp::Srl => a >> (x & (sew.bits() as u64 - 1)),
        VOp::Sra => m((sext(a, sew) >> (x & (sew.bits() as u64 - 1))) as u64),
        VOp::Mv => x,
        VOp::Mul => m(a.wrapping_mul(x)),
        VOp::Mulhu => mulhu(a, x, sew),
        VOp::Mulh => mulh(a, x, sew),
        VOp::Macc => m(d.wrapping_add(a.wrapping_mul(x))),
        VOp::Nmsac => m(d.wrapping_sub(a.wrapping_mul(x))),
        // the paper's instruction: vd += ((vs1*vs2) mod 2^SEW) >> M,
        // with M hard-wired to SEW/2 (or CSR-driven for .cfg)
        VOp::Macsr | VOp::MacsrCfg => m(d.wrapping_add(m(a.wrapping_mul(x)) >> shift)),
        VOp::FAdd => (f32::from_bits(a as u32) + f32::from_bits(x as u32)).to_bits() as u64,
        VOp::FMul => (f32::from_bits(a as u32) * f32::from_bits(x as u32)).to_bits() as u64,
        VOp::FMacc => {
            let prod = f32::from_bits(a as u32) * f32::from_bits(x as u32);
            (f32::from_bits(d as u32) + prod).to_bits() as u64
        }
        VOp::WAdduWv | VOp::NSrl | VOp::SlideDown | VOp::SlideUp => {
            unreachable!("handled separately")
        }
    }
}

pub(crate) fn check_legal(op: VOp, cfg: &ProcessorConfig, st: &ExecState) -> Result<(), SimError> {
    if op.is_fp() {
        if !cfg.fpu {
            return Err(SimError::NoFpu(op.mnemonic()));
        }
        if st.vtype.sew != Sew::E32 {
            return Err(SimError::Unsupported("fp ops are modelled at SEW=32 only"));
        }
    }
    if op == VOp::Macsr && !cfg.vmacsr {
        return Err(SimError::NoVmacsr);
    }
    if op == VOp::MacsrCfg && !cfg.configurable_shifter {
        return Err(SimError::NoCfgShifter);
    }
    Ok(())
}

pub(crate) fn check_alignment(inst: &VInst, st: &ExecState) -> Result<(), SimError> {
    let lm = st.vtype.lmul;
    let check = |v: u8, factor: u32| -> Result<(), SimError> {
        if v as u32 % factor != 0 {
            return Err(SimError::Misaligned { reg: v, lmul: factor });
        }
        if v as u32 + factor > 32 {
            return Err(SimError::GroupPastV31 { reg: v, lmul: factor });
        }
        Ok(())
    };
    let f = lm.factor();
    if let Some(vd) = inst.vd() {
        let df = if inst.vop() == Some(VOp::WAdduWv) { f * 2 } else { f };
        check(vd, df)?;
    }
    // narrowing ops read vs2 as a 2*LMUL-wide group (the dual of
    // vwaddu.wv's wide destination); their builders only use the
    // .wx/.wi forms, so every source register is the wide vs2
    let sf = if inst.vop() == Some(VOp::NSrl) { f * 2 } else { f };
    for s in inst.srcs() {
        check(s, sf)?;
    }
    Ok(())
}

/// Execute one instruction; returns the number of element operations.
pub fn execute(
    inst: &VInst,
    cfg: &ProcessorConfig,
    st: &mut ExecState,
    vrf: &mut Vrf,
    mem: &mut Mem,
) -> Result<u64, SimError> {
    execute_impl(inst, cfg, st, vrf, mem, true)
}

/// [`execute`] with every fast path disabled: the retained per-element
/// reference interpreter the differential tests compare the compiled
/// micro-op engine (and `execute`'s own VX fast paths) against.
pub fn execute_reference(
    inst: &VInst,
    cfg: &ProcessorConfig,
    st: &mut ExecState,
    vrf: &mut Vrf,
    mem: &mut Mem,
) -> Result<u64, SimError> {
    execute_impl(inst, cfg, st, vrf, mem, false)
}

fn execute_impl(
    inst: &VInst,
    cfg: &ProcessorConfig,
    st: &mut ExecState,
    vrf: &mut Vrf,
    mem: &mut Mem,
    fast: bool,
) -> Result<u64, SimError> {
    match *inst {
        VInst::Scalar { .. } => Ok(0),
        VInst::SetVl { avl, sew, lmul } => {
            st.vtype = VType::new(sew, lmul);
            st.vl = st.vtype.apply(avl, vrf.vlenb() * 8);
            Ok(0)
        }
        VInst::Load { eew, vd, addr } => {
            check_alignment(&VInst::Load { eew, vd, addr }, st)?;
            let n = st.vl as usize * eew.bytes() as usize;
            // mem and vrf are disjoint structs: no copy needed (§Perf)
            vrf.slice_mut(vd, n).copy_from_slice(mem.read(addr, n)?);
            Ok(st.vl as u64)
        }
        VInst::Store { eew, vs3, addr } => {
            check_alignment(&VInst::Store { eew, vs3, addr }, st)?;
            let n = st.vl as usize * eew.bytes() as usize;
            mem.write(addr, vrf.slice(vs3, n))?;
            Ok(st.vl as u64)
        }
        VInst::OpVV { op, vd, vs2, vs1 } => {
            check_legal(op, cfg, st)?;
            check_alignment(inst, st)?;
            exec_arith(op, vd, vs2, Src::Vec(vs1), cfg, st, vrf, fast)
        }
        VInst::OpVX { op, vd, vs2, rs1 } => {
            check_legal(op, cfg, st)?;
            check_alignment(inst, st)?;
            exec_arith(op, vd, vs2, Src::Scalar(rs1), cfg, st, vrf, fast)
        }
        VInst::OpVI { op, vd, vs2, imm } => {
            check_legal(op, cfg, st)?;
            check_alignment(inst, st)?;
            let x = if matches!(
                op,
                VOp::Sll | VOp::Srl | VOp::Sra | VOp::NSrl | VOp::SlideDown | VOp::SlideUp
            ) {
                imm as u8 as u64 // uimm5
            } else {
                trunc(imm as i64 as u64, st.vtype.sew) // simm5, truncated at SEW
            };
            exec_arith(op, vd, vs2, Src::Scalar(x), cfg, st, vrf, fast)
        }
    }
}

enum Src {
    Vec(u8),
    Scalar(u64),
}

#[allow(clippy::too_many_arguments)]
fn exec_arith(
    op: VOp,
    vd: u8,
    vs2: u8,
    src: Src,
    cfg: &ProcessorConfig,
    st: &ExecState,
    vrf: &mut Vrf,
    fast: bool,
) -> Result<u64, SimError> {
    let sew = st.vtype.sew;
    let vl = st.vl;
    let shift = match op {
        VOp::Macsr => sew.bits() / 2,
        VOp::MacsrCfg => st.csr_shift.min(sew.bits() - 1),
        _ => 0,
    };
    let _ = cfg;
    match op {
        VOp::SlideDown | VOp::SlideUp => {
            let off = match src {
                Src::Scalar(x) => x,
                Src::Vec(_) => return Err(SimError::Unsupported("slide .vv form")),
            };
            if op == VOp::SlideUp && vd == vs2 {
                // RVV 1.0: vslideup vd must not overlap vs2
                return Err(SimError::Unsupported("vslideup with vd == vs2"));
            }
            let vlmax = st.vtype.vlmax(vrf.vlenb() * 8);
            if op == VOp::SlideDown {
                for i in 0..vl {
                    let j = i as u64 + off;
                    let v = if j < vlmax as u64 { vrf.get(vs2, j as u32, sew) } else { 0 };
                    vrf.set(vd, i, sew, v);
                }
            } else {
                // ascending would read already-written elements if vd==vs2
                for i in (0..vl).rev() {
                    if (i as u64) < off {
                        break; // elements below OFFSET keep vd's old value
                    }
                    let v = vrf.get(vs2, (i as u64 - off) as u32, sew);
                    vrf.set(vd, i, sew, v);
                }
            }
            Ok(vl as u64)
        }
        VOp::NSrl => {
            // vd(SEW)[i] = vs2(2*SEW)[i] >> sh — the builders use the
            // .wx/.wi forms only (shift is a static stream constant)
            let wide = sew.widened().ok_or(SimError::Unsupported("vnsrl at SEW=64"))?;
            let sh = match src {
                Src::Scalar(x) => x & (2 * sew.bits() as u64 - 1),
                Src::Vec(_) => return Err(SimError::Unsupported("vnsrl .wv form")),
            };
            // ascending element order — the defined semantic all three
            // engines share (for vd == vs2, the narrow write i ends at
            // (i+1)*eb <= the next wide read's start (i+1)*2*eb, so the
            // low-half overlap RVV allows is exact)
            for i in 0..vl {
                let a = vrf.get(vs2, i, wide);
                vrf.set(vd, i, sew, trunc(a >> sh, sew));
            }
            Ok(vl as u64)
        }
        VOp::WAdduWv => {
            let wide = sew.widened().ok_or(SimError::Unsupported("vwaddu.wv at SEW=64"))?;
            // descending: element i of the 2*SEW dest never overlaps a
            // not-yet-read source element of vs2 (vd group is distinct
            // by the alignment rules our builders follow)
            for i in 0..vl {
                let a = vrf.get(vs2, i, sew);
                let d = vrf.get(vd, i, wide);
                vrf.set(vd, i, wide, trunc(d.wrapping_add(a), wide));
            }
            Ok(vl as u64)
        }
        _ => {
            if fast {
                if let Src::Scalar(x) = src {
                    if exec_vx_fast(op, vd, vs2, trunc(x, sew), sew, vl, shift, vrf) {
                        return Ok(vl as u64);
                    }
                }
            }
            for i in 0..vl {
                let a = vrf.get(vs2, i, sew);
                let x = match src {
                    Src::Vec(v1) => vrf.get(v1, i, sew),
                    Src::Scalar(x) => trunc(x, sew),
                };
                let d = if op.reads_vd() { vrf.get(vd, i, sew) } else { 0 };
                vrf.set(vd, i, sew, scalar_op(op, a, x, d, sew, shift));
            }
            Ok(vl as u64)
        }
    }
}

/// §Perf fast path: monomorphic slice loops for the hot vector-scalar
/// ops at E8/E16 (the Algorithm-1 inner loop is >80% vmacsr/vmacc).
/// Falls back to the generic loop (returns false) for anything it does
/// not cover; the property tests in `conv_*` pin both paths to the same
/// goldens.
#[allow(clippy::too_many_arguments)]
fn exec_vx_fast(
    op: VOp,
    vd: u8,
    vs2: u8,
    x: u64,
    sew: Sew,
    vl: u32,
    shift: u32,
    vrf: &mut Vrf,
) -> bool {
    if !matches!(sew, Sew::E8 | Sew::E16) {
        return false;
    }
    let eb = sew.bytes() as usize;
    let len = vl as usize * eb;

    // broadcast (vmv.v.i / vmv.v.x) — a plain fill
    if op == VOp::Mv {
        match sew {
            Sew::E8 => vrf.slice_mut(vd, len).fill(x as u8),
            Sew::E16 => {
                let b = (x as u16).to_le_bytes();
                for d in vrf.slice_mut(vd, len).chunks_exact_mut(2) {
                    d.copy_from_slice(&b);
                }
            }
            _ => unreachable!(),
        }
        return true;
    }

    macro_rules! lanes {
        ($t:ty, $w:expr, $f:expr) => {{
            if vd == vs2 {
                // elementwise in-place (a == old d for ternary ops)
                for d in vrf.slice_mut(vd, len).chunks_exact_mut($w) {
                    let a = <$t>::from_le_bytes(d.try_into().unwrap());
                    let r: $t = $f(a, a);
                    d.copy_from_slice(&r.to_le_bytes());
                }
                true
            } else if let Some((s, d)) = vrf.try_src_dst(vs2, vd, len) {
                for (dc, sc) in d.chunks_exact_mut($w).zip(s.chunks_exact($w)) {
                    let a = <$t>::from_le_bytes(sc.try_into().unwrap());
                    let dv = <$t>::from_le_bytes(dc.try_into().unwrap());
                    let r: $t = $f(a, dv);
                    dc.copy_from_slice(&r.to_le_bytes());
                }
                true
            } else {
                false // partially-overlapping groups: generic loop
            }
        }};
    }

    macro_rules! per_sew {
        ($f8:expr, $f16:expr) => {
            match sew {
                Sew::E8 => lanes!(u8, 1, $f8),
                Sew::E16 => lanes!(u16, 2, $f16),
                _ => unreachable!(),
            }
        };
    }

    match op {
        VOp::Macsr | VOp::MacsrCfg => {
            let (x8, x16, sh) = (x as u8, x as u16, shift);
            per_sew!(
                |a: u8, d: u8| d.wrapping_add(a.wrapping_mul(x8) >> sh),
                |a: u16, d: u16| d.wrapping_add(a.wrapping_mul(x16) >> sh)
            )
        }
        VOp::Macc => {
            let (x8, x16) = (x as u8, x as u16);
            per_sew!(
                |a: u8, d: u8| d.wrapping_add(a.wrapping_mul(x8)),
                |a: u16, d: u16| d.wrapping_add(a.wrapping_mul(x16))
            )
        }
        VOp::Mul => {
            let (x8, x16) = (x as u8, x as u16);
            per_sew!(|a: u8, _| a.wrapping_mul(x8), |a: u16, _| a.wrapping_mul(x16))
        }
        VOp::Add => {
            let (x8, x16) = (x as u8, x as u16);
            per_sew!(|a: u8, _| a.wrapping_add(x8), |a: u16, _| a.wrapping_add(x16))
        }
        VOp::Or => {
            let (x8, x16) = (x as u8, x as u16);
            per_sew!(|a: u8, _| a | x8, |a: u16, _| a | x16)
        }
        VOp::And => {
            let (x8, x16) = (x as u8, x as u16);
            per_sew!(|a: u8, _| a & x8, |a: u16, _| a & x16)
        }
        VOp::Sll => {
            let sh = (x & (sew.bits() as u64 - 1)) as u32;
            per_sew!(|a: u8, _| a << sh, |a: u16, _| a << sh)
        }
        VOp::Srl => {
            let sh = (x & (sew.bits() as u64 - 1)) as u32;
            per_sew!(|a: u8, _| a >> sh, |a: u16, _| a >> sh)
        }
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (ProcessorConfig, ExecState, Vrf, Mem) {
        let cfg = ProcessorConfig::sparq_cfgshift();
        let st = ExecState::default();
        let vrf = Vrf::new(4096);
        let mem = Mem::new(1 << 16);
        (cfg, st, vrf, mem)
    }

    fn setvl(st: &mut ExecState, vrf: &Vrf, avl: u64, sew: Sew) {
        st.vtype = VType::new(sew, Lmul::M1);
        st.vl = st.vtype.apply(avl, vrf.vlenb() * 8);
    }

    #[test]
    fn vmacsr_matches_papers_formula() {
        // Vd <- Vd + ((Vs1 x Vs2 mod 2^16) >> 8): the ULPPACK trick.
        let (cfg, mut st, mut vrf, mut mem) = setup();
        setvl(&mut st, &vrf, 4, Sew::E16);
        // a = a0 + a1<<8, w = w1 + w0<<8 with (a0,a1,w0,w1)=(3,2,1,2)
        let a = 3u64 | (2 << 8);
        let w = 2u64 | (1 << 8);
        vrf.set(2, 0, Sew::E16, a);
        vrf.set(1, 0, Sew::E16, 100); // pre-existing accumulator
        let i = VInst::OpVX { op: VOp::Macsr, vd: 1, vs2: 2, rs1: w };
        execute(&i, &cfg, &mut st, &mut vrf, &mut mem).unwrap();
        // dot = a0*w0 + a1*w1 = 3 + 4 = 7; junk a0*w1 = 6 (< 256)
        assert_eq!(vrf.get(1, 0, Sew::E16), 107);
    }

    #[test]
    fn vmacc_wraps_modulo_sew() {
        let (cfg, mut st, mut vrf, mut mem) = setup();
        setvl(&mut st, &vrf, 1, Sew::E8);
        vrf.set(2, 0, Sew::E8, 200);
        vrf.set(1, 0, Sew::E8, 100);
        let i = VInst::OpVX { op: VOp::Macc, vd: 1, vs2: 2, rs1: 2 };
        execute(&i, &cfg, &mut st, &mut vrf, &mut mem).unwrap();
        assert_eq!(vrf.get(1, 0, Sew::E8), (100u64 + 400) % 256);
    }

    #[test]
    fn slidedown_pulls_in_zero_past_vlmax() {
        let (cfg, mut st, mut vrf, mut mem) = setup();
        setvl(&mut st, &vrf, 256, Sew::E16); // vlmax for VLEN=4096
        for i in 0..256 {
            vrf.set(4, i, Sew::E16, i as u64 + 1);
        }
        let i = VInst::OpVI { op: VOp::SlideDown, vd: 4, vs2: 4, imm: 1 };
        execute(&i, &cfg, &mut st, &mut vrf, &mut mem).unwrap();
        assert_eq!(vrf.get(4, 0, Sew::E16), 2);
        assert_eq!(vrf.get(4, 254, Sew::E16), 256);
        assert_eq!(vrf.get(4, 255, Sew::E16), 0); // past vlmax
    }

    #[test]
    fn slidedown_reads_beyond_vl_within_vlmax() {
        let (cfg, mut st, mut vrf, mut mem) = setup();
        setvl(&mut st, &vrf, 4, Sew::E16);
        for i in 0..8 {
            vrf.set(4, i, Sew::E16, 10 + i as u64);
        }
        let i = VInst::OpVI { op: VOp::SlideDown, vd: 2, vs2: 4, imm: 2 };
        execute(&i, &cfg, &mut st, &mut vrf, &mut mem).unwrap();
        // element vl-1 comes from vs2[vl+1], which is beyond vl but valid
        assert_eq!(vrf.get(2, 3, Sew::E16), 15);
    }

    #[test]
    fn slideup_preserves_low_elements() {
        let (cfg, mut st, mut vrf, mut mem) = setup();
        setvl(&mut st, &vrf, 4, Sew::E16);
        for i in 0..4 {
            vrf.set(4, i, Sew::E16, i as u64 + 1);
            vrf.set(6, i, Sew::E16, 99);
        }
        let i = VInst::OpVI { op: VOp::SlideUp, vd: 6, vs2: 4, imm: 2 };
        execute(&i, &cfg, &mut st, &mut vrf, &mut mem).unwrap();
        assert_eq!(vrf.get(6, 0, Sew::E16), 99);
        assert_eq!(vrf.get(6, 1, Sew::E16), 99);
        assert_eq!(vrf.get(6, 2, Sew::E16), 1);
        assert_eq!(vrf.get(6, 3, Sew::E16), 2);
    }

    #[test]
    fn fp_ops_trap_without_fpu() {
        let cfg = ProcessorConfig::sparq();
        let mut st = ExecState::default();
        let mut vrf = Vrf::new(4096);
        let mut mem = Mem::new(1024);
        setvl(&mut st, &vrf, 4, Sew::E32);
        let i = VInst::OpVV { op: VOp::FMacc, vd: 1, vs2: 2, vs1: 3 };
        assert!(matches!(execute(&i, &cfg, &mut st, &mut vrf, &mut mem), Err(SimError::NoFpu(_))));
    }

    #[test]
    fn vmacsr_traps_on_ara() {
        let cfg = ProcessorConfig::ara();
        let mut st = ExecState::default();
        let mut vrf = Vrf::new(4096);
        let mut mem = Mem::new(1024);
        setvl(&mut st, &vrf, 4, Sew::E16);
        let i = VInst::OpVX { op: VOp::Macsr, vd: 1, vs2: 2, rs1: 3 };
        assert_eq!(execute(&i, &cfg, &mut st, &mut vrf, &mut mem), Err(SimError::NoVmacsr));
    }

    #[test]
    fn misaligned_group_trap() {
        let (cfg, mut st, mut vrf, mut mem) = setup();
        st.vtype = VType::new(Sew::E16, Lmul::M4);
        st.vl = 100;
        let i = VInst::OpVV { op: VOp::Add, vd: 2, vs2: 4, vs1: 8 };
        assert!(matches!(
            execute(&i, &cfg, &mut st, &mut vrf, &mut mem),
            Err(SimError::Misaligned { reg: 2, .. })
        ));
    }

    #[test]
    fn wadduwv_accumulates_at_double_width() {
        let (cfg, mut st, mut vrf, mut mem) = setup();
        setvl(&mut st, &vrf, 3, Sew::E16);
        for i in 0..3 {
            vrf.set(4, i, Sew::E16, 0xFFFF); // max u16
            vrf.set(8, i, Sew::E32, 10);
        }
        let i = VInst::OpVV { op: VOp::WAdduWv, vd: 8, vs2: 4, vs1: 0 };
        execute(&i, &cfg, &mut st, &mut vrf, &mut mem).unwrap();
        for i in 0..3 {
            assert_eq!(vrf.get(8, i, Sew::E32), 10 + 0xFFFF);
        }
    }

    #[test]
    fn nsrl_narrows_wide_pairs() {
        // the deinterleave idiom: at SEW=E16, vs2 is an E32 view; shift
        // 0 extracts even E16 elements, shift 16 the odd ones
        let (cfg, mut st, mut vrf, mut mem) = setup();
        setvl(&mut st, &vrf, 4, Sew::E16);
        for i in 0..8 {
            vrf.set(8, i, Sew::E16, 100 + i as u64);
        }
        let even = VInst::OpVI { op: VOp::NSrl, vd: 0, vs2: 8, imm: 0 };
        execute(&even, &cfg, &mut st, &mut vrf, &mut mem).unwrap();
        let odd = VInst::OpVI { op: VOp::NSrl, vd: 2, vs2: 8, imm: 16 };
        execute(&odd, &cfg, &mut st, &mut vrf, &mut mem).unwrap();
        for i in 0..4 {
            assert_eq!(vrf.get(0, i, Sew::E16), 100 + 2 * i as u64);
            assert_eq!(vrf.get(2, i, Sew::E16), 101 + 2 * i as u64);
        }
        // true narrowing: a wide value's high half is dropped at shift 0
        vrf.set(8, 0, Sew::E32, 0xABCD_1234);
        execute(&even, &cfg, &mut st, &mut vrf, &mut mem).unwrap();
        assert_eq!(vrf.get(0, 0, Sew::E16), 0x1234);
        // .wv form is not modelled (builders use static shift amounts)
        let vv = VInst::OpVV { op: VOp::NSrl, vd: 0, vs2: 8, vs1: 4 };
        assert!(execute(&vv, &cfg, &mut st, &mut vrf, &mut mem).is_err());
    }

    #[test]
    fn nsrl_misaligned_wide_source_traps() {
        let (cfg, mut st, mut vrf, mut mem) = setup();
        setvl(&mut st, &vrf, 4, Sew::E16);
        // vs2 must be aligned to the 2*LMUL wide group
        let i = VInst::OpVI { op: VOp::NSrl, vd: 0, vs2: 9, imm: 0 };
        assert!(matches!(
            execute(&i, &cfg, &mut st, &mut vrf, &mut mem),
            Err(SimError::Misaligned { reg: 9, .. })
        ));
    }

    #[test]
    fn load_store_roundtrip_through_vrf() {
        let (cfg, mut st, mut vrf, mut mem) = setup();
        mem.write_u16s(256, &[5, 6, 7, 8]).unwrap();
        setvl(&mut st, &vrf, 4, Sew::E16);
        execute(&VInst::Load { eew: Sew::E16, vd: 3, addr: 256 }, &cfg, &mut st, &mut vrf, &mut mem)
            .unwrap();
        execute(&VInst::Store { eew: Sew::E16, vs3: 3, addr: 512 }, &cfg, &mut st, &mut vrf, &mut mem)
            .unwrap();
        assert_eq!(mem.read_u16s(512, 4).unwrap(), vec![5, 6, 7, 8]);
    }

    #[test]
    fn fp_macc_is_ieee_f32() {
        let cfg = ProcessorConfig::ara();
        let mut st = ExecState::default();
        let mut vrf = Vrf::new(4096);
        let mut mem = Mem::new(1024);
        setvl(&mut st, &vrf, 1, Sew::E32);
        vrf.set(2, 0, Sew::E32, 1.5f32.to_bits() as u64);
        vrf.set(1, 0, Sew::E32, 0.25f32.to_bits() as u64);
        let i = VInst::OpVX { op: VOp::FMacc, vd: 1, vs2: 2, rs1: 2.0f32.to_bits() as u64 };
        execute(&i, &cfg, &mut st, &mut vrf, &mut mem).unwrap();
        assert_eq!(f32::from_bits(vrf.get(1, 0, Sew::E32) as u32), 0.25 + 1.5 * 2.0);
    }

    #[test]
    fn cfg_shifter_uses_csr() {
        let (cfg, mut st, mut vrf, mut mem) = setup();
        setvl(&mut st, &vrf, 1, Sew::E16);
        st.csr_shift = 4;
        vrf.set(2, 0, Sew::E16, 0x100);
        let i = VInst::OpVX { op: VOp::MacsrCfg, vd: 1, vs2: 2, rs1: 1 };
        execute(&i, &cfg, &mut st, &mut vrf, &mut mem).unwrap();
        assert_eq!(vrf.get(1, 0, Sew::E16), 0x10);
    }
}
