//! The vector register file: 32 architectural registers of VLEN bits,
//! stored as raw bytes (exactly like the banked SRAM slices of an Ara
//! lane, minus the banking — the timing model accounts for bandwidth).

use super::SimError;
use crate::isa::Sew;

#[derive(Debug, Clone)]
pub struct Vrf {
    bytes: Vec<u8>,
    vlenb: u32,
}

impl Vrf {
    pub fn new(vlen_bits: u32) -> Vrf {
        assert!(vlen_bits % 64 == 0, "VLEN must be a multiple of 64");
        Vrf { bytes: vec![0; (vlen_bits / 8 * 32) as usize], vlenb: vlen_bits / 8 }
    }

    /// VLEN in bytes.
    pub fn vlenb(&self) -> u32 {
        self.vlenb
    }

    /// Zero every register (machine-pool reset).
    pub fn clear(&mut self) {
        self.bytes.fill(0);
    }

    #[inline]
    pub(crate) fn base(&self, v: u8) -> usize {
        v as usize * self.vlenb as usize
    }

    /// Flat byte view of the whole register file (the micro-op engine
    /// computes register-group offsets itself — see [`super::uop`]).
    #[inline]
    pub(crate) fn flat(&self) -> &[u8] {
        &self.bytes
    }

    #[inline]
    pub(crate) fn flat_mut(&mut self) -> &mut [u8] {
        &mut self.bytes
    }

    /// Typed bounds check for a `len`-byte access to the group at `v` —
    /// the compile-path promotion of the `debug_assert!`s in
    /// [`Vrf::get`]/[`Vrf::set`]: `sim::uop` validates every access
    /// range once at compile time (through [`Vrf::check_group_for`],
    /// which needs only the VLEN) and reports
    /// [`SimError::GroupPastV31`] instead of a run-time panic, which
    /// keeps the run-time loops check-free.
    pub fn check_group(&self, v: u8, len: usize, lmul: u32) -> Result<(), SimError> {
        Vrf::check_group_for(self.vlenb as usize, v, len, lmul)
    }

    /// [`Vrf::check_group`] without a register file in hand (`vlenb` in
    /// bytes) — what `sim::uop::CompiledProgram::compile` calls, since
    /// compilation happens before any machine exists.
    pub fn check_group_for(vlenb: usize, v: u8, len: usize, lmul: u32) -> Result<(), SimError> {
        if v as usize * vlenb + len > 32 * vlenb {
            return Err(SimError::GroupPastV31 { reg: v, lmul });
        }
        Ok(())
    }

    /// Read element `i` of register group starting at `v` (flows across
    /// register boundaries like an LMUL group does), zero-extended.
    #[inline]
    pub fn get(&self, v: u8, i: u32, sew: Sew) -> u64 {
        let eb = sew.bytes() as usize;
        let off = self.base(v) + i as usize * eb;
        debug_assert!(off + eb <= self.bytes.len(), "VRF read past v31");
        let mut b = [0u8; 8];
        b[..eb].copy_from_slice(&self.bytes[off..off + eb]);
        u64::from_le_bytes(b)
    }

    /// Write element `i` of register group `v` (truncating to SEW).
    #[inline]
    pub fn set(&mut self, v: u8, i: u32, sew: Sew, val: u64) {
        let eb = sew.bytes() as usize;
        let off = self.base(v) + i as usize * eb;
        debug_assert!(off + eb <= self.bytes.len(), "VRF write past v31");
        self.bytes[off..off + eb].copy_from_slice(&val.to_le_bytes()[..eb]);
    }

    /// Raw byte view of a register group of `regs` registers (hot-path
    /// bulk ops: loads/stores/moves).
    pub fn slice(&self, v: u8, len: usize) -> &[u8] {
        &self.bytes[self.base(v)..self.base(v) + len]
    }

    pub fn slice_mut(&mut self, v: u8, len: usize) -> &mut [u8] {
        let b = self.base(v);
        &mut self.bytes[b..b + len]
    }

    /// Non-panicking split borrow: `None` when the byte ranges overlap.
    pub fn try_src_dst(&mut self, src: u8, dst: u8, len: usize) -> Option<(&[u8], &mut [u8])> {
        let (s, d) = (self.base(src), self.base(dst));
        if !(s + len <= d || d + len <= s) {
            return None;
        }
        Some(self.src_dst(src, dst, len))
    }

    /// Split-borrow two distinct register groups (src, dst) for bulk
    /// copies without allocation.  Panics if the groups overlap.
    pub fn src_dst(&mut self, src: u8, dst: u8, len: usize) -> (&[u8], &mut [u8]) {
        let (s, d) = (self.base(src), self.base(dst));
        assert!(s + len <= d || d + len <= s, "overlapping register groups");
        if s < d {
            let (a, b) = self.bytes.split_at_mut(d);
            (&a[s..s + len], &mut b[..len])
        } else {
            let (a, b) = self.bytes.split_at_mut(s);
            (&b[..len], &mut a[d..d + len])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn element_roundtrip_across_sews() {
        let mut vrf = Vrf::new(4096);
        for sew in [Sew::E8, Sew::E16, Sew::E32, Sew::E64] {
            vrf.set(4, 3, sew, 0xAB);
            assert_eq!(vrf.get(4, 3, sew), 0xAB);
        }
    }

    #[test]
    fn truncates_to_sew() {
        let mut vrf = Vrf::new(4096);
        vrf.set(0, 0, Sew::E8, 0x1FF);
        assert_eq!(vrf.get(0, 0, Sew::E8), 0xFF);
    }

    #[test]
    fn group_flows_across_register_boundary() {
        // element VLEN/SEW of a group lands in the next register
        let mut vrf = Vrf::new(256); // 32B per reg => 16 e16 elements
        vrf.set(2, 16, Sew::E16, 0x1234); // first element of v3
        assert_eq!(vrf.get(3, 0, Sew::E16), 0x1234);
    }

    #[test]
    fn check_group_is_typed_where_get_would_assert() {
        let vrf = Vrf::new(256); // 32 B/reg, 1 KiB total
        assert!(vrf.check_group(24, 8 * 32, 8).is_ok()); // v24..v31 exactly
        assert_eq!(
            vrf.check_group(24, 8 * 32 + 1, 8),
            Err(SimError::GroupPastV31 { reg: 24, lmul: 8 })
        );
        // the eew-wider-than-sew load shape: v31 + 2 registers' worth
        assert_eq!(
            vrf.check_group(31, 64, 1),
            Err(SimError::GroupPastV31 { reg: 31, lmul: 1 })
        );
    }

    #[test]
    fn split_borrow_disjoint() {
        let mut vrf = Vrf::new(256);
        vrf.set(1, 0, Sew::E8, 7);
        let (s, d) = vrf.src_dst(1, 5, 32);
        assert_eq!(s[0], 7);
        assert_eq!(d.len(), 32);
    }

    #[test]
    #[should_panic(expected = "overlapping")]
    fn split_borrow_overlap_panics() {
        let mut vrf = Vrf::new(256);
        let _ = vrf.src_dst(1, 2, 64); // 2 regs each, overlapping
    }
}
