//! §Perf: micro-op pre-compilation + word-parallel (SWAR) execution —
//! the simulator's serving hot path (DESIGN.md §Perf).
//!
//! [`Machine::run`] re-validates legality and alignment per instruction
//! on every execution and, outside a few VX fast paths, walks elements
//! one at a time through a per-element `match op`.  For
//! compile-once/execute-many serving that work is pure waste: the
//! instruction stream is fixed, so everything that does not depend on
//! run-time *data* can be resolved exactly once.
//!
//! [`CompiledProgram::compile`] folds the `vsetvli` state machine
//! forward through the trace, runs `check_legal`/`check_alignment`
//! once, resolves shift amounts, operand kinds, byte counts and flat
//! VRF offsets, validates every register-group byte range (the typed
//! promotion of the `debug_assert!`s in `Vrf::get`/`set` — see
//! [`super::vrf::Vrf::check_group`]), and pre-selects an execution
//! strategy per instruction:
//!
//! * **Bulk** — loads, stores, broadcasts, copies and slides become
//!   `copy_from_slice`/`copy_within`/`fill` over the flat VRF bytes.
//! * **Swar** — add/sub/and/or/xor/shift lanes ride in one `u64` word
//!   (8 lanes at E8) with carry masking at the lane boundaries, and the
//!   vector-scalar multiply family (vmul/vmacc/vnmsac/vmacsr) uses the
//!   ULPPACK trick *on the host*: lanes are spread into spaced fields
//!   and one scalar `u64` multiply computes 4 lane products at E8.
//! * **Generic** — a monomorphic per-element loop over the retained
//!   [`exec::scalar_op`] semantics for the cold ops (mulh/min/max/sra,
//!   fp, overlapping slides, widening adds).
//!
//! ## Superinstruction fusion + flat execution plans
//!
//! On top of the micro-op stream, compile time builds an `ExecPlan`:
//!
//! * **Nop compaction** — scalar-slot micro-ops have no architectural
//!   effect; the plan's step stream drops them entirely (their
//!   dispatch cycles live on in the precomputed totals).
//! * **Superinstruction fusion** — an idempotent pass collapses
//!   recurring bulk runs into fused blocks executed as one sweep per
//!   *run*: loads/stores contiguous in memory (one span bounds check +
//!   raw per-member copies, the requant zero-fill and im2col idioms),
//!   and fills/copies contiguous in the flat VRF (one merged word
//!   sweep).  A `vsetvli` absorbed inside a run is applied once after
//!   the block — members never read the live state.
//! * **Precomputed timing** — [`Timing`] consumes only the
//!   compile-time `Acct` values, never run-time data (see the
//!   `sim::timing` module docs), so the *entire* [`Stats`] of a
//!   successful run is a compile-time constant.  The plan replays the
//!   acct stream once at build time, records a cycle total per block,
//!   and [`Machine::run_compiled`] returns the precomputed totals —
//!   execution is data movement plus pointer arithmetic, no per-uop
//!   accounting at all.
//!
//! The PR-2 per-uop engine is retained as
//! [`Machine::run_compiled_unfused`] (it re-derives timing at run
//! time), both as the bench baseline for the fused plan and as a
//! fourth engine in the differential fuzz matrix.  The invariant —
//! pinned by `rust/tests/exec_diff.rs` (including its fusion-boundary
//! corpus) and every conv golden test — is that outputs, memory, *and
//! cycle counts* are bit-identical across all engines, unbatched and
//! rebased.
//!
//! ## Why ascending word loops are exact under group overlap
//!
//! Register-group base offsets are multiples of VLENB, and VLENB is a
//! multiple of 8 bytes (`Vrf::new` asserts VLEN % 64 == 0), so any
//! aliasing between a destination and a source group has a byte offset
//! that is a multiple of 8: an element can never alias another element
//! *inside the same 8-byte word*.  An ascending word loop that reads
//! its operand words and then writes its destination word therefore
//! observes exactly the same values as the reference's ascending
//! per-element loop, for every overlap pattern the ISA allows.

use super::exec::{self, ExecState};
use super::mem::Mem;
use super::stats::{FusedCounts, RunReport, Stats};
use super::timing::Timing;
use super::vrf::Vrf;
use super::{Machine, Program, SimError};
use crate::arch::{ProcessorConfig, Unit};
use crate::isa::{Sew, VInst, VOp, VType};

/// Execution strategy pre-selected at compile time (diagnostics; the
/// real dispatch is the [`Exec`] variant itself).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Bulk byte moves: loads/stores/broadcasts/copies/slides.
    Bulk,
    /// Word-parallel lanes: SWAR ALU ops and the multiply tricks.
    Swar,
    /// Monomorphic per-element loop (cold ops, overlapping slides).
    Generic,
}

/// Named micro-op counts per execution strategy (the former anonymous
/// `(bulk, swar, generic)` 3-tuple, grown a `fused` lane).  A bulk
/// micro-op absorbed into a multi-member fused block moves from the
/// `bulk` lane to the `fused` lane, so the four lanes still sum to the
/// number of strategy-bearing micro-ops in the stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StrategyCounts {
    /// Bulk byte moves dispatched per uop (incl. slides, which never
    /// fuse).
    pub bulk: usize,
    /// Word-parallel SWAR lanes.
    pub swar: usize,
    /// Monomorphic per-element loops.
    pub generic: usize,
    /// Bulk micro-ops executing as members of fused superinstruction
    /// blocks.
    pub fused: usize,
}

/// Fully resolved shift amount for the vmacsr family.
#[derive(Debug, Clone, Copy)]
enum Shift {
    Fixed(u32),
    /// vmacsr.cfg: read the CSR at execution time (the only run-time
    /// input besides the VRF/memory data itself).
    Csr,
}

impl Shift {
    #[inline]
    fn resolve(self, st: &ExecState, sew: Sew) -> u32 {
        match self {
            Shift::Fixed(s) => s,
            Shift::Csr => st.csr_shift.min(sew.bits() - 1),
        }
    }
}

/// The vs1/rs1/imm operand of a word loop: either a pre-splatted
/// scalar (its truncated value repeated across the 64-bit word) or the
/// flat VRF byte offset of the source vector group.
#[derive(Debug, Clone, Copy)]
enum Operand {
    Splat(u64),
    Vec(usize),
}

/// Word-parallel ALU ops (shift amounts resolved at compile time).
#[derive(Debug, Clone, Copy)]
enum AluWord {
    Add,
    Sub,
    And,
    Or,
    Xor,
    Sll(u32),
    Srl(u32),
}

/// The multiply family, with vmacsr.cfg folded into `Macsr` + a
/// [`Shift`].
#[derive(Debug, Clone, Copy)]
enum MulOp {
    Mul,
    Macc,
    Nmsac,
    Macsr,
}

/// The functional half of one micro-op.  All `usize` fields are flat
/// VRF byte offsets, pre-validated against the register-file size.
#[derive(Debug, Clone)]
enum Exec {
    /// Scalar slots: no architectural effect.
    Nop,
    /// `vsetvli`: fold the already-computed state into the machine.
    SetState { vl: u32, vtype: VType },
    Load { dst: usize, addr: u64, len: usize },
    Store { src: usize, addr: u64, len: usize },
    /// `vmv.v.x` / `vmv.v.i` broadcast.
    Fill { dst: usize, len: usize, splat: u64 },
    /// `vmv.v.v`: ascending word copy.
    Copy { dst: usize, src: usize, len: usize },
    /// Slide as one memmove + zero fill (identical or disjoint groups).
    SlideBulk { dst: usize, src: usize, copy: usize, zero: usize },
    /// Slide in exact reference element order (partial group overlap).
    SlideGen { down: bool, off: u64, dst: usize, src: usize, eb: usize, vl: u32, vlmax: u32 },
    /// SWAR word loop over add/sub/logic/shift lanes.
    Alu { op: AluWord, sew: Sew, dst: usize, a: usize, x: Operand, len: usize },
    /// Vector-scalar multiply family at E8/E16: spaced-field multiply
    /// (2 host multiplies per 64-bit word).
    MulVx { op: MulOp, sew: Sew, dst: usize, a: usize, x: u64, shift: Shift, len: usize },
    /// Multiply family, word-read lane loop (VV forms, E32/E64).
    MulLane { op: MulOp, sew: Sew, dst: usize, a: usize, x: Operand, shift: Shift, len: usize },
    /// `vwaddu.wv`: widening add-accumulate in reference element order.
    Wadd { dst: usize, src: usize, sew: Sew, vl: u32 },
    /// `vnsrl.w{x,i}`: narrowing shift in reference element order
    /// (shift amount resolved at compile time).
    Nsrl { dst: usize, src: usize, sew: Sew, vl: u32, sh: u32 },
    /// Monomorphic per-element fallback over [`exec::scalar_op`].
    Gen { op: VOp, sew: Sew, vl: u32, dst: usize, a: usize, x: Operand, eb: usize, shift: Shift, reads_vd: bool },
}

/// The timing half of one micro-op — everything `Machine::account`
/// derived from the architectural state, precomputed.
#[derive(Debug, Clone)]
enum Acct {
    Scalar { n: u32 },
    Mem { bytes: u64, reg: u8, lmul: u32, load: bool },
    Vec { unit: Unit, busy: u64, busy_cycles: u64, dst: Option<(u8, u32)>, srcs: [(u8, u32); 3], nsrcs: u8 },
}

#[derive(Debug, Clone)]
struct Uop {
    exec: Exec,
    acct: Acct,
    /// Element operations this micro-op contributes to the stats.
    ops: u64,
}

/// A trace pre-compiled for one processor configuration: legality,
/// alignment, vtype folding, operand resolution, strategy selection,
/// superinstruction fusion and the full timing replay all done once.
/// Execute it any number of times with [`Machine::run_compiled`] —
/// bit-identical (outputs and cycle counts) to [`Machine::run`] on the
/// original [`Program`].
#[derive(Debug, Clone)]
pub struct CompiledProgram {
    uops: Vec<Uop>,
    /// The fused flat execution plan (Nop-compacted steps, fused
    /// blocks, precomputed per-block cycles and run totals).
    plan: ExecPlan,
    /// The configuration the stream was validated against
    /// (`run_compiled` rejects a machine with any other config).
    pub cfg: ProcessorConfig,
    pub macs: u64,
    pub label: String,
    counts: StrategyCounts,
    /// True when some vector instruction was lowered under the
    /// *initial* (default) vtype/vl — i.e. before the stream's first
    /// `vsetvli`.  Such a program is only valid on a machine whose
    /// architectural state is still the reset state; `run_compiled`
    /// enforces that instead of silently diverging from the
    /// interpreter (which reads the live state).
    needs_default_entry: bool,
}

impl CompiledProgram {
    /// Compile `prog` for `cfg`.  Errors the interpreter would raise
    /// mid-run (illegal instruction, misaligned group, group past v31
    /// — including ranges the interpreter only catches as a
    /// `debug_assert!`, e.g. a load at EEW wider than SEW running past
    /// the register file) surface here as typed [`SimError`]s instead.
    pub fn compile(prog: &Program, cfg: &ProcessorConfig) -> Result<CompiledProgram, SimError> {
        let vlenb = (cfg.vlen_bits / 8) as usize;
        let bpc = cfg.bytes_per_cycle() as u64;
        let mut st = ExecState::default();
        let mut uops = Vec::with_capacity(prog.insts.len());
        let mut counts = StrategyCounts::default();
        let mut saw_setvl = false;
        let mut needs_default_entry = false;
        for inst in &prog.insts {
            saw_setvl |= matches!(inst, VInst::SetVl { .. });
            // a vector instruction before the first vsetvli was folded
            // against the *default* state: remember that the program
            // only replays correctly from a reset machine
            needs_default_entry |=
                !saw_setvl && !matches!(inst, VInst::Scalar { .. });
            let uop = lower(inst, cfg, &mut st, vlenb, bpc)?;
            match strategy_of(&uop.exec) {
                Some(Strategy::Bulk) => counts.bulk += 1,
                Some(Strategy::Swar) => counts.swar += 1,
                Some(Strategy::Generic) => counts.generic += 1,
                None => {}
            }
            uops.push(uop);
        }
        let plan = ExecPlan::build(&uops, cfg);
        // every fused member is a bulk op: move them to the fused lane
        counts.fused = plan.fused_uops as usize;
        counts.bulk -= counts.fused;
        Ok(CompiledProgram {
            uops,
            plan,
            cfg: cfg.clone(),
            macs: prog.macs,
            label: prog.label.clone(),
            counts,
            needs_default_entry,
        })
    }

    pub fn len(&self) -> usize {
        self.uops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.uops.is_empty()
    }

    /// Micro-op counts per strategy — how much of the stream landed on
    /// each engine lane (diagnostics and perf tests).
    pub fn strategy_counts(&self) -> StrategyCounts {
        self.counts
    }

    /// Execution-plan shape: `(blocks, fused_blocks, fused_uops,
    /// block_cycle_sum)`.  The per-block cycle advances are precomputed
    /// at compile time and partition the run, so `block_cycle_sum`
    /// equals the precomputed cycle total — the invariant the plan
    /// engine's constant-time timing rests on (pinned by unit tests).
    pub fn plan_stats(&self) -> (usize, u64, u64, u64) {
        let sum = self.plan.blocks.iter().map(|b| b.cycles).sum();
        (self.plan.blocks.len(), self.plan.fused_blocks, self.plan.fused_uops, sum)
    }
}

// ---------------------------------------------------------------- plan

/// The flat execution plan of one compiled program: a Nop-compacted
/// step stream partitioned into blocks, with the whole-run [`Stats`]
/// precomputed.  Built once at compile time; executing it is pure data
/// movement.
#[derive(Debug, Clone)]
struct ExecPlan {
    /// The functional steps, with `Exec::Nop` dropped (scalar slots
    /// have no architectural effect; their cycles live in `totals`).
    steps: Vec<Exec>,
    /// Partition of `steps` (lo/hi are step indices) into per-step
    /// `Seq` stretches and fused runs.
    blocks: Vec<Block>,
    /// Stats of one complete successful run — a compile-time constant
    /// because [`Timing`] never reads run-time data (the acct stream
    /// is replayed once at build time).
    totals: Stats,
    /// Multi-member fused blocks in the plan.
    fused_blocks: u64,
    /// Bulk micro-ops absorbed by those blocks.
    fused_uops: u64,
}

/// One plan block: a step range plus how to execute it.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Block {
    lo: u32,
    hi: u32,
    /// Timing-horizon advance across this block (precomputed; the
    /// per-block aggregate behind `CompiledProgram::plan_stats`).
    cycles: u64,
    /// Last `vsetvli` absorbed into a fused run, applied once after
    /// the block (run members never read the live vl/vtype).
    state: Option<(u32, VType)>,
    kind: BlockKind,
}

/// How a block executes.  The fused kinds hold the precomputed merged
/// ranges; rebase offsets are applied once per block.
#[derive(Debug, Clone, PartialEq, Eq)]
enum BlockKind {
    /// Step-by-step dispatch of `steps[lo..hi]`.
    Seq,
    /// Loads contiguous in memory: one span read, one copy per member
    /// (member dsts are arbitrary; they are written in member order).
    LoadRun { addr: u64, total: usize },
    /// Stores contiguous in memory: one merged bounds check, then raw
    /// member copies (member srcs are arbitrary — the zero-fill idiom
    /// stores the same register repeatedly).
    StoreRun { addr: u64, total: usize },
    /// Broadcasts of one splat word to a contiguous flat-VRF range.
    FillRun { dst: usize, len: usize, splat: u64 },
    /// Register copies contiguous on both sides (constant src-dst
    /// delta, a multiple of VLENB — exact under overlap, see the
    /// module docs).
    CopyRun { dst: usize, src: usize, len: usize },
}

impl ExecPlan {
    fn build(uops: &[Uop], cfg: &ProcessorConfig) -> ExecPlan {
        // 1. compact: drop Nops, remember each step's source uop index
        let mut steps = Vec::with_capacity(uops.len());
        let mut step_uop = Vec::with_capacity(uops.len());
        for (i, u) in uops.iter().enumerate() {
            if !matches!(u.exec, Exec::Nop) {
                steps.push(u.exec.clone());
                step_uop.push(i as u32);
            }
        }
        // 2. singleton Seq blocks, then the (idempotent) fusion pass
        let singles: Vec<Block> = (0..steps.len() as u32)
            .map(|i| Block { lo: i, hi: i + 1, cycles: 0, state: None, kind: BlockKind::Seq })
            .collect();
        let mut blocks = fuse(&steps, singles);
        // 3. replay the acct stream exactly once: per-block cycle
        //    advances plus the whole-run totals.  Each block accounts
        //    its own uops and any Nops compacted out before them; the
        //    last block also absorbs the trailing Nops.
        let mut timing = Timing::new(cfg);
        let mut totals = Stats::default();
        let mut fused_blocks = 0u64;
        let mut fused_uops = 0u64;
        let mut next_uop = 0usize;
        let nblocks = blocks.len();
        for (bi, b) in blocks.iter_mut().enumerate() {
            let end_uop = if bi + 1 == nblocks {
                uops.len()
            } else {
                step_uop[b.hi as usize - 1] as usize + 1
            };
            let h0 = timing.cycles();
            for u in &uops[next_uop..end_uop] {
                account_uop(u, &mut timing, &mut totals);
            }
            b.cycles = timing.cycles() - h0;
            next_uop = end_uop;
            if b.kind != BlockKind::Seq {
                fused_blocks += 1;
                fused_uops += steps[b.lo as usize..b.hi as usize]
                    .iter()
                    .filter(|e| run_seed(e).is_some())
                    .count() as u64;
            }
        }
        // all-Nop (or empty) programs have no steps and no blocks —
        // account the whole stream here instead
        for u in &uops[next_uop..] {
            account_uop(u, &mut timing, &mut totals);
        }
        totals.cycles = timing.cycles();
        totals.raw_stall_cycles = timing.raw_stalls;
        ExecPlan { steps, blocks, totals, fused_blocks, fused_uops }
    }
}

/// Can this step seed a fused run (and with which merged range)?
fn run_seed(e: &Exec) -> Option<BlockKind> {
    match *e {
        Exec::Load { addr, len, .. } => Some(BlockKind::LoadRun { addr, total: len }),
        Exec::Store { addr, len, .. } => Some(BlockKind::StoreRun { addr, total: len }),
        Exec::Fill { dst, len, splat } => Some(BlockKind::FillRun { dst, len, splat }),
        Exec::Copy { dst, src, len } => Some(BlockKind::CopyRun { dst, src, len }),
        _ => None,
    }
}

/// Try to absorb `e` as the next member of a run, growing the merged
/// range.  The rules are the exactness arguments documented in
/// DESIGN.md §Perf: loads/stores need only *memory* contiguity (their
/// VRF sides are applied in member order), while fills/copies need
/// flat-VRF contiguity, which pins every interior member boundary to a
/// multiple of VLENB (a multiple of 8) — so the merged word sweep
/// writes exactly the bytes the per-member sweeps would.
fn run_extend(kind: &mut BlockKind, e: &Exec) -> bool {
    match (kind, e) {
        (BlockKind::LoadRun { addr, total }, &Exec::Load { addr: a, len, .. })
            if addr.checked_add(*total as u64) == Some(a) =>
        {
            *total += len;
            true
        }
        (BlockKind::StoreRun { addr, total }, &Exec::Store { addr: a, len, .. })
            if addr.checked_add(*total as u64) == Some(a) =>
        {
            *total += len;
            true
        }
        (BlockKind::FillRun { dst, len, splat }, &Exec::Fill { dst: d, len: l, splat: s })
            if d == *dst + *len && s == *splat && *len % 8 == 0 =>
        {
            *len += l;
            true
        }
        (BlockKind::CopyRun { dst, src, len }, &Exec::Copy { dst: d, src: sc, len: l })
            if d == *dst + *len && sc == *src + *len && *len % 8 == 0 =>
        {
            *len += l;
            true
        }
        _ => false,
    }
}

/// Emit a block, merging adjacent `Seq` blocks (their step ranges are
/// contiguous by construction; cycle advances add).
fn push_block(out: &mut Vec<Block>, b: Block) {
    if b.kind == BlockKind::Seq {
        if let Some(last) = out.last_mut() {
            if last.kind == BlockKind::Seq && last.hi == b.lo {
                last.hi = b.hi;
                last.cycles += b.cycles;
                return;
            }
        }
    }
    out.push(b);
}

/// The superinstruction fusion pass.  Input: blocks partitioning the
/// step stream (initially all singleton `Seq`).  Output: the same
/// partition with contiguous bulk runs collapsed into fused blocks and
/// adjacent `Seq` blocks merged.  `vsetvli` steps between members are
/// absorbed (last one wins, applied after the block); a pending one
/// not followed by a committing member is left outside the run.
///
/// The pass is idempotent by construction: only *singleton* `Seq`
/// blocks seed or extend runs, multi-step `Seq` and fused blocks pass
/// through untouched, and the output never contains two adjacent
/// blocks that could still merge (they would have merged here) — so
/// `fuse(steps, fuse(steps, x)) == fuse(steps, x)`, pinned by a unit
/// test.
fn fuse(steps: &[Exec], blocks: Vec<Block>) -> Vec<Block> {
    let mut out: Vec<Block> = Vec::with_capacity(blocks.len());
    let mut i = 0;
    while i < blocks.len() {
        let b = &blocks[i];
        let seed = if b.kind == BlockKind::Seq && b.hi == b.lo + 1 {
            run_seed(&steps[b.lo as usize])
        } else {
            None
        };
        let Some(mut kind) = seed else {
            push_block(&mut out, blocks[i].clone());
            i += 1;
            continue;
        };
        let mut members = 1u32;
        let mut hi = b.hi;
        let mut state: Option<(u32, VType)> = None;
        let mut pend: Option<(u32, VType)> = None;
        let mut last = i; // block index of the last committed member
        let mut j = i + 1;
        while j < blocks.len() {
            let c = &blocks[j];
            if c.kind != BlockKind::Seq || c.hi != c.lo + 1 {
                break;
            }
            match steps[c.lo as usize] {
                Exec::SetState { vl, vtype } => pend = Some((vl, vtype)),
                ref e => {
                    if !run_extend(&mut kind, e) {
                        break;
                    }
                    members += 1;
                    hi = c.hi;
                    if pend.is_some() {
                        state = pend.take();
                    }
                    last = j;
                }
            }
            j += 1;
        }
        if members >= 2 {
            out.push(Block { lo: b.lo, hi, cycles: 0, state, kind });
            i = last + 1;
        } else {
            push_block(&mut out, blocks[i].clone());
            i += 1;
        }
    }
    out
}

/// The per-uop accounting the retained engine runs at execution time
/// and the plan builder replays at compile time.  It consumes only the
/// precomputed [`Acct`] — never run-time data — which is exactly why
/// the plan's totals can be precomputed (see the `sim::timing` module
/// docs for the contract).
#[inline]
fn account_uop(u: &Uop, timing: &mut Timing, st: &mut Stats) {
    match u.acct {
        Acct::Scalar { n } => {
            timing.scalar(n);
            st.add_scalar_slots(n as u64);
        }
        Acct::Mem { bytes, reg, lmul, load } => {
            let store_src = [(reg, lmul)];
            let (dst, srcs): (Option<(u8, u32)>, &[(u8, u32)]) = if load {
                (Some((reg, lmul)), &[])
            } else {
                (None, &store_src)
            };
            let (s, e) = timing.vector(Unit::Vlsu, bytes, bytes, dst, srcs);
            st.add_busy(Unit::Vlsu, e - s);
            if load {
                st.bytes_loaded += bytes;
            } else {
                st.bytes_stored += bytes;
            }
        }
        Acct::Vec { unit, busy, busy_cycles, dst, ref srcs, nsrcs } => {
            timing.vector(unit, busy, 0, dst, &srcs[..nsrcs as usize]);
            st.add_busy(unit, busy_cycles);
        }
    }
    st.element_ops += u.ops;
}

/// Strategy of one micro-op; `None` for pure bookkeeping (scalar
/// slots, vsetvli).
fn strategy_of(e: &Exec) -> Option<Strategy> {
    match e {
        Exec::Nop | Exec::SetState { .. } => None,
        Exec::Load { .. }
        | Exec::Store { .. }
        | Exec::Fill { .. }
        | Exec::Copy { .. }
        | Exec::SlideBulk { .. } => Some(Strategy::Bulk),
        Exec::Alu { .. } | Exec::MulVx { .. } | Exec::MulLane { .. } => Some(Strategy::Swar),
        _ => Some(Strategy::Generic),
    }
}

/// Execute a `Seq` stretch of the step stream one micro-op at a time —
/// also the exact-partial-state fallback when a fused run's merged
/// bounds check fails.
fn exec_seq(
    steps: &[Exec],
    base: u64,
    st: &mut ExecState,
    vrf: &mut Vrf,
    mem: &mut Mem,
) -> Result<(), SimError> {
    for e in steps {
        exec_uop(e, base, st, vrf, mem)?;
    }
    Ok(())
}

/// Execute one plan block.  Fused kinds do their whole run as one
/// sweep (with the rebase offset applied once); the absorbed `vsetvli`
/// state, if any, is applied after the block.  A fused memory run
/// whose *merged* span faults replays per-step: the span is the exact
/// union of the member intervals, so some member faults too, and the
/// replay reproduces the interpreter's partial state and first error.
fn exec_block(
    b: &Block,
    steps: &[Exec],
    base: u64,
    st: &mut ExecState,
    vrf: &mut Vrf,
    mem: &mut Mem,
) -> Result<(), SimError> {
    let (lo, hi) = (b.lo as usize, b.hi as usize);
    match b.kind {
        BlockKind::Seq => return exec_seq(&steps[lo..hi], base, st, vrf, mem),
        BlockKind::LoadRun { addr, total } => match mem.read(addr + base, total) {
            Ok(span) => {
                let flat = vrf.flat_mut();
                for e in &steps[lo..hi] {
                    if let Exec::Load { dst, addr: a, len } = *e {
                        let off = (a - addr) as usize;
                        flat[dst..dst + len].copy_from_slice(&span[off..off + len]);
                    }
                }
            }
            Err(_) => return exec_seq(&steps[lo..hi], base, st, vrf, mem),
        },
        BlockKind::StoreRun { addr, total } => {
            if mem.read(addr + base, total).is_err() {
                return exec_seq(&steps[lo..hi], base, st, vrf, mem);
            }
            let data = mem.bytes_mut();
            let flat = vrf.flat();
            for e in &steps[lo..hi] {
                if let Exec::Store { src, addr: a, len } = *e {
                    let o = (a + base) as usize;
                    data[o..o + len].copy_from_slice(&flat[src..src + len]);
                }
            }
        }
        BlockKind::FillRun { dst, len, splat } => {
            // member boundaries are multiples of 8 (run_extend), so the
            // merged sweep's chunk grid coincides with each member's
            let le = splat.to_le_bytes();
            for chunk in vrf.flat_mut()[dst..dst + len].chunks_mut(8) {
                chunk.copy_from_slice(&le[..chunk.len()]);
            }
        }
        BlockKind::CopyRun { dst, src, len } => {
            let bts = vrf.flat_mut();
            let words = len / 8;
            for w in 0..words {
                let o = w * 8;
                let v = rd64(bts, src + o);
                wr64(bts, dst + o, v);
            }
            for i in words * 8..len {
                bts[dst + i] = bts[src + i];
            }
        }
    }
    if let Some((vl, vtype)) = b.state {
        st.vl = vl;
        st.vtype = vtype;
    }
    Ok(())
}

impl Machine {
    /// The shared entry contract of every compiled-program engine.
    fn check_compiled_entry(&self, cp: &CompiledProgram) -> Result<(), SimError> {
        if self.cfg != cp.cfg {
            return Err(SimError::Unsupported(
                "machine configuration differs from the compiled program's",
            ));
        }
        // compile() folded vtype/vl forward from the reset state; a
        // program whose first vector instruction precedes its first
        // vsetvli would read the *live* state under the interpreter —
        // reject that instead of silently diverging from it.  (Streams
        // that set vtype before touching vector state — every kernel
        // builder's — replay from any entry state.)
        if cp.needs_default_entry
            && (self.state.vl != 0 || self.state.vtype != ExecState::default().vtype)
        {
            return Err(SimError::Unsupported(
                "compiled program uses vector state before its first vsetvli: run it on a reset machine",
            ));
        }
        Ok(())
    }

    /// Execute a pre-compiled program: the hot path of
    /// compile-once/execute-many serving.  Walks the fused execution
    /// plan — one sweep per fused run, step dispatch for the rest, and
    /// the precomputed [`Stats`] returned as-is ([`Timing`] never
    /// reads run-time data, so a successful run's stats are a
    /// compile-time constant).  Outputs, memory and the returned
    /// [`RunReport`] are bit-identical to [`Machine::run`] on the
    /// source [`Program`].
    pub fn run_compiled(&mut self, cp: &CompiledProgram) -> Result<RunReport, SimError> {
        self.run_compiled_rebased(cp, 0)
    }

    /// [`Machine::run_compiled`] with every memory address offset by
    /// `base` — the batched-arena rebind (DESIGN.md §Serving): one
    /// compiled program executes against any of B disjoint per-image
    /// activation slots.  `base` must be a multiple of the arena
    /// allocation alignment (64) so every access keeps its alignment;
    /// the offset is applied once per fused block, not per access.
    /// Timing is byte-count-driven and address-independent, so the
    /// report is bit-identical to the `base = 0` run.
    pub fn run_compiled_rebased(
        &mut self,
        cp: &CompiledProgram,
        base: u64,
    ) -> Result<RunReport, SimError> {
        self.check_compiled_entry(cp)?;
        for b in &cp.plan.blocks {
            exec_block(b, &cp.plan.steps, base, &mut self.state, &mut self.vrf, &mut self.mem)?;
        }
        Ok(RunReport {
            stats: cp.plan.totals.clone(),
            macs: cp.macs,
            label: cp.label.clone(),
            fused: FusedCounts { blocks: cp.plan.fused_blocks, uops: cp.plan.fused_uops },
        })
    }

    /// The retained PR-2 per-uop engine: dispatches every micro-op
    /// individually and re-derives [`Timing`] at run time.  Kept as
    /// the host-time baseline the fused plan is benched against
    /// (`benches/simspeed.rs`) and as an extra engine in the
    /// differential fuzz matrix; bit-identical (outputs, memory,
    /// stats) to [`Machine::run_compiled`].
    pub fn run_compiled_unfused(&mut self, cp: &CompiledProgram) -> Result<RunReport, SimError> {
        self.run_compiled_unfused_rebased(cp, 0)
    }

    /// [`Machine::run_compiled_unfused`] with the batched-arena rebase
    /// (see [`Machine::run_compiled_rebased`]).
    pub fn run_compiled_unfused_rebased(
        &mut self,
        cp: &CompiledProgram,
        base: u64,
    ) -> Result<RunReport, SimError> {
        self.check_compiled_entry(cp)?;
        let mut timing = Timing::new(&self.cfg);
        let mut st = Stats::default();
        for u in &cp.uops {
            exec_uop(&u.exec, base, &mut self.state, &mut self.vrf, &mut self.mem)?;
            account_uop(u, &mut timing, &mut st);
        }
        st.cycles = timing.cycles();
        st.raw_stall_cycles = timing.raw_stalls;
        Ok(RunReport {
            stats: st,
            macs: cp.macs,
            label: cp.label.clone(),
            fused: FusedCounts::default(),
        })
    }
}

// ---------------------------------------------------------------- lower

/// Raw operand before strategy selection.
enum RawSrc {
    Vec(u8),
    Scalar(u64),
}

fn lower(
    inst: &VInst,
    cfg: &ProcessorConfig,
    st: &mut ExecState,
    vlenb: usize,
    bpc: u64,
) -> Result<Uop, SimError> {
    match *inst {
        VInst::Scalar { n, .. } => Ok(Uop { exec: Exec::Nop, acct: Acct::Scalar { n }, ops: 0 }),
        VInst::SetVl { avl, sew, lmul } => {
            st.vtype = VType::new(sew, lmul);
            st.vl = st.vtype.apply(avl, cfg.vlen_bits);
            Ok(Uop {
                exec: Exec::SetState { vl: st.vl, vtype: st.vtype },
                acct: Acct::Scalar { n: 1 },
                ops: 0,
            })
        }
        VInst::Load { eew, vd, addr } => {
            exec::check_alignment(inst, st)?;
            let lmul = st.vtype.lmul.factor();
            let len = st.vl as usize * eew.bytes() as usize;
            let dst = vd as usize * vlenb;
            Vrf::check_group_for(vlenb, vd, len, lmul)?;
            let bytes = st.vl as u64 * eew.bytes() as u64;
            Ok(Uop {
                exec: Exec::Load { dst, addr, len },
                acct: Acct::Mem { bytes, reg: vd, lmul, load: true },
                ops: st.vl as u64,
            })
        }
        VInst::Store { eew, vs3, addr } => {
            exec::check_alignment(inst, st)?;
            let lmul = st.vtype.lmul.factor();
            let len = st.vl as usize * eew.bytes() as usize;
            let src = vs3 as usize * vlenb;
            Vrf::check_group_for(vlenb, vs3, len, lmul)?;
            let bytes = st.vl as u64 * eew.bytes() as u64;
            Ok(Uop {
                exec: Exec::Store { src, addr, len },
                acct: Acct::Mem { bytes, reg: vs3, lmul, load: false },
                ops: st.vl as u64,
            })
        }
        VInst::OpVV { op, vd, vs2, vs1 } => {
            exec::check_legal(op, cfg, st)?;
            exec::check_alignment(inst, st)?;
            lower_arith(inst, op, vd, vs2, RawSrc::Vec(vs1), cfg, st, vlenb, bpc)
        }
        VInst::OpVX { op, vd, vs2, rs1 } => {
            exec::check_legal(op, cfg, st)?;
            exec::check_alignment(inst, st)?;
            lower_arith(inst, op, vd, vs2, RawSrc::Scalar(rs1), cfg, st, vlenb, bpc)
        }
        VInst::OpVI { op, vd, vs2, imm } => {
            exec::check_legal(op, cfg, st)?;
            exec::check_alignment(inst, st)?;
            let x = if matches!(
                op,
                VOp::Sll | VOp::Srl | VOp::Sra | VOp::NSrl | VOp::SlideDown | VOp::SlideUp
            ) {
                imm as u8 as u64 // uimm5
            } else {
                exec::trunc(imm as i64 as u64, st.vtype.sew) // simm5 at SEW
            };
            lower_arith(inst, op, vd, vs2, RawSrc::Scalar(x), cfg, st, vlenb, bpc)
        }
    }
}

/// The timing record `Machine::account` would produce for this
/// arithmetic instruction, from the folded state.
fn arith_acct(inst: &VInst, op: VOp, st: &ExecState, bpc: u64) -> Acct {
    let lmul = st.vtype.lmul.factor();
    let sew = st.vtype.sew;
    let vl = st.vl as u64;
    let unit = if op.is_fp() || op.is_mul() {
        Unit::Mfpu
    } else if op.is_slide() {
        Unit::Sldu
    } else {
        Unit::Valu
    };
    let ebytes = if op == VOp::WAdduWv || op == VOp::NSrl {
        sew.widened().map(Sew::bytes).unwrap_or(8) as u64
    } else {
        sew.bytes() as u64
    };
    let dst_regs = if op == VOp::WAdduWv { lmul * 2 } else { lmul };
    // narrowing ops read vs2 as a 2*LMUL group (dual of the wide dst)
    let src_regs = if op == VOp::NSrl { lmul * 2 } else { lmul };
    let mut buf = [0u8; 3];
    let n = inst.srcs_into(&mut buf);
    let mut srcs = [(0u8, 0u32); 3];
    for (i, &r) in buf[..n].iter().enumerate() {
        srcs[i] = (r, src_regs);
    }
    let busy = vl * ebytes;
    Acct::Vec {
        unit,
        busy,
        busy_cycles: busy.div_ceil(bpc).max(1),
        dst: inst.vd().map(|d| (d, dst_regs)),
        srcs,
        nsrcs: n as u8,
    }
}

#[allow(clippy::too_many_arguments)]
fn lower_arith(
    inst: &VInst,
    op: VOp,
    vd: u8,
    vs2: u8,
    src: RawSrc,
    cfg: &ProcessorConfig,
    st: &ExecState,
    vlenb: usize,
    bpc: u64,
) -> Result<Uop, SimError> {
    let sew = st.vtype.sew;
    let eb = sew.bytes() as usize;
    let vl = st.vl;
    let len = vl as usize * eb;
    let dst = vd as usize * vlenb;
    let a = vs2 as usize * vlenb;
    let shift = match op {
        VOp::Macsr => Shift::Fixed(sew.bits() / 2),
        VOp::MacsrCfg => Shift::Csr,
        _ => Shift::Fixed(0),
    };
    let operand = |s: &RawSrc| match *s {
        RawSrc::Vec(v1) => Operand::Vec(v1 as usize * vlenb),
        RawSrc::Scalar(x) => Operand::Splat(splat_word(exec::trunc(x, sew), sew)),
    };
    let acct = arith_acct(inst, op, st, bpc);
    let ops = vl as u64;
    let done = |exec: Exec| Ok(Uop { exec, acct, ops });

    match op {
        VOp::SlideDown | VOp::SlideUp => {
            let off = match src {
                RawSrc::Scalar(x) => x,
                RawSrc::Vec(_) => return Err(SimError::Unsupported("slide .vv form")),
            };
            if op == VOp::SlideUp && vd == vs2 {
                return Err(SimError::Unsupported("vslideup with vd == vs2"));
            }
            let vlmax = st.vtype.vlmax(cfg.vlen_bits);
            if op == VOp::SlideDown {
                let ncopy = (vl as u64).min((vlmax as u64).saturating_sub(off)) as usize;
                if ncopy == 0 {
                    // nothing in range: pure zero fill
                    return done(Exec::SlideBulk { dst, src: dst, copy: 0, zero: len });
                }
                let src_lo = a + off as usize * eb;
                let copy = ncopy * eb;
                // identical groups memmove ascending-safe (src >= dst);
                // fully disjoint is trivially safe; partial overlap
                // must replay the exact reference element order
                if vd == vs2 || disjoint(dst, len, src_lo, copy) {
                    done(Exec::SlideBulk { dst, src: src_lo, copy, zero: len - copy })
                } else {
                    done(Exec::SlideGen { down: true, off, dst, src: a, eb, vl, vlmax })
                }
            } else {
                if off >= vl as u64 {
                    // every element keeps vd's old value
                    return done(Exec::SlideBulk { dst, src: dst, copy: 0, zero: 0 });
                }
                let copy = (vl as u64 - off) as usize * eb;
                let dst_lo = dst + off as usize * eb;
                if disjoint(dst_lo, copy, a, copy) {
                    done(Exec::SlideBulk { dst: dst_lo, src: a, copy, zero: 0 })
                } else {
                    done(Exec::SlideGen { down: false, off, dst, src: a, eb, vl, vlmax })
                }
            }
        }
        VOp::WAdduWv => {
            if sew.widened().is_none() {
                return Err(SimError::Unsupported("vwaddu.wv at SEW=64"));
            }
            done(Exec::Wadd { dst, src: a, sew, vl })
        }
        VOp::NSrl => {
            if sew.widened().is_none() {
                return Err(SimError::Unsupported("vnsrl at SEW=64"));
            }
            let sh = match src {
                RawSrc::Scalar(x) => (x & (2 * sew.bits() as u64 - 1)) as u32,
                RawSrc::Vec(_) => return Err(SimError::Unsupported("vnsrl .wv form")),
            };
            done(Exec::Nsrl { dst, src: a, sew, vl, sh })
        }
        VOp::Mv => match src {
            RawSrc::Scalar(x) => {
                done(Exec::Fill { dst, len, splat: splat_word(exec::trunc(x, sew), sew) })
            }
            RawSrc::Vec(v1) => done(Exec::Copy { dst, src: v1 as usize * vlenb, len }),
        },
        VOp::Add | VOp::Sub | VOp::And | VOp::Or | VOp::Xor => {
            let aop = match op {
                VOp::Add => AluWord::Add,
                VOp::Sub => AluWord::Sub,
                VOp::And => AluWord::And,
                VOp::Or => AluWord::Or,
                _ => AluWord::Xor,
            };
            done(Exec::Alu { op: aop, sew, dst, a, x: operand(&src), len })
        }
        VOp::Sll | VOp::Srl => match src {
            RawSrc::Scalar(x) => {
                let sh = (x & (sew.bits() as u64 - 1)) as u32;
                let aop = if op == VOp::Sll { AluWord::Sll(sh) } else { AluWord::Srl(sh) };
                done(Exec::Alu { op: aop, sew, dst, a, x: Operand::Splat(0), len })
            }
            RawSrc::Vec(_) => done(Exec::Gen {
                op,
                sew,
                vl,
                dst,
                a,
                x: operand(&src),
                eb,
                shift,
                reads_vd: op.reads_vd(),
            }),
        },
        VOp::Mul | VOp::Macc | VOp::Nmsac | VOp::Macsr | VOp::MacsrCfg => {
            let mop = match op {
                VOp::Mul => MulOp::Mul,
                VOp::Macc => MulOp::Macc,
                VOp::Nmsac => MulOp::Nmsac,
                _ => MulOp::Macsr,
            };
            match src {
                RawSrc::Scalar(x) if matches!(sew, Sew::E8 | Sew::E16) => done(Exec::MulVx {
                    op: mop,
                    sew,
                    dst,
                    a,
                    x: exec::trunc(x, sew),
                    shift,
                    len,
                }),
                _ => done(Exec::MulLane { op: mop, sew, dst, a, x: operand(&src), shift, len }),
            }
        }
        // cold ops: monomorphic per-element loop over the reference
        // semantics (no per-element `match op` — `op` selects once)
        _ => done(Exec::Gen {
            op,
            sew,
            vl,
            dst,
            a,
            x: operand(&src),
            eb,
            shift,
            reads_vd: op.reads_vd(),
        }),
    }
}

#[inline]
fn disjoint(a: usize, alen: usize, b: usize, blen: usize) -> bool {
    a + alen <= b || b + blen <= a
}

// ---------------------------------------------------------------- exec

#[inline]
fn rd64(b: &[u8], o: usize) -> u64 {
    u64::from_le_bytes(b[o..o + 8].try_into().unwrap())
}

#[inline]
fn wr64(b: &mut [u8], o: usize, v: u64) {
    b[o..o + 8].copy_from_slice(&v.to_le_bytes());
}

/// Zero-padded partial-word read (tails; the pad lanes are never
/// written back, and no SWAR op lets a lane influence a lower one).
#[inline]
fn rd_part(b: &[u8], o: usize, n: usize) -> u64 {
    let mut t = [0u8; 8];
    t[..n].copy_from_slice(&b[o..o + n]);
    u64::from_le_bytes(t)
}

#[inline]
fn wr_part(b: &mut [u8], o: usize, n: usize, v: u64) {
    b[o..o + n].copy_from_slice(&v.to_le_bytes()[..n]);
}

#[inline]
fn rd_elem(b: &[u8], o: usize, eb: usize) -> u64 {
    rd_part(b, o, eb)
}

#[inline]
fn wr_elem(b: &mut [u8], o: usize, eb: usize, v: u64) {
    wr_part(b, o, eb, v);
}

/// Per-lane MSB mask (the SWAR carry fence).
#[inline]
fn hi_mask(sew: Sew) -> u64 {
    match sew {
        Sew::E8 => 0x8080_8080_8080_8080,
        Sew::E16 => 0x8000_8000_8000_8000,
        Sew::E32 => 0x8000_0000_8000_0000,
        Sew::E64 => 0x8000_0000_0000_0000,
    }
}

/// All-ones lane value.
#[inline]
fn lane_ones(sew: Sew) -> u64 {
    exec::trunc(!0u64, sew)
}

/// Repeat the (truncated) lane value across the 64-bit word.
#[inline]
fn splat_word(x: u64, sew: Sew) -> u64 {
    match sew {
        Sew::E8 => (x as u8 as u64) * 0x0101_0101_0101_0101,
        Sew::E16 => (x as u16 as u64) * 0x0001_0001_0001_0001,
        Sew::E32 => {
            let x = x as u32 as u64;
            x | (x << 32)
        }
        Sew::E64 => x,
    }
}

/// Lane-wise wrapping add: clear the lane MSBs so carries cannot cross
/// lanes, then patch the MSBs back with the carry-in xor.
#[inline]
fn swar_add(a: u64, b: u64, h: u64) -> u64 {
    ((a & !h).wrapping_add(b & !h)) ^ ((a ^ b) & h)
}

/// Lane-wise wrapping sub: force the lane MSBs of `a` so borrows
/// cannot cross lanes, then patch the MSBs with the borrow-in xnor.
#[inline]
fn swar_sub(a: u64, b: u64, h: u64) -> u64 {
    ((a | h).wrapping_sub(b & !h)) ^ (!(a ^ b) & h)
}

/// SWAR word loop driver: ascending full words, then one zero-padded
/// partial word for the tail.  `f(a_word, x_word) -> dst_word`.
#[inline]
fn alu_loop<F: Fn(u64, u64) -> u64>(bytes: &mut [u8], dst: usize, a: usize, x: Operand, len: usize, f: F) {
    let words = len / 8;
    match x {
        Operand::Splat(s) => {
            for w in 0..words {
                let o = w * 8;
                let r = f(rd64(bytes, a + o), s);
                wr64(bytes, dst + o, r);
            }
            let t = len - words * 8;
            if t > 0 {
                let o = words * 8;
                let r = f(rd_part(bytes, a + o, t), s);
                wr_part(bytes, dst + o, t, r);
            }
        }
        Operand::Vec(xo) => {
            for w in 0..words {
                let o = w * 8;
                let r = f(rd64(bytes, a + o), rd64(bytes, xo + o));
                wr64(bytes, dst + o, r);
            }
            let t = len - words * 8;
            if t > 0 {
                let o = words * 8;
                let r = f(rd_part(bytes, a + o, t), rd_part(bytes, xo + o, t));
                wr_part(bytes, dst + o, t, r);
            }
        }
    }
}

/// Ternary SWAR word loop: `f(a_word, x_word, d_word) -> dst_word`.
#[inline]
fn mul_word_loop<F: Fn(u64, u64, u64) -> u64>(
    bytes: &mut [u8],
    dst: usize,
    a: usize,
    x: Operand,
    len: usize,
    f: F,
) {
    let words = len / 8;
    for w in 0..words {
        let o = w * 8;
        let xw = match x {
            Operand::Splat(s) => s,
            Operand::Vec(xo) => rd64(bytes, xo + o),
        };
        let r = f(rd64(bytes, a + o), xw, rd64(bytes, dst + o));
        wr64(bytes, dst + o, r);
    }
    let t = len - words * 8;
    if t > 0 {
        let o = words * 8;
        let xw = match x {
            Operand::Splat(s) => s,
            Operand::Vec(xo) => rd_part(bytes, xo + o, t),
        };
        let r = f(rd_part(bytes, a + o, t), xw, rd_part(bytes, dst + o, t));
        wr_part(bytes, dst + o, t, r);
    }
}

/// The host-side ULPPACK trick: spread the even/odd lanes of `a` into
/// spaced fields and let *one* scalar multiply compute every field's
/// lane product (the products cannot cross fields: at E8 each 8-bit
/// lane times an 8-bit scalar is < 2^16, exactly the field pitch).
/// Returns the word of per-lane `(a*x mod 2^SEW) >> sh` values.
#[inline]
fn swar_mul_prod(a: u64, x: u64, sh: u32, field: u64, lane_bits: u32) -> u64 {
    let ae = a & field;
    let ao = (a >> lane_bits) & field;
    let pe = ((ae.wrapping_mul(x) & field) >> sh) & field;
    let po = ((ao.wrapping_mul(x) & field) >> sh) & field;
    pe | (po << lane_bits)
}

/// One micro-op, functionally.  The only run-time inputs are the VRF
/// bytes, the memory, the vmacsr.cfg CSR, and the caller's arena
/// rebase offset (`base`, 0 outside batched execution).
fn exec_uop(
    e: &Exec,
    base: u64,
    st: &mut ExecState,
    vrf: &mut Vrf,
    mem: &mut Mem,
) -> Result<(), SimError> {
    match *e {
        Exec::Nop => {}
        Exec::SetState { vl, vtype } => {
            st.vl = vl;
            st.vtype = vtype;
        }
        Exec::Load { dst, addr, len } => {
            vrf.flat_mut()[dst..dst + len].copy_from_slice(mem.read(addr + base, len)?);
        }
        Exec::Store { src, addr, len } => {
            mem.write(addr + base, &vrf.flat()[src..src + len])?;
        }
        Exec::Fill { dst, len, splat } => {
            let le = splat.to_le_bytes();
            for chunk in vrf.flat_mut()[dst..dst + len].chunks_mut(8) {
                chunk.copy_from_slice(&le[..chunk.len()]);
            }
        }
        Exec::Copy { dst, src, len } => {
            let b = vrf.flat_mut();
            let words = len / 8;
            for w in 0..words {
                let o = w * 8;
                let v = rd64(b, src + o);
                wr64(b, dst + o, v);
            }
            for i in words * 8..len {
                b[dst + i] = b[src + i];
            }
        }
        Exec::SlideBulk { dst, src, copy, zero } => {
            let b = vrf.flat_mut();
            b.copy_within(src..src + copy, dst);
            b[dst + copy..dst + copy + zero].fill(0);
        }
        Exec::SlideGen { down, off, dst, src, eb, vl, vlmax } => {
            let b = vrf.flat_mut();
            if down {
                for i in 0..vl as u64 {
                    let j = i + off;
                    let v = if j < vlmax as u64 { rd_elem(b, src + j as usize * eb, eb) } else { 0 };
                    wr_elem(b, dst + i as usize * eb, eb, v);
                }
            } else {
                for i in (0..vl as u64).rev() {
                    if i < off {
                        break;
                    }
                    let v = rd_elem(b, src + (i - off) as usize * eb, eb);
                    wr_elem(b, dst + i as usize * eb, eb, v);
                }
            }
        }
        Exec::Alu { op, sew, dst, a, x, len } => {
            let b = vrf.flat_mut();
            let h = hi_mask(sew);
            match op {
                AluWord::Add => alu_loop(b, dst, a, x, len, |aw, xw| swar_add(aw, xw, h)),
                AluWord::Sub => alu_loop(b, dst, a, x, len, |aw, xw| swar_sub(aw, xw, h)),
                AluWord::And => alu_loop(b, dst, a, x, len, |aw, xw| aw & xw),
                AluWord::Or => alu_loop(b, dst, a, x, len, |aw, xw| aw | xw),
                AluWord::Xor => alu_loop(b, dst, a, x, len, |aw, xw| aw ^ xw),
                AluWord::Sll(sh) => {
                    let keep = splat_word(exec::trunc(lane_ones(sew) << sh, sew), sew);
                    alu_loop(b, dst, a, x, len, |aw, _| (aw << sh) & keep);
                }
                AluWord::Srl(sh) => {
                    let keep = splat_word(lane_ones(sew) >> sh, sew);
                    alu_loop(b, dst, a, x, len, |aw, _| (aw >> sh) & keep);
                }
            }
        }
        Exec::MulVx { op, sew, dst, a, x, shift, len } => {
            let b = vrf.flat_mut();
            let h = hi_mask(sew);
            let sh = shift.resolve(st, sew);
            let (field, lane_bits) = match sew {
                Sew::E8 => (0x00FF_00FF_00FF_00FFu64, 8u32),
                _ => (0x0000_FFFF_0000_FFFFu64, 16u32),
            };
            let prod = |aw: u64| swar_mul_prod(aw, x, sh, field, lane_bits);
            let xw = Operand::Splat(0); // multiplier folded into `prod`
            match op {
                MulOp::Mul => mul_word_loop(b, dst, a, xw, len, |aw, _, _| prod(aw)),
                MulOp::Macc | MulOp::Macsr => {
                    mul_word_loop(b, dst, a, xw, len, |aw, _, dw| swar_add(dw, prod(aw), h))
                }
                MulOp::Nmsac => {
                    mul_word_loop(b, dst, a, xw, len, |aw, _, dw| swar_sub(dw, prod(aw), h))
                }
            }
        }
        Exec::MulLane { op, sew, dst, a, x, shift, len } => {
            let b = vrf.flat_mut();
            let sh = shift.resolve(st, sew);
            exec_mul_lane(b, op, sew, dst, a, x, sh, len);
        }
        Exec::Wadd { dst, src, sew, vl } => {
            let b = vrf.flat_mut();
            exec_wadd(b, dst, src, sew, vl);
        }
        Exec::Nsrl { dst, src, sew, vl, sh } => {
            let b = vrf.flat_mut();
            exec_nsrl(b, dst, src, sew, vl, sh);
        }
        Exec::Gen { op, sew, vl, dst, a, x, eb, shift, reads_vd } => {
            let b = vrf.flat_mut();
            let sh = shift.resolve(st, sew);
            for i in 0..vl as usize {
                let av = rd_elem(b, a + i * eb, eb);
                let xv = match x {
                    Operand::Splat(s) => exec::trunc(s, sew),
                    Operand::Vec(xo) => rd_elem(b, xo + i * eb, eb),
                };
                let dv = if reads_vd { rd_elem(b, dst + i * eb, eb) } else { 0 };
                wr_elem(b, dst + i * eb, eb, exec::scalar_op(op, av, xv, dv, sew, sh));
            }
        }
    }
    Ok(())
}

/// Multiply family as a word-read lane loop (VV forms and wide SEWs):
/// one `match` per instruction, typed lane arithmetic inside.
#[allow(clippy::too_many_arguments)]
fn exec_mul_lane(b: &mut [u8], op: MulOp, sew: Sew, dst: usize, a: usize, x: Operand, sh: u32, len: usize) {
    macro_rules! lanes {
        ($t:ty, $eb:expr, $f:expr) => {{
            let f = $f;
            let eb: usize = $eb;
            let lanes: usize = 8 / eb;
            let bits: usize = eb * 8;
            let words = len / 8;
            for w in 0..words {
                let o = w * 8;
                let aw = rd64(b, a + o);
                let xw = match x {
                    Operand::Splat(s) => s,
                    Operand::Vec(xo) => rd64(b, xo + o),
                };
                let dw = rd64(b, dst + o);
                let mut r = 0u64;
                for k in 0..lanes {
                    let s = k * bits;
                    let rv: $t = f((aw >> s) as $t, (xw >> s) as $t, (dw >> s) as $t);
                    r |= (rv as u64) << s;
                }
                wr64(b, dst + o, r);
            }
            let t = len - words * 8;
            if t > 0 {
                let o = words * 8;
                let aw = rd_part(b, a + o, t);
                let xw = match x {
                    Operand::Splat(s) => s,
                    Operand::Vec(xo) => rd_part(b, xo + o, t),
                };
                let dw = rd_part(b, dst + o, t);
                let mut r = 0u64;
                for k in 0..t / eb {
                    let s = k * bits;
                    let rv: $t = f((aw >> s) as $t, (xw >> s) as $t, (dw >> s) as $t);
                    r |= (rv as u64) << s;
                }
                wr_part(b, dst + o, t, r);
            }
        }};
    }
    macro_rules! per_op {
        ($t:ty, $eb:expr) => {
            match op {
                MulOp::Mul => lanes!($t, $eb, |av: $t, xv: $t, _d: $t| av.wrapping_mul(xv)),
                MulOp::Macc => {
                    lanes!($t, $eb, |av: $t, xv: $t, dv: $t| dv.wrapping_add(av.wrapping_mul(xv)))
                }
                MulOp::Nmsac => {
                    lanes!($t, $eb, |av: $t, xv: $t, dv: $t| dv.wrapping_sub(av.wrapping_mul(xv)))
                }
                MulOp::Macsr => lanes!($t, $eb, |av: $t, xv: $t, dv: $t| dv
                    .wrapping_add(av.wrapping_mul(xv) >> sh)),
            }
        };
    }
    match sew {
        Sew::E8 => per_op!(u8, 1),
        Sew::E16 => per_op!(u16, 2),
        Sew::E32 => per_op!(u32, 4),
        Sew::E64 => per_op!(u64, 8),
    }
}

/// `vnsrl.w{x,i}` in reference element order, monomorphic per SEW pair
/// (ascending: narrow write `i` never clobbers a wide read `j > i`).
fn exec_nsrl(b: &mut [u8], dst: usize, src: usize, sew: Sew, vl: u32, sh: u32) {
    macro_rules! nsrl {
        ($n:ty, $w:ty, $eb:expr) => {{
            let eb: usize = $eb;
            for i in 0..vl as usize {
                let wo = src + i * 2 * eb;
                let no = dst + i * eb;
                let a = <$w>::from_le_bytes(b[wo..wo + 2 * eb].try_into().unwrap());
                let v = (a >> sh) as $n;
                b[no..no + eb].copy_from_slice(&v.to_le_bytes());
            }
        }};
    }
    match sew {
        Sew::E8 => nsrl!(u8, u16, 1),
        Sew::E16 => nsrl!(u16, u32, 2),
        Sew::E32 => nsrl!(u32, u64, 4),
        Sew::E64 => unreachable!("rejected at compile"),
    }
}

/// `vwaddu.wv` in reference element order, monomorphic per SEW pair.
fn exec_wadd(b: &mut [u8], dst: usize, src: usize, sew: Sew, vl: u32) {
    macro_rules! wadd {
        ($n:ty, $w:ty, $eb:expr) => {{
            let eb: usize = $eb;
            for i in 0..vl as usize {
                let no = src + i * eb;
                let wo = dst + i * 2 * eb;
                let a = <$n>::from_le_bytes(b[no..no + eb].try_into().unwrap()) as $w;
                let d = <$w>::from_le_bytes(b[wo..wo + 2 * eb].try_into().unwrap());
                b[wo..wo + 2 * eb].copy_from_slice(&d.wrapping_add(a).to_le_bytes());
            }
        }};
    }
    match sew {
        Sew::E8 => wadd!(u8, u16, 1),
        Sew::E16 => wadd!(u16, u32, 2),
        Sew::E32 => wadd!(u32, u64, 4),
        Sew::E64 => unreachable!("rejected at compile"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Lmul, ScalarKind};

    fn cfg() -> ProcessorConfig {
        ProcessorConfig::sparq_cfgshift()
    }

    fn roundtrip(p: &Program, cfg: &ProcessorConfig) -> (RunReport, Vec<u8>, RunReport, Vec<u8>) {
        let mut a = Machine::new(cfg.clone(), 1 << 16);
        let mut b = Machine::new(cfg.clone(), 1 << 16);
        let mut u = Machine::new(cfg.clone(), 1 << 16);
        // seed all VRFs with the same pseudo-random bytes
        let n = (cfg.vlen_bits / 8 * 32) as usize;
        let fill: Vec<u8> = (0..n).map(|i| (i as u32).wrapping_mul(2654435761) as u8).collect();
        a.vrf().slice_mut(0, n).copy_from_slice(&fill);
        b.vrf().slice_mut(0, n).copy_from_slice(&fill);
        u.vrf().slice_mut(0, n).copy_from_slice(&fill);
        a.mem.write(0, &[7u8; 256]).unwrap();
        b.mem.write(0, &[7u8; 256]).unwrap();
        u.mem.write(0, &[7u8; 256]).unwrap();
        let ra = a.run(p).unwrap();
        let cp = CompiledProgram::compile(p, cfg).unwrap();
        let rb = b.run_compiled(&cp).unwrap();
        // the retained per-uop engine rides along: it must agree with
        // the fused plan bit for bit
        let ru = u.run_compiled_unfused(&cp).unwrap();
        assert_eq!(ru.stats.cycles, rb.stats.cycles, "unfused engine cycles diverged");
        assert_eq!(ru.stats.unit_table(), rb.stats.unit_table());
        assert_eq!(u.vrf().slice(0, n), b.vrf().slice(0, n), "unfused engine VRF diverged");
        let va = a.vrf().slice(0, n).to_vec();
        let vb = b.vrf().slice(0, n).to_vec();
        (ra, va, rb, vb)
    }

    #[test]
    fn compiled_matches_interpreter_on_mixed_program() {
        let c = cfg();
        let mut p = Program::new("mixed");
        p.push(VInst::SetVl { avl: 37, sew: Sew::E8, lmul: Lmul::M1 });
        p.push(VInst::Load { eew: Sew::E8, vd: 1, addr: 0x10 });
        p.push(VInst::OpVX { op: VOp::Macsr, vd: 2, vs2: 1, rs1: 0x55 });
        p.push(VInst::OpVX { op: VOp::Macc, vd: 3, vs2: 1, rs1: 200 });
        p.push(VInst::OpVV { op: VOp::Add, vd: 4, vs2: 2, vs1: 3 });
        p.push(VInst::OpVV { op: VOp::Sub, vd: 4, vs2: 4, vs1: 1 });
        p.push(VInst::OpVI { op: VOp::SlideDown, vd: 4, vs2: 4, imm: 1 });
        p.push(VInst::OpVI { op: VOp::Srl, vd: 5, vs2: 4, imm: 3 });
        p.push(VInst::Scalar { kind: ScalarKind::LoopCtl, n: 2 });
        p.push(VInst::SetVl { avl: 19, sew: Sew::E16, lmul: Lmul::M2 });
        p.push(VInst::OpVX { op: VOp::Mul, vd: 6, vs2: 8, rs1: 0x1234 });
        p.push(VInst::OpVV { op: VOp::WAdduWv, vd: 12, vs2: 6, vs1: 0 });
        p.push(VInst::OpVI { op: VOp::Mv, vd: 10, vs2: 0, imm: -3 });
        p.push(VInst::Store { eew: Sew::E16, vs3: 6, addr: 0x200 });
        let (ra, va, rb, vb) = roundtrip(&p, &c);
        assert_eq!(va, vb, "VRF diverged");
        assert_eq!(ra.stats.cycles, rb.stats.cycles);
        assert_eq!(ra.stats.element_ops, rb.stats.element_ops);
        assert_eq!(ra.stats.raw_stall_cycles, rb.stats.raw_stall_cycles);
        assert_eq!(ra.stats.bytes_loaded, rb.stats.bytes_loaded);
        assert_eq!(ra.stats.bytes_stored, rb.stats.bytes_stored);
        assert_eq!(ra.stats.unit_table(), rb.stats.unit_table());
    }

    #[test]
    fn strategies_land_where_expected() {
        let c = cfg();
        let mut p = Program::new("strat");
        p.push(VInst::SetVl { avl: 64, sew: Sew::E8, lmul: Lmul::M1 });
        p.push(VInst::Load { eew: Sew::E8, vd: 1, addr: 0 }); // bulk
        p.push(VInst::OpVX { op: VOp::Macsr, vd: 2, vs2: 1, rs1: 3 }); // swar
        p.push(VInst::OpVV { op: VOp::Add, vd: 3, vs2: 1, vs1: 2 }); // swar
        p.push(VInst::OpVX { op: VOp::Mulhu, vd: 4, vs2: 1, rs1: 3 }); // generic
        let cp = CompiledProgram::compile(&p, &c).unwrap();
        assert_eq!(
            cp.strategy_counts(),
            StrategyCounts { bulk: 1, swar: 2, generic: 1, fused: 0 }
        );
    }

    /// The requant zero-fill idiom: a broadcast followed by a run of
    /// contiguous stores of the same register.  The run must fuse into
    /// one `StoreRun` block — absorbing a re-issued `vsetvli` between
    /// members but not the trailing one — and replay bit-identically
    /// to the interpreter, memory and stats included.
    #[test]
    fn contiguous_store_run_fuses_and_replays_bit_identically() {
        let c = cfg();
        let mut p = Program::new("zfill");
        p.push(VInst::SetVl { avl: 16, sew: Sew::E8, lmul: Lmul::M1 });
        p.push(VInst::OpVI { op: VOp::Mv, vd: 1, vs2: 0, imm: 0 });
        for k in 0..3u64 {
            p.push(VInst::Store { eew: Sew::E8, vs3: 1, addr: 0x100 + 16 * k });
        }
        // a vsetvli *inside* the run (same vl, so addresses stay
        // contiguous) is absorbed and applied once after the block
        p.push(VInst::SetVl { avl: 16, sew: Sew::E8, lmul: Lmul::M1 });
        for k in 3..6u64 {
            p.push(VInst::Store { eew: Sew::E8, vs3: 1, addr: 0x100 + 16 * k });
        }
        // a trailing vsetvli after the last member stays outside it
        p.push(VInst::SetVl { avl: 17, sew: Sew::E8, lmul: Lmul::M1 });
        let cp = CompiledProgram::compile(&p, &c).unwrap();
        let (blocks, fused_blocks, fused_uops, _) = cp.plan_stats();
        assert_eq!(fused_blocks, 1, "one store run expected ({blocks} blocks)");
        assert_eq!(fused_uops, 6);
        let sc = cp.strategy_counts();
        assert_eq!((sc.fused, sc.bulk), (6, 1), "6 stores fused, the fill alone stays bulk");

        let mut a = Machine::new(c.clone(), 1 << 16);
        let mut b = Machine::new(c.clone(), 1 << 16);
        a.mem.write(0x100, &[0xAB; 96]).unwrap();
        b.mem.write(0x100, &[0xAB; 96]).unwrap();
        let ra = a.run(&p).unwrap();
        let rb = b.run_compiled(&cp).unwrap();
        assert_eq!(a.mem.read(0, 512).unwrap(), b.mem.read(0, 512).unwrap());
        assert_eq!(ra.stats.cycles, rb.stats.cycles);
        assert_eq!(ra.stats.unit_table(), rb.stats.unit_table());
        assert_eq!(ra.stats.bytes_stored, rb.stats.bytes_stored);
        assert_eq!((rb.fused.blocks, rb.fused.uops), (1, 6));
        assert_eq!(b.vl(), 17, "trailing vsetvli executed after the fused block");
        assert_eq!(a.vl(), b.vl());
    }

    /// Fusion is idempotent: running the pass over an already-fused
    /// plan changes nothing (blocks, ranges, kinds, cycles).
    #[test]
    fn fusing_an_already_fused_plan_is_a_no_op() {
        let c = cfg();
        let mut p = Program::new("idem");
        p.push(VInst::SetVl { avl: 16, sew: Sew::E8, lmul: Lmul::M1 });
        // a fused load run, a lone (unfusable) load, a SWAR op, and a
        // fused store run, with scalar slots sprinkled through
        for k in 0..4u64 {
            p.push(VInst::Load { eew: Sew::E8, vd: 1 + k as u8, addr: 0x40 + 16 * k });
        }
        p.push(VInst::Scalar { kind: ScalarKind::LoopCtl, n: 1 });
        p.push(VInst::Load { eew: Sew::E8, vd: 9, addr: 0x300 });
        p.push(VInst::OpVV { op: VOp::Add, vd: 5, vs2: 1, vs1: 2 });
        for k in 0..3u64 {
            p.push(VInst::Store { eew: Sew::E8, vs3: 5, addr: 0x200 + 16 * k });
        }
        let cp = CompiledProgram::compile(&p, &c).unwrap();
        assert!(cp.plan.fused_blocks >= 2, "both runs should fuse");
        let refused = fuse(&cp.plan.steps, cp.plan.blocks.clone());
        assert_eq!(refused, cp.plan.blocks);
    }

    /// The fused engine enforces the same entry contract as the
    /// per-uop one (`run_compiled_rejects_mismatched_machine`).
    #[test]
    fn fused_plan_rejects_mismatched_machine() {
        let c = cfg();
        let mut p = Program::new("fused-mismatch");
        p.push(VInst::SetVl { avl: 16, sew: Sew::E8, lmul: Lmul::M1 });
        p.push(VInst::Load { eew: Sew::E8, vd: 1, addr: 0 });
        p.push(VInst::Load { eew: Sew::E8, vd: 2, addr: 16 });
        let cp = CompiledProgram::compile(&p, &c).unwrap();
        assert_eq!(cp.plan.fused_blocks, 1);
        let mut m = Machine::new(ProcessorConfig::ara(), 1 << 12);
        assert!(m.run_compiled(&cp).is_err());
        assert!(m.run_compiled_unfused(&cp).is_err());
    }

    /// When a fused run's merged span faults, the engine must fall
    /// back to per-member dispatch and reproduce the interpreter's
    /// partial memory state and first error exactly.
    #[test]
    fn merged_store_run_bounds_failure_matches_the_interpreter_exactly() {
        let c = cfg();
        let mem_size = 1 << 12; // stores run off the 4 KiB edge
        let mut p = Program::new("oob-run");
        p.push(VInst::SetVl { avl: 16, sew: Sew::E8, lmul: Lmul::M1 });
        p.push(VInst::OpVI { op: VOp::Mv, vd: 1, vs2: 0, imm: 5 });
        p.push(VInst::Store { eew: Sew::E8, vs3: 1, addr: 4064 });
        p.push(VInst::Store { eew: Sew::E8, vs3: 1, addr: 4080 });
        p.push(VInst::Store { eew: Sew::E8, vs3: 1, addr: 4096 }); // faults
        let cp = CompiledProgram::compile(&p, &c).unwrap();
        assert_eq!(cp.plan.fused_blocks, 1, "the run fuses before the fault is known");
        let mut a = Machine::new(c.clone(), mem_size);
        let mut b = Machine::new(c.clone(), mem_size);
        let mut u = Machine::new(c.clone(), mem_size);
        let ea = a.run(&p).unwrap_err();
        let eb = b.run_compiled(&cp).unwrap_err();
        let eu = u.run_compiled_unfused(&cp).unwrap_err();
        assert_eq!(ea, eb);
        assert_eq!(ea, eu);
        // the two in-bounds members landed before the fault, on every
        // engine
        assert_eq!(a.mem.read(4064, 32).unwrap(), &[5u8; 32][..]);
        assert_eq!(a.mem.read(4064, 32).unwrap(), b.mem.read(4064, 32).unwrap());
        assert_eq!(a.mem.read(4064, 32).unwrap(), u.mem.read(4064, 32).unwrap());
    }

    /// The per-block cycle advances partition the precomputed run
    /// total — the invariant behind constant-time timing in the plan
    /// engine — and the precomputed total equals a live run's.
    #[test]
    fn block_cycles_partition_the_precomputed_total() {
        let c = cfg();
        let mut p = Program::new("cycles");
        p.push(VInst::SetVl { avl: 32, sew: Sew::E8, lmul: Lmul::M1 });
        for k in 0..3u64 {
            p.push(VInst::Load { eew: Sew::E8, vd: 1 + k as u8, addr: 32 * k });
        }
        p.push(VInst::OpVX { op: VOp::Macsr, vd: 4, vs2: 1, rs1: 3 });
        p.push(VInst::Scalar { kind: ScalarKind::LoopCtl, n: 2 });
        p.push(VInst::Store { eew: Sew::E8, vs3: 4, addr: 0x400 });
        p.push(VInst::Store { eew: Sew::E8, vs3: 4, addr: 0x420 });
        let cp = CompiledProgram::compile(&p, &c).unwrap();
        let (_, fused_blocks, _, block_sum) = cp.plan_stats();
        assert!(fused_blocks >= 1);
        let mut m = Machine::new(c, 1 << 16);
        let r = m.run_compiled(&cp).unwrap();
        assert_eq!(block_sum, r.stats.cycles);
        let mut mu = Machine::new(cp.cfg.clone(), 1 << 16);
        let ru = mu.run_compiled_unfused(&cp).unwrap();
        assert_eq!(ru.stats.cycles, r.stats.cycles, "live timing equals the precomputed total");
    }

    #[test]
    fn vx_mul_family_lowers_to_the_spaced_field_trick() {
        // guard the fast path specifically: a regression that demotes
        // the .vx multiply family to the per-lane loop would still
        // count as "Swar" in the aggregate, so pin the variant itself
        let c = cfg();
        let mut p = Program::new("trick");
        p.push(VInst::SetVl { avl: 64, sew: Sew::E8, lmul: Lmul::M1 });
        p.push(VInst::OpVX { op: VOp::Macsr, vd: 1, vs2: 2, rs1: 3 });
        p.push(VInst::OpVV { op: VOp::Macc, vd: 3, vs2: 4, vs1: 5 });
        p.push(VInst::SetVl { avl: 8, sew: Sew::E32, lmul: Lmul::M1 });
        p.push(VInst::OpVX { op: VOp::Macc, vd: 6, vs2: 7, rs1: 3 });
        let cp = CompiledProgram::compile(&p, &c).unwrap();
        assert!(matches!(cp.uops[1].exec, Exec::MulVx { .. }), ".vx at E8 takes the trick");
        assert!(matches!(cp.uops[2].exec, Exec::MulLane { .. }), ".vv takes the lane loop");
        assert!(matches!(cp.uops[4].exec, Exec::MulLane { .. }), ".vx at E32 takes the lane loop");
    }

    #[test]
    fn wide_eew_load_past_v31_is_typed_not_a_panic() {
        // At e8/m8 with vl = VLMAX, a load at EEW=64 spans 8x the group
        // bytes: the interpreter only catches this as a debug_assert /
        // slice panic; the compile path reports it as a typed error.
        let c = cfg();
        let mut p = Program::new("oob");
        p.push(VInst::SetVl { avl: 1 << 20, sew: Sew::E8, lmul: Lmul::M8 });
        p.push(VInst::Load { eew: Sew::E64, vd: 24, addr: 0 });
        assert_eq!(
            CompiledProgram::compile(&p, &c).unwrap_err(),
            SimError::GroupPastV31 { reg: 24, lmul: 8 }
        );
    }

    #[test]
    fn compile_rejects_illegal_ops_for_the_config() {
        let mut p = Program::new("illegal");
        p.push(VInst::SetVl { avl: 8, sew: Sew::E16, lmul: Lmul::M1 });
        p.push(VInst::OpVX { op: VOp::Macsr, vd: 1, vs2: 2, rs1: 3 });
        assert_eq!(
            CompiledProgram::compile(&p, &ProcessorConfig::ara()).unwrap_err(),
            SimError::NoVmacsr
        );
    }

    #[test]
    fn run_compiled_rejects_mismatched_machine() {
        let p = Program::new("empty");
        let cp = CompiledProgram::compile(&p, &ProcessorConfig::sparq()).unwrap();
        let mut m = Machine::new(ProcessorConfig::ara(), 1 << 12);
        assert!(m.run_compiled(&cp).is_err());
    }

    #[test]
    fn swar_add_sub_lanes_are_independent() {
        for sew in [Sew::E8, Sew::E16, Sew::E32, Sew::E64] {
            let h = hi_mask(sew);
            let a = 0xFFFE_8001_7FFF_0000u64;
            let b = 0x0003_8001_8001_FFFFu64;
            let bits = sew.bits();
            let lanes = 64 / bits;
            let sum = swar_add(a, b, h);
            let dif = swar_sub(a, b, h);
            for k in 0..lanes {
                let sh = k * bits;
                let la = exec::trunc(a >> sh, sew);
                let lb = exec::trunc(b >> sh, sew);
                assert_eq!(exec::trunc(sum >> sh, sew), exec::trunc(la.wrapping_add(lb), sew));
                assert_eq!(exec::trunc(dif >> sh, sew), exec::trunc(la.wrapping_sub(lb), sew));
            }
        }
    }

    #[test]
    fn swar_mul_prod_matches_per_lane() {
        // E8: 8 lanes, every (shift, x) combination against the scalar
        for &x in &[0u64, 1, 2, 0x55, 0xAA, 0xFF] {
            for sh in 0..8u32 {
                let a = 0x80FF_7F01_C933_0212u64;
                let got = swar_mul_prod(a, x, sh, 0x00FF_00FF_00FF_00FF, 8);
                for k in 0..8 {
                    let la = (a >> (8 * k)) as u8 as u64;
                    let want = ((la * x) & 0xFF) >> sh;
                    assert_eq!((got >> (8 * k)) as u8 as u64, want, "x={x:#x} sh={sh} lane {k}");
                }
            }
        }
        // E16: 4 lanes
        for &x in &[0u64, 3, 0x8000, 0xFFFF] {
            for sh in [0u32, 8, 15] {
                let a = 0xFFFF_8001_1234_00FFu64;
                let got = swar_mul_prod(a, x, sh, 0x0000_FFFF_0000_FFFF, 16);
                for k in 0..4 {
                    let la = (a >> (16 * k)) as u16 as u64;
                    let want = ((la * x) & 0xFFFF) >> sh;
                    assert_eq!((got >> (16 * k)) as u16 as u64, want, "x={x:#x} sh={sh} lane {k}");
                }
            }
        }
    }
}
