//! Level representations and the float->level quantizers, matching
//! `python/compile/kernels/quant.py` bit-for-bit on the level domain.

/// Max unsigned activation level at `a_bits`: `2^A - 1`.
pub fn act_level_max(a_bits: u32) -> u64 {
    (1u64 << a_bits) - 1
}

/// Max zero-point-offset weight level.  Symmetric weights quantize to
/// `[-zp, +zp]` with `zp = 2^(W-1) - 1`, stored as `[0, 2*zp]`; binary
/// (W=1) weights are `{0, 1}`.
pub fn weight_level_max(w_bits: u32) -> u64 {
    if w_bits == 1 {
        1
    } else {
        2 * ((1u64 << (w_bits - 1)) - 1)
    }
}

/// Weight zero point (the level that represents 0.0).
pub fn weight_zero_point(w_bits: u32) -> u64 {
    if w_bits == 1 {
        0
    } else {
        (1u64 << (w_bits - 1)) - 1
    }
}

/// Symmetric uniform quantizer (scale fixed at construction).
#[derive(Debug, Clone, Copy)]
pub struct Quantizer {
    pub bits: u32,
    pub scale: f32,
}

impl Quantizer {
    /// Activation quantizer whose top level hits `hi`.
    pub fn for_activations(bits: u32, hi: f32) -> Quantizer {
        Quantizer { bits, scale: hi.max(1e-5) / act_level_max(bits) as f32 }
    }

    /// Weight quantizer whose max magnitude hits the top magnitude.
    pub fn for_weights(bits: u32, max_abs: f32) -> Quantizer {
        let zp = weight_zero_point(bits).max(1);
        Quantizer { bits, scale: max_abs.max(1e-5) / zp as f32 }
    }

    /// Unsigned activation level: `clip(round(x/s), 0, 2^b - 1)`.
    pub fn act_level(&self, x: f32) -> u64 {
        let q = (x / self.scale).round();
        (q.max(0.0) as u64).min(act_level_max(self.bits))
    }

    /// Zero-point-offset weight level: `clip(round(w/s) + zp, 0, 2zp)`.
    pub fn weight_level(&self, w: f32) -> u64 {
        let zp = weight_zero_point(self.bits) as f32;
        let q = (w / self.scale).round() + zp;
        (q.max(0.0) as u64).min(weight_level_max(self.bits))
    }

    /// Dequantize an activation level.
    pub fn act_value(&self, level: u64) -> f32 {
        level as f32 * self.scale
    }

    /// Dequantize a weight level.
    pub fn weight_value(&self, level: u64) -> f32 {
        (level as f32 - weight_zero_point(self.bits) as f32) * self.scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Prop;

    #[test]
    fn level_maxes() {
        assert_eq!(act_level_max(1), 1);
        assert_eq!(act_level_max(4), 15);
        assert_eq!(weight_level_max(1), 1);
        assert_eq!(weight_level_max(2), 2);
        assert_eq!(weight_level_max(4), 14);
        assert_eq!(weight_zero_point(4), 7);
        assert_eq!(weight_zero_point(1), 0);
    }

    #[test]
    fn act_levels_bounded_and_monotone() {
        Prop::new(0xACC).runs(200).check(|g| {
            let bits = g.range(1, 8) as u32;
            let q = Quantizer::for_activations(bits, 1.0 + g.f32());
            let a = g.f32() * 3.0 - 0.5;
            let b = a + g.f32();
            let (la, lb) = (q.act_level(a), q.act_level(b));
            assert!(la <= act_level_max(bits));
            assert!(lb >= la, "quantizer must be monotone");
        });
    }

    #[test]
    fn weight_roundtrip_error_within_half_scale() {
        Prop::new(0xBEE).runs(200).check(|g| {
            let bits = g.range(2, 6) as u32;
            let q = Quantizer::for_weights(bits, 1.0);
            let w = g.f32() * 2.0 - 1.0; // in [-1, 1]
            let lv = q.weight_level(w);
            let back = q.weight_value(lv);
            assert!((back - w).abs() <= q.scale / 2.0 + 1e-6, "w={w} back={back}");
        });
    }

    #[test]
    fn zero_maps_to_zero_point() {
        for bits in 2..=5 {
            let q = Quantizer::for_weights(bits, 1.0);
            assert_eq!(q.weight_level(0.0), weight_zero_point(bits));
            assert_eq!(q.weight_value(weight_zero_point(bits)), 0.0);
        }
    }
}
