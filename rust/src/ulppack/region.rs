//! The overflow-free region calculus.
//!
//! Terminology (see `ref.py` for the algebra): packing two operands per
//! B-bit container with subfields of S = B/2 bits, one modular multiply
//! yields `dot * 2^S + junk` where `dot = a0*w0 + a1*w1` and
//! `junk = a0*w1`.  Three independent capacity limits exist:
//!
//! 1. **dot field** (per multiply): `dot <= 2^S - 1` — or the shifted
//!    contribution is corrupted.  This bounds which (W, A) pairs a
//!    container admits at all.
//! 2. **junk field** (native scheme only): junk accumulates for
//!    `k_local` issues and must stay below 2^S; same for the
//!    accumulated dot.  `vmacsr` eliminates this limit — the paper's
//!    contribution.
//! 3. **accumulator**: the shifted contributions accumulate in a B-bit
//!    register and must be spilled to a wider accumulator every
//!    `spill_every` issues.
//!
//! Activations are unsigned levels `[0, 2^A - 1]`; weights are
//! zero-point-offset unsigned levels `[0, 2*(2^(W-1)-1)]` (binary W=1
//! is special-cased to `{0, 1}`), matching the QNN quantizers.

use super::quantize::{act_level_max, weight_level_max};

/// Container width: LP = 16-bit, ULP = 8-bit (the paper's two ranges).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Container {
    Ulp,
    Lp,
}

impl Container {
    pub fn bits(self) -> u32 {
        match self {
            Container::Ulp => 8,
            Container::Lp => 16,
        }
    }

    pub fn shift(self) -> u32 {
        self.bits() / 2
    }

    pub fn bytes(self) -> u32 {
        self.bits() / 8
    }

    pub fn name(self) -> &'static str {
        match self {
            Container::Ulp => "ULP",
            Container::Lp => "LP",
        }
    }
}

/// Region-admission policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RegionMode {
    /// Worst-case-guaranteed: every input combination is exact.
    Strict,
    /// The paper's Fig. 5 operating region W+A <= S: typical quantized
    /// tensors are exact; adversarial worst cases may overflow the dot
    /// field (measured overflow rates are reported in EXPERIMENTS.md).
    Paper,
}

/// Worst-case per-issue dot product `a0*w0 + a1*w1` for levels.
pub fn dot_max(w_bits: u32, a_bits: u32) -> u64 {
    2 * act_level_max(a_bits) * weight_level_max(w_bits)
}

/// Worst-case per-issue junk term `a0*w1`.
pub fn junk_max(w_bits: u32, a_bits: u32) -> u64 {
    act_level_max(a_bits) * weight_level_max(w_bits)
}

/// Does (W, A) fit this container's dot field under `mode`?
pub fn admits(w_bits: u32, a_bits: u32, c: Container, mode: RegionMode) -> bool {
    match mode {
        RegionMode::Strict => dot_max(w_bits, a_bits) <= (1 << c.shift()) - 1,
        RegionMode::Paper => w_bits + a_bits <= c.shift(),
    }
}

/// How many raw (unshifted) products the native scheme may locally
/// accumulate before a subfield can overflow; 0 = native impossible.
pub fn native_k_local(w_bits: u32, a_bits: u32, c: Container) -> u64 {
    let field = (1u64 << c.shift()) - 1;
    let d = dot_max(w_bits, a_bits);
    let j = junk_max(w_bits, a_bits);
    if d == 0 {
        return field;
    }
    if d > field {
        return 0;
    }
    (field / d).min(field / j.max(1))
}

/// After how many `vmacsr` issues must the B-bit accumulator spill to a
/// wide accumulator (worst case)?
pub fn vmacsr_spill_every(w_bits: u32, a_bits: u32, c: Container) -> u64 {
    let cap = (1u64 << c.bits()) - 1;
    let d = dot_max(w_bits, a_bits).max(1);
    (cap / d).max(1)
}

/// An execution plan for one packed conv2d at (W, A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Plan {
    pub container: Container,
    pub w_bits: u32,
    pub a_bits: u32,
    pub mode: RegionMode,
    /// vmacsr: spill cadence in issues (u64::MAX = never needed given
    /// `total_issues`); native: the local-accumulation budget.
    pub spill_every: u64,
    /// Whether exactness vs the plain integer conv is guaranteed for
    /// arbitrary inputs (strict admission) or data-dependent (paper).
    pub exact: bool,
}

/// Relative time per packed issue for a container with a drain of
/// `drain_instrs` extra ops every `cadence` issues: instruction time is
/// proportional to the container's byte width, and each drain op costs
/// roughly one issue's worth of (chained, but RAW-serialised) ALU time.
fn issue_cost(c: Container, cadence: u64, drain_instrs: u64) -> f64 {
    let per_issue = 1.0 + drain_instrs as f64 / cadence.max(1) as f64;
    per_issue * c.bytes() as f64
}

/// Choose the best container for a `vmacsr` conv at (W, A): the one
/// with the lowest per-issue cost (ULP moves half the bytes but may
/// spill more often).
pub fn plan_vmacsr(
    w_bits: u32,
    a_bits: u32,
    total_issues: u64,
    mode: RegionMode,
) -> Option<Plan> {
    let mut best: Option<(f64, Plan)> = None;
    for c in [Container::Ulp, Container::Lp] {
        if !admits(w_bits, a_bits, c, mode) {
            continue;
        }
        let spill = vmacsr_spill_every(w_bits, a_bits, c);
        let needed = spill < total_issues;
        let cost = issue_cost(c, spill, if needed { 2 } else { 0 });
        let plan = Plan {
            container: c,
            w_bits,
            a_bits,
            mode,
            spill_every: if needed { spill } else { u64::MAX },
            exact: admits(w_bits, a_bits, c, RegionMode::Strict),
        };
        // plain match, not Option::is_none_or (a 1.82 API; MSRV 1.75)
        let better = match &best {
            None => true,
            Some((bc, _)) => cost < *bc,
        };
        if better {
            best = Some((cost, plan));
        }
    }
    best.map(|(_, p)| p)
}

/// Choose the container for a native (no-vmacsr) ULPPACK conv by the
/// same cost model (the repair sequence is 3 instructions).  The native
/// scheme cannot tolerate junk-field overflow at all, so it is always
/// strict.
pub fn plan_native(w_bits: u32, a_bits: u32) -> Option<Plan> {
    let mut best: Option<(f64, Plan)> = None;
    for c in [Container::Ulp, Container::Lp] {
        let k = native_k_local(w_bits, a_bits, c);
        if k == 0 {
            continue;
        }
        let cost = issue_cost(c, k, 3);
        let plan = Plan {
            container: c,
            w_bits,
            a_bits,
            mode: RegionMode::Strict,
            spill_every: k,
            exact: true,
        };
        // plain match, not Option::is_none_or (a 1.82 API; MSRV 1.75)
        let better = match &best {
            None => true,
            Some((bc, _)) => cost < *bc,
        };
        if better {
            best = Some((cost, plan));
        }
    }
    best.map(|(_, p)| p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_max_uses_symmetric_weight_levels() {
        // W2: levels [0,2] (zp=1)  A2: [0,3]
        assert_eq!(dot_max(2, 2), 2 * 3 * 2);
        // W1 is binary {0,1}
        assert_eq!(dot_max(1, 1), 2);
        // W4: [0,14], A4: [0,15]
        assert_eq!(dot_max(4, 4), 2 * 15 * 14);
    }

    #[test]
    fn paper_headline_points_admitted_in_paper_mode() {
        assert!(admits(2, 2, Container::Ulp, RegionMode::Paper)); // 3.2x point
        assert!(admits(4, 4, Container::Lp, RegionMode::Paper)); // 1.7x point
        assert!(!admits(4, 4, Container::Lp, RegionMode::Strict)); // 420 > 255
    }

    #[test]
    fn w2a2_is_strict_on_ulp_thanks_to_symmetric_weights() {
        // dot_max = 12 <= 15: the zero-point representation buys W2A2
        // strict exactness on 8-bit containers
        assert!(admits(2, 2, Container::Ulp, RegionMode::Strict));
        assert!(!admits(2, 3, Container::Ulp, RegionMode::Strict));
    }

    #[test]
    fn native_k_local_w1a1_matches_paper_ballpark() {
        // paper: ~8 local accumulations at 1-bit on 8-bit containers
        assert_eq!(native_k_local(1, 1, Container::Ulp), 7);
    }

    #[test]
    fn native_impossible_where_dot_field_overflows() {
        assert_eq!(native_k_local(4, 4, Container::Lp), 0);
        assert!(native_k_local(3, 3, Container::Lp) >= 1);
    }

    #[test]
    fn vmacsr_spill_cadence() {
        // W2A2 @ LP: dot_max 12 -> 65535/12 = 5461 issues before spill
        assert_eq!(vmacsr_spill_every(2, 2, Container::Lp), 5461);
        // W2A2 @ ULP: 255/12 = 21
        assert_eq!(vmacsr_spill_every(2, 2, Container::Ulp), 21);
    }

    #[test]
    fn plan_vmacsr_prefers_ulp() {
        let p = plan_vmacsr(2, 2, 784, RegionMode::Paper).unwrap();
        assert_eq!(p.container, Container::Ulp);
        assert!(p.exact); // W2A2 is strict on ULP
        let p = plan_vmacsr(4, 4, 784, RegionMode::Paper).unwrap();
        assert_eq!(p.container, Container::Lp);
        assert!(!p.exact);
        assert!(plan_vmacsr(4, 4, 784, RegionMode::Strict).is_none());
    }

    #[test]
    fn plan_vmacsr_spill_infinite_when_not_needed() {
        let p = plan_vmacsr(2, 2, 784, RegionMode::Strict).unwrap();
        // ULP spills every 21 < 784 issues
        assert_eq!(p.container, Container::Ulp);
        assert_eq!(p.spill_every, 21);
        let p = plan_vmacsr(3, 3, 784, RegionMode::Strict).unwrap();
        // LP: dot 84 -> 65535/84 = 780 < 784 issues: one spill
        assert_eq!(p.container, Container::Lp);
        assert_eq!(p.spill_every, 780);
        let p = plan_vmacsr(3, 3, 700, RegionMode::Strict).unwrap();
        assert_eq!(p.spill_every, u64::MAX);
    }

    #[test]
    fn plan_native_always_strict() {
        for w in 1..=4u32 {
            for a in 1..=4u32 {
                if let Some(p) = plan_native(w, a) {
                    assert!(p.exact);
                    assert!(p.spill_every >= 1);
                }
            }
        }
    }

    #[test]
    fn plan_native_w4a4_not_runnable() {
        // dot_max(4,4) = 420 > 255: no container admits it natively
        assert_eq!(plan_native(4, 4), None);
    }
}
