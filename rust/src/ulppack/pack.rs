//! Host-side ULPPACK P1 packing (k=2) — the functional reference for
//! the runtime vector packing code in `kernels::pack_rt`, and the
//! loader used to seed weight containers.
//!
//! Layouts match `ref.py`:
//!   activations: `packed[c] = lv[2c]   | lv[2c+1] << S`
//!   weights:     `packed[c] = lv[2c+1] | lv[2c]   << S`   (swapped)

use super::region::Container;

/// Pack activation levels pairwise along the channel axis.
/// `levels` is (C, H*W) row-major flattened per channel; returns
/// (C/2, H*W) containers.
pub fn pack_activations(levels: &[Vec<u64>], c: Container) -> Vec<Vec<u64>> {
    assert!(levels.len() % 2 == 0, "channel count must be even");
    let s = c.shift();
    let mask = (1u64 << c.bits()) - 1;
    levels
        .chunks(2)
        .map(|pair| {
            pair[0]
                .iter()
                .zip(&pair[1])
                .map(|(&lo, &hi)| (lo | (hi << s)) & mask)
                .collect()
        })
        .collect()
}

/// Pack weight levels pairwise along the in-channel axis with swapped
/// halves. `levels[o][c]` is the (Fh*Fw)-flattened filter; returns
/// `[o][c/2]` containers.
pub fn pack_weights(levels: &[Vec<Vec<u64>>], c: Container) -> Vec<Vec<Vec<u64>>> {
    let s = c.shift();
    let mask = (1u64 << c.bits()) - 1;
    levels
        .iter()
        .map(|per_out| {
            assert!(per_out.len() % 2 == 0, "in-channel count must be even");
            per_out
                .chunks(2)
                .map(|pair| {
                    pair[1]
                        .iter()
                        .zip(&pair[0])
                        .map(|(&lo, &hi)| (lo | (hi << s)) & mask)
                        .collect()
                })
                .collect()
        })
        .collect()
}

/// Split a container back into (low, high) subfields.
pub fn unpack_container(v: u64, c: Container) -> (u64, u64) {
    let s = c.shift();
    let fmask = (1u64 << s) - 1;
    (v & fmask, (v >> s) & fmask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Prop;

    #[test]
    fn activation_packing_layout() {
        let levels = vec![vec![1, 2], vec![3, 4]];
        let p = pack_activations(&levels, Container::Lp);
        assert_eq!(p, vec![vec![1 | (3 << 8), 2 | (4 << 8)]]);
    }

    #[test]
    fn weight_packing_is_swapped() {
        let levels = vec![vec![vec![1], vec![2]]]; // o=0, c0=1, c1=2
        let p = pack_weights(&levels, Container::Lp);
        assert_eq!(p, vec![vec![vec![2 | (1 << 8)]]]);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        Prop::new(0x9A5).runs(300).check(|g| {
            let c = *g.pick(&[Container::Ulp, Container::Lp]);
            let s = c.shift();
            let lo = g.below(1 << s);
            let hi = g.below(1 << s);
            let packed = lo | (hi << s);
            assert_eq!(unpack_container(packed, c), (lo, hi));
        });
    }

    #[test]
    fn packed_multiply_computes_dot_in_high_field() {
        // the defining identity: (a0 + a1<<S) * (w1 + w0<<S) mod 2^B
        //   = (a0w0 + a1w1) << S  +  a0w1      (when fields fit)
        Prop::new(0x1D0).runs(500).check(|g| {
            let c = *g.pick(&[Container::Ulp, Container::Lp]);
            let s = c.shift();
            let bound = 1u64 << (s / 2); // keep products within fields
            let (a0, a1, w0, w1) =
                (g.below(bound), g.below(bound), g.below(bound), g.below(bound));
            if a0 * w0 + a1 * w1 >= (1 << s) || a0 * w1 >= (1 << s) {
                return;
            }
            let ac = a0 | (a1 << s);
            let wc = w1 | (w0 << s);
            let prod = (ac.wrapping_mul(wc)) & ((1u64 << c.bits()) - 1);
            assert_eq!(prod >> s, a0 * w0 + a1 * w1);
            assert_eq!(prod & ((1 << s) - 1), a0 * w1);
        });
    }
}
