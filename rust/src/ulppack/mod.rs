//! ULPPACK P1 packing with k=2 operands per container, and the
//! overflow-free-region calculus that decides which (W, A) precision
//! pairs run where — the analytical heart of the paper (mirrors
//! `python/compile/kernels/ref.py`; the two are kept in lock-step by
//! the cross-layer tests).

pub mod pack;
pub mod quantize;
pub mod region;

pub use pack::{pack_activations, pack_weights, unpack_container};
pub use quantize::{act_level_max, weight_level_max, Quantizer};
pub use region::{Container, Plan, RegionMode};
