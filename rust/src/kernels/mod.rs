//! The paper's "hand-written inline assembly" as instruction-stream
//! builders: each conv2d variant emits the exact RVV (+`vmacsr`) trace
//! an unrolled hand-tuned kernel would execute, against tensors that
//! live in the simulated memory.
//!
//! Variants (paper Fig. 4 legend):
//! * [`conv_int16`]  — optimized int16 baseline (the speedup denominator)
//! * [`conv_fp32`]   — fp32 baseline (runs on Ara; Sparq has no FPU)
//! * [`conv_native`] — ULPPACK on stock RVV: vmacc + the vsrl/vwaddu
//!   repair sequence every `k_local` issues (W1A1/W2A2/W3A3 bars)
//! * [`conv_vmacsr`] — Algorithm 1 on Sparq: `vmacsr` with
//!   calculus-scheduled wide-accumulator spills (LP and ULP bars)
//! * [`pack_rt`]     — the runtime packing passes (counted in the
//!   measured cycles, exactly like the paper measures)
//!
//! Golden models live in [`workload`]; each variant's module tests pin
//! its outputs to them bit-for-bit.
//!
//! Beyond the conv variants, [`requant`], [`eltwise`] and [`pool_fc`]
//! emit the *inter-layer* streams of the dataflow QNN executor
//! ([`crate::qnn::compiled::CompiledQnn`]): zero-padding + requantize
//! + narrow at every layer boundary, the requantizing `vadd.vv`
//! residual join, 2x2 maxpool via the `vnsrl` deinterleave idiom, and
//! the GAP+FC head — executed layers, not bytes/cycle estimates.
//! [`im2col_gemm`] lowers `Dense` heads as an im2col copy + packed
//! GEMM over the same region-calculus plans.  [`autotune`] measures the candidate
//! variants per (processor, layer shape, precision) on the simulator
//! and memoizes the ranking in the [`ProgramCache`], so the dataflow
//! compiler serves the fastest legal kernel per layer.
//!
//! ## Compile once, execute many
//!
//! [`compile_conv`] builds a [`CompiledConv`] (instruction stream +
//! tensor layout + the pre-compiled micro-op form with its fused
//! execution plan, see [`crate::sim::CompiledProgram`] and DESIGN.md
//! §Perf) once per (dims, variant, processor, opts, weights) tuple;
//! [`CompiledConv::execute`] rebinds activation data into a reset
//! machine and walks the fused plan — bulk runs as one sweep per run,
//! cycle totals precomputed at compile time — with bit-identical
//! outputs and cycle counts.  [`ProgramCache`] memoizes compilations —
//! including the fused form — behind a content key and
//! [`crate::sim::MachinePool`] recycles machines, which is what the
//! serving stack and the bench sweeps use ([`run_conv_cached`]).
//! [`run_conv`] keeps the original one-shot build-and-run semantics.

pub mod asm;
pub mod autotune;
pub mod cache;
pub mod conv_engine;
pub mod conv_fp32;
pub mod conv_int16;
pub mod conv_native;
pub mod conv_vmacsr;
pub mod eltwise;
pub mod im2col_gemm;
pub mod pack_rt;
pub mod pool_fc;
pub mod requant;
pub mod workload;

pub use autotune::TuneOutcome;
pub use cache::{CacheStats, ProgramCache, TuneKey};
pub use conv_engine::{CompiledConv, EngineOpts};
pub use workload::{ConvDims, OutputRef, Workload};

use crate::arch::ProcessorConfig;
use crate::sim::{Machine, MachinePool, RunReport, SimError};
use crate::ulppack::{region, RegionMode};
use conv_engine::Inner;

/// Which conv2d implementation to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ConvVariant {
    Int16,
    Fp32,
    /// Native ULPPACK at (W, A) on stock RVV.
    Native { w_bits: u32, a_bits: u32 },
    /// Algorithm 1 with `vmacsr` at (W, A).
    Vmacsr { w_bits: u32, a_bits: u32, mode: RegionMode },
}

impl ConvVariant {
    pub fn label(&self) -> String {
        match *self {
            ConvVariant::Int16 => "int16-conv2d".into(),
            ConvVariant::Fp32 => "fp32-conv2d".into(),
            ConvVariant::Native { w_bits, a_bits } => format!("W{w_bits}A{a_bits}-conv2d"),
            ConvVariant::Vmacsr { w_bits, a_bits, .. } => {
                format!("W{w_bits}A{a_bits}-vmacsr-conv2d")
            }
        }
    }

    /// The (W, A) bits the workload should be quantized to.
    pub fn bits(&self) -> (u32, u32) {
        match *self {
            ConvVariant::Int16 | ConvVariant::Fp32 => (8, 8),
            ConvVariant::Native { w_bits, a_bits }
            | ConvVariant::Vmacsr { w_bits, a_bits, .. } => (w_bits, a_bits),
        }
    }

    /// Resolve the region-calculus plan into an engine inner policy and
    /// the builder's label — the single source of truth the variant
    /// modules (`conv_native`, `conv_vmacsr`) and the cached path both
    /// delegate to, so every path reports identical labels.
    pub(crate) fn planned_inner(&self, wl: &Workload) -> Result<(Inner, String), SimError> {
        Ok(match *self {
            ConvVariant::Int16 => (Inner::Int16, self.label()),
            ConvVariant::Fp32 => (Inner::Fp32, self.label()),
            ConvVariant::Native { w_bits, a_bits } => {
                let plan = region::plan_native(w_bits, a_bits)
                    .ok_or(SimError::Unsupported("precision pair not natively packable"))?;
                (
                    Inner::Native { container: plan.container, k_local: plan.spill_every },
                    format!("W{w_bits}A{a_bits}-conv2d-native"),
                )
            }
            ConvVariant::Vmacsr { w_bits, a_bits, mode } => {
                let plan =
                    region::plan_vmacsr(w_bits, a_bits, wl.dims.issues_per_output(), mode)
                        .ok_or(SimError::Unsupported(
                            "precision pair outside every container's region",
                        ))?;
                (
                    Inner::Vmacsr { container: plan.container, spill_every: plan.spill_every },
                    format!("{}-W{w_bits}A{a_bits}-vmacsr", plan.container.name()),
                )
            }
        })
    }
}

/// One finished conv run: the timing report, the machine (for reading
/// memory back), and where the output tensor is.
pub struct ConvRun {
    pub report: RunReport,
    pub machine: Machine,
    pub out: OutputRef,
}

/// Compile one conv2d variant for `cfg` without running it — the
/// "compile" half of compile-once/execute-many.  Weights from `wl` are
/// baked into the stream; activations rebind per execution.
pub fn compile_conv(
    cfg: &ProcessorConfig,
    wl: &Workload,
    variant: ConvVariant,
) -> Result<CompiledConv, SimError> {
    compile_conv_opts(cfg, wl, variant, EngineOpts::default())
}

pub fn compile_conv_opts(
    cfg: &ProcessorConfig,
    wl: &Workload,
    variant: ConvVariant,
    opts: EngineOpts,
) -> Result<CompiledConv, SimError> {
    let (inner, label) = variant.planned_inner(wl)?;
    conv_engine::compile(cfg, wl, inner, opts, label)
}

/// Build + run one conv2d variant on a fresh machine.
pub fn run_conv(
    cfg: &ProcessorConfig,
    wl: &Workload,
    variant: ConvVariant,
) -> Result<ConvRun, SimError> {
    run_conv_opts(cfg, wl, variant, EngineOpts::default())
}

pub fn run_conv_opts(
    cfg: &ProcessorConfig,
    wl: &Workload,
    variant: ConvVariant,
    opts: EngineOpts,
) -> Result<ConvRun, SimError> {
    let cc = compile_conv_opts(cfg, wl, variant, opts)?;
    let mut m = Machine::new(cfg.clone(), wl.mem_bytes());
    let report = cc.execute_fresh(&mut m, wl)?;
    Ok(ConvRun { report, machine: m, out: cc.out })
}

/// Run one conv through the compiled-program cache on a pooled machine
/// — the hot path for sweeps and serving.  Identical outputs and cycle
/// counts to [`run_conv_opts`]; only the host-side rebuild/realloc work
/// is skipped on cache hits.
pub fn run_conv_cached(
    cache: &ProgramCache,
    pool: &MachinePool,
    cfg: &ProcessorConfig,
    wl: &Workload,
    variant: ConvVariant,
    opts: EngineOpts,
) -> Result<RunReport, SimError> {
    let cc = cache.get_or_compile(cfg, wl, variant, opts)?;
    let mut m = pool.acquire(cfg, cc.mem_bytes);
    // acquire() already reset the machine: skip execute()'s re-zeroing
    let report = cc.execute_fresh(&mut m, wl);
    pool.release(m);
    report
}
