//! The paper's "hand-written inline assembly" as instruction-stream
//! builders: each conv2d variant emits the exact RVV (+`vmacsr`) trace
//! an unrolled hand-tuned kernel would execute, against tensors that
//! live in the simulated memory.
//!
//! Variants (paper Fig. 4 legend):
//! * [`conv_int16`]  — optimized int16 baseline (the speedup denominator)
//! * [`conv_fp32`]   — fp32 baseline (runs on Ara; Sparq has no FPU)
//! * [`conv_native`] — ULPPACK on stock RVV: vmacc + the vsrl/vwaddu
//!   repair sequence every `k_local` issues (W1A1/W2A2/W3A3 bars)
//! * [`conv_vmacsr`] — Algorithm 1 on Sparq: `vmacsr` with
//!   calculus-scheduled wide-accumulator spills (LP and ULP bars)
//! * [`pack_rt`]     — the runtime packing passes (counted in the
//!   measured cycles, exactly like the paper measures)
//!
//! Golden models live in [`workload`]; each variant's module tests pin
//! its outputs to them bit-for-bit.

pub mod asm;
pub mod conv_engine;
pub mod conv_fp32;
pub mod conv_int16;
pub mod conv_native;
pub mod conv_vmacsr;
pub mod im2col_gemm;
pub mod pack_rt;
pub mod workload;

pub use conv_engine::EngineOpts;
pub use workload::{ConvDims, OutputRef, Workload};

use crate::arch::ProcessorConfig;
use crate::sim::{Machine, RunReport, SimError};
use crate::ulppack::RegionMode;

/// Which conv2d implementation to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConvVariant {
    Int16,
    Fp32,
    /// Native ULPPACK at (W, A) on stock RVV.
    Native { w_bits: u32, a_bits: u32 },
    /// Algorithm 1 with `vmacsr` at (W, A).
    Vmacsr { w_bits: u32, a_bits: u32, mode: RegionMode },
}

impl ConvVariant {
    pub fn label(&self) -> String {
        match *self {
            ConvVariant::Int16 => "int16-conv2d".into(),
            ConvVariant::Fp32 => "fp32-conv2d".into(),
            ConvVariant::Native { w_bits, a_bits } => format!("W{w_bits}A{a_bits}-conv2d"),
            ConvVariant::Vmacsr { w_bits, a_bits, .. } => {
                format!("W{w_bits}A{a_bits}-vmacsr-conv2d")
            }
        }
    }

    /// The (W, A) bits the workload should be quantized to.
    pub fn bits(&self) -> (u32, u32) {
        match *self {
            ConvVariant::Int16 | ConvVariant::Fp32 => (8, 8),
            ConvVariant::Native { w_bits, a_bits }
            | ConvVariant::Vmacsr { w_bits, a_bits, .. } => (w_bits, a_bits),
        }
    }
}

/// One finished conv run: the timing report, the machine (for reading
/// memory back), and where the output tensor is.
pub struct ConvRun {
    pub report: RunReport,
    pub machine: Machine,
    pub out: OutputRef,
}

/// Build + run one conv2d variant on a fresh machine.
pub fn run_conv(
    cfg: &ProcessorConfig,
    wl: &Workload,
    variant: ConvVariant,
) -> Result<ConvRun, SimError> {
    run_conv_opts(cfg, wl, variant, EngineOpts::default())
}

pub fn run_conv_opts(
    cfg: &ProcessorConfig,
    wl: &Workload,
    variant: ConvVariant,
    opts: EngineOpts,
) -> Result<ConvRun, SimError> {
    let mut m = Machine::new(cfg.clone(), wl.mem_bytes());
    let (prog, out) = match variant {
        ConvVariant::Int16 => conv_engine::build(
            &mut m,
            wl,
            conv_engine::Inner::Int16,
            opts,
            variant.label(),
        )?,
        ConvVariant::Fp32 => conv_engine::build(
            &mut m,
            wl,
            conv_engine::Inner::Fp32,
            opts,
            variant.label(),
        )?,
        ConvVariant::Native { w_bits, a_bits } => {
            conv_native::build_opts(&mut m, wl, w_bits, a_bits, opts)?
        }
        ConvVariant::Vmacsr { w_bits, a_bits, mode } => {
            conv_vmacsr::build_opts(&mut m, wl, w_bits, a_bits, mode, opts)?
        }
    };
    let report = m.run(&prog)?;
    Ok(ConvRun { report, machine: m, out })
}
