//! The optimized int16 conv2d baseline — the denominator of every
//! speedup the paper reports.  Identical loop structure to Algorithm 1
//! (slide-based, output-stationary) with `vmacc.vx` at SEW=16 on
//! unpacked levels; no packing passes.

use super::conv_engine::{self, EngineOpts, Inner};
use super::workload::{OutputRef, Workload};
use crate::sim::{Machine, Program, SimError};

pub fn build(m: &mut Machine, wl: &Workload) -> Result<(Program, OutputRef), SimError> {
    conv_engine::build(m, wl, Inner::Int16, EngineOpts::default(), "int16-conv2d".into())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ProcessorConfig;
    use crate::kernels::workload::{golden_mod, ConvDims, Workload};
    use crate::testutil::Prop;

    fn run(wl: &Workload) -> (Vec<i64>, crate::sim::RunReport) {
        let mut m = Machine::new(ProcessorConfig::sparq(), wl.mem_bytes());
        let (prog, out) = build(&mut m, wl).unwrap();
        let rep = m.run(&prog).unwrap();
        (out.read_ints(&m.mem).unwrap(), rep)
    }

    #[test]
    fn matches_golden_small() {
        let d = ConvDims { c: 4, h: 8, w: 10, co: 2, fh: 3, fw: 3 };
        let wl = Workload::random(d, 8, 8, 11);
        let (got, rep) = run(&wl);
        assert_eq!(got, golden_mod(&wl, 16));
        assert_eq!(rep.macs, d.macs());
        assert!(rep.stats.cycles > 0);
    }

    #[test]
    fn matches_golden_7x7_strip_mined() {
        // width > VLMAX at the chosen LMUL forces strip-mining
        let d = ConvDims { c: 2, h: 9, w: 1100, co: 1, fh: 7, fw: 7 };
        let wl = Workload::random(d, 4, 4, 3);
        let (got, _) = run(&wl);
        assert_eq!(got, golden_mod(&wl, 16));
    }

    #[test]
    fn property_random_shapes_match_golden() {
        Prop::new(0x16).runs(12).check(|g| {
            let fh = g.range(1, 5) as u32;
            let fw = g.range(1, 5) as u32;
            let d = ConvDims {
                c: 2 * g.range(1, 3) as u32,
                h: fh + g.range(1, 6) as u32,
                w: fw + g.range(1, 12) as u32,
                co: g.range(1, 3) as u32,
                fh,
                fw,
            };
            let wl = Workload::random(d, 5, 5, g.next_u64());
            let (got, _) = run(&wl);
            assert_eq!(got, golden_mod(&wl, 16), "{d:?}");
        });
    }
}
