//! Workloads: the tensors a conv2d benchmark runs on, host-side golden
//! models, and the output descriptors the builders hand back.

use crate::sim::mem::Mem;
use crate::sim::SimError;
use crate::testutil::Gen;
use crate::ulppack::{act_level_max, weight_level_max, Container};

/// Conv2d problem dimensions ('valid' padding, channel-first).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ConvDims {
    pub c: u32,
    pub h: u32,
    pub w: u32,
    pub co: u32,
    pub fh: u32,
    pub fw: u32,
}

impl ConvDims {
    pub fn ho(&self) -> u32 {
        self.h - self.fh + 1
    }

    pub fn wo(&self) -> u32 {
        self.w - self.fw + 1
    }

    /// Useful multiply-accumulates of the convolution.
    pub fn macs(&self) -> u64 {
        self.co as u64
            * self.ho() as u64
            * self.wo() as u64
            * self.c as u64
            * self.fh as u64
            * self.fw as u64
    }

    /// Packed-container issues per output element (k=2 packing).
    pub fn issues_per_output(&self) -> u64 {
        (self.c as u64 / 2) * self.fh as u64 * self.fw as u64
    }

    /// The paper's Fig. 4 workload shape (scaled-down by default; the
    /// benches take a `--large` flag for the full 512x512).
    pub fn fig4(large: bool) -> ConvDims {
        let s = if large { 512 } else { 64 };
        ConvDims { c: 32, h: s + 6, w: s + 6, co: 8, fh: 7, fw: 7 }
    }

    /// The paper's Fig. 5 workload (32 x 256 x 256, 7x7).
    pub fn fig5(large: bool) -> ConvDims {
        let s = if large { 256 } else { 64 };
        ConvDims { c: 32, h: s + 6, w: s + 6, co: 8, fh: 7, fw: 7 }
    }
}

/// Host-side tensors for one quantization of a conv problem.
#[derive(Debug, Clone)]
pub struct Workload {
    pub dims: ConvDims,
    pub w_bits: u32,
    pub a_bits: u32,
    /// Activation levels, `[c][h*w]`.
    pub act: Vec<Vec<u64>>,
    /// Weight levels (zero-point offset), `[o][c][fh*fw]`.
    pub wgt: Vec<Vec<Vec<u64>>>,
    /// f32 views (for the fp32 baseline), same shapes.
    pub act_f32: Vec<Vec<f32>>,
    pub wgt_f32: Vec<Vec<Vec<f32>>>,
}

impl Workload {
    /// Uniform-random levels in the (W, A) ranges (the paper's RTL
    /// benchmarks use random tensors too).
    pub fn random(dims: ConvDims, w_bits: u32, a_bits: u32, seed: u64) -> Workload {
        assert!(dims.c % 2 == 0, "in-channels must be even for k=2 packing");
        let mut g = Gen::new(seed);
        let amax = act_level_max(a_bits);
        let wmax = weight_level_max(w_bits);
        let hw = (dims.h * dims.w) as usize;
        let fhw = (dims.fh * dims.fw) as usize;
        let act: Vec<Vec<u64>> =
            (0..dims.c).map(|_| (0..hw).map(|_| g.below(amax + 1)).collect()).collect();
        let wgt: Vec<Vec<Vec<u64>>> = (0..dims.co)
            .map(|_| {
                (0..dims.c).map(|_| (0..fhw).map(|_| g.below(wmax + 1)).collect()).collect()
            })
            .collect();
        let act_f32 = act
            .iter()
            .map(|row| row.iter().map(|&v| v as f32 / (amax + 1) as f32).collect())
            .collect();
        let wgt_f32 = wgt
            .iter()
            .map(|per_o| {
                per_o
                    .iter()
                    .map(|f| {
                        f.iter().map(|&v| v as f32 / (wmax + 1) as f32 - 0.5).collect()
                    })
                    .collect()
            })
            .collect();
        Workload { dims, w_bits, a_bits, act, wgt, act_f32, wgt_f32 }
    }

    /// Simulated-DRAM sizing for this workload (acts + packed copy +
    /// outputs + slack).
    pub fn mem_bytes(&self) -> usize {
        let d = &self.dims;
        let acts = (d.c * d.h * d.w) as usize * 4;
        let outs = (d.co * d.ho() * d.wo()) as usize * 4;
        (acts * 3 + outs * 2 + (1 << 16)).next_power_of_two()
    }
}

/// Element type of a conv output buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutElem {
    U16,
    U32,
    F32,
}

/// Where a builder put its output tensor.
#[derive(Debug, Clone, Copy)]
pub struct OutputRef {
    pub addr: u64,
    pub elem: OutElem,
    /// co * ho * wo elements, channel-first.
    pub len: usize,
}

impl OutputRef {
    /// Read the output back as i64 (f32 outputs are bit-preserved via
    /// `read_f32`, use that instead).
    pub fn read_ints(&self, mem: &Mem) -> Result<Vec<i64>, SimError> {
        Ok(match self.elem {
            OutElem::U16 => mem.read_u16s(self.addr, self.len)?.iter().map(|&v| v as i64).collect(),
            OutElem::U32 => mem
                .read_i32s(self.addr, self.len)?
                .iter()
                .map(|&v| v as u32 as i64)
                .collect(),
            OutElem::F32 => panic!("f32 output read as ints"),
        })
    }

    pub fn read_f32(&self, mem: &Mem) -> Result<Vec<f32>, SimError> {
        assert_eq!(self.elem, OutElem::F32);
        Ok(mem.read_f32s(self.addr, self.len)?)
    }
}

// ---------------------------------------------------------------------------
// Golden models
// ---------------------------------------------------------------------------

/// Exact integer 'valid' conv on levels -> i64 (the oracle).
pub fn golden_exact(wl: &Workload) -> Vec<i64> {
    let d = &wl.dims;
    let (ho, wo) = (d.ho() as usize, d.wo() as usize);
    let mut out = vec![0i64; d.co as usize * ho * wo];
    for o in 0..d.co as usize {
        for r in 0..ho {
            for q in 0..wo {
                let mut acc = 0i64;
                for c in 0..d.c as usize {
                    for ki in 0..d.fh as usize {
                        for i in 0..d.fw as usize {
                            let x = wl.act[c][(r + ki) * d.w as usize + q + i] as i64;
                            let w = wl.wgt[o][c][ki * d.fw as usize + i] as i64;
                            acc += x * w;
                        }
                    }
                }
                out[(o * ho + r) * wo + q] = acc;
            }
        }
    }
    out
}

/// The exact conv reduced mod 2^bits (what a SEW-wide wrapping
/// accumulator produces when the packed pipeline is exact).
pub fn golden_mod(wl: &Workload, bits: u32) -> Vec<i64> {
    let mask = if bits >= 64 { u64::MAX } else { (1u64 << bits) - 1 };
    golden_exact(wl).iter().map(|&v| (v as u64 & mask) as i64).collect()
}

/// Packed-arithmetic golden: what the vmacsr dataflow computes even
/// outside the overflow-free region (container-wrapping narrow
/// accumulator spilled every `spill_every` issues into a wide one).
/// Mirrors `ref.packed_conv2d_hw_ref`, with the kernel's loop order.
pub fn golden_packed_vmacsr(wl: &Workload, container: Container, spill_every: u64) -> Vec<i64> {
    let d = &wl.dims;
    let s = container.shift();
    let cmask = (1u64 << container.bits()) - 1;
    let xp = crate::ulppack::pack_activations(&wl.act, container);
    let wp = crate::ulppack::pack_weights(&wl.wgt, container);
    let (ho, wo) = (d.ho() as usize, d.wo() as usize);
    let cp = d.c as usize / 2;
    let mut out = vec![0i64; d.co as usize * ho * wo];
    for o in 0..d.co as usize {
        for r in 0..ho {
            for q in 0..wo {
                let mut wide = 0u64;
                let mut narrow = 0u64;
                let mut issues = 0u64;
                // kernel loop order: ki (input row), then c, then i
                for ki in 0..d.fh as usize {
                    for c in 0..cp {
                        for i in 0..d.fw as usize {
                            let x = xp[c][(r + ki) * d.w as usize + q + i];
                            let w = wp[o][c][ki * d.fw as usize + i];
                            let prod = x.wrapping_mul(w) & cmask;
                            narrow = (narrow + (prod >> s)) & cmask;
                            issues += 1;
                            if spill_every != u64::MAX && issues % spill_every == 0 {
                                wide += narrow;
                                narrow = 0;
                            }
                        }
                    }
                }
                out[(o * ho + r) * wo + q] = (wide + narrow) as i64;
            }
        }
    }
    out
}

/// fp32 golden with the *kernel's* summation order (ki, then c, then i)
/// so the comparison is exact, not approximate.
pub fn golden_fp32(wl: &Workload) -> Vec<f32> {
    let d = &wl.dims;
    let (ho, wo) = (d.ho() as usize, d.wo() as usize);
    let mut out = vec![0f32; d.co as usize * ho * wo];
    for o in 0..d.co as usize {
        for r in 0..ho {
            for q in 0..wo {
                let mut acc = 0f32;
                for ki in 0..d.fh as usize {
                    for c in 0..d.c as usize {
                        for i in 0..d.fw as usize {
                            let x = wl.act_f32[c][(r + ki) * d.w as usize + q + i];
                            let w = wl.wgt_f32[o][c][ki * d.fw as usize + i];
                            acc += x * w;
                        }
                    }
                }
                out[(o * ho + r) * wo + q] = acc;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ulppack::RegionMode;

    fn small() -> ConvDims {
        ConvDims { c: 4, h: 6, w: 6, co: 2, fh: 3, fw: 3 }
    }

    #[test]
    fn dims_math() {
        let d = small();
        assert_eq!(d.ho(), 4);
        assert_eq!(d.wo(), 4);
        assert_eq!(d.macs(), 2 * 4 * 4 * 4 * 3 * 3);
        assert_eq!(d.issues_per_output(), 2 * 9);
    }

    #[test]
    fn random_levels_in_range() {
        let wl = Workload::random(small(), 3, 2, 42);
        for row in &wl.act {
            assert!(row.iter().all(|&v| v <= 3));
        }
        for o in &wl.wgt {
            for c in o {
                assert!(c.iter().all(|&v| v <= 6));
            }
        }
    }

    #[test]
    fn packed_golden_equals_exact_inside_strict_region() {
        let wl = Workload::random(small(), 2, 2, 7);
        let plan = crate::ulppack::region::plan_vmacsr(
            2,
            2,
            wl.dims.issues_per_output(),
            RegionMode::Strict,
        )
        .unwrap();
        let packed = golden_packed_vmacsr(&wl, plan.container, plan.spill_every);
        assert_eq!(packed, golden_exact(&wl));
    }

    #[test]
    fn packed_golden_differs_outside_region_on_adversarial_data() {
        // all-max W4A4 data on LP overflows the dot field
        let mut wl = Workload::random(small(), 4, 4, 7);
        for row in wl.act.iter_mut() {
            row.iter_mut().for_each(|v| *v = 15);
        }
        for o in wl.wgt.iter_mut() {
            for c in o.iter_mut() {
                c.iter_mut().for_each(|v| *v = 14);
            }
        }
        let packed = golden_packed_vmacsr(&wl, Container::Lp, 100);
        assert_ne!(packed, golden_exact(&wl));
    }

    #[test]
    fn golden_mod_wraps() {
        let wl = Workload::random(small(), 4, 4, 9);
        let exact = golden_exact(&wl);
        let modded = golden_mod(&wl, 16);
        assert!(modded.iter().all(|&v| v < 65536));
        for (e, m) in exact.iter().zip(&modded) {
            assert_eq!(((*e as u64) & 0xFFFF) as i64, *m);
        }
    }
}
