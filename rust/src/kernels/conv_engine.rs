//! The output-stationary slide-based conv2d engine — Algorithm 1 of the
//! paper, generalised over the four inner-loop policies (int16 vmacc,
//! fp32 vfmacc, native ULPPACK vmacc+repair, `vmacsr`+spill).
//!
//! Loop nest (paper Algorithm 1, 0-indexed):
//!
//! ```text
//! for o in output channels:
//!   for strip in output-column strips:          # strip-mining to VLMAX
//!     clear the Fh rotating accumulators
//!     for h in input rows:
//!       for cc in (packed) channels:
//!         V_in <- load input row (cc, h) strip
//!         for i in 0..Fw:
//!           for j in 0..Fh:                     # slot j holds an output row
//!             acc[j] += op(V_in, W[o][cc][fh-1-j][i])
//!           V_in <- vslidedown(V_in, 1)
//!           (repair / spill cadence)
//!       if slot 0's output row is complete: finalize + store
//!       rotate slots, clear the recycled accumulator
//! ```
//!
//! Slot `j` at input row `h` accumulates output row `h - (Fh-1) + j`
//! with kernel row `ki = Fh-1-j`; slot 0 completes at every `h >= Fh-1`.
//!
//! Exactness: the drain cadences come from `ulppack::region`; because a
//! drained chunk never overflows its subfields, the wide total is
//! partition-independent and the kernel output equals the golden models
//! in `workload.rs` bit-for-bit (see the integration tests).
//!
//! ## Compile once, execute many
//!
//! Emission is split from data staging (DESIGN.md §"Compile once,
//! execute many"):
//!
//! * [`compile`] lays tensors out with the same bump allocator a fresh
//!   machine uses, bakes the resolved addresses and weights into the
//!   instruction stream, and returns a [`CompiledConv`] — no machine
//!   involved.
//! * [`bind`] re-creates that layout on a freshly reset [`Machine`] and
//!   writes the workload's *activation* tensors into it (weights live
//!   in the stream as `.vx` scalar operands).
//! * [`CompiledConv::execute`] = reset + bind + run: re-executing a
//!   cached program on rebound tensors is bit-identical (outputs and
//!   cycle counts) to a cold build, which the cache-correctness tests
//!   pin.  The run step walks the pre-compiled micro-op form's fused
//!   execution plan ([`crate::sim::CompiledProgram`], DESIGN.md §Perf):
//!   legality and alignment were checked at compile time, contiguous
//!   load/store/copy/fill runs execute as one sweep per run instead of
//!   per-instruction, and the cycle totals were precomputed when the
//!   plan was built.
//!
//! [`build`] is compile + bind on the caller's machine — the original
//! single-shot API the variant modules and their tests use.

use super::asm::{strips, Asm};
use super::pack_rt;
use super::workload::{ConvDims, OutElem, OutputRef, Workload};
use crate::arch::ProcessorConfig;
use crate::isa::{Lmul, ScalarKind, Sew, VOp, VType};
use crate::sim::{CompiledProgram, Machine, Program, RunReport, SimError};
use crate::ulppack::{self, Container};

/// Inner-loop policy: what one "MAC issue" is and how accumulators are
/// kept exact.
#[derive(Debug, Clone, Copy)]
pub enum Inner {
    /// vmacc on int16 levels (the paper's speedup baseline).
    Int16,
    /// vfmacc on f32 (Ara only).
    Fp32,
    /// Algorithm 1 proper: vmacsr on packed containers, wide-accumulator
    /// spills every `spill_every` issues (u64::MAX = never).
    Vmacsr { container: Container, spill_every: u64 },
    /// Native ULPPACK: vmacc on packed containers + the vsrl/vwaddu/vmv
    /// repair sequence every `k_local` issues.
    Native { container: Container, k_local: u64 },
}

impl Inner {
    pub fn sew(self) -> Sew {
        match self {
            Inner::Int16 => Sew::E16,
            Inner::Fp32 => Sew::E32,
            Inner::Vmacsr { container, .. } | Inner::Native { container, .. } => match container {
                Container::Lp => Sew::E16,
                Container::Ulp => Sew::E8,
            },
        }
    }

    fn packed(self) -> Option<Container> {
        match self {
            Inner::Vmacsr { container, .. } | Inner::Native { container, .. } => Some(container),
            _ => None,
        }
    }

    /// Drain cadence in issues (u64::MAX = never).
    fn cadence(self) -> u64 {
        match self {
            Inner::Vmacsr { spill_every, .. } => spill_every,
            Inner::Native { k_local, .. } => k_local,
            _ => u64::MAX,
        }
    }

    /// Does this policy keep a wide (2xSEW) accumulator per slot?
    /// ULP always does: its u8 accumulator must be widened for storage
    /// anyway, and its spill cadences are far below any real reduction.
    fn has_wide(self, total_issues: u64) -> bool {
        match self {
            Inner::Int16 | Inner::Fp32 => false,
            Inner::Vmacsr { container: Container::Ulp, .. } => true,
            Inner::Vmacsr { spill_every, .. } => spill_every < total_issues,
            Inner::Native { .. } => true,
        }
    }
}

/// Engine options beyond the inner policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EngineOpts {
    /// Pack weights at runtime (counted as scalar slots) — the paper's
    /// measurement includes this; `false` models offline preprocessing
    /// (the ablation).
    pub runtime_weight_pack: bool,
    /// Pack activations at runtime with vector code (always true in the
    /// paper; `false` is the offline-packing ablation).
    pub runtime_act_pack: bool,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts { runtime_weight_pack: true, runtime_act_pack: true }
    }
}

/// Register map for one build.
struct Regs {
    lmul: Lmul,
    /// acc[j] base register per slot (rotated by index).
    acc: Vec<u8>,
    /// wide accumulator base per slot (EEW = 2*SEW, 2 regs), if any.
    wide: Vec<u8>,
    /// input row register group.
    vin: u8,
    /// scratch for the native repair.
    tmp: Option<u8>,
}

fn alloc_regs(a: &Asm, fh: u32, avl: u64, sew: Sew, wide: bool, tmp: bool) -> Regs {
    assert!((1..=7).contains(&fh), "engine supports Fh in 1..=7 (paper uses 7x7)");
    if !wide && !tmp {
        // fh accumulators + input row, at the largest LMUL that fits
        let lmul = a.lmul_for(fh + 1, avl, sew);
        let l = lmul.factor();
        Regs {
            lmul,
            acc: (0..fh).map(|j| (j * l) as u8).collect(),
            wide: vec![],
            vin: (fh * l) as u8,
            tmp: None,
        }
    } else {
        // narrow accs at v0..fh-1, wide pairs at v8+2j (even-aligned for
        // the EEW=2*SEW group), input + scratch at the top; LMUL=1
        Regs {
            lmul: Lmul::M1,
            acc: (0..fh).map(|j| j as u8).collect(),
            wide: if wide { (0..fh).map(|j| (8 + 2 * j) as u8).collect() } else { vec![] },
            vin: 22,
            tmp: if tmp { Some(23) } else { None },
        }
    }
}

/// Mirror of the machine's bump allocator (`Mem::alloc` on a fresh
/// memory: brk starts at 64), so `compile` can resolve addresses
/// without a machine and `bind` can replay the identical sequence —
/// extended with tensor liveness for the multi-layer dataflow compiler
/// (`qnn::compiled`), which threads ONE of these through every layer's
/// `compile_in_arena` call so a whole network's tensors land in a
/// single planned activation arena.
///
/// Liveness: when the arena planner knows a tensor's last reader has
/// been planned (a conv's staged/packed activation scratch once its
/// stage is emitted), it [`Self::free`]s the range; later allocations
/// reuse freed ranges first-fit (lowest address first, align-aware,
/// with fragment splitting and neighbour coalescing) before growing
/// `brk`.  A compile that never frees — every standalone [`compile`] /
/// [`bind`] pair — degenerates to the exact bump sequence a fresh
/// machine performs, so straight-line layouts stay bit-identical to
/// the pre-liveness planner unless the caller opts in.  Timing is
/// address-independent (cycles depend on the instruction stream and
/// vl only), so address reuse can never change a program's cycles.
pub(crate) struct LayoutAlloc {
    brk: u64,
    /// Dead ranges available for reuse: (base, len), sorted by base,
    /// adjacent blocks coalesced.
    free: Vec<(u64, u64)>,
    /// `false` = the append-only planner (frees are ignored); used by
    /// the liveness regression tests as the comparison baseline.
    reuse: bool,
}

impl Default for LayoutAlloc {
    fn default() -> LayoutAlloc {
        LayoutAlloc::new()
    }
}

impl LayoutAlloc {
    pub(crate) fn new() -> LayoutAlloc {
        LayoutAlloc { brk: 64, free: Vec::new(), reuse: true }
    }

    /// An allocator that ignores [`Self::free`] — the pre-liveness
    /// append-only placement, kept as the regression baseline the
    /// liveness planner must never exceed.
    pub(crate) fn append_only() -> LayoutAlloc {
        LayoutAlloc { reuse: false, ..LayoutAlloc::new() }
    }

    pub(crate) fn alloc(&mut self, bytes: u64, align: u64) -> u64 {
        debug_assert!(align.is_power_of_two());
        // first-fit over the free list: reuse the lowest dead range an
        // aligned carve fits in
        for i in 0..self.free.len() {
            let (fb, fl) = self.free[i];
            let base = (fb + align - 1) & !(align - 1);
            if base + bytes <= fb + fl {
                self.free.remove(i);
                if base > fb {
                    self.insert_free(fb, base - fb);
                }
                let tail = (fb + fl) - (base + bytes);
                if tail > 0 {
                    self.insert_free(base + bytes, tail);
                }
                return base;
            }
        }
        let base = (self.brk + align - 1) & !(align - 1);
        self.brk = base + bytes;
        base
    }

    /// Mark a previously allocated range dead: its producer/consumer
    /// stages are fully planned and nothing later reads it.  Later
    /// allocations may reuse the range.
    pub(crate) fn free(&mut self, base: u64, bytes: u64) {
        if !self.reuse || bytes == 0 {
            return;
        }
        self.insert_free(base, bytes);
    }

    fn insert_free(&mut self, base: u64, len: u64) {
        let i = self.free.partition_point(|&(b, _)| b < base);
        self.free.insert(i, (base, len));
        // coalesce with the right then the left neighbour
        if i + 1 < self.free.len() && self.free[i].0 + self.free[i].1 == self.free[i + 1].0 {
            self.free[i].1 += self.free[i + 1].1;
            self.free.remove(i + 1);
        }
        if i > 0 && self.free[i - 1].0 + self.free[i - 1].1 == self.free[i].0 {
            self.free[i - 1].1 += self.free[i].1;
            self.free.remove(i);
        }
    }

    /// High-water mark: total arena bytes the layout ever needed
    /// (freed ranges stay inside it).
    pub(crate) fn brk(&self) -> u64 {
        self.brk
    }
}

/// Tensor placement a compiled program was laid out against.  The
/// addresses are baked into the instruction stream; [`bind`] re-creates
/// exactly this layout on a freshly reset machine.
#[derive(Debug, Clone, Copy)]
pub(crate) struct ConvLayout {
    /// Activation buffer: (address, byte size).
    x: (u64, u64),
    /// Packed-activation buffer (packed policies only): (address, byte
    /// size) — the size is recorded here so `bind` replays exactly the
    /// allocation `compile` made instead of re-deriving it.
    xp: Option<(u64, u64)>,
    /// Element bytes at the kernel SEW.
    ew: u64,
    /// Host-stage the packed activations at bind time (the
    /// offline-packing ablation, `!opts.runtime_act_pack`).
    stage_packed: Option<Container>,
    /// Activations are f32 (the fp32 baseline) rather than levels.
    fp32_acts: bool,
}

/// A conv2d program compiled once for a (dims, variant, processor,
/// opts, weights) tuple.  Weights are baked into the stream as resolved
/// `.vx` scalar operands; activations rebind per execution.  Obtain one
/// via [`compile`], [`crate::kernels::compile_conv`], or a
/// [`crate::kernels::ProgramCache`], then run it any number of times
/// with [`CompiledConv::execute`] on pooled machines.
#[derive(Debug)]
pub struct CompiledConv {
    pub prog: Program,
    /// §Perf: `prog` pre-compiled to micro-ops for `cfg` (legality and
    /// alignment checked once, SWAR/bulk strategies resolved) —
    /// [`CompiledConv::execute`] runs this form.  `None` when the
    /// stream is illegal for `cfg` (e.g. a vmacsr stream built for an
    /// Ara machine) — execution then falls back to the interpreting
    /// [`Machine::run`], which reports the error exactly as the seed
    /// path did — and on the one-shot [`build`] path, which runs the
    /// interpreter and would discard the lowering.
    pub compiled: Option<CompiledProgram>,
    pub out: OutputRef,
    pub dims: ConvDims,
    /// The processor the stream was compiled for (VLEN is baked into
    /// strip-mining and LMUL choices).
    pub cfg: ProcessorConfig,
    pub opts: EngineOpts,
    pub w_bits: u32,
    pub a_bits: u32,
    /// Simulated-DRAM bytes a machine needs for this program.
    pub mem_bytes: usize,
    pub(crate) layout: ConvLayout,
}

impl CompiledConv {
    /// The (address, bytes) of the unpacked activation buffer the
    /// stream loads from — the region an upstream requantize stage
    /// writes into when this conv is chained inside a
    /// [`crate::qnn::compiled::CompiledQnn`] arena.
    pub(crate) fn input_region(&self) -> (u64, u64) {
        self.layout.x
    }

    /// Element bytes of the unpacked activation buffer.
    pub(crate) fn input_elem_bytes(&self) -> u64 {
        self.layout.ew
    }

    /// Arena ranges that are dead once this conv's stage has run: the
    /// staged activation buffer (its producer wrote it, only this
    /// stage reads it) and the packed-activation scratch (written and
    /// read inside this stage).  The dataflow planner frees these; the
    /// output buffer stays live (it is the layer tap and a downstream
    /// boundary's source).
    pub(crate) fn scratch_regions(&self) -> Vec<(u64, u64)> {
        let mut v = vec![self.layout.x];
        if let Some(xp) = self.layout.xp {
            v.push(xp);
        }
        v
    }

    /// Execute the cached program: reset the machine in place, rebind
    /// `wl`'s activation tensors at the compiled layout, and run.
    ///
    /// Re-execution is bit-identical — outputs *and* `RunReport` cycle
    /// counts — to a cold [`build`] + run of the same workload (pinned
    /// by the `program_cache` integration tests).  `wl` must have the
    /// dims and precision the program was compiled for; its weights are
    /// ignored (they are baked into the stream).
    pub fn execute(&self, m: &mut Machine, wl: &Workload) -> Result<RunReport, SimError> {
        self.execute_impl(m, wl, true)
    }

    /// [`Self::execute`] for a machine known to be freshly constructed
    /// (or just reset): skips the redundant re-zeroing pass.  The
    /// one-shot `run_conv` path uses this right after `Machine::new`.
    pub(crate) fn execute_fresh(&self, m: &mut Machine, wl: &Workload) -> Result<RunReport, SimError> {
        self.execute_impl(m, wl, false)
    }

    fn execute_impl(
        &self,
        m: &mut Machine,
        wl: &Workload,
        reset: bool,
    ) -> Result<RunReport, SimError> {
        if m.cfg != self.cfg {
            return Err(SimError::Unsupported(
                "machine configuration differs from the compiled program's",
            ));
        }
        if wl.dims != self.dims || wl.w_bits != self.w_bits || wl.a_bits != self.a_bits {
            return Err(SimError::Unsupported(
                "workload shape/precision differs from the compiled program's",
            ));
        }
        if reset {
            m.reset_for(self.mem_bytes);
        }
        bind(m, wl, self)?;
        match &self.compiled {
            Some(cp) => m.run_compiled(cp),
            None => m.run(&self.prog),
        }
    }
}

/// Compile the conv program for `inner` over `wl` against `cfg`,
/// without touching a machine: resolve the tensor layout, bake weights
/// and addresses into the stream, and return the reusable program.
pub fn compile(
    cfg: &ProcessorConfig,
    wl: &Workload,
    inner: Inner,
    opts: EngineOpts,
    label: String,
) -> Result<CompiledConv, SimError> {
    compile_impl(cfg, wl, inner, opts, label, true, &mut LayoutAlloc::new(), None, None)
}

/// [`compile`] against a caller-held arena allocator: the layer's
/// tensors are appended to the shared arena instead of starting at the
/// bottom of a private address space.  This is how `qnn::compiled`
/// chains layers — each conv's activation buffer is the region the
/// previous layer's requantize stream writes into.  `bind` must NOT be
/// used with arena-compiled programs (the addresses do not replay from
/// a fresh allocator); the dataflow executor stages inputs directly.
pub(crate) fn compile_in_arena(
    cfg: &ProcessorConfig,
    wl: &Workload,
    inner: Inner,
    opts: EngineOpts,
    label: String,
    la: &mut LayoutAlloc,
) -> Result<CompiledConv, SimError> {
    compile_impl(cfg, wl, inner, opts, label, true, la, None, None)
}

/// [`compile_in_arena`] with the runtime *weight*-packing scalar pass
/// hoisted out of the stream: its slot count is added to `hoisted`
/// instead of being emitted.  The batched QNN compiler
/// (`qnn::compiled::CompiledQnn::compile_batched`) collects these into
/// one per-batch preamble stage — weights are static across a batch,
/// so packing them per image would bill the same scalar work B times.
/// Activation packing (per-image data) always stays in the stream.
pub(crate) fn compile_in_arena_hoisted(
    cfg: &ProcessorConfig,
    wl: &Workload,
    inner: Inner,
    opts: EngineOpts,
    label: String,
    la: &mut LayoutAlloc,
    hoisted: &mut u64,
) -> Result<CompiledConv, SimError> {
    compile_impl(cfg, wl, inner, opts, label, true, la, Some(hoisted), None)
}

/// [`compile_in_arena`] with the output buffer placed at a
/// caller-chosen arena address instead of freshly allocated.  The
/// depthwise lowering (`qnn::compiled`) compiles C per-channel
/// sub-convs and needs their outputs contiguous — it pre-allocates one
/// C x H x W block and places sub-conv `ch`'s output at
/// `block + ch*H*W*out_bytes`, so downstream stages (and the layer
/// tap) see a single dense tensor.  `hoisted` as in
/// [`compile_in_arena_hoisted`] (`None` = keep weight packing in the
/// stream).
#[allow(clippy::too_many_arguments)]
pub(crate) fn compile_in_arena_placed(
    cfg: &ProcessorConfig,
    wl: &Workload,
    inner: Inner,
    opts: EngineOpts,
    label: String,
    la: &mut LayoutAlloc,
    out_at: u64,
    hoisted: Option<&mut u64>,
) -> Result<CompiledConv, SimError> {
    compile_impl(cfg, wl, inner, opts, label, true, la, hoisted, Some(out_at))
}

#[allow(clippy::too_many_arguments)]
fn compile_impl(
    cfg: &ProcessorConfig,
    wl: &Workload,
    inner: Inner,
    opts: EngineOpts,
    label: String,
    with_uops: bool,
    la: &mut LayoutAlloc,
    hoist_pack: Option<&mut u64>,
    out_at: Option<u64>,
) -> Result<CompiledConv, SimError> {
    let d = wl.dims;
    let sew = inner.sew();
    let ew = sew.bytes() as u64;
    let (ho, wo) = (d.ho(), d.wo());
    let total_issues = match inner.packed() {
        Some(_) => d.issues_per_output(),
        None => (d.c * d.fh * d.fw) as u64,
    };
    let has_wide = inner.has_wide(total_issues);
    let needs_tmp = matches!(inner, Inner::Native { .. });

    // ---- guard: the wide accumulator itself must suffice ----
    if has_wide && sew.bits() < 32 {
        let dmax = ulppack::region::dot_max(wl.w_bits, wl.a_bits).max(1);
        let wide_cap = (1u64 << (2 * sew.bits())) - 1;
        if total_issues.saturating_mul(dmax) > wide_cap {
            return Err(SimError::Unsupported(
                "wide accumulator would overflow: reduce C or kernel size",
            ));
        }
    }

    // ---- lay tensors out in simulated DRAM (same bump sequence a
    //      fresh machine performs; data is written at bind time) ----
    let channels = match inner.packed() {
        Some(_) => d.c / 2,
        None => d.c,
    };
    let row_bytes = d.w as u64 * ew;
    let x_bytes = d.c as u64 * d.h as u64 * row_bytes;
    let x_addr = la.alloc(x_bytes, 64);
    // packed activations: written by the runtime packing pass, or staged
    // by the host at bind time for the offline-packing ablation
    let xp_bytes = channels as u64 * d.h as u64 * row_bytes;
    let xp = inner.packed().map(|_| (la.alloc(xp_bytes, 64), xp_bytes));
    let xp_base = xp.map(|(addr, _)| addr).unwrap_or(x_addr);

    // output buffer
    let out_elem = match inner {
        Inner::Fp32 => OutElem::F32,
        Inner::Int16 => OutElem::U16,
        Inner::Vmacsr { container, spill_every } => {
            vmacsr_out_elem(container, spill_every, total_issues)
        }
        Inner::Native { container, .. } => packed_out_elem(container, has_wide),
    };
    let out_bytes = match out_elem {
        OutElem::U16 => 2u64,
        OutElem::U32 | OutElem::F32 => 4,
    };
    let out_len = (d.co * ho * wo) as usize;
    let out_addr = match out_at {
        // caller-placed output (the depthwise contiguous block);
        // never combined with `bind`, which replays allocations
        Some(addr) => addr,
        None => la.alloc(out_len as u64 * out_bytes, 64),
    };

    // resolved weights for the .vx operands
    let wvals: Vec<Vec<Vec<u64>>> = match inner {
        Inner::Fp32 => wl
            .wgt_f32
            .iter()
            .map(|po| po.iter().map(|f| f.iter().map(|&v| v.to_bits() as u64).collect()).collect())
            .collect(),
        Inner::Int16 => wl.wgt.clone(),
        Inner::Vmacsr { container, .. } | Inner::Native { container, .. } => {
            ulppack::pack_weights(&wl.wgt, container)
        }
    };

    // ---- emit ----
    let mut a = Asm::new(label, cfg.vlen_bits);

    if inner.packed().is_some() {
        if opts.runtime_weight_pack {
            // scalar packing of weight containers: 2 loads + shift+or +
            // store per container, all in the scalar core.  Under a
            // batched compilation the caller hoists this per-model work
            // into a per-batch preamble instead of paying it per image.
            let slots = d.co * channels * d.fh * d.fw * 4;
            match hoist_pack {
                Some(h) => *h += slots as u64,
                None => a.scalar(ScalarKind::AddrCalc, slots),
            }
        }
        if opts.runtime_act_pack {
            pack_rt::emit_pack_activations(&mut a, &d, sew, x_addr, xp_base);
        }
    }

    let regs = alloc_regs(&a, d.fh, d.w as u64, sew, has_wide, needs_tmp);
    let wide_sew = sew.widened();
    let vlmax_cols = VType::new(sew, regs.lmul).vlmax(cfg.vlen_bits);
    let max_strip = vlmax_cols.saturating_sub(d.fw - 1).max(1);
    let cadence = inner.cadence();

    // helper: clear one wide pair under the EEW view (so every byte the
    // widening add will touch is zeroed), then return to the narrow view
    let clear_wide = |a: &mut Asm, reg: u8, svl: u64| {
        a.setvl(svl, wide_sew.unwrap(), Lmul::M2);
        a.vclear(reg);
    };

    for o in 0..d.co {
        for (s0, sw) in strips(wo, max_strip) {
            let svl_in = (sw + d.fw - 1) as u64;
            let mut slots: Vec<usize> = (0..d.fh as usize).collect();
            if has_wide {
                for j in 0..d.fh as usize {
                    clear_wide(&mut a, regs.wide[j], svl_in);
                }
            }
            a.setvl(svl_in, sew, regs.lmul);
            for j in 0..d.fh as usize {
                a.vclear(regs.acc[j]);
            }
            let mut issues_since: u64 = 0;

            for h in 0..d.h {
                for cc in 0..channels {
                    a.setvl(svl_in, sew, regs.lmul);
                    let row = xp_base + ((cc * d.h + h) as u64 * d.w as u64 + s0 as u64) * ew;
                    a.vle(sew, regs.vin, row);
                    for i in 0..d.fw {
                        for j in 0..d.fh as usize {
                            let ki = d.fh as usize - 1 - j;
                            let wv = wvals[o as usize][cc as usize][ki * d.fw as usize + i as usize];
                            match inner {
                                Inner::Fp32 => a.vfmacc_weight(
                                    regs.acc[slots[j]],
                                    regs.vin,
                                    f32::from_bits(wv as u32),
                                ),
                                Inner::Int16 | Inner::Native { .. } => {
                                    a.vmacc_weight(regs.acc[slots[j]], regs.vin, wv)
                                }
                                Inner::Vmacsr { .. } => {
                                    a.vmacsr_weight(regs.acc[slots[j]], regs.vin, wv)
                                }
                            }
                        }
                        if i < d.fw - 1 {
                            a.vi(VOp::SlideDown, regs.vin, regs.vin, 1);
                        }
                        // every slot received one issue this iteration
                        issues_since += 1;
                        if issues_since >= cadence {
                            issues_since = 0;
                            emit_drain_all(&mut a, inner, &regs, &slots);
                        }
                    }
                    a.loop_overhead();
                }

                // store the completed output row (slot 0)
                let r = h as i64 - (d.fh as i64 - 1);
                if r >= 0 && (r as u32) < ho {
                    let dst = out_addr
                        + ((o * ho + r as u32) as u64 * wo as u64 + s0 as u64) * out_bytes;
                    emit_store_row(&mut a, inner, &regs, slots[0], has_wide, sw, svl_in, dst);
                }
                // rotate: slot j takes over slot j+1's registers; the
                // recycled registers become the freshest accumulator
                slots.rotate_left(1);
                let fresh = slots[d.fh as usize - 1];
                if has_wide {
                    clear_wide(&mut a, regs.wide[fresh], svl_in);
                }
                a.setvl(svl_in, sew, regs.lmul);
                a.vclear(regs.acc[fresh]);
                a.loop_overhead();
            }
            a.loop_overhead();
        }
    }

    let out = OutputRef { addr: out_addr, elem: out_elem, len: out_len };
    let prog = a.finish(d.macs());
    let compiled = if with_uops { CompiledProgram::compile(&prog, cfg).ok() } else { None };
    Ok(CompiledConv {
        prog,
        compiled,
        out,
        dims: d,
        cfg: cfg.clone(),
        opts,
        w_bits: wl.w_bits,
        a_bits: wl.a_bits,
        mem_bytes: wl.mem_bytes(),
        layout: ConvLayout {
            x: (x_addr, x_bytes),
            xp,
            ew,
            stage_packed: if opts.runtime_act_pack { None } else { inner.packed() },
            fp32_acts: matches!(inner, Inner::Fp32),
        },
    })
}

/// Output element of a packed conv: the wide accumulator's width when
/// one is kept, u16 otherwise (LP with no spill needed).  `pub(crate)`
/// so the graph validator and autotuner derive boundary widths from
/// the same rule the engine stores with.
pub(crate) fn packed_out_elem(container: Container, has_wide: bool) -> OutElem {
    if has_wide {
        match container {
            Container::Lp => OutElem::U32,
            Container::Ulp => OutElem::U16,
        }
    } else {
        OutElem::U16
    }
}

/// The output element a `vmacsr` conv stores for this region plan —
/// the single source of truth shared by [`compile`] and the golden
/// network's element-capacity cap (`qnn::compiled`), so the boundary
/// requantization shift can never diverge between the two.
pub(crate) fn vmacsr_out_elem(
    container: Container,
    spill_every: u64,
    total_issues: u64,
) -> OutElem {
    let inner = Inner::Vmacsr { container, spill_every };
    packed_out_elem(container, inner.has_wide(total_issues))
}

/// Re-create the compiled layout on a freshly reset machine and write
/// the workload's activation tensors into it.  The machine's allocator
/// must be at its initial state (fresh `Machine::new` or
/// `Machine::reset*`) so the replayed allocations land on the addresses
/// baked into the program.
pub fn bind(m: &mut Machine, wl: &Workload, cc: &CompiledConv) -> Result<(), SimError> {
    const STALE: SimError =
        SimError::Unsupported("bind requires a freshly reset machine (layout address mismatch)");
    let d = cc.dims;
    let lay = &cc.layout;
    let ew = lay.ew;
    let row_bytes = d.w as u64 * ew;

    let x_addr = m.mem.alloc(lay.x.1, 64)?;
    if x_addr != lay.x.0 {
        return Err(STALE);
    }
    if lay.fp32_acts {
        for (c, row) in wl.act_f32.iter().enumerate() {
            m.mem.write_f32s(x_addr + c as u64 * d.h as u64 * row_bytes, row)?;
        }
    } else {
        for (c, row) in wl.act.iter().enumerate() {
            let base = x_addr + c as u64 * d.h as u64 * row_bytes;
            for (i, &v) in row.iter().enumerate() {
                m.mem.store_uint(base + i as u64 * ew, ew as u32, v)?;
            }
        }
    }

    if let Some((xp_expected, xp_bytes)) = lay.xp {
        let xp_addr = m.mem.alloc(xp_bytes, 64)?;
        if xp_addr != xp_expected {
            return Err(STALE);
        }
        if let Some(cont) = lay.stage_packed {
            let packed = ulppack::pack_activations(&wl.act, cont);
            for (c, row) in packed.iter().enumerate() {
                let base = xp_addr + c as u64 * d.h as u64 * row_bytes;
                for (i, &v) in row.iter().enumerate() {
                    m.mem.store_uint(base + i as u64 * ew, ew as u32, v)?;
                }
            }
        }
    }

    let out_bytes = match cc.out.elem {
        OutElem::U16 => 2u64,
        OutElem::U32 | OutElem::F32 => 4,
    };
    let out_addr = m.mem.alloc(cc.out.len as u64 * out_bytes, 64)?;
    if out_addr != cc.out.addr {
        return Err(STALE);
    }
    Ok(())
}

/// Build the conv program for `inner` over `wl` directly on the
/// caller's (fresh) machine — compile + bind; returns the trace and
/// where the output tensor will be.  The compile-once/execute-many path
/// is [`compile`] + [`CompiledConv::execute`].  This one-shot path
/// runs through `Machine::run`, so it skips the micro-op lowering pass
/// whose result it would immediately discard.
pub fn build(
    m: &mut Machine,
    wl: &Workload,
    inner: Inner,
    opts: EngineOpts,
    label: String,
) -> Result<(Program, OutputRef), SimError> {
    let cc =
        compile_impl(&m.cfg, wl, inner, opts, label, false, &mut LayoutAlloc::new(), None, None)?;
    bind(m, wl, &cc)?;
    Ok((cc.prog, cc.out))
}

/// Drain every slot's narrow accumulator into its wide one (the spill /
/// repair sequence).  Caller guarantees the current vtype is the narrow
/// (sew, lmul) view.
fn emit_drain_all(a: &mut Asm, inner: Inner, regs: &Regs, slots: &[usize]) {
    for &sl in slots {
        emit_drain_one(a, inner, regs, sl);
    }
}

fn emit_drain_one(a: &mut Asm, inner: Inner, regs: &Regs, sl: usize) {
    match inner {
        Inner::Native { .. } => {
            // t = local >> S ; wide += t ; local = 0
            let t = regs.tmp.expect("native repair needs the scratch register");
            let s = (inner.sew().bits() / 2) as i8;
            a.vi(VOp::Srl, t, regs.acc[sl], s);
            a.vv(VOp::WAdduWv, regs.wide[sl], t, 0);
            a.vclear(regs.acc[sl]);
        }
        Inner::Vmacsr { .. } => {
            // wide += acc ; acc = 0   (already shifted by the hardware)
            a.vv(VOp::WAdduWv, regs.wide[sl], regs.acc[sl], 0);
            a.vclear(regs.acc[sl]);
        }
        _ => unreachable!("only packed policies drain"),
    }
}

/// Finalize slot `sl` and store `sw` output columns at `dst`.
#[allow(clippy::too_many_arguments)]
fn emit_store_row(
    a: &mut Asm,
    inner: Inner,
    regs: &Regs,
    sl: usize,
    has_wide: bool,
    sw: u32,
    svl_in: u64,
    dst: u64,
) {
    let sew = inner.sew();
    if has_wide {
        // final drain of this slot, then store the wide accumulator
        a.setvl(svl_in, sew, regs.lmul);
        emit_drain_one(a, inner, regs, sl);
        let ws = sew.widened().unwrap();
        a.setvl(sw as u64, ws, Lmul::M2);
        a.vse(ws, regs.wide[sl], dst);
    } else {
        a.setvl(sw as u64, sew, regs.lmul);
        a.vse(sew, regs.acc[sl], dst);
    }
}

#[cfg(test)]
mod tests {
    use super::LayoutAlloc;

    #[test]
    fn layout_alloc_without_frees_is_the_machine_bump_sequence() {
        let mut la = LayoutAlloc::new();
        assert_eq!(la.alloc(100, 64), 64);
        assert_eq!(la.alloc(8, 64), 192); // 164 rounded up
        assert_eq!(la.alloc(4, 4), 200);
        assert_eq!(la.brk(), 204);
    }

    #[test]
    fn freed_ranges_are_reused_first_fit_without_growing_the_high_water() {
        let mut la = LayoutAlloc::new();
        let a = la.alloc(128, 64);
        let b = la.alloc(128, 64);
        let c = la.alloc(64, 64);
        let top = la.brk();
        la.free(a, 128);
        // fits in a's dead range: high water unchanged
        assert_eq!(la.alloc(64, 64), a);
        assert_eq!(la.brk(), top);
        // the tail fragment of a's range serves the next small alloc
        assert_eq!(la.alloc(64, 64), a + 64);
        assert_eq!(la.brk(), top);
        // nothing free is big enough now: fall back to the bump
        let d = la.alloc(256, 64);
        assert!(d >= top);
        let _ = (b, c);
    }

    #[test]
    fn adjacent_frees_coalesce() {
        let mut la = LayoutAlloc::new();
        let a = la.alloc(64, 64);
        let b = la.alloc(64, 64);
        let top = la.brk();
        la.free(a, 64);
        la.free(b, 64);
        // a 128-byte alloc only fits if the two 64-byte blocks merged
        assert_eq!(la.alloc(128, 64), a);
        assert_eq!(la.brk(), top);
    }

    #[test]
    fn append_only_mode_ignores_frees() {
        let mut la = LayoutAlloc::append_only();
        let a = la.alloc(64, 64);
        la.free(a, 64);
        assert!(la.alloc(64, 64) > a);
    }
}
