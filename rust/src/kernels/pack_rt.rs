//! Runtime activation packing — the vector pass that turns unpacked
//! levels into ULPPACK containers before the conv runs.  The paper
//! measures this cost ("execution time includes both activations and
//! weights packing done at runtime", §V-A); it is emitted into the same
//! program the conv executes so its cycles land in the total.
//!
//! Per channel pair and row strip:
//!
//! ```text
//! vle  v0, row[2c]        # low-half levels
//! vle  v8, row[2c+1]      # high-half levels
//! vsll.vi v8, v8, S
//! vor.vv  v0, v0, v8
//! vse  v0, packed[c]
//! ```

use super::asm::{strips, Asm};
use super::workload::ConvDims;
use crate::isa::{Sew, VOp, VType};

/// Emit the packing pass for all `C/2` channel pairs over the full
/// `H x W` input.  `sew` is the container width's element type; levels
/// are stored at container width (the quantizer's output layout).
pub fn emit_pack_activations(a: &mut Asm, d: &ConvDims, sew: Sew, x_addr: u64, xp_addr: u64) {
    let ew = sew.bytes() as u64;
    let shift = (sew.bits() / 2) as i8;
    let lmul = a.lmul_for(4, d.w as u64, sew); // v0 and v8 groups, <= m8
    let max_strip = VType::new(sew, lmul).vlmax(a.vlen_bits()).max(1);
    let row_elems = d.w;
    let plane = d.h as u64 * d.w as u64;

    for cp in 0..d.c / 2 {
        let src0 = x_addr + (2 * cp) as u64 * plane * ew;
        let src1 = x_addr + (2 * cp + 1) as u64 * plane * ew;
        let dst = xp_addr + cp as u64 * plane * ew;
        for h in 0..d.h {
            for (s0, swidth) in strips(row_elems, max_strip) {
                let off = (h as u64 * d.w as u64 + s0 as u64) * ew;
                a.setvl(swidth as u64, sew, lmul);
                a.vle(sew, 0, src0 + off);
                a.vle(sew, 8, src1 + off);
                a.vi(VOp::Sll, 8, 8, shift);
                a.vv(VOp::Or, 0, 0, 8);
                a.vse(sew, 0, dst + off);
            }
            a.loop_overhead();
        }
        a.loop_overhead();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ProcessorConfig;
    use crate::kernels::workload::{ConvDims, Workload};
    use crate::sim::Machine;
    use crate::ulppack::{pack_activations, Container};

    #[test]
    fn packing_pass_matches_host_reference() {
        let d = ConvDims { c: 6, h: 5, w: 9, co: 1, fh: 3, fw: 3 };
        let wl = Workload::random(d, 2, 2, 99);
        let mut m = Machine::new(ProcessorConfig::sparq(), 1 << 20);
        let ew = 2u64;
        let plane = (d.h * d.w) as u64;
        let x_addr = m.mem.alloc(d.c as u64 * plane * ew, 64).unwrap();
        let xp_addr = m.mem.alloc((d.c / 2) as u64 * plane * ew, 64).unwrap();
        for (c, row) in wl.act.iter().enumerate() {
            let vals: Vec<u16> = row.iter().map(|&v| v as u16).collect();
            m.mem.write_u16s(x_addr + c as u64 * plane * ew, &vals).unwrap();
        }
        let mut a = Asm::new("pack", m.cfg.vlen_bits);
        emit_pack_activations(&mut a, &d, Sew::E16, x_addr, xp_addr);
        let prog = a.finish(0);
        m.run(&prog).unwrap();

        let want = pack_activations(&wl.act, Container::Lp);
        for (cp, row) in want.iter().enumerate() {
            let got = m.mem.read_u16s(xp_addr + cp as u64 * plane * ew, row.len()).unwrap();
            let want16: Vec<u16> = row.iter().map(|&v| v as u16).collect();
            assert_eq!(got, want16, "channel pair {cp}");
        }
    }

    #[test]
    fn ulp_packing_at_u8() {
        let d = ConvDims { c: 4, h: 3, w: 600, co: 1, fh: 1, fw: 1 };
        let wl = Workload::random(d, 1, 1, 5);
        let mut m = Machine::new(ProcessorConfig::sparq(), 1 << 20);
        let plane = (d.h * d.w) as u64;
        let x_addr = m.mem.alloc(d.c as u64 * plane, 64).unwrap();
        let xp_addr = m.mem.alloc((d.c / 2) as u64 * plane, 64).unwrap();
        for (c, row) in wl.act.iter().enumerate() {
            let vals: Vec<u8> = row.iter().map(|&v| v as u8).collect();
            m.mem.write_u8s(x_addr + c as u64 * plane, &vals).unwrap();
        }
        let mut a = Asm::new("pack8", m.cfg.vlen_bits);
        emit_pack_activations(&mut a, &d, Sew::E8, x_addr, xp_addr);
        let prog = a.finish(0);
        m.run(&prog).unwrap();
        let want = pack_activations(&wl.act, Container::Ulp);
        for (cp, row) in want.iter().enumerate() {
            let got = m.mem.read_u8s(xp_addr + cp as u64 * plane, row.len()).unwrap();
            let want8: Vec<u8> = row.iter().map(|&v| v as u8).collect();
            assert_eq!(got, want8, "channel pair {cp}");
        }
    }
}
