//! Algorithm 1 of the paper: the ULPPACK conv2d accelerated with the
//! `vmacsr` multiply-shift-accumulate (runs on Sparq only).  The
//! container (LP 16-bit / ULP 8-bit) and the wide-accumulator spill
//! cadence come from the region calculus in [`crate::ulppack::region`].

use super::conv_engine::{self, EngineOpts};
use super::workload::{OutputRef, Workload};
use super::ConvVariant;
use crate::sim::{Machine, Program, SimError};
use crate::ulppack::region::RegionMode;

/// Build the vmacsr conv at (W, A) under `mode`.  Fails with
/// `Unsupported` when no container admits the precision pair.
pub fn build(
    m: &mut Machine,
    wl: &Workload,
    w_bits: u32,
    a_bits: u32,
    mode: RegionMode,
) -> Result<(Program, OutputRef), SimError> {
    build_opts(m, wl, w_bits, a_bits, mode, EngineOpts::default())
}

pub fn build_opts(
    m: &mut Machine,
    wl: &Workload,
    w_bits: u32,
    a_bits: u32,
    mode: RegionMode,
    opts: EngineOpts,
) -> Result<(Program, OutputRef), SimError> {
    let (inner, label) = ConvVariant::Vmacsr { w_bits, a_bits, mode }.planned_inner(wl)?;
    conv_engine::build(m, wl, inner, opts, label)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ProcessorConfig;
    use crate::kernels::workload::{golden_exact, golden_packed_vmacsr, ConvDims, Workload};
    use crate::testutil::Prop;
    use crate::ulppack::region::plan_vmacsr;

    fn run(wl: &Workload, w: u32, a: u32, mode: RegionMode) -> (Vec<i64>, crate::sim::RunReport) {
        let mut m = Machine::new(ProcessorConfig::sparq(), wl.mem_bytes());
        let (prog, out) = build(&mut m, wl, w, a, mode).unwrap();
        let rep = m.run(&prog).unwrap();
        (out.read_ints(&m.mem).unwrap(), rep)
    }

    #[test]
    fn w2a2_exact_on_ulp() {
        let d = ConvDims { c: 8, h: 9, w: 12, co: 2, fh: 3, fw: 3 };
        let wl = Workload::random(d, 2, 2, 77);
        let (got, _) = run(&wl, 2, 2, RegionMode::Strict);
        assert_eq!(got, golden_exact(&wl));
    }

    #[test]
    fn w3a3_exact_on_lp() {
        let d = ConvDims { c: 6, h: 8, w: 14, co: 2, fh: 3, fw: 3 };
        let wl = Workload::random(d, 3, 3, 5);
        let (got, _) = run(&wl, 3, 3, RegionMode::Strict);
        assert_eq!(got, golden_exact(&wl));
    }

    #[test]
    fn w4a4_paper_mode_matches_packed_golden() {
        // outside the strict region: must equal the packed-arithmetic
        // golden bit-for-bit (that is what the hardware computes)
        let d = ConvDims { c: 6, h: 8, w: 12, co: 2, fh: 3, fw: 3 };
        let wl = Workload::random(d, 4, 4, 13);
        let plan = plan_vmacsr(4, 4, d.issues_per_output(), RegionMode::Paper).unwrap();
        let (got, _) = run(&wl, 4, 4, RegionMode::Paper);
        assert_eq!(got, golden_packed_vmacsr(&wl, plan.container, plan.spill_every));
    }

    #[test]
    fn w4a4_strict_rejected() {
        let d = ConvDims { c: 4, h: 6, w: 8, co: 1, fh: 3, fw: 3 };
        let wl = Workload::random(d, 4, 4, 1);
        let mut m = Machine::new(ProcessorConfig::sparq(), wl.mem_bytes());
        assert!(build(&mut m, &wl, 4, 4, RegionMode::Strict).is_err());
    }

    #[test]
    fn traps_on_ara() {
        let d = ConvDims { c: 4, h: 6, w: 8, co: 1, fh: 3, fw: 3 };
        let wl = Workload::random(d, 2, 2, 1);
        let mut m = Machine::new(ProcessorConfig::ara(), wl.mem_bytes());
        let (prog, _) = build(&mut m, &wl, 2, 2, RegionMode::Strict).unwrap();
        assert_eq!(m.run(&prog).unwrap_err(), crate::sim::SimError::NoVmacsr);
    }

    #[test]
    fn property_strict_pairs_match_exact_golden() {
        Prop::new(0xACE).runs(10).check(|g| {
            let pairs = [(1u32, 1u32), (1, 2), (2, 1), (2, 2), (3, 3), (2, 3), (3, 2)];
            let (w, a) = *g.pick(&pairs);
            let fh = g.range(1, 3) as u32 * 2 - 1; // 1, 3, 5
            let d = ConvDims {
                c: 2 * g.range(1, 4) as u32,
                h: fh + g.range(2, 6) as u32,
                w: fh + g.range(2, 10) as u32,
                co: g.range(1, 2) as u32,
                fh,
                fw: fh,
            };
            let wl = Workload::random(d, w, a, g.next_u64());
            let (got, _) = run(&wl, w, a, RegionMode::Strict);
            assert_eq!(got, golden_exact(&wl), "W{w}A{a} {d:?}");
        });
    }

    #[test]
    fn faster_than_int16_on_same_workload() {
        let d = ConvDims { c: 16, h: 16, w: 70, co: 2, fh: 7, fw: 7 };
        let wl2 = Workload::random(d, 2, 2, 3);
        let (_, rep2) = run(&wl2, 2, 2, RegionMode::Paper);
        let wl16 = Workload::random(d, 8, 8, 3);
        let mut m = Machine::new(ProcessorConfig::sparq(), wl16.mem_bytes());
        let (prog, _) = crate::kernels::conv_int16::build(&mut m, &wl16).unwrap();
        let rep16 = m.run(&prog).unwrap();
        let speedup = rep2.speedup_over(&rep16);
        assert!(speedup > 1.5, "W2A2 speedup over int16 only {speedup:.2}x");
    }
}
