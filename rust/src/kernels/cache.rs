//! The compiled-program cache: memoizes [`CompiledConv`] behind a
//! content key so repeated workloads (serving, bench sweeps, layer
//! schedules) stop re-emitting identical instruction streams.
//!
//! The key holds everything that shapes the emitted stream *by exact
//! value*: the processor configuration (VLEN drives strip-mining and
//! LMUL selection), the conv dims, the variant (including region mode),
//! the engine options, the precision, and the flattened *weight
//! tensors* — weights are baked into the stream as resolved `.vx`
//! scalar operands, so two workloads sharing dims but not weights must
//! not share a program.  Nothing is compared by hash digest: a cache
//! hit can never serve a program compiled from different inputs.  The
//! weight words cost a few hundred KB per entry at most, dwarfed by
//! the cached instruction stream itself.  Activations are deliberately
//! *not* keyed: they rebind per execution (`CompiledConv::execute`).
//!
//! Sharing: the cache is `Sync`; the serving coordinator shares one
//! instance across workers via `Arc` while each worker keeps a private
//! machine pool (DESIGN.md §"Compile once, execute many").

use super::conv_engine::{CompiledConv, EngineOpts};
use super::workload::{ConvDims, Workload};
use super::ConvVariant;
use crate::arch::ProcessorConfig;
use crate::sim::SimError;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Cache counters (diagnostics).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: u64,
}

/// The cache key: every compile input compared exactly, weight words
/// included (see the module docs).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ConvKey {
    cfg: ProcessorConfig,
    dims: ConvDims,
    variant: ConvVariant,
    opts: EngineOpts,
    w_bits: u32,
    a_bits: u32,
    /// The flattened weight tensors, by value.
    wgt: Vec<u64>,
}

/// Flatten the weight tensors into the key's word list: integer levels
/// always, plus the f32 bit patterns for the fp32 baseline (whose
/// stream bakes `wgt_f32`).
fn weight_words(wl: &Workload, variant: ConvVariant) -> Vec<u64> {
    let mut words = Vec::new();
    for per_o in &wl.wgt {
        for per_c in per_o {
            words.extend_from_slice(per_c);
        }
    }
    if matches!(variant, ConvVariant::Fp32) {
        for per_o in &wl.wgt_f32 {
            for per_c in per_o {
                words.extend(per_c.iter().map(|v| v.to_bits() as u64));
            }
        }
    }
    words
}

/// A concurrent map from conv content keys to compiled programs.
#[derive(Debug, Default)]
pub struct ProgramCache {
    map: Mutex<HashMap<ConvKey, Arc<CompiledConv>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ProgramCache {
    pub fn new() -> ProgramCache {
        ProgramCache::default()
    }

    /// The content key `get_or_compile` uses (exposed for tests and
    /// diagnostics).
    pub fn key(
        cfg: &ProcessorConfig,
        wl: &Workload,
        variant: ConvVariant,
        opts: EngineOpts,
    ) -> ConvKey {
        ConvKey {
            cfg: cfg.clone(),
            dims: wl.dims,
            variant,
            opts,
            w_bits: wl.w_bits,
            a_bits: wl.a_bits,
            wgt: weight_words(wl, variant),
        }
    }

    /// Look up the compiled program for this (cfg, workload, variant,
    /// opts) tuple, compiling and inserting on a miss.  Compilation
    /// runs outside the lock; on a concurrent double-compile the first
    /// inserted entry wins and both callers get the same `Arc`.
    pub fn get_or_compile(
        &self,
        cfg: &ProcessorConfig,
        wl: &Workload,
        variant: ConvVariant,
        opts: EngineOpts,
    ) -> Result<Arc<CompiledConv>, SimError> {
        let key = Self::key(cfg, wl, variant, opts);
        if let Some(cc) = self.map.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(cc));
        }
        let compiled = Arc::new(super::compile_conv_opts(cfg, wl, variant, opts)?);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut map = self.map.lock().unwrap();
        let entry = map.entry(key).or_insert(compiled);
        Ok(Arc::clone(entry))
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.map.lock().unwrap().len() as u64,
        }
    }

    /// Drop every cached program (keeps the counters).
    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ulppack::RegionMode;

    fn wl(seed: u64) -> Workload {
        Workload::random(ConvDims { c: 4, h: 6, w: 8, co: 2, fh: 3, fw: 3 }, 2, 2, seed)
    }

    #[test]
    fn same_inputs_hit_different_inputs_miss() {
        let cache = ProgramCache::new();
        let cfg = ProcessorConfig::sparq();
        let v = ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Strict };
        let a = cache.get_or_compile(&cfg, &wl(1), v, EngineOpts::default()).unwrap();
        let b = cache.get_or_compile(&cfg, &wl(1), v, EngineOpts::default()).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "identical request must share the entry");
        // different weights (seed) must not share a program
        cache.get_or_compile(&cfg, &wl(2), v, EngineOpts::default()).unwrap();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 2, 2));
    }

    #[test]
    fn key_separates_cfg_variant_and_opts() {
        let w = wl(3);
        let v = ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Strict };
        let base = ProgramCache::key(&ProcessorConfig::sparq(), &w, v, EngineOpts::default());
        let lanes = ProgramCache::key(
            &ProcessorConfig::sparq().with_lanes(8),
            &w,
            v,
            EngineOpts::default(),
        );
        let mode = ProgramCache::key(
            &ProcessorConfig::sparq(),
            &w,
            ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Paper },
            EngineOpts::default(),
        );
        let opts = ProgramCache::key(
            &ProcessorConfig::sparq(),
            &w,
            v,
            EngineOpts { runtime_act_pack: false, runtime_weight_pack: false },
        );
        assert_ne!(base, lanes);
        assert_ne!(base, mode);
        assert_ne!(base, opts);
    }

    #[test]
    fn unsupported_variant_still_errors() {
        let cache = ProgramCache::new();
        let w = Workload::random(ConvDims { c: 4, h: 6, w: 8, co: 1, fh: 3, fw: 3 }, 4, 4, 1);
        let v = ConvVariant::Vmacsr { w_bits: 4, a_bits: 4, mode: RegionMode::Strict };
        assert!(cache
            .get_or_compile(&ProcessorConfig::sparq(), &w, v, EngineOpts::default())
            .is_err());
        assert_eq!(cache.stats().entries, 0);
    }

    #[test]
    fn clear_empties_entries() {
        let cache = ProgramCache::new();
        let cfg = ProcessorConfig::sparq();
        cache.get_or_compile(&cfg, &wl(1), ConvVariant::Int16, EngineOpts::default()).unwrap();
        assert_eq!(cache.stats().entries, 1);
        cache.clear();
        assert_eq!(cache.stats().entries, 0);
    }
}
