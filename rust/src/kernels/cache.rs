//! The compiled-program cache: memoizes [`CompiledConv`] behind a
//! content key so repeated workloads (serving, bench sweeps, layer
//! schedules) stop re-emitting identical instruction streams.
//!
//! The key holds everything that shapes the emitted stream *by exact
//! value*: the processor configuration (VLEN drives strip-mining and
//! LMUL selection), the conv dims, the variant (including region mode),
//! the engine options, the precision, and the flattened *weight
//! tensors* — weights are baked into the stream as resolved `.vx`
//! scalar operands, so two workloads sharing dims but not weights must
//! not share a program.  Nothing is compared by hash digest: a cache
//! hit can never serve a program compiled from different inputs.  (A
//! precomputed FNV-1a fingerprint cheapens the *lookup* — it is the
//! map hash and an equality pre-filter, never the verdict; see
//! [`ConvKey`].)  The
//! weight words cost a few hundred KB per entry at most, dwarfed by
//! the cached instruction stream itself.  Activations are deliberately
//! *not* keyed: they rebind per execution (`CompiledConv::execute`).
//!
//! Sharing: the cache is `Sync`; the serving coordinator shares one
//! instance across workers via `Arc` while each worker keeps a private
//! machine pool (DESIGN.md §"Compile once, execute many").

use super::autotune::TuneOutcome;
use super::conv_engine::{CompiledConv, EngineOpts};
use super::workload::{ConvDims, Workload};
use super::ConvVariant;
use crate::arch::ProcessorConfig;
use crate::qnn::compiled::{CompiledQnn, QnnNet};
use crate::qnn::graph::{LayerDesc, QnnGraph};
use crate::qnn::schedule::QnnPrecision;
use crate::sim::SimError;
use crate::ulppack::RegionMode;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Cache counters (diagnostics).  Program lookups (conv + graph maps)
/// and autotune lookups are counted separately: a network compile is
/// one program miss however many layer shapes it tunes along the way,
/// so the serving invariants ("second inference is all hits") stay
/// crisp.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: u64,
    /// Autotune ranking lookups served from the memo.
    pub tune_hits: u64,
    /// Autotune rankings measured (candidate probes executed).
    pub tune_misses: u64,
    pub tune_entries: u64,
}

/// The cache key: every compile input compared exactly, weight words
/// included (see the module docs).
///
/// `fp` is a hand-rolled FNV-1a fingerprint over all the fields below.
/// It is a *pre-filter only*: `Hash` is just this one word (so map
/// lookups stop re-hashing the flattened weight vector on every call)
/// and `PartialEq` checks it before the field-by-field compare (so
/// probes against non-matching entries short-circuit without touching
/// the weights).  Equality itself remains exact — a fingerprint match
/// never *admits* a hit on its own, preserving the "no hash-digest
/// shortcuts" contract above.
#[derive(Debug, Clone)]
pub struct ConvKey {
    fp: u64,
    cfg: ProcessorConfig,
    dims: ConvDims,
    variant: ConvVariant,
    opts: EngineOpts,
    w_bits: u32,
    a_bits: u32,
    /// The flattened weight tensors, by value.
    wgt: Vec<u64>,
}

impl PartialEq for ConvKey {
    fn eq(&self, o: &ConvKey) -> bool {
        // cheap fingerprint first; the exact compare still decides
        self.fp == o.fp
            && self.cfg == o.cfg
            && self.dims == o.dims
            && self.variant == o.variant
            && self.opts == o.opts
            && self.w_bits == o.w_bits
            && self.a_bits == o.a_bits
            && self.wgt == o.wgt
    }
}

impl Eq for ConvKey {}

impl Hash for ConvKey {
    fn hash<H: Hasher>(&self, h: &mut H) {
        // equal keys have equal fields, hence equal fingerprints — the
        // Hash/Eq contract holds with only the fingerprint hashed
        self.fp.hash(h);
    }
}

/// Hand-rolled 64-bit FNV-1a (the crate is dependency-free).
struct Fnv1a(u64);

impl Fnv1a {
    fn new() -> Fnv1a {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }

    #[inline]
    fn bytes(&mut self, bs: &[u8]) {
        for &b in bs {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
        }
    }

    #[inline]
    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }

    #[inline]
    fn u32(&mut self, v: u32) {
        self.bytes(&v.to_le_bytes());
    }
}

/// Fold every stream-shaping `ProcessorConfig` field into a
/// fingerprint (shared by the conv and graph-level keys).
fn fp_cfg(f: &mut Fnv1a, cfg: &ProcessorConfig) {
    f.bytes(cfg.name.as_bytes());
    f.u32(cfg.name.len() as u32); // length-delimit the only string field
    for v in [
        cfg.lanes,
        cfg.vlen_bits,
        cfg.datapath_bits,
        cfg.fpu as u32,
        cfg.vmacsr as u32,
        cfg.configurable_shifter as u32,
        cfg.mem_bytes_per_cycle,
        cfg.issue_latency,
        cfg.mem_latency,
        cfg.issue_bubble,
    ] {
        f.u32(v);
    }
}

fn fingerprint(
    cfg: &ProcessorConfig,
    dims: ConvDims,
    variant: ConvVariant,
    opts: EngineOpts,
    w_bits: u32,
    a_bits: u32,
    wgt: &[u64],
) -> u64 {
    let mut f = Fnv1a::new();
    fp_cfg(&mut f, cfg);
    for v in [dims.c, dims.h, dims.w, dims.co, dims.fh, dims.fw] {
        f.u32(v);
    }
    match variant {
        ConvVariant::Int16 => f.u32(0),
        ConvVariant::Fp32 => f.u32(1),
        ConvVariant::Native { w_bits, a_bits } => {
            f.u32(2);
            f.u32(w_bits);
            f.u32(a_bits);
        }
        ConvVariant::Vmacsr { w_bits, a_bits, mode } => {
            f.u32(3);
            f.u32(w_bits);
            f.u32(a_bits);
            f.u32(match mode {
                RegionMode::Strict => 0,
                RegionMode::Paper => 1,
            });
        }
    }
    f.u32(opts.runtime_weight_pack as u32);
    f.u32(opts.runtime_act_pack as u32);
    f.u32(w_bits);
    f.u32(a_bits);
    for &w in wgt {
        f.u64(w);
    }
    f.0
}

/// Flatten the weight tensors into the key's word list: integer levels
/// always, plus the f32 bit patterns for the fp32 baseline (whose
/// stream bakes `wgt_f32`).
fn weight_words(wl: &Workload, variant: ConvVariant) -> Vec<u64> {
    let mut words = Vec::new();
    for per_o in &wl.wgt {
        for per_c in per_o {
            words.extend_from_slice(per_c);
        }
    }
    if matches!(variant, ConvVariant::Fp32) {
        for per_o in &wl.wgt_f32 {
            for per_c in per_o {
                words.extend(per_c.iter().map(|v| v.to_bits() as u64));
            }
        }
    }
    words
}

/// The graph-level key for whole-network entries: the processor, every
/// layer descriptor by value, the DAG edges (`preds` — two graphs with
/// the same layer multiset but different wiring are different
/// programs), the precision, the weight seed (the network's weights
/// derive deterministically from it), and the batch layout.  `batch` is 0 for the unbatched legacy layout and B >= 1
/// for a [`CompiledQnn::compile_batched`] arena — the two emit
/// different streams (the batched layout hoists the weight-pack pass
/// into a preamble), so they must never alias.  Same discipline as
/// [`ConvKey`]: the fingerprint is the map hash and an equality
/// pre-filter; the exact field compare decides.
#[derive(Debug, Clone)]
pub struct QnnKey {
    fp: u64,
    cfg: ProcessorConfig,
    layers: Vec<LayerDesc>,
    preds: Vec<Vec<usize>>,
    input: (u32, u32, u32),
    classes: u32,
    precision: QnnPrecision,
    seed: u64,
    /// 0 = unbatched layout; B >= 1 = batched arena with B slots.
    batch: u32,
}

impl PartialEq for QnnKey {
    fn eq(&self, o: &QnnKey) -> bool {
        self.fp == o.fp
            && self.cfg == o.cfg
            && self.layers == o.layers
            && self.preds == o.preds
            && self.input == o.input
            && self.classes == o.classes
            && self.precision == o.precision
            && self.seed == o.seed
            && self.batch == o.batch
    }
}

impl Eq for QnnKey {}

impl Hash for QnnKey {
    fn hash<H: Hasher>(&self, h: &mut H) {
        self.fp.hash(h);
    }
}

fn qnn_fingerprint(
    cfg: &ProcessorConfig,
    graph: &QnnGraph,
    precision: QnnPrecision,
    seed: u64,
    batch: u32,
) -> u64 {
    let mut f = Fnv1a::new();
    fp_cfg(&mut f, cfg);
    for layer in &graph.layers {
        match *layer {
            LayerDesc::Conv { c_in, c_out, h, w, f: k, quantized, precision } => {
                f.u32(0);
                for v in [c_in, c_out, h, w, k, quantized as u32] {
                    f.u32(v);
                }
                // the per-layer (W, A) override: two otherwise-identical
                // graphs differing in one layer's precision must
                // fingerprint (and key) apart
                match precision {
                    None => f.u32(0),
                    Some((pw, pa)) => {
                        f.u32(1);
                        f.u32(pw);
                        f.u32(pa);
                    }
                }
            }
            LayerDesc::MaxPool { c, h, w } => {
                f.u32(1);
                for v in [c, h, w] {
                    f.u32(v);
                }
            }
            LayerDesc::GapFc { c, classes } => {
                f.u32(2);
                f.u32(c);
                f.u32(classes);
            }
            LayerDesc::Add { c, h, w } => {
                f.u32(3);
                for v in [c, h, w] {
                    f.u32(v);
                }
            }
            LayerDesc::DepthwiseConv { c, h, w, f: k, precision } => {
                f.u32(4);
                for v in [c, h, w, k] {
                    f.u32(v);
                }
                match precision {
                    None => f.u32(0),
                    Some((pw, pa)) => {
                        f.u32(1);
                        f.u32(pw);
                        f.u32(pa);
                    }
                }
            }
            LayerDesc::Dense { c_in, h, w, c_out, precision } => {
                f.u32(5);
                for v in [c_in, h, w, c_out] {
                    f.u32(v);
                }
                match precision {
                    None => f.u32(0),
                    Some((pw, pa)) => {
                        f.u32(1);
                        f.u32(pw);
                        f.u32(pa);
                    }
                }
            }
        }
    }
    // the DAG wiring: length-delimited edge lists per node, so two
    // graphs sharing a layer multiset but not their edges never alias
    for ps in &graph.preds {
        f.u32(ps.len() as u32);
        for &p in ps {
            f.u32(p as u32);
        }
    }
    f.u32(graph.input.0);
    f.u32(graph.input.1);
    f.u32(graph.input.2);
    f.u32(graph.classes);
    match precision {
        QnnPrecision::Fp32 => f.u32(0),
        QnnPrecision::SubByte { w_bits, a_bits } => {
            f.u32(1);
            f.u32(w_bits);
            f.u32(a_bits);
        }
    }
    f.u64(seed);
    f.u32(batch);
    f.0
}

/// The autotune memo key: processor, layer shape, resolved precision,
/// stem/quantized flag, engine options — everything that shapes the
/// candidate set and their measured cycles, and nothing more (weights
/// are excluded: timing is data-independent, so one ranking serves
/// every network over the tuple).  Same discipline as [`ConvKey`]: the
/// fingerprint is the map hash and an equality pre-filter; the exact
/// field compare decides.
#[derive(Debug, Clone)]
pub struct TuneKey {
    fp: u64,
    cfg: ProcessorConfig,
    dims: ConvDims,
    w_bits: u32,
    a_bits: u32,
    quantized: bool,
    opts: EngineOpts,
}

impl TuneKey {
    /// Forge the fingerprint (tests only): a collision must never
    /// admit a hit — equality stays exact over every field.
    #[cfg(test)]
    pub(crate) fn with_forged_fp(mut self, fp: u64) -> TuneKey {
        self.fp = fp;
        self
    }
}

impl PartialEq for TuneKey {
    fn eq(&self, o: &TuneKey) -> bool {
        self.fp == o.fp
            && self.cfg == o.cfg
            && self.dims == o.dims
            && self.w_bits == o.w_bits
            && self.a_bits == o.a_bits
            && self.quantized == o.quantized
            && self.opts == o.opts
    }
}

impl Eq for TuneKey {}

impl Hash for TuneKey {
    fn hash<H: Hasher>(&self, h: &mut H) {
        self.fp.hash(h);
    }
}

fn tune_fingerprint(
    cfg: &ProcessorConfig,
    dims: ConvDims,
    w_bits: u32,
    a_bits: u32,
    quantized: bool,
    opts: EngineOpts,
) -> u64 {
    let mut f = Fnv1a::new();
    fp_cfg(&mut f, cfg);
    for v in [dims.c, dims.h, dims.w, dims.co, dims.fh, dims.fw] {
        f.u32(v);
    }
    f.u32(w_bits);
    f.u32(a_bits);
    f.u32(quantized as u32);
    f.u32(opts.runtime_weight_pack as u32);
    f.u32(opts.runtime_act_pack as u32);
    f.0
}

/// A concurrent map from conv content keys to compiled programs, plus
/// a second map from graph-level keys to whole compiled networks
/// ([`CompiledQnn`]) and a third from [`TuneKey`]s to autotune
/// rankings — the dataflow executor's compile-once cache.
#[derive(Debug, Default)]
pub struct ProgramCache {
    map: Mutex<HashMap<ConvKey, Arc<CompiledConv>>>,
    qnn_map: Mutex<HashMap<QnnKey, Arc<CompiledQnn>>>,
    tune_map: Mutex<HashMap<TuneKey, Arc<TuneOutcome>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    tune_hits: AtomicU64,
    tune_misses: AtomicU64,
}

impl ProgramCache {
    pub fn new() -> ProgramCache {
        ProgramCache::default()
    }

    /// The content key `get_or_compile` uses (exposed for tests and
    /// diagnostics).
    pub fn key(
        cfg: &ProcessorConfig,
        wl: &Workload,
        variant: ConvVariant,
        opts: EngineOpts,
    ) -> ConvKey {
        let wgt = weight_words(wl, variant);
        ConvKey {
            fp: fingerprint(cfg, wl.dims, variant, opts, wl.w_bits, wl.a_bits, &wgt),
            cfg: cfg.clone(),
            dims: wl.dims,
            variant,
            opts,
            w_bits: wl.w_bits,
            a_bits: wl.a_bits,
            wgt,
        }
    }

    /// Look up the compiled program for this (cfg, workload, variant,
    /// opts) tuple, compiling and inserting on a miss.  Compilation
    /// runs outside the lock; on a concurrent double-compile the first
    /// inserted entry wins and both callers get the same `Arc`.
    pub fn get_or_compile(
        &self,
        cfg: &ProcessorConfig,
        wl: &Workload,
        variant: ConvVariant,
        opts: EngineOpts,
    ) -> Result<Arc<CompiledConv>, SimError> {
        let key = Self::key(cfg, wl, variant, opts);
        if let Some(cc) = self.map.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(cc));
        }
        let compiled = Arc::new(super::compile_conv_opts(cfg, wl, variant, opts)?);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut map = self.map.lock().unwrap();
        let entry = map.entry(key).or_insert(compiled);
        Ok(Arc::clone(entry))
    }

    /// The graph-level key `get_or_compile_qnn` uses (unbatched
    /// layout, `batch = 0`).
    pub fn qnn_key(
        cfg: &ProcessorConfig,
        graph: &QnnGraph,
        precision: QnnPrecision,
        seed: u64,
    ) -> QnnKey {
        Self::qnn_key_batched(cfg, graph, precision, seed, 0)
    }

    /// The graph-level key with an explicit batch layout (`batch = 0`
    /// is the unbatched layout; `B >= 1` a batched arena).
    pub fn qnn_key_batched(
        cfg: &ProcessorConfig,
        graph: &QnnGraph,
        precision: QnnPrecision,
        seed: u64,
        batch: u32,
    ) -> QnnKey {
        QnnKey {
            fp: qnn_fingerprint(cfg, graph, precision, seed, batch),
            cfg: cfg.clone(),
            layers: graph.layers.clone(),
            preds: graph.preds.clone(),
            input: graph.input,
            classes: graph.classes,
            precision,
            seed,
            batch,
        }
    }

    /// Look up the whole compiled network for (cfg, graph, precision,
    /// seed), compiling it once on a miss — graph validation, weight
    /// derivation, per-layer autotuning (memoized under [`TuneKey`]s in
    /// this same cache), arena planning and every layer stream
    /// included.  Counted in the same hit/miss stats as the conv
    /// entries (tune lookups count separately).
    pub fn get_or_compile_qnn(
        &self,
        cfg: &ProcessorConfig,
        graph: &QnnGraph,
        precision: QnnPrecision,
        seed: u64,
    ) -> Result<Arc<CompiledQnn>, SimError> {
        let key = Self::qnn_key(cfg, graph, precision, seed);
        self.qnn_entry(key, || {
            let net = QnnNet::from_seed(graph, precision, seed)?;
            CompiledQnn::compile_tuned(cfg, net, self)
        })
    }

    /// [`Self::get_or_compile_qnn`] for the batch-`batch` arena layout
    /// ([`CompiledQnn::compile_batched`]): one cached program whose
    /// machine holds `batch` per-image activation slots.  Keyed apart
    /// from the unbatched entries — the layouts emit different streams.
    pub fn get_or_compile_qnn_batched(
        &self,
        cfg: &ProcessorConfig,
        graph: &QnnGraph,
        precision: QnnPrecision,
        seed: u64,
        batch: u32,
    ) -> Result<Arc<CompiledQnn>, SimError> {
        // validate BEFORE keying: batch = 0 is the legacy-layout
        // sentinel in QnnKey, so an unvalidated 0 would alias the
        // unbatched entry on a warm cache instead of erroring
        if batch == 0 || batch > crate::qnn::compiled::MAX_BATCH {
            return Err(SimError::Unsupported(
                "batch size must be between 1 and MAX_BATCH (64)",
            ));
        }
        let key = Self::qnn_key_batched(cfg, graph, precision, seed, batch);
        self.qnn_entry(key, || {
            let net = QnnNet::from_seed(graph, precision, seed)?;
            CompiledQnn::compile_batched(cfg, net, self, batch)
        })
    }

    fn qnn_entry(
        &self,
        key: QnnKey,
        compile: impl FnOnce() -> Result<CompiledQnn, SimError>,
    ) -> Result<Arc<CompiledQnn>, SimError> {
        if let Some(cq) = self.qnn_map.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(cq));
        }
        let compiled = Arc::new(compile()?);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut map = self.qnn_map.lock().unwrap();
        let entry = map.entry(key).or_insert(compiled);
        Ok(Arc::clone(entry))
    }

    /// The autotune memo key `get_or_tune` uses (exposed for tests and
    /// diagnostics).
    pub fn tune_key(
        cfg: &ProcessorConfig,
        dims: ConvDims,
        w_bits: u32,
        a_bits: u32,
        quantized: bool,
        opts: EngineOpts,
    ) -> TuneKey {
        TuneKey {
            fp: tune_fingerprint(cfg, dims, w_bits, a_bits, quantized, opts),
            cfg: cfg.clone(),
            dims,
            w_bits,
            a_bits,
            quantized,
            opts,
        }
    }

    /// Look up an autotune ranking, measuring with `compute` on a
    /// miss.  Measurement runs outside the lock; on a concurrent
    /// double-measure the first inserted ranking wins and both callers
    /// get the same `Arc` (the probes are deterministic, so the two
    /// rankings are identical anyway).
    pub fn get_or_tune(
        &self,
        key: TuneKey,
        compute: impl FnOnce() -> Result<TuneOutcome, SimError>,
    ) -> Result<Arc<TuneOutcome>, SimError> {
        if let Some(t) = self.tune_map.lock().unwrap().get(&key) {
            self.tune_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(t));
        }
        let outcome = Arc::new(compute()?);
        self.tune_misses.fetch_add(1, Ordering::Relaxed);
        let mut map = self.tune_map.lock().unwrap();
        let entry = map.entry(key).or_insert(outcome);
        Ok(Arc::clone(entry))
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.map.lock().unwrap().len() as u64
                + self.qnn_map.lock().unwrap().len() as u64,
            tune_hits: self.tune_hits.load(Ordering::Relaxed),
            tune_misses: self.tune_misses.load(Ordering::Relaxed),
            tune_entries: self.tune_map.lock().unwrap().len() as u64,
        }
    }

    /// Drop every cached program and tuning (keeps the counters).
    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
        self.qnn_map.lock().unwrap().clear();
        self.tune_map.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ulppack::RegionMode;

    fn wl(seed: u64) -> Workload {
        Workload::random(ConvDims { c: 4, h: 6, w: 8, co: 2, fh: 3, fw: 3 }, 2, 2, seed)
    }

    #[test]
    fn same_inputs_hit_different_inputs_miss() {
        let cache = ProgramCache::new();
        let cfg = ProcessorConfig::sparq();
        let v = ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Strict };
        let a = cache.get_or_compile(&cfg, &wl(1), v, EngineOpts::default()).unwrap();
        let b = cache.get_or_compile(&cfg, &wl(1), v, EngineOpts::default()).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "identical request must share the entry");
        // different weights (seed) must not share a program
        cache.get_or_compile(&cfg, &wl(2), v, EngineOpts::default()).unwrap();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 2, 2));
    }

    #[test]
    fn key_separates_cfg_variant_and_opts() {
        let w = wl(3);
        let v = ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Strict };
        let base = ProgramCache::key(&ProcessorConfig::sparq(), &w, v, EngineOpts::default());
        let lanes = ProgramCache::key(
            &ProcessorConfig::sparq().with_lanes(8),
            &w,
            v,
            EngineOpts::default(),
        );
        let mode = ProgramCache::key(
            &ProcessorConfig::sparq(),
            &w,
            ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Paper },
            EngineOpts::default(),
        );
        let opts = ProgramCache::key(
            &ProcessorConfig::sparq(),
            &w,
            v,
            EngineOpts { runtime_act_pack: false, runtime_weight_pack: false },
        );
        assert_ne!(base, lanes);
        assert_ne!(base, mode);
        assert_ne!(base, opts);
    }

    #[test]
    fn unsupported_variant_still_errors() {
        let cache = ProgramCache::new();
        let w = Workload::random(ConvDims { c: 4, h: 6, w: 8, co: 1, fh: 3, fw: 3 }, 4, 4, 1);
        let v = ConvVariant::Vmacsr { w_bits: 4, a_bits: 4, mode: RegionMode::Strict };
        assert!(cache
            .get_or_compile(&ProcessorConfig::sparq(), &w, v, EngineOpts::default())
            .is_err());
        assert_eq!(cache.stats().entries, 0);
    }

    #[test]
    fn fingerprint_is_a_prefilter_not_the_verdict() {
        let cfg = ProcessorConfig::sparq();
        let v = ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Strict };
        let a = ProgramCache::key(&cfg, &wl(1), v, EngineOpts::default());
        let b = ProgramCache::key(&cfg, &wl(1), v, EngineOpts::default());
        assert_eq!(a.fp, b.fp, "equal inputs must fingerprint equal (Hash/Eq contract)");
        assert_eq!(a, b);
        let c = ProgramCache::key(&cfg, &wl(2), v, EngineOpts::default());
        assert_ne!(a, c);
        // even a forged fingerprint collision must NOT admit a hit:
        // equality stays exact over the weight words
        let mut forged = c.clone();
        forged.fp = a.fp;
        assert_ne!(a, forged, "a fingerprint collision must not alias different weights");
    }

    #[test]
    fn qnn_entries_share_and_key_exactly() {
        let cache = ProgramCache::new();
        let cfg = ProcessorConfig::sparq();
        let g = QnnGraph::sparq_cnn();
        let p = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
        let a = cache.get_or_compile_qnn(&cfg, &g, p, 7).unwrap();
        let b = cache.get_or_compile_qnn(&cfg, &g, p, 7).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "identical network request must share the entry");
        // a different weight seed is a different network
        cache.get_or_compile_qnn(&cfg, &g, p, 8).unwrap();
        // and a different precision too
        cache
            .get_or_compile_qnn(&cfg, &g, QnnPrecision::SubByte { w_bits: 4, a_bits: 4 }, 7)
            .unwrap();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 3, 3));
        let k1 = ProgramCache::qnn_key(&cfg, &g, p, 7);
        let k2 = ProgramCache::qnn_key(&cfg, &g, p, 8);
        assert_ne!(k1, k2);
        cache.clear();
        assert_eq!(cache.stats().entries, 0);
    }

    #[test]
    fn clear_empties_entries() {
        let cache = ProgramCache::new();
        let cfg = ProcessorConfig::sparq();
        cache.get_or_compile(&cfg, &wl(1), ConvVariant::Int16, EngineOpts::default()).unwrap();
        assert_eq!(cache.stats().entries, 1);
        cache.clear();
        assert_eq!(cache.stats().entries, 0);
    }

    #[test]
    fn tune_key_separates_cfg_precision_and_opts() {
        let d = ConvDims { c: 4, h: 6, w: 8, co: 2, fh: 3, fw: 3 };
        let base = ProgramCache::tune_key(&ProcessorConfig::sparq(), d, 2, 2, true, EngineOpts::default());
        let cfg = ProgramCache::tune_key(&ProcessorConfig::ara(), d, 2, 2, true, EngineOpts::default());
        let prec = ProgramCache::tune_key(&ProcessorConfig::sparq(), d, 4, 4, true, EngineOpts::default());
        let stem = ProgramCache::tune_key(&ProcessorConfig::sparq(), d, 2, 2, false, EngineOpts::default());
        let opts = ProgramCache::tune_key(
            &ProcessorConfig::sparq(),
            d,
            2,
            2,
            true,
            EngineOpts { runtime_act_pack: false, runtime_weight_pack: false },
        );
        assert_ne!(base, cfg);
        assert_ne!(base, prec);
        assert_ne!(base, stem);
        assert_ne!(base, opts);
        let same = ProgramCache::tune_key(&ProcessorConfig::sparq(), d, 2, 2, true, EngineOpts::default());
        assert_eq!(base, same);
        assert_eq!(base.fp, same.fp, "equal inputs must fingerprint equal (Hash/Eq contract)");
    }

    #[test]
    fn tune_fingerprint_is_a_prefilter_not_the_verdict() {
        // a forged fingerprint collision must NOT alias two precisions
        let d = ConvDims { c: 4, h: 6, w: 8, co: 2, fh: 3, fw: 3 };
        let a = ProgramCache::tune_key(&ProcessorConfig::sparq(), d, 2, 2, true, EngineOpts::default());
        let b = ProgramCache::tune_key(&ProcessorConfig::sparq(), d, 3, 3, true, EngineOpts::default());
        let forged = b.clone().with_forged_fp(a.fp);
        assert_ne!(a, forged, "a fingerprint collision must not alias different precisions");
    }

    #[test]
    fn qnn_key_separates_batch_layouts() {
        // the unbatched layout (batch = 0 sentinel), a batch-1 arena
        // and a batch-8 arena are three distinct programs — the batched
        // layouts hoist the weight-pack pass, so aliasing them would
        // serve wrong cycle counts
        let cfg = ProcessorConfig::sparq();
        let g = QnnGraph::sparq_cnn();
        let p = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
        let legacy = ProgramCache::qnn_key(&cfg, &g, p, 7);
        let b1 = ProgramCache::qnn_key_batched(&cfg, &g, p, 7, 1);
        let b8 = ProgramCache::qnn_key_batched(&cfg, &g, p, 7, 8);
        assert_ne!(legacy, b1);
        assert_ne!(b1, b8);
        assert_ne!(legacy.fp, b8.fp, "batch must reach the fingerprint");
        let cache = ProgramCache::new();
        let a = cache.get_or_compile_qnn_batched(&cfg, &g, p, 7, 8).unwrap();
        let b = cache.get_or_compile_qnn_batched(&cfg, &g, p, 7, 8).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "identical batched request must share the entry");
        assert_eq!(a.batch, 8);
        cache.get_or_compile_qnn(&cfg, &g, p, 7).unwrap();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 2));
        // batch = 0 must error even on a WARM cache — the sentinel
        // would otherwise alias the legacy unbatched entry
        assert!(cache.get_or_compile_qnn_batched(&cfg, &g, p, 7, 0).is_err());
        assert_eq!(cache.stats().hits, s.hits, "batch=0 must not hit the legacy entry");
    }

    #[test]
    fn qnn_key_distinguishes_per_layer_overrides() {
        // two graphs identical except one layer's (w_bits, a_bits)
        // must occupy distinct entries
        let cfg = ProcessorConfig::sparq();
        let p = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
        let plain = QnnGraph::sparq_cnn();
        let mixed = QnnGraph::sparq_cnn_mixed((4, 4), (2, 2));
        let k1 = ProgramCache::qnn_key(&cfg, &plain, p, 7);
        let k2 = ProgramCache::qnn_key(&cfg, &mixed, p, 7);
        assert_ne!(k1, k2);
        assert_ne!(k1.fp, k2.fp, "the override must reach the fingerprint");
        // and only the deep conv differing still separates
        let deep = QnnGraph::sparq_cnn_mixed((4, 4), (3, 3));
        assert_ne!(ProgramCache::qnn_key(&cfg, &mixed, p, 7), ProgramCache::qnn_key(&cfg, &deep, p, 7));
    }

    #[test]
    fn qnn_key_distinguishes_dag_wiring_and_new_node_kinds() {
        let cfg = ProcessorConfig::sparq();
        let p = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
        let res = QnnGraph::sparq_resnetlike();
        // rewire the join's first edge from layer 1 to the stem: the
        // layer multiset is untouched, only the edges differ
        let mut rewired = res.clone();
        rewired.preds[3] = vec![0, 2];
        rewired.validate().unwrap();
        let k1 = ProgramCache::qnn_key(&cfg, &res, p, 7);
        let k2 = ProgramCache::qnn_key(&cfg, &rewired, p, 7);
        assert_ne!(k1, k2);
        assert_ne!(k1.fp, k2.fp, "the DAG edges must reach the fingerprint");
        // the residual / depthwise / dense builders all key apart
        let mobile = ProgramCache::qnn_key(&cfg, &QnnGraph::sparq_mobilenetlike(), p, 7);
        let dense = ProgramCache::qnn_key(&cfg, &QnnGraph::sparq_denselike(), p, 7);
        assert_ne!(k1, mobile);
        assert_ne!(mobile, dense);
    }
}
