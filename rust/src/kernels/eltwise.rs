//! The residual-join stage: requantize two producer branches into a
//! common activation level domain and add them element-wise
//! ([`crate::qnn::graph::LayerDesc::Add`]).
//!
//! Each branch arrives as the dense wide sums its producer conv left
//! behind (u16 for ULP containers and the int16 stem, u32 for spilled
//! LP layers).  The join cannot add raw sums — the branches may sit at
//! different element widths and different magnitudes — so it applies
//! the same `min(amax, v >> rshift)` requantization a layer boundary
//! would to EACH branch first, then adds the aligned levels at E16:
//!
//! ```text
//! # per strip, per branch (wide register group v8 / v16):
//! vle{W}   v8, branch          # producer's dense sums
//! vsrl.vx  v8, v8, rshift      # branch requantization shift
//! vminu.vx v8, v8, amax        # clamp into the A-bit level range
//! vnsrl.wx v0, v8, 0           # narrow to E16 (skipped when W == 16)
//! # then:
//! vadd.vv  v0, v0, v4          # the join
//! vse16    v0, dst
//! ```
//!
//! The output is a dense `c x h x w` E16 tensor of values in
//! `0 ..= 2*amax`; the downstream consumer's ordinary boundary requant
//! (`kernels::requant`) renormalizes it into that layer's own level
//! range.  The clamp runs at the wide width *before* the narrowing
//! shift, exactly like `emit_requant`, so nothing truncates silently.
//!
//! The host golden model is [`add_requant_host`]; the golden network
//! applies it per element and the dataflow tests pin the emitted
//! stream to it bit-for-bit.

use super::asm::{strips, Asm};
use super::requant::requant_host;
use crate::isa::{Lmul, Sew, VOp, VType};

/// One residual join: the two producer branches (dense tensors of
/// `len` elements each, at 16- or 32-bit widths) and the common level
/// domain they are requantized into before the add.
#[derive(Debug, Clone, Copy)]
pub struct AddSpec {
    /// First branch: dense sums at `a_sew`, requantized by `a_rshift`.
    pub a_src: u64,
    pub a_sew: Sew,
    pub a_rshift: u32,
    /// Second branch.
    pub b_src: u64,
    pub b_sew: Sew,
    pub b_rshift: u32,
    /// Level clamp both branches share: `level = min(amax, v >> rshift)`.
    pub amax: u64,
    /// Join output: dense `len` elements at E16, values `0..=2*amax`.
    pub dst: u64,
    /// Elements per branch (= c * h * w).
    pub len: u32,
}

/// Emit the requantize-both-branches + `vadd.vv` stream for one join.
/// Branch widths must be E16 or E32 (every packed/stem producer's
/// output element; one `vnsrl` step max, like any boundary).
pub fn emit_add_requant(a: &mut Asm, s: &AddSpec) {
    for sew in [s.a_sew, s.b_sew] {
        assert!(
            matches!(sew, Sew::E16 | Sew::E32),
            "join branches are 16- or 32-bit producer elements, got {sew}"
        );
    }
    // strip at the widest view so both branches fit M1 register groups
    let max_strip = VType::new(Sew::E32, Lmul::M1).vlmax(a.vlen_bits()).max(1);
    // requantize one branch; returns the register holding E16 levels.
    // wide groups v8/v16 (even: a vnsrl source spans 2 registers at
    // M1), narrow results v0/v4.
    let branch = |a: &mut Asm, sew: Sew, src: u64, rshift: u32, wide: u8, narrow: u8, s0: u32, sw: u32| -> u8 {
        let eb = sew.bytes() as u64;
        a.setvl(sw as u64, sew, Lmul::M1);
        a.vle(sew, wide, src + s0 as u64 * eb);
        if rshift > 0 {
            a.vx(VOp::Srl, wide, wide, rshift as u64);
        }
        a.vx(VOp::Min, wide, wide, s.amax);
        if sew == Sew::E16 {
            wide
        } else {
            a.setvl(sw as u64, Sew::E16, Lmul::M1);
            a.vx(VOp::NSrl, narrow, wide, 0);
            narrow
        }
    };
    for (s0, sw) in strips(s.len, max_strip) {
        let ra = branch(a, s.a_sew, s.a_src, s.a_rshift, 8, 0, s0, sw);
        let rb = branch(a, s.b_sew, s.b_src, s.b_rshift, 16, 4, s0, sw);
        a.setvl(sw as u64, Sew::E16, Lmul::M1);
        a.vv(VOp::Add, 0, ra, rb);
        a.vse(Sew::E16, 0, s.dst + s0 as u64 * 2);
        a.loop_overhead();
    }
}

/// Host-side golden of one joined element: each branch requantized
/// into the common domain, then added.  Bounded by `2*amax`, so the
/// E16 store can never wrap.
pub fn add_requant_host(va: u64, a_rshift: u32, vb: u64, b_rshift: u32, amax: u64) -> u64 {
    requant_host(va, a_rshift, amax) + requant_host(vb, b_rshift, amax)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ProcessorConfig;
    use crate::sim::Machine;
    use crate::testutil::Gen;

    fn run_spec(spec: &AddSpec, a_vals: &[u64], b_vals: &[u64]) -> Vec<u64> {
        let cfg = ProcessorConfig::sparq();
        let mut m = Machine::new(cfg.clone(), 1 << 20);
        let ab = spec.a_sew.bytes() as u64;
        for (i, &v) in a_vals.iter().enumerate() {
            m.mem.store_uint(spec.a_src + i as u64 * ab, ab as u32, v).unwrap();
        }
        let bb = spec.b_sew.bytes() as u64;
        for (i, &v) in b_vals.iter().enumerate() {
            m.mem.store_uint(spec.b_src + i as u64 * bb, bb as u32, v).unwrap();
        }
        // poison the destination so every element is provably written
        for i in 0..spec.len as u64 {
            m.mem.store_uint(spec.dst + i * 2, 2, 0x5555).unwrap();
        }
        let mut a = Asm::new("add-requant", cfg.vlen_bits);
        emit_add_requant(&mut a, spec);
        let prog = a.finish(0);
        m.run(&prog).unwrap();
        (0..spec.len as u64).map(|i| m.mem.load_uint(spec.dst + i * 2, 2).unwrap()).collect()
    }

    fn golden(spec: &AddSpec, a_vals: &[u64], b_vals: &[u64]) -> Vec<u64> {
        a_vals
            .iter()
            .zip(b_vals)
            .map(|(&va, &vb)| add_requant_host(va, spec.a_rshift, vb, spec.b_rshift, spec.amax))
            .collect()
    }

    #[test]
    fn mixed_width_join_matches_host() {
        // a u32 (spilled LP) branch joining a u16 branch — multiple
        // strips at VLEN=512/E32
        let spec = AddSpec {
            a_src: 0x1000,
            a_sew: Sew::E32,
            a_rshift: 9,
            b_src: 0x4000,
            b_sew: Sew::E16,
            b_rshift: 5,
            amax: 3,
            dst: 0x8000,
            len: 61,
        };
        let mut g = Gen::new(0x101D_0ADD);
        let a_vals: Vec<u64> = (0..spec.len).map(|_| g.below(1 << 14)).collect();
        let b_vals: Vec<u64> = (0..spec.len).map(|_| g.below(1 << 11)).collect();
        assert_eq!(run_spec(&spec, &a_vals, &b_vals), golden(&spec, &a_vals, &b_vals));
    }

    #[test]
    fn equal_width_join_matches_host() {
        let spec = AddSpec {
            a_src: 0x1000,
            a_sew: Sew::E16,
            a_rshift: 4,
            b_src: 0x2000,
            b_sew: Sew::E16,
            b_rshift: 4,
            amax: 15,
            dst: 0x3000,
            len: 40,
        };
        let mut g = Gen::new(77);
        let a_vals: Vec<u64> = (0..spec.len).map(|_| g.below(1 << 10)).collect();
        let b_vals: Vec<u64> = (0..spec.len).map(|_| g.below(1 << 10)).collect();
        assert_eq!(run_spec(&spec, &a_vals, &b_vals), golden(&spec, &a_vals, &b_vals));
    }

    #[test]
    fn clamp_applies_per_branch_before_the_add() {
        // both branches at the clamp ceiling: the result is 2*amax,
        // not a wrapped or doubly-clamped value
        let spec = AddSpec {
            a_src: 0x100,
            a_sew: Sew::E32,
            a_rshift: 0,
            b_src: 0x200,
            b_sew: Sew::E16,
            b_rshift: 0,
            amax: 7,
            dst: 0x300,
            len: 2,
        };
        let got = run_spec(&spec, &[0xFFFF_FFFF, 3], &[0xFFFF, 4]);
        assert_eq!(got, vec![14, 7]);
    }
}
