//! The fp32 conv2d baseline — runs on Ara (Sparq traps: no FPU).
//! Same slide-based structure with `vfmacc.vf` at SEW=32.

use super::conv_engine::{self, EngineOpts, Inner};
use super::workload::{OutputRef, Workload};
use crate::sim::{Machine, Program, SimError};

pub fn build(m: &mut Machine, wl: &Workload) -> Result<(Program, OutputRef), SimError> {
    conv_engine::build(m, wl, Inner::Fp32, EngineOpts::default(), "fp32-conv2d".into())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ProcessorConfig;
    use crate::kernels::workload::{golden_fp32, ConvDims, Workload};
    use crate::sim::SimError;

    #[test]
    fn matches_order_exact_golden() {
        let d = ConvDims { c: 4, h: 8, w: 10, co: 2, fh: 3, fw: 3 };
        let wl = Workload::random(d, 4, 4, 21);
        let mut m = Machine::new(ProcessorConfig::ara(), wl.mem_bytes());
        let (prog, out) = build(&mut m, &wl).unwrap();
        m.run(&prog).unwrap();
        let got = out.read_f32(&m.mem).unwrap();
        let want = golden_fp32(&wl);
        // the golden replicates the kernel's summation order: exact
        assert_eq!(got, want);
    }

    #[test]
    fn traps_on_sparq() {
        let d = ConvDims { c: 2, h: 4, w: 6, co: 1, fh: 3, fw: 3 };
        let wl = Workload::random(d, 4, 4, 2);
        let mut m = Machine::new(ProcessorConfig::sparq(), wl.mem_bytes());
        let (prog, _) = build(&mut m, &wl).unwrap();
        assert!(matches!(m.run(&prog), Err(SimError::NoFpu(_))));
    }

    #[test]
    fn matches_golden_7x7() {
        let d = ConvDims { c: 2, h: 10, w: 40, co: 1, fh: 7, fw: 7 };
        let wl = Workload::random(d, 4, 4, 5);
        let mut m = Machine::new(ProcessorConfig::ara(), wl.mem_bytes());
        let (prog, out) = build(&mut m, &wl).unwrap();
        m.run(&prog).unwrap();
        assert_eq!(out.read_f32(&m.mem).unwrap(), golden_fp32(&wl));
    }
}
