//! `Asm` — the builder the kernel writers use; a thin, stateful wrapper
//! over [`Program`] that mirrors what hand-written inline assembly
//! would emit (vsetvli tracking, scalar-overhead bookkeeping, strip-
//! mining helpers).

use crate::isa::{Lmul, ScalarKind, Sew, VInst, VOp, VType};
use crate::sim::Program;

/// Builder state: the (SEW, LMUL, vl) the emitted stream is under.
pub struct Asm {
    pub prog: Program,
    vlen_bits: u32,
    cur: Option<(Sew, Lmul, u32)>,
}

impl Asm {
    pub fn new(label: impl Into<String>, vlen_bits: u32) -> Asm {
        Asm { prog: Program::new(label), vlen_bits, cur: None }
    }

    pub fn finish(mut self, macs: u64) -> Program {
        self.prog.macs = macs;
        self.prog
    }

    /// The machine's VLEN (bits) this stream is built for.
    pub fn vlen_bits(&self) -> u32 {
        self.vlen_bits
    }

    /// Current vl.
    pub fn vl(&self) -> u32 {
        self.cur.expect("vsetvli not issued").2
    }

    pub fn vtype(&self) -> VType {
        let (sew, lmul, _) = self.cur.expect("vsetvli not issued");
        VType::new(sew, lmul)
    }

    /// Emit `vsetvli` (skipped if the requested state is already in
    /// effect — like a peephole-optimised kernel would).
    pub fn setvl(&mut self, avl: u64, sew: Sew, lmul: Lmul) -> u32 {
        let vl = VType::new(sew, lmul).apply(avl, self.vlen_bits);
        if self.cur == Some((sew, lmul, vl)) {
            return vl;
        }
        self.cur = Some((sew, lmul, vl));
        self.prog.push(VInst::SetVl { avl, sew, lmul });
        vl
    }

    /// Largest LMUL whose register budget allows `groups` live register
    /// groups (32 architectural registers).
    pub fn lmul_for(&self, groups: u32, avl: u64, sew: Sew) -> Lmul {
        let max_by_budget = 32 / groups.max(1);
        let mut best = Lmul::M1;
        for lm in [Lmul::M2, Lmul::M4, Lmul::M8] {
            if lm.factor() > max_by_budget {
                break;
            }
            // stop growing once a single group already covers the row
            if VType::new(sew, best).vlmax(self.vlen_bits) as u64 >= avl {
                break;
            }
            best = lm;
        }
        best
    }

    // ---- memory ----
    pub fn vle(&mut self, eew: Sew, vd: u8, addr: u64) {
        self.scalar(ScalarKind::AddrCalc, 1);
        self.prog.push(VInst::Load { eew, vd, addr });
    }

    pub fn vse(&mut self, eew: Sew, vs3: u8, addr: u64) {
        self.scalar(ScalarKind::AddrCalc, 1);
        self.prog.push(VInst::Store { eew, vs3, addr });
    }

    // ---- arithmetic ----
    pub fn vv(&mut self, op: VOp, vd: u8, vs2: u8, vs1: u8) {
        self.prog.push(VInst::OpVV { op, vd, vs2, vs1 });
    }

    pub fn vx(&mut self, op: VOp, vd: u8, vs2: u8, rs1: u64) {
        self.prog.push(VInst::OpVX { op, vd, vs2, rs1 });
    }

    pub fn vi(&mut self, op: VOp, vd: u8, vs2: u8, imm: i8) {
        self.prog.push(VInst::OpVI { op, vd, vs2, imm });
    }

    /// `vmv.v.i vd, 0` — clear an accumulator.
    pub fn vclear(&mut self, vd: u8) {
        self.prog.push(VInst::OpVI { op: VOp::Mv, vd, vs2: 0, imm: 0 });
    }

    /// `vmacc.vx` with a pre-loaded scalar weight: models the scalar
    /// load feeding rs1 (1 slot) + the vector op.
    pub fn vmacc_weight(&mut self, vd: u8, vs2: u8, weight: u64) {
        self.scalar(ScalarKind::WeightLoad, 1);
        self.vx(VOp::Macc, vd, vs2, weight);
    }

    /// `vmacsr.vx` likewise (the paper only uses the vector-scalar form).
    pub fn vmacsr_weight(&mut self, vd: u8, vs2: u8, weight: u64) {
        self.scalar(ScalarKind::WeightLoad, 1);
        self.vx(VOp::Macsr, vd, vs2, weight);
    }

    /// `vfmacc.vf` with a scalar f32 weight.
    pub fn vfmacc_weight(&mut self, vd: u8, vs2: u8, weight: f32) {
        self.scalar(ScalarKind::WeightLoad, 1);
        self.vx(VOp::FMacc, vd, vs2, weight.to_bits() as u64);
    }

    // ---- scalar-core overhead ----
    pub fn scalar(&mut self, kind: ScalarKind, n: u32) {
        self.prog.push(VInst::Scalar { kind, n });
    }

    /// Loop-iteration overhead (counter bump + compare + branch).
    pub fn loop_overhead(&mut self) {
        self.scalar(ScalarKind::LoopCtl, 2);
    }
}

/// Strip-mining: split `total` output columns into strips of at most
/// `max_strip`, returning (start, width) pairs.
pub fn strips(total: u32, max_strip: u32) -> Vec<(u32, u32)> {
    assert!(max_strip > 0);
    let mut out = Vec::new();
    let mut s = 0;
    while s < total {
        let w = max_strip.min(total - s);
        out.push((s, w));
        s += w;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn setvl_dedupes() {
        let mut a = Asm::new("t", 4096);
        a.setvl(100, Sew::E16, Lmul::M1);
        a.setvl(100, Sew::E16, Lmul::M1);
        assert_eq!(a.prog.len(), 1);
        a.setvl(50, Sew::E16, Lmul::M1);
        assert_eq!(a.prog.len(), 2);
    }

    #[test]
    fn lmul_for_respects_register_budget() {
        let a = Asm::new("t", 4096);
        // 8 groups (7x7 conv: 7 accumulators + input) -> at most m4
        assert_eq!(a.lmul_for(8, 518, Sew::E16), Lmul::M4);
        // 22 groups (spilling variants) -> m1
        assert_eq!(a.lmul_for(22, 518, Sew::E16), Lmul::M1);
        // small rows don't need big groups
        assert_eq!(a.lmul_for(8, 64, Sew::E16), Lmul::M1);
    }

    #[test]
    fn strips_cover_exactly() {
        assert_eq!(strips(10, 4), vec![(0, 4), (4, 4), (8, 2)]);
        assert_eq!(strips(4, 8), vec![(0, 4)]);
        let total: u32 = strips(517, 256).iter().map(|&(_, w)| w).sum();
        assert_eq!(total, 517);
    }

    #[test]
    fn weight_macc_emits_scalar_slot() {
        let mut a = Asm::new("t", 4096);
        a.setvl(16, Sew::E16, Lmul::M1);
        a.vmacc_weight(1, 2, 7);
        assert_eq!(a.prog.len(), 3); // setvl + scalar + vmacc
    }
}
