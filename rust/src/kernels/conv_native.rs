//! Native ULPPACK conv2d on stock RVV (runs on Ara *and* Sparq): vmacc
//! accumulates raw packed products locally, and every `k_local` issues
//! the vsrl + vwaddu + vmv repair sequence extracts the dot-product
//! field — the exact overhead `vmacsr` was designed to remove (paper
//! Fig. 2).

use super::conv_engine::{self, EngineOpts};
use super::workload::{OutputRef, Workload};
use super::ConvVariant;
use crate::sim::{Machine, Program, SimError};

/// Build the native ULPPACK conv at (W, A).  Fails with `Unsupported`
/// when no container sustains even one local accumulation.
pub fn build(
    m: &mut Machine,
    wl: &Workload,
    w_bits: u32,
    a_bits: u32,
) -> Result<(Program, OutputRef), SimError> {
    build_opts(m, wl, w_bits, a_bits, EngineOpts::default())
}

pub fn build_opts(
    m: &mut Machine,
    wl: &Workload,
    w_bits: u32,
    a_bits: u32,
    opts: EngineOpts,
) -> Result<(Program, OutputRef), SimError> {
    let (inner, label) = ConvVariant::Native { w_bits, a_bits }.planned_inner(wl)?;
    conv_engine::build(m, wl, inner, opts, label)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ProcessorConfig;
    use crate::kernels::workload::{golden_exact, ConvDims, Workload};
    use crate::testutil::Prop;

    fn run(wl: &Workload, w: u32, a: u32) -> (Vec<i64>, crate::sim::RunReport) {
        let mut m = Machine::new(ProcessorConfig::ara(), wl.mem_bytes());
        let (prog, out) = build(&mut m, wl, w, a).unwrap();
        let rep = m.run(&prog).unwrap();
        (out.read_ints(&m.mem).unwrap(), rep)
    }

    #[test]
    fn w1a1_exact() {
        let d = ConvDims { c: 8, h: 9, w: 12, co: 2, fh: 3, fw: 3 };
        let wl = Workload::random(d, 1, 1, 4);
        let (got, _) = run(&wl, 1, 1);
        assert_eq!(got, golden_exact(&wl));
    }

    #[test]
    fn w3a3_exact_with_subrow_repairs() {
        // k_local(3,3,LP) = 3 < fw: repairs fire inside the i loop
        let d = ConvDims { c: 4, h: 11, w: 14, co: 1, fh: 5, fw: 5 };
        let wl = Workload::random(d, 3, 3, 8);
        let (got, _) = run(&wl, 3, 3);
        assert_eq!(got, golden_exact(&wl));
    }

    #[test]
    fn w4a4_rejected() {
        let d = ConvDims { c: 4, h: 6, w: 8, co: 1, fh: 3, fw: 3 };
        let wl = Workload::random(d, 4, 4, 1);
        let mut m = Machine::new(ProcessorConfig::ara(), wl.mem_bytes());
        assert!(build(&mut m, &wl, 4, 4).is_err());
    }

    #[test]
    fn runs_on_stock_ara() {
        // no vmacsr involved: the whole point of the native scheme
        let d = ConvDims { c: 4, h: 6, w: 8, co: 1, fh: 3, fw: 3 };
        let wl = Workload::random(d, 2, 2, 6);
        let (got, _) = run(&wl, 2, 2);
        assert_eq!(got, golden_exact(&wl));
    }

    #[test]
    fn property_native_pairs_match_exact_golden() {
        Prop::new(0x7A7).runs(8).check(|g| {
            let pairs = [(1u32, 1u32), (1, 2), (2, 2), (3, 3), (2, 3)];
            let (w, a) = *g.pick(&pairs);
            let f = *g.pick(&[1u32, 3, 5]);
            let d = ConvDims {
                c: 2 * g.range(1, 3) as u32,
                h: f + g.range(2, 5) as u32,
                w: f + g.range(2, 9) as u32,
                co: g.range(1, 2) as u32,
                fh: f,
                fw: f,
            };
            let wl = Workload::random(d, w, a, g.next_u64());
            let (got, _) = run(&wl, w, a);
            assert_eq!(got, golden_exact(&wl), "W{w}A{a} {d:?}");
        });
    }

    #[test]
    fn slower_than_vmacsr_same_precision() {
        use crate::ulppack::RegionMode;
        let d = ConvDims { c: 8, h: 14, w: 70, co: 2, fh: 7, fw: 7 };
        let wl = Workload::random(d, 2, 2, 3);
        let (_, rep_nat) = run(&wl, 2, 2);
        let mut m = Machine::new(ProcessorConfig::sparq(), wl.mem_bytes());
        let (prog, _) =
            crate::kernels::conv_vmacsr::build(&mut m, &wl, 2, 2, RegionMode::Strict).unwrap();
        let rep_sr = m.run(&prog).unwrap();
        assert!(
            rep_sr.stats.cycles < rep_nat.stats.cycles,
            "vmacsr {} !< native {}",
            rep_sr.stats.cycles,
            rep_nat.stats.cycles
        );
    }
}
