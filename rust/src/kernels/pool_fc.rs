//! Executed (not estimated) maxpool and GAP+FC streams for the
//! dataflow QNN executor — before the multi-layer refactor these
//! layers were costed with a fabricated bytes/cycle formula; now they
//! run through the same simulator as the convs.
//!
//! ## 2x2 maxpool (stride 2)
//!
//! Per channel and output row, with only unit-stride memory ops:
//!
//! ```text
//! vle{W}   v8,  in[c][2r]       # row A (w elements)
//! vle{W}   v10, in[c][2r+1]     # row B
//! vmaxu.vv v8,  v8, v10         # vertical max
//! vnsrl.wx v0,  v8, 0           # even columns  (deinterleave ...)
//! vnsrl.wx v2,  v8, W           # odd columns   (... via pair view)
//! vmaxu.vv v0,  v0, v2          # horizontal max
//! vse{W}   v0,  out[c][r]       # w/2 elements
//! ```
//!
//! The `vnsrl` pair is the classic RVV even/odd deinterleave: viewing
//! the vector as 2*W-wide pairs, shift 0 extracts the even elements
//! and shift W the odd ones.
//!
//! ## GAP + FC head
//!
//! Global-average pooling keeps integer *sums* (the 1/HW factor is a
//! class-uniform scale, so the argmax is unchanged — the golden model
//! uses sums too).  Per channel: a slide-down/add reduction tree
//! produces the channel sum in element 0, `vwaddu.wv` widens it to
//! E32, and one `vmacc.vx` per class accumulates `sum * w[k][c]` with
//! the FC weight baked into the stream as a scalar operand — the same
//! "weights live in the stream" discipline the conv kernels use.

use super::asm::Asm;
use crate::isa::{Lmul, Sew, VOp, VType};

/// Emit 2x2/stride-2 maxpool over a dense `c x h x w` tensor at `sew`
/// (`h`, `w` even), writing the dense `c x h/2 x w/2` result to `dst`.
pub fn emit_maxpool2(a: &mut Asm, c: u32, h: u32, w: u32, sew: Sew, src: u64, dst: u64) {
    assert!(h % 2 == 0 && w % 2 == 0, "2x2 pooling needs even spatial dims");
    let eb = sew.bytes() as u64;
    // w input elements load into v8's M1 group; the vnsrl wide view
    // spans v8..v9, so w/2 must also fit one narrow register
    assert!(
        w as u64 * eb <= (a.vlen_bits() / 8) as u64,
        "pool row must fit one register at M1"
    );
    let (ho, wo) = (h / 2, w / 2);
    for ch in 0..c {
        for r in 0..ho {
            let row_a = src + ((ch * h + 2 * r) as u64 * w as u64) * eb;
            let row_b = row_a + w as u64 * eb;
            a.setvl(w as u64, sew, Lmul::M1);
            a.vle(sew, 8, row_a);
            a.vle(sew, 10, row_b);
            a.vv(VOp::Max, 8, 8, 10);
            a.setvl(wo as u64, sew, Lmul::M1);
            a.vx(VOp::NSrl, 0, 8, 0);
            a.vx(VOp::NSrl, 2, 8, sew.bits() as u64);
            a.vv(VOp::Max, 0, 0, 2);
            a.vse(sew, 0, dst + ((ch * ho + r) as u64 * wo as u64) * eb);
            a.loop_overhead();
        }
        a.loop_overhead();
    }
}

/// Host golden for [`emit_maxpool2`] on a flat `c x h x w` tensor.
pub fn maxpool2_host(vals: &[i64], c: u32, h: u32, w: u32) -> Vec<i64> {
    let (ho, wo) = ((h / 2) as usize, (w / 2) as usize);
    let (h, w) = (h as usize, w as usize);
    let mut out = vec![0i64; c as usize * ho * wo];
    for ch in 0..c as usize {
        for r in 0..ho {
            for q in 0..wo {
                let at = |dr: usize, dq: usize| vals[(ch * h + 2 * r + dr) * w + 2 * q + dq];
                out[(ch * ho + r) * wo + q] =
                    at(0, 0).max(at(0, 1)).max(at(1, 0)).max(at(1, 1));
            }
        }
    }
    out
}

/// Emit the GAP+FC head: levels (`c` channels x `hw` elements at
/// `sew_in`, E8 or E16) reduce to per-channel sums, widen to E32, and
/// accumulate into `classes` logits stored as u32 at `logits`.
/// `fc_wgt[k][ch]` are the (level-domain) FC weights.
///
/// Value-range preconditions (the caller guards them — see
/// `qnn::compiled`'s typed checks): the channel sum `hw * max_level`
/// must fit `sew_in`'s lanes, and `c * sum_max * max_weight` must fit
/// u32, or the reduction wraps where the host golden does not.
pub fn emit_gap_fc(
    a: &mut Asm,
    c: u32,
    hw: u32,
    sew_in: Sew,
    src: u64,
    fc_wgt: &[Vec<u64>],
    logits: u64,
) {
    let classes = fc_wgt.len();
    assert!(classes <= 4, "acc registers v0/v2/v4/v6 hold up to 4 logits");
    assert!(hw.is_power_of_two(), "the reduction tree wants a power-of-two HW");
    assert!(sew_in == Sew::E8 || sew_in == Sew::E16, "levels are sub-word");
    let eb = sew_in.bytes() as u64;
    let acc = |k: usize| (2 * k) as u8; // E32 logits in v0/v2/v4/v6

    a.setvl(1, Sew::E32, Lmul::M1);
    for k in 0..classes {
        a.vclear(acc(k));
    }
    for ch in 0..c {
        // clear v8 past the loaded elements: the slide tree reads up to
        // index hw + hw/2 - 1, which must be zero, not stale
        a.setvl(2 * hw as u64, sew_in, Lmul::M1);
        a.vclear(8);
        a.setvl(hw as u64, sew_in, Lmul::M1);
        a.vle(sew_in, 8, src + ch as u64 * hw as u64 * eb);
        let mut step = hw / 2;
        while step >= 1 {
            a.vx(VOp::SlideDown, 10, 8, step as u64);
            a.vv(VOp::Add, 8, 8, 10);
            step /= 2;
        }
        // widen the element-0 sum to E32 (E8 goes through E16 first)
        let mut cur = sew_in;
        let mut reg = 8u8;
        while cur != Sew::E32 {
            let wide = cur.widened().unwrap();
            let wreg = reg + 4; // v12 then v16: even, disjoint
            a.setvl(1, wide, Lmul::M1);
            a.vclear(wreg);
            a.setvl(1, cur, Lmul::M1);
            a.vv(VOp::WAdduWv, wreg, reg, 0);
            reg = wreg;
            cur = wide;
        }
        a.setvl(1, Sew::E32, Lmul::M1);
        for (k, per_class) in fc_wgt.iter().enumerate() {
            a.vmacc_weight(acc(k), reg, per_class[ch as usize]);
        }
        a.loop_overhead();
    }
    a.setvl(1, Sew::E32, Lmul::M1);
    for k in 0..classes {
        a.vse(Sew::E32, acc(k), logits + 4 * k as u64);
    }
}

/// Host golden for [`emit_gap_fc`]: `logit[k] = sum_c w[k][c] *
/// (sum of channel c's levels)`.
pub fn gap_fc_host(levels: &[i64], c: u32, hw: u32, fc_wgt: &[Vec<u64>]) -> Vec<i64> {
    let gap: Vec<i64> = (0..c as usize)
        .map(|ch| levels[ch * hw as usize..(ch + 1) * hw as usize].iter().sum())
        .collect();
    fc_wgt
        .iter()
        .map(|per_class| {
            (0..c as usize).map(|ch| per_class[ch] as i64 * gap[ch]).sum::<i64>()
        })
        .collect()
}

/// The largest `vl` the GAP reduction's clear pass requests — callers
/// size `hw` so `2*hw` fits one register at `sew_in`/M1.
pub fn gap_fits(hw: u32, sew_in: Sew, vlen_bits: u32) -> bool {
    2 * hw <= VType::new(sew_in, Lmul::M1).vlmax(vlen_bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ProcessorConfig;
    use crate::sim::Machine;
    use crate::testutil::Gen;

    #[test]
    fn maxpool_matches_host_at_both_widths() {
        for (sew, maxv) in [(Sew::E16, 1u64 << 14), (Sew::E32, 1u64 << 30)] {
            let (c, h, w) = (3u32, 6u32, 8u32);
            let cfg = ProcessorConfig::sparq();
            let mut m = Machine::new(cfg.clone(), 1 << 20);
            let eb = sew.bytes() as u64;
            let (src, dst) = (0x1000u64, 0x8000u64);
            let mut g = Gen::new(0xBEEF);
            let vals: Vec<i64> = (0..c * h * w).map(|_| g.below(maxv) as i64).collect();
            for (i, &v) in vals.iter().enumerate() {
                m.mem.store_uint(src + i as u64 * eb, eb as u32, v as u64).unwrap();
            }
            let mut a = Asm::new("pool", cfg.vlen_bits);
            emit_maxpool2(&mut a, c, h, w, sew, src, dst);
            m.run(&a.finish(0)).unwrap();
            let want = maxpool2_host(&vals, c, h, w);
            let got: Vec<i64> = (0..want.len())
                .map(|i| m.mem.load_uint(dst + i as u64 * eb, eb as u32).unwrap() as i64)
                .collect();
            assert_eq!(got, want, "sew {sew}");
        }
    }

    #[test]
    fn gap_fc_matches_host() {
        for sew_in in [Sew::E8, Sew::E16] {
            let (c, hw, classes) = (32u32, 16u32, 4usize);
            let cfg = ProcessorConfig::sparq();
            assert!(gap_fits(hw, sew_in, cfg.vlen_bits));
            let mut m = Machine::new(cfg.clone(), 1 << 20);
            let eb = sew_in.bytes() as u64;
            let (src, logits) = (0x1000u64, 0xC000u64);
            let mut g = Gen::new(0x60D);
            let levels: Vec<i64> = (0..c * hw).map(|_| g.below(16) as i64).collect();
            let fc_wgt: Vec<Vec<u64>> =
                (0..classes).map(|_| (0..c).map(|_| g.below(15)).collect()).collect();
            for (i, &v) in levels.iter().enumerate() {
                m.mem.store_uint(src + i as u64 * eb, eb as u32, v as u64).unwrap();
            }
            let mut a = Asm::new("gapfc", cfg.vlen_bits);
            emit_gap_fc(&mut a, c, hw, sew_in, src, &fc_wgt, logits);
            m.run(&a.finish((c * classes as u32) as u64)).unwrap();
            let want = gap_fc_host(&levels, c, hw, &fc_wgt);
            let got: Vec<i64> =
                (0..classes).map(|k| m.mem.load_uint(logits + 4 * k as u64, 4).unwrap() as i64).collect();
            assert_eq!(got, want, "sew {sew_in}");
        }
    }

    #[test]
    fn maxpool_rejects_odd_dims() {
        let cfg = ProcessorConfig::sparq();
        let mut a = Asm::new("bad", cfg.vlen_bits);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            emit_maxpool2(&mut a, 1, 5, 4, Sew::E16, 0, 0x100)
        }));
        assert!(r.is_err());
    }
}
