//! Cached kernel autotuning: measure every candidate conv variant for
//! a (processor, layer shape, precision) tuple on the simulator and
//! memoize the ranking, so the dataflow compiler
//! ([`crate::qnn::compiled::CompiledQnn`]) picks the *fastest legal*
//! kernel per layer instead of a hand-picked one per network.
//!
//! ## Candidates
//!
//! For a quantized conv at (W, A):
//!
//! * `Vmacsr { .., RegionMode::Paper }` and `{ .., RegionMode::Strict }`
//!   (only on processors implementing `vmacsr`),
//! * `Native { .. }` — ULPPACK on stock RVV (also the only packed
//!   scheme on Ara-like configs),
//! * `Int16` — the unpacked baseline (always legal; never wins on
//!   Sparq, but it is the reference the paper's speedups divide by and
//!   a real fallback on precisions nothing else admits).
//!
//! The int16 stem has exactly one candidate (`Int16`).
//!
//! ## Measurement
//!
//! Each candidate is compiled for the processor and executed **once**
//! on an arena-isolated probe machine (its own `Machine`, its own
//! address space — never the shared activation arena).  The timing
//! model is data-independent (cycles depend on the instruction stream
//! and `vl`, not the values), so a zero-filled probe workload measures
//! exactly the cycles the real layer will cost, and one probe
//! execution is the whole measurement.  Candidates that do not compile
//! (precision outside the container region, `vmacsr` on a machine
//! without it) are recorded as rejected with their error text.
//!
//! ## Memoization
//!
//! Rankings live in the shared [`ProgramCache`] under a [`TuneKey`]
//! (`kernels::cache`) — the same fingerprint-prefilter +
//! exact-compare discipline as `ConvKey`/`QnnKey`.  Weights are *not*
//! part of the key: timing is data-independent, so one ranking serves
//! every network sharing the (cfg, shape, precision) tuple.  Repeat
//! compilations of the same network are therefore all-hits at both the
//! graph level (`QnnKey`) and, for new networks over known shapes, the
//! tune level.

use super::cache::ProgramCache;
use super::conv_engine::{packed_out_elem, vmacsr_out_elem};
use super::workload::{ConvDims, OutElem, Workload};
use super::{compile_conv_opts, ConvVariant, EngineOpts};
use crate::arch::ProcessorConfig;
use crate::isa::Sew;
use crate::qnn::graph::container_sew;
use crate::sim::{Machine, SimError};
use crate::ulppack::{region, RegionMode};

/// One measured candidate.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub variant: ConvVariant,
    /// The builder label the variant compiles under (e.g.
    /// `ULP-W2A2-vmacsr`).
    pub label: String,
    /// Measured cycles of one probe execution.
    pub cycles: u64,
}

/// The memoized result of tuning one (cfg, shape, precision) tuple.
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    /// Every candidate that compiled and ran, fastest first; ties keep
    /// the candidate order (vmacsr-paper before strict before native
    /// before int16), so the ranking is deterministic.
    pub ranked: Vec<Candidate>,
    /// Candidates that failed to compile or run: (variant label, error).
    pub rejected: Vec<(String, String)>,
}

impl TuneOutcome {
    /// The winner (the ranking is never empty — `Int16` always runs).
    pub fn best(&self) -> &Candidate {
        &self.ranked[0]
    }
}

/// The candidate variants for a conv layer at resolved (W, A) on
/// `cfg`, in deterministic tie-breaking order.
pub fn candidate_variants(cfg: &ProcessorConfig, w_bits: u32, a_bits: u32, quantized: bool) -> Vec<ConvVariant> {
    if !quantized {
        return vec![ConvVariant::Int16];
    }
    let mut v = Vec::new();
    if cfg.vmacsr {
        v.push(ConvVariant::Vmacsr { w_bits, a_bits, mode: RegionMode::Paper });
        v.push(ConvVariant::Vmacsr { w_bits, a_bits, mode: RegionMode::Strict });
    }
    v.push(ConvVariant::Native { w_bits, a_bits });
    v.push(ConvVariant::Int16);
    v
}

/// (input element width, output element) a candidate would move the
/// layer's activations at — derived from the same region plans the
/// engine compiles with, so the dataflow compiler can check boundary
/// legality *before* committing arena addresses.  `None` when the
/// variant cannot run the precision at all.
pub fn variant_io(variant: ConvVariant, dims: ConvDims) -> Option<(Sew, OutElem)> {
    match variant {
        ConvVariant::Int16 => Some((Sew::E16, OutElem::U16)),
        ConvVariant::Fp32 => Some((Sew::E32, OutElem::F32)),
        ConvVariant::Vmacsr { w_bits, a_bits, mode } => {
            let issues = dims.issues_per_output();
            let plan = region::plan_vmacsr(w_bits, a_bits, issues, mode)?;
            Some((
                container_sew(plan.container),
                vmacsr_out_elem(plan.container, plan.spill_every, issues),
            ))
        }
        ConvVariant::Native { w_bits, a_bits } => {
            let plan = region::plan_native(w_bits, a_bits)?;
            // the native scheme always keeps a wide accumulator
            Some((container_sew(plan.container), packed_out_elem(plan.container, true)))
        }
    }
}

/// A zero-filled workload of the right shape and precision: the probe
/// the candidates are measured on (timing is data-independent, so
/// zeros measure exactly what real data would).
fn probe_workload(dims: ConvDims, w_bits: u32, a_bits: u32) -> Workload {
    let hw = (dims.h * dims.w) as usize;
    let fhw = (dims.fh * dims.fw) as usize;
    Workload {
        dims,
        w_bits,
        a_bits,
        act: vec![vec![0; hw]; dims.c as usize],
        wgt: vec![vec![vec![0; fhw]; dims.c as usize]; dims.co as usize],
        act_f32: vec![],
        wgt_f32: vec![],
    }
}

/// Tune one conv layer: look the (cfg, dims, precision, opts) tuple up
/// in `cache` (under its [`super::cache::TuneKey`]), measuring every
/// candidate on a miss.  Errors only when *no* candidate runs.
pub fn autotune_conv(
    cache: &ProgramCache,
    cfg: &ProcessorConfig,
    dims: ConvDims,
    w_bits: u32,
    a_bits: u32,
    quantized: bool,
    opts: EngineOpts,
) -> Result<std::sync::Arc<TuneOutcome>, SimError> {
    let key = ProgramCache::tune_key(cfg, dims, w_bits, a_bits, quantized, opts);
    cache.get_or_tune(key, || measure(cfg, dims, w_bits, a_bits, quantized, opts))
}

/// The uncached measurement: compile + probe-execute every candidate.
fn measure(
    cfg: &ProcessorConfig,
    dims: ConvDims,
    w_bits: u32,
    a_bits: u32,
    quantized: bool,
    opts: EngineOpts,
) -> Result<TuneOutcome, SimError> {
    let mut ranked = Vec::new();
    let mut rejected = Vec::new();
    for variant in candidate_variants(cfg, w_bits, a_bits, quantized) {
        let (wb, ab) = variant.bits();
        let wl = probe_workload(dims, wb, ab);
        // arena-isolated probe: a private machine with the candidate's
        // own layout, never the shared activation arena
        let run = compile_conv_opts(cfg, &wl, variant, opts).and_then(|cc| {
            let mut m = Machine::new(cfg.clone(), cc.mem_bytes);
            let report = cc.execute(&mut m, &wl)?;
            Ok(Candidate { variant, label: report.label.clone(), cycles: report.stats.cycles })
        });
        match run {
            Ok(c) => ranked.push(c),
            Err(e) => rejected.push((variant.label(), e.to_string())),
        }
    }
    if ranked.is_empty() {
        return Err(SimError::Unsupported(
            "no conv variant is legal for this precision on this processor",
        ));
    }
    // stable: ties keep the candidate order (paper-mode vmacsr first)
    ranked.sort_by_key(|c| c.cycles);
    Ok(TuneOutcome { ranked, rejected })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ConvDims {
        ConvDims { c: 8, h: 10, w: 10, co: 2, fh: 3, fw: 3 }
    }

    #[test]
    fn vmacsr_wins_on_sparq_native_on_ara() {
        let cache = ProgramCache::new();
        let sparq = autotune_conv(&cache, &ProcessorConfig::sparq(), dims(), 2, 2, true, EngineOpts::default())
            .unwrap();
        assert!(
            matches!(sparq.best().variant, ConvVariant::Vmacsr { mode: RegionMode::Paper, .. }),
            "{:?}",
            sparq.best()
        );
        // every candidate measured: 2 vmacsr modes + native + int16
        assert_eq!(sparq.ranked.len() + sparq.rejected.len(), 4);
        let ara = autotune_conv(&cache, &ProcessorConfig::ara(), dims(), 2, 2, true, EngineOpts::default())
            .unwrap();
        assert!(matches!(ara.best().variant, ConvVariant::Native { .. }), "{:?}", ara.best());
    }

    #[test]
    fn int16_is_the_fallback_when_nothing_packs() {
        // W4A4 on Ara: vmacsr absent, native impossible -> int16 serves
        let cache = ProgramCache::new();
        let t = autotune_conv(&cache, &ProcessorConfig::ara(), dims(), 4, 4, true, EngineOpts::default())
            .unwrap();
        assert_eq!(t.ranked.len(), 1);
        assert!(matches!(t.best().variant, ConvVariant::Int16));
        assert_eq!(t.rejected.len(), 1, "native W4A4 must be recorded as rejected");
    }

    #[test]
    fn stem_has_one_candidate_and_outcomes_memoize() {
        let cache = ProgramCache::new();
        let cfg = ProcessorConfig::sparq();
        let a = autotune_conv(&cache, &cfg, dims(), 8, 2, false, EngineOpts::default()).unwrap();
        assert_eq!(a.ranked.len(), 1);
        assert!(matches!(a.best().variant, ConvVariant::Int16));
        let b = autotune_conv(&cache, &cfg, dims(), 8, 2, false, EngineOpts::default()).unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b), "repeat tuning must hit the cache");
        let s = cache.stats();
        assert_eq!((s.tune_hits, s.tune_misses, s.tune_entries), (1, 1, 1));
    }

    #[test]
    fn variant_io_matches_the_region_plans() {
        let d = dims(); // issues = 4*9 = 36
        // W2A2 vmacsr: ULP container, 8-bit in; spill 21 < 36 -> wide u16
        let (s, e) = variant_io(
            ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Paper },
            d,
        )
        .unwrap();
        assert_eq!((s, e), (Sew::E8, OutElem::U16));
        // W4A4 vmacsr: LP, 16-bit in; spill 156 > 36 -> narrow u16 out
        let (s, e) = variant_io(
            ConvVariant::Vmacsr { w_bits: 4, a_bits: 4, mode: RegionMode::Paper },
            d,
        )
        .unwrap();
        assert_eq!((s, e), (Sew::E16, OutElem::U16));
        // native W4A4: impossible
        assert!(variant_io(ConvVariant::Native { w_bits: 4, a_bits: 4 }, d).is_none());
        assert_eq!(
            variant_io(ConvVariant::Int16, d).unwrap(),
            (Sew::E16, OutElem::U16)
        );
    }
}
