//! im2col + GEMM conv2d — the alternative the paper argues *against*
//! (§III-A: "the choice of a dedicated convolution algorithm over an
//! image-to-column operation followed by a GEMM is motivated by the
//! reduction of the memory footprint induced by the im2col operation").
//!
//! Implemented here so the claim is measurable: the im2col pass
//! materialises a (C/2 · Fh · Fw) x (Ho · Wo) packed column matrix in
//! DRAM, then a vmacsr GEMM consumes it.  The ablation bench compares
//! cycles *and* VLSU bytes against the direct slide-based kernel.
//!
//! im2col row (cc, ki, i) is the input plane (cc) shifted by (ki, i) —
//! with unit-stride rows this is a strided copy the VLSU can stream;
//! the GEMM is then a pure vmacsr reduction with zero slides.

use super::asm::{strips, Asm};
use super::conv_engine::EngineOpts;
use super::pack_rt;
use super::workload::{OutElem, OutputRef, Workload};
use crate::isa::{Lmul, ScalarKind, Sew, VOp, VType};
use crate::sim::{Machine, Program, SimError};
use crate::ulppack::{self, region, Container, RegionMode};

/// Build the packed im2col + GEMM conv at (W, A) with `vmacsr`.
pub fn build(
    m: &mut Machine,
    wl: &Workload,
    w_bits: u32,
    a_bits: u32,
    mode: RegionMode,
) -> Result<(Program, OutputRef), SimError> {
    let d = wl.dims;
    let plan = region::plan_vmacsr(w_bits, a_bits, d.issues_per_output(), mode)
        .ok_or(SimError::Unsupported("precision pair outside every container's region"))?;
    let cont = plan.container;
    let sew = match cont {
        Container::Lp => Sew::E16,
        Container::Ulp => Sew::E8,
    };
    let ew = sew.bytes() as u64;
    let (ho, wo) = (d.ho(), d.wo());
    let n = (ho * wo) as u64; // GEMM N dimension
    let cp = d.c / 2;
    let k_rows = (cp * d.fh * d.fw) as u64; // GEMM K dimension

    // ---- stage tensors ----
    let plane = d.h as u64 * d.w as u64;
    let x_addr = m.mem.alloc(d.c as u64 * plane * ew, 64)?;
    for (c, row) in wl.act.iter().enumerate() {
        let base = x_addr + c as u64 * plane * ew;
        for (i, &v) in row.iter().enumerate() {
            m.mem.store_uint(base + i as u64 * ew, ew as u32, v)?;
        }
    }
    let xp_addr = m.mem.alloc(cp as u64 * plane * ew, 64)?;
    // the im2col matrix: K x N packed containers — the footprint the
    // paper's direct kernel avoids
    let col_addr = m.mem.alloc(k_rows * n * ew, 64)?;
    let out_elem = OutElem::U32;
    let out_len = (d.co * ho * wo) as usize;
    let out_addr = m.mem.alloc(out_len as u64 * 4, 64)?;
    let wp = ulppack::pack_weights(&wl.wgt, cont);

    let mut a = Asm::new(format!("{}-W{w_bits}A{a_bits}-im2col-gemm", cont.name()), m.cfg.vlen_bits);

    // ---- pass 1: runtime activation packing (same as the direct path)
    let opts = EngineOpts::default();
    if opts.runtime_weight_pack {
        a.scalar(ScalarKind::AddrCalc, d.co * cp * d.fh * d.fw * 4);
    }
    pack_rt::emit_pack_activations(&mut a, &d, sew, x_addr, xp_addr);

    // ---- pass 2: im2col — stream each shifted plane row into the
    // column matrix (row-of-patches layout: K-major, N contiguous)
    let lmul_cp = a.lmul_for(2, wo as u64, sew);
    let vlmax_cp = VType::new(sew, lmul_cp).vlmax(m.cfg.vlen_bits);
    let mut krow = 0u64;
    for cc in 0..cp {
        for ki in 0..d.fh {
            for i in 0..d.fw {
                // column-matrix row (cc,ki,i) = x[cc][r+ki][q+i] over (r,q)
                for r in 0..ho {
                    let src = xp_addr
                        + (cc as u64 * plane + (r + ki) as u64 * d.w as u64 + i as u64) * ew;
                    let dst = col_addr + (krow * n + r as u64 * wo as u64) * ew;
                    for (s0, sw) in strips(wo, vlmax_cp) {
                        a.setvl(sw as u64, sew, lmul_cp);
                        a.vle(sew, 0, src + s0 as u64 * ew);
                        a.vse(sew, 0, dst + s0 as u64 * ew);
                    }
                    a.loop_overhead();
                }
                krow += 1;
            }
        }
    }

    // ---- pass 3: GEMM — out[o] = sum_k w[o][k] * col[k], vmacsr'd
    // per N-strip with a narrow accumulator + wide spills
    let lmul = Lmul::M1;
    let vlmax = VType::new(sew, lmul).vlmax(m.cfg.vlen_bits);
    let spill_every = plan.spill_every;
    // registers: acc=v0, wide=v2/3, load=v4
    for o in 0..d.co {
        for (s0, sw) in strips(n as u32, vlmax) {
            a.setvl(sw as u64, sew.widened().unwrap(), Lmul::M2);
            a.vclear(2);
            a.setvl(sw as u64, sew, lmul);
            a.vclear(0);
            let mut since = 0u64;
            let mut krow = 0u64;
            for cc in 0..cp {
                for ki in 0..d.fh {
                    for i in 0..d.fw {
                        let wv = wp[o as usize][cc as usize][(ki * d.fw + i) as usize];
                        let src = col_addr + (krow * n + s0 as u64) * ew;
                        a.vle(sew, 4, src);
                        a.vmacsr_weight(0, 4, wv);
                        krow += 1;
                        since += 1;
                        if since >= spill_every {
                            since = 0;
                            a.vv(VOp::WAdduWv, 2, 0, 0);
                            a.vclear(0);
                        }
                    }
                }
                a.loop_overhead();
            }
            // final spill + widen to u32 output
            a.vv(VOp::WAdduWv, 2, 0, 0);
            match cont {
                Container::Lp => {
                    a.setvl(sw as u64, Sew::E32, Lmul::M2);
                    a.vse(Sew::E32, 2, out_addr + (o as u64 * n + s0 as u64) * 4);
                }
                Container::Ulp => {
                    // wide is u16; widen once more through v8/v11
                    a.setvl(sw as u64, Sew::E32, Lmul::M4);
                    a.vclear(8);
                    a.setvl(sw as u64, Sew::E16, Lmul::M2);
                    a.vv(VOp::WAdduWv, 8, 2, 0);
                    a.setvl(sw as u64, Sew::E32, Lmul::M4);
                    a.vse(Sew::E32, 8, out_addr + (o as u64 * n + s0 as u64) * 4);
                }
            }
            a.loop_overhead();
        }
    }

    let out = OutputRef { addr: out_addr, elem: out_elem, len: out_len };
    Ok((a.finish(d.macs()), out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ProcessorConfig;
    use crate::kernels::workload::{golden_exact, ConvDims};
    use crate::kernels::{run_conv, ConvVariant};

    fn run(wl: &Workload, w: u32, a: u32) -> (Vec<i64>, crate::sim::RunReport) {
        let mut m = Machine::new(ProcessorConfig::sparq(), wl.mem_bytes() * 8);
        let (prog, out) = build(&mut m, wl, w, a, RegionMode::Strict).unwrap();
        let rep = m.run(&prog).unwrap();
        (out.read_ints(&m.mem).unwrap(), rep)
    }

    #[test]
    fn gemm_path_matches_oracle_lp() {
        let d = ConvDims { c: 6, h: 9, w: 11, co: 2, fh: 3, fw: 3 };
        let wl = Workload::random(d, 3, 3, 21);
        let (got, _) = run(&wl, 3, 3);
        assert_eq!(got, golden_exact(&wl));
    }

    #[test]
    fn gemm_path_matches_oracle_ulp() {
        let d = ConvDims { c: 8, h: 8, w: 10, co: 2, fh: 3, fw: 3 };
        let wl = Workload::random(d, 2, 2, 4);
        let (got, _) = run(&wl, 2, 2);
        assert_eq!(got, golden_exact(&wl));
    }

    #[test]
    fn direct_kernel_moves_fewer_bytes_and_wins() {
        // the paper's §III-A argument, measured
        let d = ConvDims { c: 16, h: 20, w: 68, co: 2, fh: 7, fw: 7 };
        let wl = Workload::random(d, 2, 2, 9);
        let (_, gemm) = run(&wl, 2, 2);
        let direct = run_conv(
            &ProcessorConfig::sparq(),
            &wl,
            ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Strict },
        )
        .unwrap()
        .report;
        let gemm_bytes = gemm.stats.bytes_loaded + gemm.stats.bytes_stored;
        let direct_bytes = direct.stats.bytes_loaded + direct.stats.bytes_stored;
        assert!(
            gemm_bytes > 2 * direct_bytes,
            "im2col should blow up memory traffic: {gemm_bytes} vs {direct_bytes}"
        );
        assert!(
            direct.stats.cycles < gemm.stats.cycles,
            "direct {} !< gemm {}",
            direct.stats.cycles,
            gemm.stats.cycles
        );
    }
}
