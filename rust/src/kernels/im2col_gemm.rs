//! im2col + GEMM conv2d — the alternative the paper argues *against*
//! (§III-A: "the choice of a dedicated convolution algorithm over an
//! image-to-column operation followed by a GEMM is motivated by the
//! reduction of the memory footprint induced by the im2col operation").
//!
//! Implemented here so the claim is measurable: the im2col pass
//! materialises a (C/2 · Fh · Fw) x (Ho · Wo) packed column matrix in
//! DRAM, then a vmacsr GEMM consumes it.  The ablation bench compares
//! cycles *and* VLSU bytes against the direct slide-based kernel.
//!
//! im2col row (cc, ki, i) is the input plane (cc) shifted by (ki, i) —
//! with unit-stride rows this is a strided copy the VLSU can stream;
//! the GEMM is then a pure vmacsr reduction with zero slides.
//!
//! Beyond the ablation, this kernel backs the DAG compiler's `Dense`
//! node ([`crate::qnn::graph::LayerDesc::Dense`]): a fully-connected
//! head is a full-extent 'valid' conv (fh = h, fw = w, ho = wo = 1),
//! where im2col degenerates to flattening — the one shape where the
//! paper's footprint argument doesn't bite.  [`compile_in_arena`]
//! builds that form against a [`LayoutAlloc`] arena (activations
//! rebind per run, like the conv engine), and [`golden_packed_gemm`]
//! is the host-side bit-exact mirror of the GEMM's accumulation order
//! (cc → ki → i, which differs from the direct kernel's ki → c → i —
//! outside the overflow-free region the two orders wrap differently).

use super::asm::{strips, Asm};
use super::conv_engine::{EngineOpts, LayoutAlloc};
use super::pack_rt;
use super::workload::{ConvDims, OutElem, OutputRef, Workload};
use crate::arch::ProcessorConfig;
use crate::isa::{Lmul, ScalarKind, Sew, VOp, VType};
use crate::sim::{Machine, Program, SimError};
use crate::ulppack::{self, region, Container, RegionMode};

fn container_sew(cont: Container) -> Sew {
    match cont {
        Container::Lp => Sew::E16,
        Container::Ulp => Sew::E8,
    }
}

/// Emit the three passes against already-placed tensors: runtime
/// activation packing (x -> xp), the im2col strided copy (xp -> col),
/// and the vmacsr GEMM (col -> out, u32).  Shared by the one-shot
/// [`build`] and the arena-resident [`compile_in_arena`].
#[allow(clippy::too_many_arguments)]
fn emit_streams(
    a: &mut Asm,
    d: &ConvDims,
    cont: Container,
    spill_every: u64,
    wp: &[Vec<Vec<u64>>],
    opts: &EngineOpts,
    x_addr: u64,
    xp_addr: u64,
    col_addr: u64,
    out_addr: u64,
    mut hoisted_wslots: Option<&mut u64>,
) {
    let sew = container_sew(cont);
    let ew = sew.bytes() as u64;
    let (ho, wo) = (d.ho(), d.wo());
    let n = (ho * wo) as u64; // GEMM N dimension
    let cp = d.c / 2;
    let plane = d.h as u64 * d.w as u64;

    // ---- pass 1: runtime activation packing (same as the direct path)
    if opts.runtime_weight_pack {
        let slots = d.co * cp * d.fh * d.fw * 4;
        match hoisted_wslots.as_deref_mut() {
            Some(h) => *h += slots as u64,
            None => a.scalar(ScalarKind::AddrCalc, slots),
        }
    }
    pack_rt::emit_pack_activations(a, d, sew, x_addr, xp_addr);

    // ---- pass 2: im2col — stream each shifted plane row into the
    // column matrix (row-of-patches layout: K-major, N contiguous)
    let lmul_cp = a.lmul_for(2, wo as u64, sew);
    let vlmax_cp = VType::new(sew, lmul_cp).vlmax(a.vlen_bits());
    let mut krow = 0u64;
    for cc in 0..cp {
        for ki in 0..d.fh {
            for i in 0..d.fw {
                // column-matrix row (cc,ki,i) = x[cc][r+ki][q+i] over (r,q)
                for r in 0..ho {
                    let src = xp_addr
                        + (cc as u64 * plane + (r + ki) as u64 * d.w as u64 + i as u64) * ew;
                    let dst = col_addr + (krow * n + r as u64 * wo as u64) * ew;
                    for (s0, sw) in strips(wo, vlmax_cp) {
                        a.setvl(sw as u64, sew, lmul_cp);
                        a.vle(sew, 0, src + s0 as u64 * ew);
                        a.vse(sew, 0, dst + s0 as u64 * ew);
                    }
                    a.loop_overhead();
                }
                krow += 1;
            }
        }
    }

    // ---- pass 3: GEMM — out[o] = sum_k w[o][k] * col[k], vmacsr'd
    // per N-strip with a narrow accumulator + wide spills
    let lmul = Lmul::M1;
    let vlmax = VType::new(sew, lmul).vlmax(a.vlen_bits());
    // registers: acc=v0, wide=v2/3, load=v4
    for o in 0..d.co {
        for (s0, sw) in strips(n as u32, vlmax) {
            a.setvl(sw as u64, sew.widened().unwrap(), Lmul::M2);
            a.vclear(2);
            a.setvl(sw as u64, sew, lmul);
            a.vclear(0);
            let mut since = 0u64;
            let mut krow = 0u64;
            for cc in 0..cp {
                for ki in 0..d.fh {
                    for i in 0..d.fw {
                        let wv = wp[o as usize][cc as usize][(ki * d.fw + i) as usize];
                        let src = col_addr + (krow * n + s0 as u64) * ew;
                        a.vle(sew, 4, src);
                        a.vmacsr_weight(0, 4, wv);
                        krow += 1;
                        since += 1;
                        if since >= spill_every {
                            since = 0;
                            a.vv(VOp::WAdduWv, 2, 0, 0);
                            a.vclear(0);
                        }
                    }
                }
                a.loop_overhead();
            }
            // final spill + widen to u32 output
            a.vv(VOp::WAdduWv, 2, 0, 0);
            match cont {
                Container::Lp => {
                    a.setvl(sw as u64, Sew::E32, Lmul::M2);
                    a.vse(Sew::E32, 2, out_addr + (o as u64 * n + s0 as u64) * 4);
                }
                Container::Ulp => {
                    // wide is u16; widen once more through v8/v11
                    a.setvl(sw as u64, Sew::E32, Lmul::M4);
                    a.vclear(8);
                    a.setvl(sw as u64, Sew::E16, Lmul::M2);
                    a.vv(VOp::WAdduWv, 8, 2, 0);
                    a.setvl(sw as u64, Sew::E32, Lmul::M4);
                    a.vse(Sew::E32, 8, out_addr + (o as u64 * n + s0 as u64) * 4);
                }
            }
            a.loop_overhead();
        }
    }
}

/// Build the packed im2col + GEMM conv at (W, A) with `vmacsr`,
/// staging the workload's activations host-side (the one-shot
/// ablation path).
pub fn build(
    m: &mut Machine,
    wl: &Workload,
    w_bits: u32,
    a_bits: u32,
    mode: RegionMode,
) -> Result<(Program, OutputRef), SimError> {
    let d = wl.dims;
    let plan = region::plan_vmacsr(w_bits, a_bits, d.issues_per_output(), mode)
        .ok_or(SimError::Unsupported("precision pair outside every container's region"))?;
    let cont = plan.container;
    let sew = container_sew(cont);
    let ew = sew.bytes() as u64;
    let (ho, wo) = (d.ho(), d.wo());
    let n = (ho * wo) as u64;
    let cp = d.c / 2;
    let k_rows = (cp * d.fh * d.fw) as u64;

    // ---- stage tensors ----
    let plane = d.h as u64 * d.w as u64;
    let x_addr = m.mem.alloc(d.c as u64 * plane * ew, 64)?;
    for (c, row) in wl.act.iter().enumerate() {
        let base = x_addr + c as u64 * plane * ew;
        for (i, &v) in row.iter().enumerate() {
            m.mem.store_uint(base + i as u64 * ew, ew as u32, v)?;
        }
    }
    let xp_addr = m.mem.alloc(cp as u64 * plane * ew, 64)?;
    // the im2col matrix: K x N packed containers — the footprint the
    // paper's direct kernel avoids
    let col_addr = m.mem.alloc(k_rows * n * ew, 64)?;
    let out_len = (d.co * ho * wo) as usize;
    let out_addr = m.mem.alloc(out_len as u64 * 4, 64)?;
    let wp = ulppack::pack_weights(&wl.wgt, cont);

    let mut a = Asm::new(format!("{}-W{w_bits}A{a_bits}-im2col-gemm", cont.name()), m.cfg.vlen_bits);
    let opts = EngineOpts::default();
    emit_streams(&mut a, &d, cont, plan.spill_every, &wp, &opts, x_addr, xp_addr, col_addr, out_addr, None);

    let out = OutputRef { addr: out_addr, elem: OutElem::U32, len: out_len };
    Ok((a.finish(d.macs()), out))
}

/// An im2col+GEMM stage compiled against an arena: weights baked in,
/// activations written at runtime into `x` by the upstream boundary
/// stage (unpacked levels, `d.c` planes at `x_sew`).
pub(crate) struct CompiledGemm {
    pub prog: Program,
    /// u32 output, `co * ho * wo` elements — never freed (taps).
    pub out: OutputRef,
    /// The unpacked activation landing zone the boundary stage fills.
    pub x: (u64, u64),
    pub x_sew: Sew,
    /// Dead once this stage has run: the packed planes + the column
    /// matrix.  The liveness planner may hand them to later stages.
    pub scratch: Vec<(u64, u64)>,
    pub label: String,
    pub container: Container,
}

/// Compile the GEMM form against `la` without staging activations —
/// the DAG compiler's `Dense` path.  Layout mirrors [`build`]
/// (x, xp, col, out in that order); `hoisted_wslots` accumulates the
/// weight-pack AddrCalc slots into the program-wide prologue counter
/// exactly like the conv engine's hoisted mode.
pub(crate) fn compile_in_arena(
    cfg: &ProcessorConfig,
    wl: &Workload,
    w_bits: u32,
    a_bits: u32,
    mode: RegionMode,
    opts: &EngineOpts,
    la: &mut LayoutAlloc,
    hoisted_wslots: Option<&mut u64>,
) -> Result<CompiledGemm, SimError> {
    let d = wl.dims;
    let plan = region::plan_vmacsr(w_bits, a_bits, d.issues_per_output(), mode)
        .ok_or(SimError::Unsupported("precision pair outside every container's region"))?;
    let cont = plan.container;
    let sew = container_sew(cont);
    let ew = sew.bytes() as u64;
    let (ho, wo) = (d.ho(), d.wo());
    let n = (ho * wo) as u64;
    let cp = d.c / 2;
    let k_rows = (cp * d.fh * d.fw) as u64;
    let plane = d.h as u64 * d.w as u64;

    let x_bytes = d.c as u64 * plane * ew;
    let x_addr = la.alloc(x_bytes, 64);
    let xp_bytes = cp as u64 * plane * ew;
    let xp_addr = la.alloc(xp_bytes, 64);
    let col_bytes = k_rows * n * ew;
    let col_addr = la.alloc(col_bytes, 64);
    let out_len = (d.co * ho * wo) as usize;
    let out_addr = la.alloc(out_len as u64 * 4, 64);
    let wp = ulppack::pack_weights(&wl.wgt, cont);

    let label = format!("{}-W{w_bits}A{a_bits}-im2col-gemm", cont.name());
    let mut a = Asm::new(label.clone(), cfg.vlen_bits);
    emit_streams(&mut a, &d, cont, plan.spill_every, &wp, opts, x_addr, xp_addr, col_addr, out_addr, hoisted_wslots);

    Ok(CompiledGemm {
        prog: a.finish(d.macs()),
        out: OutputRef { addr: out_addr, elem: OutElem::U32, len: out_len },
        x: (x_addr, x_bytes),
        x_sew: sew,
        scratch: vec![(xp_addr, xp_bytes), (col_addr, col_bytes)],
        label,
        container: cont,
    })
}

/// Host-side bit-exact mirror of the GEMM's packed accumulation: the
/// container-wrapping narrow accumulator spilled every `spill_every`
/// issues into a wide accumulator that itself wraps at 2x the
/// container width (E16 for ULP, E32 for LP — the register the final
/// store reads).  Loop order cc -> ki -> i, matching [`emit_streams`]
/// pass 3, NOT the direct kernel's ki -> c -> i
/// (`golden_packed_vmacsr`): inside the overflow-free region both
/// reduce to the exact dot, outside it they wrap differently.
pub fn golden_packed_gemm(
    wl: &Workload,
    w_bits: u32,
    a_bits: u32,
    mode: RegionMode,
) -> Option<Vec<u64>> {
    let d = &wl.dims;
    let plan = region::plan_vmacsr(w_bits, a_bits, d.issues_per_output(), mode)?;
    let cont = plan.container;
    let spill_every = plan.spill_every;
    let s = cont.shift();
    let cmask = (1u64 << cont.bits()) - 1;
    let wmask = (1u64 << (2 * cont.bits())) - 1;
    let xp = ulppack::pack_activations(&wl.act, cont);
    let wp = ulppack::pack_weights(&wl.wgt, cont);
    let (ho, wo) = (d.ho() as usize, d.wo() as usize);
    let cp = d.c as usize / 2;
    let mut out = Vec::with_capacity(d.co as usize * ho * wo);
    for o in 0..d.co as usize {
        for r in 0..ho {
            for q in 0..wo {
                let mut wide = 0u64;
                let mut narrow = 0u64;
                let mut since = 0u64;
                for cc in 0..cp {
                    for ki in 0..d.fh as usize {
                        for i in 0..d.fw as usize {
                            let x = xp[cc][(r + ki) * d.w as usize + q + i];
                            let w = wp[o][cc][ki * d.fw as usize + i];
                            let prod = x.wrapping_mul(w) & cmask;
                            narrow = (narrow + (prod >> s)) & cmask;
                            since += 1;
                            if since >= spill_every {
                                since = 0;
                                wide = (wide + narrow) & wmask;
                                narrow = 0;
                            }
                        }
                    }
                }
                out.push((wide + narrow) & wmask);
            }
        }
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::workload::golden_exact;
    use crate::kernels::{run_conv, ConvVariant};

    fn run(wl: &Workload, w: u32, a: u32) -> (Vec<i64>, crate::sim::RunReport) {
        let mut m = Machine::new(ProcessorConfig::sparq(), wl.mem_bytes() * 8);
        let (prog, out) = build(&mut m, wl, w, a, RegionMode::Strict).unwrap();
        let rep = m.run(&prog).unwrap();
        (out.read_ints(&m.mem).unwrap(), rep)
    }

    #[test]
    fn gemm_path_matches_oracle_lp() {
        let d = ConvDims { c: 6, h: 9, w: 11, co: 2, fh: 3, fw: 3 };
        let wl = Workload::random(d, 3, 3, 21);
        let (got, _) = run(&wl, 3, 3);
        assert_eq!(got, golden_exact(&wl));
    }

    #[test]
    fn gemm_path_matches_oracle_ulp() {
        let d = ConvDims { c: 8, h: 8, w: 10, co: 2, fh: 3, fw: 3 };
        let wl = Workload::random(d, 2, 2, 4);
        let (got, _) = run(&wl, 2, 2);
        assert_eq!(got, golden_exact(&wl));
    }

    #[test]
    fn odd_channel_count_pads_with_a_zero_plane_and_matches_the_scalar_dot() {
        // 5 real input channels padded to 6 with a zero plane — the
        // oracle here is the raw scalar quantized dot over the 5 REAL
        // channels only, written out by hand: a zeroed activation
        // plane must contribute exactly nothing, whatever its weights
        let d = ConvDims { c: 6, h: 7, w: 9, co: 3, fh: 3, fw: 3 };
        let mut wl = Workload::random(d, 2, 2, 33);
        for v in wl.act[5].iter_mut() {
            *v = 0;
        }
        let (got, _) = run(&wl, 2, 2);
        let mut want = Vec::new();
        for o in 0..d.co as usize {
            for r in 0..d.ho() as usize {
                for q in 0..d.wo() as usize {
                    let mut acc = 0i64;
                    for c in 0..5usize {
                        for ki in 0..d.fh as usize {
                            for i in 0..d.fw as usize {
                                let x = wl.act[c][(r + ki) * d.w as usize + q + i] as i64;
                                let w = wl.wgt[o][c][ki * d.fw as usize + i] as i64;
                                acc += x * w;
                            }
                        }
                    }
                    want.push(acc);
                }
            }
        }
        assert_eq!(got, want);
    }

    #[test]
    fn strict_mode_golden_mirror_reduces_to_the_exact_oracle() {
        for (d, w, a, seed) in [
            (ConvDims { c: 6, h: 9, w: 11, co: 2, fh: 3, fw: 3 }, 3, 3, 21),
            (ConvDims { c: 8, h: 8, w: 10, co: 2, fh: 3, fw: 3 }, 2, 2, 4),
        ] {
            let wl = Workload::random(d, w, a, seed);
            let got: Vec<i64> = golden_packed_gemm(&wl, w, a, RegionMode::Strict)
                .unwrap()
                .into_iter()
                .map(|v| v as i64)
                .collect();
            assert_eq!(got, golden_exact(&wl));
        }
    }

    #[test]
    fn paper_mode_gemm_is_pinned_to_the_packed_golden_mirror() {
        // W4A4 exists only in Paper mode; accumulation may wrap, and
        // the host mirror must reproduce the wrap bit for bit
        let d = ConvDims { c: 8, h: 8, w: 8, co: 2, fh: 3, fw: 3 };
        let wl = Workload::random(d, 4, 4, 7);
        let mut m = Machine::new(ProcessorConfig::sparq(), wl.mem_bytes() * 8);
        let (prog, out) = build(&mut m, &wl, 4, 4, RegionMode::Paper).unwrap();
        m.run(&prog).unwrap();
        let got = out.read_ints(&m.mem).unwrap();
        let want: Vec<i64> = golden_packed_gemm(&wl, 4, 4, RegionMode::Paper)
            .unwrap()
            .into_iter()
            .map(|v| v as i64)
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn arena_compile_matches_the_one_shot_build() {
        // same streams, arena-placed: execute by hand-staging x at the
        // arena offset the boundary stage would write
        let d = ConvDims { c: 6, h: 5, w: 5, co: 4, fh: 5, fw: 5 }; // dense-like: full extent
        let wl = Workload::random(d, 4, 4, 11);
        let mut la = LayoutAlloc::new();
        let cg = compile_in_arena(
            &ProcessorConfig::sparq(),
            &wl,
            4,
            4,
            RegionMode::Paper,
            &EngineOpts::default(),
            &mut la,
            None,
        )
        .unwrap();
        let mut m = Machine::new(ProcessorConfig::sparq(), (la.brk() as usize).max(1 << 16));
        let ew = cg.x_sew.bytes() as u64;
        let plane = (d.h * d.w) as u64;
        for (c, row) in wl.act.iter().enumerate() {
            for (i, &v) in row.iter().enumerate() {
                m.mem
                    .store_uint(cg.x.0 + (c as u64 * plane + i as u64) * ew, ew as u32, v)
                    .unwrap();
            }
        }
        m.run(&cg.prog).unwrap();
        let got = cg.out.read_ints(&m.mem).unwrap();
        let want: Vec<i64> = golden_packed_gemm(&wl, 4, 4, RegionMode::Paper)
            .unwrap()
            .into_iter()
            .map(|v| v as i64)
            .collect();
        assert_eq!(got, want);
        assert_eq!(cg.out.len, d.co as usize);
        assert!(!cg.scratch.is_empty());
    }

    #[test]
    fn direct_kernel_moves_fewer_bytes_and_wins() {
        // the paper's §III-A argument, measured
        let d = ConvDims { c: 16, h: 20, w: 68, co: 2, fh: 7, fw: 7 };
        let wl = Workload::random(d, 2, 2, 9);
        let (_, gemm) = run(&wl, 2, 2);
        let direct = run_conv(
            &ProcessorConfig::sparq(),
            &wl,
            ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Strict },
        )
        .unwrap()
        .report;
        let gemm_bytes = gemm.stats.bytes_loaded + gemm.stats.bytes_stored;
        let direct_bytes = direct.stats.bytes_loaded + direct.stats.bytes_stored;
        assert!(
            gemm_bytes > 2 * direct_bytes,
            "im2col should blow up memory traffic: {gemm_bytes} vs {direct_bytes}"
        );
        assert!(
            direct.stats.cycles < gemm.stats.cycles,
            "direct {} !< gemm {}",
            direct.stats.cycles,
            gemm.stats.cycles
        );
    }
}
