//! Inter-layer requantize + repack placement — the real instruction
//! streams that move activations between chained layers of a
//! [`crate::qnn::compiled::CompiledQnn`].
//!
//! A conv layer leaves wide accumulator sums (u16 for ULP containers,
//! u32 for LP) in its dense output buffer; the next layer wants
//! zero-padded *level* tensors at its own element width.  This module
//! emits that boundary as vector code, so its cycles land in the
//! end-to-end total exactly like the runtime packing passes do:
//!
//! ```text
//! # zero-fill the whole padded destination buffer (the explicit
//! # zero-padding border — and the explicit zero channel when an odd
//! # c_in was padded to even)
//! vmv.v.i v4, 0 ; vse ... (strip loop)
//! # per channel, per row strip:
//! vle{W}   v8, src            # wide sums
//! vsrl.vx  v8, v8, rshift     # the layer's requantization shift
//! vminu.vx v8, v8, amax       # clamp into the A-bit level range
//! vnsrl.wx v0, v8, 0          # narrow W -> W/2 (skipped when N == W)
//! vse{N}   v0, dst_interior   # into the padded interior
//! ```
//!
//! The clamp runs at the wide width *before* the narrowing shift, so a
//! post-shift value that still exceeds the level range can never be
//! silently truncated — `min` then `narrow` is exact.
//!
//! The host golden model is [`requant_host`]; `qnn`'s golden network
//! applies it at every layer boundary and the cross-layer tests pin
//! the emitted stream to it bit-for-bit.

use super::asm::{strips, Asm};
use crate::isa::{Lmul, Sew, VOp, VType};

/// One layer boundary: where the producer's dense values live, where
/// the consumer's padded level tensor goes, and the requantization.
#[derive(Debug, Clone, Copy)]
pub struct RequantSpec {
    /// Producer values: dense `c x h x w` at element width `src_sew`.
    pub src: u64,
    pub src_sew: Sew,
    /// Logical dims of the producer tensor.
    pub c: u32,
    pub h: u32,
    pub w: u32,
    /// Consumer buffer: `c_pad x (h + 2*pad) x (w + 2*pad)` at
    /// `dst_sew`, zero-filled, values written to the interior.
    pub dst: u64,
    pub dst_sew: Sew,
    /// Consumer channel count (>= c; extra channels stay zero — the
    /// explicit odd-`c_in` padding channel).
    pub c_pad: u32,
    /// 'same'-conv border on each side of the interior (0 = dense).
    pub pad: u32,
    /// Requantization: `level = min(amax, value >> rshift)`.
    pub rshift: u32,
    pub amax: u64,
}

impl RequantSpec {
    pub fn dst_w(&self) -> u32 {
        self.w + 2 * self.pad
    }

    pub fn dst_h(&self) -> u32 {
        self.h + 2 * self.pad
    }

    /// Total destination elements (padded).
    pub fn dst_len(&self) -> u64 {
        self.c_pad as u64 * self.dst_h() as u64 * self.dst_w() as u64
    }
}

/// Emit the zero-fill + requantize + narrow + place stream for one
/// layer boundary.  `src_sew` must equal `dst_sew` or be its widened
/// form (one `vnsrl` step).
pub fn emit_requant(a: &mut Asm, s: &RequantSpec) {
    let ws = s.src_sew;
    let wn = s.dst_sew;
    assert!(
        ws == wn || wn.widened() == Some(ws),
        "requant narrows by at most one SEW step ({ws} -> {wn})"
    );
    assert!(s.rshift < ws.bits(), "rshift must stay below the wide element width");
    let wsb = ws.bytes() as u64;
    let wnb = wn.bytes() as u64;

    emit_zero_fill(a, s.dst, wn, s.dst_len());

    // v8 (even: the wide group a vnsrl reads spans 2 registers at M1)
    // holds the wide strip, v0 the narrowed result
    let max_strip = VType::new(ws, Lmul::M1).vlmax(a.vlen_bits()).max(1);
    let (hp, wp) = (s.dst_h() as u64, s.dst_w() as u64);
    for c in 0..s.c {
        for r in 0..s.h {
            let src_row = s.src + ((c * s.h + r) as u64 * s.w as u64) * wsb;
            let dst_row = s.dst
                + ((c as u64 * hp + (r + s.pad) as u64) * wp + s.pad as u64) * wnb;
            for (s0, sw) in strips(s.w, max_strip) {
                a.setvl(sw as u64, ws, Lmul::M1);
                a.vle(ws, 8, src_row + s0 as u64 * wsb);
                if s.rshift > 0 {
                    a.vx(VOp::Srl, 8, 8, s.rshift as u64);
                }
                a.vx(VOp::Min, 8, 8, s.amax);
                if wn == ws {
                    a.vse(ws, 8, dst_row + s0 as u64 * wnb);
                } else {
                    a.setvl(sw as u64, wn, Lmul::M1);
                    a.vx(VOp::NSrl, 0, 8, 0);
                    a.vse(wn, 0, dst_row + s0 as u64 * wnb);
                }
            }
            a.loop_overhead();
        }
        a.loop_overhead();
    }
}

/// Zero an `len`-element buffer at `sew` with vector stores (the
/// explicit padding pass — borders and padded channels become real
/// stored zeros, costed like any other store).
pub fn emit_zero_fill(a: &mut Asm, addr: u64, sew: Sew, len: u64) {
    let eb = sew.bytes() as u64;
    let lmul = Lmul::M4; // v4..v7: one wide zero group
    let max_strip = VType::new(sew, lmul).vlmax(a.vlen_bits()).max(1) as u64;
    a.setvl(max_strip.min(len), sew, lmul);
    a.vclear(4);
    let mut off = 0u64;
    while off < len {
        let n = max_strip.min(len - off);
        a.setvl(n, sew, lmul);
        a.vse(sew, 4, addr + off * eb);
        off += n;
    }
    a.loop_overhead();
}

/// Host-side golden of the requantization a boundary applies to one
/// value: `min(amax, v >> rshift)`.  Producer values are non-negative
/// by construction (levels and zero-point-offset weights).
pub fn requant_host(v: u64, rshift: u32, amax: u64) -> u64 {
    (v >> rshift).min(amax)
}

/// The deterministic per-boundary shift: large enough that the maximum
/// possible producer value lands inside the A-bit level range, so the
/// clamp only trims the tail of the distribution.
pub fn rshift_for(max_val: u64, a_bits: u32) -> u32 {
    let bits = 64 - max_val.leading_zeros();
    bits.saturating_sub(a_bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ProcessorConfig;
    use crate::sim::Machine;
    use crate::testutil::Gen;

    fn run_spec(spec: &RequantSpec, vals: &[u64]) -> Vec<u64> {
        let cfg = ProcessorConfig::sparq();
        let mut m = Machine::new(cfg.clone(), 1 << 20);
        let wsb = spec.src_sew.bytes() as u64;
        for (i, &v) in vals.iter().enumerate() {
            m.mem.store_uint(spec.src + i as u64 * wsb, wsb as u32, v).unwrap();
        }
        // poison the destination so the zero-fill is actually observed
        let wnb = spec.dst_sew.bytes() as u64;
        for i in 0..spec.dst_len() {
            m.mem.store_uint(spec.dst + i * wnb, wnb as u32, 0x55).unwrap();
        }
        let mut a = Asm::new("requant", cfg.vlen_bits);
        emit_requant(&mut a, spec);
        let prog = a.finish(0);
        m.run(&prog).unwrap();
        (0..spec.dst_len())
            .map(|i| m.mem.load_uint(spec.dst + i * wnb, wnb as u32).unwrap())
            .collect()
    }

    fn golden(spec: &RequantSpec, vals: &[u64]) -> Vec<u64> {
        let (hp, wp) = (spec.dst_h() as usize, spec.dst_w() as usize);
        let mut out = vec![0u64; spec.dst_len() as usize];
        for c in 0..spec.c as usize {
            for r in 0..spec.h as usize {
                for q in 0..spec.w as usize {
                    let v = vals[(c * spec.h as usize + r) * spec.w as usize + q];
                    out[(c * hp + r + spec.pad as usize) * wp + q + spec.pad as usize] =
                        requant_host(v, spec.rshift, spec.amax);
                }
            }
        }
        out
    }

    #[test]
    fn narrowing_requant_with_padding_matches_host() {
        // E32 sums -> E16 levels, 1-wide border, one extra zero channel
        let spec = RequantSpec {
            src: 0x1000,
            src_sew: Sew::E32,
            c: 3,
            h: 5,
            w: 7,
            dst: 0x8000,
            dst_sew: Sew::E16,
            c_pad: 4,
            pad: 1,
            rshift: 6,
            amax: 15,
        };
        let mut g = Gen::new(0xCAFE);
        let vals: Vec<u64> = (0..spec.c * spec.h * spec.w).map(|_| g.below(1 << 12)).collect();
        assert_eq!(run_spec(&spec, &vals), golden(&spec, &vals));
    }

    #[test]
    fn same_width_requant_dense_matches_host() {
        // E16 -> E16 (the int16 stem feeding an LP layer), no padding
        let spec = RequantSpec {
            src: 0x1000,
            src_sew: Sew::E16,
            c: 2,
            h: 4,
            w: 9,
            dst: 0x4000,
            dst_sew: Sew::E16,
            c_pad: 2,
            pad: 0,
            rshift: 3,
            amax: 7,
        };
        let mut g = Gen::new(7);
        let vals: Vec<u64> = (0..spec.c * spec.h * spec.w).map(|_| g.below(1 << 14)).collect();
        assert_eq!(run_spec(&spec, &vals), golden(&spec, &vals));
    }

    #[test]
    fn clamp_happens_before_the_narrowing_shift() {
        // a value whose shifted form exceeds the narrow width must
        // clamp to amax, not wrap through the vnsrl truncation
        let spec = RequantSpec {
            src: 0x1000,
            src_sew: Sew::E16,
            c: 1,
            h: 1,
            w: 4,
            dst: 0x2000,
            dst_sew: Sew::E8,
            c_pad: 1,
            pad: 0,
            rshift: 2,
            amax: 3,
        };
        let vals = [0xFFFF, 0x0400, 3, 12];
        let got = run_spec(&spec, &vals);
        assert_eq!(got, vec![3, 3, 0, 3]);
    }

    #[test]
    fn rshift_for_keeps_max_in_range() {
        for (max, a) in [(6858u64, 2u32), (2592, 2), (107_000, 4), (3, 2), (1, 8)] {
            let sh = rshift_for(max, a);
            assert!(max >> sh <= (1 << a) - 1 || max < (1 << a), "max={max} a={a} sh={sh}");
        }
        assert_eq!(rshift_for(0, 4), 0);
    }
}
