//! The config system: an INI-flavoured `[section]` + `key = value`
//! format (the vendored crate set has no serde/toml, so the parser is
//! in-repo — ~100 lines, fully tested).
//!
//! ```text
//! [processor]
//! preset = sparq          # ara | sparq | sparq-cfgshift
//! lanes = 4
//! vlen_bits = 4096
//! fpu = false
//! vmacsr = true
//!
//! [serve]
//! workers = 2
//! batch_window_us = 500
//! queue_depth = 256
//! ring_frames = 0          # 0 = derive from queue_depth / batch
//! deadline_us = 0          # 0 = no default per-request deadline
//! restart_budget = 8       # supervisor respawns before degraded
//! restart_backoff_us = 200 # base respawn backoff (doubles per failure)
//! breaker_threshold = 3    # consecutive shard errors before ejection
//! probation_us = 50000     # how long an ejected shard sits out
//! cores = 1                # simulated cores per dispatched batch frame
//! work_steal = false       # work-stealing shard policy (default round-robin)
//! ```

use crate::arch::ProcessorConfig;
use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ConfigError {
    Syntax(usize),
    BadValue { section: String, key: String, value: String },
    UnknownPreset(String),
    Io(String),
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::Syntax(line) => write!(f, "line {line}: expected 'key = value'"),
            ConfigError::BadValue { section, key, value } => {
                write!(f, "[{section}] {key}: invalid value '{value}'")
            }
            ConfigError::UnknownPreset(p) => {
                write!(f, "unknown preset '{p}' (ara | sparq | sparq-cfgshift)")
            }
            ConfigError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// Parsed config: section -> key -> value.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut cfg = Config::default();
        let mut section = String::from("global");
        for (ln, raw) in text.lines().enumerate() {
            let line = match raw.find('#') {
                Some(i) => &raw[..i],
                None => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') && line.ends_with(']') {
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or(ConfigError::Syntax(ln + 1))?;
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), v.trim().to_string());
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<Config, ConfigError> {
        let text = std::fs::read_to_string(path).map_err(|e| ConfigError::Io(e.to_string()))?;
        Config::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    fn typed<T: std::str::FromStr>(&self, section: &str, key: &str) -> Result<Option<T>, ConfigError> {
        match self.get(section, key) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|_| ConfigError::BadValue {
                section: section.into(),
                key: key.into(),
                value: v.into(),
            }),
        }
    }

    pub fn get_u32(&self, section: &str, key: &str) -> Result<Option<u32>, ConfigError> {
        self.typed(section, key)
    }

    pub fn get_u64(&self, section: &str, key: &str) -> Result<Option<u64>, ConfigError> {
        self.typed(section, key)
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Result<Option<bool>, ConfigError> {
        match self.get(section, key) {
            None => Ok(None),
            Some("true") | Some("1") | Some("yes") => Ok(Some(true)),
            Some("false") | Some("0") | Some("no") => Ok(Some(false)),
            Some(v) => Err(ConfigError::BadValue {
                section: section.into(),
                key: key.into(),
                value: v.into(),
            }),
        }
    }

    /// Build the processor config from the `[processor]` section
    /// (preset first, then field overrides).
    pub fn processor(&self) -> Result<ProcessorConfig, ConfigError> {
        let mut p = match self.get("processor", "preset").unwrap_or("sparq") {
            "ara" => ProcessorConfig::ara(),
            "sparq" => ProcessorConfig::sparq(),
            "sparq-cfgshift" => ProcessorConfig::sparq_cfgshift(),
            other => return Err(ConfigError::UnknownPreset(other.into())),
        };
        if let Some(lanes) = self.get_u32("processor", "lanes")? {
            p = p.with_lanes(lanes);
        }
        if let Some(v) = self.get_u32("processor", "vlen_bits")? {
            p.vlen_bits = v;
        }
        if let Some(v) = self.get_bool("processor", "fpu")? {
            p.fpu = v;
        }
        if let Some(v) = self.get_bool("processor", "vmacsr")? {
            p.vmacsr = v;
        }
        if let Some(v) = self.get_u32("processor", "mem_bytes_per_cycle")? {
            p.mem_bytes_per_cycle = v;
        }
        if let Some(v) = self.get_u32("processor", "issue_bubble")? {
            p.issue_bubble = v;
        }
        Ok(p)
    }

    /// `[serve]` parameters with defaults.
    pub fn serve(&self) -> Result<ServeConfig, ConfigError> {
        Ok(ServeConfig {
            workers: self.get_u32("serve", "workers")?.unwrap_or(1) as usize,
            batch_window_us: self.get_u64("serve", "batch_window_us")?.unwrap_or(500),
            queue_depth: self.get_u32("serve", "queue_depth")?.unwrap_or(256) as usize,
            ring_frames: self.get_u32("serve", "ring_frames")?.unwrap_or(0) as usize,
            batch: self.get_u32("serve", "batch")?.unwrap_or(4) as usize,
            deadline_us: self.get_u64("serve", "deadline_us")?.unwrap_or(0),
            restart_budget: self.get_u32("serve", "restart_budget")?.unwrap_or(8),
            restart_backoff_us: self.get_u64("serve", "restart_backoff_us")?.unwrap_or(200),
            breaker_threshold: self.get_u32("serve", "breaker_threshold")?.unwrap_or(3),
            probation_us: self.get_u64("serve", "probation_us")?.unwrap_or(50_000),
            cores: self.get_u32("serve", "cores")?.unwrap_or(1) as usize,
            work_steal: self.get_bool("serve", "work_steal")?.unwrap_or(false),
        })
    }
}

/// Serving-stack knobs (see `coordinator`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeConfig {
    pub workers: usize,
    pub batch_window_us: u64,
    pub queue_depth: usize,
    /// Batch frames in the lock-free front-door ring
    /// (`coordinator::ring::BatchRing`; rounded up to a power of two).
    /// `0` derives the frame count from `queue_depth / batch` so the
    /// ring carries the same rider budget as the old sharded queues.
    pub ring_frames: usize,
    /// Activation slots per batched execution
    /// (`coordinator::QnnBatchServer`; clamped to the compiled
    /// `MAX_BATCH`).  The generic executor path takes its batch from
    /// the executor instead.
    pub batch: usize,
    /// Default per-request deadline in microseconds; `0` disables it.
    /// `submit_with_deadline` overrides per request.
    pub deadline_us: u64,
    /// How many worker respawns the supervisor may spend over the
    /// server's lifetime before it declares the pool degraded.
    pub restart_budget: u32,
    /// Base backoff between respawn attempts for one worker slot,
    /// microseconds (doubles per consecutive failure, capped).
    pub restart_backoff_us: u64,
    /// Consecutive failed batches on one shard before the circuit
    /// breaker ejects it (`QnnBatchServer`); `0` disables the breaker.
    pub breaker_threshold: u32,
    /// How long an ejected shard sits out before it is probed again,
    /// microseconds.
    pub probation_us: u64,
    /// Simulated cores per dispatched batch frame
    /// (`coordinator::cluster::QnnCluster`): each sealed frame is
    /// sharded across this many per-core machine pools executing
    /// host-parallel.  `1` (the default) is the plain batched path.
    pub cores: usize,
    /// Use the work-stealing shard policy instead of static
    /// round-robin (outputs identical; core assignment — and thus the
    /// per-core cycles account — becomes load-dependent).
    pub work_steal: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 1,
            batch_window_us: 500,
            queue_depth: 256,
            ring_frames: 0,
            batch: 4,
            deadline_us: 0,
            restart_budget: 8,
            restart_backoff_us: 200,
            breaker_threshold: 3,
            probation_us: 50_000,
            cores: 1,
            work_steal: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
[processor]
preset = ara
lanes = 8        # inline comment
vmacsr = true

[serve]
workers = 3
queue_depth = 64
";

    #[test]
    fn parses_sections_and_comments() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get("processor", "preset"), Some("ara"));
        assert_eq!(c.get_u32("processor", "lanes").unwrap(), Some(8));
        assert_eq!(c.get("nope", "x"), None);
    }

    #[test]
    fn builds_processor_with_overrides() {
        let c = Config::parse(SAMPLE).unwrap();
        let p = c.processor().unwrap();
        assert_eq!(p.lanes, 8);
        assert!(p.fpu); // ara preset
        assert!(p.vmacsr); // overridden
        assert_eq!(p.vlen_bits, 8192); // scaled by with_lanes
    }

    #[test]
    fn serve_defaults_and_overrides() {
        let c = Config::parse(SAMPLE).unwrap();
        let s = c.serve().unwrap();
        assert_eq!(s.workers, 3);
        assert_eq!(s.queue_depth, 64);
        assert_eq!(s.batch_window_us, 500); // default
        assert_eq!(s.ring_frames, 0); // default: derived from queue_depth
        assert_eq!(s.batch, 4); // default
        assert_eq!(s.deadline_us, 0); // default: no deadline
        assert_eq!(s.restart_budget, 8);
        assert_eq!(s.restart_backoff_us, 200);
        assert_eq!(s.breaker_threshold, 3);
        assert_eq!(s.probation_us, 50_000);
        assert_eq!(s.cores, 1); // default: plain batched path
        assert!(!s.work_steal); // default: round-robin sharding
        let c = Config::parse(
            "[serve]\nbatch = 8\nring_frames = 32\ndeadline_us = 2000\nrestart_budget = 2\n\
             restart_backoff_us = 500\nbreaker_threshold = 5\nprobation_us = 10000\n\
             cores = 4\nwork_steal = true",
        )
        .unwrap();
        let s = c.serve().unwrap();
        assert_eq!(s.batch, 8);
        assert_eq!(s.ring_frames, 32);
        assert_eq!(s.deadline_us, 2000);
        assert_eq!(s.restart_budget, 2);
        assert_eq!(s.restart_backoff_us, 500);
        assert_eq!(s.breaker_threshold, 5);
        assert_eq!(s.probation_us, 10_000);
        assert_eq!(s.cores, 4);
        assert!(s.work_steal);
    }

    #[test]
    fn error_cases() {
        assert_eq!(Config::parse("junk line").unwrap_err(), ConfigError::Syntax(1));
        let c = Config::parse("[processor]\npreset = turbo").unwrap();
        assert!(matches!(c.processor(), Err(ConfigError::UnknownPreset(_))));
        let c = Config::parse("[processor]\nlanes = many").unwrap();
        assert!(matches!(c.processor(), Err(ConfigError::BadValue { .. })));
        let c = Config::parse("[processor]\nfpu = maybe").unwrap();
        assert!(matches!(c.processor(), Err(ConfigError::BadValue { .. })));
    }

    #[test]
    fn empty_config_gives_sparq_defaults() {
        let c = Config::parse("").unwrap();
        let p = c.processor().unwrap();
        assert_eq!(p.name, "sparq");
        assert!(!p.fpu && p.vmacsr);
    }
}
