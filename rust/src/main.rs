//! `sparq` — the CLI.  Subcommands map one-to-one onto the paper's
//! experiments (see DESIGN.md §5) plus the serving stack:
//!
//! ```text
//! sparq fig4 [--large] [--seed N]          ops/cycle bar chart (Fig. 4)
//! sparq fig5 [--native|--vmacsr] [--large] speedup grids (Fig. 5a/5b)
//! sparq table1 [--artifacts DIR]           QNN accuracy (Table I)
//! sparq table2                             lane area/power/fmax (Table II)
//! sparq utilization [--large]              §III-A lane utilization
//! sparq qnn-cycles [--precision wXaY|fp32] per-layer schedule
//! sparq serve [--requests N] [--config F]  batched serving demo
//! sparq isa [WORD...]                      encode/decode explorer
//! ```

use std::process::ExitCode;

use sparq::config::Config;
use sparq::qnn::schedule::QnnPrecision;
use sparq::report;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if args.is_empty() { &[][..] } else { &args[1..] };
    let r = match cmd {
        "fig4" => cmd_fig4(rest),
        "fig5" => cmd_fig5(rest),
        "table1" => cmd_table1(rest),
        "table2" => cmd_table2(),
        "utilization" => cmd_utilization(rest),
        "qnn-cycles" => cmd_qnn_cycles(rest),
        "serve" => cmd_serve(rest),
        "bench-check" => cmd_bench_check(rest),
        "isa" => cmd_isa(rest),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n{HELP}")),
    };
    match r {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

const HELP: &str = "\
sparq — reproduction of 'Sparq: A Custom RISC-V Vector Processor for
Efficient Sub-Byte Quantized Inference' (Dupuis et al., 2023)

USAGE: sparq <command> [flags]

COMMANDS
  fig4         ops/cycle for every conv2d implementation     [--large] [--seed N]
  fig5         speedup grid over the precision region        [--native|--vmacsr|--both] [--large]
  table1       QNN accuracy via the PJRT artifacts           [--artifacts DIR]
  table2       lane area / power / fmax model (Ara vs Sparq)
  utilization  MFPU utilization of the baselines             [--large]
  qnn-cycles   per-layer simulated schedule                  [--precision wXaY|fp32] [--ladder]
               (--ladder sweeps W1A1..W4A4, mixed stem/head, and the
               resnetlike/mobilenetlike/denselike DAG rungs, autotuned)
  serve        batched serving demo (PJRT artifacts, or the  [--requests N] [--model NAME] [--config FILE]
               cached-program simulator backend without them) [--precision wXaY|mixed] [--batch B]
               (--batch B serves through the batch-B compiled arena behind a    [--topology T] [--ring-frames R]
               lock-free slot-reservation ring: producers CAS into the open     [--deadline-us D] [--chaos-seed S]
               batch frame, frames seal on fill or window expiry, any worker    [--cores K] [--work-steal]
               dispatches — fill/seal/queue metrics; --ring-frames R sizes the
               ring (0 derives it from queue_depth / batch);
               --topology chain|resnetlike|mobilenetlike|denselike picks the
               simulated network graph — DAG topologies compile to the same
               one-program liveness-planned arena as the chain;
               --deadline-us D sheds requests older than D typed, --chaos-seed S
               injects a replayable storm of worker faults on the simulator
               backend to demo supervision/failover — see DESIGN.md §Robustness;
               --cores K shards each dispatched batch frame across a K-core
               cluster executing host-parallel (deterministic max-over-cores
               makespan; with --chaos-seed a second derived storm targets
               individual cores), --work-steal swaps the round-robin shard
               policy for work stealing — see DESIGN.md §Cluster)
  bench-check  compare BENCH_*.json against the committed     [--baselines DIR] [--bless]
               cycle baselines (tolerance 0 on cycle fields; CI gate)
  isa          vmacsr encoding explorer                      [hex words...]
";

fn flag(rest: &[String], name: &str) -> bool {
    rest.iter().any(|a| a == name)
}

fn opt<'a>(rest: &'a [String], name: &str) -> Option<&'a str> {
    rest.iter().position(|a| a == name).and_then(|i| rest.get(i + 1)).map(|s| s.as_str())
}

fn seed_of(rest: &[String]) -> u64 {
    opt(rest, "--seed").and_then(|s| s.parse().ok()).unwrap_or(42)
}

fn cmd_fig4(rest: &[String]) -> Result<(), String> {
    let large = flag(rest, "--large");
    let rows = report::fig4(large, seed_of(rest)).map_err(|e| e.to_string())?;
    print!("{}", report::render_fig4(&rows, sparq::kernels::ConvDims::fig4(large)));
    Ok(())
}

fn cmd_fig5(rest: &[String]) -> Result<(), String> {
    let large = flag(rest, "--large");
    let both = flag(rest, "--both") || (!flag(rest, "--native") && !flag(rest, "--vmacsr"));
    let dims = sparq::kernels::ConvDims::fig5(large);
    if flag(rest, "--native") || both {
        let cells = report::fig5(false, large, seed_of(rest)).map_err(|e| e.to_string())?;
        print!("{}", report::render_fig5(&cells, false, dims));
        println!();
    }
    if flag(rest, "--vmacsr") || both {
        let cells = report::fig5(true, large, seed_of(rest)).map_err(|e| e.to_string())?;
        print!("{}", report::render_fig5(&cells, true, dims));
    }
    Ok(())
}

fn cmd_table2() -> Result<(), String> {
    let (ara, sq) = report::table2();
    print!("{}", report::render_table2(&ara, &sq));
    Ok(())
}

fn cmd_utilization(rest: &[String]) -> Result<(), String> {
    let large = flag(rest, "--large");
    let rows = report::utilization(large, seed_of(rest)).map_err(|e| e.to_string())?;
    print!("{}", report::render_utilization(&rows, large));
    Ok(())
}

fn cmd_table1(rest: &[String]) -> Result<(), String> {
    let dir = opt(rest, "--artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(sparq::runtime::artifacts_dir);
    if !dir.join("manifest.txt").exists() {
        return Err(format!("no artifacts at {} — run `make artifacts` first", dir.display()));
    }
    let rt = sparq::runtime::Runtime::load(&dir).map_err(|e| e.to_string())?;
    let ts = sparq::runtime::TestSet::load(dir.join("testset.bin")).map_err(|e| e.to_string())?;
    let mut rows = Vec::new();
    let mut fp32_acc = None;
    for name in ["qnn_fp32", "qnn_w4a4", "qnn_w3a3", "qnn_w2a2"] {
        let art = rt.manifest.artifact(name).ok_or(format!("{name} missing from manifest"))?;
        let batch = art.meta_u32("batch").unwrap_or(16) as usize;
        let acc = evaluate(&rt, name, &ts, batch)?;
        if name == "qnn_fp32" {
            fp32_acc = Some(acc);
        }
        let delta = acc - fp32_acc.unwrap();
        rows.push((name.trim_start_matches("qnn_").to_string(), acc, delta));
    }
    print!("{}", report::render_table1(&rows));
    Ok(())
}

/// Evaluate one artifact over the whole test set; returns accuracy.
fn evaluate(
    rt: &sparq::runtime::Runtime,
    model: &str,
    ts: &sparq::runtime::TestSet,
    batch: usize,
) -> Result<f64, String> {
    let dims = [batch as i64, ts.c as i64, ts.h as i64, ts.w as i64];
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut start = 0;
    while start < ts.n {
        let (data, real) = ts.batch(start, batch);
        let logits = rt.exec_f32(model, &[(&data, &dims)]).map_err(|e| e.to_string())?;
        let classes = logits.len() / batch;
        for i in 0..real {
            let row = &logits[i * classes..(i + 1) * classes];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(k, _)| k)
                .unwrap();
            correct += (pred == ts.labels[start + i] as usize) as usize;
            total += 1;
        }
        start += batch;
    }
    Ok(correct as f64 / total as f64)
}

fn cmd_qnn_cycles(rest: &[String]) -> Result<(), String> {
    if flag(rest, "--ladder") {
        let ctx = report::SweepCtx::new();
        let rows = report::precision_ladder(&ctx).map_err(|e| e.to_string())?;
        let fmax = sparq::power::LaneReport::for_config(&sparq::ProcessorConfig::sparq()).fmax_ghz();
        print!("{}", report::render_ladder(&rows, fmax));
        return Ok(());
    }
    let prec = match opt(rest, "--precision").unwrap_or("w2a2") {
        "fp32" => QnnPrecision::Fp32,
        s => {
            let s = s.trim_start_matches('w');
            let (w, a) = s.split_once('a').ok_or("precision must be fp32 or wXaY")?;
            QnnPrecision::SubByte {
                w_bits: w.parse().map_err(|_| "bad W bits")?,
                a_bits: a.parse().map_err(|_| "bad A bits")?,
            }
        }
    };
    let cfg = match prec {
        QnnPrecision::Fp32 => sparq::ProcessorConfig::ara(),
        _ => sparq::ProcessorConfig::sparq(),
    };
    let sched = report::qnn_schedule(&cfg, prec).map_err(|e| e.to_string())?;
    let fmax = sparq::power::LaneReport::for_config(&cfg).fmax_ghz();
    print!("{}", report::render_schedule(&sched, fmax));
    Ok(())
}

/// Serve a whole network on the simulator backend: the graph picked
/// by `--topology` (the SparqCNN chain by default, or the residual /
/// depthwise / dense-head DAGs) is compiled once into one multi-layer
/// dataflow program (shared program cache, graph-level key) and every
/// request classifies through it end-to-end on a per-worker machine
/// pool (no artifacts, no PJRT).  `--batch B` switches to the batched
/// request path (`coordinator::QnnBatchServer`): a batch-B arena fed
/// by the lock-free slot-reservation ring, one batched execution per
/// sealed frame.
fn cmd_serve_sim(rest: &[String]) -> Result<(), String> {
    use sparq::kernels::ProgramCache;
    use sparq::qnn::QnnGraph;
    use std::sync::Arc;

    let n: usize = opt(rest, "--requests").and_then(|s| s.parse().ok()).unwrap_or(64);
    let mut serve_cfg = match opt(rest, "--config") {
        Some(f) => Config::load(f).map_err(|e| e.to_string())?.serve().map_err(|e| e.to_string())?,
        None => sparq::config::ServeConfig::default(),
    };
    let batched = flag(rest, "--batch");
    if let Some(b) = opt(rest, "--batch") {
        serve_cfg.batch = b.parse().map_err(|_| "bad --batch value")?;
        if serve_cfg.batch == 0 {
            return Err("--batch must be at least 1".into());
        }
    }
    if let Some(d) = opt(rest, "--deadline-us") {
        serve_cfg.deadline_us = d.parse().map_err(|_| "bad --deadline-us value")?;
    }
    if let Some(r) = opt(rest, "--ring-frames") {
        serve_cfg.ring_frames = r.parse().map_err(|_| "bad --ring-frames value")?;
    }
    if let Some(c) = opt(rest, "--cores") {
        serve_cfg.cores = c.parse().map_err(|_| "bad --cores value")?;
        if serve_cfg.cores == 0 {
            return Err("--cores must be at least 1".into());
        }
    }
    if flag(rest, "--work-steal") {
        serve_cfg.work_steal = true;
    }
    // A seeded storm of injected worker faults (kills, panics, errors,
    // delays) — the same seed replays the same fault sequence, so the
    // demo doubles as a reproducible supervision/failover exercise.
    // With a multi-core cluster, a second storm derived from the same
    // seed targets individual cores (batched path only).
    let (plan, core_plan): (
        Option<Arc<sparq::coordinator::FaultPlan>>,
        Option<Arc<sparq::coordinator::FaultPlan>>,
    ) = match opt(rest, "--chaos-seed") {
        Some(s) => {
            let chaos_seed: u64 = s.parse().map_err(|_| "bad --chaos-seed value")?;
            let worker = Some(Arc::new(sparq::coordinator::FaultPlan::seeded(
                chaos_seed,
                sparq::coordinator::ChaosSpec::storm(),
            )));
            let core = (serve_cfg.cores > 1).then(|| {
                Arc::new(sparq::coordinator::FaultPlan::seeded(
                    chaos_seed ^ 0xC0DE_C0DE_C0DE_C0DE,
                    sparq::coordinator::ChaosSpec::storm(),
                ))
            });
            (worker, core)
        }
        None => (None, None),
    };
    // "mixed" = the W4A4 stem-adjacent / W2A2 deep configuration: the
    // per-layer overrides flow through the same autotuned dataflow
    // compiler as the uniform precisions.  Uniform precisions parse
    // the generic wXaY form (same syntax `qnn-cycles` accepts); bad
    // strings error instead of silently serving a default.
    // `--topology` swaps the served network graph — the residual,
    // depthwise and dense-head DAGs compile through the same cached
    // one-program path as the chain
    let prec_arg = opt(rest, "--precision").unwrap_or("w2a2");
    let topo = opt(rest, "--topology").unwrap_or("chain");
    let (graph, precision) = if prec_arg == "mixed" {
        if topo != "chain" {
            return Err("--precision mixed applies to the chain topology only".into());
        }
        (
            QnnGraph::sparq_cnn_mixed((4, 4), (2, 2)),
            QnnPrecision::SubByte { w_bits: 2, a_bits: 2 },
        )
    } else {
        let s = prec_arg.trim_start_matches('w');
        let (w, a) =
            s.split_once('a').ok_or("serve precision must be 'mixed' or wXaY (e.g. w2a2)")?;
        let precision = QnnPrecision::SubByte {
            w_bits: w.parse().map_err(|_| "bad W bits")?,
            a_bits: a.parse().map_err(|_| "bad A bits")?,
        };
        let graph = match topo {
            "chain" => QnnGraph::sparq_cnn(),
            "resnetlike" => QnnGraph::sparq_resnetlike(),
            "mobilenetlike" => QnnGraph::sparq_mobilenetlike(),
            "denselike" => QnnGraph::sparq_denselike(),
            other => {
                return Err(format!(
                    "unknown --topology '{other}' \
                     (expected chain, resnetlike, mobilenetlike or denselike)"
                ))
            }
        };
        (graph, precision)
    };
    let cfg = sparq::ProcessorConfig::sparq();
    let cache = Arc::new(ProgramCache::new());
    let seed = sparq::qnn::schedule::DEFAULT_QNN_SEED;

    if batched {
        return cmd_serve_sim_batched(
            &cfg, &graph, precision, seed, serve_cfg, &cache, n, prec_arg, topo, plan, core_plan,
        );
    }

    // per-image hardware cost from the same compiled network
    let cyc = {
        let pool = sparq::sim::MachinePool::new();
        sparq::qnn::schedule::schedule_seeded(&cfg, &graph, precision, seed, &cache, &pool)
            .map_err(|e| e.to_string())?
            .total_cycles()
    };

    let mut factory = sparq::coordinator::sim_qnn_factory(
        cfg.clone(),
        graph.clone(),
        precision,
        4,
        seed,
        Arc::clone(&cache),
    );
    if let Some(p) = &plan {
        factory = sparq::coordinator::chaos_factory(factory, Arc::clone(p));
    }
    let server =
        sparq::coordinator::Server::start(factory, serve_cfg, cyc).map_err(|e| e.to_string())?;

    println!(
        "serving the {topo} network at {} on the simulated dataflow backend \
         ({cyc} cycles/image), {} worker(s), {n} requests...",
        if prec_arg == "mixed" { "mixed W4A4-stem/W2A2".to_string() } else { precision.label() },
        serve_cfg.workers
    );
    let (ic, ih, iw) = graph.input;
    let image_len = (ic * ih * iw) as usize;
    let mut pending = Vec::new();
    let mut served = 0usize;
    for i in 0..n {
        let image: Vec<f32> =
            (0..image_len).map(|k| ((k as u64 * 31 + i as u64) % 4) as f32).collect();
        match server.submit(image) {
            Ok(rx) => pending.push(rx),
            Err(e) => println!("request {i}: {e}"),
        }
        if pending.len() >= 32 {
            for rx in pending.drain(..) {
                served += matches!(rx.recv(), Ok(Ok(_))) as usize;
            }
        }
    }
    for rx in pending.drain(..) {
        served += matches!(rx.recv(), Ok(Ok(_))) as usize;
    }
    let health = server.health();
    let snap = server.shutdown();
    let cs = cache.stats();
    println!(
        "done: {served}/{n} served\n  latency p50/p95/p99: {}/{}/{} us\n  mean batch {:.1}, throughput {:.0} req/s, {} worker errors\n  program cache: {} compile(s) shared by {} worker(s) ({} cache hits) for {served} network inferences",
        snap.p50_us,
        snap.p95_us,
        snap.p99_us,
        snap.mean_batch,
        snap.throughput_rps,
        snap.errors,
        cs.misses,
        serve_cfg.workers.max(1),
        cs.hits,
    );
    println!(
        "  robustness: {} restart(s) (budget left {}), {} deadline-shed, {} bad-input, {} fast-failed{}",
        health.restarts,
        health.restart_budget_left,
        snap.deadline_shed,
        snap.bad_input,
        snap.no_workers,
        if health.degraded { " — pool DEGRADED" } else { "" },
    );
    Ok(())
}

/// The batched request path: batch-B arena compilation + the
/// lock-free slot-reservation ring front door
/// ([`sparq::coordinator::QnnBatchServer`]).  Prints the serving
/// metrics — batch-fill histogram, full-vs-window seal split,
/// queue-depth high-water, latency percentiles in wall time AND
/// simulated cycles.
#[allow(clippy::too_many_arguments)]
fn cmd_serve_sim_batched(
    cfg: &sparq::ProcessorConfig,
    graph: &sparq::qnn::QnnGraph,
    precision: QnnPrecision,
    seed: u64,
    serve_cfg: sparq::config::ServeConfig,
    cache: &sparq::kernels::ProgramCache,
    n: usize,
    prec_arg: &str,
    topo: &str,
    plan: Option<std::sync::Arc<sparq::coordinator::FaultPlan>>,
    core_plan: Option<std::sync::Arc<sparq::coordinator::FaultPlan>>,
) -> Result<(), String> {
    let server = sparq::coordinator::QnnBatchServer::start_chaos_cores(
        cfg.clone(),
        graph,
        precision,
        seed,
        serve_cfg,
        cache,
        plan,
        core_plan,
    )
    .map_err(|e| e.to_string())?;
    println!(
        "serving the {topo} network at {} through the batch-{} arena \
         ({} worker(s) on a {}-frame ring, window {} us; {}-core cluster, {} sharding), \
         {n} requests...",
        if prec_arg == "mixed" { "mixed W4A4-stem/W2A2".to_string() } else { precision.label() },
        server.batch(),
        serve_cfg.workers.max(1),
        server.ring_frames(),
        serve_cfg.batch_window_us,
        server.cores(),
        server.shard_policy().label(),
    );
    let image_len = server.image_len();
    let mut pending = Vec::new();
    let mut served = 0usize;
    let mut rejected = 0usize;
    for i in 0..n {
        let image: Vec<f32> =
            (0..image_len).map(|k| ((k as u64 * 31 + i as u64) % 4) as f32).collect();
        match server.submit(image) {
            Ok(rx) => pending.push(rx),
            Err(e) => {
                rejected += 1;
                println!("request {i}: {e}");
            }
        }
        if pending.len() >= 32 {
            for rx in pending.drain(..) {
                served += matches!(rx.recv(), Ok(Ok(_))) as usize;
            }
        }
    }
    for rx in pending.drain(..) {
        served += matches!(rx.recv(), Ok(Ok(_))) as usize;
    }
    let health = server.health();
    let policy_label = server.shard_policy().label();
    let snap = server.shutdown();
    let cs = cache.stats();
    let fills: Vec<String> =
        snap.batch_fill.iter().map(|&(k, c)| format!("{k}x{c}")).collect();
    println!(
        "done: {served}/{n} served, {rejected} rejected (typed backpressure)\n  \
         latency p50/p95/p99: {}/{}/{} us | p50/p99 sim cycles: {}/{}\n  \
         {} batches (fill histogram: {}; {} sealed full, {} by window), queue depth max {}\n  \
         program cache: {} compile(s), {} hits for {served} batched inferences",
        snap.p50_us,
        snap.p95_us,
        snap.p99_us,
        snap.p50_cycles,
        snap.p99_cycles,
        snap.batches,
        if fills.is_empty() { "-".to_string() } else { fills.join(" ") },
        snap.seals_full,
        snap.seals_window,
        snap.queue_depth_max,
        cs.misses,
        cs.hits,
    );
    println!(
        "  robustness: {}/{} shard(s) up, {} failover retr{}, {} breaker trip(s), \
         {} deadline-shed, {} bad-input, {} fast-failed",
        health.alive,
        health.shards.len(),
        snap.retries,
        if snap.retries == 1 { "y" } else { "ies" },
        snap.breaker_trips,
        snap.deadline_shed,
        snap.bad_input,
        snap.no_workers,
    );
    println!(
        "  cluster: {}/{} core(s) up ({} sharding), {} core failure(s)",
        health.cores_alive,
        health.cores.len(),
        policy_label,
        snap.core_failures,
    );
    Ok(())
}

fn cmd_serve(rest: &[String]) -> Result<(), String> {
    let dir = opt(rest, "--artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(sparq::runtime::artifacts_dir);
    // the decision honours --artifacts: backend compiled in + a
    // manifest in the *requested* directory, else simulator serving
    if !sparq::runtime::backend_available() || !dir.join("manifest.txt").exists() {
        println!(
            "no executable PJRT artifacts at {} — falling back to the simulator serving backend",
            dir.display()
        );
        return cmd_serve_sim(rest);
    }
    if flag(rest, "--batch") {
        // the batch-B arena is a simulator-backend feature; the PJRT
        // path batches at the artifact's static batch size — say so
        // instead of silently ignoring the flag
        println!(
            "note: --batch applies to the simulator serving backend only; \
             the PJRT path batches at the artifact's static batch dimension"
        );
    }
    let model = opt(rest, "--model").unwrap_or("qnn_w4a4").to_string();
    let n: usize = opt(rest, "--requests").and_then(|s| s.parse().ok()).unwrap_or(256);
    let mut serve_cfg = match opt(rest, "--config") {
        Some(f) => Config::load(f).map_err(|e| e.to_string())?.serve().map_err(|e| e.to_string())?,
        None => sparq::config::ServeConfig::default(),
    };
    if let Some(d) = opt(rest, "--deadline-us") {
        serve_cfg.deadline_us = d.parse().map_err(|_| "bad --deadline-us value")?;
    }

    // hardware-cost attribution from the simulator
    let prec = match model.as_str() {
        "qnn_fp32" => QnnPrecision::Fp32,
        "qnn_w3a3" => QnnPrecision::SubByte { w_bits: 3, a_bits: 3 },
        "qnn_w2a2" => QnnPrecision::SubByte { w_bits: 2, a_bits: 2 },
        _ => QnnPrecision::SubByte { w_bits: 4, a_bits: 4 },
    };
    let hw = match prec {
        QnnPrecision::Fp32 => sparq::ProcessorConfig::ara(),
        _ => sparq::ProcessorConfig::sparq(),
    };
    let cyc = report::qnn_schedule(&hw, prec).map_err(|e| e.to_string())?.total_cycles();

    let ts = sparq::runtime::TestSet::load(dir.join("testset.bin")).map_err(|e| e.to_string())?;
    let dirc = dir.clone();
    let modelc = model.clone();
    let server = sparq::coordinator::Server::start(
        Box::new(move || {
            Ok(Box::new(sparq::coordinator::PjrtExecutor::new(&dirc, &modelc)?)
                as Box<dyn sparq::coordinator::Executor>)
        }),
        serve_cfg,
        cyc,
    )
    .map_err(|e| e.to_string())?;

    println!("serving {model} with {} worker(s), {n} requests...", serve_cfg.workers);
    let mut correct = 0usize;
    let mut pending = Vec::new();
    for i in 0..n {
        let img = ts.image(i % ts.n).to_vec();
        match server.submit(img) {
            Ok(rx) => pending.push((i, rx)),
            Err(e) => println!("request {i}: {e}"),
        }
        if pending.len() >= 64 {
            for (j, rx) in pending.drain(..) {
                if let Ok(Ok(r)) = rx.recv() {
                    correct += (r.class == ts.labels[j % ts.n] as usize) as usize;
                }
            }
        }
    }
    for (j, rx) in pending.drain(..) {
        if let Ok(Ok(r)) = rx.recv() {
            correct += (r.class == ts.labels[j % ts.n] as usize) as usize;
        }
    }
    let snap = server.shutdown();
    println!(
        "done: {}/{} correct ({:.1}%)\n  latency p50/p95/p99: {}/{}/{} us\n  mean batch {:.1}, throughput {:.0} req/s\n  simulated Sparq cost: {} cycles total ({} cycles/image)",
        correct,
        n,
        100.0 * correct as f64 / n as f64,
        snap.p50_us,
        snap.p95_us,
        snap.p99_us,
        snap.mean_batch,
        snap.throughput_rps,
        snap.total_sim_cycles,
        cyc
    );
    Ok(())
}

/// The CI perf-regression gate: compare the cycle fields of freshly
/// generated `BENCH_*.json` files (CWD) against the committed
/// baselines (tolerance 0 — simulated cycles are deterministic).
/// `--bless` copies the current files over the baselines instead
/// (step 2 of the bless protocol in `benchcheck`'s module docs).
fn cmd_bench_check(rest: &[String]) -> Result<(), String> {
    use sparq::benchcheck::{self, CheckOutcome};
    let dir = std::path::PathBuf::from(opt(rest, "--baselines").unwrap_or("ci/bench_baselines"));
    if flag(rest, "--bless") {
        std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
        for name in benchcheck::BENCH_FILES {
            if std::path::Path::new(name).exists() {
                std::fs::copy(name, dir.join(name)).map_err(|e| format!("blessing {name}: {e}"))?;
                println!("blessed {name} -> {}", dir.join(name).display());
            } else {
                println!("skip {name}: not generated (run its bench with -- --json first)");
            }
        }
        return Ok(());
    }
    let mut drifted = false;
    let mut checked = 0usize;
    for name in benchcheck::BENCH_FILES {
        let base_path = dir.join(name);
        let Ok(base) = std::fs::read_to_string(&base_path) else {
            println!("skip {name}: no committed baseline at {}", base_path.display());
            continue;
        };
        let Ok(cur) = std::fs::read_to_string(name) else {
            // a BLESSED baseline with no fresh bench output means the
            // gate would silently stop gating — that is a failure, not
            // a skip (only un-blessed bootstrap placeholders pass)
            let doc = benchcheck::parse(&base).map_err(|e| format!("{name}: {e}"))?;
            if benchcheck::is_unblessed(&doc) {
                println!("skip {name}: baseline is UNBLESSED and no bench output in CWD");
            } else {
                drifted = true;
                println!(
                    "{name}: MISSING bench output in CWD but {} is a blessed baseline — \
                     run the bench with -- --json before bench-check",
                    base_path.display()
                );
            }
            continue;
        };
        checked += 1;
        match benchcheck::compare_texts(&base, &cur).map_err(|e| format!("{name}: {e}"))? {
            CheckOutcome::Unblessed => {
                println!(
                    "{name}: baseline is UNBLESSED — bootstrap pass; bless it with \
                     `sparq bench-check --bless` + commit (protocol in ROADMAP.md)"
                );
            }
            CheckOutcome::Match { fields } => {
                println!("{name}: OK ({fields} cycle fields match the baseline exactly)");
            }
            CheckOutcome::Drift(diffs) => {
                drifted = true;
                println!("{name}: CYCLE DRIFT against {}:", base_path.display());
                for d in &diffs {
                    println!("  {d}");
                }
            }
        }
    }
    if drifted {
        return Err(
            "cycle counts drifted from the committed baselines — either fix the \
             regression or bless the new numbers (`sparq bench-check --bless` + commit)"
                .into(),
        );
    }
    if checked == 0 {
        println!("bench-check: nothing to compare (no BENCH_*.json in CWD)");
    }
    Ok(())
}

fn cmd_isa(rest: &[String]) -> Result<(), String> {
    use sparq::isa::{decode, disasm, encode, VInst, VOp};
    if rest.is_empty() {
        // showcase the paper's Fig. 3 encoding
        println!("vmacsr encodings (paper Fig. 3 — funct6 after vmacc):");
        for inst in [
            VInst::OpVV { op: VOp::Macsr, vd: 1, vs2: 2, vs1: 3 },
            VInst::OpVX { op: VOp::Macsr, vd: 1, vs2: 2, rs1: 0 },
            VInst::OpVX { op: VOp::Macc, vd: 1, vs2: 2, rs1: 0 },
        ] {
            let w = encode(&inst).map_err(|e| e.to_string())?;
            println!("  {w:#010x}  {}", disasm(&inst));
        }
        return Ok(());
    }
    for arg in rest {
        let word = u32::from_str_radix(arg.trim_start_matches("0x"), 16)
            .map_err(|_| format!("'{arg}' is not a hex word"))?;
        match decode(word) {
            Ok(inst) => println!("{word:#010x}  {}", disasm(&inst)),
            Err(e) => println!("{word:#010x}  <illegal: {e}>"),
        }
    }
    Ok(())
}
