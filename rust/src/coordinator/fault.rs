//! Deterministic fault injection for the serving stack.
//!
//! Chaos testing is only useful if a failing run can be replayed
//! bit-identically, so everything here is a pure function of a seed and
//! a call index — there is no wall-clock or OS randomness anywhere in
//! the decision path.
//!
//! Two decision layers compose inside a [`FaultPlan`]:
//!
//! * **Rules** ([`FaultRule`]) match on a worker id and that worker's
//!   *local* call index (its 1st, 2nd, ... executed batch).  Rules are
//!   checked first and are the tool for targeted scenarios ("shard 0
//!   errors on its first three batches").
//! * **A seeded spec** ([`ChaosSpec`]) draws from a splitmix64 hash of
//!   `(seed, global call index)` against per-mille rates.  Because the
//!   draw depends only on the *global* index — not on which worker
//!   happened to pick the request up — the multiset of injected faults
//!   over N calls is identical across runs even though thread
//!   interleaving is not.
//!
//! The injection point is [`ChaosExecutor`], a wrapper implementing
//! [`Executor`] around any inner executor; [`chaos_factory`] lifts the
//! wrap over an [`ExecutorFactory`] so `Server::start` needs no changes
//! to run under chaos.  The batched path consults the plan directly
//! (see `coordinator::batch`).
//!
//! Worker death cannot be modelled by a panic (the worker loop catches
//! panics by design), so a killed worker is signalled by a sentinel
//! error string ([`KILL_SENTINEL`], tested via [`is_kill`]) that the
//! worker loop translates into "reply, then exit the thread" — which is
//! exactly what the supervisor exists to repair.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::{Executor, ExecutorFactory};

/// Error-message marker meaning "the worker owning this executor must
/// die after replying".  Checked by both worker loops via [`is_kill`].
pub const KILL_SENTINEL: &str = "chaos: kill worker";

/// True if `msg` carries the worker-kill sentinel.
pub fn is_kill(msg: &str) -> bool {
    msg.contains(KILL_SENTINEL)
}

/// Upper bound on distinct per-worker call counters a plan tracks.
/// Worker ids wrap modulo this; respawned workers get fresh ids from
/// [`chaos_factory`], so targeted rules only ever address the first
/// generation deterministically.
const MAX_WORKERS: usize = 64;

/// What to inject at one executor call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Execute normally.
    None,
    /// Panic inside the executor (caught by the worker loop; the batch
    /// fails typed, the worker survives).
    Panic,
    /// Return a typed `Err` without executing.
    Error,
    /// Sleep this many microseconds, then return a typed `Err` without
    /// executing — a failure that burns real time first, so rider
    /// deadlines can expire *before* failover runs (drives the
    /// failover shed/drain regression tests).
    SlowError(u64),
    /// Return the kill-sentinel `Err`; the worker replies and exits.
    Kill,
    /// Sleep this many microseconds, then execute normally (drives
    /// deadline shedding and drain tests).
    Delay(u64),
    /// Execute normally, then overwrite the first logit of each image
    /// with a NaN/minimum sentinel (exercises NaN-safe argmax).
    CorruptLogits,
}

/// Which of a worker's calls a [`FaultRule`] fires on.  All selectors
/// except [`CallSel::GlobalNth`] address the worker's *local* call
/// index; `GlobalNth` addresses the plan-wide global index, which is
/// the tool for "whoever executes the k-th batch" scenarios on the
/// shared-ring path (batch-to-worker assignment there is a scheduling
/// race, so local indices cannot target "the first batch served").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CallSel {
    /// Exactly the n-th local call (0-based).
    Nth(u64),
    /// Exactly the n-th *global* call (0-based), whichever worker
    /// consumes it.
    GlobalNth(u64),
    /// Every k-th local call (`n % k == 0`); `k == 0` never matches.
    Every(u64),
    /// Local calls in `[lo, hi)`.
    Range(u64, u64),
    /// Every call.
    Always,
}

impl CallSel {
    fn matches_at(&self, global: u64, local: u64) -> bool {
        match *self {
            CallSel::Nth(k) => local == k,
            CallSel::GlobalNth(k) => global == k,
            CallSel::Every(k) => k != 0 && local % k == 0,
            CallSel::Range(lo, hi) => local >= lo && local < hi,
            CallSel::Always => true,
        }
    }
}

/// A targeted injection: fire `action` when `when` matches the local
/// call index of `worker` (or of any worker if `worker` is `None`).
/// First matching rule wins; rules shadow the seeded spec.
#[derive(Debug, Clone)]
pub struct FaultRule {
    pub worker: Option<usize>,
    pub when: CallSel,
    pub action: FaultAction,
}

/// Background fault rates in per-mille of executor calls, drawn
/// deterministically from the plan seed and the global call index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChaosSpec {
    pub kill_per_mille: u16,
    pub panic_per_mille: u16,
    pub error_per_mille: u16,
    pub delay_per_mille: u16,
    /// Sleep length for `Delay` draws, microseconds.
    pub delay_us: u64,
    pub corrupt_per_mille: u16,
}

impl ChaosSpec {
    /// No background faults (rules only).
    pub fn quiet() -> ChaosSpec {
        ChaosSpec {
            kill_per_mille: 0,
            panic_per_mille: 0,
            error_per_mille: 0,
            delay_per_mille: 0,
            delay_us: 0,
            corrupt_per_mille: 0,
        }
    }

    /// An aggressive mix used by the chaos suite: ~4% kills, ~4%
    /// panics, ~4% typed errors, ~1% 100µs delays, ~2% corrupt logits.
    pub fn storm() -> ChaosSpec {
        ChaosSpec {
            kill_per_mille: 40,
            panic_per_mille: 40,
            error_per_mille: 40,
            delay_per_mille: 10,
            delay_us: 100,
            corrupt_per_mille: 20,
        }
    }

    /// The action this spec injects at global call `n` under `seed`.
    /// Pure: same `(seed, n)` always yields the same action.
    fn action(&self, seed: u64, n: u64) -> FaultAction {
        let total = self.kill_per_mille
            + self.panic_per_mille
            + self.error_per_mille
            + self.delay_per_mille
            + self.corrupt_per_mille;
        if total == 0 {
            return FaultAction::None;
        }
        let draw = (splitmix(seed ^ n.wrapping_mul(0x9e37_79b9_7f4a_7c15)) % 1000) as u16;
        let mut edge = self.kill_per_mille;
        if draw < edge {
            return FaultAction::Kill;
        }
        edge += self.panic_per_mille;
        if draw < edge {
            return FaultAction::Panic;
        }
        edge += self.error_per_mille;
        if draw < edge {
            return FaultAction::Error;
        }
        edge += self.delay_per_mille;
        if draw < edge {
            return FaultAction::Delay(self.delay_us);
        }
        edge += self.corrupt_per_mille;
        if draw < edge {
            return FaultAction::CorruptLogits;
        }
        FaultAction::None
    }
}

fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// A replayable fault schedule shared (via `Arc`) by every chaos
/// executor of one server.  Carries one global call counter (feeds the
/// seeded spec) and per-worker counters (feed the rules), so both
/// decision layers are deterministic under thread interleaving.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    spec: Option<ChaosSpec>,
    rules: Vec<FaultRule>,
    calls: AtomicU64,
    worker_calls: Vec<AtomicU64>,
}

impl FaultPlan {
    /// Background chaos at the given rates, replayable from `seed`.
    pub fn seeded(seed: u64, spec: ChaosSpec) -> FaultPlan {
        FaultPlan {
            seed,
            spec: Some(spec),
            rules: Vec::new(),
            calls: AtomicU64::new(0),
            worker_calls: (0..MAX_WORKERS).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Targeted rules only, no background faults.
    pub fn from_rules(rules: Vec<FaultRule>) -> FaultPlan {
        FaultPlan {
            seed: 0,
            spec: None,
            rules,
            calls: AtomicU64::new(0),
            worker_calls: (0..MAX_WORKERS).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Add a targeted rule (checked before the seeded spec).
    pub fn with_rule(mut self, rule: FaultRule) -> FaultPlan {
        self.rules.push(rule);
        self
    }

    /// Total executor calls consumed so far (shed requests never
    /// consume a call — the chaos suite asserts on this).
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::SeqCst)
    }

    /// Consume one call on behalf of `worker` and return the action to
    /// inject.  Advances both the global and the worker-local counter.
    pub fn next_for(&self, worker: usize) -> FaultAction {
        let g = self.calls.fetch_add(1, Ordering::SeqCst);
        let p = self.worker_calls[worker % MAX_WORKERS].fetch_add(1, Ordering::SeqCst);
        self.decide(worker, g, p)
    }

    /// Pure decision: rules on `(worker, local)` first, then the
    /// seeded spec on `global`.
    fn decide(&self, worker: usize, global: u64, local: u64) -> FaultAction {
        for r in &self.rules {
            let worker_ok = match r.worker {
                Some(w) => w == worker,
                None => true,
            };
            if worker_ok && r.when.matches_at(global, local) {
                return r.action;
            }
        }
        match self.spec {
            Some(spec) => spec.action(self.seed, global),
            None => FaultAction::None,
        }
    }
}

/// An [`Executor`] wrapper that consults a shared [`FaultPlan`] before
/// each `run`.  `Panic`/`Error`/`Kill` replace the inner call entirely;
/// `SlowError` sleeps and then fails typed; `Delay` sleeps first;
/// `CorruptLogits` poisons the first logit of each image in an
/// otherwise-successful result.
pub struct ChaosExecutor {
    inner: Box<dyn Executor>,
    plan: Arc<FaultPlan>,
    worker: usize,
}

impl ChaosExecutor {
    pub fn new(inner: Box<dyn Executor>, plan: Arc<FaultPlan>, worker: usize) -> ChaosExecutor {
        ChaosExecutor { inner, plan, worker }
    }
}

impl Executor for ChaosExecutor {
    fn batch(&self) -> usize {
        self.inner.batch()
    }

    fn image_len(&self) -> usize {
        self.inner.image_len()
    }

    fn classes(&self) -> usize {
        self.inner.classes()
    }

    fn run(&mut self, batch: &[f32]) -> Result<Vec<f32>, String> {
        match self.plan.next_for(self.worker) {
            FaultAction::None => self.inner.run(batch),
            FaultAction::Panic => panic!("chaos: injected panic (worker {})", self.worker),
            FaultAction::Error => Err(format!("chaos: injected error (worker {})", self.worker)),
            FaultAction::SlowError(us) => {
                std::thread::sleep(Duration::from_micros(us));
                Err(format!("chaos: injected slow error (worker {})", self.worker))
            }
            FaultAction::Kill => Err(format!("{} (worker {})", KILL_SENTINEL, self.worker)),
            FaultAction::Delay(us) => {
                std::thread::sleep(Duration::from_micros(us));
                self.inner.run(batch)
            }
            FaultAction::CorruptLogits => {
                let mut logits = self.inner.run(batch)?;
                let classes = self.classes().max(1);
                let mut i = 0;
                while i < logits.len() {
                    logits[i] = f32::NAN;
                    i += classes;
                }
                Ok(logits)
            }
        }
    }
}

/// Wrap an executor factory so every worker it builds runs under the
/// shared `plan`.  Worker ids are assigned in construction order
/// (respawned workers get fresh ids), so targeted rules address the
/// initial generation 0..N-1 deterministically.
pub fn chaos_factory(inner: ExecutorFactory, plan: Arc<FaultPlan>) -> ExecutorFactory {
    let next_id = Arc::new(AtomicUsize::new(0));
    Box::new(move || {
        let worker = next_id.fetch_add(1, Ordering::SeqCst);
        let exec = inner()?;
        Ok(Box::new(ChaosExecutor::new(exec, Arc::clone(&plan), worker)) as Box<dyn Executor>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plan_replays_identically() {
        let a = FaultPlan::seeded(42, ChaosSpec::storm());
        let b = FaultPlan::seeded(42, ChaosSpec::storm());
        let seq_a: Vec<FaultAction> = (0..256).map(|_| a.next_for(0)).collect();
        let seq_b: Vec<FaultAction> = (0..256).map(|_| b.next_for(0)).collect();
        assert_eq!(seq_a, seq_b);
        assert_eq!(a.calls(), 256);
    }

    #[test]
    fn seeded_draws_ignore_worker_id() {
        // The spec layer keys on the global index only, so the same
        // global sequence is injected no matter which worker consumes
        // each call — this is what makes storm totals replayable.
        let a = FaultPlan::seeded(7, ChaosSpec::storm());
        let b = FaultPlan::seeded(7, ChaosSpec::storm());
        let seq_a: Vec<FaultAction> = (0..128).map(|i| a.next_for(i % 2)).collect();
        let seq_b: Vec<FaultAction> = (0..128).map(|i| b.next_for((i + 1) % 2)).collect();
        assert_eq!(seq_a, seq_b);
    }

    #[test]
    fn different_seeds_differ() {
        let a = FaultPlan::seeded(1, ChaosSpec::storm());
        let b = FaultPlan::seeded(2, ChaosSpec::storm());
        let seq_a: Vec<FaultAction> = (0..256).map(|_| a.next_for(0)).collect();
        let seq_b: Vec<FaultAction> = (0..256).map(|_| b.next_for(0)).collect();
        assert_ne!(seq_a, seq_b);
    }

    #[test]
    fn storm_rates_land_near_per_mille_budget() {
        let plan = FaultPlan::seeded(123, ChaosSpec::storm());
        let mut faults = 0u32;
        for _ in 0..4000 {
            if plan.next_for(0) != FaultAction::None {
                faults += 1;
            }
        }
        // storm() budgets 150‰; allow a generous window around it.
        assert!((300..=900).contains(&faults), "faults = {faults}");
    }

    #[test]
    fn rules_match_worker_local_indices() {
        let plan = FaultPlan::from_rules(vec![
            FaultRule { worker: Some(0), when: CallSel::Range(0, 2), action: FaultAction::Error },
            FaultRule { worker: Some(1), when: CallSel::Nth(1), action: FaultAction::Kill },
        ]);
        // Interleave workers; rules must see each worker's own count.
        assert_eq!(plan.next_for(0), FaultAction::Error); // w0 local 0
        assert_eq!(plan.next_for(1), FaultAction::None); // w1 local 0
        assert_eq!(plan.next_for(0), FaultAction::Error); // w0 local 1
        assert_eq!(plan.next_for(1), FaultAction::Kill); // w1 local 1
        assert_eq!(plan.next_for(0), FaultAction::None); // w0 local 2
    }

    #[test]
    fn rule_selectors_cover_every_and_always() {
        assert!(CallSel::Every(3).matches_at(9, 0));
        assert!(!CallSel::Every(3).matches_at(9, 2));
        assert!(CallSel::Every(3).matches_at(9, 6));
        assert!(!CallSel::Every(0).matches_at(9, 0));
        assert!(CallSel::Always.matches_at(9, u64::MAX));
        assert!(CallSel::Range(2, 4).matches_at(9, 3));
        assert!(!CallSel::Range(2, 4).matches_at(9, 4));
        // GlobalNth is the one selector keyed on the global index
        assert!(CallSel::GlobalNth(9).matches_at(9, 0));
        assert!(!CallSel::GlobalNth(9).matches_at(8, 9));
    }

    #[test]
    fn global_nth_fires_on_the_global_call_whoever_consumes_it() {
        let plan = FaultPlan::from_rules(vec![FaultRule {
            worker: None,
            when: CallSel::GlobalNth(2),
            action: FaultAction::Kill,
        }]);
        // workers interleave arbitrarily; only the third global call
        // (whichever worker it lands on) draws the kill
        assert_eq!(plan.next_for(1), FaultAction::None); // global 0
        assert_eq!(plan.next_for(0), FaultAction::None); // global 1
        assert_eq!(plan.next_for(1), FaultAction::Kill); // global 2
        assert_eq!(plan.next_for(1), FaultAction::None); // global 3
    }

    #[test]
    fn slow_error_rule_is_decided_like_any_action() {
        let plan = FaultPlan::from_rules(vec![FaultRule {
            worker: Some(0),
            when: CallSel::Nth(0),
            action: FaultAction::SlowError(250),
        }]);
        assert_eq!(plan.next_for(0), FaultAction::SlowError(250));
        assert_eq!(plan.next_for(0), FaultAction::None);
    }

    #[test]
    fn kill_sentinel_roundtrips() {
        assert!(is_kill(&format!("{KILL_SENTINEL} (worker 3)")));
        assert!(!is_kill("conv compile failed"));
    }
}
