//! The batched QNN request path (DESIGN.md §Serving): a lock-free
//! slot-reservation front door ([`super::ring::BatchRing`]) feeding
//! workers that execute *batch-B* compiled programs.
//!
//! Where the generic [`super::Server`] drives any [`super::Executor`]
//! one image at a time, [`QnnBatchServer`] serves the whole SparqCNN
//! through the batch-B arena layout
//! ([`crate::qnn::compiled::CompiledQnn::compile_batched`]):
//!
//! * **Slot reservation.**  `submit` claims a slot in the current open
//!   batch frame with one CAS and moves the image into the slot in
//!   place — no per-shard queue, no channel copy, no round-robin
//!   submitter.  Every producer feeds the *same* open batch, so
//!   batches fill as fast as load arrives (the old N-queue design
//!   split low offered load N ways and ran every batch underfilled).
//!   Only when every frame of the ring is claimed-and-unconsumed does
//!   the caller see typed backpressure ([`super::ServeError::QueueFull`]).
//! * **Seal and dispatch.**  A frame seals the instant its last slot
//!   is written or its batching window (`batch_window_us`) expires —
//!   the two contenders race on a single CAS (see `coordinator/ring.rs`).
//!   Any idle worker consumes the sealed frame and runs ONE batched
//!   execution: every image staged into its own activation slot, the
//!   per-batch weight-pack preamble paid once, each stage stream
//!   replayed per slot with rebased addresses.
//! * **Cluster dispatch.**  The execution itself goes through a
//!   [`super::cluster::QnnCluster`] shared by every worker: with
//!   `ServeConfig::cores == 1` (the default) that is exactly the old
//!   single-pool batched execution, bit-identical in logits *and*
//!   cycles; with `--cores K` the frame is sharded across K per-core
//!   machine pools executing host-parallel and merged back into
//!   request order (DESIGN.md §Cluster).  Per-slot results are
//!   batch-layout-invariant, so the K-core scatter is bit-identical to
//!   the 1-core run of the same frame.
//! * **Scatter.**  Per-image logits/cycles fan back out to each
//!   request's completion channel; the [`Metrics`] sink records
//!   per-request wall *and* simulated-cycle latency plus the executed
//!   batch's fill and how it sealed (last writer vs window).
//!
//! Robustness (DESIGN.md §Robustness):
//!
//! * **Failover.**  A request whose batch fails with a transient
//!   `Worker` error is re-queued ONCE into the ring
//!   (`attempts`-guarded, counted in `Metrics::retries`); any worker —
//!   possibly the one that just failed — may pick up the retry, and
//!   only the second failure reaches the client typed.  Requests whose
//!   deadline passed during the failed execution are shed typed
//!   ([`super::ServeError::Deadline`]) instead of burning a slot, and
//!   once a drain has begun they are answered
//!   [`super::ServeError::Closed`] and counted in `drain_shed`.
//! * **Circuit breaker.**  Per-worker consecutive-error counters eject
//!   a persistently failing worker for a probation window
//!   (`breaker_threshold` / `probation_us` in `ServeConfig`); an
//!   ejected worker *pauses consuming* from the shared ring while any
//!   non-ejected worker is alive, re-admits itself when probation
//!   expires (its next batch is the probe), and a success heals it.
//!   If every live worker is ejected, they keep serving (alive-only
//!   fallback), so an all-ejected pool never strands the ring.
//! * **Typed refusals.**  Wrong-length images are rejected at submit
//!   time ([`super::ServeError::BadInput`]) — never truncated or
//!   padded; when every worker has died, submit fails fast with
//!   [`super::ServeError::NoWorkers`] instead of queueing forever, and
//!   the last worker out closes and drains the ring so no rider hangs.
//! * **Graceful drain.**  `shutdown_with_deadline` closes the ring
//!   immediately (new submits see `Closed`), finishes queued work
//!   until the deadline, sheds the rest typed, and reports
//!   [`super::DrainStats`].
//! * **Deterministic chaos.**  `start_chaos` threads a seeded
//!   [`FaultPlan`] into every worker; each *executed batch* consults
//!   the plan exactly once (panic / typed error / slow error / kill /
//!   delay / corrupt logits) — the plan's global counter makes the
//!   injected multiset a function of the seed alone, so chaos replays
//!   bit-identically even though batch composition over a shared ring
//!   is scheduling-dependent.  `start_chaos_cores` adds a *second,
//!   independent* plan consulted inside the cluster once per core
//!   execution with the core id as the plan's worker index, so chaos
//!   can target individual cores: a killed core fails only its shard's
//!   riders (typed, failed over through the ring) and stays out of
//!   every later shard map, while the worker and the surviving cores
//!   keep serving.  Only when the *whole cluster* is dead does the
//!   worker exit and the terminal drain answer the stragglers.
//!
//! Per-image results are bit-identical to unbatched inference (the
//! batch determinism tests in `rust/tests/serve_batch.rs` pin logits
//! and cycles), so batching is purely a throughput/amortization
//! decision.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::cluster::{self, ClusterRun, CoreHealth, QnnCluster, ShardPolicy};
use super::fault::{self, FaultAction, FaultPlan};
use super::ring::{BatchRing, Pop, PushError};
use super::{DrainStats, InferResult, Metrics, ServeError, Snapshot};
use crate::arch::ProcessorConfig;
use crate::config::ServeConfig;
use crate::kernels::ProgramCache;
use crate::qnn::compiled::{argmax_i64, MAX_BATCH};
use crate::qnn::schedule::QnnPrecision;
use crate::qnn::QnnGraph;
use crate::runtime::SimQnnModel;

/// How long one `pop` waits for riders before re-checking worker
/// eligibility (breaker pauses, shutdown).
const POP_POLL: Duration = Duration::from_millis(1);
/// How long an ejected worker sleeps between eligibility re-checks.
const EJECT_POLL: Duration = Duration::from_micros(200);

struct BatchRequest {
    image: Vec<f32>,
    resp: SyncSender<Result<InferResult, ServeError>>,
    enqueued: Instant,
    /// Absolute deadline; shed typed pre-execution once passed.
    deadline: Option<Instant>,
    /// Failover retries already spent (max 1).
    attempts: u8,
}

/// Per-worker breaker/liveness state ("shard" survives in the public
/// health vocabulary: one shard == one batch worker).
#[derive(Debug)]
struct ShardState {
    /// The worker thread is running (cleared on exit).
    alive: AtomicBool,
    /// Consecutive failed batches (a success resets it).
    consecutive: AtomicU32,
    /// Failed batches on this worker, total.
    errors: AtomicU64,
    /// Times the breaker ejected this worker.
    trips: AtomicU64,
    /// While `Some(t)` with `t` in the future, the worker pauses
    /// consuming (while any non-ejected peer is alive); expiry
    /// re-admits it and a success clears the field.
    ejected_until: Mutex<Option<Instant>>,
}

impl ShardState {
    fn new() -> ShardState {
        ShardState {
            alive: AtomicBool::new(true),
            consecutive: AtomicU32::new(0),
            errors: AtomicU64::new(0),
            trips: AtomicU64::new(0),
            ejected_until: Mutex::new(None),
        }
    }

    fn ejected(&self, now: Instant) -> bool {
        self.ejected_until.lock().unwrap().is_some_and(|t| now < t)
    }
}

/// State shared by the server handle and every worker.
struct BatchShared {
    shards: Vec<ShardState>,
    /// The lock-free front door: one ring of batch frames every
    /// producer claims slots in and every worker consumes from.
    ring: BatchRing<BatchRequest>,
    /// The K-core execution layer every worker dispatches sealed
    /// frames through (K == 1 is the plain batched path, bit-identical
    /// to pre-cluster serving).  Core liveness is global: a core a
    /// chaos plan kills is dead for every worker.
    cluster: Arc<QnnCluster>,
    /// Per-core fault plan, consulted inside the cluster once per core
    /// execution (the worker-level `plan` is separate and still
    /// consulted once per executed batch).
    core_plan: Option<Arc<FaultPlan>>,
    metrics: Arc<Metrics>,
    /// Workers still running (the last one out closes + drains the
    /// ring so no rider is ever stranded).
    live: AtomicUsize,
    /// A graceful shutdown began: riders flushed out of the ring are
    /// drain-shed (`Closed`), not dead-pool refusals (`NoWorkers`).
    stopping: AtomicBool,
    /// Graceful-drain deadline (see `shutdown_with_deadline`).
    drain_by: Mutex<Option<Instant>>,
    /// Consecutive errors before ejection; 0 disables the breaker.
    breaker_threshold: u32,
    probation: Duration,
}

impl BatchShared {
    /// Someone other than `me` is alive and not sitting out probation
    /// (the breaker pause condition: an ejected worker only pauses
    /// while a healthy peer can cover the ring).
    fn other_can_serve(&self, me: usize, now: Instant) -> bool {
        self.shards.iter().enumerate().any(|(i, s)| {
            i != me && s.alive.load(Ordering::SeqCst) && !s.ejected(now)
        })
    }
}

/// Per-shard health view (see [`QnnBatchServer::health`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardHealth {
    pub alive: bool,
    /// Failed batches on this shard, total.
    pub errors: u64,
    /// Consecutive failed batches right now.
    pub consecutive_errors: u32,
    /// Times the breaker ejected this shard.
    pub trips: u64,
    /// Currently sitting out a probation window.
    pub ejected: bool,
}

/// Pool-level health of the batched server.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchHealth {
    pub shards: Vec<ShardHealth>,
    /// Shards alive right now.
    pub alive: usize,
    /// Breaker ejections across all shards.
    pub breaker_trips: u64,
    /// Per-core liveness/counters of the execution cluster.
    pub cores: Vec<CoreHealth>,
    /// Cluster cores alive right now.
    pub cores_alive: usize,
}

/// A running batched QNN inference server (simulator backend, no
/// artifacts).  The network compiles once into the shared
/// [`ProgramCache`] under its batched graph-level key; every worker
/// shares the `Arc`'d model through one [`QnnCluster`] whose per-core
/// [`crate::sim::MachinePool`]s execute the dispatched frames.
pub struct QnnBatchServer {
    shared: Arc<BatchShared>,
    pub metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
    batch: usize,
    image_len: usize,
    default_deadline: Option<Duration>,
}

impl QnnBatchServer {
    /// Compile the batched network (or fetch it from `cache`) and
    /// start `serve.workers` batch workers at batch size `serve.batch`
    /// (clamped to `1..=`[`MAX_BATCH`]).
    pub fn start(
        cfg: ProcessorConfig,
        graph: &QnnGraph,
        precision: QnnPrecision,
        seed: u64,
        serve: ServeConfig,
        cache: &ProgramCache,
    ) -> Result<QnnBatchServer, ServeError> {
        QnnBatchServer::start_chaos(cfg, graph, precision, seed, serve, cache, None)
    }

    /// [`QnnBatchServer::start`] with a fault-injection plan threaded
    /// into every worker — each executed batch consults the plan
    /// once (DESIGN.md §Robustness).  `None` serves clean.
    pub fn start_chaos(
        cfg: ProcessorConfig,
        graph: &QnnGraph,
        precision: QnnPrecision,
        seed: u64,
        serve: ServeConfig,
        cache: &ProgramCache,
        plan: Option<Arc<FaultPlan>>,
    ) -> Result<QnnBatchServer, ServeError> {
        QnnBatchServer::start_chaos_cores(cfg, graph, precision, seed, serve, cache, plan, None)
    }

    /// [`QnnBatchServer::start_chaos`] plus a *per-core* fault plan:
    /// the cluster consults `core_plan.next_for(core_id)` once per
    /// core execution, so `FaultRule { worker: Some(core), .. }`
    /// targets a specific core of the K-core cluster (DESIGN.md
    /// §Cluster).  The worker-level `plan` is independent and still
    /// consulted once per executed batch.
    #[allow(clippy::too_many_arguments)]
    pub fn start_chaos_cores(
        cfg: ProcessorConfig,
        graph: &QnnGraph,
        precision: QnnPrecision,
        seed: u64,
        serve: ServeConfig,
        cache: &ProgramCache,
        plan: Option<Arc<FaultPlan>>,
        core_plan: Option<Arc<FaultPlan>>,
    ) -> Result<QnnBatchServer, ServeError> {
        let batch = serve.batch.clamp(1, MAX_BATCH as usize) as u32;
        let model = Arc::new(
            SimQnnModel::compile_batched(&cfg, graph, precision, seed, cache, batch)
                .map_err(|e| ServeError::Worker(e.to_string()))?,
        );
        let workers = serve.workers.max(1);
        // the ring carries the old queue budget: `queue_depth` riders
        // split into batch-sized frames (explicit `ring_frames` wins;
        // BatchRing rounds up to a power of two, floor 2)
        let frames = if serve.ring_frames > 0 {
            serve.ring_frames
        } else {
            (serve.queue_depth / (batch as usize)).max(2)
        };
        let window = Duration::from_micros(serve.batch_window_us);
        let metrics = Arc::new(Metrics::default());
        let image_len = model.input_len();
        let policy =
            if serve.work_steal { ShardPolicy::WorkSteal } else { ShardPolicy::RoundRobin };
        let qcluster = Arc::new(QnnCluster::new(
            Arc::clone(&model),
            serve.cores.clamp(1, cluster::MAX_CORES),
            policy,
        ));
        let shared = Arc::new(BatchShared {
            shards: (0..workers).map(|_| ShardState::new()).collect(),
            ring: BatchRing::new(frames, batch as usize, window),
            cluster: qcluster,
            core_plan,
            metrics: Arc::clone(&metrics),
            live: AtomicUsize::new(workers),
            stopping: AtomicBool::new(false),
            drain_by: Mutex::new(None),
            breaker_threshold: serve.breaker_threshold,
            probation: Duration::from_micros(serve.probation_us.max(1)),
        });
        let mut handles = Vec::with_capacity(workers);
        for wid in 0..workers {
            let shared = Arc::clone(&shared);
            let plan = plan.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("sparq-batch-worker-{wid}"))
                    .spawn(move || {
                        worker_loop(wid, &shared, plan);
                        // Exit path (kill or shutdown): mark the worker
                        // dead; the LAST worker out closes the ring and
                        // answers every remaining rider typed — a
                        // request that raced past the liveness check in
                        // `submit` sees `Closed`/`NoWorkers`, never a
                        // hang.
                        shared.shards[wid].alive.store(false, Ordering::SeqCst);
                        if shared.live.fetch_sub(1, Ordering::SeqCst) == 1 {
                            terminal_drain(&shared);
                        }
                    })
                    .map_err(|e| ServeError::Worker(e.to_string()))?,
            );
        }
        Ok(QnnBatchServer {
            shared,
            metrics,
            workers: handles,
            batch: batch as usize,
            image_len,
            default_deadline: (serve.deadline_us > 0)
                .then(|| Duration::from_micros(serve.deadline_us)),
        })
    }

    /// The compiled batch size workers execute at.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Per-image input length (c * h * w).
    pub fn image_len(&self) -> usize {
        self.image_len
    }

    /// Batch frames in the front-door ring.
    pub fn ring_frames(&self) -> usize {
        self.shared.ring.frames()
    }

    /// Configured cluster width (simulated cores per dispatched frame).
    pub fn cores(&self) -> usize {
        self.shared.cluster.cores()
    }

    /// The cluster's shard policy.
    pub fn shard_policy(&self) -> ShardPolicy {
        self.shared.cluster.policy()
    }

    /// Non-blocking submit with the config-level default deadline.
    pub fn submit(
        &self,
        image: Vec<f32>,
    ) -> Result<Receiver<Result<InferResult, ServeError>>, ServeError> {
        self.submit_with_deadline(image, self.default_deadline)
    }

    /// Non-blocking submit with an explicit per-request deadline: one
    /// CAS claims a slot in the current open batch frame and the image
    /// moves into it in place.  [`ServeError::QueueFull`] only when
    /// every frame of the ring is claimed-and-unconsumed.  Wrong-length
    /// images are refused typed ([`ServeError::BadInput`]); a fully
    /// dead pool fails fast ([`ServeError::NoWorkers`]).
    pub fn submit_with_deadline(
        &self,
        image: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Result<Receiver<Result<InferResult, ServeError>>, ServeError> {
        if image.len() != self.image_len {
            self.metrics.record_bad_input();
            return Err(ServeError::BadInput { got: image.len(), want: self.image_len });
        }
        if !self.shared.shards.iter().any(|s| s.alive.load(Ordering::SeqCst)) {
            self.metrics.record_no_workers(1);
            return Err(ServeError::NoWorkers);
        }
        if self.shared.ring.is_closed() {
            return Err(ServeError::Closed);
        }
        let (rtx, rrx) = sync_channel(1);
        let now = Instant::now();
        let req = BatchRequest {
            image,
            resp: rtx,
            enqueued: now,
            deadline: deadline.map(|d| now + d),
            attempts: 0,
        };
        // gauge BEFORE the push: a worker may consume (and queue_dec)
        // the instant the slot write lands, and inc-after-push would
        // let the gauge transiently read negative
        self.metrics.queue_inc();
        match self.shared.ring.push(req) {
            Ok(_) => Ok(rrx),
            Err((PushError::Closed, _)) => {
                self.metrics.queue_dec(1);
                Err(ServeError::Closed)
            }
            Err((PushError::Full, _)) => {
                self.metrics.queue_dec(1);
                self.metrics.record_rejected();
                Err(ServeError::QueueFull)
            }
        }
    }

    /// Blocking inference.
    pub fn infer(&self, image: Vec<f32>) -> Result<InferResult, ServeError> {
        let rx = self.submit(image)?;
        rx.recv().map_err(|_| ServeError::Closed)?
    }

    /// Bounded-time inference: the request carries `timeout` as its
    /// deadline; returns [`ServeError::Deadline`] if no response
    /// arrives within it.  Never blocks longer than `timeout`.
    pub fn infer_timeout(
        &self,
        image: Vec<f32>,
        timeout: Duration,
    ) -> Result<InferResult, ServeError> {
        let rx = self.submit_with_deadline(image, Some(timeout))?;
        match rx.recv_timeout(timeout) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => Err(ServeError::Deadline),
            Err(RecvTimeoutError::Disconnected) => Err(ServeError::Closed),
        }
    }

    /// Shard/breaker health right now.
    pub fn health(&self) -> BatchHealth {
        let now = Instant::now();
        let shards: Vec<ShardHealth> = self
            .shared
            .shards
            .iter()
            .map(|s| ShardHealth {
                alive: s.alive.load(Ordering::SeqCst),
                errors: s.errors.load(Ordering::SeqCst),
                consecutive_errors: s.consecutive.load(Ordering::SeqCst),
                trips: s.trips.load(Ordering::SeqCst),
                ejected: s.ejected(now),
            })
            .collect();
        let alive = shards.iter().filter(|s| s.alive).count();
        let breaker_trips = shards.iter().map(|s| s.trips).sum();
        let cores = self.shared.cluster.core_health();
        let cores_alive = cores.iter().filter(|c| c.alive).count();
        BatchHealth { shards, alive, breaker_trips, cores, cores_alive }
    }

    /// Drain the ring fully, stop the workers, return the final
    /// metrics (the original unbounded drain).
    pub fn shutdown(mut self) -> Snapshot {
        self.stop_workers();
        self.metrics.snapshot()
    }

    /// Graceful bounded drain: stop accepting work immediately, let
    /// queued work finish until `deadline`, shed the rest typed with
    /// [`ServeError::Closed`], and report what happened.  In-flight
    /// batches run to completion, so the wall time is bounded by the
    /// deadline plus one batch execution.
    pub fn shutdown_with_deadline(mut self, deadline: Duration) -> (Snapshot, DrainStats) {
        let t0 = Instant::now();
        let before = self.metrics.snapshot();
        *self.shared.drain_by.lock().unwrap() = Some(t0 + deadline);
        self.stop_workers();
        let after = self.metrics.snapshot();
        let stats = DrainStats {
            completed: after.completed.saturating_sub(before.completed),
            shed: after.drain_shed.saturating_sub(before.drain_shed),
            wall_us: t0.elapsed().as_micros() as u64,
        };
        (after, stats)
    }

    fn stop_workers(&mut self) {
        // close the front door; workers drain the sealed/filling
        // frames (pop only reports Closed once the ring is empty) and
        // exit
        self.shared.stopping.store(true, Ordering::SeqCst);
        self.shared.ring.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// The last worker out flushes every rider still in the ring so no
/// client ever hangs on a response channel: during a graceful
/// shutdown the riders are drain-shed typed (`Closed`), after a
/// chaos kill of the whole pool they are dead-pool refusals
/// (`NoWorkers`).
fn terminal_drain(shared: &BatchShared) {
    shared.ring.close();
    let stopping = shared.stopping.load(Ordering::SeqCst);
    loop {
        match shared.ring.pop(Duration::ZERO) {
            Pop::Batch(reqs, _) => {
                shared.metrics.queue_dec(reqs.len() as u64);
                for r in reqs {
                    if stopping {
                        shared.metrics.record_drain_shed(1);
                        let _ = r.resp.send(Err(ServeError::Closed));
                    } else {
                        shared.metrics.record_no_workers(1);
                        let _ = r.resp.send(Err(ServeError::NoWorkers));
                    }
                }
            }
            Pop::Idle | Pop::Closed => return,
        }
    }
}

/// Re-queue `req` into the ring after a failed batch.  Expired
/// requests are shed typed at failover time (no queue slot burned);
/// once a drain has begun the ring is closed and the rider is
/// drain-shed `Closed`, not mislabelled a worker error.  Only when
/// the ring is genuinely full does the originating error reach the
/// client.
fn fail_over(shared: &BatchShared, req: BatchRequest, err: &str) {
    if let Some(d) = req.deadline {
        if Instant::now() > d {
            shared.metrics.record_deadline_shed(1);
            let _ = req.resp.send(Err(ServeError::Deadline));
            return;
        }
    }
    shared.metrics.queue_inc();
    match shared.ring.push(req) {
        Ok(_) => shared.metrics.record_retries(1),
        Err((PushError::Closed, req)) => {
            shared.metrics.queue_dec(1);
            shared.metrics.record_drain_shed(1);
            let _ = req.resp.send(Err(ServeError::Closed));
        }
        Err((PushError::Full, req)) => {
            shared.metrics.queue_dec(1);
            shared.metrics.record_errors(1);
            let _ = req.resp.send(Err(ServeError::Worker(err.to_string())));
        }
    }
}

fn worker_loop(wid: usize, shared: &Arc<BatchShared>, plan: Option<Arc<FaultPlan>>) {
    let metrics = &shared.metrics;
    loop {
        // Breaker pause: an ejected worker stops consuming from the
        // shared ring while a healthy peer can cover it (probation
        // expiry re-admits it; if everyone is ejected it keeps
        // serving so the ring never strands).
        let st = &shared.shards[wid];
        if st.ejected(Instant::now()) && shared.other_can_serve(wid, Instant::now()) {
            std::thread::sleep(EJECT_POLL);
            continue;
        }
        let (mut reqs, meta) = match shared.ring.pop(POP_POLL) {
            Pop::Batch(reqs, meta) => (reqs, meta),
            Pop::Idle => continue,
            Pop::Closed => return, // drained shutdown
        };
        metrics.queue_dec(reqs.len() as u64);
        metrics.record_seal(meta.sealed_by_window);

        // Graceful drain: past the drain deadline, queued work is shed
        // typed instead of executed.
        if let Some(dl) = *shared.drain_by.lock().unwrap() {
            if Instant::now() > dl {
                metrics.record_drain_shed(reqs.len() as u64);
                for r in reqs {
                    let _ = r.resp.send(Err(ServeError::Closed));
                }
                continue;
            }
        }

        // Deadline shedding: expired requests are answered typed and
        // never executed.
        let now = Instant::now();
        let mut shed = 0u64;
        reqs.retain(|r| match r.deadline {
            Some(d) if now > d => {
                shed += 1;
                let _ = r.resp.send(Err(ServeError::Deadline));
                false
            }
            _ => true,
        });
        if shed > 0 {
            metrics.record_deadline_shed(shed);
        }
        if reqs.is_empty() {
            continue;
        }

        // One fault-plan consult per executed batch.
        let injected =
            plan.as_ref().map(|p| p.next_for(wid)).unwrap_or(FaultAction::None);
        if let FaultAction::Delay(us) = injected {
            std::thread::sleep(Duration::from_micros(us));
        }

        let fill = reqs.len() as u32;
        // `submit` validated every image length, so images stage into
        // the arena exactly as sent — no truncation, no padding.
        let result: Result<ClusterRun, String> = match injected {
            FaultAction::Error => Err(format!("chaos: injected error (shard {wid})")),
            FaultAction::SlowError(us) => {
                // a failure that burns real time first: by the time
                // failover runs, rider deadlines may have passed
                std::thread::sleep(Duration::from_micros(us));
                Err(format!("chaos: injected slow error (shard {wid})"))
            }
            FaultAction::Kill => Err(format!("{} (shard {wid})", fault::KILL_SENTINEL)),
            _ => {
                let inputs: Vec<&[f32]> =
                    reqs.iter().map(|r| r.image.as_slice()).collect();
                // a poisoned batch must not kill the worker (same catch
                // as the generic server); the images stay owned by the
                // requests, so a failover retry re-executes the real
                // request with zero restore bookkeeping.  The cluster
                // catches per-core panics internally — this outer catch
                // guards the worker-level injected panic and the
                // dispatch path itself.
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    if injected == FaultAction::Panic {
                        panic!("chaos: injected panic (shard {wid})");
                    }
                    shared.cluster.infer_frame_chaos(&inputs, shared.core_plan.as_deref())
                }))
                .map_err(|p| super::panic_message(p.as_ref()))
                .and_then(|r| r.map_err(|e| e.to_string()))
            }
        };
        match result {
            Ok(mut run) => {
                if injected == FaultAction::CorruptLogits {
                    for res in run.results.iter_mut() {
                        if let Ok((logits, _)) = res {
                            if let Some(first) = logits.first_mut() {
                                *first = i64::MIN;
                            }
                        }
                    }
                }
                // Breaker bookkeeping is per *frame*: a fully clean
                // run heals this worker, any failed core counts one
                // failed batch against it (the core failures
                // themselves are tracked in the cluster and in
                // `Metrics::core_failures`).
                if run.failed_cores.is_empty() {
                    st.consecutive.store(0, Ordering::SeqCst);
                    *st.ejected_until.lock().unwrap() = None;
                } else {
                    metrics.record_core_failures(run.failed_cores.len() as u64);
                    st.errors.fetch_add(1, Ordering::SeqCst);
                    let consecutive = st.consecutive.fetch_add(1, Ordering::SeqCst) + 1;
                    if shared.breaker_threshold > 0 && consecutive >= shared.breaker_threshold
                    {
                        *st.ejected_until.lock().unwrap() =
                            Some(Instant::now() + shared.probation);
                        st.trips.fetch_add(1, Ordering::SeqCst);
                        metrics.record_breaker_trip();
                    }
                }
                // Per-request scatter: successful shards answer their
                // riders exactly as before; a failed core's riders
                // fail over through the ring (once) or reach the
                // client typed.
                let mut riders = Vec::with_capacity(reqs.len());
                let mut cluster_killed = false;
                for (mut r, res) in reqs.into_iter().zip(run.results) {
                    match res {
                        Ok((logits, slot_cycles)) => {
                            let class = argmax_i64(&logits);
                            let lat = r.enqueued.elapsed().as_micros() as u64;
                            riders.push((lat, slot_cycles));
                            let _ = r.resp.send(Ok(InferResult {
                                logits: logits.iter().map(|&v| v as f32).collect(),
                                class,
                                sim_cycles: slot_cycles,
                                batch: fill,
                            }));
                        }
                        Err(e) => {
                            cluster_killed |= fault::is_kill(&e);
                            if r.attempts == 0 {
                                r.attempts = 1;
                                fail_over(shared, r, &e);
                            } else {
                                metrics.record_errors(1);
                                let _ = r.resp.send(Err(ServeError::Worker(e)));
                            }
                        }
                    }
                }
                if !riders.is_empty() {
                    metrics.record_batch(&riders, fill);
                }
                // A killed core only stops THIS core; the worker keeps
                // serving on the survivors.  Once the last core is
                // dead the cluster can never serve again, so the
                // worker exits (the spawn closure marks it dead and
                // the last worker out terminally drains the ring).
                if cluster_killed && shared.cluster.live_cores() == 0 {
                    return;
                }
            }
            Err(e) => {
                st.errors.fetch_add(1, Ordering::SeqCst);
                let consecutive = st.consecutive.fetch_add(1, Ordering::SeqCst) + 1;
                if shared.breaker_threshold > 0 && consecutive >= shared.breaker_threshold {
                    *st.ejected_until.lock().unwrap() =
                        Some(Instant::now() + shared.probation);
                    st.trips.fetch_add(1, Ordering::SeqCst);
                    metrics.record_breaker_trip();
                }
                let killed = fault::is_kill(&e);
                for mut r in reqs {
                    if r.attempts == 0 {
                        // transient failure: one retry through the ring
                        r.attempts = 1;
                        fail_over(shared, r, &e);
                    } else {
                        metrics.record_errors(1);
                        let _ = r.resp.send(Err(ServeError::Worker(e.clone())));
                    }
                }
                if killed {
                    // the spawn closure marks this worker dead; the
                    // last worker out closes and drains the ring
                    return;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qnn::QnnNet;

    fn w2a2() -> QnnPrecision {
        QnnPrecision::SubByte { w_bits: 2, a_bits: 2 }
    }

    #[test]
    fn serves_golden_classifications_through_the_batched_arena() {
        let cache = ProgramCache::new();
        let graph = QnnGraph::sparq_cnn();
        let seed = 0xBA7C_5EED;
        let serve = ServeConfig {
            workers: 2,
            batch_window_us: 200,
            queue_depth: 64,
            batch: 4,
            ..ServeConfig::default()
        };
        let server = QnnBatchServer::start(
            ProcessorConfig::sparq(),
            &graph,
            w2a2(),
            seed,
            serve,
            &cache,
        )
        .unwrap();
        assert_eq!(server.batch(), 4);
        assert_eq!(server.ring_frames(), 16, "queue_depth / batch frames");
        let net = QnnNet::from_seed(&graph, w2a2(), seed).unwrap();
        let images: Vec<Vec<u64>> = (0..8).map(|i| net.test_image(500 + i)).collect();
        let labels: Vec<usize> =
            images.iter().map(|img| net.golden_forward(img).unwrap().argmax).collect();
        let mut pending = Vec::new();
        for img in &images {
            let f: Vec<f32> = img.iter().map(|&v| v as f32).collect();
            pending.push(server.submit(f).expect("submit"));
        }
        for (i, rx) in pending.into_iter().enumerate() {
            let r = rx.recv().unwrap().expect("infer");
            assert_eq!(r.class, labels[i], "image {i} classification diverged from golden");
            assert!(r.sim_cycles > 0);
            assert!(r.batch >= 1 && r.batch <= 4);
        }
        let snap = server.shutdown();
        assert_eq!(snap.completed, 8);
        assert_eq!(snap.errors, 0);
        assert_eq!(snap.batches, snap.batch_fill.iter().map(|&(_, n)| n).sum::<u64>());
        assert_eq!(
            snap.batches,
            snap.seals_full + snap.seals_window,
            "every consumed batch records how it sealed"
        );
        assert!(snap.p50_cycles > 0, "cycle latency percentiles must be recorded");
        assert_eq!(snap.queue_depth, 0, "all queued requests must have drained");
    }

    #[test]
    fn start_surfaces_compile_errors_typed() {
        // fp32 has no dataflow executor: the server must fail to start
        // with a typed Worker error instead of spawning dead workers
        let cache = ProgramCache::new();
        let serve = ServeConfig::default();
        let r = QnnBatchServer::start(
            ProcessorConfig::sparq(),
            &QnnGraph::sparq_cnn(),
            QnnPrecision::Fp32,
            1,
            serve,
            &cache,
        );
        assert!(matches!(r, Err(ServeError::Worker(_))));
    }

    #[test]
    fn wrong_length_image_is_rejected_typed() {
        let cache = ProgramCache::new();
        let serve = ServeConfig { workers: 1, batch: 2, ..ServeConfig::default() };
        let server = QnnBatchServer::start(
            ProcessorConfig::sparq(),
            &QnnGraph::sparq_cnn(),
            w2a2(),
            7,
            serve,
            &cache,
        )
        .unwrap();
        let want = server.image_len();
        match server.submit(vec![0.5; want + 1]) {
            Err(ServeError::BadInput { got, want: w }) => {
                assert_eq!(got, want + 1);
                assert_eq!(w, want);
            }
            other => panic!("expected BadInput, got {other:?}"),
        }
        match server.submit(vec![0.5; 1]) {
            Err(ServeError::BadInput { got: 1, .. }) => {}
            other => panic!("expected BadInput, got {other:?}"),
        }
        let snap = server.shutdown();
        assert_eq!(snap.bad_input, 2);
        assert_eq!(snap.completed, 0);
        assert_eq!(snap.queue_depth, 0);
    }

    #[test]
    fn health_starts_clean() {
        let cache = ProgramCache::new();
        let serve = ServeConfig { workers: 2, batch: 2, ..ServeConfig::default() };
        let server = QnnBatchServer::start(
            ProcessorConfig::sparq(),
            &QnnGraph::sparq_cnn(),
            w2a2(),
            7,
            serve,
            &cache,
        )
        .unwrap();
        let h = server.health();
        assert_eq!(h.alive, 2);
        assert_eq!(h.breaker_trips, 0);
        assert!(h.shards.iter().all(|s| s.alive && !s.ejected && s.errors == 0));
        server.shutdown();
    }

    #[test]
    fn cluster_cores_config_reaches_the_server() {
        let cache = ProgramCache::new();
        let serve = ServeConfig {
            workers: 1,
            batch: 4,
            batch_window_us: 200,
            cores: 3,
            ..ServeConfig::default()
        };
        let server = QnnBatchServer::start(
            ProcessorConfig::sparq(),
            &QnnGraph::sparq_cnn(),
            w2a2(),
            7,
            serve,
            &cache,
        )
        .unwrap();
        assert_eq!(server.cores(), 3);
        assert_eq!(server.shard_policy(), ShardPolicy::RoundRobin);
        let h = server.health();
        assert_eq!(h.cores_alive, 3);
        assert!(h.cores.iter().all(|c| c.alive && c.failures == 0));
        let mut pending = Vec::new();
        for i in 0..6usize {
            let f: Vec<f32> =
                (0..server.image_len()).map(|j| ((i + j) % 4) as f32).collect();
            pending.push(server.submit(f).expect("submit"));
        }
        for rx in pending {
            rx.recv().unwrap().expect("sharded infer");
        }
        let snap = server.shutdown();
        assert_eq!(snap.completed, 6);
        assert_eq!(snap.errors, 0);
        assert_eq!(snap.core_failures, 0);
    }

    #[test]
    fn explicit_ring_frames_override_wins() {
        let cache = ProgramCache::new();
        let serve = ServeConfig {
            workers: 1,
            batch: 2,
            queue_depth: 256,
            ring_frames: 3,
            ..ServeConfig::default()
        };
        let server = QnnBatchServer::start(
            ProcessorConfig::sparq(),
            &QnnGraph::sparq_cnn(),
            w2a2(),
            7,
            serve,
            &cache,
        )
        .unwrap();
        assert_eq!(server.ring_frames(), 4, "explicit frames round up to a power of two");
        server.shutdown();
    }
}
