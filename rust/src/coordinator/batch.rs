//! The batched QNN request path (DESIGN.md §Serving): a sharded,
//! bounded submission queue in front of workers that execute
//! *batch-B* compiled programs.
//!
//! Where the generic [`super::Server`] drives any [`super::Executor`]
//! one image at a time, [`QnnBatchServer`] serves the whole SparqCNN
//! through the batch-B arena layout
//! ([`crate::qnn::compiled::CompiledQnn::compile_batched`]):
//!
//! * **Shard assignment.**  Each worker owns a private bounded queue
//!   (its shard) — no shared-receiver lock.  `submit` assigns requests
//!   round-robin and fails over to the other shards when the chosen
//!   one is full; only when *every* shard is full does the caller see
//!   typed backpressure ([`super::ServeError::QueueFull`]).
//! * **Batching window.**  A worker takes its shard's first request,
//!   drains up to `batch - 1` more within `batch_window_us`, then runs
//!   ONE batched execution: every image staged into its own activation
//!   slot, the per-batch weight-pack preamble paid once, each stage
//!   stream replayed per slot with rebased addresses.
//! * **Scatter.**  Per-image logits/cycles fan back out to each
//!   request's completion channel; the [`Metrics`] sink records
//!   per-request wall *and* simulated-cycle latency plus the executed
//!   batch's fill.
//!
//! Robustness (DESIGN.md §Robustness):
//!
//! * **Shard failover.**  A request whose batch fails with a transient
//!   `Worker` error is retried ONCE on a different shard
//!   (`attempts`-guarded, counted in `Metrics::retries`); only the
//!   second failure reaches the client typed.
//! * **Circuit breaker.**  Per-shard consecutive-error counters eject a
//!   persistently failing shard for a probation window
//!   (`breaker_threshold` / `probation_us` in `ServeConfig`); routing
//!   skips ejected shards, re-admits them when probation expires (the
//!   next request is the probe), and a success heals the shard.  If
//!   every live shard is ejected, routing falls back to alive-only.
//! * **Typed refusals.**  Wrong-length images are rejected at submit
//!   time ([`super::ServeError::BadInput`]) — never truncated or
//!   padded; when every shard worker has died, submit fails fast with
//!   [`super::ServeError::NoWorkers`] instead of queueing forever.
//! * **Graceful drain.**  `shutdown_with_deadline` rejects new work,
//!   finishes queued work until the deadline, sheds the rest typed,
//!   and reports [`super::DrainStats`].
//! * **Deterministic chaos.**  `start_chaos` threads a seeded
//!   [`FaultPlan`] into every shard worker; each executed batch
//!   consults the plan (panic / typed error / kill / delay / corrupt
//!   logits), so the chaos suite replays bit-identically.
//!
//! Per-image results are bit-identical to unbatched inference (the
//! batch determinism tests in `rust/tests/serve_batch.rs` pin logits
//! and cycles), so batching is purely a throughput/amortization
//! decision.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::fault::{self, FaultAction, FaultPlan};
use super::{DrainStats, InferResult, Metrics, ServeError, Snapshot};
use crate::arch::ProcessorConfig;
use crate::config::ServeConfig;
use crate::kernels::ProgramCache;
use crate::qnn::compiled::{argmax_i64, MAX_BATCH};
use crate::qnn::schedule::QnnPrecision;
use crate::qnn::QnnGraph;
use crate::runtime::SimQnnModel;
use crate::sim::MachinePool;

struct BatchRequest {
    image: Vec<f32>,
    resp: SyncSender<Result<InferResult, ServeError>>,
    enqueued: Instant,
    /// Absolute deadline; shed typed pre-execution once passed.
    deadline: Option<Instant>,
    /// Failover retries already spent (max 1).
    attempts: u8,
}

/// Per-shard breaker/liveness state.
#[derive(Debug)]
struct ShardState {
    /// The shard's worker thread is running (cleared on exit).
    alive: AtomicBool,
    /// Consecutive failed batches (a success resets it).
    consecutive: AtomicU32,
    /// Failed batches on this shard, total.
    errors: AtomicU64,
    /// Times the breaker ejected this shard.
    trips: AtomicU64,
    /// While `Some(t)` with `t` in the future, routing skips the shard
    /// (pass 1); expiry re-admits it and a success clears the field.
    ejected_until: Mutex<Option<Instant>>,
}

impl ShardState {
    fn new() -> ShardState {
        ShardState {
            alive: AtomicBool::new(true),
            consecutive: AtomicU32::new(0),
            errors: AtomicU64::new(0),
            trips: AtomicU64::new(0),
            ejected_until: Mutex::new(None),
        }
    }

    fn ejected(&self, now: Instant) -> bool {
        self.ejected_until.lock().unwrap().is_some_and(|t| now < t)
    }
}

/// State shared by the server handle and every shard worker (workers
/// need the sender list to fail requests over to another shard).
struct BatchShared {
    shards: Vec<ShardState>,
    /// `None` once shutdown began: new submits see `Closed`, workers
    /// exit when their queue drains.
    txs: RwLock<Option<Vec<SyncSender<BatchRequest>>>>,
    metrics: Arc<Metrics>,
    /// Graceful-drain deadline (see `shutdown_with_deadline`).
    drain_by: RwLock<Option<Instant>>,
    /// Consecutive errors before ejection; 0 disables the breaker.
    breaker_threshold: u32,
    probation: Duration,
}

/// Per-shard health view (see [`QnnBatchServer::health`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardHealth {
    pub alive: bool,
    /// Failed batches on this shard, total.
    pub errors: u64,
    /// Consecutive failed batches right now.
    pub consecutive_errors: u32,
    /// Times the breaker ejected this shard.
    pub trips: u64,
    /// Currently sitting out a probation window.
    pub ejected: bool,
}

/// Pool-level health of the batched server.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchHealth {
    pub shards: Vec<ShardHealth>,
    /// Shards alive right now.
    pub alive: usize,
    /// Breaker ejections across all shards.
    pub breaker_trips: u64,
}

/// A running batched QNN inference server (simulator backend, no
/// artifacts).  The network compiles once into the shared
/// [`ProgramCache`] under its batched graph-level key; every worker
/// shares the `Arc`'d model and owns a private [`MachinePool`].
pub struct QnnBatchServer {
    shared: Arc<BatchShared>,
    rr: AtomicUsize,
    pub metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
    batch: usize,
    image_len: usize,
    default_deadline: Option<Duration>,
}

impl QnnBatchServer {
    /// Compile the batched network (or fetch it from `cache`) and
    /// start `serve.workers` shard workers at batch size `serve.batch`
    /// (clamped to `1..=`[`MAX_BATCH`]).
    pub fn start(
        cfg: ProcessorConfig,
        graph: &QnnGraph,
        precision: QnnPrecision,
        seed: u64,
        serve: ServeConfig,
        cache: &ProgramCache,
    ) -> Result<QnnBatchServer, ServeError> {
        QnnBatchServer::start_chaos(cfg, graph, precision, seed, serve, cache, None)
    }

    /// [`QnnBatchServer::start`] with a fault-injection plan threaded
    /// into every shard worker — each executed batch consults the plan
    /// once (DESIGN.md §Robustness).  `None` serves clean.
    pub fn start_chaos(
        cfg: ProcessorConfig,
        graph: &QnnGraph,
        precision: QnnPrecision,
        seed: u64,
        serve: ServeConfig,
        cache: &ProgramCache,
        plan: Option<Arc<FaultPlan>>,
    ) -> Result<QnnBatchServer, ServeError> {
        let batch = serve.batch.clamp(1, MAX_BATCH as usize) as u32;
        let model = Arc::new(
            SimQnnModel::compile_batched(&cfg, graph, precision, seed, cache, batch)
                .map_err(|e| ServeError::Worker(e.to_string()))?,
        );
        let workers = serve.workers.max(1);
        // the queue budget splits across the shards (at least 1 each)
        let shard_depth = (serve.queue_depth / workers).max(1);
        let window = Duration::from_micros(serve.batch_window_us);
        let metrics = Arc::new(Metrics::default());
        let image_len = model.input_len();
        let mut txs = Vec::with_capacity(workers);
        let mut rxs = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (tx, rx) = sync_channel::<BatchRequest>(shard_depth);
            txs.push(tx);
            rxs.push(rx);
        }
        let shared = Arc::new(BatchShared {
            shards: (0..workers).map(|_| ShardState::new()).collect(),
            txs: RwLock::new(Some(txs)),
            metrics: Arc::clone(&metrics),
            drain_by: RwLock::new(None),
            breaker_threshold: serve.breaker_threshold,
            probation: Duration::from_micros(serve.probation_us.max(1)),
        });
        let mut handles = Vec::with_capacity(workers);
        for (wid, rx) in rxs.into_iter().enumerate() {
            let shared = Arc::clone(&shared);
            let model = Arc::clone(&model);
            let plan = plan.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("sparq-batch-worker-{wid}"))
                    .spawn(move || {
                        worker_loop(&rx, wid, &shared, &model, window, plan);
                        // Exit path (kill or shutdown): mark the shard
                        // dead, then fail queued work over to the live
                        // shards.  A request that races into the queue
                        // after this drain is dropped with the channel
                        // — its client sees a typed `Closed`, never a
                        // hang.
                        shared.shards[wid].alive.store(false, Ordering::SeqCst);
                        while let Ok(req) = rx.try_recv() {
                            shared.metrics.queue_dec(1);
                            fail_over(&shared, wid, req, "shard worker exited");
                        }
                    })
                    .map_err(|e| ServeError::Worker(e.to_string()))?,
            );
        }
        Ok(QnnBatchServer {
            shared,
            rr: AtomicUsize::new(0),
            metrics,
            workers: handles,
            batch: batch as usize,
            image_len,
            default_deadline: (serve.deadline_us > 0)
                .then(|| Duration::from_micros(serve.deadline_us)),
        })
    }

    /// The compiled batch size workers execute at.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Per-image input length (c * h * w).
    pub fn image_len(&self) -> usize {
        self.image_len
    }

    /// Non-blocking submit with the config-level default deadline.
    pub fn submit(
        &self,
        image: Vec<f32>,
    ) -> Result<Receiver<Result<InferResult, ServeError>>, ServeError> {
        self.submit_with_deadline(image, self.default_deadline)
    }

    /// Non-blocking submit with an explicit per-request deadline:
    /// round-robin shard assignment, skipping dead and breaker-ejected
    /// shards (ejected-but-alive shards are a second-pass fallback so
    /// an all-ejected pool still serves); [`ServeError::QueueFull`]
    /// only when every candidate shard is at capacity.  Wrong-length
    /// images are refused typed ([`ServeError::BadInput`]); a fully
    /// dead pool fails fast ([`ServeError::NoWorkers`]).
    pub fn submit_with_deadline(
        &self,
        image: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Result<Receiver<Result<InferResult, ServeError>>, ServeError> {
        if image.len() != self.image_len {
            self.metrics.record_bad_input();
            return Err(ServeError::BadInput { got: image.len(), want: self.image_len });
        }
        let g = self.shared.txs.read().unwrap();
        let Some(txs) = g.as_ref() else {
            return Err(ServeError::Closed);
        };
        if !self.shared.shards.iter().any(|s| s.alive.load(Ordering::SeqCst)) {
            self.metrics.record_no_workers(1);
            return Err(ServeError::NoWorkers);
        }
        let n = txs.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        let (rtx, rrx) = sync_channel(1);
        let now = Instant::now();
        let mut req = BatchRequest {
            image,
            resp: rtx,
            enqueued: now,
            deadline: deadline.map(|d| now + d),
            attempts: 0,
        };
        // gauge BEFORE the send: a worker may dequeue (and queue_dec)
        // the instant try_send lands, and inc-after-send would let the
        // gauge transiently read negative
        self.metrics.queue_inc();
        for pass in 0..2 {
            for k in 0..n {
                let i = (start + k) % n;
                let st = &self.shared.shards[i];
                if !st.alive.load(Ordering::SeqCst) {
                    continue;
                }
                if pass == 0 && st.ejected(now) {
                    continue;
                }
                req = match txs[i].try_send(req) {
                    Ok(()) => return Ok(rrx),
                    Err(TrySendError::Full(r)) | Err(TrySendError::Disconnected(r)) => r,
                };
            }
        }
        self.metrics.queue_dec(1);
        self.metrics.record_rejected();
        Err(ServeError::QueueFull)
    }

    /// Blocking inference.
    pub fn infer(&self, image: Vec<f32>) -> Result<InferResult, ServeError> {
        let rx = self.submit(image)?;
        rx.recv().map_err(|_| ServeError::Closed)?
    }

    /// Bounded-time inference: the request carries `timeout` as its
    /// deadline; returns [`ServeError::Deadline`] if no response
    /// arrives within it.  Never blocks longer than `timeout`.
    pub fn infer_timeout(
        &self,
        image: Vec<f32>,
        timeout: Duration,
    ) -> Result<InferResult, ServeError> {
        let rx = self.submit_with_deadline(image, Some(timeout))?;
        match rx.recv_timeout(timeout) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => Err(ServeError::Deadline),
            Err(RecvTimeoutError::Disconnected) => Err(ServeError::Closed),
        }
    }

    /// Shard/breaker health right now.
    pub fn health(&self) -> BatchHealth {
        let now = Instant::now();
        let shards: Vec<ShardHealth> = self
            .shared
            .shards
            .iter()
            .map(|s| ShardHealth {
                alive: s.alive.load(Ordering::SeqCst),
                errors: s.errors.load(Ordering::SeqCst),
                consecutive_errors: s.consecutive.load(Ordering::SeqCst),
                trips: s.trips.load(Ordering::SeqCst),
                ejected: s.ejected(now),
            })
            .collect();
        let alive = shards.iter().filter(|s| s.alive).count();
        let breaker_trips = shards.iter().map(|s| s.trips).sum();
        BatchHealth { shards, alive, breaker_trips }
    }

    /// Drain the shards fully, stop the workers, return the final
    /// metrics (the original unbounded drain).
    pub fn shutdown(mut self) -> Snapshot {
        self.stop_workers();
        self.metrics.snapshot()
    }

    /// Graceful bounded drain: stop accepting work immediately, let
    /// queued work finish until `deadline`, shed the rest typed with
    /// [`ServeError::Closed`], and report what happened.  In-flight
    /// batches run to completion, so the wall time is bounded by the
    /// deadline plus one batch execution.
    pub fn shutdown_with_deadline(mut self, deadline: Duration) -> (Snapshot, DrainStats) {
        let t0 = Instant::now();
        let before = self.metrics.snapshot();
        *self.shared.drain_by.write().unwrap() = Some(t0 + deadline);
        self.stop_workers();
        let after = self.metrics.snapshot();
        let stats = DrainStats {
            completed: after.completed.saturating_sub(before.completed),
            shed: after.drain_shed.saturating_sub(before.drain_shed),
            wall_us: t0.elapsed().as_micros() as u64,
        };
        (after, stats)
    }

    fn stop_workers(&mut self) {
        // close every shard; workers exit once their queue drains
        self.shared.txs.write().unwrap().take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Re-queue `req` on any live shard other than `from` (ejected shards
/// are a second-pass fallback).  If no shard can take it, the request
/// fails typed with the originating error.
fn fail_over(shared: &BatchShared, from: usize, mut req: BatchRequest, err: &str) {
    {
        let g = shared.txs.read().unwrap();
        if let Some(txs) = g.as_ref() {
            let now = Instant::now();
            shared.metrics.queue_inc();
            for pass in 0..2 {
                for (i, tx) in txs.iter().enumerate() {
                    if i == from || !shared.shards[i].alive.load(Ordering::SeqCst) {
                        continue;
                    }
                    if pass == 0 && shared.shards[i].ejected(now) {
                        continue;
                    }
                    req = match tx.try_send(req) {
                        Ok(()) => {
                            shared.metrics.record_retries(1);
                            return;
                        }
                        Err(TrySendError::Full(r)) | Err(TrySendError::Disconnected(r)) => r,
                    };
                }
            }
            shared.metrics.queue_dec(1);
        }
    }
    shared.metrics.record_errors(1);
    let _ = req.resp.send(Err(ServeError::Worker(err.to_string())));
}

fn worker_loop(
    rx: &Receiver<BatchRequest>,
    wid: usize,
    shared: &Arc<BatchShared>,
    model: &Arc<SimQnnModel>,
    window: Duration,
    plan: Option<Arc<FaultPlan>>,
) {
    let pool = MachinePool::new();
    let batch = model.batch();
    let metrics = &shared.metrics;
    loop {
        // take the shard's first request (blocking), then fill the
        // batch greedily within the window
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // shard closed: shut down
        };
        metrics.queue_dec(1);
        let mut reqs = vec![first];
        let wdl = Instant::now() + window;
        while reqs.len() < batch {
            let left = wdl.saturating_duration_since(Instant::now());
            match rx.recv_timeout(left) {
                Ok(r) => {
                    metrics.queue_dec(1);
                    reqs.push(r);
                }
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        // Graceful drain: past the drain deadline, queued work is shed
        // typed instead of executed.
        if let Some(dl) = *shared.drain_by.read().unwrap() {
            if Instant::now() > dl {
                metrics.record_drain_shed(reqs.len() as u64);
                for r in reqs {
                    let _ = r.resp.send(Err(ServeError::Closed));
                }
                continue;
            }
        }

        // Deadline shedding: expired requests are answered typed and
        // never executed.
        let now = Instant::now();
        let mut shed = 0u64;
        reqs.retain(|r| match r.deadline {
            Some(d) if now > d => {
                shed += 1;
                let _ = r.resp.send(Err(ServeError::Deadline));
                false
            }
            _ => true,
        });
        if shed > 0 {
            metrics.record_deadline_shed(shed);
        }
        if reqs.is_empty() {
            continue;
        }

        // One fault-plan consult per executed batch.
        let injected =
            plan.as_ref().map(|p| p.next_for(wid)).unwrap_or(FaultAction::None);
        if let FaultAction::Delay(us) = injected {
            std::thread::sleep(Duration::from_micros(us));
        }

        let fill = reqs.len() as u32;
        // `submit` validated every image length, so images stage into
        // the arena exactly as sent — no truncation, no padding.
        let result: Result<(Vec<(Vec<i64>, u64)>, u64), String> = match injected {
            FaultAction::Error => Err(format!("chaos: injected error (shard {wid})")),
            FaultAction::Kill => Err(format!("{} (shard {wid})", fault::KILL_SENTINEL)),
            _ => {
                let inputs: Vec<Vec<f32>> =
                    reqs.iter_mut().map(|r| std::mem::take(&mut r.image)).collect();
                // a poisoned batch must not kill the worker (same catch
                // as the generic server)
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    if injected == FaultAction::Panic {
                        panic!("chaos: injected panic (shard {wid})");
                    }
                    model.infer_batch(&pool, &inputs)
                }))
                .map_err(|p| super::panic_message(p.as_ref()))
                .and_then(|r| r.map_err(|e| e.to_string()));
                if res.is_err() {
                    // restore the images so a failover retry re-executes
                    // the real request, not an empty one
                    for (r, img) in reqs.iter_mut().zip(inputs) {
                        r.image = img;
                    }
                }
                res
            }
        };
        let st = &shared.shards[wid];
        match result {
            Ok((mut per_image, _batch_cycles)) => {
                if injected == FaultAction::CorruptLogits {
                    for (logits, _) in per_image.iter_mut() {
                        if let Some(first) = logits.first_mut() {
                            *first = i64::MIN;
                        }
                    }
                }
                // a success heals the breaker
                st.consecutive.store(0, Ordering::SeqCst);
                *st.ejected_until.lock().unwrap() = None;
                let mut riders = Vec::with_capacity(reqs.len());
                for (r, (logits, slot_cycles)) in reqs.into_iter().zip(per_image) {
                    let class = argmax_i64(&logits);
                    let lat = r.enqueued.elapsed().as_micros() as u64;
                    riders.push((lat, slot_cycles));
                    let _ = r.resp.send(Ok(InferResult {
                        logits: logits.iter().map(|&v| v as f32).collect(),
                        class,
                        sim_cycles: slot_cycles,
                        batch: fill,
                    }));
                }
                metrics.record_batch(&riders, fill);
            }
            Err(e) => {
                st.errors.fetch_add(1, Ordering::SeqCst);
                let consecutive = st.consecutive.fetch_add(1, Ordering::SeqCst) + 1;
                if shared.breaker_threshold > 0 && consecutive >= shared.breaker_threshold {
                    *st.ejected_until.lock().unwrap() =
                        Some(Instant::now() + shared.probation);
                    st.trips.fetch_add(1, Ordering::SeqCst);
                    metrics.record_breaker_trip();
                }
                let killed = fault::is_kill(&e);
                for mut r in reqs {
                    if r.attempts == 0 {
                        // transient failure: one retry on another shard
                        r.attempts = 1;
                        fail_over(shared, wid, r, &e);
                    } else {
                        metrics.record_errors(1);
                        let _ = r.resp.send(Err(ServeError::Worker(e.clone())));
                    }
                }
                if killed {
                    // the spawn closure marks the shard dead and fails
                    // queued work over to the surviving shards
                    return;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qnn::QnnNet;

    fn w2a2() -> QnnPrecision {
        QnnPrecision::SubByte { w_bits: 2, a_bits: 2 }
    }

    #[test]
    fn serves_golden_classifications_through_the_batched_arena() {
        let cache = ProgramCache::new();
        let graph = QnnGraph::sparq_cnn();
        let seed = 0xBA7C_5EED;
        let serve = ServeConfig {
            workers: 2,
            batch_window_us: 200,
            queue_depth: 64,
            batch: 4,
            ..ServeConfig::default()
        };
        let server = QnnBatchServer::start(
            ProcessorConfig::sparq(),
            &graph,
            w2a2(),
            seed,
            serve,
            &cache,
        )
        .unwrap();
        assert_eq!(server.batch(), 4);
        let net = QnnNet::from_seed(&graph, w2a2(), seed).unwrap();
        let images: Vec<Vec<u64>> = (0..8).map(|i| net.test_image(500 + i)).collect();
        let labels: Vec<usize> =
            images.iter().map(|img| net.golden_forward(img).unwrap().argmax).collect();
        let mut pending = Vec::new();
        for img in &images {
            let f: Vec<f32> = img.iter().map(|&v| v as f32).collect();
            pending.push(server.submit(f).expect("submit"));
        }
        for (i, rx) in pending.into_iter().enumerate() {
            let r = rx.recv().unwrap().expect("infer");
            assert_eq!(r.class, labels[i], "image {i} classification diverged from golden");
            assert!(r.sim_cycles > 0);
            assert!(r.batch >= 1 && r.batch <= 4);
        }
        let snap = server.shutdown();
        assert_eq!(snap.completed, 8);
        assert_eq!(snap.errors, 0);
        assert_eq!(snap.batches, snap.batch_fill.iter().map(|&(_, n)| n).sum::<u64>());
        assert!(snap.p50_cycles > 0, "cycle latency percentiles must be recorded");
        assert_eq!(snap.queue_depth, 0, "all queued requests must have drained");
    }

    #[test]
    fn start_surfaces_compile_errors_typed() {
        // fp32 has no dataflow executor: the server must fail to start
        // with a typed Worker error instead of spawning dead workers
        let cache = ProgramCache::new();
        let serve = ServeConfig::default();
        let r = QnnBatchServer::start(
            ProcessorConfig::sparq(),
            &QnnGraph::sparq_cnn(),
            QnnPrecision::Fp32,
            1,
            serve,
            &cache,
        );
        assert!(matches!(r, Err(ServeError::Worker(_))));
    }

    #[test]
    fn wrong_length_image_is_rejected_typed() {
        let cache = ProgramCache::new();
        let serve = ServeConfig { workers: 1, batch: 2, ..ServeConfig::default() };
        let server = QnnBatchServer::start(
            ProcessorConfig::sparq(),
            &QnnGraph::sparq_cnn(),
            w2a2(),
            7,
            serve,
            &cache,
        )
        .unwrap();
        let want = server.image_len();
        match server.submit(vec![0.5; want + 1]) {
            Err(ServeError::BadInput { got, want: w }) => {
                assert_eq!(got, want + 1);
                assert_eq!(w, want);
            }
            other => panic!("expected BadInput, got {other:?}"),
        }
        match server.submit(vec![0.5; 1]) {
            Err(ServeError::BadInput { got: 1, .. }) => {}
            other => panic!("expected BadInput, got {other:?}"),
        }
        let snap = server.shutdown();
        assert_eq!(snap.bad_input, 2);
        assert_eq!(snap.completed, 0);
        assert_eq!(snap.queue_depth, 0);
    }

    #[test]
    fn health_starts_clean() {
        let cache = ProgramCache::new();
        let serve = ServeConfig { workers: 2, batch: 2, ..ServeConfig::default() };
        let server = QnnBatchServer::start(
            ProcessorConfig::sparq(),
            &QnnGraph::sparq_cnn(),
            w2a2(),
            7,
            serve,
            &cache,
        )
        .unwrap();
        let h = server.health();
        assert_eq!(h.alive, 2);
        assert_eq!(h.breaker_trips, 0);
        assert!(h.shards.iter().all(|s| s.alive && !s.ejected && s.errors == 0));
        server.shutdown();
    }
}
