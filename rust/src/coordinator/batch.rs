//! The batched QNN request path (DESIGN.md §Serving): a sharded,
//! bounded submission queue in front of workers that execute
//! *batch-B* compiled programs.
//!
//! Where the generic [`super::Server`] drives any [`super::Executor`]
//! one image at a time, [`QnnBatchServer`] serves the whole SparqCNN
//! through the batch-B arena layout
//! ([`crate::qnn::compiled::CompiledQnn::compile_batched`]):
//!
//! * **Shard assignment.**  Each worker owns a private bounded queue
//!   (its shard) — no shared-receiver lock.  `submit` assigns requests
//!   round-robin and fails over to the other shards when the chosen
//!   one is full; only when *every* shard is full does the caller see
//!   typed backpressure ([`super::ServeError::QueueFull`]).
//! * **Batching window.**  A worker takes its shard's first request,
//!   drains up to `batch - 1` more within `batch_window_us`, then runs
//!   ONE batched execution: every image staged into its own activation
//!   slot, the per-batch weight-pack preamble paid once, each stage
//!   stream replayed per slot with rebased addresses.
//! * **Scatter.**  Per-image logits/cycles fan back out to each
//!   request's completion channel; the [`Metrics`] sink records
//!   per-request wall *and* simulated-cycle latency plus the executed
//!   batch's fill.
//!
//! Per-image results are bit-identical to unbatched inference (the
//! batch determinism tests in `rust/tests/serve_batch.rs` pin logits
//! and cycles), so batching is purely a throughput/amortization
//! decision.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::{InferResult, Metrics, ServeError, Snapshot};
use crate::arch::ProcessorConfig;
use crate::config::ServeConfig;
use crate::kernels::ProgramCache;
use crate::qnn::compiled::{argmax_i64, MAX_BATCH};
use crate::qnn::schedule::QnnPrecision;
use crate::qnn::QnnGraph;
use crate::runtime::SimQnnModel;
use crate::sim::MachinePool;

struct BatchRequest {
    image: Vec<f32>,
    resp: SyncSender<Result<InferResult, ServeError>>,
    enqueued: Instant,
}

/// A running batched QNN inference server (simulator backend, no
/// artifacts).  The network compiles once into the shared
/// [`ProgramCache`] under its batched graph-level key; every worker
/// shares the `Arc`'d model and owns a private [`MachinePool`].
pub struct QnnBatchServer {
    shards: Option<Vec<SyncSender<BatchRequest>>>,
    rr: AtomicUsize,
    pub metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
    batch: usize,
    image_len: usize,
}

impl QnnBatchServer {
    /// Compile the batched network (or fetch it from `cache`) and
    /// start `serve.workers` shard workers at batch size `serve.batch`
    /// (clamped to `1..=`[`MAX_BATCH`]).
    pub fn start(
        cfg: ProcessorConfig,
        graph: &QnnGraph,
        precision: QnnPrecision,
        seed: u64,
        serve: ServeConfig,
        cache: &ProgramCache,
    ) -> Result<QnnBatchServer, ServeError> {
        let batch = serve.batch.clamp(1, MAX_BATCH as usize) as u32;
        let model = Arc::new(
            SimQnnModel::compile_batched(&cfg, graph, precision, seed, cache, batch)
                .map_err(|e| ServeError::Worker(e.to_string()))?,
        );
        let workers = serve.workers.max(1);
        // the queue budget splits across the shards (at least 1 each)
        let shard_depth = (serve.queue_depth / workers).max(1);
        let window = Duration::from_micros(serve.batch_window_us);
        let metrics = Arc::new(Metrics::default());
        let image_len = model.input_len();
        let mut shards = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for wid in 0..workers {
            let (tx, rx) = sync_channel::<BatchRequest>(shard_depth);
            shards.push(tx);
            let metrics = Arc::clone(&metrics);
            let model = Arc::clone(&model);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("sparq-batch-worker-{wid}"))
                    .spawn(move || worker_loop(rx, metrics, model, window))
                    .map_err(|e| ServeError::Worker(e.to_string()))?,
            );
        }
        Ok(QnnBatchServer {
            shards: Some(shards),
            rr: AtomicUsize::new(0),
            metrics,
            workers: handles,
            batch: batch as usize,
            image_len,
        })
    }

    /// The compiled batch size workers execute at.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Per-image input length (c * h * w).
    pub fn image_len(&self) -> usize {
        self.image_len
    }

    /// Non-blocking submit: round-robin shard assignment with failover
    /// — the request lands on the first non-full shard after its
    /// assigned one; [`ServeError::QueueFull`] only when every shard
    /// is at capacity (typed backpressure, recorded in the metrics).
    pub fn submit(
        &self,
        image: Vec<f32>,
    ) -> Result<Receiver<Result<InferResult, ServeError>>, ServeError> {
        let shards = self.shards.as_ref().ok_or(ServeError::Closed)?;
        let n = shards.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        let (rtx, rrx) = sync_channel(1);
        let mut req = BatchRequest { image, resp: rtx, enqueued: Instant::now() };
        // gauge BEFORE the send: a worker may dequeue (and queue_dec)
        // the instant try_send lands, and inc-after-send would let the
        // gauge transiently read negative
        self.metrics.queue_inc();
        for k in 0..n {
            match shards[(start + k) % n].try_send(req) {
                Ok(()) => return Ok(rrx),
                Err(TrySendError::Full(r)) => req = r,
                Err(TrySendError::Disconnected(_)) => {
                    self.metrics.queue_dec(1);
                    return Err(ServeError::Closed);
                }
            }
        }
        self.metrics.queue_dec(1);
        self.metrics.record_rejected();
        Err(ServeError::QueueFull)
    }

    /// Blocking inference.
    pub fn infer(&self, image: Vec<f32>) -> Result<InferResult, ServeError> {
        let rx = self.submit(image)?;
        rx.recv().map_err(|_| ServeError::Closed)?
    }

    /// Drain the shards, stop the workers, return the final metrics.
    pub fn shutdown(mut self) -> Snapshot {
        self.shards.take(); // close every shard; workers exit on disconnect
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

fn worker_loop(
    rx: Receiver<BatchRequest>,
    metrics: Arc<Metrics>,
    model: Arc<SimQnnModel>,
    window: Duration,
) {
    let pool = MachinePool::new();
    let batch = model.batch();
    let per = model.input_len();
    loop {
        // take the shard's first request (blocking), then fill the
        // batch greedily within the window
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // shard closed: shut down
        };
        metrics.queue_dec(1);
        let mut reqs = vec![first];
        let deadline = Instant::now() + window;
        while reqs.len() < batch {
            let left = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(left) {
                Ok(r) => {
                    metrics.queue_dec(1);
                    reqs.push(r);
                }
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        // normalize request images to the model's input length (short
        // images zero-pad, long ones truncate — same contract as the
        // generic server's padded batch assembly).  Taken by value:
        // the request only needs its channel/timestamp from here on,
        // so the hot path pays no per-image copy.
        let inputs: Vec<Vec<f32>> = reqs
            .iter_mut()
            .map(|r| {
                let mut img = std::mem::take(&mut r.image);
                img.resize(per, 0.0);
                img
            })
            .collect();
        // a poisoned batch must not kill the worker (same catch as the
        // generic server)
        let result: Result<_, String> =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                model.infer_batch(&pool, &inputs)
            }))
            .map_err(|p| super::panic_message(p.as_ref()))
            .and_then(|r| r.map_err(|e| e.to_string()));
        let fill = reqs.len() as u32;
        match result {
            Ok((per_image, _batch_cycles)) => {
                let mut riders = Vec::with_capacity(reqs.len());
                for (r, (logits, slot_cycles)) in reqs.into_iter().zip(per_image) {
                    let class = argmax_i64(&logits);
                    let lat = r.enqueued.elapsed().as_micros() as u64;
                    riders.push((lat, slot_cycles));
                    let _ = r.resp.send(Ok(InferResult {
                        logits: logits.iter().map(|&v| v as f32).collect(),
                        class,
                        sim_cycles: slot_cycles,
                        batch: fill,
                    }));
                }
                metrics.record_batch(&riders, fill);
            }
            Err(e) => {
                metrics.record_errors(reqs.len() as u64);
                for r in reqs {
                    let _ = r.resp.send(Err(ServeError::Worker(e.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qnn::QnnNet;

    fn w2a2() -> QnnPrecision {
        QnnPrecision::SubByte { w_bits: 2, a_bits: 2 }
    }

    #[test]
    fn serves_golden_classifications_through_the_batched_arena() {
        let cache = ProgramCache::new();
        let graph = QnnGraph::sparq_cnn();
        let seed = 0xBA7C_5EED;
        let serve =
            ServeConfig { workers: 2, batch_window_us: 200, queue_depth: 64, batch: 4 };
        let server = QnnBatchServer::start(
            ProcessorConfig::sparq(),
            &graph,
            w2a2(),
            seed,
            serve,
            &cache,
        )
        .unwrap();
        assert_eq!(server.batch(), 4);
        let net = QnnNet::from_seed(&graph, w2a2(), seed).unwrap();
        let images: Vec<Vec<u64>> = (0..8).map(|i| net.test_image(500 + i)).collect();
        let labels: Vec<usize> =
            images.iter().map(|img| net.golden_forward(img).unwrap().argmax).collect();
        let mut pending = Vec::new();
        for img in &images {
            let f: Vec<f32> = img.iter().map(|&v| v as f32).collect();
            pending.push(server.submit(f).expect("submit"));
        }
        for (i, rx) in pending.into_iter().enumerate() {
            let r = rx.recv().unwrap().expect("infer");
            assert_eq!(r.class, labels[i], "image {i} classification diverged from golden");
            assert!(r.sim_cycles > 0);
            assert!(r.batch >= 1 && r.batch <= 4);
        }
        let snap = server.shutdown();
        assert_eq!(snap.completed, 8);
        assert_eq!(snap.errors, 0);
        assert_eq!(snap.batches, snap.batch_fill.iter().map(|&(_, n)| n).sum::<u64>());
        assert!(snap.p50_cycles > 0, "cycle latency percentiles must be recorded");
        assert_eq!(snap.queue_depth, 0, "all queued requests must have drained");
    }

    #[test]
    fn start_surfaces_compile_errors_typed() {
        // fp32 has no dataflow executor: the server must fail to start
        // with a typed Worker error instead of spawning dead workers
        let cache = ProgramCache::new();
        let serve = ServeConfig::default();
        let r = QnnBatchServer::start(
            ProcessorConfig::sparq(),
            &QnnGraph::sparq_cnn(),
            QnnPrecision::Fp32,
            1,
            serve,
            &cache,
        );
        assert!(matches!(r, Err(ServeError::Worker(_))));
    }
}
