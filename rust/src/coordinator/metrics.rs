//! Serving metrics: latency distribution (wall *and* simulated
//! cycles), batch-fill histogram, queue-depth gauge, throughput — the
//! numbers the e2e example, `sparq serve` and the serve benches report.
//!
//! Latency histories are **bounded**: percentiles come from a
//! fixed-capacity reservoir sample ([`SAMPLE_CAP`] values per series,
//! Algorithm R with a deterministic xorshift stream), so a server that
//! lives for millions of requests holds a few pages of history instead
//! of growing without bound, and `snapshot()` sorts at most
//! [`SAMPLE_CAP`] values under the mutex instead of the entire run.
//! Every *counter* stays exact — `completed`, `total_sim_cycles`,
//! `mean_batch` (an exact running sum, not a sample), the fill
//! histogram and all robustness counters never lose a count.  Below
//! the cap the reservoir holds every value, so small runs report exact
//! percentiles.

use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Values retained per latency series for percentile estimation.
/// Below this cap percentiles are exact; above it they are a uniform
/// sample of the whole run (Algorithm R), so long-run percentiles stay
/// stable while memory stays flat.
pub const SAMPLE_CAP: usize = 4096;

/// Fixed-capacity uniform sample of an unbounded stream (Vitter's
/// Algorithm R).  The replacement stream is a deterministic
/// xorshift64*, so identical record sequences produce identical
/// samples — snapshot percentiles are replayable.
#[derive(Debug)]
struct Reservoir {
    /// Values offered so far (exact).
    seen: u64,
    samples: Vec<u64>,
    rng: u64,
}

impl Reservoir {
    fn new(seed: u64) -> Reservoir {
        Reservoir { seen: 0, samples: Vec::new(), rng: seed | 1 }
    }

    fn next(&mut self) -> u64 {
        let mut x = self.rng;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.rng = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    fn offer(&mut self, v: u64) {
        self.seen += 1;
        if self.samples.len() < SAMPLE_CAP {
            self.samples.push(v);
        } else {
            // keep each of the `seen` values with probability cap/seen
            let j = self.next() % self.seen;
            if (j as usize) < SAMPLE_CAP {
                self.samples[j as usize] = v;
            }
        }
    }

    /// A sorted copy of the sample (at most [`SAMPLE_CAP`] values).
    fn sorted(&self) -> Vec<u64> {
        let mut s = self.samples.clone();
        s.sort_unstable();
        s
    }
}

/// Thread-safe metrics sink shared between workers and the caller.
#[derive(Debug)]
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Instant,
    /// Requests currently sitting in the submission ring (gauge).
    depth: AtomicI64,
    /// High-water mark of the queue-depth gauge.
    depth_max: AtomicI64,
}

#[derive(Debug)]
struct Inner {
    /// Wall-latency reservoir (bounded; see the module docs).
    latencies_us: Reservoir,
    /// Per-request simulated-cycle latency reservoir (the hardware
    /// cost the request's inference was billed — slot cycles on the
    /// batched path).
    cycle_lats: Reservoir,
    /// Exact running sum of per-request batch sizes (`mean_batch` =
    /// sum / completed — no history needed).
    batch_size_sum: u64,
    /// Executed-batch fill histogram: `fill_hist[k]` = batches that
    /// ran with exactly `k` riders.
    fill_hist: Vec<u64>,
    /// Batches executed (the sum of `fill_hist`).
    batches: u64,
    /// Batches sealed by their last writer (the frame filled).
    seals_full: u64,
    /// Batches sealed by window expiry or close (underfilled frames
    /// dispatched so latency stays bounded).
    seals_window: u64,
    completed: u64,
    rejected: u64,
    /// Requests that got an error response instead of a result
    /// (executor errors and caught executor panics count once per
    /// request in the failed batch; a worker init failure counts 1).
    errors: u64,
    /// Requests shed by a worker because their deadline had already
    /// passed at dequeue time (never executed).
    deadline_shed: u64,
    /// Requests rejected at submit time for a wrong-length image.
    bad_input: u64,
    /// Requests re-queued after a transient worker error (batched
    /// path failover).
    retries: u64,
    /// Circuit-breaker ejections of a persistently failing shard.
    breaker_trips: u64,
    /// Queued requests shed during a deadline-bounded drain.
    drain_shed: u64,
    /// Requests refused (at submit or by the terminal queue drain)
    /// because the worker pool was empty with no restart budget left.
    no_workers: u64,
    /// Failed cluster-core executions (a frame whose shard failed on
    /// `n` cores counts `n`; see `coordinator::cluster`).
    core_failures: u64,
    sim_cycles: u128,
}

impl Default for Inner {
    fn default() -> Inner {
        Inner {
            // distinct fixed seeds: the two reservoirs must not make
            // correlated keep/evict decisions
            latencies_us: Reservoir::new(0x9E37_79B9_7F4A_7C15),
            cycle_lats: Reservoir::new(0xD1B5_4A32_D192_ED03),
            batch_size_sum: 0,
            fill_hist: Vec::new(),
            batches: 0,
            seals_full: 0,
            seals_window: 0,
            completed: 0,
            rejected: 0,
            errors: 0,
            deadline_shed: 0,
            bad_input: 0,
            retries: 0,
            breaker_trips: 0,
            drain_shed: 0,
            no_workers: 0,
            core_failures: 0,
            sim_cycles: 0,
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            inner: Mutex::new(Inner::default()),
            started: Instant::now(),
            depth: AtomicI64::new(0),
            depth_max: AtomicI64::new(0),
        }
    }
}

impl Metrics {
    pub fn record(&self, latency_us: u64, batch: u32, sim_cycles: u64) {
        let mut g = self.inner.lock().unwrap();
        record_one(&mut g, latency_us, batch, sim_cycles);
    }

    /// Record every rider of one executed batch under a single lock
    /// (the batched worker's per-batch bookkeeping), plus the batch's
    /// fill in the histogram.
    pub fn record_batch(&self, riders: &[(u64, u64)], fill: u32) {
        let mut g = self.inner.lock().unwrap();
        for &(latency_us, sim_cycles) in riders {
            record_one(&mut g, latency_us, fill, sim_cycles);
        }
        record_fill(&mut g, fill);
    }

    /// Record one executed batch's fill (size) in the histogram.
    pub fn record_fill(&self, fill: u32) {
        let mut g = self.inner.lock().unwrap();
        record_fill(&mut g, fill);
    }

    /// A consumed batch frame sealed by window expiry/close
    /// (`by_window`) or by its last writer filling it.
    pub fn record_seal(&self, by_window: bool) {
        let mut g = self.inner.lock().unwrap();
        if by_window {
            g.seals_window += 1;
        } else {
            g.seals_full += 1;
        }
    }

    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// Worker-side failures: `n` = number of requests that received an
    /// error response (a failed batch counts once per rider; a worker
    /// init failure, which serves nobody, counts 1).
    pub fn record_errors(&self, n: u64) {
        self.inner.lock().unwrap().errors += n;
    }

    /// `n` requests were shed unexecuted because their deadline had
    /// passed before a worker could start them.
    pub fn record_deadline_shed(&self, n: u64) {
        self.inner.lock().unwrap().deadline_shed += n;
    }

    /// A submit was refused for a wrong-length image.
    pub fn record_bad_input(&self) {
        self.inner.lock().unwrap().bad_input += 1;
    }

    /// `n` requests were re-queued after a transient worker error.
    pub fn record_retries(&self, n: u64) {
        self.inner.lock().unwrap().retries += n;
    }

    /// The circuit breaker ejected a shard.
    pub fn record_breaker_trip(&self) {
        self.inner.lock().unwrap().breaker_trips += 1;
    }

    /// `n` queued requests were shed by a deadline-bounded drain.
    pub fn record_drain_shed(&self, n: u64) {
        self.inner.lock().unwrap().drain_shed += n;
    }

    /// `n` requests were refused because the worker pool was empty
    /// with no restart budget left.
    pub fn record_no_workers(&self, n: u64) {
        self.inner.lock().unwrap().no_workers += n;
    }

    /// `n` cluster-core executions failed while serving one frame
    /// (kill/error/panic on a core; the frame's other shards still
    /// scattered normally).
    pub fn record_core_failures(&self, n: u64) {
        self.inner.lock().unwrap().core_failures += n;
    }

    /// A request entered the submission ring.
    pub fn queue_inc(&self) {
        let d = self.depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.depth_max.fetch_max(d, Ordering::Relaxed);
    }

    /// `n` requests left the submission ring (a worker drained them).
    pub fn queue_dec(&self, n: u64) {
        self.depth.fetch_sub(n as i64, Ordering::Relaxed);
    }

    /// Snapshot of the distribution so far.  Percentiles are exact
    /// below [`SAMPLE_CAP`] recorded requests and reservoir estimates
    /// above it; every counter is exact regardless.
    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        let lat = g.latencies_us.sorted();
        let cyc = g.cycle_lats.sorted();
        let pct = |sorted: &[u64], p: f64| -> u64 {
            if sorted.is_empty() {
                return 0;
            }
            let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
            sorted[idx]
        };
        let elapsed = self.started.elapsed().as_secs_f64();
        Snapshot {
            completed: g.completed,
            rejected: g.rejected,
            errors: g.errors,
            deadline_shed: g.deadline_shed,
            bad_input: g.bad_input,
            retries: g.retries,
            breaker_trips: g.breaker_trips,
            drain_shed: g.drain_shed,
            no_workers: g.no_workers,
            core_failures: g.core_failures,
            p50_us: pct(&lat, 0.50),
            p95_us: pct(&lat, 0.95),
            p99_us: pct(&lat, 0.99),
            p50_cycles: pct(&cyc, 0.50),
            p99_cycles: pct(&cyc, 0.99),
            mean_batch: if g.completed == 0 {
                0.0
            } else {
                g.batch_size_sum as f64 / g.completed as f64
            },
            batch_fill: g
                .fill_hist
                .iter()
                .enumerate()
                .filter(|&(_, &n)| n > 0)
                .map(|(k, &n)| (k as u32, n))
                .collect(),
            batches: g.batches,
            seals_full: g.seals_full,
            seals_window: g.seals_window,
            queue_depth: self.depth.load(Ordering::Relaxed),
            queue_depth_max: self.depth_max.load(Ordering::Relaxed),
            throughput_rps: if elapsed > 0.0 { g.completed as f64 / elapsed } else { 0.0 },
            total_sim_cycles: g.sim_cycles,
        }
    }
}

fn record_one(g: &mut Inner, latency_us: u64, batch: u32, sim_cycles: u64) {
    g.latencies_us.offer(latency_us);
    g.cycle_lats.offer(sim_cycles);
    g.batch_size_sum += batch as u64;
    g.completed += 1;
    g.sim_cycles += sim_cycles as u128;
}

fn record_fill(g: &mut Inner, fill: u32) {
    let k = fill as usize;
    if g.fill_hist.len() <= k {
        g.fill_hist.resize(k + 1, 0);
    }
    g.fill_hist[k] += 1;
    g.batches += 1;
}

/// A point-in-time view of the metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    pub completed: u64,
    pub rejected: u64,
    /// Requests that received an error response (plus 1 per worker
    /// init failure) — comparable against `completed`.
    pub errors: u64,
    /// Requests shed unexecuted because their deadline had passed
    /// before a worker could start them.
    pub deadline_shed: u64,
    /// Submits refused for a wrong-length image.
    pub bad_input: u64,
    /// Requests re-queued after a transient worker error (batched
    /// path failover).
    pub retries: u64,
    /// Circuit-breaker shard ejections.
    pub breaker_trips: u64,
    /// Queued requests shed by a deadline-bounded drain.
    pub drain_shed: u64,
    /// Requests refused because the worker pool was empty with no
    /// restart budget left.
    pub no_workers: u64,
    /// Failed cluster-core executions across all served frames.
    pub core_failures: u64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    /// Per-request latency in *simulated* cycles (deterministic; the
    /// hardware-side dual of the wall percentiles).
    pub p50_cycles: u64,
    pub p99_cycles: u64,
    pub mean_batch: f64,
    /// `(fill, batches)` pairs: how many executed batches carried
    /// exactly `fill` riders (empty fills omitted).
    pub batch_fill: Vec<(u32, u64)>,
    /// Batches executed in total.
    pub batches: u64,
    /// Consumed batch frames sealed by their last writer (filled).
    pub seals_full: u64,
    /// Consumed batch frames sealed by window expiry or close.
    pub seals_window: u64,
    /// Requests currently queued (gauge at snapshot time).
    pub queue_depth: i64,
    /// High-water mark of the queue-depth gauge.
    pub queue_depth_max: i64,
    pub throughput_rps: f64,
    /// Simulated Sparq cycles attributed across completed requests.
    pub total_sim_cycles: u128,
}

/// What a deadline-bounded drain (`shutdown_with_deadline`) did: how
/// many queued requests finished vs were shed, and how long the drain
/// took on the wall clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct DrainStats {
    /// Requests completed between the drain starting and finishing.
    pub completed: u64,
    /// Queued requests shed with `ServeError::Closed` once the drain
    /// deadline passed.
    pub shed: u64,
    /// Wall time the drain took, microseconds.
    pub wall_us: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_over_known_distribution() {
        let m = Metrics::default();
        for i in 1..=100u64 {
            m.record(i, 4, 10 * i);
        }
        let s = m.snapshot();
        assert_eq!(s.completed, 100);
        // below the cap the reservoir holds every value, so the
        // percentiles stay exact: index = round(99 * p): p50 ->
        // lat[50] = 51, etc.
        assert_eq!(s.p50_us, 51);
        assert_eq!(s.p95_us, 95);
        assert_eq!(s.p99_us, 99);
        assert_eq!(s.mean_batch, 4.0);
        // simulated-cycle percentiles ride the same machinery
        assert_eq!(s.p50_cycles, 510);
        assert_eq!(s.p99_cycles, 990);
        assert_eq!(s.total_sim_cycles, (1..=100u128).map(|i| 10 * i).sum());
    }

    #[test]
    fn empty_snapshot_is_zeroes() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p99_us, 0);
        assert_eq!(s.p99_cycles, 0);
        assert_eq!(s.mean_batch, 0.0);
        assert!(s.batch_fill.is_empty());
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.seals_full + s.seals_window, 0);
    }

    #[test]
    fn rejection_counter() {
        let m = Metrics::default();
        m.record_rejected();
        m.record_rejected();
        assert_eq!(m.snapshot().rejected, 2);
    }

    #[test]
    fn error_counter_counts_requests() {
        let m = Metrics::default();
        m.record_errors(4); // a failed batch of 4 riders
        m.record_errors(1); // a worker init failure
        assert_eq!(m.snapshot().errors, 5);
        assert_eq!(m.snapshot().completed, 0);
    }

    #[test]
    fn robustness_counters() {
        let m = Metrics::default();
        m.record_deadline_shed(3);
        m.record_bad_input();
        m.record_bad_input();
        m.record_retries(2);
        m.record_breaker_trip();
        m.record_drain_shed(5);
        m.record_no_workers(4);
        m.record_core_failures(2);
        let s = m.snapshot();
        assert_eq!(s.deadline_shed, 3);
        assert_eq!(s.bad_input, 2);
        assert_eq!(s.retries, 2);
        assert_eq!(s.breaker_trips, 1);
        assert_eq!(s.drain_shed, 5);
        assert_eq!(s.no_workers, 4);
        assert_eq!(s.core_failures, 2);
        // None of these count as completions or worker errors.
        assert_eq!(s.completed, 0);
        assert_eq!(s.errors, 0);
    }

    #[test]
    fn batch_fill_histogram_and_queue_gauge() {
        let m = Metrics::default();
        m.queue_inc();
        m.queue_inc();
        m.queue_inc();
        m.queue_dec(2);
        m.record_batch(&[(10, 100), (12, 100)], 2);
        m.record_batch(&[(9, 100)], 1);
        m.record_fill(2);
        let s = m.snapshot();
        assert_eq!(s.completed, 3);
        assert_eq!(s.batches, 3);
        assert_eq!(s.batch_fill, vec![(1, 1), (2, 2)]);
        assert_eq!(s.queue_depth, 1);
        assert_eq!(s.queue_depth_max, 3);
    }

    #[test]
    fn seal_counters_split_full_vs_window() {
        let m = Metrics::default();
        m.record_seal(false);
        m.record_seal(false);
        m.record_seal(true);
        let s = m.snapshot();
        assert_eq!(s.seals_full, 2);
        assert_eq!(s.seals_window, 1);
    }

    /// The satellite bugfix pinned: a long-lived server's history is
    /// bounded at [`SAMPLE_CAP`] values per series while every counter
    /// stays exact and the percentiles stay stable estimates of the
    /// true distribution.
    #[test]
    fn long_run_history_is_bounded_with_stable_percentiles() {
        let m = Metrics::default();
        const N: u64 = 150_000;
        // deterministic uniform 1..=1000 sweep, cycles = 10x wall
        for i in 0..N {
            let v = (i % 1000) + 1;
            m.record(v, 4, 10 * v);
        }
        {
            let g = m.inner.lock().unwrap();
            assert_eq!(g.latencies_us.samples.len(), SAMPLE_CAP, "wall history must cap");
            assert_eq!(g.cycle_lats.samples.len(), SAMPLE_CAP, "cycle history must cap");
            assert_eq!(g.latencies_us.seen, N, "the sample must still count every value");
        }
        let s = m.snapshot();
        // exact counters survive the sampling untouched
        assert_eq!(s.completed, N);
        assert_eq!(s.mean_batch, 4.0);
        let expect_cycles: u128 =
            (0..N as u128).map(|i| 10 * ((i % 1000) + 1)).sum();
        assert_eq!(s.total_sim_cycles, expect_cycles);
        // percentile estimates stay near the true uniform quantiles
        // (4096 samples of U(1,1000): p50 within +-100 is > 10 sigma)
        assert!(
            (400..=600).contains(&s.p50_us),
            "p50 {} drifted off a uniform 1..=1000 distribution",
            s.p50_us
        );
        assert!(s.p99_us >= 950 && s.p99_us <= 1000, "p99 {} off the tail", s.p99_us);
        assert!(
            (4000..=6000).contains(&s.p50_cycles),
            "cycle p50 {} drifted",
            s.p50_cycles
        );
        // the sample is deterministic: an identical run snapshots
        // identical percentiles
        let m2 = Metrics::default();
        for i in 0..N {
            let v = (i % 1000) + 1;
            m2.record(v, 4, 10 * v);
        }
        let s2 = m2.snapshot();
        assert_eq!(s.p50_us, s2.p50_us);
        assert_eq!(s.p99_us, s2.p99_us);
        assert_eq!(s.p50_cycles, s2.p50_cycles);
    }
}
