//! Serving metrics: latency distribution (wall *and* simulated
//! cycles), batch-fill histogram, queue-depth gauge, throughput — the
//! numbers the e2e example, `sparq serve` and the serve benches report.

use std::sync::atomic::{AtomicI64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Thread-safe metrics sink shared between workers and the caller.
#[derive(Debug)]
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Instant,
    /// Requests currently sitting in submission queues (gauge).
    depth: AtomicI64,
    /// High-water mark of the queue-depth gauge.
    depth_max: AtomicI64,
}

#[derive(Debug, Default)]
struct Inner {
    latencies_us: Vec<u64>,
    batch_sizes: Vec<u32>,
    /// Per-request simulated-cycle latencies (the hardware cost the
    /// request's inference was billed — slot cycles on the batched
    /// path).
    cycle_lats: Vec<u64>,
    /// Executed-batch fill histogram: `fill_hist[k]` = batches that
    /// ran with exactly `k` riders.
    fill_hist: Vec<u64>,
    /// Batches executed (the sum of `fill_hist`).
    batches: u64,
    completed: u64,
    rejected: u64,
    /// Requests that got an error response instead of a result
    /// (executor errors and caught executor panics count once per
    /// request in the failed batch; a worker init failure counts 1).
    errors: u64,
    /// Requests shed by a worker because their deadline had already
    /// passed at dequeue time (never executed).
    deadline_shed: u64,
    /// Requests rejected at submit time for a wrong-length image.
    bad_input: u64,
    /// Requests re-queued onto a different shard after a transient
    /// worker error (batched path failover).
    retries: u64,
    /// Circuit-breaker ejections of a persistently failing shard.
    breaker_trips: u64,
    /// Queued requests shed during a deadline-bounded drain.
    drain_shed: u64,
    /// Requests refused (at submit or by the terminal queue drain)
    /// because the worker pool was empty with no restart budget left.
    no_workers: u64,
    sim_cycles: u128,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            inner: Mutex::new(Inner::default()),
            started: Instant::now(),
            depth: AtomicI64::new(0),
            depth_max: AtomicI64::new(0),
        }
    }
}

impl Metrics {
    pub fn record(&self, latency_us: u64, batch: u32, sim_cycles: u64) {
        let mut g = self.inner.lock().unwrap();
        record_one(&mut g, latency_us, batch, sim_cycles);
    }

    /// Record every rider of one executed batch under a single lock
    /// (the batched worker's per-batch bookkeeping), plus the batch's
    /// fill in the histogram.
    pub fn record_batch(&self, riders: &[(u64, u64)], fill: u32) {
        let mut g = self.inner.lock().unwrap();
        for &(latency_us, sim_cycles) in riders {
            record_one(&mut g, latency_us, fill, sim_cycles);
        }
        record_fill(&mut g, fill);
    }

    /// Record one executed batch's fill (size) in the histogram.
    pub fn record_fill(&self, fill: u32) {
        let mut g = self.inner.lock().unwrap();
        record_fill(&mut g, fill);
    }

    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// Worker-side failures: `n` = number of requests that received an
    /// error response (a failed batch counts once per rider; a worker
    /// init failure, which serves nobody, counts 1).
    pub fn record_errors(&self, n: u64) {
        self.inner.lock().unwrap().errors += n;
    }

    /// `n` requests were shed unexecuted because their deadline had
    /// passed before a worker could start them.
    pub fn record_deadline_shed(&self, n: u64) {
        self.inner.lock().unwrap().deadline_shed += n;
    }

    /// A submit was refused for a wrong-length image.
    pub fn record_bad_input(&self) {
        self.inner.lock().unwrap().bad_input += 1;
    }

    /// `n` requests were re-queued onto a different shard after a
    /// transient worker error.
    pub fn record_retries(&self, n: u64) {
        self.inner.lock().unwrap().retries += n;
    }

    /// The circuit breaker ejected a shard.
    pub fn record_breaker_trip(&self) {
        self.inner.lock().unwrap().breaker_trips += 1;
    }

    /// `n` queued requests were shed by a deadline-bounded drain.
    pub fn record_drain_shed(&self, n: u64) {
        self.inner.lock().unwrap().drain_shed += n;
    }

    /// `n` requests were refused because the worker pool was empty
    /// with no restart budget left.
    pub fn record_no_workers(&self, n: u64) {
        self.inner.lock().unwrap().no_workers += n;
    }

    /// A request entered a submission queue.
    pub fn queue_inc(&self) {
        let d = self.depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.depth_max.fetch_max(d, Ordering::Relaxed);
    }

    /// `n` requests left a submission queue (a worker drained them).
    pub fn queue_dec(&self, n: u64) {
        self.depth.fetch_sub(n as i64, Ordering::Relaxed);
    }

    /// Snapshot of the distribution so far.
    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        let mut lat = g.latencies_us.clone();
        lat.sort_unstable();
        let mut cyc = g.cycle_lats.clone();
        cyc.sort_unstable();
        let pct = |sorted: &[u64], p: f64| -> u64 {
            if sorted.is_empty() {
                return 0;
            }
            let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
            sorted[idx]
        };
        let elapsed = self.started.elapsed().as_secs_f64();
        Snapshot {
            completed: g.completed,
            rejected: g.rejected,
            errors: g.errors,
            deadline_shed: g.deadline_shed,
            bad_input: g.bad_input,
            retries: g.retries,
            breaker_trips: g.breaker_trips,
            drain_shed: g.drain_shed,
            no_workers: g.no_workers,
            p50_us: pct(&lat, 0.50),
            p95_us: pct(&lat, 0.95),
            p99_us: pct(&lat, 0.99),
            p50_cycles: pct(&cyc, 0.50),
            p99_cycles: pct(&cyc, 0.99),
            mean_batch: if g.batch_sizes.is_empty() {
                0.0
            } else {
                g.batch_sizes.iter().map(|&b| b as f64).sum::<f64>() / g.batch_sizes.len() as f64
            },
            batch_fill: g
                .fill_hist
                .iter()
                .enumerate()
                .filter(|&(_, &n)| n > 0)
                .map(|(k, &n)| (k as u32, n))
                .collect(),
            batches: g.batches,
            queue_depth: self.depth.load(Ordering::Relaxed),
            queue_depth_max: self.depth_max.load(Ordering::Relaxed),
            throughput_rps: if elapsed > 0.0 { g.completed as f64 / elapsed } else { 0.0 },
            total_sim_cycles: g.sim_cycles,
        }
    }
}

fn record_one(g: &mut Inner, latency_us: u64, batch: u32, sim_cycles: u64) {
    g.latencies_us.push(latency_us);
    g.batch_sizes.push(batch);
    g.cycle_lats.push(sim_cycles);
    g.completed += 1;
    g.sim_cycles += sim_cycles as u128;
}

fn record_fill(g: &mut Inner, fill: u32) {
    let k = fill as usize;
    if g.fill_hist.len() <= k {
        g.fill_hist.resize(k + 1, 0);
    }
    g.fill_hist[k] += 1;
    g.batches += 1;
}

/// A point-in-time view of the metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    pub completed: u64,
    pub rejected: u64,
    /// Requests that received an error response (plus 1 per worker
    /// init failure) — comparable against `completed`.
    pub errors: u64,
    /// Requests shed unexecuted because their deadline had passed
    /// before a worker could start them.
    pub deadline_shed: u64,
    /// Submits refused for a wrong-length image.
    pub bad_input: u64,
    /// Requests re-queued onto a different shard after a transient
    /// worker error (batched path failover).
    pub retries: u64,
    /// Circuit-breaker shard ejections.
    pub breaker_trips: u64,
    /// Queued requests shed by a deadline-bounded drain.
    pub drain_shed: u64,
    /// Requests refused because the worker pool was empty with no
    /// restart budget left.
    pub no_workers: u64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    /// Per-request latency in *simulated* cycles (deterministic; the
    /// hardware-side dual of the wall percentiles).
    pub p50_cycles: u64,
    pub p99_cycles: u64,
    pub mean_batch: f64,
    /// `(fill, batches)` pairs: how many executed batches carried
    /// exactly `fill` riders (empty fills omitted).
    pub batch_fill: Vec<(u32, u64)>,
    /// Batches executed in total.
    pub batches: u64,
    /// Requests currently queued (gauge at snapshot time).
    pub queue_depth: i64,
    /// High-water mark of the queue-depth gauge.
    pub queue_depth_max: i64,
    pub throughput_rps: f64,
    /// Simulated Sparq cycles attributed across completed requests.
    pub total_sim_cycles: u128,
}

/// What a deadline-bounded drain (`shutdown_with_deadline`) did: how
/// many queued requests finished vs were shed, and how long the drain
/// took on the wall clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct DrainStats {
    /// Requests completed between the drain starting and finishing.
    pub completed: u64,
    /// Queued requests shed with `ServeError::Closed` once the drain
    /// deadline passed.
    pub shed: u64,
    /// Wall time the drain took, microseconds.
    pub wall_us: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_over_known_distribution() {
        let m = Metrics::default();
        for i in 1..=100u64 {
            m.record(i, 4, 10 * i);
        }
        let s = m.snapshot();
        assert_eq!(s.completed, 100);
        // index = round(99 * p): p50 -> lat[50] = 51, etc.
        assert_eq!(s.p50_us, 51);
        assert_eq!(s.p95_us, 95);
        assert_eq!(s.p99_us, 99);
        assert_eq!(s.mean_batch, 4.0);
        // simulated-cycle percentiles ride the same machinery
        assert_eq!(s.p50_cycles, 510);
        assert_eq!(s.p99_cycles, 990);
        assert_eq!(s.total_sim_cycles, (1..=100u128).map(|i| 10 * i).sum());
    }

    #[test]
    fn empty_snapshot_is_zeroes() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p99_us, 0);
        assert_eq!(s.p99_cycles, 0);
        assert_eq!(s.mean_batch, 0.0);
        assert!(s.batch_fill.is_empty());
        assert_eq!(s.queue_depth, 0);
    }

    #[test]
    fn rejection_counter() {
        let m = Metrics::default();
        m.record_rejected();
        m.record_rejected();
        assert_eq!(m.snapshot().rejected, 2);
    }

    #[test]
    fn error_counter_counts_requests() {
        let m = Metrics::default();
        m.record_errors(4); // a failed batch of 4 riders
        m.record_errors(1); // a worker init failure
        assert_eq!(m.snapshot().errors, 5);
        assert_eq!(m.snapshot().completed, 0);
    }

    #[test]
    fn robustness_counters() {
        let m = Metrics::default();
        m.record_deadline_shed(3);
        m.record_bad_input();
        m.record_bad_input();
        m.record_retries(2);
        m.record_breaker_trip();
        m.record_drain_shed(5);
        m.record_no_workers(4);
        let s = m.snapshot();
        assert_eq!(s.deadline_shed, 3);
        assert_eq!(s.bad_input, 2);
        assert_eq!(s.retries, 2);
        assert_eq!(s.breaker_trips, 1);
        assert_eq!(s.drain_shed, 5);
        assert_eq!(s.no_workers, 4);
        // None of these count as completions or worker errors.
        assert_eq!(s.completed, 0);
        assert_eq!(s.errors, 0);
    }

    #[test]
    fn batch_fill_histogram_and_queue_gauge() {
        let m = Metrics::default();
        m.queue_inc();
        m.queue_inc();
        m.queue_inc();
        m.queue_dec(2);
        m.record_batch(&[(10, 100), (12, 100)], 2);
        m.record_batch(&[(9, 100)], 1);
        m.record_fill(2);
        let s = m.snapshot();
        assert_eq!(s.completed, 3);
        assert_eq!(s.batches, 3);
        assert_eq!(s.batch_fill, vec![(1, 1), (2, 2)]);
        assert_eq!(s.queue_depth, 1);
        assert_eq!(s.queue_depth_max, 3);
    }
}
