//! Serving metrics: latency distribution, batch-size histogram,
//! throughput — the numbers the e2e example reports.

use std::sync::Mutex;
use std::time::Instant;

/// Thread-safe metrics sink shared between workers and the caller.
#[derive(Debug)]
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Instant,
}

#[derive(Debug, Default)]
struct Inner {
    latencies_us: Vec<u64>,
    batch_sizes: Vec<u32>,
    completed: u64,
    rejected: u64,
    /// Requests that got an error response instead of a result
    /// (executor errors and caught executor panics count once per
    /// request in the failed batch; a worker init failure counts 1).
    errors: u64,
    sim_cycles: u128,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics { inner: Mutex::new(Inner::default()), started: Instant::now() }
    }
}

impl Metrics {
    pub fn record(&self, latency_us: u64, batch: u32, sim_cycles: u64) {
        let mut g = self.inner.lock().unwrap();
        g.latencies_us.push(latency_us);
        g.batch_sizes.push(batch);
        g.completed += 1;
        g.sim_cycles += sim_cycles as u128;
    }

    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    /// Worker-side failures: `n` = number of requests that received an
    /// error response (a failed batch counts once per rider; a worker
    /// init failure, which serves nobody, counts 1).
    pub fn record_errors(&self, n: u64) {
        self.inner.lock().unwrap().errors += n;
    }

    /// Snapshot of the distribution so far.
    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        let mut lat = g.latencies_us.clone();
        lat.sort_unstable();
        let pct = |p: f64| -> u64 {
            if lat.is_empty() {
                return 0;
            }
            let idx = ((lat.len() as f64 - 1.0) * p).round() as usize;
            lat[idx]
        };
        let elapsed = self.started.elapsed().as_secs_f64();
        Snapshot {
            completed: g.completed,
            rejected: g.rejected,
            errors: g.errors,
            p50_us: pct(0.50),
            p95_us: pct(0.95),
            p99_us: pct(0.99),
            mean_batch: if g.batch_sizes.is_empty() {
                0.0
            } else {
                g.batch_sizes.iter().map(|&b| b as f64).sum::<f64>() / g.batch_sizes.len() as f64
            },
            throughput_rps: if elapsed > 0.0 { g.completed as f64 / elapsed } else { 0.0 },
            total_sim_cycles: g.sim_cycles,
        }
    }
}

/// A point-in-time view of the metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    pub completed: u64,
    pub rejected: u64,
    /// Requests that received an error response (plus 1 per worker
    /// init failure) — comparable against `completed`.
    pub errors: u64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub mean_batch: f64,
    pub throughput_rps: f64,
    /// Simulated Sparq cycles attributed across completed requests.
    pub total_sim_cycles: u128,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_over_known_distribution() {
        let m = Metrics::default();
        for i in 1..=100u64 {
            m.record(i, 4, 10);
        }
        let s = m.snapshot();
        assert_eq!(s.completed, 100);
        // index = round(99 * p): p50 -> lat[50] = 51, etc.
        assert_eq!(s.p50_us, 51);
        assert_eq!(s.p95_us, 95);
        assert_eq!(s.p99_us, 99);
        assert_eq!(s.mean_batch, 4.0);
        assert_eq!(s.total_sim_cycles, 1000);
    }

    #[test]
    fn empty_snapshot_is_zeroes() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p99_us, 0);
        assert_eq!(s.mean_batch, 0.0);
    }

    #[test]
    fn rejection_counter() {
        let m = Metrics::default();
        m.record_rejected();
        m.record_rejected();
        assert_eq!(m.snapshot().rejected, 2);
    }

    #[test]
    fn error_counter_counts_requests() {
        let m = Metrics::default();
        m.record_errors(4); // a failed batch of 4 riders
        m.record_errors(1); // a worker init failure
        assert_eq!(m.snapshot().errors, 5);
        assert_eq!(m.snapshot().completed, 0);
    }
}
