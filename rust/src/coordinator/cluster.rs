//! Multi-core cluster scale-out (DESIGN.md §Cluster): one sealed batch
//! frame sharded across K simulated Sparq cores executing host-parallel.
//!
//! The paper evaluates Sparq as a single 4-lane core; serving "millions
//! of users" takes many of them.  [`QnnCluster`] is that layer: it owns
//! K per-core [`MachinePool`]s around one shared `Arc`'d batch-compiled
//! model (per-slot results are batch-layout-invariant, so every core
//! can execute any shard of the frame against the same compiled
//! program), fans a frame's requests across the live cores via
//! `std::thread::scope`, and merges the results into request order plus
//! one deterministic cycles account.
//!
//! **Shard policy.**  [`ShardPolicy::RoundRobin`] (the default) assigns
//! request `i` of the frame to live core `i mod K` — a pure function of
//! the request index, so the shard map, the per-core cycle loads, and
//! the merged makespan are all bit-reproducible.
//! [`ShardPolicy::WorkSteal`] (behind `ServeConfig::work_steal`) lets
//! cores grab fixed-size index chunks from a shared atomic cursor —
//! useful when per-request cost is uneven (e.g. mixed-precision
//! traffic), at the price of a scheduling-dependent chunk→core map.
//! Both policies produce **bit-identical per-request outputs** (logits
//! and per-slot cycles do not depend on which core ran the slot); only
//! the *account* of a work-stealing run depends on the race.
//!
//! **Merged cycles account.**  K cores run in parallel, so the cluster
//! finishes a frame when its busiest core does:
//!
//! ```text
//! makespan = max over cores of (per-core batch cycles)
//!          + shard_merge_overhead(fan)
//! ```
//!
//! where the fan is the number of live cores the frame was sharded
//! across and [`shard_merge_overhead`] is a fixed linear model
//! ([`SHARD_CYCLES_PER_CORE`] + [`MERGE_CYCLES_PER_CORE`] per core,
//! zero at fan 1 so a 1-core cluster is bit-identical — cycles
//! included — to a plain batched execution).  Every term is
//! deterministic simulated arithmetic, so cluster numbers stay
//! `sparq bench-check`-gateable at tolerance 0 (BENCH_cluster.json).
//!
//! **Robustness per core** (the PR-7 contract): a core execution that
//! fails — injected via a per-core [`FaultPlan`] consulted once per
//! core execution with the *core id* as the plan's worker index, or a
//! real executor panic — fails only *its shard's* requests, each with a
//! typed error string; the other cores' riders scatter normally.  A
//! killed core is marked dead and excluded from every later shard map
//! (its riders fail over through the serving ring exactly like a
//! killed worker's), and a cluster whose last core died answers every
//! request with the kill sentinel so the serving layer can terminally
//! drain instead of hanging clients.  Under round-robin with a single
//! consumer the per-core local call indices are deterministic, so
//! per-core chaos replays bit-identically.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::fault::{self, FaultAction, FaultPlan};
use crate::arch::ProcessorConfig;
use crate::kernels::ProgramCache;
use crate::qnn::schedule::QnnPrecision;
use crate::qnn::QnnGraph;
use crate::runtime::SimQnnModel;
use crate::sim::{MachinePool, SimError};

/// Hard cap on cluster width — mirrors `fault::MAX_WORKERS` so a
/// per-core [`FaultRule`](super::FaultRule) can always address every
/// core by id.
pub const MAX_CORES: usize = 64;

/// Fixed cycles to scatter one core's shard descriptor (slot indices +
/// arena base) from the frame dispatcher to a core.
pub const SHARD_CYCLES_PER_CORE: u64 = 48;

/// Fixed cycles to gather one core's results back into request order
/// at the merge barrier.
pub const MERGE_CYCLES_PER_CORE: u64 = 16;

/// The fixed shard/merge overhead model: distributing a frame across
/// `fan` cores and merging the results costs `fan * (SHARD + MERGE)`
/// cycles, and a fan of one costs nothing — a 1-core cluster is
/// bit-identical (cycles included) to a plain batched execution.
pub fn shard_merge_overhead(fan: usize) -> u64 {
    if fan <= 1 {
        0
    } else {
        fan as u64 * (SHARD_CYCLES_PER_CORE + MERGE_CYCLES_PER_CORE)
    }
}

/// How a sealed frame's requests are assigned to live cores.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPolicy {
    /// Request `i` goes to live core `i mod K`: a pure function of the
    /// request index, fully deterministic (the gated default).
    RoundRobin,
    /// Cores grab fixed-size index chunks from a shared atomic cursor;
    /// the chunk→core map is a scheduling race, but per-request
    /// outputs are bit-identical to round-robin's (asserted in
    /// `rust/tests/cluster_determinism.rs`).
    WorkSteal,
}

impl ShardPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            ShardPolicy::RoundRobin => "round-robin",
            ShardPolicy::WorkSteal => "work-steal",
        }
    }
}

/// Per-request cluster outcome: `(logits, slot_cycles)` or a typed
/// error string (kill-sentinel-bearing when the core was killed).
pub type CoreResult = Result<(Vec<i64>, u64), String>;

/// One core's slice of a frame's merged cycles account.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CoreAccount {
    pub core: usize,
    /// Requests this core executed (or failed) this frame.
    pub requests: u32,
    /// Batched executions this core ran this frame (1 under
    /// round-robin when it had work; possibly more under stealing).
    pub executions: u32,
    /// Total simulated cycles this core spent on the frame (per-batch
    /// preamble included once per execution; 0 if idle or failed —
    /// failed executions bill no deterministic cycles).
    pub cycles: u64,
}

/// The merged deterministic cycles account of one frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClusterAccount {
    /// One entry per configured core (idle cores appear zeroed).
    pub per_core: Vec<CoreAccount>,
    /// Live cores the frame was sharded across.
    pub sharded_across: usize,
    /// `shard_merge_overhead(sharded_across)`.
    pub overhead_cycles: u64,
    /// `max over cores of per-core cycles + overhead_cycles` — when
    /// the cluster is done with the frame.
    pub makespan_cycles: u64,
}

/// What [`QnnCluster::infer_frame`] returns: per-request results in
/// the frame's original request order plus the merged account.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterRun {
    /// One entry per input, in request order.
    pub results: Vec<CoreResult>,
    pub account: ClusterAccount,
    /// Cores whose execution(s) failed this frame, ascending.
    pub failed_cores: Vec<usize>,
}

/// Point-in-time liveness/counters of one cluster core.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CoreHealth {
    pub core: usize,
    pub alive: bool,
    /// Batched executions this core has run, total.
    pub executions: u64,
    /// Failed executions on this core, total.
    pub failures: u64,
}

/// One simulated core: a private machine pool (no cross-core lock
/// traffic on the arena path) plus liveness and counters.
struct CoreState {
    pool: MachinePool,
    alive: AtomicBool,
    executions: AtomicU64,
    failures: AtomicU64,
}

impl CoreState {
    fn new() -> CoreState {
        CoreState {
            pool: MachinePool::new(),
            alive: AtomicBool::new(true),
            executions: AtomicU64::new(0),
            failures: AtomicU64::new(0),
        }
    }
}

/// What one core thread brings back from a frame.
struct CoreOut {
    core: usize,
    /// `(original request index, result)` pairs.
    results: Vec<(usize, CoreResult)>,
    cycles: u64,
    executions: u32,
    requests: u32,
    failed: bool,
}

/// A K-core execution cluster around one batch-compiled QNN: shard a
/// sealed frame across the live cores, execute host-parallel, merge
/// deterministically.  See the module docs for the model.
pub struct QnnCluster {
    model: Arc<SimQnnModel>,
    cores: Vec<CoreState>,
    policy: ShardPolicy,
}

impl QnnCluster {
    /// Wrap an already-compiled batched model in a `cores`-wide
    /// cluster (clamped to `1..=`[`MAX_CORES`]).  Cheap: the model is
    /// shared, only the per-core pools are allocated.
    pub fn new(model: Arc<SimQnnModel>, cores: usize, policy: ShardPolicy) -> QnnCluster {
        let cores = cores.clamp(1, MAX_CORES);
        QnnCluster { model, cores: (0..cores).map(|_| CoreState::new()).collect(), policy }
    }

    /// Compile the batched network (or fetch it from `cache`) and wrap
    /// it in a cluster.
    #[allow(clippy::too_many_arguments)]
    pub fn compile(
        cfg: &ProcessorConfig,
        graph: &QnnGraph,
        precision: QnnPrecision,
        seed: u64,
        cache: &ProgramCache,
        batch: u32,
        cores: usize,
        policy: ShardPolicy,
    ) -> Result<QnnCluster, SimError> {
        let model =
            Arc::new(SimQnnModel::compile_batched(cfg, graph, precision, seed, cache, batch)?);
        Ok(QnnCluster::new(model, cores, policy))
    }

    /// Configured cluster width.
    pub fn cores(&self) -> usize {
        self.cores.len()
    }

    /// Cores alive right now.
    pub fn live_cores(&self) -> usize {
        self.cores.iter().filter(|c| c.alive.load(Ordering::SeqCst)).count()
    }

    pub fn policy(&self) -> ShardPolicy {
        self.policy
    }

    /// The shared compiled model (its `batch()` bounds the frame size).
    pub fn model(&self) -> &Arc<SimQnnModel> {
        &self.model
    }

    /// Per-core liveness and counters.
    pub fn core_health(&self) -> Vec<CoreHealth> {
        self.cores
            .iter()
            .enumerate()
            .map(|(i, c)| CoreHealth {
                core: i,
                alive: c.alive.load(Ordering::SeqCst),
                executions: c.executions.load(Ordering::SeqCst),
                failures: c.failures.load(Ordering::SeqCst),
            })
            .collect()
    }

    /// Execute one frame clean (no fault plan).
    pub fn infer_frame(&self, inputs: &[&[f32]]) -> Result<ClusterRun, SimError> {
        self.infer_frame_chaos(inputs, None)
    }

    /// Execute one frame with an optional per-core fault plan: each
    /// core execution consults `plan.next_for(core_id)` exactly once,
    /// so `FaultRule { worker: Some(core), .. }` targets a specific
    /// core of the cluster (DESIGN.md §Cluster).
    ///
    /// Shards `inputs` across the live cores under the cluster's
    /// policy, executes host-parallel, and merges into request order.
    /// A frame-level `Err` only occurs for an invalid frame (empty or
    /// larger than the compiled batch); per-core failures come back as
    /// typed per-request error strings in [`ClusterRun::results`].
    pub fn infer_frame_chaos(
        &self,
        inputs: &[&[f32]],
        plan: Option<&FaultPlan>,
    ) -> Result<ClusterRun, SimError> {
        if inputs.is_empty() || inputs.len() > self.model.batch() {
            // surface the model's own typed frame-validation error
            match self.model.infer_batch_refs(&self.cores[0].pool, inputs) {
                Err(e) => return Err(e),
                Ok(_) => unreachable!("an invalid frame must fail model validation"),
            }
        }
        let n = inputs.len();
        let live: Vec<usize> = (0..self.cores.len())
            .filter(|&c| self.cores[c].alive.load(Ordering::SeqCst))
            .collect();
        if live.is_empty() {
            // a fully dead cluster cannot serve: every request gets the
            // kill sentinel so the serving layer terminally drains
            // instead of hanging clients
            let msg = format!("{} (cluster: no live cores)", fault::KILL_SENTINEL);
            return Ok(ClusterRun {
                results: (0..n).map(|_| Err(msg.clone())).collect(),
                account: ClusterAccount {
                    per_core: (0..self.cores.len())
                        .map(|core| CoreAccount { core, requests: 0, executions: 0, cycles: 0 })
                        .collect(),
                    sharded_across: 0,
                    overhead_cycles: 0,
                    makespan_cycles: 0,
                },
                failed_cores: Vec::new(),
            });
        }
        let outs: Vec<CoreOut> = match self.policy {
            ShardPolicy::RoundRobin => {
                // request i -> live core i mod K: the shard map is a
                // pure function of the request index
                let mut shards: Vec<Vec<usize>> = vec![Vec::new(); live.len()];
                for i in 0..n {
                    shards[i % live.len()].push(i);
                }
                std::thread::scope(|s| {
                    let handles: Vec<_> = live
                        .iter()
                        .zip(&shards)
                        .filter(|(_, idxs)| !idxs.is_empty())
                        .map(|(&core, idxs)| {
                            s.spawn(move || {
                                let (results, cycles, failed) =
                                    self.run_shard(core, idxs, inputs, plan);
                                CoreOut {
                                    core,
                                    results,
                                    cycles,
                                    executions: 1,
                                    requests: idxs.len() as u32,
                                    failed,
                                }
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().expect("cluster core panicked")).collect()
                })
            }
            ShardPolicy::WorkSteal => {
                // cores race for fixed-size chunks of the index space;
                // a slow core simply takes fewer chunks
                let chunk = n.div_ceil(live.len() * 2).max(1);
                let cursor = AtomicUsize::new(0);
                let cursor = &cursor;
                std::thread::scope(|s| {
                    let handles: Vec<_> = live
                        .iter()
                        .map(|&core| {
                            s.spawn(move || {
                                let mut out = CoreOut {
                                    core,
                                    results: Vec::new(),
                                    cycles: 0,
                                    executions: 0,
                                    requests: 0,
                                    failed: false,
                                };
                                loop {
                                    let start = cursor.fetch_add(chunk, Ordering::SeqCst);
                                    if start >= n {
                                        break;
                                    }
                                    let idxs: Vec<usize> =
                                        (start..(start + chunk).min(n)).collect();
                                    let (results, cycles, failed) =
                                        self.run_shard(core, &idxs, inputs, plan);
                                    out.results.extend(results);
                                    out.cycles += cycles;
                                    out.executions += 1;
                                    out.requests += idxs.len() as u32;
                                    out.failed |= failed;
                                }
                                out
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().expect("cluster core panicked")).collect()
                })
            }
        };

        // merge: results back into request order, cycles into the
        // max-over-cores makespan
        let mut merged: Vec<Option<CoreResult>> = vec![None; n];
        let mut per_core: Vec<CoreAccount> = (0..self.cores.len())
            .map(|core| CoreAccount { core, requests: 0, executions: 0, cycles: 0 })
            .collect();
        let mut failed_cores = Vec::new();
        for out in outs {
            per_core[out.core] = CoreAccount {
                core: out.core,
                requests: out.requests,
                executions: out.executions,
                cycles: out.cycles,
            };
            if out.failed {
                failed_cores.push(out.core);
            }
            for (i, r) in out.results {
                merged[i] = Some(r);
            }
        }
        failed_cores.sort_unstable();
        let results: Vec<CoreResult> =
            merged.into_iter().map(|r| r.expect("every request must be assigned a core")).collect();
        let busiest = per_core.iter().map(|c| c.cycles).max().unwrap_or(0);
        let overhead = shard_merge_overhead(live.len());
        Ok(ClusterRun {
            results,
            account: ClusterAccount {
                per_core,
                sharded_across: live.len(),
                overhead_cycles: overhead,
                makespan_cycles: busiest + overhead,
            },
            failed_cores,
        })
    }

    /// One batched execution of `idxs`' inputs on `core`.  Returns the
    /// per-request results, the execution's total cycles (0 on
    /// failure), and whether it failed.
    fn run_shard(
        &self,
        core: usize,
        idxs: &[usize],
        inputs: &[&[f32]],
        plan: Option<&FaultPlan>,
    ) -> (Vec<(usize, CoreResult)>, u64, bool) {
        let st = &self.cores[core];
        st.executions.fetch_add(1, Ordering::SeqCst);
        // one fault-plan consult per core execution, keyed by core id
        let injected = plan.map(|p| p.next_for(core)).unwrap_or(FaultAction::None);
        if let FaultAction::Delay(us) = injected {
            std::thread::sleep(Duration::from_micros(us));
        }
        let fail = |msg: String| {
            st.failures.fetch_add(1, Ordering::SeqCst);
            let results: Vec<(usize, CoreResult)> =
                idxs.iter().map(|&i| (i, Err(msg.clone()))).collect();
            (results, 0u64, true)
        };
        match injected {
            FaultAction::Error => fail(format!("chaos: injected error (core {core})")),
            FaultAction::SlowError(us) => {
                std::thread::sleep(Duration::from_micros(us));
                fail(format!("chaos: injected slow error (core {core})"))
            }
            FaultAction::Kill => {
                // the core is dead from here on: later frames shard
                // around it, and its riders fail over typed
                st.alive.store(false, Ordering::SeqCst);
                fail(format!("{} (core {core})", fault::KILL_SENTINEL))
            }
            _ => {
                let shard: Vec<&[f32]> = idxs.iter().map(|&i| inputs[i]).collect();
                let exec = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    if injected == FaultAction::Panic {
                        panic!("chaos: injected panic (core {core})");
                    }
                    self.model.infer_batch_refs(&st.pool, &shard)
                }))
                .map_err(|p| super::panic_message(p.as_ref()))
                .and_then(|r| r.map_err(|e| e.to_string()));
                match exec {
                    Ok((per_image, total)) => {
                        let mut results = Vec::with_capacity(idxs.len());
                        for (&i, (mut logits, slot_cycles)) in idxs.iter().zip(per_image) {
                            if injected == FaultAction::CorruptLogits {
                                if let Some(first) = logits.first_mut() {
                                    *first = i64::MIN;
                                }
                            }
                            results.push((i, Ok((logits, slot_cycles))));
                        }
                        (results, total, false)
                    }
                    Err(e) => fail(format!("cluster core {core}: {e}")),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_model_is_zero_at_fan_one_and_linear_after() {
        assert_eq!(shard_merge_overhead(0), 0);
        assert_eq!(shard_merge_overhead(1), 0);
        let per_core = SHARD_CYCLES_PER_CORE + MERGE_CYCLES_PER_CORE;
        assert_eq!(shard_merge_overhead(2), 2 * per_core);
        assert_eq!(shard_merge_overhead(8), 8 * per_core);
        // strictly increasing in the fan past 1 (the monotonicity the
        // capacity grid's strict-increase assertion leans on)
        for fan in 2..MAX_CORES {
            assert!(shard_merge_overhead(fan + 1) > shard_merge_overhead(fan));
        }
    }

    #[test]
    fn policy_labels() {
        assert_eq!(ShardPolicy::RoundRobin.label(), "round-robin");
        assert_eq!(ShardPolicy::WorkSteal.label(), "work-steal");
    }
}
