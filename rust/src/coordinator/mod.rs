//! The serving coordinator — the L3 stack around the QNN: bounded
//! request queues (backpressure), dynamic batcher, worker threads,
//! per-request metrics, and simulated-hardware cycle attribution from
//! the `qnn` scheduler.
//!
//! Two request paths exist:
//!
//! * The generic [`Server`] drives any [`Executor`] (the PJRT artifact
//!   path [`PjrtExecutor`], a single simulated conv
//!   [`SimConvExecutor`], or the whole SparqCNN one image at a time
//!   [`SimQnnExecutor`]) behind one shared bounded queue.
//! * The batched QNN path ([`batch::QnnBatchServer`], DESIGN.md
//!   §Serving) serves the batch-B compiled arena: per-worker *shard*
//!   queues, a batching window that fills up to B activation slots,
//!   ONE batched execution per window, and per-request scatter — what
//!   `sparq serve --batch` and the `serve_throughput` bench run.
//!
//! Design notes:
//! * PJRT handles are not `Send`, so each generic-path worker thread
//!   owns its *own* compiled runtime (standard per-core replication
//!   for CPU serving).  The simulator models are plain data, so the
//!   batched path shares one `Arc`'d model instead.
//! * The batcher is a greedy window: a worker takes the first request,
//!   then drains up to `batch-1` more within `batch_window_us`, pads
//!   the tail with zero images (the artifact's batch dimension is
//!   static), executes once, and fans results back out.
//! * Backpressure: queues are bounded `sync_channel`s; `submit` fails
//!   fast with [`ServeError::QueueFull`] when capacity is exhausted
//!   (callers see rejections, not latency collapse).

pub mod batch;
pub mod metrics;

pub use batch::QnnBatchServer;
pub use metrics::{Metrics, Snapshot};

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::ServeConfig;
use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    QueueFull,
    Closed,
    Worker(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::QueueFull => write!(f, "queue full (backpressure)"),
            ServeError::Closed => write!(f, "server is shut down"),
            ServeError::Worker(e) => write!(f, "worker failed: {e}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// What a worker computes for one image.
#[derive(Debug, Clone)]
pub struct InferResult {
    pub logits: Vec<f32>,
    pub class: usize,
    /// Simulated Sparq cycles attributed to this image.
    pub sim_cycles: u64,
    /// Size of the batch this request rode in (diagnostic).
    pub batch: u32,
}

struct Request {
    image: Vec<f32>,
    resp: SyncSender<Result<InferResult, ServeError>>,
    enqueued: Instant,
}

/// The model-execution backend a worker drives.  The production
/// implementation wraps the PJRT runtime; tests use a mock.  Note: NOT
/// `Send` — PJRT handles are thread-pinned, so each worker builds its
/// own executor via the (Send) factory and never moves it.
pub trait Executor: 'static {
    /// Static batch size of the compiled model.
    fn batch(&self) -> usize;
    /// Per-image input length (c*h*w).
    fn image_len(&self) -> usize;
    /// Number of classes (logits per image).
    fn classes(&self) -> usize;
    /// Run one padded batch; returns batch*classes logits.
    fn run(&mut self, batch_data: &[f32]) -> Result<Vec<f32>, String>;
}

/// Factory so each worker thread can build its own (non-Send) executor.
pub type ExecutorFactory = Box<dyn Fn() -> Result<Box<dyn Executor>, String> + Send + Sync>;

/// A running inference server.
pub struct Server {
    tx: Option<SyncSender<Request>>,
    pub metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Start `cfg.workers` workers; `sim_cycles_per_image` is the
    /// hardware cost the qnn scheduler attributes to one inference.
    pub fn start(
        factory: ExecutorFactory,
        cfg: ServeConfig,
        sim_cycles_per_image: u64,
    ) -> Result<Server, ServeError> {
        let (tx, rx) = sync_channel::<Request>(cfg.queue_depth);
        let rx = Arc::new(std::sync::Mutex::new(rx));
        let metrics = Arc::new(Metrics::default());
        let factory = Arc::new(factory);
        let mut workers = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let rx = Arc::clone(&rx);
            let metrics = Arc::clone(&metrics);
            let factory = Arc::clone(&factory);
            let window = Duration::from_micros(cfg.batch_window_us);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("sparq-worker-{wid}"))
                    .spawn(move || worker_loop(rx, metrics, factory, window, sim_cycles_per_image))
                    .map_err(|e| ServeError::Worker(e.to_string()))?,
            );
        }
        Ok(Server { tx: Some(tx), metrics, workers })
    }

    /// Blocking inference.
    pub fn infer(&self, image: Vec<f32>) -> Result<InferResult, ServeError> {
        let rx = self.submit(image)?;
        rx.recv().map_err(|_| ServeError::Closed)?
    }

    /// Non-blocking submit; the receiver yields the result later.
    pub fn submit(
        &self,
        image: Vec<f32>,
    ) -> Result<Receiver<Result<InferResult, ServeError>>, ServeError> {
        let (rtx, rrx) = sync_channel(1);
        let req = Request { image, resp: rtx, enqueued: Instant::now() };
        // gauge BEFORE the send: a worker may dequeue (and queue_dec)
        // the instant try_send lands, and inc-after-send would let the
        // gauge transiently read negative
        self.metrics.queue_inc();
        let Some(tx) = self.tx.as_ref() else {
            self.metrics.queue_dec(1);
            return Err(ServeError::Closed);
        };
        match tx.try_send(req) {
            Ok(()) => Ok(rrx),
            Err(TrySendError::Full(_)) => {
                self.metrics.queue_dec(1);
                self.metrics.record_rejected();
                Err(ServeError::QueueFull)
            }
            Err(TrySendError::Disconnected(_)) => {
                self.metrics.queue_dec(1);
                Err(ServeError::Closed)
            }
        }
    }

    /// Drain the queue, stop the workers, return the final metrics.
    pub fn shutdown(mut self) -> Snapshot {
        self.tx.take(); // close the channel; workers exit on disconnect
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

fn worker_loop(
    rx: Arc<std::sync::Mutex<Receiver<Request>>>,
    metrics: Arc<Metrics>,
    factory: Arc<ExecutorFactory>,
    window: Duration,
    sim_cycles_per_image: u64,
) {
    let mut exec = match factory() {
        Ok(e) => e,
        Err(e) => {
            metrics.record_errors(1);
            eprintln!("executor init failed: {e}");
            return;
        }
    };
    let batch = exec.batch();
    let per = exec.image_len();
    let classes = exec.classes();

    loop {
        // take the first request (blocking), then greedily batch
        let first = {
            let g = rx.lock().unwrap();
            match g.recv() {
                Ok(r) => r,
                Err(_) => return, // channel closed: shut down
            }
        };
        metrics.queue_dec(1);
        let mut reqs = vec![first];
        let deadline = Instant::now() + window;
        while reqs.len() < batch {
            let g = rx.lock().unwrap();
            let left = deadline.saturating_duration_since(Instant::now());
            match g.recv_timeout(left) {
                Ok(r) => {
                    metrics.queue_dec(1);
                    reqs.push(r);
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        // assemble the padded batch
        let mut data = vec![0f32; batch * per];
        for (i, r) in reqs.iter().enumerate() {
            let n = r.image.len().min(per);
            data[i * per..i * per + n].copy_from_slice(&r.image[..n]);
        }
        // One poisoned request must not kill the worker: a panicking
        // executor is caught and mapped to `ServeError::Worker` like any
        // other executor error, recorded in the metrics, and the worker
        // loops on to the next batch.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| exec.run(&data)))
            .unwrap_or_else(|p| Err(panic_message(p.as_ref())));
        let bsz = reqs.len() as u32;
        match result {
            Ok(logits) => {
                // fills count EXECUTED batches only (errored batches are
                // tracked via `errors`) — same accounting as the batched
                // QnnBatchServer path, so the histograms stay comparable
                metrics.record_fill(bsz);
                for (i, r) in reqs.into_iter().enumerate() {
                    let l = logits[i * classes..(i + 1) * classes].to_vec();
                    let class = argmax(&l);
                    let lat = r.enqueued.elapsed().as_micros() as u64;
                    metrics.record(lat, bsz, sim_cycles_per_image);
                    let _ = r.resp.send(Ok(InferResult {
                        logits: l,
                        class,
                        sim_cycles: sim_cycles_per_image,
                        batch: bsz,
                    }));
                }
            }
            Err(e) => {
                metrics.record_errors(reqs.len() as u64);
                for r in reqs {
                    let _ = r.resp.send(Err(ServeError::Worker(e.clone())));
                }
            }
        }
    }
}

/// Best-effort text of a caught executor panic payload.
pub(crate) fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        format!("executor panicked: {s}")
    } else if let Some(s) = p.downcast_ref::<String>() {
        format!("executor panicked: {s}")
    } else {
        "executor panicked".into()
    }
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).map(|(i, _)| i).unwrap_or(0)
}

/// PJRT-backed executor over a named artifact.
pub struct PjrtExecutor {
    rt: crate::runtime::Runtime,
    model: String,
    batch: usize,
    image_len: usize,
    classes: usize,
    dims: [i64; 4],
}

impl PjrtExecutor {
    /// Build from an artifacts directory + model name (reads the batch
    /// and shapes from the manifest).
    pub fn new(dir: &std::path::Path, model: &str) -> Result<PjrtExecutor, String> {
        let rt = crate::runtime::Runtime::load(dir).map_err(|e| e.to_string())?;
        let art = rt
            .manifest
            .artifact(model)
            .ok_or_else(|| format!("model {model} not in manifest"))?;
        let batch = art.meta_u32("batch").unwrap_or(16) as usize;
        let classes = art.meta_u32("out").unwrap_or(4) as usize;
        let shape: Vec<i64> = art
            .meta
            .get("in")
            .map(|s| s.split('x').filter_map(|t| t.parse().ok()).collect())
            .unwrap_or_else(|| vec![1, 16, 16]);
        let image_len = shape.iter().product::<i64>() as usize;
        Ok(PjrtExecutor {
            rt,
            model: model.to_string(),
            batch,
            image_len,
            classes,
            dims: [batch as i64, shape[0], shape[1], shape[2]],
        })
    }
}

impl Executor for PjrtExecutor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn image_len(&self) -> usize {
        self.image_len
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn run(&mut self, batch_data: &[f32]) -> Result<Vec<f32>, String> {
        self.rt
            .exec_f32(&self.model, &[(batch_data, &self.dims)])
            .map_err(|e| e.to_string())
    }
}

/// Simulator-backed executor: compile-once/execute-many serving of a
/// sub-byte conv2d on the simulated Sparq.  The compiled program comes
/// from a [`ProgramCache`] **shared across all workers** (via the
/// factory's `Arc`); each worker owns a *private* [`MachinePool`], so
/// steady-state serving holds one machine per worker with no
/// cross-worker lock traffic.  What each worker actually executes is
/// the cached micro-op form (`sim::CompiledProgram`, DESIGN.md §Perf)
/// — per-request host work is activation rebind + word-parallel
/// execution, with zero per-instruction re-validation.
///
/// Request contract: an "image" is the flattened (c, h, w) activation
/// tensor as f32 levels (clamped + rounded into the A-bit range); the
/// "logits" are the per-output-channel sums of the conv output — a
/// global-average-pool head over real simulated conv numerics.
pub struct SimConvExecutor {
    model: crate::runtime::SimConvModel,
    pool: crate::sim::MachinePool,
    batch: usize,
}

use crate::kernels::{ConvDims, ConvVariant, ProgramCache};
use crate::ProcessorConfig;

impl SimConvExecutor {
    pub fn new(
        cfg: &ProcessorConfig,
        dims: ConvDims,
        variant: ConvVariant,
        batch: usize,
        seed: u64,
        cache: &ProgramCache,
    ) -> Result<SimConvExecutor, String> {
        let model = crate::runtime::SimConvModel::compile(cfg, dims, variant, seed, cache)
            .map_err(|e| e.to_string())?;
        Ok(SimConvExecutor {
            model,
            pool: crate::sim::MachinePool::new(),
            batch: batch.max(1),
        })
    }

    /// Pool diagnostics (tests assert reuse).
    pub fn pool_stats(&self) -> crate::sim::pool::PoolStats {
        self.pool.stats()
    }
}

impl Executor for SimConvExecutor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn image_len(&self) -> usize {
        self.model.input_len()
    }

    fn classes(&self) -> usize {
        self.model.dims.co as usize
    }

    fn run(&mut self, batch_data: &[f32]) -> Result<Vec<f32>, String> {
        let per = self.model.input_len();
        let classes = self.model.dims.co as usize;
        let plane = self.model.output_len() / classes;
        let mut logits = Vec::with_capacity(batch_data.len() / per * classes);
        for img in batch_data.chunks(per) {
            // All-zero activation levels produce an exactly-zero conv
            // output (every product is 0), so zero-padded batch slots —
            // and genuine all-zero images — skip the simulation instead
            // of paying a full conv2d per padding slot.
            if img.iter().all(|&v| self.model.quantize_level(v) == 0) {
                logits.resize(logits.len() + classes, 0.0);
                continue;
            }
            let (out, _report) =
                self.model.infer(&self.pool, img).map_err(|e| e.to_string())?;
            for o in 0..classes {
                logits.push(out[o * plane..(o + 1) * plane].iter().sum::<i64>() as f32);
            }
        }
        Ok(logits)
    }
}

/// Factory for [`Server::start`]: every worker builds its own
/// `SimConvExecutor` (private machine pool) against the one shared
/// program cache.
pub fn sim_conv_factory(
    cfg: ProcessorConfig,
    dims: ConvDims,
    variant: ConvVariant,
    batch: usize,
    seed: u64,
    cache: Arc<ProgramCache>,
) -> ExecutorFactory {
    Box::new(move || {
        Ok(Box::new(SimConvExecutor::new(&cfg, dims, variant, batch, seed, &cache)?)
            as Box<dyn Executor>)
    })
}

/// Whole-network simulator executor: serves SparqCNN classification
/// through the chained dataflow program (`qnn::compiled::CompiledQnn`)
/// — every request runs conv/requant/maxpool/GAP+FC end-to-end in the
/// simulated arena and the logits come straight out of it.  Same
/// sharing model as [`SimConvExecutor`]: the compiled network lives in
/// the [`ProgramCache`] shared across workers (graph-level key), each
/// worker owns a private [`MachinePool`] sized for the arena.
pub struct SimQnnExecutor {
    model: crate::runtime::SimQnnModel,
    pool: crate::sim::MachinePool,
    batch: usize,
}

impl SimQnnExecutor {
    pub fn new(
        cfg: &ProcessorConfig,
        graph: &crate::qnn::QnnGraph,
        precision: crate::qnn::schedule::QnnPrecision,
        batch: usize,
        seed: u64,
        cache: &ProgramCache,
    ) -> Result<SimQnnExecutor, String> {
        let model = crate::runtime::SimQnnModel::compile(cfg, graph, precision, seed, cache)
            .map_err(|e| e.to_string())?;
        Ok(SimQnnExecutor {
            model,
            pool: crate::sim::MachinePool::new(),
            batch: batch.max(1),
        })
    }

    /// Pool diagnostics (tests assert reuse).
    pub fn pool_stats(&self) -> crate::sim::pool::PoolStats {
        self.pool.stats()
    }
}

impl Executor for SimQnnExecutor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn image_len(&self) -> usize {
        self.model.input_len()
    }

    fn classes(&self) -> usize {
        self.model.classes()
    }

    fn run(&mut self, batch_data: &[f32]) -> Result<Vec<f32>, String> {
        let per = self.model.input_len();
        let classes = self.model.classes();
        let mut logits = Vec::with_capacity(batch_data.len() / per * classes);
        for img in batch_data.chunks(per) {
            // All-zero level images flow zeros through every layer
            // (convs of zeros, requant(0)=0, max(0)=0, FC on zero GAP
            // sums), so zero-padded batch slots skip the simulation.
            if img.iter().all(|&v| self.model.quantize_level(v) == 0) {
                logits.resize(logits.len() + classes, 0.0);
                continue;
            }
            let (out, _cycles) = self.model.infer(&self.pool, img).map_err(|e| e.to_string())?;
            logits.extend(out.iter().map(|&v| v as f32));
        }
        Ok(logits)
    }
}

/// Factory for [`Server::start`]: full-network simulator serving —
/// every worker builds its own `SimQnnExecutor` (private machine pool)
/// against the one shared program cache.
pub fn sim_qnn_factory(
    cfg: ProcessorConfig,
    graph: crate::qnn::QnnGraph,
    precision: crate::qnn::schedule::QnnPrecision,
    batch: usize,
    seed: u64,
    cache: Arc<ProgramCache>,
) -> ExecutorFactory {
    Box::new(move || {
        Ok(Box::new(SimQnnExecutor::new(&cfg, &graph, precision, batch, seed, &cache)?)
            as Box<dyn Executor>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A mock: "logits" = [sum(image), 0, 0, index-of-first-nonzero].
    struct Mock {
        batch: usize,
        calls: usize,
    }

    impl Executor for Mock {
        fn batch(&self) -> usize {
            self.batch
        }
        fn image_len(&self) -> usize {
            4
        }
        fn classes(&self) -> usize {
            2
        }
        fn run(&mut self, data: &[f32]) -> Result<Vec<f32>, String> {
            self.calls += 1;
            Ok(data
                .chunks(4)
                .flat_map(|img| {
                    let s: f32 = img.iter().sum();
                    [s, -s]
                })
                .collect())
        }
    }

    fn mock_server(workers: usize, window_us: u64, depth: usize) -> Server {
        let cfg = ServeConfig { workers, batch_window_us: window_us, queue_depth: depth, ..Default::default() };
        Server::start(Box::new(|| Ok(Box::new(Mock { batch: 4, calls: 0 }))), cfg, 1234).unwrap()
    }

    #[test]
    fn single_request_roundtrip() {
        let s = mock_server(1, 100, 16);
        let r = s.infer(vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(r.logits, vec![10.0, -10.0]);
        assert_eq!(r.class, 0);
        assert_eq!(r.sim_cycles, 1234);
        let snap = s.shutdown();
        assert_eq!(snap.completed, 1);
    }

    #[test]
    fn negative_sum_classifies_to_second_logit() {
        let s = mock_server(1, 100, 16);
        let r = s.infer(vec![-5.0, 0.0, 0.0, 0.0]).unwrap();
        assert_eq!(r.class, 1);
        s.shutdown();
    }

    #[test]
    fn batching_aggregates_concurrent_requests() {
        let s = Arc::new(mock_server(1, 20_000, 64));
        let mut handles = vec![];
        for i in 0..8 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                s.infer(vec![i as f32, 0.0, 0.0, 0.0]).unwrap()
            }));
        }
        let results: Vec<InferResult> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // with an open 20ms window and batch 4, most requests share rides
        let max_batch = results.iter().map(|r| r.batch).max().unwrap();
        assert!(max_batch >= 2, "no batching happened");
        let s = Arc::try_unwrap(s).ok().unwrap();
        assert_eq!(s.shutdown().completed, 8);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // no worker consumes: factory that blocks forever is hard; use
        // depth 1 and a slow drip instead — fill the queue synchronously
        let cfg = ServeConfig { workers: 1, batch_window_us: 10, queue_depth: 1, ..Default::default() };
        let s = Server::start(
            Box::new(|| {
                std::thread::sleep(std::time::Duration::from_millis(200));
                Ok(Box::new(Mock { batch: 4, calls: 0 }) as Box<dyn Executor>)
            }),
            cfg,
            0,
        )
        .unwrap();
        // while the worker is still initialising, flood the queue
        let mut rejected = false;
        let mut pending = vec![];
        for _ in 0..8 {
            match s.submit(vec![0.0; 4]) {
                Ok(rx) => pending.push(rx),
                Err(ServeError::QueueFull) => {
                    rejected = true;
                    break;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(rejected, "queue never filled");
        for rx in pending {
            let _ = rx.recv();
        }
        let snap = s.shutdown();
        assert!(snap.rejected >= 1);
    }

    #[test]
    fn multiple_workers_all_serve() {
        let s = Arc::new(mock_server(3, 50, 64));
        let mut handles = vec![];
        for i in 0..30 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                s.infer(vec![i as f32, 1.0, 0.0, 0.0]).unwrap()
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = Arc::try_unwrap(s).ok().unwrap();
        let snap = s.shutdown();
        assert_eq!(snap.completed, 30);
        assert!(snap.throughput_rps > 0.0);
    }

    #[test]
    fn shutdown_closes_queue() {
        let s = mock_server(1, 10, 4);
        let snap = s.shutdown();
        assert_eq!(snap.completed, 0);
    }

    /// An executor that panics on the first batch, then recovers.
    struct PanicsOnce {
        panicked: bool,
    }

    impl Executor for PanicsOnce {
        fn batch(&self) -> usize {
            1
        }
        fn image_len(&self) -> usize {
            4
        }
        fn classes(&self) -> usize {
            2
        }
        fn run(&mut self, data: &[f32]) -> Result<Vec<f32>, String> {
            if !self.panicked {
                self.panicked = true;
                panic!("poisoned request");
            }
            let s: f32 = data.iter().sum();
            Ok(vec![s, -s])
        }
    }

    #[test]
    fn executor_panic_does_not_kill_the_worker() {
        let cfg = ServeConfig { workers: 1, batch_window_us: 10, queue_depth: 16, ..Default::default() };
        let s = Server::start(
            Box::new(|| Ok(Box::new(PanicsOnce { panicked: false }) as Box<dyn Executor>)),
            cfg,
            7,
        )
        .unwrap();
        // first request rides the poisoned batch -> typed worker error
        let first = s.infer(vec![1.0; 4]);
        match first {
            Err(ServeError::Worker(msg)) => assert!(msg.contains("poisoned"), "{msg}"),
            other => panic!("expected Worker error, got {other:?}"),
        }
        // the worker survived: the next request succeeds
        let second = s.infer(vec![1.0, 2.0, 3.0, 4.0]).expect("worker must survive the panic");
        assert_eq!(second.logits, vec![10.0, -10.0]);
        let snap = s.shutdown();
        assert_eq!(snap.errors, 1);
        assert_eq!(snap.completed, 1);
    }
}
