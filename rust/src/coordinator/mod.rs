//! The serving coordinator — the L3 stack around the QNN: bounded
//! request queues (backpressure), dynamic batcher, worker threads,
//! per-request metrics, and simulated-hardware cycle attribution from
//! the `qnn` scheduler.
//!
//! Two request paths exist:
//!
//! * The generic [`Server`] drives any [`Executor`] (the PJRT artifact
//!   path [`PjrtExecutor`], a single simulated conv
//!   [`SimConvExecutor`], or the whole SparqCNN one image at a time
//!   [`SimQnnExecutor`]) behind one shared bounded queue.
//! * The batched QNN path ([`batch::QnnBatchServer`], DESIGN.md
//!   §Serving) serves the batch-B compiled arena behind a lock-free
//!   slot-reservation front door ([`ring::BatchRing`]): producers CAS
//!   a slot in the current open batch frame and write their image in
//!   place, frames seal when they fill or their window expires, and
//!   any worker dispatches a sealed frame as ONE batched execution
//!   with per-request scatter — what `sparq serve --batch` and the
//!   `serve_throughput` bench run.  With `--cores K` the dispatched
//!   frame is *sharded across a K-core cluster*
//!   ([`cluster::QnnCluster`], DESIGN.md §Cluster): per-core machine
//!   pools execute shards host-parallel and the results merge back
//!   into request order with a deterministic max-over-cores makespan
//!   account, so scale-out numbers stay cycle-gateable.
//!
//! Design notes:
//! * PJRT handles are not `Send`, so each generic-path worker thread
//!   owns its *own* compiled runtime (standard per-core replication
//!   for CPU serving).  The simulator models are plain data, so the
//!   batched path shares one `Arc`'d model instead.
//! * Batching on the generic path is a greedy window: a worker takes
//!   the first request, then drains up to `batch-1` more within
//!   `batch_window_us`, pads the tail with zero images (the artifact's
//!   batch dimension is static), executes once, and fans results back
//!   out.  On the batched path the window lives in the ring: frames
//!   assemble *as requests arrive* and the window-expiry-vs-last-writer
//!   seal race is one CAS (`coordinator/ring.rs`).
//! * Backpressure: the generic queue is a bounded `sync_channel`, the
//!   ring is a bounded frame budget; either way `submit` fails fast
//!   with [`ServeError::QueueFull`] when capacity is exhausted
//!   (callers see rejections, not latency collapse).
//!
//! Robustness substrate (DESIGN.md §Robustness): every failure mode is
//! typed, bounded, observable, and deterministically testable.
//!
//! * Every submit is validated (`ServeError::BadInput` for wrong-length
//!   images — nothing is silently truncated or zero-padded) and may
//!   carry a deadline (`submit_with_deadline` / `infer_timeout`);
//!   workers shed already-expired requests with `ServeError::Deadline`
//!   *before* execution.
//! * A supervisor thread watches per-worker liveness (heartbeat
//!   counters + a drop-guard dead flag), respawns dead workers under a
//!   restart budget with exponential backoff, and terminally drains the
//!   queue with `ServeError::NoWorkers` once the pool is empty and the
//!   budget is spent — `submit` fails fast instead of queueing forever,
//!   and `health()` exposes alive/restarts/degraded.
//! * The batched path adds failover (one retry back through the ring)
//!   and a circuit breaker whose ejected workers pause consuming while
//!   a healthy peer covers, with probation re-admit
//!   (`batch::QnnBatchServer`).  The same contract holds per *cluster
//!   core*: a killed core fails only its shard's riders (typed, failed
//!   over through the ring), the dead core is excluded from later
//!   shard maps, and per-core fault targeting replays deterministically
//!   ([`cluster`]).
//! * `shutdown_with_deadline` drains gracefully: new work is rejected,
//!   queued work finishes until the deadline and is shed typed after
//!   it, and [`metrics::DrainStats`] reports what happened.
//! * All of it is testable bit-identically via the seeded
//!   fault-injection harness in [`fault`] (`rust/tests/serve_faults.rs`).

pub mod batch;
pub mod cluster;
pub mod fault;
pub mod metrics;
pub mod ring;

pub use batch::QnnBatchServer;
pub use cluster::{
    shard_merge_overhead, ClusterAccount, ClusterRun, CoreAccount, CoreHealth, QnnCluster,
    ShardPolicy,
};
pub use fault::{chaos_factory, CallSel, ChaosSpec, FaultAction, FaultPlan, FaultRule};
pub use metrics::{DrainStats, Metrics, Snapshot};

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::ServeConfig;
use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    QueueFull,
    Closed,
    Worker(String),
    /// The request's deadline passed before a result was produced —
    /// either shed by a worker pre-execution or timed out client-side.
    Deadline,
    /// The worker pool is empty and the restart budget is spent; the
    /// request was refused instead of queueing forever.
    NoWorkers,
    /// The image length does not match the model's input length; the
    /// request was refused at submit time (never truncated or padded).
    BadInput { got: usize, want: usize },
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::QueueFull => write!(f, "queue full (backpressure)"),
            ServeError::Closed => write!(f, "server is shut down"),
            ServeError::Worker(e) => write!(f, "worker failed: {e}"),
            ServeError::Deadline => write!(f, "deadline exceeded"),
            ServeError::NoWorkers => write!(f, "no live workers (restart budget spent)"),
            ServeError::BadInput { got, want } => {
                write!(f, "bad input: image length {got}, model wants {want}")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// What a worker computes for one image.
#[derive(Debug, Clone)]
pub struct InferResult {
    pub logits: Vec<f32>,
    pub class: usize,
    /// Simulated Sparq cycles attributed to this image.
    pub sim_cycles: u64,
    /// Size of the batch this request rode in (diagnostic).
    pub batch: u32,
}

struct Request {
    image: Vec<f32>,
    resp: SyncSender<Result<InferResult, ServeError>>,
    enqueued: Instant,
    /// Absolute deadline; a worker sheds the request unexecuted with
    /// [`ServeError::Deadline`] once this has passed.
    deadline: Option<Instant>,
}

/// How often an idle worker wakes to tick its heartbeat, and how often
/// the supervisor scans the pool.
const HEARTBEAT_POLL: Duration = Duration::from_millis(20);
const SUPERVISOR_POLL: Duration = Duration::from_micros(200);

/// Per-worker-slot liveness state shared with the supervisor.
#[derive(Debug, Default)]
struct SlotState {
    /// The worker's loop is running (set after a successful factory
    /// call, cleared by the drop guard on any exit path).
    alive: AtomicBool,
    /// A thread for this slot has been spawned but has not finished
    /// initialising yet (gates double-respawn).
    starting: AtomicBool,
    /// Monotone liveness counter, ticked once per worker loop
    /// iteration (exposed via [`Health::heartbeats`]).
    heartbeat: AtomicU64,
}

/// Supervision state shared by workers, the supervisor thread, and the
/// server handle.
#[derive(Debug)]
struct Supervision {
    slots: Vec<SlotState>,
    /// Live workers right now (guard-accurate).
    live: AtomicI64,
    /// Respawn attempts the supervisor has made.
    restarts: AtomicU64,
    /// Respawns the supervisor may still spend.
    budget_left: AtomicI64,
    /// Latched once the pool died with no budget left.
    degraded: AtomicBool,
    /// Tells the supervisor to exit.
    stop: AtomicBool,
    /// Graceful-drain deadline: once set and passed, workers shed
    /// queued work with [`ServeError::Closed`] instead of executing.
    drain_by: RwLock<Option<Instant>>,
}

impl Supervision {
    fn new(workers: usize, restart_budget: u32) -> Supervision {
        Supervision {
            slots: (0..workers).map(|_| SlotState::default()).collect(),
            live: AtomicI64::new(0),
            restarts: AtomicU64::new(0),
            budget_left: AtomicI64::new(restart_budget as i64),
            degraded: AtomicBool::new(false),
            stop: AtomicBool::new(false),
            drain_by: RwLock::new(None),
        }
    }

    /// A worker finished initialising and entered its loop.
    fn worker_up(&self, slot: usize) {
        self.live.fetch_add(1, Ordering::SeqCst);
        self.slots[slot].alive.store(true, Ordering::SeqCst);
        self.slots[slot].starting.store(false, Ordering::SeqCst);
    }

    /// True once no worker can ever serve again: pool empty, nothing
    /// starting, and no restart budget left.  Monotone — the budget
    /// never replenishes, so once true it stays true.
    fn pool_dead(&self) -> bool {
        self.live.load(Ordering::SeqCst) <= 0
            && self.budget_left.load(Ordering::SeqCst) <= 0
            && self.slots.iter().all(|s| !s.starting.load(Ordering::SeqCst))
    }

    fn drain_deadline(&self) -> Option<Instant> {
        *self.drain_by.read().unwrap()
    }
}

/// Marks the slot dead on *any* worker exit (return, kill, unwind), so
/// the supervisor's view is accurate without cooperation from the exit
/// path.
struct WorkerGuard {
    sup: Arc<Supervision>,
    slot: usize,
}

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        self.sup.slots[self.slot].alive.store(false, Ordering::SeqCst);
        self.sup.live.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Point-in-time pool health (see [`Server::health`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Health {
    /// Worker slots the server was configured with.
    pub configured: usize,
    /// Workers alive right now.
    pub alive: usize,
    /// Respawn attempts the supervisor has made.
    pub restarts: u64,
    /// Respawns the supervisor may still spend.
    pub restart_budget_left: u32,
    /// True when capacity is below the configured pool (or the pool is
    /// dead for good).
    pub degraded: bool,
    /// Per-slot heartbeat counters (monotone while the slot is alive).
    pub heartbeats: Vec<u64>,
}

/// The model-execution backend a worker drives.  The production
/// implementation wraps the PJRT runtime; tests use a mock.  Note: NOT
/// `Send` — PJRT handles are thread-pinned, so each worker builds its
/// own executor via the (Send) factory and never moves it.
pub trait Executor: 'static {
    /// Static batch size of the compiled model.
    fn batch(&self) -> usize;
    /// Per-image input length (c*h*w).
    fn image_len(&self) -> usize;
    /// Number of classes (logits per image).
    fn classes(&self) -> usize;
    /// Run one padded batch; returns batch*classes logits.
    fn run(&mut self, batch_data: &[f32]) -> Result<Vec<f32>, String>;
}

/// Factory so each worker thread can build its own (non-Send) executor.
pub type ExecutorFactory = Box<dyn Fn() -> Result<Box<dyn Executor>, String> + Send + Sync>;

/// A running inference server.
pub struct Server {
    tx: Option<SyncSender<Request>>,
    pub metrics: Arc<Metrics>,
    sup: Arc<Supervision>,
    /// Worker handles; the supervisor pushes respawned workers here.
    workers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    supervisor: Option<JoinHandle<()>>,
    /// Learned from the first worker's init ack; `submit` validates
    /// image lengths against it.
    image_len: usize,
    default_deadline: Option<Duration>,
}

impl Server {
    /// Start `cfg.workers` workers; `sim_cycles_per_image` is the
    /// hardware cost the qnn scheduler attributes to one inference.
    ///
    /// Blocks until every worker's `factory()` has resolved.  Fails
    /// with [`ServeError::NoWorkers`] if *zero* workers come up; a
    /// partially-failed pool starts degraded and the supervisor keeps
    /// trying to fill the failed slots under the restart budget.
    pub fn start(
        factory: ExecutorFactory,
        cfg: ServeConfig,
        sim_cycles_per_image: u64,
    ) -> Result<Server, ServeError> {
        let workers_n = cfg.workers.max(1);
        let (tx, rx) = sync_channel::<Request>(cfg.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::default());
        let factory = Arc::new(factory);
        let sup = Arc::new(Supervision::new(workers_n, cfg.restart_budget));
        let window = Duration::from_micros(cfg.batch_window_us);
        let handles = Arc::new(Mutex::new(Vec::new()));

        // Workers ack their init result (the factory runs *in* the
        // worker thread — executors are not Send), so start can fail
        // typed instead of silently shrinking the pool.
        let (ack_tx, ack_rx) = std::sync::mpsc::channel::<Result<usize, String>>();
        for slot in 0..workers_n {
            sup.slots[slot].starting.store(true, Ordering::SeqCst);
            match spawn_worker(
                slot,
                &rx,
                &metrics,
                &factory,
                &sup,
                window,
                sim_cycles_per_image,
                Some(ack_tx.clone()),
            ) {
                Ok(h) => handles.lock().unwrap().push(h),
                Err(e) => {
                    sup.slots[slot].starting.store(false, Ordering::SeqCst);
                    let _ = ack_tx.send(Err(e.to_string()));
                }
            }
        }
        drop(ack_tx);
        let mut image_len = None;
        let mut first_err = None;
        for ack in ack_rx.iter() {
            match ack {
                Ok(len) => {
                    image_len.get_or_insert(len);
                }
                Err(e) => {
                    metrics.record_errors(1);
                    first_err.get_or_insert(e);
                }
            }
        }
        let Some(image_len) = image_len else {
            // zero workers came up: fail fast, don't hand out a server
            // that queues forever
            if let Some(e) = first_err {
                eprintln!("server start: every worker failed to initialise: {e}");
            }
            sup.stop.store(true, Ordering::SeqCst);
            for w in handles.lock().unwrap().drain(..) {
                let _ = w.join();
            }
            return Err(ServeError::NoWorkers);
        };

        let supervisor = {
            let sup = Arc::clone(&sup);
            let rx = Arc::clone(&rx);
            let metrics = Arc::clone(&metrics);
            let factory = Arc::clone(&factory);
            let handles = Arc::clone(&handles);
            let backoff = Duration::from_micros(cfg.restart_backoff_us.max(1));
            std::thread::Builder::new()
                .name("sparq-supervisor".into())
                .spawn(move || {
                    supervisor_loop(
                        sup,
                        rx,
                        metrics,
                        factory,
                        handles,
                        window,
                        sim_cycles_per_image,
                        backoff,
                    )
                })
                .map_err(|e| ServeError::Worker(e.to_string()))?
        };

        Ok(Server {
            tx: Some(tx),
            metrics,
            sup,
            workers: handles,
            supervisor: Some(supervisor),
            image_len,
            default_deadline: (cfg.deadline_us > 0)
                .then(|| Duration::from_micros(cfg.deadline_us)),
        })
    }

    /// Blocking inference (honours the config-level default deadline,
    /// if any, on the worker side only — the call itself blocks until
    /// a response arrives or the server dies).
    pub fn infer(&self, image: Vec<f32>) -> Result<InferResult, ServeError> {
        let rx = self.submit(image)?;
        rx.recv().map_err(|_| ServeError::Closed)?
    }

    /// Bounded-time inference: the request carries `timeout` as its
    /// deadline and the call returns [`ServeError::Deadline`] if no
    /// response arrives within it.  Never blocks longer than `timeout`.
    pub fn infer_timeout(
        &self,
        image: Vec<f32>,
        timeout: Duration,
    ) -> Result<InferResult, ServeError> {
        let rx = self.submit_with_deadline(image, Some(timeout))?;
        match rx.recv_timeout(timeout) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => Err(ServeError::Deadline),
            Err(RecvTimeoutError::Disconnected) => Err(ServeError::Closed),
        }
    }

    /// Non-blocking submit with the config-level default deadline; the
    /// receiver yields the result later.
    pub fn submit(
        &self,
        image: Vec<f32>,
    ) -> Result<Receiver<Result<InferResult, ServeError>>, ServeError> {
        self.submit_with_deadline(image, self.default_deadline)
    }

    /// Non-blocking submit with an explicit per-request deadline
    /// (`None` = no deadline).  Validates the image length
    /// ([`ServeError::BadInput`]) and fails fast with
    /// [`ServeError::NoWorkers`] when the pool is dead for good.
    pub fn submit_with_deadline(
        &self,
        image: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Result<Receiver<Result<InferResult, ServeError>>, ServeError> {
        if image.len() != self.image_len {
            self.metrics.record_bad_input();
            return Err(ServeError::BadInput { got: image.len(), want: self.image_len });
        }
        if self.sup.pool_dead() {
            self.metrics.record_no_workers(1);
            return Err(ServeError::NoWorkers);
        }
        let (rtx, rrx) = sync_channel(1);
        let now = Instant::now();
        let req = Request {
            image,
            resp: rtx,
            enqueued: now,
            deadline: deadline.map(|d| now + d),
        };
        // gauge BEFORE the send: a worker may dequeue (and queue_dec)
        // the instant try_send lands, and inc-after-send would let the
        // gauge transiently read negative
        self.metrics.queue_inc();
        let Some(tx) = self.tx.as_ref() else {
            self.metrics.queue_dec(1);
            return Err(ServeError::Closed);
        };
        match tx.try_send(req) {
            Ok(()) => Ok(rrx),
            Err(TrySendError::Full(_)) => {
                self.metrics.queue_dec(1);
                self.metrics.record_rejected();
                Err(ServeError::QueueFull)
            }
            Err(TrySendError::Disconnected(_)) => {
                self.metrics.queue_dec(1);
                Err(ServeError::Closed)
            }
        }
    }

    /// Pool health right now.
    pub fn health(&self) -> Health {
        let alive = self.sup.live.load(Ordering::SeqCst).max(0) as usize;
        let configured = self.sup.slots.len();
        Health {
            configured,
            alive,
            restarts: self.sup.restarts.load(Ordering::SeqCst),
            restart_budget_left: self.sup.budget_left.load(Ordering::SeqCst).max(0) as u32,
            degraded: self.sup.degraded.load(Ordering::SeqCst) || alive < configured,
            heartbeats: self
                .sup
                .slots
                .iter()
                .map(|s| s.heartbeat.load(Ordering::Relaxed))
                .collect(),
        }
    }

    /// Drain the queue fully, stop the workers, return the final
    /// metrics (the original unbounded drain).
    pub fn shutdown(mut self) -> Snapshot {
        self.stop_threads();
        self.metrics.snapshot()
    }

    /// Graceful bounded drain: stop accepting work immediately, let
    /// queued work finish until `deadline`, shed whatever is still
    /// queued after it with [`ServeError::Closed`], and report what
    /// happened.  In-flight batches run to completion (execution is
    /// not preempted), so the wall time is bounded by the deadline
    /// plus one batch execution.
    pub fn shutdown_with_deadline(mut self, deadline: Duration) -> (Snapshot, DrainStats) {
        let t0 = Instant::now();
        let before = self.metrics.snapshot();
        *self.sup.drain_by.write().unwrap() = Some(t0 + deadline);
        self.stop_threads();
        let after = self.metrics.snapshot();
        let stats = DrainStats {
            completed: after.completed.saturating_sub(before.completed),
            shed: after.drain_shed.saturating_sub(before.drain_shed),
            wall_us: t0.elapsed().as_micros() as u64,
        };
        (after, stats)
    }

    fn stop_threads(&mut self) {
        self.tx.take(); // close the channel; workers exit once drained
        self.sup.stop.store(true, Ordering::SeqCst);
        if let Some(s) = self.supervisor.take() {
            let _ = s.join();
        }
        // The workers vec is stable now: only the supervisor pushed.
        for w in self.workers.lock().unwrap().drain(..) {
            let _ = w.join();
        }
    }
}

/// Spawn one worker thread for `slot`.  The factory runs *inside* the
/// thread (executors are not `Send`); on success the slot is marked
/// alive and guarded, and `ack` (when present — server start) carries
/// `Ok(image_len)` or the factory error.
#[allow(clippy::too_many_arguments)]
fn spawn_worker(
    slot: usize,
    rx: &Arc<Mutex<Receiver<Request>>>,
    metrics: &Arc<Metrics>,
    factory: &Arc<ExecutorFactory>,
    sup: &Arc<Supervision>,
    window: Duration,
    sim_cycles_per_image: u64,
    ack: Option<std::sync::mpsc::Sender<Result<usize, String>>>,
) -> std::io::Result<JoinHandle<()>> {
    let rx = Arc::clone(rx);
    let metrics = Arc::clone(metrics);
    let factory = Arc::clone(factory);
    let sup = Arc::clone(sup);
    std::thread::Builder::new().name(format!("sparq-worker-{slot}")).spawn(move || {
        let exec = match factory() {
            Ok(e) => e,
            Err(e) => {
                sup.slots[slot].starting.store(false, Ordering::SeqCst);
                match ack {
                    Some(a) => {
                        let _ = a.send(Err(e));
                    }
                    None => {
                        metrics.record_errors(1);
                        eprintln!("worker {slot} respawn: executor init failed: {e}");
                    }
                }
                return;
            }
        };
        sup.worker_up(slot);
        let _guard = WorkerGuard { sup: Arc::clone(&sup), slot };
        if let Some(a) = ack {
            let _ = a.send(Ok(exec.image_len()));
        }
        worker_loop(exec, slot, &rx, &metrics, &sup, window, sim_cycles_per_image);
    })
}

/// The supervisor: scans the pool every [`SUPERVISOR_POLL`], respawns
/// dead slots under the restart budget with per-slot exponential
/// backoff, and — once the pool is dead for good — latches `degraded`
/// and terminally drains the queue with [`ServeError::NoWorkers`] so
/// no submitted request is ever stranded.
#[allow(clippy::too_many_arguments)]
fn supervisor_loop(
    sup: Arc<Supervision>,
    rx: Arc<Mutex<Receiver<Request>>>,
    metrics: Arc<Metrics>,
    factory: Arc<ExecutorFactory>,
    handles: Arc<Mutex<Vec<JoinHandle<()>>>>,
    window: Duration,
    sim_cycles_per_image: u64,
    backoff: Duration,
) {
    let n = sup.slots.len();
    let mut next_try = vec![Instant::now(); n];
    // Spawn attempts since the slot was last seen alive (drives the
    // backoff doubling while a factory keeps failing).
    let mut attempts = vec![0u32; n];
    while !sup.stop.load(Ordering::SeqCst) {
        std::thread::sleep(SUPERVISOR_POLL);
        let now = Instant::now();
        for slot in 0..n {
            let s = &sup.slots[slot];
            if s.alive.load(Ordering::SeqCst) {
                attempts[slot] = 0;
                continue;
            }
            if s.starting.load(Ordering::SeqCst) || now < next_try[slot] {
                continue;
            }
            if sup.budget_left.load(Ordering::SeqCst) <= 0 {
                continue;
            }
            sup.budget_left.fetch_sub(1, Ordering::SeqCst);
            s.starting.store(true, Ordering::SeqCst);
            match spawn_worker(
                slot,
                &rx,
                &metrics,
                &factory,
                &sup,
                window,
                sim_cycles_per_image,
                None,
            ) {
                Ok(h) => {
                    sup.restarts.fetch_add(1, Ordering::SeqCst);
                    handles.lock().unwrap().push(h);
                }
                Err(_) => s.starting.store(false, Ordering::SeqCst),
            }
            attempts[slot] = attempts[slot].saturating_add(1);
            next_try[slot] = now + backoff * 2u32.saturating_pow(attempts[slot].min(6));
        }
        if sup.pool_dead() {
            sup.degraded.store(true, Ordering::SeqCst);
            // Nobody will ever drain the queue again: answer whatever
            // is in it typed instead of leaving clients to hang.
            let g = rx.lock().unwrap();
            let mut drained = 0u64;
            while let Ok(req) = g.try_recv() {
                let _ = req.resp.send(Err(ServeError::NoWorkers));
                drained += 1;
            }
            drop(g);
            if drained > 0 {
                metrics.queue_dec(drained);
                metrics.record_no_workers(drained);
            }
        }
    }
}

fn worker_loop(
    mut exec: Box<dyn Executor>,
    slot: usize,
    rx: &Arc<Mutex<Receiver<Request>>>,
    metrics: &Arc<Metrics>,
    sup: &Arc<Supervision>,
    window: Duration,
    sim_cycles_per_image: u64,
) {
    let batch = exec.batch();
    let per = exec.image_len();
    let classes = exec.classes();

    loop {
        sup.slots[slot].heartbeat.fetch_add(1, Ordering::Relaxed);
        // take the first request (bounded wait so heartbeats tick while
        // idle), then greedily batch
        let first = {
            let g = rx.lock().unwrap();
            match g.recv_timeout(HEARTBEAT_POLL) {
                Ok(r) => r,
                Err(RecvTimeoutError::Timeout) => continue, // heartbeat tick
                Err(RecvTimeoutError::Disconnected) => return, // channel closed: shut down
            }
        };
        metrics.queue_dec(1);
        let mut reqs = vec![first];
        let wdl = Instant::now() + window;
        while reqs.len() < batch {
            let g = rx.lock().unwrap();
            let left = wdl.saturating_duration_since(Instant::now());
            match g.recv_timeout(left) {
                Ok(r) => {
                    metrics.queue_dec(1);
                    reqs.push(r);
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        // Graceful drain: past the drain deadline, queued work is shed
        // typed instead of executed.
        if let Some(dl) = sup.drain_deadline() {
            if Instant::now() > dl {
                metrics.record_drain_shed(reqs.len() as u64);
                for r in reqs {
                    let _ = r.resp.send(Err(ServeError::Closed));
                }
                continue;
            }
        }

        // Deadline shedding: an expired request is answered typed and
        // never executed (it would be wasted work — the client is gone).
        let now = Instant::now();
        let mut shed = 0u64;
        reqs.retain(|r| match r.deadline {
            Some(d) if now > d => {
                shed += 1;
                let _ = r.resp.send(Err(ServeError::Deadline));
                false
            }
            _ => true,
        });
        if shed > 0 {
            metrics.record_deadline_shed(shed);
        }
        if reqs.is_empty() {
            continue;
        }

        // assemble the padded batch (submit validated every image is
        // exactly `image_len` long; the min() is belt-and-braces for a
        // heterogeneous-executor misconfiguration)
        let mut data = vec![0f32; batch * per];
        for (i, r) in reqs.iter().enumerate() {
            let n = r.image.len().min(per);
            data[i * per..i * per + n].copy_from_slice(&r.image[..n]);
        }
        // One poisoned request must not kill the worker: a panicking
        // executor is caught and mapped to `ServeError::Worker` like any
        // other executor error, recorded in the metrics, and the worker
        // loops on to the next batch.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| exec.run(&data)))
            .unwrap_or_else(|p| Err(panic_message(p.as_ref())));
        let bsz = reqs.len() as u32;
        match result {
            Ok(logits) => {
                // fills count EXECUTED batches only (errored batches are
                // tracked via `errors`) — same accounting as the batched
                // QnnBatchServer path, so the histograms stay comparable
                metrics.record_fill(bsz);
                for (i, r) in reqs.into_iter().enumerate() {
                    let l = logits[i * classes..(i + 1) * classes].to_vec();
                    let class = argmax(&l);
                    let lat = r.enqueued.elapsed().as_micros() as u64;
                    metrics.record(lat, bsz, sim_cycles_per_image);
                    let _ = r.resp.send(Ok(InferResult {
                        logits: l,
                        class,
                        sim_cycles: sim_cycles_per_image,
                        batch: bsz,
                    }));
                }
            }
            Err(e) => {
                metrics.record_errors(reqs.len() as u64);
                let killed = fault::is_kill(&e);
                for r in reqs {
                    let _ = r.resp.send(Err(ServeError::Worker(e.clone())));
                }
                if killed {
                    // A chaos kill models a crashed worker: reply, then
                    // die — the drop guard flips the slot dead and the
                    // supervisor takes it from there.
                    return;
                }
            }
        }
    }
}

/// Best-effort text of a caught executor panic payload.
pub(crate) fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        format!("executor panicked: {s}")
    } else if let Some(s) = p.downcast_ref::<String>() {
        format!("executor panicked: {s}")
    } else {
        "executor panicked".into()
    }
}

/// NaN-safe argmax.  `total_cmp` gives a total order (NaN sorts above
/// +inf), so a corrupt logit can never panic the worker thread — the
/// old `partial_cmp(..).unwrap()` here was a latent capacity leak: one
/// NaN logit killed the worker outside `catch_unwind`, silently and
/// permanently shrinking the pool.
fn argmax(xs: &[f32]) -> usize {
    xs.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).map(|(i, _)| i).unwrap_or(0)
}

/// PJRT-backed executor over a named artifact.
pub struct PjrtExecutor {
    rt: crate::runtime::Runtime,
    model: String,
    batch: usize,
    image_len: usize,
    classes: usize,
    dims: [i64; 4],
}

impl PjrtExecutor {
    /// Build from an artifacts directory + model name (reads the batch
    /// and shapes from the manifest).
    pub fn new(dir: &std::path::Path, model: &str) -> Result<PjrtExecutor, String> {
        let rt = crate::runtime::Runtime::load(dir).map_err(|e| e.to_string())?;
        let art = rt
            .manifest
            .artifact(model)
            .ok_or_else(|| format!("model {model} not in manifest"))?;
        let batch = art.meta_u32("batch").unwrap_or(16) as usize;
        let classes = art.meta_u32("out").unwrap_or(4) as usize;
        let shape: Vec<i64> = art
            .meta
            .get("in")
            .map(|s| s.split('x').filter_map(|t| t.parse().ok()).collect())
            .unwrap_or_else(|| vec![1, 16, 16]);
        let image_len = shape.iter().product::<i64>() as usize;
        Ok(PjrtExecutor {
            rt,
            model: model.to_string(),
            batch,
            image_len,
            classes,
            dims: [batch as i64, shape[0], shape[1], shape[2]],
        })
    }
}

impl Executor for PjrtExecutor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn image_len(&self) -> usize {
        self.image_len
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn run(&mut self, batch_data: &[f32]) -> Result<Vec<f32>, String> {
        self.rt
            .exec_f32(&self.model, &[(batch_data, &self.dims)])
            .map_err(|e| e.to_string())
    }
}

/// Simulator-backed executor: compile-once/execute-many serving of a
/// sub-byte conv2d on the simulated Sparq.  The compiled program comes
/// from a [`ProgramCache`] **shared across all workers** (via the
/// factory's `Arc`); each worker owns a *private* [`MachinePool`], so
/// steady-state serving holds one machine per worker with no
/// cross-worker lock traffic.  What each worker actually executes is
/// the cached micro-op form (`sim::CompiledProgram`, DESIGN.md §Perf)
/// — per-request host work is activation rebind + word-parallel
/// execution, with zero per-instruction re-validation.
///
/// Request contract: an "image" is the flattened (c, h, w) activation
/// tensor as f32 levels (clamped + rounded into the A-bit range); the
/// "logits" are the per-output-channel sums of the conv output — a
/// global-average-pool head over real simulated conv numerics.
pub struct SimConvExecutor {
    model: crate::runtime::SimConvModel,
    pool: crate::sim::MachinePool,
    batch: usize,
}

use crate::kernels::{ConvDims, ConvVariant, ProgramCache};
use crate::ProcessorConfig;

impl SimConvExecutor {
    pub fn new(
        cfg: &ProcessorConfig,
        dims: ConvDims,
        variant: ConvVariant,
        batch: usize,
        seed: u64,
        cache: &ProgramCache,
    ) -> Result<SimConvExecutor, String> {
        let model = crate::runtime::SimConvModel::compile(cfg, dims, variant, seed, cache)
            .map_err(|e| e.to_string())?;
        Ok(SimConvExecutor {
            model,
            pool: crate::sim::MachinePool::new(),
            batch: batch.max(1),
        })
    }

    /// Pool diagnostics (tests assert reuse).
    pub fn pool_stats(&self) -> crate::sim::pool::PoolStats {
        self.pool.stats()
    }
}

impl Executor for SimConvExecutor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn image_len(&self) -> usize {
        self.model.input_len()
    }

    fn classes(&self) -> usize {
        self.model.dims.co as usize
    }

    fn run(&mut self, batch_data: &[f32]) -> Result<Vec<f32>, String> {
        let per = self.model.input_len();
        let classes = self.model.dims.co as usize;
        let plane = self.model.output_len() / classes;
        let mut logits = Vec::with_capacity(batch_data.len() / per * classes);
        for img in batch_data.chunks(per) {
            // All-zero activation levels produce an exactly-zero conv
            // output (every product is 0), so zero-padded batch slots —
            // and genuine all-zero images — skip the simulation instead
            // of paying a full conv2d per padding slot.
            if img.iter().all(|&v| self.model.quantize_level(v) == 0) {
                logits.resize(logits.len() + classes, 0.0);
                continue;
            }
            let (out, _report) =
                self.model.infer(&self.pool, img).map_err(|e| e.to_string())?;
            for o in 0..classes {
                logits.push(out[o * plane..(o + 1) * plane].iter().sum::<i64>() as f32);
            }
        }
        Ok(logits)
    }
}

/// Factory for [`Server::start`]: every worker builds its own
/// `SimConvExecutor` (private machine pool) against the one shared
/// program cache.
pub fn sim_conv_factory(
    cfg: ProcessorConfig,
    dims: ConvDims,
    variant: ConvVariant,
    batch: usize,
    seed: u64,
    cache: Arc<ProgramCache>,
) -> ExecutorFactory {
    Box::new(move || {
        Ok(Box::new(SimConvExecutor::new(&cfg, dims, variant, batch, seed, &cache)?)
            as Box<dyn Executor>)
    })
}

/// Whole-network simulator executor: serves SparqCNN classification
/// through the chained dataflow program (`qnn::compiled::CompiledQnn`)
/// — every request runs conv/requant/maxpool/GAP+FC end-to-end in the
/// simulated arena and the logits come straight out of it.  Same
/// sharing model as [`SimConvExecutor`]: the compiled network lives in
/// the [`ProgramCache`] shared across workers (graph-level key), each
/// worker owns a private [`MachinePool`] sized for the arena.
pub struct SimQnnExecutor {
    model: crate::runtime::SimQnnModel,
    pool: crate::sim::MachinePool,
    batch: usize,
}

impl SimQnnExecutor {
    pub fn new(
        cfg: &ProcessorConfig,
        graph: &crate::qnn::QnnGraph,
        precision: crate::qnn::schedule::QnnPrecision,
        batch: usize,
        seed: u64,
        cache: &ProgramCache,
    ) -> Result<SimQnnExecutor, String> {
        let model = crate::runtime::SimQnnModel::compile(cfg, graph, precision, seed, cache)
            .map_err(|e| e.to_string())?;
        Ok(SimQnnExecutor {
            model,
            pool: crate::sim::MachinePool::new(),
            batch: batch.max(1),
        })
    }

    /// Pool diagnostics (tests assert reuse).
    pub fn pool_stats(&self) -> crate::sim::pool::PoolStats {
        self.pool.stats()
    }
}

impl Executor for SimQnnExecutor {
    fn batch(&self) -> usize {
        self.batch
    }

    fn image_len(&self) -> usize {
        self.model.input_len()
    }

    fn classes(&self) -> usize {
        self.model.classes()
    }

    fn run(&mut self, batch_data: &[f32]) -> Result<Vec<f32>, String> {
        let per = self.model.input_len();
        let classes = self.model.classes();
        let mut logits = Vec::with_capacity(batch_data.len() / per * classes);
        for img in batch_data.chunks(per) {
            // All-zero level images flow zeros through every layer
            // (convs of zeros, requant(0)=0, max(0)=0, FC on zero GAP
            // sums), so zero-padded batch slots skip the simulation.
            if img.iter().all(|&v| self.model.quantize_level(v) == 0) {
                logits.resize(logits.len() + classes, 0.0);
                continue;
            }
            let (out, _cycles) = self.model.infer(&self.pool, img).map_err(|e| e.to_string())?;
            logits.extend(out.iter().map(|&v| v as f32));
        }
        Ok(logits)
    }
}

/// Factory for [`Server::start`]: full-network simulator serving —
/// every worker builds its own `SimQnnExecutor` (private machine pool)
/// against the one shared program cache.
pub fn sim_qnn_factory(
    cfg: ProcessorConfig,
    graph: crate::qnn::QnnGraph,
    precision: crate::qnn::schedule::QnnPrecision,
    batch: usize,
    seed: u64,
    cache: Arc<ProgramCache>,
) -> ExecutorFactory {
    Box::new(move || {
        Ok(Box::new(SimQnnExecutor::new(&cfg, &graph, precision, batch, seed, &cache)?)
            as Box<dyn Executor>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A mock: "logits" = [sum(image), 0, 0, index-of-first-nonzero].
    struct Mock {
        batch: usize,
        calls: usize,
    }

    impl Executor for Mock {
        fn batch(&self) -> usize {
            self.batch
        }
        fn image_len(&self) -> usize {
            4
        }
        fn classes(&self) -> usize {
            2
        }
        fn run(&mut self, data: &[f32]) -> Result<Vec<f32>, String> {
            self.calls += 1;
            Ok(data
                .chunks(4)
                .flat_map(|img| {
                    let s: f32 = img.iter().sum();
                    [s, -s]
                })
                .collect())
        }
    }

    fn mock_server(workers: usize, window_us: u64, depth: usize) -> Server {
        let cfg = ServeConfig { workers, batch_window_us: window_us, queue_depth: depth, ..Default::default() };
        Server::start(Box::new(|| Ok(Box::new(Mock { batch: 4, calls: 0 }))), cfg, 1234).unwrap()
    }

    #[test]
    fn single_request_roundtrip() {
        let s = mock_server(1, 100, 16);
        let r = s.infer(vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(r.logits, vec![10.0, -10.0]);
        assert_eq!(r.class, 0);
        assert_eq!(r.sim_cycles, 1234);
        let snap = s.shutdown();
        assert_eq!(snap.completed, 1);
    }

    #[test]
    fn negative_sum_classifies_to_second_logit() {
        let s = mock_server(1, 100, 16);
        let r = s.infer(vec![-5.0, 0.0, 0.0, 0.0]).unwrap();
        assert_eq!(r.class, 1);
        s.shutdown();
    }

    #[test]
    fn batching_aggregates_concurrent_requests() {
        let s = Arc::new(mock_server(1, 20_000, 64));
        let mut handles = vec![];
        for i in 0..8 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                s.infer(vec![i as f32, 0.0, 0.0, 0.0]).unwrap()
            }));
        }
        let results: Vec<InferResult> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // with an open 20ms window and batch 4, most requests share rides
        let max_batch = results.iter().map(|r| r.batch).max().unwrap();
        assert!(max_batch >= 2, "no batching happened");
        let s = Arc::try_unwrap(s).ok().unwrap();
        assert_eq!(s.shutdown().completed, 8);
    }

    /// Executes like Mock but takes `delay` per batch (pins the worker
    /// so queues fill / deadlines expire deterministically).
    struct SlowMock {
        delay: Duration,
    }

    impl Executor for SlowMock {
        fn batch(&self) -> usize {
            1
        }
        fn image_len(&self) -> usize {
            4
        }
        fn classes(&self) -> usize {
            2
        }
        fn run(&mut self, data: &[f32]) -> Result<Vec<f32>, String> {
            std::thread::sleep(self.delay);
            let s: f32 = data.iter().sum();
            Ok(vec![s, -s])
        }
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // a slow executor pins the single worker while the depth-1
        // queue fills; submits past that are typed rejections
        let cfg =
            ServeConfig { workers: 1, batch_window_us: 10, queue_depth: 1, ..Default::default() };
        let s = Server::start(
            Box::new(|| {
                Ok(Box::new(SlowMock { delay: Duration::from_millis(100) }) as Box<dyn Executor>)
            }),
            cfg,
            0,
        )
        .unwrap();
        let mut rejected = false;
        let mut pending = vec![];
        for _ in 0..16 {
            match s.submit(vec![0.0; 4]) {
                Ok(rx) => pending.push(rx),
                Err(ServeError::QueueFull) => {
                    rejected = true;
                    break;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(rejected, "queue never filled");
        for rx in pending {
            let _ = rx.recv();
        }
        let snap = s.shutdown();
        assert!(snap.rejected >= 1);
    }

    #[test]
    fn argmax_is_nan_safe() {
        // total order: positive NaN sorts above every number
        assert_eq!(argmax(&[f32::NAN, 1.0]), 0);
        assert_eq!(argmax(&[1.0, f32::NAN]), 1);
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[]), 0);
    }

    /// Returns a NaN logit for every image.
    struct NanMock;

    impl Executor for NanMock {
        fn batch(&self) -> usize {
            1
        }
        fn image_len(&self) -> usize {
            4
        }
        fn classes(&self) -> usize {
            2
        }
        fn run(&mut self, _data: &[f32]) -> Result<Vec<f32>, String> {
            Ok(vec![f32::NAN, 1.0])
        }
    }

    #[test]
    fn nan_logits_do_not_kill_the_worker() {
        // regression: argmax used partial_cmp().unwrap() outside
        // catch_unwind — one NaN logit killed the worker for good
        let cfg =
            ServeConfig { workers: 1, batch_window_us: 10, queue_depth: 16, ..Default::default() };
        let s = Server::start(Box::new(|| Ok(Box::new(NanMock) as Box<dyn Executor>)), cfg, 0)
            .unwrap();
        let first = s.infer(vec![1.0; 4]).expect("NaN logits must not fail the request");
        assert!(first.logits[0].is_nan());
        assert_eq!(first.class, 0); // NaN sorts above 1.0 in total order
        // the worker survived: a second request still serves
        s.infer(vec![2.0; 4]).expect("worker must survive NaN logits");
        assert_eq!(s.health().alive, 1);
        let snap = s.shutdown();
        assert_eq!(snap.completed, 2);
        assert_eq!(snap.errors, 0);
    }

    #[test]
    fn wrong_length_image_is_rejected_typed() {
        let s = mock_server(1, 10, 16);
        match s.infer(vec![0.5; 3]) {
            Err(ServeError::BadInput { got: 3, want: 4 }) => {}
            other => panic!("expected BadInput, got {other:?}"),
        }
        match s.infer(vec![0.5; 5]) {
            Err(ServeError::BadInput { got: 5, want: 4 }) => {}
            other => panic!("expected BadInput, got {other:?}"),
        }
        // valid-length traffic is unaffected
        s.infer(vec![1.0; 4]).unwrap();
        let snap = s.shutdown();
        assert_eq!(snap.bad_input, 2);
        assert_eq!(snap.completed, 1);
    }

    #[test]
    fn start_fails_typed_when_every_worker_fails_init() {
        let cfg = ServeConfig { workers: 2, ..Default::default() };
        let r = Server::start(Box::new(|| Err("no such model".into())), cfg, 0);
        match r {
            Err(ServeError::NoWorkers) => {}
            Ok(_) => panic!("start must fail when zero workers come up"),
            Err(e) => panic!("expected NoWorkers, got {e:?}"),
        }
    }

    #[test]
    fn partial_init_failure_starts_degraded_but_serves() {
        use std::sync::atomic::AtomicUsize;
        let calls = Arc::new(AtomicUsize::new(0));
        let cfg = ServeConfig { workers: 2, restart_budget: 0, ..Default::default() };
        let s = Server::start(
            Box::new(move || {
                if calls.fetch_add(1, Ordering::SeqCst) == 0 {
                    Err("first worker loses".into())
                } else {
                    Ok(Box::new(Mock { batch: 4, calls: 0 }) as Box<dyn Executor>)
                }
            }),
            cfg,
            0,
        )
        .expect("one worker up is enough to start");
        let h = s.health();
        assert_eq!(h.configured, 2);
        assert_eq!(h.alive, 1);
        assert!(h.degraded);
        s.infer(vec![1.0; 4]).expect("the surviving worker serves");
        let snap = s.shutdown();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.errors, 1); // the failed init
    }

    #[test]
    fn expired_requests_are_shed_not_executed() {
        // worker busy for 50ms on the first request; the second carries
        // a 1ms deadline and must come back Deadline, never executed
        let cfg =
            ServeConfig { workers: 1, batch_window_us: 10, queue_depth: 16, ..Default::default() };
        let s = Server::start(
            Box::new(|| {
                Ok(Box::new(SlowMock { delay: Duration::from_millis(50) }) as Box<dyn Executor>)
            }),
            cfg,
            0,
        )
        .unwrap();
        let r1 = s.submit_with_deadline(vec![1.0; 4], None).unwrap();
        std::thread::sleep(Duration::from_millis(5)); // let the worker take r1
        let r2 = s.submit_with_deadline(vec![2.0; 4], Some(Duration::from_millis(1))).unwrap();
        assert!(r1.recv().unwrap().is_ok());
        match r2.recv().unwrap() {
            Err(ServeError::Deadline) => {}
            other => panic!("expected Deadline, got {other:?}"),
        }
        let snap = s.shutdown();
        assert_eq!(snap.deadline_shed, 1);
        assert_eq!(snap.completed, 1);
    }

    #[test]
    fn infer_timeout_bounds_the_client_wait() {
        let cfg =
            ServeConfig { workers: 1, batch_window_us: 10, queue_depth: 16, ..Default::default() };
        let s = Server::start(
            Box::new(|| {
                Ok(Box::new(SlowMock { delay: Duration::from_millis(300) }) as Box<dyn Executor>)
            }),
            cfg,
            0,
        )
        .unwrap();
        let _busy = s.submit(vec![1.0; 4]).unwrap(); // pins the worker
        std::thread::sleep(Duration::from_millis(5));
        let t0 = Instant::now();
        match s.infer_timeout(vec![2.0; 4], Duration::from_millis(10)) {
            Err(ServeError::Deadline) => {}
            other => panic!("expected Deadline, got {other:?}"),
        }
        assert!(t0.elapsed() < Duration::from_millis(250), "client wait was not bounded");
        s.shutdown();
    }

    #[test]
    fn drain_with_deadline_sheds_queued_work_typed() {
        let cfg =
            ServeConfig { workers: 1, batch_window_us: 10, queue_depth: 64, ..Default::default() };
        let s = Server::start(
            Box::new(|| {
                Ok(Box::new(SlowMock { delay: Duration::from_millis(20) }) as Box<dyn Executor>)
            }),
            cfg,
            0,
        )
        .unwrap();
        let pending: Vec<_> = (0..10).map(|_| s.submit(vec![1.0; 4]).unwrap()).collect();
        let (snap, stats) = s.shutdown_with_deadline(Duration::from_millis(30));
        // every request resolved exactly one way: executed or shed
        assert_eq!(stats.completed + stats.shed, 10, "{stats:?}");
        assert!(stats.shed > 0, "a 30ms drain cannot finish 10x20ms of work");
        assert!(stats.completed >= 1, "work in flight at drain start still completes");
        assert_eq!(snap.drain_shed, stats.shed);
        for rx in pending {
            match rx.recv().unwrap() {
                Ok(_) | Err(ServeError::Closed) => {}
                other => panic!("expected Ok or Closed, got {other:?}"),
            }
        }
    }

    #[test]
    fn multiple_workers_all_serve() {
        let s = Arc::new(mock_server(3, 50, 64));
        let mut handles = vec![];
        for i in 0..30 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                s.infer(vec![i as f32, 1.0, 0.0, 0.0]).unwrap()
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = Arc::try_unwrap(s).ok().unwrap();
        let snap = s.shutdown();
        assert_eq!(snap.completed, 30);
        assert!(snap.throughput_rps > 0.0);
    }

    #[test]
    fn shutdown_closes_queue() {
        let s = mock_server(1, 10, 4);
        let snap = s.shutdown();
        assert_eq!(snap.completed, 0);
    }

    /// An executor that panics on the first batch, then recovers.
    struct PanicsOnce {
        panicked: bool,
    }

    impl Executor for PanicsOnce {
        fn batch(&self) -> usize {
            1
        }
        fn image_len(&self) -> usize {
            4
        }
        fn classes(&self) -> usize {
            2
        }
        fn run(&mut self, data: &[f32]) -> Result<Vec<f32>, String> {
            if !self.panicked {
                self.panicked = true;
                panic!("poisoned request");
            }
            let s: f32 = data.iter().sum();
            Ok(vec![s, -s])
        }
    }

    #[test]
    fn executor_panic_does_not_kill_the_worker() {
        let cfg = ServeConfig { workers: 1, batch_window_us: 10, queue_depth: 16, ..Default::default() };
        let s = Server::start(
            Box::new(|| Ok(Box::new(PanicsOnce { panicked: false }) as Box<dyn Executor>)),
            cfg,
            7,
        )
        .unwrap();
        // first request rides the poisoned batch -> typed worker error
        let first = s.infer(vec![1.0; 4]);
        match first {
            Err(ServeError::Worker(msg)) => assert!(msg.contains("poisoned"), "{msg}"),
            other => panic!("expected Worker error, got {other:?}"),
        }
        // the worker survived: the next request succeeds
        let second = s.infer(vec![1.0, 2.0, 3.0, 4.0]).expect("worker must survive the panic");
        assert_eq!(second.logits, vec![10.0, -10.0]);
        let snap = s.shutdown();
        assert_eq!(snap.errors, 1);
        assert_eq!(snap.completed, 1);
    }
}
