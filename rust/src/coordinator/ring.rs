//! Lock-free slot-reservation batch assembly (DESIGN.md §Serving).
//!
//! [`BatchRing`] replaces the per-shard `sync_channel` front door of
//! the batched server: instead of N private queues that each fill
//! slowly (underfilled batches at low load) and serialize producers
//! behind channel locks at high load, every producer reserves a slot
//! in the *current open batch frame* with one CAS and writes its
//! payload into that slot in place.  A power-of-two ring of frames
//! lets producers move on to the next frame the instant one fills or
//! seals, while consumers dispatch sealed frames concurrently.
//!
//! ## Frame life cycle
//!
//! Every frame cycles `open → filling → sealed → executing →
//! recycled`, driven entirely by one packed `AtomicU64` state word:
//!
//! ```text
//! bits  0..10   written   slots whose payload write has landed
//! bits 10..20   claimed   slots reserved by producers (<= batch)
//! bits 20..22   phase     0 = OPEN, 1 = SEALED, 2 = EXECUTING
//! bit  22       window    sealed by window expiry / close, not by
//!                         the last writer (diagnostic)
//! bits 23..64   gen       the frame's current sequence number,
//!                         modulo 2^41 (ABA guard across recycling)
//! ```
//!
//! Packing everything into one word is what makes the races cheap to
//! reason about: *every* transition is a single CAS that verifies the
//! generation, the phase, and both fill counters at once.  The
//! transition rules live in pure functions ([`claim_transition`],
//! [`seal_transition`], [`consume_transition`]) shared by the runtime
//! CAS loops and the hand-rolled loom-style model checker in the test
//! module, which enumerates thread interleavings over the same rules.
//!
//! * **Claim** (producer): `(gen, OPEN, claimed < B)` →
//!   `claimed + 1`.  The CAS (morally a `fetch_add` on the claimed
//!   field, but gen/phase-checked so a stale producer can never
//!   pollute a recycled frame) hands the producer exclusive ownership
//!   of slot index `claimed`.
//! * **Write** (producer): move the payload into the owned slot, then
//!   blindly `fetch_add` the written field — legal even if the frame
//!   sealed meanwhile, because a sealed frame is only *consumed* once
//!   `written == claimed`.
//! * **Seal**: `(gen, OPEN, claimed >= 1)` → `SEALED`.  Two
//!   contenders race here — the writer that filled the last slot and
//!   a consumer whose batching window expired — and the single CAS is
//!   the whole conflict resolution: exactly one wins, the loser's CAS
//!   fails on the phase bits.
//! * **Consume** (consumer): once `SEALED` with `written == claimed`,
//!   CAS to `EXECUTING`; the winner advances the ring tail, drains the
//!   slots, and recycles the frame with `gen + frames` in one store.
//!
//! ## Shutdown
//!
//! `close` flips the closed flag and then waits for the submitter
//! count to quiesce, after which no new claim can start (every
//! producer increments the count *before* re-checking the flag, so a
//! zero count observed after the flag is set proves quiescence — the
//! SeqCst total order makes the argument airtight).  Consumers seal
//! non-empty frames immediately once closed, so a drain never waits
//! out a batching window.
//!
//! Slot payloads travel through `Mutex<Option<T>>` cells, but the
//! mutexes are uncontended *by construction*: the claim CAS gives the
//! producer exclusive write ownership, and the consume CAS plus the
//! `written == claimed` gate give the consumer a happens-after on
//! every write.  The mutex is only there to make the transfer safe
//! Rust instead of `UnsafeCell` — it never blocks.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

const WRITTEN_SHIFT: u32 = 0;
const CLAIMED_SHIFT: u32 = 10;
const PHASE_SHIFT: u32 = 20;
const GEN_SHIFT: u32 = 23;
const FIELD_MASK: u64 = (1 << 10) - 1;
const PHASE_MASK: u64 = 0b11 << PHASE_SHIFT;
const WINDOW_BIT: u64 = 1 << 22;
/// Generations wrap modulo 2^41 — an ABA hazard would need a producer
/// to sleep across 2^41 frame lives of the same index.
const GEN_MASK: u64 = (1 << 41) - 1;

const PHASE_OPEN: u64 = 0;
const PHASE_SEALED: u64 = 1;
const PHASE_EXECUTING: u64 = 2;

/// Largest batch the 10-bit fill counters support (the serving stack
/// clamps to `MAX_BATCH = 64` well below this).
pub const MAX_RING_BATCH: usize = 512;

#[inline]
fn written_of(s: u64) -> u64 {
    (s >> WRITTEN_SHIFT) & FIELD_MASK
}

#[inline]
fn claimed_of(s: u64) -> u64 {
    (s >> CLAIMED_SHIFT) & FIELD_MASK
}

#[inline]
fn phase_of(s: u64) -> u64 {
    (s & PHASE_MASK) >> PHASE_SHIFT
}

#[inline]
fn gen_of(s: u64) -> u64 {
    s >> GEN_SHIFT
}

/// A fresh OPEN word for generation `gen` (zero claims, zero writes).
#[inline]
fn fresh(gen: u64) -> u64 {
    (gen & GEN_MASK) << GEN_SHIFT
}

/// Producer claim: an OPEN frame with room yields `(slot, new_word)`.
#[inline]
fn claim_transition(s: u64, batch: u64) -> Option<(u64, u64)> {
    if phase_of(s) != PHASE_OPEN || claimed_of(s) >= batch {
        return None;
    }
    Some((claimed_of(s), s + (1 << CLAIMED_SHIFT)))
}

/// Seal: an OPEN frame with at least one claim freezes its claims.
/// Both the last writer and the window-expiry consumer funnel through
/// this rule; the CAS in [`BatchRing::try_seal`] picks the winner.
#[inline]
fn seal_transition(s: u64, by_window: bool) -> Option<u64> {
    if phase_of(s) != PHASE_OPEN || claimed_of(s) == 0 {
        return None;
    }
    let mut ns = (s & !PHASE_MASK) | (PHASE_SEALED << PHASE_SHIFT);
    if by_window {
        ns |= WINDOW_BIT;
    }
    Some(ns)
}

/// Consume: a SEALED frame whose writes have all landed moves to
/// EXECUTING (the winning consumer owns the slots from here on).
#[inline]
fn consume_transition(s: u64) -> Option<u64> {
    if phase_of(s) != PHASE_SEALED || written_of(s) != claimed_of(s) {
        return None;
    }
    Some((s & !PHASE_MASK) | (PHASE_EXECUTING << PHASE_SHIFT))
}

struct Frame<T> {
    state: AtomicU64,
    /// One cell per batch slot.  Uncontended by construction (see the
    /// module docs) — the mutex only makes the ownership transfer
    /// expressible in safe Rust.
    slots: Box<[Mutex<Option<T>>]>,
}

/// Why a push was refused (the payload rides back with the error).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushError {
    /// Every frame of the ring is claimed-and-unconsumed: typed
    /// backpressure, never blocking.
    Full,
    /// [`BatchRing::close`] ran; no new work is accepted.
    Closed,
}

/// What one sealed batch looked like when it was consumed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchMeta {
    /// The frame's sequence number (monotone across the ring).
    pub seq: u64,
    /// Riders in the batch (`1..=batch`).
    pub fill: u32,
    /// Sealed by window expiry or close, not by the last writer.
    pub sealed_by_window: bool,
}

/// One [`BatchRing::pop`] outcome.
#[derive(Debug)]
pub enum Pop<T> {
    /// A sealed batch, drained in slot order.
    Batch(Vec<T>, BatchMeta),
    /// No riders appeared within the poll budget.
    Idle,
    /// The ring is closed and fully drained.
    Closed,
}

/// Adaptive wait: spin briefly, then yield, then sleep in 50 µs steps
/// (windows down at 50 µs stay meaningful; nothing here parks forever).
struct Backoff {
    step: u32,
}

impl Backoff {
    fn new() -> Backoff {
        Backoff { step: 0 }
    }

    fn snooze(&mut self) {
        if self.step < 64 {
            std::hint::spin_loop();
        } else if self.step < 192 {
            std::thread::yield_now();
        } else {
            std::thread::sleep(Duration::from_micros(50));
        }
        self.step = self.step.saturating_add(1);
    }
}

/// The lock-free batch-assembly ring (see the module docs).  Generic
/// over the payload so the serving path carries requests while the
/// bench and the concurrency suite drive it with plain integers.
pub struct BatchRing<T> {
    frames: Box<[Frame<T>]>,
    mask: u64,
    batch: usize,
    window: Duration,
    /// Producer cursor: the sequence number producers try to claim in.
    head: AtomicU64,
    /// Consumer cursor: the next sequence number to consume.
    tail: AtomicU64,
    closed: AtomicBool,
    /// Producers currently inside `push` (the close/drain quiescence
    /// counter).
    submitters: AtomicU64,
}

impl<T> BatchRing<T> {
    /// A ring of `frames` batch frames (rounded up to a power of two,
    /// at least 2) of `batch` slots each.  `window` is the batching
    /// window consumers enforce on partially filled frames; zero
    /// seals every non-empty frame immediately.
    pub fn new(frames: usize, batch: usize, window: Duration) -> BatchRing<T> {
        assert!(batch >= 1 && batch <= MAX_RING_BATCH, "batch must be in 1..={MAX_RING_BATCH}");
        let n = frames.clamp(2, 1 << 16).next_power_of_two();
        let frames: Vec<Frame<T>> = (0..n)
            .map(|i| Frame {
                state: AtomicU64::new(fresh(i as u64)),
                slots: (0..batch).map(|_| Mutex::new(None)).collect(),
            })
            .collect();
        BatchRing {
            frames: frames.into_boxed_slice(),
            mask: (n - 1) as u64,
            batch,
            window,
            head: AtomicU64::new(0),
            tail: AtomicU64::new(0),
            closed: AtomicBool::new(false),
            submitters: AtomicU64::new(0),
        }
    }

    /// Frames in the ring (power of two).
    pub fn frames(&self) -> usize {
        self.frames.len()
    }

    /// Slots per frame.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Total rider capacity (`frames * batch`).
    pub fn capacity(&self) -> usize {
        self.frames.len() * self.batch
    }

    /// The ring stopped accepting work.
    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// Reserve a slot in the current open frame and move `item` into
    /// it.  Returns the frame's sequence number, or the item back with
    /// a typed refusal — never blocks on a full ring.
    pub fn push(&self, item: T) -> Result<u64, (PushError, T)> {
        self.submitters.fetch_add(1, Ordering::SeqCst);
        let r = self.push_inner(item);
        self.submitters.fetch_sub(1, Ordering::SeqCst);
        r
    }

    fn push_inner(&self, item: T) -> Result<u64, (PushError, T)> {
        let mut bo = Backoff::new();
        loop {
            if self.closed.load(Ordering::SeqCst) {
                return Err((PushError::Closed, item));
            }
            let seq = self.head.load(Ordering::SeqCst);
            let f = &self.frames[(seq & self.mask) as usize];
            let s = f.state.load(Ordering::SeqCst);
            let g = gen_of(s);
            if g != seq & GEN_MASK {
                if g == seq.wrapping_add(self.frames.len() as u64) & GEN_MASK {
                    // The frame's `seq` life was sealed and consumed
                    // (a window seal can outrun every producer) before
                    // anyone advanced head past it — move on.
                    let _ = self.head.compare_exchange(
                        seq,
                        seq.wrapping_add(1),
                        Ordering::SeqCst,
                        Ordering::SeqCst,
                    );
                    continue;
                }
                if self.head.load(Ordering::SeqCst) != seq {
                    // Stale head view; chase it.
                    continue;
                }
                // The frame still holds its previous life: the ring
                // has `frames` outstanding batches — typed Full.
                return Err((PushError::Full, item));
            }
            match claim_transition(s, self.batch as u64) {
                None => {
                    // Sealed or fully claimed: help head forward and
                    // retry on the next frame.  Losing this CAS to a
                    // racing producer is fine — both chase the result.
                    let _ = self.head.compare_exchange(
                        seq,
                        seq.wrapping_add(1),
                        Ordering::SeqCst,
                        Ordering::SeqCst,
                    );
                    continue;
                }
                Some((slot, ns)) => {
                    if f.state
                        .compare_exchange(s, ns, Ordering::SeqCst, Ordering::SeqCst)
                        .is_err()
                    {
                        // Another producer claimed (or a seal landed)
                        // first; re-read and retry.
                        bo.snooze();
                        continue;
                    }
                    // Slot `slot` is exclusively ours: move the item
                    // in, then publish the write.
                    *f.slots[slot as usize].lock().unwrap() = Some(item);
                    let after =
                        f.state.fetch_add(1 << WRITTEN_SHIFT, Ordering::SeqCst) + 1;
                    // The writer that filled the last slot seals; the
                    // window-expiry consumer is the other contender
                    // and exactly one CAS wins.
                    if claimed_of(after) >= self.batch as u64 {
                        self.try_seal(f, after, false);
                    }
                    return Ok(seq);
                }
            }
        }
    }

    /// Drive `seal_transition` to a verdict: retry while the word
    /// keeps changing under an OPEN phase (claims/writes landing),
    /// stop as soon as the frame is sealed (by us or a racer).
    /// Returns whether OUR seal won.
    fn try_seal(&self, f: &Frame<T>, mut s: u64, by_window: bool) -> bool {
        loop {
            let Some(ns) = seal_transition(s, by_window) else {
                return false;
            };
            match f.state.compare_exchange(s, ns, Ordering::SeqCst, Ordering::SeqCst) {
                Ok(_) => return true,
                Err(cur) => s = cur,
            }
        }
    }

    /// Consume the next sealed batch.  Waits past `poll` only while
    /// the tail frame is non-empty (a non-empty frame is guaranteed to
    /// seal — by its last writer, by our window expiry, or by close —
    /// and returning early would restart the window clock).  An empty
    /// tail frame at the poll deadline yields [`Pop::Idle`];
    /// closed-and-drained yields [`Pop::Closed`].
    pub fn pop(&self, poll: Duration) -> Pop<T> {
        let give_up = Instant::now() + poll;
        let mut window_seq = 0u64;
        let mut window_start: Option<Instant> = None;
        let mut bo = Backoff::new();
        loop {
            let seq = self.tail.load(Ordering::SeqCst);
            let f = &self.frames[(seq & self.mask) as usize];
            let s = f.state.load(Ordering::SeqCst);
            if gen_of(s) != seq & GEN_MASK {
                // Another consumer recycled this frame between our
                // tail read and state read; chase the new tail.
                bo.snooze();
                continue;
            }
            match phase_of(s) {
                PHASE_OPEN if claimed_of(s) == 0 => {
                    if self.closed.load(Ordering::SeqCst)
                        && self.submitters.load(Ordering::SeqCst) == 0
                    {
                        // Quiescence proof: any claim landing after
                        // this state re-read would come from a
                        // submitter that registered after our zero
                        // read, and such a submitter must see the
                        // closed flag (SeqCst total order) — so an
                        // unchanged empty word means drained for good.
                        if f.state.load(Ordering::SeqCst) == s {
                            return Pop::Closed;
                        }
                        continue;
                    }
                    if Instant::now() >= give_up {
                        return Pop::Idle;
                    }
                    window_start = None;
                    bo.snooze();
                }
                PHASE_OPEN => {
                    // Filling.  Closed short-circuits the window so
                    // drains never idle; otherwise seal when the
                    // window (measured from when WE first saw the
                    // frame non-empty) expires.
                    if self.closed.load(Ordering::SeqCst) {
                        self.try_seal(f, s, true);
                        continue;
                    }
                    if window_start.is_none() || window_seq != seq {
                        window_seq = seq;
                        window_start = Some(Instant::now());
                    }
                    if window_start.unwrap().elapsed() >= self.window {
                        self.try_seal(f, s, true);
                        continue;
                    }
                    bo.snooze();
                }
                PHASE_SEALED => {
                    let Some(ns) = consume_transition(s) else {
                        // A claimed slot's write is still in flight
                        // (its producer is between claim and publish —
                        // a handful of instructions).
                        bo.snooze();
                        continue;
                    };
                    if f.state
                        .compare_exchange(s, ns, Ordering::SeqCst, Ordering::SeqCst)
                        .is_err()
                    {
                        continue; // another consumer won the frame
                    }
                    // Ours.  Advance the tail first so other consumers
                    // move to the next frame while we drain.
                    let _ = self.tail.compare_exchange(
                        seq,
                        seq.wrapping_add(1),
                        Ordering::SeqCst,
                        Ordering::SeqCst,
                    );
                    let fill = claimed_of(s) as usize;
                    let mut items = Vec::with_capacity(fill);
                    for slot in &f.slots[..fill] {
                        items.push(
                            slot.lock().unwrap().take().expect("sealed slot must hold an item"),
                        );
                    }
                    let meta = BatchMeta {
                        seq,
                        fill: fill as u32,
                        sealed_by_window: s & WINDOW_BIT != 0,
                    };
                    // Recycle for lap `seq + frames` in one store (we
                    // are the frame's only owner here).
                    let next_gen = seq.wrapping_add(self.frames.len() as u64);
                    f.state.store(fresh(next_gen), Ordering::SeqCst);
                    return Pop::Batch(items, meta);
                }
                _ => {
                    // EXECUTING: the winning consumer's tail bump is
                    // imminent.
                    bo.snooze();
                }
            }
        }
    }

    /// Stop accepting work and wait for in-flight submitters to
    /// finish.  After `close` returns, no new claim can start; riders
    /// already claimed stay in the ring for consumers to drain.
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        self.quiesce();
    }

    /// Wait until no producer is inside `push`.  `push` never blocks,
    /// so this terminates promptly.
    pub fn quiesce(&self) {
        let mut bo = Backoff::new();
        while self.submitters.load(Ordering::SeqCst) != 0 {
            bo.snooze();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_word_fields_roundtrip() {
        let s = fresh(12345) + (3 << CLAIMED_SHIFT) + (2 << WRITTEN_SHIFT);
        assert_eq!(gen_of(s), 12345);
        assert_eq!(claimed_of(s), 3);
        assert_eq!(written_of(s), 2);
        assert_eq!(phase_of(s), PHASE_OPEN);
        let sealed = seal_transition(s, true).unwrap();
        assert_eq!(phase_of(sealed), PHASE_SEALED);
        assert_ne!(sealed & WINDOW_BIT, 0);
        assert_eq!(claimed_of(sealed), 3);
        assert_eq!(gen_of(sealed), 12345);
        // not consumable until written == claimed
        assert!(consume_transition(sealed).is_none());
        let done = sealed + 1;
        let exec = consume_transition(done).unwrap();
        assert_eq!(phase_of(exec), PHASE_EXECUTING);
        // and never twice
        assert!(consume_transition(exec).is_none());
        assert!(seal_transition(exec, false).is_none());
        assert!(claim_transition(exec, 8).is_none());
    }

    #[test]
    fn geometry_is_power_of_two_with_floor() {
        let r: BatchRing<u8> = BatchRing::new(0, 4, Duration::ZERO);
        assert_eq!(r.frames(), 2);
        let r: BatchRing<u8> = BatchRing::new(5, 4, Duration::ZERO);
        assert_eq!(r.frames(), 8);
        assert_eq!(r.capacity(), 32);
        assert_eq!(r.batch(), 4);
    }

    #[test]
    fn full_frame_is_sealed_by_its_last_writer() {
        let r: BatchRing<u32> = BatchRing::new(2, 3, Duration::from_secs(10));
        for v in 0..3 {
            r.push(v).unwrap();
        }
        // the huge window proves the seal came from the last writer
        match r.pop(Duration::ZERO) {
            Pop::Batch(items, meta) => {
                assert_eq!(items, vec![0, 1, 2]);
                assert_eq!(meta.fill, 3);
                assert_eq!(meta.seq, 0);
                assert!(!meta.sealed_by_window, "a full frame seals via its last writer");
            }
            other => panic!("expected a batch, got {other:?}"),
        }
    }

    #[test]
    fn window_expiry_seals_a_partial_frame() {
        let r: BatchRing<u32> = BatchRing::new(2, 8, Duration::from_millis(1));
        r.push(7).unwrap();
        r.push(8).unwrap();
        match r.pop(Duration::from_secs(5)) {
            Pop::Batch(items, meta) => {
                assert_eq!(items, vec![7, 8]);
                assert_eq!(meta.fill, 2);
                assert!(meta.sealed_by_window);
            }
            other => panic!("expected a batch, got {other:?}"),
        }
    }

    #[test]
    fn exhausted_ring_pushes_back_typed_and_recovers() {
        // no consumer: 2 frames x 2 slots accept exactly 4 riders
        let r: BatchRing<u32> = BatchRing::new(2, 2, Duration::from_secs(10));
        for v in 0..4 {
            assert!(r.push(v).is_ok(), "rider {v} must fit");
        }
        match r.push(99) {
            Err((PushError::Full, item)) => assert_eq!(item, 99),
            other => panic!("expected Full, got {other:?}"),
        }
        // a late consumer recovers every rider exactly once, in order
        let mut got = Vec::new();
        for _ in 0..2 {
            match r.pop(Duration::ZERO) {
                Pop::Batch(items, meta) => {
                    assert_eq!(meta.fill, 2);
                    got.extend(items);
                }
                other => panic!("expected a batch, got {other:?}"),
            }
        }
        assert_eq!(got, vec![0, 1, 2, 3]);
        // and the freed frames accept work again
        assert!(r.push(5).is_ok());
    }

    #[test]
    fn close_refuses_new_work_and_seals_immediately() {
        // window far longer than the test: only `close` can seal
        let r: BatchRing<u32> = BatchRing::new(4, 8, Duration::from_secs(60));
        for v in 0..3 {
            r.push(v).unwrap();
        }
        r.close();
        match r.push(99) {
            Err((PushError::Closed, item)) => assert_eq!(item, 99),
            other => panic!("expected Closed, got {other:?}"),
        }
        match r.pop(Duration::ZERO) {
            Pop::Batch(items, meta) => {
                assert_eq!(items, vec![0, 1, 2]);
                assert!(meta.sealed_by_window, "a close seal counts as a window seal");
            }
            other => panic!("expected the drained batch, got {other:?}"),
        }
        assert!(matches!(r.pop(Duration::ZERO), Pop::Closed));
        assert!(matches!(r.pop(Duration::ZERO), Pop::Closed));
    }

    #[test]
    fn empty_open_ring_idles_within_poll_budget() {
        let r: BatchRing<u32> = BatchRing::new(2, 2, Duration::ZERO);
        assert!(matches!(r.pop(Duration::ZERO), Pop::Idle));
        assert!(matches!(r.pop(Duration::from_micros(100)), Pop::Idle));
    }

    /// Hand-rolled loom-style model checker: exhaustively enumerate
    /// thread interleavings of the *same* transition rules the runtime
    /// CAS loops use ([`claim_transition`] / [`seal_transition`] /
    /// [`consume_transition`]) over one frame word, and assert the
    /// state-machine invariants on every leaf:
    ///
    /// * claims never exceed the batch, writes never exceed claims;
    /// * exactly one sealer wins (last writer XOR window consumer);
    /// * the consumer only ever takes a frame whose every claimed slot
    ///   has been written — no torn batch is observable.
    mod model {
        use super::super::{
            claim_transition, claimed_of, consume_transition, phase_of, seal_transition,
            written_of, PHASE_OPEN, PHASE_SEALED, WINDOW_BIT, WRITTEN_SHIFT,
        };

        const BATCH: u64 = 2;

        /// One simulated producer: claim -> write slot -> publish ->
        /// (maybe) seal, exactly mirroring `push_inner`'s step
        /// structure between atomic accesses.
        #[derive(Clone, Copy, PartialEq)]
        enum Producer {
            Claim,
            Write(u64),
            Publish(u64),
            SealIfLast,
            Done,
        }

        /// The window-expiry consumer side: one seal attempt, then
        /// (once sealed by anyone) one consume attempt.
        #[derive(Clone, Copy, PartialEq)]
        enum Consumer {
            WindowSeal,
            Consume,
            Done { consumed_fill: Option<u64> },
        }

        #[derive(Clone)]
        struct World {
            word: u64,
            /// Models the slot cells: which slots hold a payload.
            slot_written: [bool; BATCH as usize],
            producers: [Producer; BATCH as usize],
            consumer: Consumer,
            window_seal_won: bool,
            last_writer_seal_won: bool,
        }

        impl World {
            fn new() -> World {
                World {
                    word: 0, // fresh(0): gen 0, OPEN, no claims
                    slot_written: [false; BATCH as usize],
                    producers: [Producer::Claim; BATCH as usize],
                    consumer: Consumer::WindowSeal,
                    window_seal_won: false,
                    last_writer_seal_won: false,
                }
            }

            fn invariants(&self) {
                assert!(claimed_of(self.word) <= BATCH, "claims exceeded the batch");
                assert!(
                    written_of(self.word) <= claimed_of(self.word),
                    "writes exceeded claims"
                );
                assert!(
                    !(self.window_seal_won && self.last_writer_seal_won),
                    "two sealers won the same frame"
                );
            }

            /// Step producer `i` once.  Returns false if it was done.
            fn step_producer(&mut self, i: usize) -> bool {
                match self.producers[i] {
                    Producer::Claim => match claim_transition(self.word, BATCH) {
                        // the model CAS never fails: each DFS step is
                        // one uninterrupted atomic access
                        Some((slot, ns)) => {
                            self.word = ns;
                            self.producers[i] = Producer::Write(slot);
                        }
                        None => self.producers[i] = Producer::Done,
                    },
                    Producer::Write(slot) => {
                        self.slot_written[slot as usize] = true;
                        self.producers[i] = Producer::Publish(slot);
                    }
                    Producer::Publish(_) => {
                        self.word += 1 << WRITTEN_SHIFT;
                        self.producers[i] = if claimed_of(self.word) >= BATCH {
                            Producer::SealIfLast
                        } else {
                            Producer::Done
                        };
                    }
                    Producer::SealIfLast => {
                        if let Some(ns) = seal_transition(self.word, false) {
                            self.word = ns;
                            self.last_writer_seal_won = true;
                        }
                        self.producers[i] = Producer::Done;
                    }
                    Producer::Done => return false,
                }
                true
            }

            fn step_consumer(&mut self) -> bool {
                match self.consumer {
                    Consumer::WindowSeal => {
                        if claimed_of(self.word) >= 1 {
                            if let Some(ns) = seal_transition(self.word, true) {
                                self.word = ns;
                                self.window_seal_won = true;
                            }
                            self.consumer = Consumer::Consume;
                        } else if phase_of(self.word) != PHASE_OPEN {
                            self.consumer = Consumer::Consume;
                        } else {
                            // nothing to seal yet; stay (bounded by
                            // the DFS: this step only repeats while
                            // other threads still have steps)
                        }
                    }
                    Consumer::Consume => {
                        if let Some(ns) = consume_transition(self.word) {
                            let fill = claimed_of(ns);
                            // the gate: every claimed slot's payload
                            // must be visible to the consumer
                            for s in 0..fill as usize {
                                assert!(
                                    self.slot_written[s],
                                    "consumed a slot before its write landed"
                                );
                            }
                            self.word = ns;
                            self.consumer = Consumer::Done { consumed_fill: Some(fill) };
                        } else if phase_of(self.word) == PHASE_SEALED {
                            // sealed but a write is in flight: spin
                            // (same bounded-repeat note as above)
                        } else if phase_of(self.word) == PHASE_OPEN {
                            // seal lost to nothing yet — retry the
                            // window seal
                            self.consumer = Consumer::WindowSeal;
                        } else {
                            self.consumer = Consumer::Done { consumed_fill: None };
                        }
                    }
                    Consumer::Done { .. } => return false,
                }
                true
            }

            fn done(&self) -> bool {
                self.producers.iter().all(|p| matches!(p, Producer::Done))
                    && matches!(self.consumer, Consumer::Done { .. })
            }

            /// Leaf check: if everything claimed was sealed and
            /// consumed, the books must balance.
            fn finale(&self) {
                let claimed = claimed_of(self.word);
                assert_eq!(
                    written_of(self.word),
                    claimed,
                    "every claim must eventually publish"
                );
                if let Consumer::Done { consumed_fill: Some(fill) } = self.consumer {
                    assert_eq!(fill, claimed, "the consumer must take the frozen fill");
                    assert_eq!(
                        self.window_seal_won,
                        self.word & WINDOW_BIT != 0,
                        "the window bit must record which sealer won"
                    );
                }
                if claimed > 0 && phase_of(self.word) != PHASE_OPEN {
                    assert!(
                        self.window_seal_won ^ self.last_writer_seal_won,
                        "exactly one sealer must win a sealed frame"
                    );
                }
            }
        }

        /// DFS over every interleaving.  A thread whose step is a pure
        /// spin (no state change, no progress) is only re-scheduled
        /// when some other thread can still move, so the search is
        /// finite.
        fn explore(w: &World, depth: u32, leaves: &mut u64) {
            assert!(depth < 64, "model runaway");
            w.invariants();
            if w.done() {
                w.finale();
                *leaves += 1;
                return;
            }
            let mut moved = false;
            for i in 0..BATCH as usize {
                let mut next = w.clone();
                if next.step_producer(i) {
                    let progressed = next.word != w.word
                        || next.producers[i] != w.producers[i]
                        || next.slot_written != w.slot_written;
                    if progressed {
                        moved = true;
                        explore(&next, depth + 1, leaves);
                    }
                }
            }
            {
                let mut next = w.clone();
                if next.step_consumer() {
                    let progressed =
                        next.word != w.word || next.consumer != w.consumer;
                    if progressed {
                        moved = true;
                        explore(&next, depth + 1, leaves);
                    }
                }
            }
            // Everyone left is spinning on someone else's progress —
            // with no runnable thread that would be a deadlock.
            assert!(moved, "model deadlock: no thread can make progress");
        }

        #[test]
        fn every_interleaving_of_claim_write_seal_consume_is_sound() {
            let mut leaves = 0u64;
            explore(&World::new(), 0, &mut leaves);
            assert!(leaves > 100, "the model must branch substantially (got {leaves})");
        }
    }
}
