//! Dynamic-trace instruction form: what the kernel builders emit and the
//! simulator executes.
//!
//! Loads/stores carry *resolved* byte addresses (the scalar core's
//! address computation is accounted separately as [`ScalarKind`]
//! dispatch slots), and `..VX` forms carry the resolved scalar operand —
//! i.e. this is a post-register-read trace of the vector instruction
//! stream, which is exactly the input an RTL-faithful timing model of
//! the vector engine needs.

use super::vtype::{Lmul, Sew};
use std::fmt;

/// Vector arithmetic / permutation opcodes used by the paper's kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum VOp {
    // --- integer ALU (VALU) ---
    Add,
    Sub,
    And,
    Or,
    Xor,
    Sll,
    Srl,
    Sra,
    Min,
    Max,
    /// vmv.v.{v,x,i} — move/broadcast (executes on the VALU)
    Mv,
    /// vwaddu.wv — widening unsigned add-accumulate: vd(2*SEW) += vs2(SEW)
    WAdduWv,
    /// vnsrl.w{x,i} — narrowing logical shift right: vd(SEW) =
    /// vs2(2*SEW) >> shamt.  The inter-layer requantize streams use it
    /// to narrow wide conv accumulators into the next layer's level
    /// width, and the maxpool kernel uses the classic shift-0/shift-SEW
    /// pair to deinterleave even/odd columns.
    NSrl,
    // --- SIMD multiplier (MFPU fixed-point side) ---
    Mul,
    Mulh,
    Mulhu,
    /// vmacc: vd += vs1*vs2 (modular at SEW)
    Macc,
    /// vnmsac: vd -= vs1*vs2
    Nmsac,
    /// **vmacsr** (Sparq custom): vd += (vs1*vs2 mod 2^SEW) >> (SEW/2),
    /// logical shift — the paper's multiply-shift-accumulate.
    Macsr,
    /// vmacsr.cfg (this repo's "future work" extension): shift amount
    /// comes from a CSR instead of being hard-wired to SEW/2.
    MacsrCfg,
    // --- floating point (VFPU — only present on Ara, removed in Sparq) ---
    FAdd,
    FMul,
    /// vfmacc: vd += vs1*vs2 (fp32)
    FMacc,
    // --- slide unit (SLDU) ---
    SlideDown,
    SlideUp,
}

impl VOp {
    /// True for ops executed by the floating-point side of the MFPU —
    /// these trap on Sparq (no FPU).
    pub fn is_fp(self) -> bool {
        matches!(self, VOp::FAdd | VOp::FMul | VOp::FMacc)
    }

    /// True for the multiplier-side ops (occupy the SIMD multiplier).
    pub fn is_mul(self) -> bool {
        matches!(
            self,
            VOp::Mul | VOp::Mulh | VOp::Mulhu | VOp::Macc | VOp::Nmsac | VOp::Macsr | VOp::MacsrCfg
        )
    }

    /// True for the slide-unit ops.
    pub fn is_slide(self) -> bool {
        matches!(self, VOp::SlideDown | VOp::SlideUp)
    }

    /// True for ternary (read-modify-write vd) ops.
    pub fn reads_vd(self) -> bool {
        matches!(
            self,
            VOp::Macc | VOp::Nmsac | VOp::Macsr | VOp::MacsrCfg | VOp::FMacc | VOp::WAdduWv
        )
    }

    pub fn mnemonic(self) -> &'static str {
        match self {
            VOp::Add => "vadd",
            VOp::Sub => "vsub",
            VOp::And => "vand",
            VOp::Or => "vor",
            VOp::Xor => "vxor",
            VOp::Sll => "vsll",
            VOp::Srl => "vsrl",
            VOp::Sra => "vsra",
            VOp::Min => "vminu",
            VOp::Max => "vmaxu",
            VOp::Mv => "vmv.v",
            VOp::WAdduWv => "vwaddu.w",
            VOp::NSrl => "vnsrl",
            VOp::Mul => "vmul",
            VOp::Mulh => "vmulh",
            VOp::Mulhu => "vmulhu",
            VOp::Macc => "vmacc",
            VOp::Nmsac => "vnmsac",
            VOp::Macsr => "vmacsr",
            VOp::MacsrCfg => "vmacsr.cfg",
            VOp::FAdd => "vfadd",
            VOp::FMul => "vfmul",
            VOp::FMacc => "vfmacc",
            VOp::SlideDown => "vslidedown",
            VOp::SlideUp => "vslideup",
        }
    }
}

/// Scalar-core work interleaved with the vector stream.  Each entry
/// occupies issue slots in the (single-issue) front end but no vector
/// unit — this is how loop control, address generation, and the scalar
/// weight loads of Algorithm 1 cost cycles without being simulated at
/// the RV64I level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ScalarKind {
    /// Address computation (adds/shifts feeding loads/stores).
    AddrCalc,
    /// Loop counters, compares, branches.
    LoopCtl,
    /// Scalar load of a (packed) weight word feeding a `.vx` operand.
    WeightLoad,
    /// CSR read/write (e.g. programming the configurable shifter).
    Csr,
}

/// One instruction of the dynamic trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum VInst {
    /// `vsetvli` — sets (vl, SEW, LMUL) for subsequent instructions.
    SetVl { avl: u64, sew: Sew, lmul: Lmul },
    /// Unit-stride vector load: `vle{eew}.v vd, (addr)`; element count
    /// taken from the current `vl` (scaled if `eew != sew`).
    Load { eew: Sew, vd: u8, addr: u64 },
    /// Unit-stride vector store: `vse{eew}.v vs3, (addr)`.
    Store { eew: Sew, vs3: u8, addr: u64 },
    /// Vector-vector: `op vd, vs2, vs1` (RVV operand order).
    OpVV { op: VOp, vd: u8, vs2: u8, vs1: u8 },
    /// Vector-scalar: `op vd, vs2, rs1` with the scalar value resolved.
    OpVX { op: VOp, vd: u8, vs2: u8, rs1: u64 },
    /// Vector-immediate: `op vd, vs2, imm`.
    OpVI { op: VOp, vd: u8, vs2: u8, imm: i8 },
    /// Scalar-core overhead (see [`ScalarKind`]); `n` back-to-back slots.
    Scalar { kind: ScalarKind, n: u32 },
}

impl VInst {
    /// The destination vector register, if any.
    pub fn vd(&self) -> Option<u8> {
        match *self {
            VInst::Load { vd, .. } => Some(vd),
            VInst::OpVV { vd, .. } | VInst::OpVX { vd, .. } | VInst::OpVI { vd, .. } => Some(vd),
            _ => None,
        }
    }

    /// Source vector registers, allocation-free: fills `buf` and
    /// returns the count (the timing model calls this per instruction —
    /// §Perf iteration 2 removed the former per-call `Vec`).
    pub fn srcs_into(&self, buf: &mut [u8; 3]) -> usize {
        match *self {
            VInst::Store { vs3, .. } => {
                buf[0] = vs3;
                1
            }
            VInst::OpVV { op, vd, vs2, vs1 } => {
                buf[0] = vs2;
                buf[1] = vs1;
                if op.reads_vd() {
                    buf[2] = vd;
                    3
                } else {
                    2
                }
            }
            VInst::OpVX { op, vd, vs2, .. } | VInst::OpVI { op, vd, vs2, .. } => {
                buf[0] = vs2;
                if op.reads_vd() {
                    buf[1] = vd;
                    2
                } else {
                    1
                }
            }
            _ => 0,
        }
    }

    /// Source vector registers (convenience; allocates).
    pub fn srcs(&self) -> Vec<u8> {
        let mut buf = [0u8; 3];
        let n = self.srcs_into(&mut buf);
        buf[..n].to_vec()
    }

    pub fn vop(&self) -> Option<VOp> {
        match *self {
            VInst::OpVV { op, .. } | VInst::OpVX { op, .. } | VInst::OpVI { op, .. } => Some(op),
            _ => None,
        }
    }
}

impl fmt::Display for VInst {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", super::disasm::disasm(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn srcs_include_vd_for_ternary_ops() {
        let i = VInst::OpVX { op: VOp::Macsr, vd: 3, vs2: 4, rs1: 7 };
        assert_eq!(i.srcs(), vec![4, 3]);
        let i = VInst::OpVV { op: VOp::Add, vd: 3, vs2: 4, vs1: 5 };
        assert_eq!(i.srcs(), vec![4, 5]);
    }

    #[test]
    fn unit_classification() {
        assert!(VOp::Macsr.is_mul() && !VOp::Macsr.is_fp());
        assert!(VOp::FMacc.is_fp() && !VOp::FMacc.is_mul());
        assert!(VOp::SlideDown.is_slide());
        assert!(VOp::WAdduWv.reads_vd());
        // vnsrl reads its (wide) vs2 only — an overwriting narrow op
        assert!(!VOp::NSrl.is_mul() && !VOp::NSrl.is_fp() && !VOp::NSrl.is_slide());
        assert!(!VOp::NSrl.reads_vd());
    }
}
