//! The RISC-V "V" 1.0 instruction subset Ara implements, extended with
//! the paper's custom `vmacsr` (vector multiply-shift-accumulate).
//!
//! Layering: [`VInst`](inst::VInst) is the *dynamic trace* form the
//! kernel builders emit and the simulator executes (operands carry
//! resolved addresses/scalars, like a post-register-read trace).
//! [`encode`]/[`decode`] map the architectural part of each instruction
//! to/from its faithful 32-bit RVV machine encoding — this is where the
//! `vmacsr` funct6 slot from the paper's Fig. 3 lives — and [`disasm`]
//! renders assembly text.

pub mod decode;
pub mod disasm;
pub mod encode;
pub mod inst;
pub mod vtype;

pub use decode::{decode, DecodeError};
pub use disasm::disasm;
pub use encode::{encode, EncodeError};
pub use inst::{ScalarKind, VInst, VOp};
pub use vtype::{Lmul, Sew, VType};
