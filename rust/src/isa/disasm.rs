//! Assembly-text rendering of trace instructions (debugging aid and the
//! `isa_explorer` example's output format).

use super::inst::{ScalarKind, VInst, VOp};

/// Render one instruction in RVV assembly syntax (dynamic operands are
/// rendered with their resolved values in `{}` braces).
pub fn disasm(inst: &VInst) -> String {
    match *inst {
        VInst::SetVl { avl, sew, lmul } => {
            format!("vsetvli a0, {{avl={avl}}}, {sew},{lmul},ta,ma")
        }
        VInst::Load { eew, vd, addr } => {
            format!("vle{}.v v{vd}, ({{{addr:#x}}})", eew.bits())
        }
        VInst::Store { eew, vs3, addr } => {
            format!("vse{}.v v{vs3}, ({{{addr:#x}}})", eew.bits())
        }
        VInst::OpVV { op, vd, vs2, vs1 } => {
            if op == VOp::Mv {
                format!("vmv.v.v v{vd}, v{vs1}")
            } else {
                // narrowing ops read a wide vs2: .wv, not .vv (RVV asm)
                let suffix = if op == VOp::NSrl { "wv" } else { "vv" };
                format!("{}.{suffix} v{vd}, v{vs2}, v{vs1}", op.mnemonic())
            }
        }
        VInst::OpVX { op, vd, vs2, rs1 } => {
            if op == VOp::Mv {
                format!("vmv.v.x v{vd}, {{{rs1:#x}}}")
            } else {
                let suffix = if op.is_fp() {
                    "vf"
                } else if op == VOp::NSrl {
                    "wx"
                } else {
                    "vx"
                };
                format!("{}.{suffix} v{vd}, v{vs2}, {{{rs1:#x}}}", op.mnemonic())
            }
        }
        VInst::OpVI { op, vd, vs2, imm } => {
            if op == VOp::Mv {
                format!("vmv.v.i v{vd}, {imm}")
            } else {
                let suffix = if op == VOp::NSrl { "wi" } else { "vi" };
                format!("{}.{suffix} v{vd}, v{vs2}, {imm}", op.mnemonic())
            }
        }
        VInst::Scalar { kind, n } => {
            let k = match kind {
                ScalarKind::AddrCalc => "addr-calc",
                ScalarKind::LoopCtl => "loop-ctl",
                ScalarKind::WeightLoad => "weight-load",
                ScalarKind::Csr => "csr",
            };
            format!("<scalar {k} x{n}>")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::vtype::{Lmul, Sew};

    #[test]
    fn renders_vmacsr() {
        let i = VInst::OpVX { op: VOp::Macsr, vd: 3, vs2: 1, rs1: 0x1234 };
        assert_eq!(disasm(&i), "vmacsr.vx v3, v1, {0x1234}");
    }

    #[test]
    fn renders_fp_with_vf_suffix() {
        let i = VInst::OpVX { op: VOp::FMacc, vd: 3, vs2: 1, rs1: 42 };
        assert!(disasm(&i).starts_with("vfmacc.vf"));
    }

    #[test]
    fn renders_narrowing_with_w_suffix() {
        let i = VInst::OpVI { op: VOp::NSrl, vd: 0, vs2: 8, imm: 16 };
        assert_eq!(disasm(&i), "vnsrl.wi v0, v8, 16");
        let x = VInst::OpVX { op: VOp::NSrl, vd: 0, vs2: 8, rs1: 32 };
        assert!(disasm(&x).starts_with("vnsrl.wx"));
    }

    #[test]
    fn renders_setvl_and_mem() {
        let s = disasm(&VInst::SetVl { avl: 512, sew: Sew::E16, lmul: Lmul::M2 });
        assert!(s.contains("e16,m2"));
        let l = disasm(&VInst::Load { eew: Sew::E8, vd: 2, addr: 64 });
        assert!(l.starts_with("vle8.v v2"));
    }
}
