//! The `vtype` CSR state machine: SEW / LMUL / VL computation per the
//! RVV 1.0 spec (`vsetvli` semantics).

use std::fmt;

/// Selected element width.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Sew {
    E8,
    E16,
    E32,
    E64,
}

impl Sew {
    /// Element width in bits.
    pub fn bits(self) -> u32 {
        match self {
            Sew::E8 => 8,
            Sew::E16 => 16,
            Sew::E32 => 32,
            Sew::E64 => 64,
        }
    }

    /// Element width in bytes.
    pub fn bytes(self) -> u32 {
        self.bits() / 8
    }

    /// The `vsew[2:0]` encoding of the vtype CSR.
    pub fn vsew(self) -> u32 {
        match self {
            Sew::E8 => 0,
            Sew::E16 => 1,
            Sew::E32 => 2,
            Sew::E64 => 3,
        }
    }

    pub fn from_vsew(v: u32) -> Option<Sew> {
        match v {
            0 => Some(Sew::E8),
            1 => Some(Sew::E16),
            2 => Some(Sew::E32),
            3 => Some(Sew::E64),
            _ => None,
        }
    }

    /// The doubled width used by widening ops (`vwaddu.wv`).
    pub fn widened(self) -> Option<Sew> {
        match self {
            Sew::E8 => Some(Sew::E16),
            Sew::E16 => Some(Sew::E32),
            Sew::E32 => Some(Sew::E64),
            Sew::E64 => None,
        }
    }
}

impl fmt::Display for Sew {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "e{}", self.bits())
    }
}

/// Register-group multiplier (fractional LMUL is not used by any of the
/// paper's kernels and is not modelled).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Lmul {
    M1,
    M2,
    M4,
    M8,
}

impl Lmul {
    pub fn factor(self) -> u32 {
        match self {
            Lmul::M1 => 1,
            Lmul::M2 => 2,
            Lmul::M4 => 4,
            Lmul::M8 => 8,
        }
    }

    /// The `vlmul[2:0]` encoding.
    pub fn vlmul(self) -> u32 {
        match self {
            Lmul::M1 => 0,
            Lmul::M2 => 1,
            Lmul::M4 => 2,
            Lmul::M8 => 3,
        }
    }

    pub fn from_vlmul(v: u32) -> Option<Lmul> {
        match v {
            0 => Some(Lmul::M1),
            1 => Some(Lmul::M2),
            2 => Some(Lmul::M4),
            3 => Some(Lmul::M8),
            _ => None,
        }
    }

    /// Smallest LMUL whose VLMAX covers `avl` elements, if any.
    pub fn covering(avl: u64, sew: Sew, vlen_bits: u32) -> Option<Lmul> {
        for lmul in [Lmul::M1, Lmul::M2, Lmul::M4, Lmul::M8] {
            if VType::new(sew, lmul).vlmax(vlen_bits) as u64 >= avl {
                return Some(lmul);
            }
        }
        None
    }
}

impl fmt::Display for Lmul {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "m{}", self.factor())
    }
}

/// A (SEW, LMUL) pair — the subset of the vtype CSR the kernels use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct VType {
    pub sew: Sew,
    pub lmul: Lmul,
}

impl VType {
    pub fn new(sew: Sew, lmul: Lmul) -> VType {
        VType { sew, lmul }
    }

    /// VLMAX = VLEN/SEW * LMUL (RVV 1.0 §3.4.2).
    pub fn vlmax(self, vlen_bits: u32) -> u32 {
        vlen_bits / self.sew.bits() * self.lmul.factor()
    }

    /// `vsetvli` rd-result: vl = min(AVL, VLMAX).
    pub fn apply(self, avl: u64, vlen_bits: u32) -> u32 {
        (avl.min(self.vlmax(vlen_bits) as u64)) as u32
    }

    /// vtype CSR bits (vill=0, vma=0, vta=0).
    pub fn to_bits(self) -> u32 {
        (self.sew.vsew() << 3) | self.lmul.vlmul()
    }

    pub fn from_bits(bits: u32) -> Option<VType> {
        Some(VType {
            sew: Sew::from_vsew((bits >> 3) & 0x7)?,
            lmul: Lmul::from_vlmul(bits & 0x7)?,
        })
    }

    /// Register-group alignment check: vd must be a multiple of LMUL.
    pub fn reg_aligned(self, v: u8) -> bool {
        v as u32 % self.lmul.factor() == 0
    }
}

impl fmt::Display for VType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{},{},ta,ma", self.sew, self.lmul)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vlmax_matches_spec_examples() {
        // VLEN=4096: e16/m1 -> 256, e8/m1 -> 512, e16/m2 -> 512
        assert_eq!(VType::new(Sew::E16, Lmul::M1).vlmax(4096), 256);
        assert_eq!(VType::new(Sew::E8, Lmul::M1).vlmax(4096), 512);
        assert_eq!(VType::new(Sew::E16, Lmul::M2).vlmax(4096), 512);
        assert_eq!(VType::new(Sew::E64, Lmul::M8).vlmax(4096), 512);
    }

    #[test]
    fn vsetvli_clamps_to_vlmax() {
        let vt = VType::new(Sew::E16, Lmul::M1);
        assert_eq!(vt.apply(100, 4096), 100);
        assert_eq!(vt.apply(1000, 4096), 256);
    }

    #[test]
    fn vtype_bits_roundtrip() {
        for sew in [Sew::E8, Sew::E16, Sew::E32, Sew::E64] {
            for lmul in [Lmul::M1, Lmul::M2, Lmul::M4, Lmul::M8] {
                let vt = VType::new(sew, lmul);
                assert_eq!(VType::from_bits(vt.to_bits()), Some(vt));
            }
        }
    }

    #[test]
    fn covering_picks_smallest() {
        assert_eq!(Lmul::covering(256, Sew::E16, 4096), Some(Lmul::M1));
        assert_eq!(Lmul::covering(257, Sew::E16, 4096), Some(Lmul::M2));
        assert_eq!(Lmul::covering(512, Sew::E16, 4096), Some(Lmul::M2));
        assert_eq!(Lmul::covering(3000, Sew::E16, 4096), None);
    }

    #[test]
    fn widened_chain() {
        assert_eq!(Sew::E8.widened(), Some(Sew::E16));
        assert_eq!(Sew::E64.widened(), None);
    }

    #[test]
    fn reg_alignment() {
        let vt = VType::new(Sew::E16, Lmul::M4);
        assert!(vt.reg_aligned(0) && vt.reg_aligned(4) && vt.reg_aligned(28));
        assert!(!vt.reg_aligned(2) && !vt.reg_aligned(30) || vt.lmul.factor() <= 2);
    }
}
