//! Decoder: 32-bit machine word -> trace instruction (dynamic fields
//! zeroed — they live in scalar registers on real hardware).
//!
//! Reserved encodings return [`DecodeError`]; the dispatcher-level
//! failure injection tests rely on that (Ara's dispatcher would raise
//! an illegal-instruction exception).

use super::encode::{funct3, mem_width, OPC_V, OPC_VL, OPC_VS};
use super::inst::{VInst, VOp};
use super::vtype::{Sew, VType};
use std::fmt;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeError {
    UnknownOpcode(u32),
    ReservedFunct6 { funct6: u32, funct3: u32 },
    ReservedVType(u32),
    BadMemWidth(u32),
    MaskedUnsupported,
}

impl fmt::Display for DecodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            DecodeError::UnknownOpcode(op) => write!(f, "unknown major opcode {op:#04x}"),
            DecodeError::ReservedFunct6 { funct6, funct3 } => {
                write!(f, "reserved funct6 {funct6:#08b} in funct3 space {funct3:#05b}")
            }
            DecodeError::ReservedVType(bits) => write!(f, "reserved vtype bits {bits:#013b}"),
            DecodeError::BadMemWidth(w) => {
                write!(f, "unsupported memory width encoding {w:#05b}")
            }
            DecodeError::MaskedUnsupported => {
                write!(f, "masked (vm=0) encodings are not implemented by this subset")
            }
        }
    }
}

impl std::error::Error for DecodeError {}

fn opi_from_funct6(f6: u32) -> Option<VOp> {
    Some(match f6 {
        0b000000 => VOp::Add,
        0b000010 => VOp::Sub,
        0b000100 => VOp::Min,
        0b000110 => VOp::Max,
        0b001001 => VOp::And,
        0b001010 => VOp::Or,
        0b001011 => VOp::Xor,
        0b010111 => VOp::Mv,
        0b100101 => VOp::Sll,
        0b101000 => VOp::Srl,
        0b101001 => VOp::Sra,
        0b101100 => VOp::NSrl,
        0b001110 => VOp::SlideUp,
        0b001111 => VOp::SlideDown,
        _ => return None,
    })
}

fn opm_from_funct6(f6: u32) -> Option<VOp> {
    Some(match f6 {
        0b100100 => VOp::Mulhu,
        0b100101 => VOp::Mul,
        0b100111 => VOp::Mulh,
        0b101101 => VOp::Macc,
        0b101110 => VOp::Macsr,
        0b101010 => VOp::MacsrCfg,
        0b101111 => VOp::Nmsac,
        0b110101 => VOp::WAdduWv,
        _ => return None,
    })
}

fn opf_from_funct6(f6: u32) -> Option<VOp> {
    Some(match f6 {
        0b000000 => VOp::FAdd,
        0b100100 => VOp::FMul,
        0b101100 => VOp::FMacc,
        _ => return None,
    })
}

fn sew_from_mem_width(w: u32) -> Option<Sew> {
    for s in [Sew::E8, Sew::E16, Sew::E32, Sew::E64] {
        if mem_width(s.bits()) == w {
            return Some(s);
        }
    }
    None
}

/// Decode a 32-bit word.  Dynamic operands (addresses, scalar values,
/// AVL) decode to 0 — see `encode.rs` for why.
pub fn decode(word: u32) -> Result<VInst, DecodeError> {
    let opcode = word & 0x7f;
    match opcode {
        OPC_VL | OPC_VS => {
            let width = (word >> 12) & 0x7;
            let eew = sew_from_mem_width(width).ok_or(DecodeError::BadMemWidth(width))?;
            let vreg = ((word >> 7) & 0x1f) as u8;
            if (word >> 25) & 1 == 0 {
                return Err(DecodeError::MaskedUnsupported);
            }
            Ok(if opcode == OPC_VL {
                VInst::Load { eew, vd: vreg, addr: 0 }
            } else {
                VInst::Store { eew, vs3: vreg, addr: 0 }
            })
        }
        OPC_V => {
            let f3 = (word >> 12) & 0x7;
            if f3 == funct3::OPCFG {
                let vtypei = (word >> 20) & 0x7ff;
                let vt = VType::from_bits(vtypei).ok_or(DecodeError::ReservedVType(vtypei))?;
                return Ok(VInst::SetVl { avl: 0, sew: vt.sew, lmul: vt.lmul });
            }
            let f6 = word >> 26;
            let vm = (word >> 25) & 1;
            if vm == 0 {
                return Err(DecodeError::MaskedUnsupported);
            }
            let vd = ((word >> 7) & 0x1f) as u8;
            let vs2 = ((word >> 20) & 0x1f) as u8;
            let v1 = ((word >> 15) & 0x1f) as u8;
            let err = DecodeError::ReservedFunct6 { funct6: f6, funct3: f3 };
            match f3 {
                funct3::OPIVV => {
                    let op = opi_from_funct6(f6).ok_or(err)?;
                    Ok(VInst::OpVV { op, vd, vs2, vs1: v1 })
                }
                funct3::OPIVX => {
                    let op = opi_from_funct6(f6).ok_or(err)?;
                    Ok(VInst::OpVX { op, vd, vs2, rs1: 0 })
                }
                funct3::OPIVI => {
                    let op = opi_from_funct6(f6).ok_or(err)?;
                    // shifts/slides take uimm5; others simm5
                    let imm = if matches!(op, VOp::Sll | VOp::Srl | VOp::Sra | VOp::NSrl | VOp::SlideUp | VOp::SlideDown)
                    {
                        v1 as i8
                    } else {
                        // sign-extend 5 bits (shift in u8 space: v1 can
                        // reach 31, and 31i8 << 3 would overflow)
                        ((v1 << 3) as i8) >> 3
                    };
                    Ok(VInst::OpVI { op, vd, vs2, imm })
                }
                funct3::OPMVV => {
                    let op = opm_from_funct6(f6).ok_or(err)?;
                    Ok(VInst::OpVV { op, vd, vs2, vs1: v1 })
                }
                funct3::OPMVX => {
                    let op = opm_from_funct6(f6).ok_or(err)?;
                    Ok(VInst::OpVX { op, vd, vs2, rs1: 0 })
                }
                funct3::OPFVV => {
                    let op = opf_from_funct6(f6).ok_or(err)?;
                    Ok(VInst::OpVV { op, vd, vs2, vs1: v1 })
                }
                funct3::OPFVF => {
                    let op = opf_from_funct6(f6).ok_or(err)?;
                    Ok(VInst::OpVX { op, vd, vs2, rs1: 0 })
                }
                _ => unreachable!(),
            }
        }
        0b001_0011 if word == 0x0000_0013 => {
            // canonical NOP stands in for scalar slots
            Ok(VInst::Scalar { kind: super::inst::ScalarKind::LoopCtl, n: 1 })
        }
        _ => Err(DecodeError::UnknownOpcode(opcode)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::encode::encode;
    use crate::isa::inst::ScalarKind;
    use crate::isa::vtype::Lmul;
    use crate::testutil::Prop;

    /// Every encodable (op, format) pair, for exhaustive round-trips.
    fn all_ops() -> Vec<VInst> {
        let mut v = vec![];
        let vv_ops = [
            VOp::Add, VOp::Sub, VOp::And, VOp::Or, VOp::Xor, VOp::Min, VOp::Max, VOp::Mv,
            VOp::Sll, VOp::Srl, VOp::Sra, VOp::NSrl, VOp::Mul, VOp::Mulh, VOp::Mulhu, VOp::Macc,
            VOp::Nmsac, VOp::Macsr, VOp::MacsrCfg, VOp::WAdduWv, VOp::FAdd, VOp::FMul,
            VOp::FMacc,
        ];
        for op in vv_ops {
            v.push(VInst::OpVV { op, vd: 1, vs2: 2, vs1: 3 });
            v.push(VInst::OpVX { op, vd: 1, vs2: 2, rs1: 0 });
        }
        for op in [VOp::Add, VOp::Sll, VOp::Srl, VOp::NSrl, VOp::SlideDown, VOp::SlideUp, VOp::Mv] {
            v.push(VInst::OpVI { op, vd: 1, vs2: 2, imm: 5 });
        }
        for op in [VOp::SlideDown, VOp::SlideUp] {
            v.push(VInst::OpVX { op, vd: 8, vs2: 16, rs1: 0 });
        }
        v
    }

    #[test]
    fn roundtrip_every_op() {
        for inst in all_ops() {
            let w = encode(&inst).unwrap();
            let back = decode(w).unwrap_or_else(|e| panic!("{inst}: {e}"));
            assert_eq!(encode(&back).unwrap(), w, "{inst}");
        }
    }

    #[test]
    fn roundtrip_random_fields() {
        // property: register/imm fields survive encode->decode->encode
        Prop::new(0xB0B).runs(500).check(|g| {
            let ops = all_ops();
            let mut inst = ops[g.below(ops.len() as u64) as usize];
            match &mut inst {
                VInst::OpVV { vd, vs2, vs1, .. } => {
                    *vd = g.below(32) as u8;
                    *vs2 = g.below(32) as u8;
                    *vs1 = g.below(32) as u8;
                }
                VInst::OpVX { vd, vs2, .. } => {
                    *vd = g.below(32) as u8;
                    *vs2 = g.below(32) as u8;
                }
                VInst::OpVI { vd, vs2, imm, .. } => {
                    *vd = g.below(32) as u8;
                    *vs2 = g.below(32) as u8;
                    *imm = g.below(16) as i8;
                }
                _ => {}
            }
            let w = encode(&inst).unwrap();
            let back = decode(w).expect("decodable");
            assert_eq!(encode(&back).unwrap(), w);
        });
    }

    #[test]
    fn setvl_roundtrip_all_vtypes() {
        for sew in [Sew::E8, Sew::E16, Sew::E32, Sew::E64] {
            for lmul in [Lmul::M1, Lmul::M2, Lmul::M4, Lmul::M8] {
                let i = VInst::SetVl { avl: 0, sew, lmul };
                assert_eq!(decode(encode(&i).unwrap()).unwrap(), i);
            }
        }
    }

    #[test]
    fn loads_stores_roundtrip() {
        for eew in [Sew::E8, Sew::E16, Sew::E32, Sew::E64] {
            let l = VInst::Load { eew, vd: 7, addr: 0 };
            assert_eq!(decode(encode(&l).unwrap()).unwrap(), l);
            let s = VInst::Store { eew, vs3: 7, addr: 0 };
            assert_eq!(decode(encode(&s).unwrap()).unwrap(), s);
        }
    }

    #[test]
    fn reserved_funct6_rejected() {
        // funct6 111111 in OPMVV space is unassigned in our subset
        let w = (0b111111 << 26) | (1 << 25) | (funct3::OPMVV << 12) | OPC_V;
        assert!(matches!(decode(w), Err(DecodeError::ReservedFunct6 { .. })));
    }

    #[test]
    fn masked_encodings_rejected() {
        let mut w = encode(&VInst::OpVV { op: VOp::Macsr, vd: 1, vs2: 2, vs1: 3 }).unwrap();
        w &= !(1 << 25); // clear vm
        assert_eq!(decode(w), Err(DecodeError::MaskedUnsupported));
    }

    #[test]
    fn garbage_rejected() {
        assert!(decode(0xffff_ffff).is_err());
        assert!(decode(0x0000_0000).is_err());
    }

    #[test]
    fn nop_is_scalar_slot() {
        assert_eq!(
            decode(0x0000_0013).unwrap(),
            VInst::Scalar { kind: ScalarKind::LoopCtl, n: 1 }
        );
    }
}
