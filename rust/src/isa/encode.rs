//! Faithful 32-bit RVV 1.0 machine encodings for the implemented subset,
//! including the paper's `vmacsr` (Fig. 3: the free funct6 slot right
//! after `vmacc`, in both OPMVV and OPMVX formats).
//!
//! The dynamic parts of the trace (resolved addresses, scalar values,
//! AVL) do not live in the instruction word on real hardware either —
//! they come from scalar registers.  The encoder emits `a0` (x10) as
//! the scalar register for those operands, so
//! `encode(decode(encode(i))) == encode(i)` holds for every instruction
//! (see the round-trip property tests in `decode.rs`).

use super::inst::{VInst, VOp};
use super::vtype::VType;
use std::fmt;

/// A trace instruction with no machine encoding (op/format mismatch).
/// Surfaced as a typed error so a bad kernel builder propagates a
/// `Result` through `run_conv` instead of aborting a serving worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EncodeError {
    /// The op has no encoding in the requested operand format
    /// (`"VV"`, `"VX"`, or `"VI"`).
    NoEncoding { op: VOp, form: &'static str },
}

impl fmt::Display for EncodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            EncodeError::NoEncoding { op, form } => {
                write!(f, "op {op:?} has no {form} encoding")
            }
        }
    }
}

impl std::error::Error for EncodeError {}

/// OP-V major opcode.
pub const OPC_V: u32 = 0b101_0111;
/// Vector load / store major opcodes.
pub const OPC_VL: u32 = 0b000_0111;
pub const OPC_VS: u32 = 0b010_0111;

/// funct3 encodings (RVV 1.0 table 10).
pub mod funct3 {
    pub const OPIVV: u32 = 0b000;
    pub const OPFVV: u32 = 0b001;
    pub const OPMVV: u32 = 0b010;
    pub const OPIVI: u32 = 0b011;
    pub const OPIVX: u32 = 0b100;
    pub const OPFVF: u32 = 0b101;
    pub const OPMVX: u32 = 0b110;
    pub const OPCFG: u32 = 0b111;
}

/// The scalar register the encoder substitutes for dynamic operands.
pub const TRACE_RS1: u32 = 10; // a0

/// funct6 for an op in the OPI* (integer ALU) space, if it lives there.
pub fn funct6_opi(op: VOp) -> Option<u32> {
    Some(match op {
        VOp::Add => 0b000000,
        VOp::Sub => 0b000010,
        VOp::Min => 0b000100,
        VOp::Max => 0b000110,
        VOp::And => 0b001001,
        VOp::Or => 0b001010,
        VOp::Xor => 0b001011,
        VOp::Mv => 0b010111, // vmv.v.* = vmerge with vm=1, vs2=v0
        VOp::Sll => 0b100101,
        VOp::Srl => 0b101000,
        VOp::Sra => 0b101001,
        // RVV 1.0 narrowing shift: vnsrl.w{v,x,i}
        VOp::NSrl => 0b101100,
        VOp::SlideUp => 0b001110,
        VOp::SlideDown => 0b001111,
        _ => return None,
    })
}

/// funct6 for an op in the OPM* (multiplier / widening) space.
pub fn funct6_opm(op: VOp) -> Option<u32> {
    Some(match op {
        VOp::Mulhu => 0b100100,
        VOp::Mul => 0b100101,
        VOp::Mulh => 0b100111,
        VOp::Macc => 0b101101,
        // the paper's custom instruction: the free slot after vmacc
        VOp::Macsr => 0b101110,
        VOp::Nmsac => 0b101111,
        // this repo's configurable-shift extension (paper future work):
        // the reserved slot between vmadd (101001) and vnmsub (101011)
        VOp::MacsrCfg => 0b101010,
        VOp::WAdduWv => 0b110101,
        _ => return None,
    })
}

/// funct6 for an op in the OPF* (floating point) space.
pub fn funct6_opf(op: VOp) -> Option<u32> {
    Some(match op {
        VOp::FAdd => 0b000000,
        VOp::FMul => 0b100100,
        VOp::FMacc => 0b101100,
        _ => return None,
    })
}

/// Memory element-width field (RVV 1.0 table 8: mem width encoding).
pub fn mem_width(bits: u32) -> u32 {
    match bits {
        8 => 0b000,
        16 => 0b101,
        32 => 0b110,
        64 => 0b111,
        _ => unreachable!("unsupported EEW {bits}"),
    }
}

fn opv(funct6: u32, vm: u32, vs2: u32, v1: u32, f3: u32, vd: u32) -> u32 {
    (funct6 << 26) | (vm << 25) | (vs2 << 20) | (v1 << 15) | (f3 << 12) | (vd << 7) | OPC_V
}

/// Encode one trace instruction to its 32-bit machine word.
///
/// Malformed instructions (unknown op/format combination) return a
/// typed [`EncodeError`] — the kernel builders only construct encodable
/// instructions and the property tests sweep every constructible
/// combination, but a bad builder must surface as a `Result` rather
/// than abort the process (e.g. a serving worker thread).
pub fn encode(inst: &VInst) -> Result<u32, EncodeError> {
    Ok(match *inst {
        VInst::SetVl { sew, lmul, .. } => {
            let vtypei = VType::new(sew, lmul).to_bits();
            // vsetvli rd=a0, rs1=a0, vtypei  (bit31=0 selects vsetvli)
            (vtypei << 20) | (TRACE_RS1 << 15) | (funct3::OPCFG << 12) | (TRACE_RS1 << 7) | OPC_V
        }
        VInst::Load { eew, vd, .. } => {
            // nf=0 mew=0 mop=00 (unit stride) vm=1 lumop=00000
            (1 << 25)
                | (TRACE_RS1 << 15)
                | (mem_width(eew.bits()) << 12)
                | ((vd as u32) << 7)
                | OPC_VL
        }
        VInst::Store { eew, vs3, .. } => {
            (1 << 25)
                | (TRACE_RS1 << 15)
                | (mem_width(eew.bits()) << 12)
                | ((vs3 as u32) << 7)
                | OPC_VS
        }
        VInst::OpVV { op, vd, vs2, vs1 } => {
            let (f6, f3) = if let Some(f6) = funct6_opi(op) {
                (f6, funct3::OPIVV)
            } else if let Some(f6) = funct6_opm(op) {
                (f6, funct3::OPMVV)
            } else if let Some(f6) = funct6_opf(op) {
                (f6, funct3::OPFVV)
            } else {
                return Err(EncodeError::NoEncoding { op, form: "VV" });
            };
            let vs2 = if op == VOp::Mv { 0 } else { vs2 as u32 };
            opv(f6, 1, vs2, vs1 as u32, f3, vd as u32)
        }
        VInst::OpVX { op, vd, vs2, .. } => {
            let (f6, f3) = if let Some(f6) = funct6_opi(op) {
                (f6, funct3::OPIVX)
            } else if let Some(f6) = funct6_opm(op) {
                (f6, funct3::OPMVX)
            } else if let Some(f6) = funct6_opf(op) {
                (f6, funct3::OPFVF)
            } else {
                return Err(EncodeError::NoEncoding { op, form: "VX" });
            };
            let vs2 = if op == VOp::Mv { 0 } else { vs2 as u32 };
            opv(f6, 1, vs2, TRACE_RS1, f3, vd as u32)
        }
        VInst::OpVI { op, vd, vs2, imm } => {
            let f6 =
                funct6_opi(op).ok_or(EncodeError::NoEncoding { op, form: "VI" })?;
            let vs2 = if op == VOp::Mv { 0 } else { vs2 as u32 };
            opv(f6, 1, vs2, (imm as u32) & 0x1f, funct3::OPIVI, vd as u32)
        }
        VInst::Scalar { .. } => {
            // Scalar slots are not vector instructions; encode as a
            // canonical RV64I NOP (addi x0, x0, 0) for completeness.
            0x0000_0013
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::vtype::{Lmul, Sew};

    #[test]
    fn vmacsr_uses_the_free_slot_after_vmacc() {
        assert_eq!(funct6_opm(VOp::Macc), Some(0b101101));
        assert_eq!(funct6_opm(VOp::Macsr), Some(0b101110));
    }

    #[test]
    fn vmacsr_vx_word_fields() {
        let w = encode(&VInst::OpVX { op: VOp::Macsr, vd: 1, vs2: 2, rs1: 99 }).unwrap();
        assert_eq!(w & 0x7f, OPC_V);
        assert_eq!((w >> 12) & 0x7, funct3::OPMVX);
        assert_eq!(w >> 26, 0b101110);
        assert_eq!((w >> 7) & 0x1f, 1); // vd
        assert_eq!((w >> 20) & 0x1f, 2); // vs2
        assert_eq!((w >> 15) & 0x1f, TRACE_RS1);
        assert_eq!((w >> 25) & 1, 1); // vm=1 (unmasked)
    }

    #[test]
    fn vsetvli_word() {
        let w = encode(&VInst::SetVl { avl: 256, sew: Sew::E16, lmul: Lmul::M2 }).unwrap();
        assert_eq!(w & 0x7f, OPC_V);
        assert_eq!((w >> 12) & 0x7, funct3::OPCFG);
        assert_eq!(w >> 31, 0); // vsetvli (not vsetvl)
        let vtypei = (w >> 20) & 0x7ff;
        assert_eq!(VType::from_bits(vtypei), Some(VType::new(Sew::E16, Lmul::M2)));
    }

    #[test]
    fn load_store_width_fields() {
        let l = encode(&VInst::Load { eew: Sew::E16, vd: 4, addr: 0xdead }).unwrap();
        assert_eq!(l & 0x7f, OPC_VL);
        assert_eq!((l >> 12) & 0x7, 0b101);
        let s = encode(&VInst::Store { eew: Sew::E8, vs3: 9, addr: 0 }).unwrap();
        assert_eq!(s & 0x7f, OPC_VS);
        assert_eq!((s >> 12) & 0x7, 0b000);
        assert_eq!((s >> 7) & 0x1f, 9);
    }

    #[test]
    fn vmul_and_vsll_share_funct6_but_not_funct3() {
        // both 100101 — disambiguated by OPM vs OPI funct3 space
        assert_eq!(funct6_opi(VOp::Sll), Some(0b100101));
        assert_eq!(funct6_opm(VOp::Mul), Some(0b100101));
        let sll = encode(&VInst::OpVI { op: VOp::Sll, vd: 1, vs2: 2, imm: 8 }).unwrap();
        let mul = encode(&VInst::OpVV { op: VOp::Mul, vd: 1, vs2: 2, vs1: 3 }).unwrap();
        assert_ne!((sll >> 12) & 7, (mul >> 12) & 7);
    }

    #[test]
    fn unencodable_forms_are_typed_errors_not_panics() {
        // slides have no OPM/OPF space; FMacc has no VI form; WAdduWv
        // has no VI form — all previously panicked in the encoder.
        assert_eq!(
            encode(&VInst::OpVI { op: VOp::FMacc, vd: 1, vs2: 2, imm: 0 }),
            Err(EncodeError::NoEncoding { op: VOp::FMacc, form: "VI" })
        );
        assert_eq!(
            encode(&VInst::OpVI { op: VOp::WAdduWv, vd: 1, vs2: 2, imm: 0 }),
            Err(EncodeError::NoEncoding { op: VOp::WAdduWv, form: "VI" })
        );
        let e = encode(&VInst::OpVI { op: VOp::Macc, vd: 0, vs2: 0, imm: 0 }).unwrap_err();
        assert!(e.to_string().contains("no VI encoding"), "{e}");
    }
}
