//! proptest-lite: a tiny deterministic property-testing harness.
//!
//! The vendored crate set has no `proptest`/`quickcheck`, so invariants
//! are checked with this ~60-line xorshift-based runner: deterministic
//! seeds (failures are reproducible by construction), a `runs(n)` knob,
//! and generator helpers for the value shapes the tests need.  No
//! shrinking — cases are kept small enough to debug directly.

/// xorshift64* PRNG — deterministic, fast, good enough for test-case
/// generation (NOT for cryptography).
#[derive(Debug, Clone)]
pub struct Gen {
    state: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen { state: seed.max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform i64 in [lo, hi] inclusive.
    pub fn irange(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// f32 uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// A vec of `len` values each uniform in [0, bound).
    pub fn vec_below(&mut self, len: usize, bound: u64) -> Vec<u64> {
        (0..len).map(|_| self.below(bound)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Iteration count for the long-running fuzz/property suites
/// (`rust/tests/exec_diff.rs`, `rust/tests/ulppack_props.rs`):
/// `SPARQ_FUZZ_ITERS`, when set, replaces the suite's default case
/// count — PR CI runs the cheap defaults, the nightly scheduled job
/// sets it high for deep coverage.  Unparsable or zero values fall
/// back to the default (a typo must not silently skip the suite).
pub fn fuzz_iters(default: u32) -> u32 {
    scaled_iters("SPARQ_FUZZ_ITERS", default)
}

/// Load scale for the chaos/fault-injection suite
/// (`rust/tests/serve_faults.rs`): `SPARQ_CHAOS_ITERS`, when set,
/// replaces the suite's default request count — same contract as
/// [`fuzz_iters`], elevated by the nightly deep-fuzz CI job.
pub fn chaos_iters(default: u32) -> u32 {
    scaled_iters("SPARQ_CHAOS_ITERS", default)
}

fn scaled_iters(var: &str, default: u32) -> u32 {
    match std::env::var(var) {
        Ok(v) => match v.trim().parse::<u32>() {
            Ok(n) if n > 0 => n,
            _ => default,
        },
        Err(_) => default,
    }
}

/// Property runner: `Prop::new(seed).runs(200).check(|g| { ... })`.
pub struct Prop {
    seed: u64,
    runs: u32,
}

impl Prop {
    pub fn new(seed: u64) -> Prop {
        Prop { seed, runs: 100 }
    }

    pub fn runs(mut self, n: u32) -> Prop {
        self.runs = n;
        self
    }

    /// Run the property across `runs` deterministic cases.  A panic in
    /// the closure reports the case number and seed so the failure can
    /// be re-run in isolation.
    pub fn check<F: FnMut(&mut Gen)>(self, mut f: F) {
        for case in 0..self.runs {
            let seed = self.seed.wrapping_add(0x9E3779B97F4A7C15u64.wrapping_mul(case as u64 + 1));
            let mut g = Gen::new(seed);
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut g)));
            if let Err(e) = r {
                eprintln!("property failed at case {case} (gen seed {seed:#x})");
                std::panic::resume_unwind(e);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let a: Vec<u64> = (0..10).map(|_| 0).scan(Gen::new(42), |g, _| Some(g.next_u64())).collect();
        let b: Vec<u64> = (0..10).map(|_| 0).scan(Gen::new(42), |g, _| Some(g.next_u64())).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn below_respects_bound() {
        let mut g = Gen::new(7);
        for _ in 0..1000 {
            assert!(g.below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut g = Gen::new(9);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..2000 {
            let v = g.range(3, 6);
            assert!((3..=6).contains(&v));
            saw_lo |= v == 3;
            saw_hi |= v == 6;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn f32_unit_interval() {
        let mut g = Gen::new(1);
        for _ in 0..100 {
            let v = g.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }
}
