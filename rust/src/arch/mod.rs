//! Processor configuration — which machine are we simulating?
//!
//! `ara()` is the baseline (4 lanes, FPU, no `vmacsr`); `sparq()` is
//! the paper's machine (FPU removed, `vmacsr` present).  Everything
//! else (lane count sweeps, the configurable-shifter future-work
//! extension) is expressed as field tweaks for the ablation benches.

use std::fmt;

/// The functional units of one Ara/Sparq lane (paper Fig. 6 legend).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Unit {
    /// SIMD multiplier + (on Ara) FPU — executes vmul/vmacc/vmacsr/vf*.
    Mfpu,
    /// Integer ALU — add/logic/shift/slide-free moves/widening adds.
    Valu,
    /// Vector load/store unit.
    Vlsu,
    /// Slide unit (inter-lane shuffle network).
    Sldu,
    /// Instruction dispatch (the scalar core + Ara sequencer front end).
    Dispatch,
}

impl Unit {
    pub const ALL: [Unit; 5] = [Unit::Mfpu, Unit::Valu, Unit::Vlsu, Unit::Sldu, Unit::Dispatch];

    pub fn name(self) -> &'static str {
        match self {
            Unit::Mfpu => "MFPU",
            Unit::Valu => "VALU",
            Unit::Vlsu => "VLSU",
            Unit::Sldu => "SLDU",
            Unit::Dispatch => "DISP",
        }
    }
}

impl fmt::Display for Unit {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Static description of the simulated machine.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ProcessorConfig {
    /// Human-readable name used in reports.
    pub name: String,
    /// Number of vector lanes (the paper evaluates 4).
    pub lanes: u32,
    /// VLEN in bits.  Ara's VRF is `32 * VLEN / 8` bytes total; the
    /// paper's 4-lane, 16 KiB-VRF configuration is VLEN = 4096.
    pub vlen_bits: u32,
    /// Lane datapath width in bits (64 for Ara — one 64-bit word per
    /// lane per cycle through each functional unit).
    pub datapath_bits: u32,
    /// Vector FPU present?  (Removed in Sparq — the paper's Table II.)
    pub fpu: bool,
    /// `vmacsr` implemented?  (Sparq yes, Ara no.)
    pub vmacsr: bool,
    /// The future-work extension: run-time configurable shift amount
    /// (`vmacsr.cfg` + CSR) instead of the hard-wired SEW/2.
    pub configurable_shifter: bool,
    /// Memory port bandwidth in bytes/cycle (the AXI width towards L2).
    /// Ara's default system gives the VLSU one lane-word per lane.
    pub mem_bytes_per_cycle: u32,
    /// Startup latency of a vector instruction reaching its unit
    /// (dispatch -> sequencer -> operand fetch), in cycles.
    pub issue_latency: u32,
    /// Extra latency of a memory access (AXI round trip), in cycles.
    pub mem_latency: u32,
    /// Pipeline bubble between back-to-back instructions on one unit
    /// (operand-requester turnaround in Ara's lanes); this is what keeps
    /// real lane utilization below 100% (§III-A's 93.8%).
    pub issue_bubble: u32,
}

impl ProcessorConfig {
    /// The baseline: Ara, RVV 1.0, 4 lanes, with FPU, no custom ops.
    pub fn ara() -> ProcessorConfig {
        ProcessorConfig {
            name: "ara".into(),
            lanes: 4,
            vlen_bits: 4096,
            datapath_bits: 64,
            fpu: true,
            vmacsr: false,
            configurable_shifter: false,
            mem_bytes_per_cycle: 4 * 8,
            issue_latency: 4,
            mem_latency: 10,
            issue_bubble: 1,
        }
    }

    /// The paper's machine: Ara minus FPU plus `vmacsr`.
    pub fn sparq() -> ProcessorConfig {
        ProcessorConfig {
            name: "sparq".into(),
            fpu: false,
            vmacsr: true,
            ..ProcessorConfig::ara()
        }
    }

    /// Sparq with the future-work configurable shifter.
    pub fn sparq_cfgshift() -> ProcessorConfig {
        ProcessorConfig {
            name: "sparq-cfgshift".into(),
            configurable_shifter: true,
            ..ProcessorConfig::sparq()
        }
    }

    /// Lane-count variant (used by the scaling ablation).
    pub fn with_lanes(mut self, lanes: u32) -> ProcessorConfig {
        assert!(lanes.is_power_of_two() && lanes >= 1 && lanes <= 16);
        // Ara scales VLEN and memory bandwidth with the lane count.
        self.vlen_bits = self.vlen_bits / self.lanes * lanes;
        self.mem_bytes_per_cycle = self.mem_bytes_per_cycle / self.lanes * lanes;
        self.name = format!("{}-{}l", self.name, lanes);
        self.lanes = lanes;
        self
    }

    /// Bytes the whole vector engine moves through one unit per cycle.
    pub fn bytes_per_cycle(&self) -> u32 {
        self.lanes * self.datapath_bits / 8
    }

    /// Total VRF capacity in bytes (32 architectural registers).
    pub fn vrf_bytes(&self) -> u32 {
        32 * self.vlen_bits / 8
    }
}

impl fmt::Display for ProcessorConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} ({} lanes, VLEN={}, FPU={}, vmacsr={})",
            self.name, self.lanes, self.vlen_bits, self.fpu, self.vmacsr
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_configs() {
        let ara = ProcessorConfig::ara();
        assert!(ara.fpu && !ara.vmacsr);
        let sq = ProcessorConfig::sparq();
        assert!(!sq.fpu && sq.vmacsr);
        assert_eq!(ara.lanes, 4);
        // Table II: 16 KiB VRF
        assert_eq!(ara.vrf_bytes(), 16 * 1024);
    }

    #[test]
    fn lane_scaling_scales_vlen_and_bandwidth() {
        let p = ProcessorConfig::sparq().with_lanes(8);
        assert_eq!(p.lanes, 8);
        assert_eq!(p.vlen_bits, 8192);
        assert_eq!(p.bytes_per_cycle(), 64);
        assert_eq!(p.mem_bytes_per_cycle, 64);
    }

    #[test]
    fn four_lanes_move_32_bytes_per_cycle() {
        assert_eq!(ProcessorConfig::ara().bytes_per_cycle(), 32);
    }
}
