//! Loader for the `SPQD` test-set binary `python/compile/dataset.py`
//! writes: `magic 'SPQD' | u32 n,c,h,w | f32 data | u8 labels`.

use super::RuntimeError;
use std::path::Path;

/// A held-out evaluation set.
#[derive(Debug, Clone)]
pub struct TestSet {
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    /// Flattened (n, c, h, w) images, row-major.
    pub images: Vec<f32>,
    pub labels: Vec<u8>,
}

impl TestSet {
    pub fn load(path: impl AsRef<Path>) -> Result<TestSet, RuntimeError> {
        let bytes = std::fs::read(path)?;
        Self::parse(&bytes)
    }

    pub fn parse(bytes: &[u8]) -> Result<TestSet, RuntimeError> {
        let bad = |m: &str| RuntimeError::Manifest(format!("testset: {m}"));
        if bytes.len() < 20 || &bytes[..4] != b"SPQD" {
            return Err(bad("bad magic"));
        }
        let rd = |i: usize| {
            u32::from_le_bytes(bytes[4 + 4 * i..8 + 4 * i].try_into().unwrap()) as usize
        };
        let (n, c, h, w) = (rd(0), rd(1), rd(2), rd(3));
        let nf = n * c * h * w;
        let expected = 20 + 4 * nf + n;
        if bytes.len() != expected {
            return Err(bad(&format!("size {} != expected {expected}", bytes.len())));
        }
        let images = bytes[20..20 + 4 * nf]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        let labels = bytes[20 + 4 * nf..].to_vec();
        Ok(TestSet { n, c, h, w, images, labels })
    }

    /// The images of one batch (padded with zeros to `batch` images if
    /// the tail is short); returns (data, real_count).
    pub fn batch(&self, start: usize, batch: usize) -> (Vec<f32>, usize) {
        let per = self.c * self.h * self.w;
        let real = batch.min(self.n.saturating_sub(start));
        let mut out = vec![0f32; batch * per];
        out[..real * per].copy_from_slice(&self.images[start * per..(start + real) * per]);
        (out, real)
    }

    /// One image's data.
    pub fn image(&self, i: usize) -> &[f32] {
        let per = self.c * self.h * self.w;
        &self.images[i * per..(i + 1) * per]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(n: usize) -> Vec<u8> {
        let (c, h, w) = (1usize, 2usize, 2usize);
        let mut v = b"SPQD".to_vec();
        for d in [n as u32, c as u32, h as u32, w as u32] {
            v.extend_from_slice(&d.to_le_bytes());
        }
        for i in 0..n * c * h * w {
            v.extend_from_slice(&(i as f32).to_le_bytes());
        }
        v.extend((0..n).map(|i| (i % 4) as u8));
        v
    }

    #[test]
    fn parses_and_batches() {
        let ts = TestSet::parse(&sample(5)).unwrap();
        assert_eq!((ts.n, ts.c, ts.h, ts.w), (5, 1, 2, 2));
        assert_eq!(ts.image(1), &[4.0, 5.0, 6.0, 7.0]);
        let (b, real) = ts.batch(4, 4);
        assert_eq!(real, 1);
        assert_eq!(b.len(), 16);
        assert_eq!(&b[0..4], &[16.0, 17.0, 18.0, 19.0]);
        assert!(b[4..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn rejects_bad_magic_and_size() {
        assert!(TestSet::parse(b"NOPE").is_err());
        let mut s = sample(3);
        s.pop();
        assert!(TestSet::parse(&s).is_err());
    }
}
