//! The simulator-backed models: a single conv ([`SimConvModel`]) and —
//! since the dataflow refactor — the whole network
//! ([`SimQnnModel`]), both compiled once (through a shared
//! [`ProgramCache`]) and executed many times on pooled machines.
//! These are the runtimes the serving coordinator's executors drive:
//! real sub-byte numerics, bit-exact against the golden models
//! (`kernels::workload` for the conv, `qnn::QnnNet::golden_forward`
//! for the network), with no PJRT artifacts and no python.  Every
//! `infer` runs the cached *micro-op* form of the program
//! (`sim::CompiledProgram`, DESIGN.md §Perf): legality was validated
//! at compile time and the inner loops execute word-parallel, so the
//! per-request host cost is input staging + SWAR execution only.

use crate::arch::ProcessorConfig;
use crate::kernels::{
    CompiledConv, ConvDims, ConvVariant, EngineOpts, ProgramCache, Workload,
};
use crate::qnn::compiled::CompiledQnn;
use crate::qnn::graph::QnnGraph;
use crate::qnn::schedule::QnnPrecision;
use crate::sim::{MachinePool, RunReport, SimError};
use crate::ulppack::act_level_max;
use std::sync::Arc;

/// A compiled, weight-frozen conv2d ready to serve inference requests.
///
/// The weights come from the deterministic workload seed (standing in
/// for a trained checkpoint, as everywhere else in the reproduction);
/// each request supplies fresh activations.
pub struct SimConvModel {
    pub cc: Arc<CompiledConv>,
    pub cfg: ProcessorConfig,
    pub dims: ConvDims,
    pub variant: ConvVariant,
    /// Workload template: frozen weights + rebindable activations.
    template: Workload,
    amax: u64,
}

impl SimConvModel {
    /// Compile (or fetch from `cache`) the conv program for this model.
    /// Fp32 is rejected: the serving readback path is integer-only.
    pub fn compile(
        cfg: &ProcessorConfig,
        dims: ConvDims,
        variant: ConvVariant,
        seed: u64,
        cache: &ProgramCache,
    ) -> Result<SimConvModel, SimError> {
        if matches!(variant, ConvVariant::Fp32) {
            return Err(SimError::Unsupported(
                "SimConvModel serves integer conv variants only",
            ));
        }
        let (wb, ab) = variant.bits();
        let template = Workload::random(dims, wb, ab, seed);
        let cc = cache.get_or_compile(cfg, &template, variant, EngineOpts::default())?;
        Ok(SimConvModel {
            cc,
            cfg: cfg.clone(),
            dims,
            variant,
            template,
            amax: act_level_max(ab),
        })
    }

    /// Activation tensor length (c * h * w levels, channel-first).
    pub fn input_len(&self) -> usize {
        (self.dims.c * self.dims.h * self.dims.w) as usize
    }

    /// Output tensor length (co * ho * wo).
    pub fn output_len(&self) -> usize {
        self.cc.out.len
    }

    /// The weight tensor this model was frozen with (for building
    /// golden references in tests).
    pub fn weights(&self) -> &[Vec<Vec<u64>>] {
        &self.template.wgt
    }

    /// Clamp + round one f32 into the activation level range.
    pub fn quantize_level(&self, v: f32) -> u64 {
        quantize(v, self.amax)
    }

    /// Run one inference: rebind `input` (flattened c-first activation
    /// levels, quantized via [`Self::quantize_level`]) into a pooled
    /// machine, execute the cached program, read the output back.
    pub fn infer(
        &mut self,
        pool: &MachinePool,
        input: &[f32],
    ) -> Result<(Vec<i64>, RunReport), SimError> {
        if input.len() != self.input_len() {
            return Err(SimError::Unsupported("input length != c*h*w"));
        }
        let hw = (self.dims.h * self.dims.w) as usize;
        let amax = self.amax;
        for (c, row) in self.template.act.iter_mut().enumerate() {
            for (i, lv) in row.iter_mut().enumerate() {
                *lv = quantize(input[c * hw + i], amax);
            }
        }
        let mut m = pool.acquire(&self.cfg, self.cc.mem_bytes);
        // acquire() already reset the machine: skip execute()'s re-zeroing
        let result = match self.cc.execute_fresh(&mut m, &self.template) {
            Ok(rep) => self.cc.out.read_ints(&m.mem).map(|out| (out, rep)),
            Err(e) => Err(e),
        };
        pool.release(m);
        result
    }
}

/// A compiled, weight-frozen *whole network* ready to serve
/// classification requests: the QnnGraph compiled once into a chained
/// multi-layer program over a planned activation arena
/// ([`CompiledQnn`]), fetched from the shared [`ProgramCache`] under
/// its graph-level key.  Each request stages fresh activations into
/// the arena; logits come straight out of it.
///
/// Mixed-precision graphs (per-layer `(w_bits, a_bits)` overrides)
/// serve through the same path: the compiler resolves each layer's
/// precision, autotunes its kernel variant (rankings memoized in the
/// shared cache under `TuneKey`s), and repeat inference is all-hits at
/// the graph level — no re-tuning, no re-compiling.
pub struct SimQnnModel {
    pub cq: Arc<CompiledQnn>,
    pub cfg: ProcessorConfig,
    amax: u64,
}

impl SimQnnModel {
    /// Compile (or fetch from `cache`) the whole network.  The weights
    /// derive from the one graph-level `seed` (standing in for a
    /// trained checkpoint, as everywhere else in the reproduction).
    pub fn compile(
        cfg: &ProcessorConfig,
        graph: &QnnGraph,
        precision: QnnPrecision,
        seed: u64,
        cache: &ProgramCache,
    ) -> Result<SimQnnModel, SimError> {
        let cq = cache.get_or_compile_qnn(cfg, graph, precision, seed)?;
        let amax = act_level_max(cq.net.a_bits());
        Ok(SimQnnModel { cq, cfg: cfg.clone(), amax })
    }

    /// [`Self::compile`] against the batch-`batch` arena layout
    /// ([`crate::qnn::compiled::CompiledQnn::compile_batched`]): one
    /// cached program whose machine holds `batch` per-image activation
    /// slots, served through [`Self::infer_batch`].
    pub fn compile_batched(
        cfg: &ProcessorConfig,
        graph: &QnnGraph,
        precision: QnnPrecision,
        seed: u64,
        cache: &ProgramCache,
        batch: u32,
    ) -> Result<SimQnnModel, SimError> {
        let cq = cache.get_or_compile_qnn_batched(cfg, graph, precision, seed, batch)?;
        let amax = act_level_max(cq.net.a_bits());
        Ok(SimQnnModel { cq, cfg: cfg.clone(), amax })
    }

    /// Activation slots of the compiled arena (1 unless the model was
    /// compiled with [`Self::compile_batched`]).
    pub fn batch(&self) -> usize {
        self.cq.batch as usize
    }

    /// Input image length (c * h * w levels, channel-first).
    pub fn input_len(&self) -> usize {
        self.cq.net.input_len()
    }

    pub fn classes(&self) -> usize {
        self.cq.net.graph.classes as usize
    }

    /// Clamp + round one f32 into the activation level range.
    pub fn quantize_level(&self, v: f32) -> u64 {
        quantize(v, self.amax)
    }

    /// Run one full-network inference: quantize `input` to levels,
    /// stage it into a pooled machine's arena, run every chained layer
    /// stream, and read the logits back.  Returns (logits, total
    /// simulated cycles of this inference).
    ///
    /// On a batch-compiled model this is a singleton batch through
    /// [`Self::infer_batch`] — the weight-pack pass lives in the
    /// per-batch preamble there, so routing through the slot-only
    /// `execute_fresh` would under-report the single-image cost.
    pub fn infer(&self, pool: &MachinePool, input: &[f32]) -> Result<(Vec<i64>, u64), SimError> {
        if self.cq.batch > 1 || self.cq.preamble.is_some() {
            let (mut per_image, total) = self.infer_batch_refs(pool, &[input])?;
            let (logits, _slot_cycles) = per_image.pop().expect("singleton batch");
            return Ok((logits, total));
        }
        if input.len() != self.input_len() {
            return Err(SimError::Unsupported("input length != c*h*w"));
        }
        let levels: Vec<u64> = input.iter().map(|&v| quantize(v, self.amax)).collect();
        let mut m = pool.acquire(&self.cfg, self.cq.mem_bytes);
        // acquire() already reset the machine
        let result = self.cq.execute_fresh(&mut m, &levels);
        pool.release(m);
        let run = result?;
        Ok((run.logits, run.total_cycles()))
    }

    /// Run one *batched* execution: quantize up to [`Self::batch`]
    /// images, stage each into its own activation slot of one pooled
    /// machine, and run the whole batch through the shared program
    /// (per-batch weight-pack preamble paid once).  Returns one
    /// `(logits, slot_cycles)` pair per image — slot cycles are
    /// bit-identical to a one-image execution — plus the batch's total
    /// simulated cycles (preamble included), which is what throughput
    /// accounting divides by the fill.
    #[allow(clippy::type_complexity)]
    pub fn infer_batch(
        &self,
        pool: &MachinePool,
        inputs: &[Vec<f32>],
    ) -> Result<(Vec<(Vec<i64>, u64)>, u64), SimError> {
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        self.infer_batch_refs(pool, &refs)
    }

    /// [`Self::infer_batch`] over borrowed images — the batched server
    /// stages requests straight out of their ring slots without
    /// cloning or taking ownership of the image buffers.
    #[allow(clippy::type_complexity)]
    pub fn infer_batch_refs(
        &self,
        pool: &MachinePool,
        inputs: &[&[f32]],
    ) -> Result<(Vec<(Vec<i64>, u64)>, u64), SimError> {
        if inputs.is_empty() || inputs.len() > self.batch() {
            return Err(SimError::Unsupported(
                "batch must stage between 1 and the compiled batch size images",
            ));
        }
        for input in inputs {
            if input.len() != self.input_len() {
                return Err(SimError::Unsupported("input length != c*h*w"));
            }
        }
        let levels: Vec<Vec<u64>> = inputs
            .iter()
            .map(|input| input.iter().map(|&v| quantize(v, self.amax)).collect())
            .collect();
        let mut m = pool.acquire(&self.cfg, self.cq.mem_bytes);
        // acquire() already reset the machine
        let result = self.cq.execute_batch_fresh(&mut m, &levels);
        pool.release(m);
        let batch = result?;
        let total = batch.total_cycles();
        let per_image = batch
            .runs
            .into_iter()
            .map(|run| {
                let cycles = run.total_cycles();
                (run.logits, cycles)
            })
            .collect();
        Ok((per_image, total))
    }
}

/// Clamp + round one f32 into `[0, amax]` levels (NaN -> 0).  Shared
/// by the inference rebind loop and the public `quantize_level`.
fn quantize(v: f32, amax: u64) -> u64 {
    let hi = amax as f32;
    let v = if v.is_nan() { 0.0 } else { v.clamp(0.0, hi) };
    v.round() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::workload::golden_exact;
    use crate::ulppack::RegionMode;

    fn model() -> (SimConvModel, ProgramCache) {
        let cache = ProgramCache::new();
        let m = SimConvModel::compile(
            &ProcessorConfig::sparq(),
            ConvDims { c: 4, h: 8, w: 8, co: 2, fh: 3, fw: 3 },
            ConvVariant::Vmacsr { w_bits: 2, a_bits: 2, mode: RegionMode::Strict },
            0xFEED,
            &cache,
        )
        .unwrap();
        (m, cache)
    }

    #[test]
    fn infer_matches_golden_on_fresh_activations() {
        let (mut model, _cache) = model();
        let pool = MachinePool::new();
        // activations distinct from the template's: request-supplied
        let input: Vec<f32> = (0..model.input_len()).map(|i| (i % 4) as f32).collect();
        let (got, rep) = model.infer(&pool, &input).unwrap();
        assert!(rep.stats.cycles > 0);
        // golden: same weights, the request's activation levels
        let mut wl = model.template.clone();
        let hw = (model.dims.h * model.dims.w) as usize;
        for (c, row) in wl.act.iter_mut().enumerate() {
            for (i, lv) in row.iter_mut().enumerate() {
                *lv = (input[c * hw + i]) as u64;
            }
        }
        assert_eq!(got, golden_exact(&wl));
    }

    #[test]
    fn repeated_inference_reuses_machines_and_cycles_are_stable() {
        let (mut model, _cache) = model();
        let pool = MachinePool::new();
        let input: Vec<f32> = vec![1.0; model.input_len()];
        let (_, r1) = model.infer(&pool, &input).unwrap();
        let (_, r2) = model.infer(&pool, &input).unwrap();
        assert_eq!(r1.stats.cycles, r2.stats.cycles);
        assert_eq!(pool.stats().created, 1);
        assert_eq!(pool.stats().reused, 1);
    }

    #[test]
    fn out_of_range_inputs_are_clamped_not_wrapped() {
        let (mut model, _) = model();
        let pool = MachinePool::new();
        let mut input = vec![0.0f32; model.input_len()];
        input[0] = 999.0;
        input[1] = -5.0;
        input[2] = f32::NAN;
        let (got, _) = model.infer(&pool, &input).unwrap();
        assert_eq!(got.len(), model.output_len());
        assert_eq!(model.quantize_level(999.0), 3); // A2 max level
        assert_eq!(model.quantize_level(-5.0), 0);
        assert_eq!(model.quantize_level(f32::NAN), 0);
    }

    #[test]
    fn fp32_rejected() {
        let cache = ProgramCache::new();
        assert!(SimConvModel::compile(
            &ProcessorConfig::ara(),
            ConvDims { c: 2, h: 4, w: 4, co: 1, fh: 1, fw: 1 },
            ConvVariant::Fp32,
            1,
            &cache,
        )
        .is_err());
    }

    #[test]
    fn qnn_model_serves_the_golden_network() {
        use crate::qnn::QnnGraph;
        use crate::qnn::schedule::QnnPrecision;
        let cache = ProgramCache::new();
        let model = SimQnnModel::compile(
            &ProcessorConfig::sparq(),
            &QnnGraph::sparq_cnn(),
            QnnPrecision::SubByte { w_bits: 2, a_bits: 2 },
            0xFEED,
            &cache,
        )
        .unwrap();
        assert_eq!(model.input_len(), 256);
        assert_eq!(model.classes(), 4);
        let pool = MachinePool::new();
        let input: Vec<f32> = (0..model.input_len()).map(|i| (i % 4) as f32).collect();
        let (logits, cycles) = model.infer(&pool, &input).unwrap();
        assert!(cycles > 0);
        // bit-exact against the host golden network on the quantized image
        let levels: Vec<u64> = input.iter().map(|&v| model.quantize_level(v)).collect();
        let golden = model.cq.net.golden_forward(&levels).unwrap();
        assert_eq!(logits, golden.logits);
        // repeated inference: identical logits and cycles, pooled machine
        let (l2, c2) = model.infer(&pool, &input).unwrap();
        assert_eq!(l2, logits);
        assert_eq!(c2, cycles);
        assert_eq!(pool.stats().reused, 1);
    }

    #[test]
    fn batched_qnn_model_amortizes_the_preamble_and_matches_singles() {
        use crate::qnn::schedule::QnnPrecision;
        use crate::qnn::QnnGraph;
        let cache = ProgramCache::new();
        let prec = QnnPrecision::SubByte { w_bits: 2, a_bits: 2 };
        let model = SimQnnModel::compile_batched(
            &ProcessorConfig::sparq(),
            &QnnGraph::sparq_cnn(),
            prec,
            0xFEED,
            &cache,
            4,
        )
        .unwrap();
        assert_eq!(model.batch(), 4);
        let pool = MachinePool::new();
        let inputs: Vec<Vec<f32>> = (0..4)
            .map(|k| (0..model.input_len()).map(|i| ((i + k * 3) % 4) as f32).collect())
            .collect();
        let (per_image, total) = model.infer_batch(&pool, &inputs).unwrap();
        assert_eq!(per_image.len(), 4);
        // each slot matches the singleton batch of the same image,
        // logits and cycles
        let mut singles_total = 0u64;
        for (k, input) in inputs.iter().enumerate() {
            let (one, one_total) =
                model.infer_batch(&pool, std::slice::from_ref(input)).unwrap();
            assert_eq!(one[0].0, per_image[k].0, "image {k} logits diverged");
            assert_eq!(one[0].1, per_image[k].1, "image {k} slot cycles diverged");
            singles_total += one_total;
        }
        // the batch pays the weight-pack preamble once instead of 4x
        assert!(total < singles_total, "batching must amortize the preamble");
        // single-image infer() on a batched model routes through the
        // singleton batch, so it reports the TRUE per-image cost
        // (preamble included) — not the slot-only cycles
        let (l0, c0) = model.infer(&pool, &inputs[0]).unwrap();
        assert_eq!(l0, per_image[0].0);
        assert!(c0 > per_image[0].1, "infer must include the preamble cycles");
        // oversized and empty batches are typed errors
        assert!(model.infer_batch(&pool, &[]).is_err());
        let five = vec![inputs[0].clone(); 5];
        assert!(model.infer_batch(&pool, &five).is_err());
        // warm repeat: no recompilation at any batch size already seen
        let before = cache.stats();
        let again = SimQnnModel::compile_batched(
            &ProcessorConfig::sparq(),
            &QnnGraph::sparq_cnn(),
            prec,
            0xFEED,
            &cache,
            4,
        )
        .unwrap();
        assert_eq!(cache.stats().misses, before.misses, "warm batched compile re-missed");
        let (p2, t2) = again.infer_batch(&pool, &inputs).unwrap();
        assert_eq!(t2, total);
        for (a, b) in p2.iter().zip(&per_image) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn qnn_model_serves_a_mixed_precision_network_all_hits_on_repeat() {
        use crate::qnn::schedule::QnnPrecision;
        use crate::qnn::QnnGraph;
        let cache = ProgramCache::new();
        let graph = QnnGraph::sparq_cnn_mixed((4, 4), (2, 2));
        let model = SimQnnModel::compile(
            &ProcessorConfig::sparq(),
            &graph,
            QnnPrecision::SubByte { w_bits: 2, a_bits: 2 },
            0xABAD,
            &cache,
        )
        .unwrap();
        let pool = MachinePool::new();
        let input: Vec<f32> = (0..model.input_len()).map(|i| ((i * 7) % 4) as f32).collect();
        let (logits, cycles) = model.infer(&pool, &input).unwrap();
        // bit-exact against the golden network under the compiled
        // per-layer variant choices
        let levels: Vec<u64> = input.iter().map(|&v| model.quantize_level(v)).collect();
        let golden = model.cq.golden(&levels).unwrap();
        assert_eq!(logits, golden.logits);
        // the two quantized layers really run different containers
        let labels: Vec<String> =
            model.cq.variants.iter().map(|v| v.label()).collect();
        assert!(labels[1].contains("W4A4"), "{labels:?}");
        assert!(labels[2].contains("W2A2"), "{labels:?}");
        // a second model over the same (graph, precision, seed) is a
        // pure graph-level hit: nothing re-tunes, nothing re-compiles
        let before = cache.stats();
        let again = SimQnnModel::compile(
            &ProcessorConfig::sparq(),
            &graph,
            QnnPrecision::SubByte { w_bits: 2, a_bits: 2 },
            0xABAD,
            &cache,
        )
        .unwrap();
        let after = cache.stats();
        assert_eq!(after.misses, before.misses);
        assert_eq!(after.tune_misses, before.tune_misses, "repeat compile re-tuned");
        assert!(after.hits > before.hits);
        let (l2, c2) = again.infer(&pool, &input).unwrap();
        assert_eq!(l2, logits);
        assert_eq!(c2, cycles);
    }
}
