//! Parser for `artifacts/manifest.txt` — the tab-separated index
//! `python/compile/aot.py` writes:
//!
//! ```text
//! artifact<TAB>name<TAB>file<TAB>key=value<TAB>...
//! data<TAB>name<TAB>file<TAB>key=value<TAB>...
//! ```

use super::RuntimeError;
use std::collections::HashMap;
use std::path::Path;

/// One AOT-compiled model.
#[derive(Debug, Clone, PartialEq)]
pub struct Artifact {
    pub name: String,
    pub file: String,
    pub meta: HashMap<String, String>,
}

impl Artifact {
    /// Typed metadata accessor (`batch=16` etc.).
    pub fn meta_u32(&self, key: &str) -> Option<u32> {
        self.meta.get(key)?.parse().ok()
    }

    pub fn meta_f64(&self, key: &str) -> Option<f64> {
        self.meta.get(key)?.parse().ok()
    }
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: Vec<Artifact>,
    pub data: Vec<Artifact>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest, RuntimeError> {
        let mut m = Manifest::default();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            if fields.len() < 3 {
                return Err(RuntimeError::Manifest(format!(
                    "line {}: expected at least 3 tab-separated fields",
                    ln + 1
                )));
            }
            let meta = fields[3..]
                .iter()
                .filter_map(|kv| kv.split_once('='))
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect();
            let art = Artifact { name: fields[1].into(), file: fields[2].into(), meta };
            match fields[0] {
                "artifact" => m.artifacts.push(art),
                "data" => m.data.push(art),
                other => {
                    return Err(RuntimeError::Manifest(format!(
                        "line {}: unknown record type '{other}'",
                        ln + 1
                    )))
                }
            }
        }
        Ok(m)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Manifest, RuntimeError> {
        Manifest::parse(&std::fs::read_to_string(path)?)
    }

    pub fn artifact(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn datum(&self, name: &str) -> Option<&Artifact> {
        self.data.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
data\ttestset\ttestset.bin\tn=512\tc=1\th=16\tw=16\tclasses=4
artifact\tqnn_fp32\tqnn_fp32.hlo.txt\tbatch=16\tin=1x16x16\tout=4\tacc_ref=0.9980
artifact\tqnn_w2a2\tqnn_w2a2.hlo.txt\tbatch=16\twbits=2\tabits=2\tcontainer=8
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.data.len(), 1);
        let a = m.artifact("qnn_w2a2").unwrap();
        assert_eq!(a.meta_u32("wbits"), Some(2));
        assert_eq!(a.meta_u32("container"), Some(8));
        let fp = m.artifact("qnn_fp32").unwrap();
        assert!((fp.meta_f64("acc_ref").unwrap() - 0.998).abs() < 1e-6);
        assert_eq!(m.datum("testset").unwrap().meta_u32("n"), Some(512));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("artifact\tonly-two").is_err());
        assert!(Manifest::parse("mystery\ta\tb").is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let m = Manifest::parse("# header\n\nartifact\ta\tb.hlo.txt\n").unwrap();
        assert_eq!(m.artifacts.len(), 1);
    }
}
