//! Artifact loading and execution backends.
//!
//! Two backends exist:
//!
//! * **PJRT** (not in-tree): loads the AOT-compiled HLO-text artifacts
//!   that `python/compile/aot.py` produced (`make artifacts`) and
//!   executes them on the CPU PJRT client — python never runs on this
//!   path.  Interchange is HLO *text* (see `aot.py` and DESIGN.md: jax
//!   >= 0.5 emits 64-bit-id protos that the xla_extension 0.5.1 crate
//!   rejects; the text parser reassigns ids).  The `xla` crate is
//!   **not vendored** in this repository, so what ships is a stub
//!   whose execution methods return [`RuntimeError::Backend`];
//!   [`artifacts_present`] reports `false` ([`backend_available`] is
//!   constant-false), keeping every artifact-gated test and bench on
//!   its skip path.  The `pjrt` cargo feature is the designated slot
//!   for the real backend and is a `compile_error!` until it lands.
//!
//! * **Simulator** ([`simconv`], always available): compiles a sub-byte
//!   conv2d ([`SimConvModel`]) or the whole SparqCNN as one chained
//!   dataflow program ([`SimQnnModel`] over
//!   [`crate::qnn::compiled::CompiledQnn`]) once through the program
//!   cache and serves repeated inferences on pooled machines — the
//!   compile-once/execute-many runtime the coordinator's executors and
//!   the `sparq serve` fallback use.  No artifacts, no python,
//!   bit-exact against the golden models.  For batched serving,
//!   [`SimQnnModel::compile_batched`] compiles the batch-B arena
//!   layout and [`SimQnnModel::infer_batch`] stages up to B images
//!   into one machine per execution (DESIGN.md §Serving).

// The feature exists as the designated slot for the PJRT backend, but
// the backend itself is not in-tree (it needs the non-vendored `xla`
// crate).  Enabling it must fail loudly at build time rather than
// producing a binary whose artifact-gated tests all fail at runtime
// against the stub.
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature is a placeholder: vendor the `xla` crate and restore the \
     PJRT backend (see DESIGN.md §6) before enabling it"
);

pub mod manifest;
pub mod simconv;
pub mod testset;

pub use manifest::{Artifact, Manifest};
pub use simconv::{SimConvModel, SimQnnModel};
pub use testset::TestSet;

use std::fmt;
use std::path::{Path, PathBuf};

#[derive(Debug)]
pub enum RuntimeError {
    Io(std::io::Error),
    Manifest(String),
    /// The execution backend is unavailable or failed (e.g. built
    /// without the `pjrt` feature).
    Backend(String),
    UnknownModel(String),
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RuntimeError::Io(e) => write!(f, "artifact directory problem: {e}"),
            RuntimeError::Manifest(m) => write!(f, "manifest: {m}"),
            RuntimeError::Backend(m) => write!(f, "backend: {m}"),
            RuntimeError::UnknownModel(m) => {
                write!(f, "unknown model '{m}' (is it in artifacts/manifest.txt?)")
            }
        }
    }
}

impl std::error::Error for RuntimeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RuntimeError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for RuntimeError {
    fn from(e: std::io::Error) -> RuntimeError {
        RuntimeError::Io(e)
    }
}

fn backend_unavailable() -> RuntimeError {
    RuntimeError::Backend(
        "built without the `pjrt` feature: PJRT execution is unavailable \
         (the xla crate is not vendored; see DESIGN.md)"
            .into(),
    )
}

/// A loaded inference runtime over an artifacts directory.
///
/// Without the `pjrt` feature this parses the manifest (model names and
/// metadata stay queryable) but `exec_*` returns
/// [`RuntimeError::Backend`] — the offline build serves through
/// [`simconv`] instead.
pub struct Runtime {
    pub manifest: Manifest,
    pub dir: PathBuf,
}

impl Runtime {
    /// Load the manifest in `dir`.  (A future PJRT backend will also
    /// compile every HLO module here, once per process.)
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime, RuntimeError> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.txt"))?;
        Ok(Runtime { manifest, dir })
    }

    /// Names of the loaded models.
    pub fn models(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.manifest.artifacts.iter().map(|a| a.name.as_str()).collect();
        v.sort();
        v
    }

    pub fn platform(&self) -> String {
        "none (built without the `pjrt` feature)".into()
    }

    fn check_model(&self, name: &str) -> Result<(), RuntimeError> {
        if self.manifest.artifact(name).is_none() {
            return Err(RuntimeError::UnknownModel(name.into()));
        }
        Ok(())
    }

    /// Execute a model whose inputs and output are f32 tensors.
    /// `inputs` = (data, dims) pairs; returns the flattened f32 output.
    pub fn exec_f32(
        &self,
        name: &str,
        inputs: &[(&[f32], &[i64])],
    ) -> Result<Vec<f32>, RuntimeError> {
        self.check_model(name)?;
        let _ = inputs;
        Err(backend_unavailable())
    }

    /// Execute a model whose inputs and output are i32 tensors.
    pub fn exec_i32(
        &self,
        name: &str,
        inputs: &[(&[i32], &[i64])],
    ) -> Result<Vec<i32>, RuntimeError> {
        self.check_model(name)?;
        let _ = inputs;
        Err(backend_unavailable())
    }
}

/// The repo-conventional artifacts directory, overridable via
/// `SPARQ_ARTIFACTS` (used by every example and bench).
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("SPARQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Can this build actually *execute* artifacts?  `false` until a real
/// PJRT backend lands behind the `pjrt` feature (which is currently a
/// `compile_error!` placeholder, so this is constant-false today).
pub fn backend_available() -> bool {
    cfg!(feature = "pjrt")
}

/// True when `make artifacts` has been run *and* an executing backend
/// is compiled in (integration tests and benches skip politely
/// otherwise — the stub backend can load a manifest but never execute
/// it).  For a caller-supplied directory use [`backend_available`]
/// plus its own manifest check.
pub fn artifacts_present() -> bool {
    backend_available() && artifacts_dir().join("manifest.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_backend_is_a_typed_error_not_a_panic() {
        let dir = std::env::temp_dir().join(format!("sparq-rt-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "artifact\tqnn_w2a2\tqnn_w2a2.hlo.txt\tbatch=16\twbits=2\tabits=2\n",
        )
        .unwrap();
        let rt = Runtime::load(&dir).unwrap();
        assert_eq!(rt.models(), vec!["qnn_w2a2"]);
        match rt.exec_f32("qnn_w2a2", &[]) {
            Err(RuntimeError::Backend(m)) => assert!(m.contains("pjrt"), "{m}"),
            other => panic!("expected Backend error, got {other:?}"),
        }
        assert!(matches!(rt.exec_f32("nope", &[]), Err(RuntimeError::UnknownModel(_))));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_dir_is_io_error() {
        assert!(matches!(
            Runtime::load("/definitely/not/a/dir"),
            Err(RuntimeError::Io(_))
        ));
    }
}
