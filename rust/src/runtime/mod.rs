//! The PJRT runtime: loads the AOT-compiled HLO-text artifacts that
//! `python/compile/aot.py` produced (`make artifacts`) and executes
//! them on the CPU PJRT client — python never runs on this path.
//!
//! Interchange is HLO *text* (see `aot.py` and DESIGN.md: jax >= 0.5
//! emits 64-bit-id protos that the crate's xla_extension 0.5.1
//! rejects; the text parser reassigns ids).  Every artifact is lowered
//! with `return_tuple=True`, so results unwrap with `to_tuple1()`.

pub mod manifest;
pub mod testset;

pub use manifest::{Artifact, Manifest};
pub use testset::TestSet;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use thiserror::Error;

#[derive(Debug, Error)]
pub enum RuntimeError {
    #[error("artifact directory problem: {0}")]
    Io(#[from] std::io::Error),
    #[error("manifest: {0}")]
    Manifest(String),
    #[error("xla/pjrt: {0}")]
    Xla(#[from] xla::Error),
    #[error("unknown model '{0}' (is it in artifacts/manifest.txt?)")]
    UnknownModel(String),
}

/// A loaded, compiled inference runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    pub manifest: Manifest,
    pub dir: PathBuf,
}

impl Runtime {
    /// Load every artifact in `dir` (compiling each HLO module once).
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime, RuntimeError> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.txt"))?;
        let client = xla::PjRtClient::cpu()?;
        let mut exes = HashMap::new();
        for art in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(dir.join(&art.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            exes.insert(art.name.clone(), client.compile(&comp)?);
        }
        Ok(Runtime { client, exes, manifest, dir })
    }

    /// Names of the loaded models.
    pub fn models(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.exes.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable, RuntimeError> {
        self.exes.get(name).ok_or_else(|| RuntimeError::UnknownModel(name.into()))
    }

    /// Execute a model whose inputs and output are f32 tensors.
    /// `inputs` = (data, dims) pairs; returns the flattened f32 output.
    pub fn exec_f32(
        &self,
        name: &str,
        inputs: &[(&[f32], &[i64])],
    ) -> Result<Vec<f32>, RuntimeError> {
        let lits = inputs
            .iter()
            .map(|(data, dims)| xla::Literal::vec1(data).reshape(dims))
            .collect::<Result<Vec<_>, _>>()?;
        let result = self.exe(name)?.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?.to_vec::<f32>()?)
    }

    /// Execute a model whose inputs and output are i32 tensors.
    pub fn exec_i32(
        &self,
        name: &str,
        inputs: &[(&[i32], &[i64])],
    ) -> Result<Vec<i32>, RuntimeError> {
        let lits = inputs
            .iter()
            .map(|(data, dims)| xla::Literal::vec1(data).reshape(dims))
            .collect::<Result<Vec<_>, _>>()?;
        let result = self.exe(name)?.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?.to_vec::<i32>()?)
    }
}

/// The repo-conventional artifacts directory, overridable via
/// `SPARQ_ARTIFACTS` (used by every example and bench).
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("SPARQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// True if `make artifacts` has been run (integration tests and benches
/// skip politely otherwise).
pub fn artifacts_present() -> bool {
    artifacts_dir().join("manifest.txt").exists()
}
