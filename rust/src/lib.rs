//! # sparq — a systems reproduction of *Sparq: A Custom RISC-V Vector
//! Processor for Efficient Sub-Byte Quantized Inference* (Dupuis et al., 2023)
//!
//! The crate is organised bottom-up (see `DESIGN.md` at the repo root):
//!
//! * [`isa`] — the RISC-V "V" 1.0 instruction subset Ara implements, plus
//!   the paper's custom `vmacsr` multiply-shift-accumulate instruction
//!   (encoder / decoder / disassembler, faithful 32-bit encodings).
//! * [`arch`] — processor configuration: lane count, VLEN, which
//!   functional units exist (the FPU is removable — that *is* Sparq).
//! * [`sim`] — a cycle-approximate, functionally-exact simulator of the
//!   Ara/Sparq vector machine: VRF, MFPU/ALU/VLSU/SLDU units, chaining,
//!   per-unit utilization counters.  Machines reset in place and are
//!   recycled through [`sim::MachinePool`] instead of reallocated.  The
//!   hot path pre-compiles traces to micro-ops and executes them
//!   word-parallel ([`sim::CompiledProgram`] +
//!   `Machine::run_compiled`, DESIGN.md §Perf).
//! * [`ulppack`] — the ULPPACK P1 packing calculus: container layouts,
//!   overflow-free regions, local-accumulation and spill cadences.
//! * [`kernels`] — the "hand-written inline assembly" of the paper as
//!   instruction-stream builders: fp32/int16 baselines, native ULPPACK,
//!   and the `vmacsr` LP/ULP conv2d of Algorithm 1.  Kernels follow a
//!   compile-once/execute-many split: `compile_conv` bakes a reusable
//!   [`kernels::CompiledConv`] (weights + layout in the stream),
//!   `CompiledConv::execute` rebinds activations into a pooled machine,
//!   and [`kernels::ProgramCache`] memoizes compilations behind a
//!   content key (see DESIGN.md §"Compile once, execute many").
//!   [`kernels::autotune`] measures the candidate variants per
//!   (processor, layer shape, precision) and memoizes the ranking in
//!   the same cache (DESIGN.md §"Mixed precision & autotuning").
//! * [`power`] — the GF22FDX-calibrated analytical area/power/fmax model
//!   behind Table II.
//! * [`qnn`] — the quantized CNN graph (explicit `preds` edges: chains,
//!   residual joins, depthwise and dense-head DAGs), its DAG-aware
//!   shape/precision validation, and the dataflow compiler
//!   ([`qnn::compiled::CompiledQnn`], DESIGN.md §Graph programs) that
//!   turns the whole network into ONE chained multi-layer program over
//!   a liveness-planned activation arena — per-layer convs whose
//!   inputs rebind to the producing layer's output region,
//!   zero-padding/requantize/maxpool/eltwise-join/GAP+FC as real
//!   instruction streams, cached whole in the [`ProgramCache`] under a
//!   graph-level key.  `qnn::schedule` reads per-layer cycles off one
//!   real end-to-end run.
//! * [`runtime`] — artifact loading and execution backends: the PJRT
//!   path (behind the off-by-default `pjrt` feature; the `xla` crate is
//!   not vendored) and the simulator-backed models
//!   ([`runtime::simconv`]): a single conv, or the whole network
//!   ([`runtime::SimQnnModel`]) classifying through the cached
//!   dataflow program with no artifacts at all.
//! * [`coordinator`] — the serving stack: request queues, dynamic
//!   batcher, worker pool, latency metrics.  Workers share one
//!   [`kernels::ProgramCache`] via `Arc` and own a private machine
//!   pool each (compile-once/execute-many serving).  Two request
//!   paths: the generic executor [`coordinator::Server`] and the
//!   batched QNN path ([`coordinator::QnnBatchServer`], DESIGN.md
//!   §Serving) over batch-B compiled arenas with sharded queues.
//! * [`benchcheck`] — the CI perf-regression gate: parses
//!   `BENCH_*.json` and compares every deterministic cycle field
//!   against `ci/bench_baselines/` at tolerance 0 (`sparq
//!   bench-check`).
//! * [`report`] — paper-style table/figure printers (Fig. 4, Fig. 5,
//!   Table I, Table II).
//! * [`config`] — the hand-rolled key=value config system and presets.
//! * [`testutil`] — a tiny property-testing harness (xorshift PRNG).

pub mod arch;
pub mod benchcheck;
pub mod config;
pub mod coordinator;
pub mod isa;
pub mod kernels;
pub mod power;
pub mod qnn;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod testutil;
pub mod ulppack;

pub use arch::ProcessorConfig;
pub use kernels::{CompiledConv, ProgramCache};
pub use sim::{CompiledProgram, Machine, MachinePool, Program};
